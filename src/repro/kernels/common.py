"""Shared helpers for Pallas kernels: padding, blocking, interpret policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """Run kernels in interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def pad_axis(x: jax.Array, axis: int, multiple: int, value) -> jax.Array:
    """Pad ``axis`` of x up to the next multiple of ``multiple`` with ``value``."""
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return jnp.pad(x, widths, constant_values=value)


def as_2d_blocks(flat: jax.Array, cols: int):
    """Reshape a 1-D array to (rows, cols), padding with zeros.

    Returns (blocked, original_size).
    """
    n = flat.shape[0]
    padded = pad_axis(flat, 0, cols, 0)
    return padded.reshape(-1, cols), n


def next_pow2(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p
