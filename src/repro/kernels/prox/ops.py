"""Public entry points for the fused FedEPM client update."""
from __future__ import annotations

from typing import Literal

import jax

from repro.kernels.prox.prox import prox_update_pallas
from repro.kernels.prox.ref import prox_update_ref

Impl = Literal["pallas", "ref"]


def prox_update(wi: jax.Array, wtau: jax.Array, g: jax.Array, mu, lam, eta,
                *, impl: Impl = "pallas", block_r: int = 256,
                interpret: bool | None = None) -> jax.Array:
    if impl == "pallas":
        return prox_update_pallas(wi, wtau, g, mu, lam, eta,
                                  block_r=block_r, interpret=interpret)
    if impl == "ref":
        return prox_update_ref(wi, wtau, g, mu, lam, eta)
    raise ValueError(f"unknown prox impl {impl!r}")


def prox_update_tree(tree_wi, tree_wtau, tree_g, mu, lam, eta,
                     *, impl: Impl = "ref", interpret: bool | None = None):
    """Leaf-wise fused update over parameter pytrees."""

    def per_leaf(wi, wtau, g):
        return prox_update(wi, wtau, g, mu, lam, eta, impl=impl,
                           interpret=interpret)

    return jax.tree_util.tree_map(per_leaf, tree_wi, tree_wtau, tree_g)
