"""Pure-jnp oracle for the fused FedEPM client update, paper eq. (20).

Given the broadcast point w^tau, the client's current iterate w_i^k, the
round gradient g_i = grad f_i(w^tau), and the (already-updated) proximal
weight mu_{i,k+1}:

    wt  = mu * (w_i - w_tau) - g
    out = w_tau + soft(wt, lam) / (eta + mu)

This is the exact closed-form solution of the linearised sub-problem (23).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def soft(t: jax.Array, a) -> jax.Array:
    return jnp.sign(t) * jnp.maximum(jnp.abs(t) - a, 0.0)


def prox_update_ref(wi: jax.Array, wtau: jax.Array, g: jax.Array,
                    mu, lam, eta) -> jax.Array:
    """Computed in fp32; result cast back to the client-state dtype (the
    distributed runtime stores W/Z in bf16 for the large archs)."""
    f32 = jnp.float32
    wt = mu * (wi.astype(f32) - wtau.astype(f32)) - g.astype(f32)
    out = wtau.astype(f32) + soft(wt, lam) / (eta + mu)
    return out.astype(wi.dtype)
