"""Pallas TPU kernel: fused FedEPM client update (paper eq. (20)).

Why a kernel: the inner FedEPM iteration is run k0 times per round over the
*entire parameter tree* and is purely elementwise -- it is memory-bound by
construction. Unfused, eq. (20) is five HBM-roundtrip ops
(sub, scale, sub, soft-threshold, scale-add); fused it is one read of
(w_i, w_tau, g) and one write, i.e. 4 streams instead of ~12. Block shape
(block_r, 128) keeps the lane dimension hardware-aligned; the scalar triple
(mu, lam, eta) rides along as a (1, 4) VMEM operand mapped to every block
(mu changes every iteration, so it must stay a runtime value -- baking it in
statically would force a retrace per step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret

_LANES = 128


def _prox_kernel(wi_ref, wtau_ref, g_ref, s_ref, o_ref):
    wi = wi_ref[...].astype(jnp.float32)
    wtau = wtau_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    mu = s_ref[0, 0]
    lam = s_ref[0, 1]
    eta = s_ref[0, 2]
    wt = mu * (wi - wtau) - g
    soft = jnp.sign(wt) * jnp.maximum(jnp.abs(wt) - lam, 0.0)
    o_ref[...] = (wtau + soft / (eta + mu)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def _prox_call(wi, wtau, g, scalars, *, block_r: int, interpret: bool):
    R, C = wi.shape
    grid = (R // block_r,)
    blk = lambda i: (i, 0)
    spec = pl.BlockSpec((block_r, C), blk)
    return pl.pallas_call(
        _prox_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, pl.BlockSpec((1, 4), lambda i: (0, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((R, C), wi.dtype),
        interpret=interpret,
    )(wi, wtau, g, scalars)


def prox_update_pallas(wi: jax.Array, wtau: jax.Array, g: jax.Array,
                       mu, lam, eta, *, block_r: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """Fused eq. (20) update on arrays of any (matching) shape."""
    if interpret is None:
        interpret = default_interpret()
    shape = wi.shape
    n = wi.size
    cols = _LANES
    rows = -(-n // cols)
    # round rows up to a multiple of block_r
    rows = -(-rows // block_r) * block_r
    pad = rows * cols - n

    def flat(x):
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, cols)

    scalars = jnp.stack(
        [jnp.asarray(mu, jnp.float32), jnp.asarray(lam, jnp.float32),
         jnp.asarray(eta, jnp.float32), jnp.asarray(0.0, jnp.float32)]
    ).reshape(1, 4)
    out = _prox_call(flat(wi), flat(wtau), flat(g), scalars,
                     block_r=block_r, interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)
