"""Pallas TPU kernel for the Elastic-Net Solver (ENS), paper Algorithm 1.

TPU adaptation (see DESIGN.md §2): the paper's per-coordinate *data-dependent
sort* of the m client values is replaced by a **bitonic sorting network** over
a padded power-of-two axis -- a fixed schedule of log^2(P) vectorised
compare-exchange passes with no divergence, executed on the VPU. Using the
median identity (kernels/ens/ref.py) the whole ENS reduces to: build the
2m+1 candidate rows, sort, take the middle row.

Tiling: the coordinate axis n is tiled into ``block_n``-wide VMEM blocks
(lane-aligned, multiples of 128); the client axis m stays whole inside the
block since m is small (#client groups on the mesh). VMEM working set per
block is P * block_n * 4 bytes with P = next_pow2(2m+1) -- e.g. m=32,
block_n=512 -> 128 KiB, far under the ~16 MiB/core VMEM budget, leaving room
for double buffering of the input stream from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, next_pow2, pad_axis

_NEG = -3.0e38  # sentinels well outside any fp32 parameter value
_POS = 3.0e38


def _bitonic_sort_axis0(x: jax.Array, P: int) -> jax.Array:
    """Sort (P, B) ascending along axis 0 with a static bitonic network."""
    k = 2
    while k <= P:
        j = k // 2
        while j >= 1:
            y = x.reshape(P // (2 * j), 2, j, -1)
            lo, hi = y[:, 0], y[:, 1]
            mn = jnp.minimum(lo, hi)
            mx = jnp.maximum(lo, hi)
            # row index of the pair's low element is a*2j (+c); bit k of it
            # only depends on the block index a because 2j <= k.
            a = lax.broadcasted_iota(jnp.int32, (P // (2 * j), 1, 1), 0)
            asc = (a * (2 * j)) & k == 0
            new_lo = jnp.where(asc, mn, mx)
            new_hi = jnp.where(asc, mx, mn)
            x = jnp.stack([new_lo, new_hi], axis=1).reshape(P, -1)
            j //= 2
        k *= 2
    return x


def _ens_kernel(z_ref, offs_ref, o_ref, *, m: int, P: int, med_idx: int,
                q_lo: int, q_hi: int):
    z = z_ref[...].astype(jnp.float32)  # (m, B)
    offs = offs_ref[...].astype(jnp.float32)  # (m+1, 1)
    B = z.shape[1]
    mean = jnp.mean(z, axis=0, keepdims=True)  # (1, B)
    cands = mean + offs  # (m+1, B)
    parts = [z, cands]
    if q_lo:
        parts.append(jnp.full((q_lo, B), _NEG, dtype=jnp.float32))
    if q_hi:
        parts.append(jnp.full((q_hi, B), _POS, dtype=jnp.float32))
    x = jnp.concatenate(parts, axis=0)  # (P, B)
    x = _bitonic_sort_axis0(x, P)
    o_ref[...] = x[med_idx][None, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _ens_call(Z: jax.Array, offs: jax.Array, *, block_n: int, interpret: bool):
    m, n = Z.shape
    C = 2 * m + 1
    P = next_pow2(C)
    q = P - C
    q_lo, q_hi = q // 2, q - q // 2
    med_idx = m + q_lo

    Zp = pad_axis(Z, 1, block_n, 0)
    np_ = Zp.shape[1]
    grid = (np_ // block_n,)
    out = pl.pallas_call(
        functools.partial(
            _ens_kernel, m=m, P=P, med_idx=med_idx, q_lo=q_lo, q_hi=q_hi
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
            pl.BlockSpec((m + 1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), Z.dtype),
        interpret=interpret,
    )(Zp, offs)
    return out[0, :n]


def ens_offsets(m: int, lam, eta, dtype=jnp.float32) -> jax.Array:
    """The m+1 interior candidate offsets (lam/eta)*(2a-m)/m, shape (m+1, 1)."""
    a = jnp.arange(m + 1, dtype=dtype)
    return ((lam / eta) * (2.0 * a - m) / m).reshape(m + 1, 1)


def ens_pallas(Z: jax.Array, lam, eta, *, block_n: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """ENS over Z (m, n) -> (n,) via the Pallas kernel."""
    if Z.ndim != 2:
        raise ValueError(f"ens_pallas expects (m, n); got {Z.shape}")
    if interpret is None:
        interpret = default_interpret()
    offs = ens_offsets(Z.shape[0], lam, eta, dtype=jnp.float32)
    return _ens_call(Z, offs, block_n=block_n, interpret=interpret)
