"""Pure-jnp oracles for the Elastic-Net Solver (ENS), paper Lemma III.1/III.2.

ENS solves, coordinate-wise over j in [n],

    w*_j = argmin_w  sum_{i=1..m} ( lam*|w - Z_ij| + (eta/2)*(w - Z_ij)^2 )

Three implementations are provided:

``ens_ref``     -- the production-quality jnp reference (median identity, see
                   below). O(n * m log m). This is the oracle the Pallas
                   kernel is validated against and the jnp fallback used by
                   the distributed runtime when kernels are disabled.
``ens_oracle``  -- brute-force argmin over the full candidate set by direct
                   objective evaluation. O(n * m^2). Used only in tests as
                   an independently-correct ground truth.
``ens_paper``   -- the *literal* Algorithm 1 from the paper. NOTE: as printed,
                   Lemma III.1 has a sign error (w(s) = mean - (lam/eta)(2s/m-1)
                   should be mean + ...; equivalently the paper's s counts
                   values *below* w while its selection rule sorts
                   *descending*), and ties/edge cases (e.g. m=1) are
                   mishandled. Kept for the reproduction-notes benchmark.

The median identity
-------------------
The objective is strictly convex and piecewise quadratic. Zeroing the
subgradient on the open interval with exactly ``a`` client values strictly
above w gives the interior candidate

    c_a = mean + (lam/eta) * (2a - m)/m ,     a = 0..m,

valid when it really lies in its interval; otherwise the solution sits at a
client value (knot) where the subdifferential interval covers zero. One can
check (and tests do, against ``ens_oracle``) that the unique minimizer is the
**median of the 2m+1 values {Z_1j..Z_mj, c_0..c_m}**:

* lam -> 0: all m+1 candidates collapse onto the mean, which then holds the
  majority of the 2m+1 slots => ENS = mean (plain FedAvg aggregation).
* eta -> 0: the candidates fly off to +-inf in balanced numbers => ENS =
  median of the client values, matching the paper's eq. (5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _check_2d(Z: jax.Array) -> None:
    if Z.ndim != 2:
        raise ValueError(f"ENS expects Z of shape (m, n); got {Z.shape}")


def ens_candidates(Z: jax.Array, lam, eta) -> jax.Array:
    """Stack the 2m+1 per-coordinate candidates: (2m+1, ...).

    Works on ANY trailing shape -- coordinate-wise along axis 0. (No
    flattening: under pjit a (m, ...)->(m, n) reshape of a feature-sharded
    leaf is unrepresentable and silently REPLICATES the whole tensor.)
    """
    m = Z.shape[0]
    mean = jnp.mean(Z, axis=0, keepdims=True)           # (1, ...)
    a = jnp.arange(m + 1, dtype=Z.dtype)
    offs = (lam / eta) * (2.0 * a - m) / m              # (m+1,)
    offs = offs.reshape((m + 1,) + (1,) * (Z.ndim - 1))
    cands = mean + offs                                  # (m+1, ...)
    return jnp.concatenate([Z, cands], axis=0)


def ens_ref(Z: jax.Array, lam, eta) -> jax.Array:
    """ENS via the median identity. Z: (m, ...) -> (...)."""
    stacked = ens_candidates(Z, lam, eta)  # (2m+1, ...)
    m = Z.shape[0]
    sorted_ = jnp.sort(stacked, axis=0)
    return sorted_[m]  # middle of 2m+1


def ens_objective(Z: jax.Array, w: jax.Array, lam, eta) -> jax.Array:
    """Per-coordinate objective sum_i lam|w - Z_i| + eta/2 (w - Z_i)^2.

    Z: (m, n); w: (..., n) broadcastable -> (..., n).
    """
    d = w[..., None, :] - Z  # (..., m, n)
    return jnp.sum(lam * jnp.abs(d) + 0.5 * eta * d * d, axis=-2)


def ens_oracle(Z: jax.Array, lam, eta) -> jax.Array:
    """Brute-force: evaluate the objective at every candidate, take argmin."""
    cands = ens_candidates(Z, lam, eta)  # (C, n)
    obj = ens_objective(Z, cands, lam, eta)  # (C, n)
    idx = jnp.argmin(obj, axis=0)  # (n,)
    return jnp.take_along_axis(cands, idx[None, :], axis=0)[0]


def ens_paper(Z: jax.Array, lam, eta) -> jax.Array:
    """Literal Algorithm 1 from the paper (first s passing the test).

    w_j(s) = mean_j - (lam/eta)(2s/m - 1), selected by
    w_desc[s] >= w_j(s) > w_desc[s+1] with w_desc[m+1] := -inf.
    As printed this returns non-minimizers in asymmetric/tied cases; see
    module docstring. Implemented faithfully for the comparison benchmark.
    """
    _check_2d(Z)
    m, n = Z.shape
    desc = -jnp.sort(-Z, axis=0)  # descending, (m, n)
    mean = jnp.mean(Z, axis=0)
    s = jnp.arange(1, m + 1, dtype=Z.dtype)
    ws = mean[None, :] - (lam / eta) * (2.0 * s[:, None] / m - 1.0)  # (m, n)
    upper = desc  # w_desc[s], s = 1..m
    lower = jnp.concatenate(
        [desc[1:], jnp.full((1, n), -jnp.inf, dtype=Z.dtype)], axis=0
    )  # w_desc[s+1]
    valid = (upper >= ws) & (ws > lower)  # (m, n)
    # first s (smallest index) passing the test, as in the paper's loop
    first = jnp.argmax(valid, axis=0)  # (n,)
    any_valid = jnp.any(valid, axis=0)
    picked = jnp.take_along_axis(ws, first[None, :], axis=0)[0]
    # the paper's loop would fall through without returning; fall back to mean
    return jnp.where(any_valid, picked, mean)


def ens_tree(tree_Z, lam, eta):
    """Apply ENS leaf-wise to a pytree whose leaves have a leading client axis.

    Each leaf has shape (m, ...); returns a pytree of leaves with shape (...).
    ENS is coordinate-wise, so reshaping to (m, -1) is exact.
    """

    return jax.tree_util.tree_map(lambda zi: ens_ref(zi, lam, eta),
                                  tree_Z)
