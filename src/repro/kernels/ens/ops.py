"""Public jit'd entry points for ENS with kernel/reference dispatch.

``ens(Z, lam, eta)``        -- (m, n) -> (n,), picks Pallas kernel or jnp ref.
``ens_tree(tree, lam, eta)`` -- leaf-wise over a pytree with leading client axis.
"""
from __future__ import annotations

from typing import Literal

import jax

from repro.kernels.ens import ref as _ref
from repro.kernels.ens.ens import ens_pallas

Impl = Literal["pallas", "ref", "oracle"]

# leaves above this many elements are processed in lax.map chunks over
# their axis-1 (the stacked-layer axis), so the (2m+1)-stacked sort
# buffer of a 30 GB MoE leaf never materialises at once (it also
# SERIALISES the per-chunk sorts -- without it the scheduler overlaps
# every leaf's sort and the transient peak is sum-of-leaves)
_CHUNK_THRESHOLD = 1 << 24


def _ens_ref_chunked(z, lam, eta):
    import jax.numpy as jnp
    from jax import lax

    if z.size <= _CHUNK_THRESHOLD or z.ndim < 2 or z.shape[1] < 2:
        return _ref.ens_ref(z, lam, eta)
    zs = jnp.moveaxis(z, 1, 0)  # (L, m, ...)
    out = lax.map(lambda zl: _ref.ens_ref(zl, lam, eta), zs)
    return out  # (L, ...) == the leaf layout with the client axis removed


def ens(Z: jax.Array, lam, eta, *, impl: Impl = "pallas",
        block_n: int = 512, interpret: bool | None = None) -> jax.Array:
    if impl == "pallas":
        return ens_pallas(Z, lam, eta, block_n=block_n, interpret=interpret)
    if impl == "ref":
        return _ref.ens_ref(Z, lam, eta)
    if impl == "oracle":
        return _ref.ens_oracle(Z, lam, eta)
    raise ValueError(f"unknown ENS impl {impl!r}")


def ens_tree(tree_Z, lam, eta, *, impl: Impl = "ref", block_n: int = 512,
             interpret: bool | None = None):
    """Leaf-wise ENS. Each leaf (m, ...) -> (...). Coordinate-wise, so exact.

    The "ref" path sorts along axis 0 WITHOUT flattening (a (m, -1) reshape
    of a sharded leaf is unrepresentable under SPMD and would replicate);
    the Pallas path flattens -- it runs on local 2-D blocks (shard_map or
    single device), where the reshape is free.
    """
    if impl == "ref":
        return jax.tree_util.tree_map(
            lambda z: _ens_ref_chunked(z, lam, eta).astype(z.dtype),
            tree_Z)

    def per_leaf(z):
        m = z.shape[0]
        out = ens(z.reshape(m, -1), lam, eta, impl=impl, block_n=block_n,
                  interpret=interpret)
        return out.reshape(z.shape[1:]).astype(z.dtype)

    return jax.tree_util.tree_map(per_leaf, tree_Z)
