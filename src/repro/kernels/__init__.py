"""Pallas TPU kernels with bit-identical jnp references.

One subpackage per op family (ens/, prox/, quant/), each following the
ref.py + <name>.py + ops.py convention documented in docs/kernels.md.
Kernels exist ONLY for compute hot-spots; callers import the ops modules
and select the implementation per call.
"""
