"""Pallas TPU kernel: batched column-bounded quantization for the codec.

Why a kernel: the fused multi-leaf upload codec (repro.sim.transport) lays
EVERY (leaf, client) pair of a pytree out as one row of a single padded
2-D array, so one kernel launch encodes the whole upload instead of one
launch per leaf. Rows differ in how many leading columns are live (the
per-leaf top-k keep count, or a dense leaf's un-padded width), so the
kernel fuses the quantize-dequantize chain with the live-column select:

    out[i, j] = Q_bits(x[i, j]; scale[i])  if j <  kcols[i]
                f[i, j]                    otherwise

Unfused that is ~8 HBM-roundtrip elementwise ops (scale bcast, div, dither
add, floor, clip, mul, iota compare, select); fused it is one read of
(x, f, dither) and one write.

Layout mirrors the row-wise quantize kernel (kernels/quant/quant.py): the
column axis n is tiled into ``block_n``-wide lane-aligned VMEM blocks, the
row axis stays whole inside the block, and the per-row (scale, kcols)
operands ride along as (m, 1) VMEM columns mapped to every block; the
global column index is reconstructed from ``pl.program_id``. The uint32
dither is an input -- NOT drawn in-kernel -- so the jnp reference
(ref.quantize_cols_ref) consumes the identical random stream and the two
agree bit-for-bit. VMEM per block: 4 * m * block_n * 4 B (x, f, dither,
out) -- m=128, block_n=512 -> 1 MiB, well under the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, pad_axis
from repro.kernels.quant.ref import quant_levels

_INV_2_32 = float(2.0 ** -32)


def _quant_cols_kernel(x_ref, f_ref, u_ref, s_ref, k_ref, o_ref, *, L: int,
                       stochastic: bool, block_n: int):
    x = x_ref[...].astype(jnp.float32)          # (m, B)
    s = s_ref[...].astype(jnp.float32)          # (m, 1)
    kc = k_ref[...]                             # (m, 1) int32
    delta = s * (1.0 / L)  # mul-by-reciprocal, matching ref (see ref.py)
    safe = jnp.where(delta > 0, delta, 1.0)
    if stochastic:
        u = u_ref[...].astype(jnp.float32) * _INV_2_32
    else:
        u = 0.5
    q = jnp.floor(x / safe + u)
    q = jnp.clip(q, -L, L)
    dq = jnp.where(delta > 0, q * safe, 0.0).astype(o_ref.dtype)
    col = pl.program_id(0) * block_n + jax.lax.broadcasted_iota(
        jnp.int32, x.shape, 1)
    o_ref[...] = jnp.where(col < kc, dq, f_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("bits", "stochastic", "block_n",
                                    "interpret"))
def _quant_cols_call(X, F, u32, scale, kcols, *, bits: int, stochastic: bool,
                     block_n: int, interpret: bool):
    m, n = X.shape
    L = quant_levels(bits)
    Xp = pad_axis(X, 1, block_n, 0)
    Fp = pad_axis(F, 1, block_n, 0)
    Up = pad_axis(u32, 1, block_n, 0)
    np_ = Xp.shape[1]
    grid = (np_ // block_n,)
    blk = pl.BlockSpec((m, block_n), lambda i: (0, i))
    col = pl.BlockSpec((m, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_quant_cols_kernel, L=L, stochastic=stochastic,
                          block_n=block_n),
        grid=grid,
        in_specs=[blk, blk, blk, col, col],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((m, np_), X.dtype),
        interpret=interpret,
    )(Xp, Fp, Up, scale.reshape(m, 1),
      kcols.reshape(m, 1).astype(jnp.int32))
    return out[:, :n]


def quantize_cols_pallas(X: jax.Array, F: jax.Array, scale: jax.Array,
                         kcols: jax.Array, bits: int,
                         u32: jax.Array | None = None, *, block_n: int = 512,
                         interpret: bool | None = None) -> jax.Array:
    """Column-bounded quantize-dequantize with fallback substitution.

    X, F: (m, n) values and per-position fallback; scale: (m,) per-row
    magnitude bound; kcols: (m,) live-column counts -- columns j < kcols[i]
    quantize, the rest return F bit-untouched; u32: (m, n) uint32 dither
    (None => deterministic round-half-up). Semantics identical to
    ref.quantize_cols_ref.
    """
    if X.ndim != 2 or X.shape != F.shape:
        raise ValueError(
            f"quantize_cols_pallas expects matching (m, n); got {X.shape} "
            f"vs {F.shape}")
    if interpret is None:
        interpret = default_interpret()
    stochastic = u32 is not None
    if u32 is None:
        u32 = jnp.zeros(X.shape, jnp.uint32)
    return _quant_cols_call(X, F, u32, scale, kcols, bits=bits,
                            stochastic=stochastic, block_n=block_n,
                            interpret=interpret)
