"""Pallas TPU kernel: fused clip + Laplace-noise + quantize for DP uploads.

Why a kernel: the private upload path composes three elementwise stages --
l1-clip scaling, per-client Laplace perturbation, and the column-bounded
quantize-dequantize the codec already fuses (kernels/quant/batch.py).
Run sequentially that is three HBM round-trips over the full batched
(leaf, client)-row layout; fused it is one read of (x, f, dither-q,
noise) and one write:

    y[i, j]   = x[i, j] * clipf[i] + b[i] * lap[i, j]
    out[i, j] = Q_bits(y[i, j]; scale[i])  if j <  kcols[i]
                f[i, j]                    otherwise

The per-row operands (clipf, b, scale, kcols) ride along as (m, 1) VMEM
columns mapped to every block, exactly like batch.py; the quantizer's
uint32 dither plane AND the float32 unit-Laplace plane are inputs --
NOT drawn or transformed in-kernel -- so the jnp reference
(ref.private_quantize_cols_ref) consumes the identical streams and the
two agree bit-for-bit (see the ref docstring for why the inverse-CDF
transform must stay out of fusible bodies). VMEM per block:
5 * m * block_n * 4 B (x, f, u_q, lap, out) -- m=128, block_n=512 ->
1.25 MiB, well under the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, pad_axis
from repro.kernels.quant.ref import quant_levels

_INV_2_32 = float(2.0 ** -32)


def _private_cols_kernel(x_ref, f_ref, uq_ref, lap_ref, cf_ref, b_ref, s_ref,
                         k_ref, o_ref, *, L: int, block_n: int):
    x = x_ref[...].astype(jnp.float32)           # (m, B)
    cf = cf_ref[...].astype(jnp.float32)         # (m, 1)
    b = b_ref[...].astype(jnp.float32)           # (m, 1)
    s = s_ref[...].astype(jnp.float32)           # (m, 1)
    kc = k_ref[...]                              # (m, 1) int32
    lap = lap_ref[...].astype(jnp.float32)       # (m, B) unit Laplace
    y = x * cf + b * lap
    delta = s * (1.0 / L)  # mul-by-reciprocal, matching ref (see ref.py)
    safe = jnp.where(delta > 0, delta, 1.0)
    u = uq_ref[...].astype(jnp.float32) * _INV_2_32
    q = jnp.floor(y / safe + u)
    q = jnp.clip(q, -L, L)
    dq = jnp.where(delta > 0, q * safe, 0.0).astype(o_ref.dtype)
    col = pl.program_id(0) * block_n + jax.lax.broadcasted_iota(
        jnp.int32, y.shape, 1)
    o_ref[...] = jnp.where(col < kc, dq, f_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("bits", "block_n", "interpret"))
def _private_cols_call(X, F, u32q, lap, clipf, noise_b, scale, kcols, *,
                       bits: int, block_n: int, interpret: bool):
    m, n = X.shape
    L = quant_levels(bits)
    Xp = pad_axis(X, 1, block_n, 0)
    Fp = pad_axis(F, 1, block_n, 0)
    Uq = pad_axis(u32q, 1, block_n, 0)
    Lp = pad_axis(lap, 1, block_n, 0)
    np_ = Xp.shape[1]
    grid = (np_ // block_n,)
    blk = pl.BlockSpec((m, block_n), lambda i: (0, i))
    col = pl.BlockSpec((m, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_private_cols_kernel, L=L, block_n=block_n),
        grid=grid,
        in_specs=[blk, blk, blk, blk, col, col, col, col],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((m, np_), X.dtype),
        interpret=interpret,
    )(Xp, Fp, Uq, Lp, clipf.reshape(m, 1), noise_b.reshape(m, 1),
      scale.reshape(m, 1), kcols.reshape(m, 1).astype(jnp.int32))
    return out[:, :n]


def private_quantize_cols_pallas(X: jax.Array, F: jax.Array,
                                 clipf: jax.Array, noise_b: jax.Array,
                                 scale: jax.Array, kcols: jax.Array,
                                 bits: int, u32q: jax.Array, lap: jax.Array,
                                 *, block_n: int = 512,
                                 interpret: bool | None = None) -> jax.Array:
    """Fused clip + Laplace-noise + column-bounded quantize-dequantize.

    X, F: (m, n) values and per-position fallback; clipf, noise_b, scale:
    (m,) per-row clip factor, Laplace scale, and quantizer magnitude
    bound; kcols: (m,) live-column counts; u32q: (m, n) uint32 quantizer
    dither plane; lap: (m, n) float32 unit-Laplace noise plane (drawn by
    the caller). Semantics identical to ref.private_quantize_cols_ref.
    """
    if X.ndim != 2 or X.shape != F.shape:
        raise ValueError(
            f"private_quantize_cols_pallas expects matching (m, n); got "
            f"{X.shape} vs {F.shape}")
    if interpret is None:
        interpret = default_interpret()
    return _private_cols_call(X, F, u32q, lap, clipf, noise_b, scale,
                              kcols, bits=bits, block_n=block_n,
                              interpret=interpret)
