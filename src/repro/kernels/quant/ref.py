"""Pure-jnp oracle for uniform stochastic quantization (upload codec).

Per row i of X (a client's kept coordinates), values are snapped to the
uniform grid {j * delta_i : j in [-L, L]} with

    delta_i = scale_i / L,    L = 2^(bits-1) - 1,

so a row whose magnitudes are bounded by scale_i round-trips into ``bits``
bits per coordinate (sign + magnitude level).

Rounding:

  * stochastic (``u`` given): q = floor(x/delta + u), u ~ U[0,1) -- the
    classic unbiased dither: E[q*delta] = x for |x| <= L*delta.
  * deterministic (``u`` None): q = floor(x/delta + 1/2) (round-half-up),
    which keeps |q*delta - x| <= delta/2.

The random bits are SUPPLIED by the caller (uint32, same shape as X) rather
than drawn in-kernel, so the Pallas kernel and this reference consume the
identical dither and must agree bit-for-bit -- that is what the kernel test
asserts. Returns the DEQUANTIZED values (grid points, x.dtype); the byte
ledger (repro.sim.transport) accounts the wire size as bits/8 per kept
coordinate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INV_2_32 = float(2.0 ** -32)


def quant_levels(bits: int) -> int:
    """L = 2^(bits-1) - 1 grid steps each side of zero."""
    if bits < 2:
        raise ValueError(f"need bits >= 2 (sign + >=1 magnitude bit); got {bits}")
    return (1 << (bits - 1)) - 1


def quantize_ref(X: jax.Array, scale: jax.Array, bits: int,
                 u32: jax.Array | None = None) -> jax.Array:
    """Quantize-dequantize X (m, n) row-wise. scale: (m,); u32: (m, n) or None.

    Rows with scale <= 0 (all-zero rows) quantize to exact zeros.
    """
    L = quant_levels(bits)
    x = X.astype(jnp.float32)
    s = scale.astype(jnp.float32).reshape(-1, 1)
    # multiply by the precomputed reciprocal rather than divide by L: XLA
    # folds div-by-constant into mul-by-reciprocal inside jit (the Pallas
    # path) but not outside, which would break the bit-for-bit kernel/ref
    # contract by 1 ulp of delta
    delta = s * (1.0 / L)
    safe = jnp.where(delta > 0, delta, 1.0)
    if u32 is None:
        u = 0.5
    else:
        u = u32.astype(jnp.float32) * _INV_2_32
    q = jnp.floor(x / safe + u)
    q = jnp.clip(q, -L, L)
    out = jnp.where(delta > 0, q * safe, 0.0)
    return out.astype(X.dtype)


def quantize_cols_ref(X: jax.Array, F: jax.Array, scale: jax.Array,
                      kcols: jax.Array, bits: int,
                      u32: jax.Array | None = None) -> jax.Array:
    """Column-bounded quantize-dequantize with fallback substitution.

    The batched upload codec lays every (leaf, client) pair out as one row
    of a padded 2-D array (repro.sim.transport); rows then differ in how
    many leading columns are LIVE -- kept top-k values for a sparse leaf,
    real (un-padded) coordinates for a dense one. Per row i:

        out[i, j] = quantize(X[i, j])   if j <  kcols[i]
                    F[i, j]             otherwise

    i.e. live columns snap to the ``bits``-bit grid of ``scale[i]`` exactly
    as ``quantize_ref`` does, dead columns pass the fallback F through
    bit-untouched (the server's stale copy for a memoryless codec, zeros
    for the EF residual path, the raw input for plain padding). X, F:
    (m, n); scale: (m,); kcols: (m,) int32; u32: (m, n) dither or None.
    """
    L = quant_levels(bits)
    x = X.astype(jnp.float32)
    s = scale.astype(jnp.float32).reshape(-1, 1)
    delta = s * (1.0 / L)  # mul-by-reciprocal: see the note on quantize_ref
    safe = jnp.where(delta > 0, delta, 1.0)
    if u32 is None:
        u = 0.5
    else:
        u = u32.astype(jnp.float32) * _INV_2_32
    q = jnp.floor(x / safe + u)
    q = jnp.clip(q, -L, L)
    dq = jnp.where(delta > 0, q * safe, 0.0).astype(X.dtype)
    col = jnp.arange(X.shape[1], dtype=jnp.int32)[None, :]
    return jnp.where(col < kcols.reshape(-1, 1).astype(jnp.int32), dq, F)


def laplace_from_u32(u32: jax.Array) -> jax.Array:
    """Unit-scale Laplace noise from caller-supplied uint32 bits.

    Maps u32 -> u = u32 * 2^-32 - 0.5 in [-0.5, 0.5), then applies the
    inverse CDF ``eps = -sign(u) * log1p(-2|u|)`` (the same transform
    ``repro.core.dp.sample_laplace`` uses). ``|u|`` is clamped a hair
    below 0.5 so the u32 == 0 endpoint cannot produce an infinity. The
    bits are SUPPLIED (never drawn here) so the Pallas kernel and this
    reference consume the identical stream and agree bit-for-bit.
    """
    u = u32.astype(jnp.float32) * _INV_2_32 - 0.5
    a = jnp.minimum(2.0 * jnp.abs(u), 1.0 - 1e-7)
    return -jnp.sign(u) * jnp.log1p(-a)


def private_quantize_cols_ref(X: jax.Array, F: jax.Array, clipf: jax.Array,
                              noise_b: jax.Array, scale: jax.Array,
                              kcols: jax.Array, bits: int, u32q: jax.Array,
                              lap: jax.Array) -> jax.Array:
    """Fused clip + Laplace-noise + column-bounded quantize (upload DP).

    Per row i (one (leaf, client) pair of the batched upload layout):

        y[i, j]   = X[i, j] * clipf[i] + noise_b[i] * lap[i, j]
        out[i, j] = Q_bits(y[i, j]; scale[i])  if j <  kcols[i]
                    F[i, j]                    otherwise

    ``clipf`` is the per-client l1-clip factor (1.0 in surrogate mode),
    ``noise_b`` the per-client Laplace scale ``b = delta_hat / eps`` --
    both computed host/caller-side from static config so the kernel stays
    branch-free. ``lap`` is the UNIT-scale Laplace plane, float32,
    supplied by the caller (the sim draws it host-side in a standalone
    program, ``repro.sim.transport.draw_unit_noise``): like the dither,
    noise enters as data so the Pallas kernel, this reference, and both
    sim engines consume the identical stream -- and because the
    ``log1p`` inverse CDF is a transcendental whose last ulp shifts with
    XLA:CPU's fusion context, computing it in-kernel would break the
    engines' bit-for-bit contract. ``scale`` is the caller's bound on the
    CLIPPED, pre-noise magnitudes, so a noisy value can land past the
    grid edge and saturate at +-L*delta -- bounded-output behavior that
    is standard for quantized DP uploads (docs/privacy.md); an all-zero
    row (scale 0) quantizes to exact zeros, noise included. X, F, u32q,
    lap: (m, n); clipf, noise_b, scale: (m,); kcols: (m,) int32.
    """
    L = quant_levels(bits)
    x = X.astype(jnp.float32)
    cf = clipf.astype(jnp.float32).reshape(-1, 1)
    b = noise_b.astype(jnp.float32).reshape(-1, 1)
    y = x * cf + b * lap.astype(jnp.float32)
    s = scale.astype(jnp.float32).reshape(-1, 1)
    delta = s * (1.0 / L)  # mul-by-reciprocal: see the note on quantize_ref
    safe = jnp.where(delta > 0, delta, 1.0)
    u = u32q.astype(jnp.float32) * _INV_2_32
    q = jnp.floor(y / safe + u)
    q = jnp.clip(q, -L, L)
    dq = jnp.where(delta > 0, q * safe, 0.0).astype(X.dtype)
    col = jnp.arange(X.shape[1], dtype=jnp.int32)[None, :]
    return jnp.where(col < kcols.reshape(-1, 1).astype(jnp.int32), dq, F)


def ef_accumulate_ref(Z: jax.Array, H: jax.Array, scale: jax.Array, bits: int,
                      u32: jax.Array | None = None) -> jax.Array:
    """Error-feedback accumulate/compress step: H + Q_bits(Z - H), row-wise.

    Z, H: (m, n); scale: (m,) magnitude bound of the RESIDUAL Z - H; u32:
    (m, n) dither or None (round-half-up). Returns the server/client shared
    reconstruction h_i' = h_i + Q(z_i - h_i) -- what the wire carries is the
    quantized residual, so the codec memory contracts toward z_i instead of
    discarding the quantization error each round (EF21-style).

    Every arithmetic step mirrors ``ef_accumulate_pallas`` (float32 residual,
    mul-by-reciprocal grid, f32 accumulate, single final cast) so the two
    agree bit-for-bit; rows with scale <= 0 pass H through exactly.
    """
    L = quant_levels(bits)
    z = Z.astype(jnp.float32)
    h = H.astype(jnp.float32)
    r = z - h
    s = scale.astype(jnp.float32).reshape(-1, 1)
    delta = s * (1.0 / L)
    safe = jnp.where(delta > 0, delta, 1.0)
    if u32 is None:
        u = 0.5
    else:
        u = u32.astype(jnp.float32) * _INV_2_32
    q = jnp.floor(r / safe + u)
    q = jnp.clip(q, -L, L)
    dec = jnp.where(delta > 0, q * safe, 0.0)
    return (h + dec).astype(Z.dtype)
