"""Pallas TPU kernel: fused error-feedback accumulate/compress step.

The EF21-style codec keeps a per-client memory h_i of what the server has
reconstructed so far; each round the client transmits Q(z_i - h_i) and BOTH
sides update h_i <- h_i + Q(z_i - h_i). Unfused that chain is ~8
HBM-roundtrip elementwise ops (sub, scale bcast, div, dither add, floor,
clip, mul, add); fused it is one read of (z, h, dither) and one write of
the new memory -- the same memory-bound argument as the plain quantizer
(kernels/quant/quant.py), with the residual and the accumulate folded in.

Layout is identical to the quantize kernel: the coordinate axis n is tiled
into ``block_n``-wide lane-aligned VMEM blocks, the client axis m stays
whole inside the block, and the per-row residual scale rides along as an
(m, 1) VMEM operand mapped to every block. The uint32 dither is an input --
NOT drawn in-kernel -- so the jnp reference (ef_accumulate_ref) consumes
the identical random stream and the two agree bit-for-bit. VMEM per block:
4 * m * block_n * 4 B (z, h, dither, out) -- m=128, block_n=512 -> 1 MiB,
well under the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, pad_axis
from repro.kernels.quant.ref import quant_levels

_INV_2_32 = float(2.0 ** -32)


def _ef_kernel(z_ref, h_ref, u_ref, s_ref, o_ref, *, L: int,
               stochastic: bool):
    z = z_ref[...].astype(jnp.float32)          # (m, B)
    h = h_ref[...].astype(jnp.float32)          # (m, B)
    s = s_ref[...].astype(jnp.float32)          # (m, 1)
    r = z - h
    delta = s * (1.0 / L)  # mul-by-reciprocal, matching ref (see ref.py)
    safe = jnp.where(delta > 0, delta, 1.0)
    if stochastic:
        u = u_ref[...].astype(jnp.float32) * _INV_2_32
    else:
        u = 0.5
    q = jnp.floor(r / safe + u)
    q = jnp.clip(q, -L, L)
    dec = jnp.where(delta > 0, q * safe, 0.0)
    o_ref[...] = (h + dec).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "stochastic", "block_n",
                                    "interpret"))
def _ef_call(Z, H, u32, scale, *, bits: int, stochastic: bool, block_n: int,
             interpret: bool):
    m, n = Z.shape
    L = quant_levels(bits)
    Zp = pad_axis(Z, 1, block_n, 0)
    Hp = pad_axis(H, 1, block_n, 0)
    Up = pad_axis(u32, 1, block_n, 0)
    np_ = Zp.shape[1]
    grid = (np_ // block_n,)
    blk = pl.BlockSpec((m, block_n), lambda i: (0, i))
    out = pl.pallas_call(
        functools.partial(_ef_kernel, L=L, stochastic=stochastic),
        grid=grid,
        in_specs=[blk, blk, blk, pl.BlockSpec((m, 1), lambda i: (0, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((m, np_), Z.dtype),
        interpret=interpret,
    )(Zp, Hp, Up, scale.reshape(m, 1))
    return out[:, :n]


def ef_accumulate_pallas(Z: jax.Array, H: jax.Array, scale: jax.Array,
                         bits: int, u32: jax.Array | None = None, *,
                         block_n: int = 512,
                         interpret: bool | None = None) -> jax.Array:
    """Fused H + Q_bits(Z - H), row-wise on the uniform ``bits``-bit grid.

    Z, H: (m, n); scale: (m,) per-row magnitude bound of the residual Z - H;
    u32: (m, n) uint32 dither (None => deterministic round-half-up).
    Semantics identical to ref.ef_accumulate_ref.
    """
    if Z.ndim != 2 or Z.shape != H.shape:
        raise ValueError(
            f"ef_accumulate_pallas expects matching (m, n); got {Z.shape} "
            f"vs {H.shape}")
    if interpret is None:
        interpret = default_interpret()
    stochastic = u32 is not None
    if u32 is None:
        u32 = jnp.zeros(Z.shape, jnp.uint32)
    return _ef_call(Z, H, u32, scale, bits=bits, stochastic=stochastic,
                    block_n=block_n, interpret=interpret)
