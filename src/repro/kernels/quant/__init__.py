"""Upload-codec kernels: uniform stochastic quantization + error feedback.

Layout follows the repo's kernel convention (see docs/kernels.md): ``ref.py``
holds the pure-jnp oracle, ``quant.py``/``ef.py`` the Pallas TPU kernels,
``ops.py`` the public impl-dispatching entry points. The Pallas and jnp
paths consume caller-supplied dither bits and agree BIT-FOR-BIT
(tests/test_kernels_quant.py).
"""
from repro.kernels.quant.ops import ef_accumulate, quantize  # noqa: F401
