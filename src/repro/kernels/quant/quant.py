"""Pallas TPU kernel: uniform stochastic quantization for the upload codec.

Why a kernel: on the simulated-federation hot path every selected client's
upload is encoded each round; quantize-dequantize is purely elementwise and
memory-bound. Unfused it is ~6 HBM-roundtrip ops (scale bcast, div, dither
add, floor, clip, mul); fused it is one read of (x, dither) and one write.

Layout mirrors the ENS kernel: the coordinate axis n is tiled into
``block_n``-wide VMEM blocks (lane-aligned), the client axis m stays whole
inside the block (m is small); the per-row scale rides along as an (m, 1)
VMEM operand mapped to every block. The uint32 dither is an input -- NOT
drawn in-kernel -- so the jnp reference (kernels/quant/ref.py) consumes the
identical random stream and the two agree bit-for-bit; on-TPU PRNG would
make the codec unreproducible across backends and untestable in interpret
mode. VMEM per block: 3 * m * block_n * 4 B (x, dither, out) -- m=128,
block_n=512 -> 768 KiB, comfortably under the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, pad_axis
from repro.kernels.quant.ref import quant_levels

_INV_2_32 = float(2.0 ** -32)


def _quant_kernel(x_ref, u_ref, s_ref, o_ref, *, L: int, stochastic: bool):
    x = x_ref[...].astype(jnp.float32)          # (m, B)
    s = s_ref[...].astype(jnp.float32)          # (m, 1)
    delta = s * (1.0 / L)  # mul-by-reciprocal, matching ref (see ref.py)
    safe = jnp.where(delta > 0, delta, 1.0)
    if stochastic:
        u = u_ref[...].astype(jnp.float32) * _INV_2_32
    else:
        u = 0.5
    q = jnp.floor(x / safe + u)
    q = jnp.clip(q, -L, L)
    o_ref[...] = jnp.where(delta > 0, q * safe, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "stochastic", "block_n",
                                    "interpret"))
def _quant_call(X, u32, scale, *, bits: int, stochastic: bool, block_n: int,
                interpret: bool):
    m, n = X.shape
    L = quant_levels(bits)
    Xp = pad_axis(X, 1, block_n, 0)
    Up = pad_axis(u32, 1, block_n, 0)
    np_ = Xp.shape[1]
    grid = (np_ // block_n,)
    blk = pl.BlockSpec((m, block_n), lambda i: (0, i))
    out = pl.pallas_call(
        functools.partial(_quant_kernel, L=L, stochastic=stochastic),
        grid=grid,
        in_specs=[blk, blk, pl.BlockSpec((m, 1), lambda i: (0, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((m, np_), X.dtype),
        interpret=interpret,
    )(Xp, Up, scale.reshape(m, 1))
    return out[:, :n]


def quantize_pallas(X: jax.Array, scale: jax.Array, bits: int,
                    u32: jax.Array | None = None, *, block_n: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """Quantize-dequantize X (m, n) row-wise on the uniform ``bits``-bit grid.

    scale: (m,) per-row magnitude bound; u32: (m, n) uint32 dither (None =>
    deterministic round-half-up). Semantics identical to ref.quantize_ref.
    """
    if X.ndim != 2:
        raise ValueError(f"quantize_pallas expects (m, n); got {X.shape}")
    if interpret is None:
        interpret = default_interpret()
    stochastic = u32 is not None
    if u32 is None:
        u32 = jnp.zeros(X.shape, jnp.uint32)
    return _quant_call(X, u32, scale, bits=bits, stochastic=stochastic,
                       block_n=block_n, interpret=interpret)
