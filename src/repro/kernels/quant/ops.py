"""Public entry points for the upload-codec quantizer with impl dispatch.

Two ops, each a (Pallas kernel, bit-identical jnp reference) pair:

``quantize``      -- memoryless row-wise quantize-dequantize (the classic
                     stochastic-quantization codec path).
``ef_accumulate`` -- fused error-feedback step H + Q(Z - H): compress the
                     residual against the shared codec memory and accumulate
                     the decoded value back into it (EF21-style).
``quantize_cols`` -- batched multi-leaf codec step: quantize each row's
                     leading kcols[i] live columns, pass the fallback
                     through elsewhere (the padded 2-D layout the fused
                     transport codec uses for whole-pytree encodes).
``private_quantize_cols`` -- quantize_cols with a fused per-row clip
                     factor and Laplace perturbation in front (the DP
                     upload path, repro.sim.transport.private_roundtrip).
"""
from __future__ import annotations

from typing import Literal

import jax

from repro.kernels.quant import ref as _ref
from repro.kernels.quant.batch import quantize_cols_pallas
from repro.kernels.quant.ef import ef_accumulate_pallas
from repro.kernels.quant.privacy import private_quantize_cols_pallas
from repro.kernels.quant.quant import quantize_pallas

Impl = Literal["pallas", "ref"]


def quantize(X: jax.Array, scale: jax.Array, bits: int,
             u32: jax.Array | None = None, *, impl: Impl = "ref",
             block_n: int = 512, interpret: bool | None = None) -> jax.Array:
    """Row-wise uniform (stochastic) quantize-dequantize.

    X: (m, n) values; scale: (m,) per-row magnitude bound; bits: wire bits
    per coordinate (>= 2); u32: optional (m, n) uint32 dither -- present =>
    unbiased stochastic rounding, absent => deterministic round-half-up.
    Returns grid-snapped values in X.dtype.
    """
    if impl == "pallas":
        return quantize_pallas(X, scale, bits, u32, block_n=block_n,
                               interpret=interpret)
    if impl == "ref":
        return _ref.quantize_ref(X, scale, bits, u32)
    raise ValueError(f"unknown quant impl {impl!r}")


# the ref MUST run jitted: the trailing accumulate h + q*delta is fused to
# an FMA by XLA (one rounding) but evaluated as mul-then-add eagerly (two
# roundings) -- same class of hazard as the div-vs-reciprocal note in
# ref.py, and it breaks the bit-for-bit kernel/ref contract by 1 ulp
_ef_ref_jit = jax.jit(_ref.ef_accumulate_ref, static_argnames=("bits",))


def ef_accumulate(Z: jax.Array, H: jax.Array, scale: jax.Array, bits: int,
                  u32: jax.Array | None = None, *, impl: Impl = "ref",
                  block_n: int = 512,
                  interpret: bool | None = None) -> jax.Array:
    """Fused error-feedback accumulate/compress: H + Q_bits(Z - H), row-wise.

    Z, H: (m, n) upload and shared codec memory; scale: (m,) per-row
    magnitude bound of the residual Z - H; bits: wire bits per coordinate
    (>= 2); u32: optional (m, n) uint32 dither (present => unbiased
    stochastic rounding). Returns the updated memory / server reconstruction
    in Z.dtype.
    """
    if impl == "pallas":
        return ef_accumulate_pallas(Z, H, scale, bits, u32, block_n=block_n,
                                    interpret=interpret)
    if impl == "ref":
        return _ef_ref_jit(Z, H, scale, bits, u32)
    raise ValueError(f"unknown quant impl {impl!r}")


def quantize_cols(X: jax.Array, F: jax.Array, scale: jax.Array,
                  kcols: jax.Array, bits: int, u32: jax.Array | None = None,
                  *, impl: Impl = "ref", block_n: int = 512,
                  interpret: bool | None = None) -> jax.Array:
    """Batched column-bounded quantize-dequantize with fallback.

    X, F: (m, n) values and fallback; scale: (m,) per-row magnitude bound;
    kcols: (m,) live-column counts (columns j < kcols[i] quantize, the rest
    return F[i, j] bit-untouched); bits: wire bits per coordinate (>= 2);
    u32: optional (m, n) uint32 dither -- present => unbiased stochastic
    rounding. One launch encodes a whole pytree's (leaf, client) rows.
    """
    if X.ndim != 2 or X.shape != F.shape:
        raise ValueError(
            f"quantize_cols expects matching (m, n); got {X.shape} "
            f"vs {F.shape}")
    if impl == "pallas":
        return quantize_cols_pallas(X, F, scale, kcols, bits, u32,
                                    block_n=block_n, interpret=interpret)
    if impl == "ref":
        return _ref.quantize_cols_ref(X, F, scale, kcols, bits, u32)
    raise ValueError(f"unknown quant impl {impl!r}")


# like ef_accumulate: the ref MUST run jitted so x*clipf + b*lap fuses to
# the same FMA the Pallas path's XLA program uses (see the note above)
_private_ref_jit = jax.jit(_ref.private_quantize_cols_ref,
                           static_argnames=("bits",))


def private_quantize_cols(X: jax.Array, F: jax.Array, clipf: jax.Array,
                          noise_b: jax.Array, scale: jax.Array,
                          kcols: jax.Array, bits: int, u32q: jax.Array,
                          lap: jax.Array, *, impl: Impl = "ref",
                          block_n: int = 512,
                          interpret: bool | None = None) -> jax.Array:
    """Fused clip + Laplace-noise + column-bounded quantize-dequantize.

    X, F: (m, n) values and fallback; clipf, noise_b, scale: (m,) per-row
    l1-clip factor, Laplace scale, and quantizer magnitude bound (on the
    clipped pre-noise values -- noisy outliers saturate at the grid
    edge); kcols: (m,) live-column counts; bits: wire bits (>= 2); u32q:
    (m, n) uint32 quantizer dither plane; lap: (m, n) float32
    unit-Laplace noise plane, precomputed by the caller (the sim draws it
    host-side via repro.sim.transport.draw_unit_noise so both engines and
    both impls consume one bit-identical stream). One launch transforms a
    whole pytree's (leaf, client) rows.
    """
    if X.ndim != 2 or X.shape != F.shape:
        raise ValueError(
            f"private_quantize_cols expects matching (m, n); got {X.shape} "
            f"vs {F.shape}")
    if impl == "pallas":
        return private_quantize_cols_pallas(X, F, clipf, noise_b, scale,
                                            kcols, bits, u32q, lap,
                                            block_n=block_n,
                                            interpret=interpret)
    if impl == "ref":
        return _private_ref_jit(X, F, clipf, noise_b, scale, kcols, bits,
                                u32q, lap)
    raise ValueError(f"unknown quant impl {impl!r}")
