"""Public entry points for the upload-codec quantizer with impl dispatch."""
from __future__ import annotations

from typing import Literal

import jax

from repro.kernels.quant import ref as _ref
from repro.kernels.quant.quant import quantize_pallas

Impl = Literal["pallas", "ref"]


def quantize(X: jax.Array, scale: jax.Array, bits: int,
             u32: jax.Array | None = None, *, impl: Impl = "ref",
             block_n: int = 512, interpret: bool | None = None) -> jax.Array:
    """Row-wise uniform (stochastic) quantize-dequantize.

    X: (m, n) values; scale: (m,) per-row magnitude bound; bits: wire bits
    per coordinate (>= 2); u32: optional (m, n) uint32 dither -- present =>
    unbiased stochastic rounding, absent => deterministic round-half-up.
    Returns grid-snapped values in X.dtype.
    """
    if impl == "pallas":
        return quantize_pallas(X, scale, bits, u32, block_n=block_n,
                               interpret=interpret)
    if impl == "ref":
        return _ref.quantize_ref(X, scale, bits, u32)
    raise ValueError(f"unknown quant impl {impl!r}")
