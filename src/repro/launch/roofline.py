"""Roofline analysis (deliverable g).

Three terms per (arch x shape x mesh), in SECONDS:

  compute    = FLOPs / (chips * 197e12)          [bf16 MXU peak]
  memory     = HBM bytes / (chips * 819e9)
  collective = ICI bytes / (chips * 50e9)        [per-link bound]

Sources and honesty notes
-------------------------
* ``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
  empirically; see EXPERIMENTS.md §Methodology). All layer stacks, the
  flash-attention chunking, the SSD/mLSTM chunk recurrences and the
  FedEPM client loop are lax.scans, so raw cost_analysis UNDERCOUNTS.
  We therefore use an ANALYTIC model (functions below, assumptions
  documented inline) as the primary FLOP/byte source, validated against
  cost_analysis on reduced fully-unrolled configs (tests/test_roofline.py).
* Collective bytes ARE recovered from the compiled HLO: the dry-run stores
  a census of collective ops with their computation; this module resolves
  each computation's execution multiplicity through the while-loop call
  chain (body -> parent, trips parsed from the loop condition constants)
  and sums bytes * multiplicity.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import re
from typing import Optional

# ---- hardware constants (TPU v5e, per chip) -------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


# ---------------------------------------------------------------------------
# trip-corrected collective bytes from the dry-run artifact
# ---------------------------------------------------------------------------

def _computation_multipliers(hlo_or_rec) -> dict:
    """Map computation name -> execution multiplicity via while nesting."""
    if isinstance(hlo_or_rec, dict):
        # reconstruct from the recorded census + while_trips: we stored
        # trips per BODY name; parents unknown -> conservative: multiply
        # each body by its own trips and by any enclosing body whose name
        # prefixes appear; instead the dryrun now stores the parent chain.
        return hlo_or_rec.get("while_trips", {})
    raise TypeError


def _chain_multiplier(comp: str, trips: dict, parents: dict) -> int:
    mult = 1
    seen = set()
    while comp in trips:
        if comp in seen:
            break
        seen.add(comp)
        mult *= max(1, int(trips[comp]))
        comp = parents.get(comp, "")
    return mult


def parse_hlo_loops(hlo_text: str):
    """Returns (trips: body->count, parents: body->containing computation).

    Computations in HLO text start at column 0 as '[ENTRY ]%name (...) -> ...'.
    A while op inside computation C with body=%B makes C the parent of B.
    Trip counts come from the canonical loop condition
    'compare(iter, constant(N)), direction=LT'.
    """
    comp_lines: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(|\{)", line)
            if m:
                current = m.group(1)
                comp_lines[current] = []
                continue
        if current is not None:
            comp_lines[current].append(line)

    parents: dict[str, str] = {}
    bodies: dict[str, str] = {}   # body -> condition
    for comp, lines in comp_lines.items():
        for line in lines:
            m = re.search(r"while\(.*?\), condition=%?([\w\.\-]+), "
                          r"body=%?([\w\.\-]+)", line)
            if m:
                cond, body = m.group(1), m.group(2)
                parents[body] = comp
                bodies[body] = cond

    trips: dict[str, int] = {}
    for body, cond in bodies.items():
        n = None
        for line in comp_lines.get(cond, []):
            m = re.search(r"constant\((\d+)\)", line)
            if m:
                n = int(m.group(1))
        if n is not None:
            trips[body] = n
    return trips, parents


def collective_seconds(rec: dict, chips: int) -> tuple[float, dict]:
    """Trip-corrected collective bytes (per-device) -> seconds on ICI.

    The dry-run census records each collective's OUTPUT bytes per device
    and its computation; multiplicity resolves through the while chain.
    """
    trips = rec.get("while_trips", {})
    parents = rec.get("while_parents", {})
    per_op: dict[str, float] = {}
    total = 0.0
    for op in rec.get("collectives", []):
        mult = _chain_multiplier(op.get("computation", ""), trips, parents)
        b = op["bytes"] * mult
        total += b
        per_op[op["op"]] = per_op.get(op["op"], 0.0) + b
    return total / ICI_BW, {"bytes_by_op": per_op,
                            "total_bytes": total}


# ---------------------------------------------------------------------------
# analytic FLOP / HBM models
# ---------------------------------------------------------------------------

def _param_counts(cfg) -> dict:
    """Exact-ish parameter counts per component (matches models/*)."""
    d, ff, L, V, hd = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab, cfg.hd
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    out = {"embed": V * d, "unembed": 0 if cfg.tie_embeddings else V * d}
    attn = d * hd * (H + 2 * Hkv) + H * hd * d
    if cfg.family in ("dense", "vlm", "audio"):
        mlp = d * ff * (3 if cfg.mlp == "swiglu" else 2)
        out["layer_matmul"] = attn + mlp
        out["layer_active"] = attn + mlp
        out["attn_layers"] = L
    elif cfg.family == "moe":
        mlp_total = cfg.n_experts * d * ff * 3 + d * cfg.n_experts
        mlp_active = cfg.top_k * d * ff * 3 + d * cfg.n_experts
        out["layer_matmul"] = attn + mlp_total
        out["layer_active"] = attn + mlp_active
        out["attn_layers"] = L
    elif cfg.family == "xlstm":
        d_in = cfg.ssm_expand * d
        m_per = 2 * d * d_in + 3 * d_in * d_in + d_in * 2 * H + d_in * d
        d_glu = int(d * 4 / 3)
        s_per = 3 * d * d + 2 * d * H + 3 * d * d_glu
        n_s = sum(1 for i in range(L)
                  if cfg.slstm_every and i % cfg.slstm_every == 0)
        out["layer_matmul"] = (m_per * (L - n_s) + s_per * n_s) / max(L, 1)
        out["layer_active"] = out["layer_matmul"]
        out["attn_layers"] = 0
    else:  # hybrid (mamba2 + shared attn)
        d_in = cfg.ssm_expand * d
        N = cfg.ssm_state
        Hs = cfg.ssm_heads or d_in // 64
        per = d * (2 * d_in + 2 * N + Hs) + d_in * d
        out["layer_matmul"] = per
        out["layer_active"] = per
        # shared attn applications
        n_apps = math.ceil(L / cfg.shared_attn_every) \
            if cfg.shared_attn_every else 0
        out["shared_attn_apps"] = n_apps
        out["shared_attn_params"] = attn + d * ff * 3
        out["attn_layers"] = n_apps
    return out


def total_param_bytes(cfg) -> int:
    pc = _param_counts(cfg)
    L = cfg.n_layers
    n = pc["embed"] + pc["unembed"] + L * pc["layer_matmul"]
    n += pc.get("shared_attn_params", 0)
    import numpy as _np
    import jax.numpy as jnp
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    return int(n * itemsize)


def fwd_matmul_flops(cfg, tokens: int) -> float:
    """2 * active params * tokens (matmul part incl. unembed). The shared
    attn block's params are REUSED n_apps times per token (zamba2)."""
    pc = _param_counts(cfg)
    per_tok = pc["layer_active"] * cfg.n_layers
    if pc.get("shared_attn_apps"):
        per_tok += pc["shared_attn_params"] * pc["shared_attn_apps"]
    per_tok += (cfg.d_model * cfg.vocab)  # unembed (tied or not: same flops)
    return 2.0 * per_tok * tokens


def attn_fwd_flops(cfg, batch: int, T: int) -> float:
    """Score + PV matmuls, causal (T_eff = T/2) or windowed."""
    hd = cfg.hd
    H = cfg.n_heads
    n_attn = _param_counts(cfg).get("attn_layers", cfg.n_layers)
    if cfg.attention == "bidirectional":
        t_eff = T
    elif cfg.sliding_window and cfg.sliding_window < T:
        w = cfg.sliding_window
        t_eff = w  # ~w for T >> w
    else:
        t_eff = T / 2.0
    per_layer = 4.0 * batch * T * t_eff * H * hd  # 2 matmuls x 2 flops
    return per_layer * n_attn


def ssd_fwd_flops(cfg, batch: int, T: int) -> float:
    """Chunked SSD / mLSTM intra+inter chunk matmul flops."""
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        Hs = cfg.ssm_heads or d_in // 64
        hd = d_in // Hs
        N = cfg.ssm_state
        c = cfg.ssm_chunk
        # per chunk: G=CB^T (2c^2 N), y_intra (2c^2 Hs hd), y_state
        # (2cN Hs hd), h update (2cN Hs hd)
        per_chunk = 2 * c * c * N + 2 * c * c * Hs * hd \
            + 4 * c * N * Hs * hd
        return batch * (T / c) * per_chunk * cfg.n_layers
    if cfg.family == "xlstm":
        d_in = cfg.ssm_expand * cfg.d_model
        H = cfg.n_heads
        hd = d_in // H
        c = cfg.ssm_chunk
        per_chunk = 2 * c * c * H * hd * 2 + 4 * c * H * hd * hd
        n_m = cfg.n_layers - sum(
            1 for i in range(cfg.n_layers)
            if cfg.slstm_every and i % cfg.slstm_every == 0)
        return batch * (T / c) * per_chunk * n_m
    return 0.0


def train_flops(cfg, global_batch: int, T: int, k0: int, m: int) -> dict:
    """One FedEPM round. Gradient at w^tau is computed ONCE per round per
    client (the paper's computational-efficiency claim): fwd+bwd with
    per-block remat = 2 fwd + 2 bwd-matmul ~= 4x fwd for matmuls; flash
    attention pays fwd + remat-fwd + bwd(recompute s,p + 2 grad matmuls)
    ~= 5x fwd. Inner prox iterations are elementwise: ~8 flops/coord.
    """
    tokens = global_batch * T
    mm = fwd_matmul_flops(cfg, tokens) * 4.0
    at = attn_fwd_flops(cfg, global_batch, T) * 5.0
    sd = ssd_fwd_flops(cfg, global_batch, T) * 4.0
    n_params = total_param_bytes(cfg) / _itemsize(cfg)
    elementwise = (k0 * 8.0 + 30.0) * m * n_params  # prox + ENS + noise
    return {"matmul": mm, "attention": at, "ssd": sd,
            "elementwise": elementwise,
            "total": mm + at + sd + elementwise}


def _itemsize(cfg):
    import jax.numpy as jnp
    return jnp.dtype(cfg.param_dtype).itemsize


def prefill_flops(cfg, B: int, T: int) -> dict:
    mm = fwd_matmul_flops(cfg, B * T)
    # prefill unembeds ONLY the last position
    mm -= 2.0 * cfg.d_model * cfg.vocab * (B * T - B)
    at = attn_fwd_flops(cfg, B, T)
    sd = ssd_fwd_flops(cfg, B, T)
    return {"matmul": mm, "attention": at, "ssd": sd,
            "total": mm + at + sd}


def decode_flops(cfg, B: int, S: int) -> dict:
    mm = fwd_matmul_flops(cfg, B)
    pc = _param_counts(cfg)
    n_attn = pc.get("attn_layers", cfg.n_layers)
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    at = 4.0 * B * ctx * cfg.n_heads * cfg.hd * n_attn
    sd = 0.0
    if cfg.family in ("hybrid", "xlstm"):
        d_in = cfg.ssm_expand * cfg.d_model
        Hs = (cfg.ssm_heads or d_in // 64) if cfg.family == "hybrid" \
            else cfg.n_heads
        hd = d_in // Hs
        N = cfg.ssm_state if cfg.family == "hybrid" else hd
        sd = 6.0 * B * Hs * hd * N * cfg.n_layers
    return {"matmul": mm, "attention": at, "ssd": sd,
            "total": mm + at + sd}


# ---------------------------------------------------------------------------
# HBM byte models
# ---------------------------------------------------------------------------

def train_hbm_bytes(cfg, global_batch: int, T: int, k0: int, m: int,
                    state_bytes_per_param: int) -> dict:
    """Per-round traffic: 3 param passes for grad (fwd read, remat read,
    bwd read+grad write ~ 4P), activation streams (~20 d-wide tensors per
    layer per token), and the FedEPM elementwise state traffic: ENS reads
    Z (mP) + writes w (P); each of k0 prox iters reads (W, w, g) and
    writes W (4mP) -- the motivation for the fused prox kernel."""
    P = total_param_bytes(cfg) / _itemsize(cfg)
    pbytes = total_param_bytes(cfg)
    grad = 4.0 * pbytes
    act = 20.0 * cfg.n_layers * global_batch * T * cfg.d_model * 2
    sb = P * state_bytes_per_param
    fed = (m + 1) * sb + k0 * 4 * m * sb + 3 * m * sb  # ENS + prox + noise
    return {"grad_params": grad, "activations": act, "fedepm_state": fed,
            "total": grad + act + fed}


def prefill_hbm_bytes(cfg, B: int, T: int) -> dict:
    pbytes = total_param_bytes(cfg)
    act = 12.0 * cfg.n_layers * B * T * cfg.d_model * 2
    return {"params": pbytes, "activations": act, "total": pbytes + act}


def decode_hbm_bytes(cfg, B: int, S: int) -> dict:
    """Decode is memory-bound: all params + the KV/recurrent state."""
    pbytes = total_param_bytes(cfg)
    pc = _param_counts(cfg)
    n_attn = pc.get("attn_layers", cfg.n_layers)
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    cache = 2.0 * B * ctx * cfg.n_kv_heads * cfg.hd * 2 * n_attn
    rec = 0.0
    if cfg.family in ("hybrid", "xlstm"):
        d_in = cfg.ssm_expand * cfg.d_model
        Hs = (cfg.ssm_heads or d_in // 64) if cfg.family == "hybrid" \
            else cfg.n_heads
        hd = d_in // Hs
        N = cfg.ssm_state if cfg.family == "hybrid" else hd
        rec = 2.0 * B * Hs * hd * N * 4 * cfg.n_layers
    return {"params": pbytes, "cache": cache, "recurrent": rec,
            "total": pbytes + cache + rec}


# ---------------------------------------------------------------------------
# report assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_raw: float
    useful_ratio: float        # MODEL_FLOPS / analytic HLO-equivalent
    detail: dict

    def dominant(self):
        return max((self.compute_s, "compute"),
                   (self.memory_s, "memory"),
                   (self.collective_s, "collective"))


def analyse(rec: dict, cfg, shape) -> Roofline:
    """rec: a dry-run artifact; cfg: full ArchConfig; shape: InputShape."""
    chips = 1
    for v in rec["mesh_shape"].values():
        chips *= v
    static = rec.get("static", {})
    kind = rec.get("kind", "train")
    if kind == "train":
        m = static.get("m", 16)
        k0 = static.get("k0", 4)
        sbp = 2 if static.get("mode") == "temporal" or True else 4
        import jax.numpy as jnp
        sbp = jnp.dtype(cfg.param_dtype).itemsize
        fl = train_flops(cfg, shape.global_batch, shape.seq_len, k0, m)
        hb = train_hbm_bytes(cfg, shape.global_batch, shape.seq_len, k0, m,
                             sbp)
        # MODEL_FLOPS: 6 N_active D (the classic training-efficiency
        # denominator; one grad per round over the global batch)
        pc = _param_counts(cfg)
        n_active = pc["layer_active"] * cfg.n_layers + pc["embed"] \
            + pc["unembed"] + pc.get("shared_attn_params", 0)
        model_flops = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif kind == "prefill":
        fl = prefill_flops(cfg, shape.global_batch, shape.seq_len)
        hb = prefill_hbm_bytes(cfg, shape.global_batch, shape.seq_len)
        pc = _param_counts(cfg)
        n_active = pc["layer_active"] * cfg.n_layers + pc["embed"] \
            + pc.get("shared_attn_params", 0)
        model_flops = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        fl = decode_flops(cfg, shape.global_batch, shape.seq_len)
        hb = decode_hbm_bytes(cfg, shape.global_batch, shape.seq_len)
        pc = _param_counts(cfg)
        n_active = pc["layer_active"] * cfg.n_layers + pc["embed"] \
            + pc["unembed"] + pc.get("shared_attn_params", 0)
        model_flops = 2.0 * n_active * shape.global_batch

    coll_s, coll_detail = collective_seconds(rec, chips)
    compute_s = fl["total"] / (chips * PEAK_FLOPS)
    memory_s = hb["total"] / (chips * HBM_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        hlo_flops_raw=rec.get("cost", {}).get("flops", 0.0),
        useful_ratio=model_flops / max(fl["total"], 1.0),
        detail={"flops": fl, "hbm": hb, "collectives": coll_detail,
                "peak_hbm_per_dev": rec.get("memory", {}).get("peak_bytes")})


def analyse_artifact(path: str) -> Optional[Roofline]:
    from repro import configs as cfgs
    from repro.launch.steps import resolve_arch
    from repro.models.config import INPUT_SHAPES

    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return None
    shape = INPUT_SHAPES[rec["shape"]]
    res = resolve_arch(rec["arch"], shape)
    cfg = res[0]
    return analyse(rec, cfg, shape)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "../../../artifacts/dryrun/single"))
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows = []
    for fn in sorted(os.listdir(args.dir)):
        if not fn.endswith(".json"):
            continue
        r = analyse_artifact(os.path.join(args.dir, fn))
        if r is None:
            continue
        rows.append(r)
        dom_s = max(r.compute_s, r.memory_s, r.collective_s)
        print(f"{r.arch:18s} {r.shape:12s} C={r.compute_s*1e3:9.2f}ms "
              f"M={r.memory_s*1e3:9.2f}ms X={r.collective_s*1e3:9.2f}ms "
              f"-> {r.bottleneck:10s} useful={r.useful_ratio:5.2f} "
              f"bound={dom_s*1e3:9.2f}ms")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([dataclasses.asdict(r) for r in rows], f, indent=1,
                      default=str)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
