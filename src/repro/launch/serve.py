"""Serving launcher: prefill + decode loop on the mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
        --devices 8 --mesh-shape 4,2 --reduced --new-tokens 8
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh-shape", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core import distributed as dist_mod
    from repro.launch.mesh import client_axes, make_production_mesh
    from repro.launch.steps import _named, serve_activation_rules
    from repro.models.registry import get_model
    from repro.sharding.rules import axis_rules

    if args.mesh_shape:
        dd, mm = (int(x) for x in args.mesh_shape.split(","))
        mesh = jax.make_mesh((dd, mm), ("data", "model"))
    else:
        mesh = make_production_mesh()

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get_config(args.arch)
    model = get_model(cfg)
    if not model.has_decode:
        print(f"{args.arch} is encoder-only; nothing to decode")
        return 1

    rules = serve_activation_rules(mesh)
    aparams = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = dist_mod.param_specs(cfg, aparams, mesh, dist_mod.DistConfig())
    psh = _named(pspecs, mesh)
    params = jax.jit(lambda k: model.init(k), out_shardings=psh)(
        jax.random.PRNGKey(0))

    B, Tp = args.batch, args.prompt_len
    max_len = Tp + args.new_tokens + (cfg.n_patches or 0)

    def prefill_fn(p, b):
        with axis_rules(mesh, rules):
            return model.prefill(p, b, max_len=max_len)

    def decode_fn(p, st, b):
        with axis_rules(mesh, rules):
            return model.decode_step(p, st, b)

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, Tp), 0,
                                 cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_patches, cfg.d_model),
            dtype=cfg.dtype)

    t0 = time.time()
    logits, state = jax.jit(prefill_fn)(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {Tp}x{B}: {time.time()-t0:.2f}s")

    # the decode state keeps whatever shardings prefill produced (the
    # dry-run path pins them via auto_state_specs; here the live arrays
    # already carry shardings, so let jit adopt them)
    decode = jax.jit(decode_fn, donate_argnums=1)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for _ in range(args.new_tokens):
        logits, state = decode(params, state, {"tokens": tok})
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode {args.new_tokens} tokens: {dt:.2f}s "
          f"({args.new_tokens*B/dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
