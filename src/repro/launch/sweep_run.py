"""Multi-cell sweep driver: one command runs a ``[sweep]`` spec grid.

``python -m repro.launch.sweep_run --spec FILE.toml --out-dir DIR`` reads
a spec file carrying a ``[sweep]`` table (dotted-path axes + ``seeds``;
:func:`repro.spec.sweep.load_sweep`, docs/spec.md), expands the
cross-product, and executes every cell:

* **in parallel** across local processes (``--jobs N``; ``--jobs 1`` runs
  inline in this process). Each worker process holds its own
  ``repro.spec.build`` task-data cache, so cells sharing a resolved
  ``TaskSpec`` reuse ONE device copy of the batches and the warm jit
  caches within that worker;
* **resumably**: each finished cell writes an atomic per-cell result file
  under ``DIR/cells/`` (temp file + ``os.replace``) recording the cell
  spec, runner, context and summary. A rerun of the same sweep skips
  every cell whose result file is present, ``ok``, and fingerprint-equal
  (same spec/runner/ctx) -- so a killed run re-executes only the
  missing/failed cells;
* into **one merged artifact**: when every cell is ``ok``, the driver
  writes ``DIR/merged.json`` -- a self-describing document (base spec,
  axes, seeds, cell name -> run summary). The default runner attaches the
  run-telemetry recorder (``--no-telemetry`` to opt out), so each summary
  carries the ``"telemetry"`` block from docs/observability.md; the merge
  strips that block's wall-clock fields (``wall_s``,
  ``rounds_per_sec_wall``), which makes the merged artifact byte-for-byte
  deterministic: independent of ``--jobs``, and identical between an
  uninterrupted run and a kill + resume (pinned in
  tests/test_sweep_run.py). Per-cell wall times stay in the cell files.

Any cell failure leaves a ``failed`` cell file (re-executed on rerun),
skips the merge, and exits nonzero -- a broken grid can never pass CI
silently. The benchmark modules (benchmarks/fig6_stragglers.py,
fig7_async.py, bench_engine.py) run their figure grids through
:func:`execute_cells`/:func:`write_merged` with custom runners.

Exit codes: 0 all cells ok (merged written); 1 any cell failed; 3 cells
left pending by ``--max-cells`` (resume by rerunning).
"""
from __future__ import annotations

import argparse
import contextlib
import copy
import hashlib
import json
import os
import pathlib
import re
import sys
import tempfile
import time
import traceback
from dataclasses import dataclass, field
from typing import Mapping, Sequence

SCHEMA = 1
DEFAULT_RUNNER = "repro.launch.sweep_run:run_cell"
# wall-clock fields inside summary["telemetry"] -- everything else in a
# run summary is a pure function of the spec, which is what makes the
# merged artifact byte-identical across --jobs counts and resumes
VOLATILE_TELEMETRY_KEYS = ("wall_s", "rounds_per_sec_wall")

EXIT_OK, EXIT_FAILED, EXIT_PENDING = 0, 1, 3


# ---------------------------------------------------------------------------
# runners
# ---------------------------------------------------------------------------

def run_cell(spec, ctx: Mapping) -> dict:
    """The default cell runner: ``spec.build().run()`` -> summary dict.

    ``ctx["telemetry"]`` (default True) attaches the event recorder when
    the spec itself leaves telemetry off -- observational-only, so the
    rest of the summary is unchanged (docs/observability.md).
    """
    if ctx.get("telemetry", True) and not spec.telemetry.enabled:
        spec = spec.replace(**{"telemetry.enabled": True})
    return spec.build().run()


def _resolve_runner(ref: str):
    """``"module:attr"`` -> callable ``runner(spec, ctx) -> summary``."""
    import importlib
    mod, _, attr = ref.partition(":")
    if not mod or not attr:
        raise ValueError(f"runner ref {ref!r} is not 'module:attr'")
    fn = getattr(importlib.import_module(mod), attr)
    if not callable(fn):
        raise TypeError(f"runner ref {ref!r} resolved to non-callable "
                        f"{fn!r}")
    return fn


# ---------------------------------------------------------------------------
# per-cell result files
# ---------------------------------------------------------------------------

def cell_filename(name: str) -> str:
    """Filesystem-safe, collision-free file name for one cell.

    Cell names carry ``/``, ``=`` and arbitrary value text; the readable
    prefix is sanitized and truncated, and a short digest of the FULL
    name keeps two long names from colliding after truncation.
    """
    safe = re.sub(r"[^A-Za-z0-9._=-]+", "_", name).strip("_")[:80]
    digest = hashlib.sha1(name.encode()).hexdigest()[:10]
    return f"{safe}.{digest}.json"


def _atomic_write_json(path: pathlib.Path, doc: dict) -> None:
    """Write ``doc`` via temp file + ``os.replace`` in the target dir, so
    a kill mid-write never leaves a truncated result file behind."""
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def _read_cell(path: pathlib.Path) -> dict | None:
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None                      # missing/corrupt == not done


def _norm(doc):
    """JSON-round-trip normalization, so fingerprints compare equal
    between the in-memory dict and the one read back from a cell file."""
    return json.loads(json.dumps(doc, sort_keys=True))


def _execute_one(payload) -> tuple[str, str, str | None]:
    """Run one cell and write its result file. -> (name, status, error).

    Top-level (picklable) so it runs identically inline and in spawned
    pool workers; the spec travels as its ``to_dict`` form.
    """
    name, spec_dict, runner_ref, ctx, path_str = payload
    from repro.spec import ExperimentSpec
    path = pathlib.Path(path_str)
    spec = ExperimentSpec.from_dict(spec_dict)
    rec = {"schema": SCHEMA, "name": name, "spec": spec_dict,
           "runner": runner_ref, "ctx": ctx}
    t0 = time.perf_counter()
    try:
        runner = _resolve_runner(runner_ref)
        rec.update(status="ok", summary=runner(spec, ctx),
                   wall_s=time.perf_counter() - t0)
        err = None
    except Exception as e:  # noqa: BLE001 - per-cell isolation is the point
        err = f"{type(e).__name__}: {e}"
        rec.update(status="failed", error=err,
                   traceback=traceback.format_exc(),
                   wall_s=time.perf_counter() - t0)
    _atomic_write_json(path, rec)
    return name, rec["status"], err


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

@dataclass
class SweepResult:
    """Outcome of one :func:`execute_cells` invocation."""

    records: dict            # cell name -> result-file record, grid order
    executed: list = field(default_factory=list)
    skipped: list = field(default_factory=list)
    failed: list = field(default_factory=list)
    pending: list = field(default_factory=list)   # cut by max_cells

    @property
    def ok(self) -> bool:
        return not self.failed and not self.pending


def execute_cells(cells: Sequence, *, out_dir, jobs: int = 1,
                  runner: str = DEFAULT_RUNNER,
                  ctx: Mapping | None = None,
                  cell_ctx: Mapping[str, Mapping] | None = None,
                  max_cells: int | None = None, rerun: bool = False,
                  progress=None) -> SweepResult:
    """Execute a grid of validated spec cells, resumably and in parallel.

    ``runner`` is a ``"module:attr"`` ref resolved IN THE WORKER (it must
    be importable there); ``ctx`` is a JSON-serializable dict passed to
    every cell, ``cell_ctx`` maps cell names to per-cell overrides (how
    fig7's race cells receive their per-cell objective targets). A cell
    whose existing result file is ``ok`` with the same (spec, runner,
    ctx) fingerprint is skipped, unless ``rerun`` forces re-execution.
    ``max_cells`` caps how many pending cells this invocation attempts
    (the resume test's controlled kill point). ``progress(name, status,
    err, done, total)`` is called per finished cell.
    """
    ctx = dict(ctx or {})
    cell_ctx = cell_ctx or {}
    names = [c.name for c in cells]
    if len(set(names)) != len(names):
        dupe = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate cell name(s): {dupe[:3]}")
    unknown = set(cell_ctx) - set(names)
    if unknown:
        raise ValueError(f"cell_ctx for unknown cell(s): "
                         f"{sorted(unknown)[:3]}")
    cells_dir = pathlib.Path(out_dir) / "cells"
    cells_dir.mkdir(parents=True, exist_ok=True)

    res = SweepResult(records={})
    todo = []
    paths = {}
    for cell in cells:
        cctx = _norm({**ctx, **dict(cell_ctx.get(cell.name, {}))})
        path = paths[cell.name] = cells_dir / cell_filename(cell.name)
        spec_dict = _norm(cell.to_dict())
        rec = _read_cell(path)
        if (not rerun and rec is not None and rec.get("status") == "ok"
                and _norm(rec.get("spec")) == spec_dict
                and rec.get("runner") == runner
                and _norm(rec.get("ctx")) == cctx):
            res.records[cell.name] = rec
            res.skipped.append(cell.name)
        else:
            todo.append((cell.name, spec_dict, runner, cctx, str(path)))
    if max_cells is not None and len(todo) > max_cells:
        todo, cut = todo[:max_cells], todo[max_cells:]
        res.pending = [t[0] for t in cut]

    def _account(name, status, err):
        (res.executed if status == "ok" else res.failed).append(name)
        if progress is not None:
            progress(name, status, err,
                     len(res.executed) + len(res.failed) +
                     len(res.skipped), len(cells))

    if jobs <= 1 or len(todo) <= 1:
        for payload in todo:
            _account(*_execute_one(payload))
    else:
        # spawn, not fork: workers must initialize their own jax runtime.
        # Each worker's process-local task-data cache is what shares one
        # device dataset across the same-task cells it picks up.
        import multiprocessing as mp
        with mp.get_context("spawn").Pool(
                processes=min(jobs, len(todo))) as pool:
            for out in pool.imap(_execute_one, todo):
                _account(*out)

    for name in res.executed + res.failed:
        res.records[name] = _read_cell(paths[name]) or {
            "status": "failed", "name": name,
            "error": "result file unreadable after execution"}
    # re-key in grid order (records were filled skip-first)
    res.records = {n: res.records[n] for n in names if n in res.records}
    return res


def _strip_volatile(summary: dict) -> dict:
    out = copy.deepcopy(summary)
    tel = out.get("telemetry")
    if isinstance(tel, dict):
        for key in VOLATILE_TELEMETRY_KEYS:
            tel.pop(key, None)
    return out


def write_merged(out_path, cells: Sequence, records: Mapping, *,
                 meta: Mapping | None = None) -> dict:
    """Merge ok cell records into the ONE self-describing sweep artifact.

    ``cells`` fixes the artifact's cell order (the grid order, not
    completion order); every cell must have an ``ok`` record. The
    document is written with sorted keys and no wall-clock fields, so the
    same grid always produces the same bytes.
    """
    body = {}
    for cell in cells:
        rec = records.get(cell.name)
        if rec is None or rec.get("status") != "ok":
            raise ValueError(f"cannot merge: cell {cell.name!r} has no ok "
                             f"result")
        body[cell.name] = _strip_volatile(rec["summary"])
    doc = {"schema": SCHEMA, "kind": "sweep", **(dict(meta or {})),
           "n_cells": len(body), "cells": body}
    _atomic_write_json(pathlib.Path(out_path), doc)
    return doc


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="expand a [sweep] spec file and run every cell: "
                    "parallel, resumable, one merged JSON artifact")
    ap.add_argument("--spec", required=True,
                    help="spec file (.toml/.json) with an optional "
                         "[sweep] table of dotted-path axes + seeds")
    ap.add_argument("--out-dir", required=True,
                    help="sweep state dir: per-cell results under "
                         "cells/, merged artifact at merged.json")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes (1 = inline, no subprocess)")
    ap.add_argument("--max-cells", type=int, default=None,
                    help="attempt at most N pending cells this run "
                         "(exit %d; rerun to resume)" % EXIT_PENDING)
    ap.add_argument("--rerun", action="store_true",
                    help="re-execute every cell, ignoring existing "
                         "result files")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="do not attach the run-telemetry recorder to "
                         "cells (summaries lose their 'telemetry' block)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-cell progress lines")
    args = ap.parse_args(argv)

    from repro.spec import SpecError, load_sweep
    try:
        base, cells = load_sweep(args.spec)
    except SpecError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    out_dir = pathlib.Path(args.out_dir)
    if not args.quiet:
        print(f"# sweep {base.name!r}: {len(cells)} cell(s) -> {out_dir}",
              file=sys.stderr)

    def progress(name, status, err, done, total):
        if not args.quiet:
            tail = "" if err is None else f"  {err}"
            print(f"# [{done}/{total}] {status:6s} {name}{tail}",
                  file=sys.stderr, flush=True)

    res = execute_cells(
        cells, out_dir=out_dir, jobs=args.jobs, max_cells=args.max_cells,
        rerun=args.rerun, ctx={"telemetry": not args.no_telemetry},
        progress=progress)

    print(f"# executed={len(res.executed)} skipped={len(res.skipped)} "
          f"failed={len(res.failed)} pending={len(res.pending)}",
          file=sys.stderr)
    if res.failed:
        for name in res.failed:
            rec = res.records.get(name) or {}
            print(f"# FAILED {name}: {rec.get('error')}", file=sys.stderr)
        print(f"# {len(res.failed)} cell(s) failed; rerun re-executes "
              f"only these", file=sys.stderr)
        return EXIT_FAILED
    if res.pending:
        print(f"# incomplete: {len(res.pending)} cell(s) pending "
              f"(--max-cells cut); rerun to resume", file=sys.stderr)
        return EXIT_PENDING
    from repro.spec.sweep import parse_sweep_table
    from repro.spec.serialize import read_spec_file
    table = dict(read_spec_file(args.spec)).get("sweep") or {}
    axes, seeds = parse_sweep_table(table) if table else ({}, None)
    merged = out_dir / "merged.json"
    write_merged(merged, cells, res.records,
                 meta={"name": base.name, "base": base.to_dict(),
                       "axes": axes, "seeds": seeds})
    print(f"{merged}: {len(cells)} cell(s) merged")
    return EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
