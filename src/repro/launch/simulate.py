"""CLI for the federated systems simulation on the paper's logreg task.

Runs one algorithm under one aggregation policy over simulated wall-clock
time and reports per-round and summary systems metrics (simulated time,
stragglers dropped, bytes moved) alongside the algorithmic ones (objective,
accuracy). The algorithm math is exactly core/'s -- the sim only decides
WHO participates (from simulated arrival times) and WHAT the server holds
(dequantized uploads when the codec is on).

Usage:
  python -m repro.launch.simulate --alg fedepm --aggregation deadline \
      --deadline 0.002 --latency pareto --m 50 --rounds 30 --d 4000
  python -m repro.launch.simulate --alg fedepm --aggregation sync \
      --topk 0.25 --bits 8 --error-feedback   # compressed, EF memory
  python -m repro.launch.simulate --alg fedepm --aggregation async \
      --buffer-size 8 --latency pareto        # FedBuff-style buffered
  python -m repro.launch.simulate --alg sfedavg --aggregation async \
      --max-concurrency 6 --buffer-size 4 \
      --trace-file tests/fixtures/device_trace.csv   # client-level dispatch
  python -m repro.launch.simulate --alg sfedavg --aggregation overselect \
      --overselect 1.5 --latency lognormal

Aggregation modes: sync (wait for all), deadline (drop stragglers past
--deadline, eq. (22) carry-through), adaptive (per-client EWMA-learned
deadlines), overselect (contact a uniform candidate set at rate
rho*--overselect, keep the first ceil(rho*m) arrivals), async (client-
level dispatch: per-client start/upload events with an optional
--max-concurrency in-flight cap, aggregate every --buffer-size arrivals
with staleness-weighted merges; one reported "round" = one aggregation
event; all three algorithms run under identical async semantics).
``--policy`` is accepted as an alias of ``--aggregation``. Device fleets
come from --trace-file (resampled real logs) or the synthetic lognormal
profiles. Full semantics: docs/sim.md.

``--engine scan`` runs the clocked policies through the fused on-device
round engine (repro.sim.engine): K rounds compile into one ``lax.scan``
with donated state buffers and the participation-mask stream precomputed,
reproducing the eager trajectory bit-for-bit at a fraction of the host
dispatch overhead (docs/perf.md, benchmarks/bench_engine.py):

  python -m repro.launch.simulate --alg fedepm --aggregation sync \
      --engine scan --m 50 --rounds 200
"""
from __future__ import annotations

import argparse
import json
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_logreg import termination_reached
from repro.core import baselines, fedepm
from repro.core.tasks import accuracy_logistic, make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.sim import (
    CodecConfig,
    FedSim,
    LatencyTrace,
    SimConfig,
    make_profiles,
    run_rounds,
)


def build_sim(args) -> tuple[FedSim, dict]:
    X, y = synth.adult_like(d=args.d, n=args.n, seed=args.seed)
    batches = jax.tree_util.tree_map(
        jnp.asarray, partition_iid(X, y, m=args.m, seed=args.seed))
    loss = make_logistic_loss()
    key = jax.random.PRNGKey(args.seed)
    w0 = jnp.zeros(args.n)

    if args.alg == "fedepm":
        cfg = fedepm.FedEPMConfig.paper_defaults(
            m=args.m, rho=args.rho, k0=args.k0, eps_dp=args.eps)
        state = fedepm.init_state(key, w0, cfg)
    else:
        cfg = baselines.BaselineConfig(m=args.m, k0=args.k0, rho=args.rho,
                                       eps_dp=args.eps)
        state = baselines.init_state(key, w0, cfg)

    codec = None
    if args.topk < 1.0 or args.bits > 0:
        codec = CodecConfig(topk_frac=args.topk,
                            bits=args.bits, impl=args.quant_impl,
                            error_feedback=args.error_feedback)
    sim_cfg = SimConfig(
        policy=args.aggregation,
        deadline=args.deadline if args.deadline > 0 else math.inf,
        overselect_factor=args.overselect,
        latency=args.latency, latency_sigma=args.latency_sigma,
        latency_alpha=args.latency_alpha, seed=args.seed, codec=codec,
        buffer_size=args.buffer_size, staleness_exp=args.staleness_exp,
        max_concurrency=args.max_concurrency,
        deadline_slack=args.deadline_slack, ewma_beta=args.ewma_beta)
    if args.trace_file:
        profiles = LatencyTrace.load(args.trace_file).sample_profiles(
            args.m, seed=args.seed)
    else:
        profiles = make_profiles(args.m, seed=args.seed,
                                 availability=args.availability)
    sim = FedSim(alg=args.alg, cfg=cfg, state=state, batches=batches,
                 loss_fn=loss, profiles=profiles, sim=sim_cfg)
    aux = {"X": X, "y": y, "batches": batches, "loss": loss, "n": args.n}
    return sim, aux


def run(args) -> dict:
    sim, aux = build_sim(args)
    loss, batches = aux["loss"], aux["batches"]
    fobj = jax.jit(
        lambda w: fedepm.global_objective(loss, w, batches))
    gsq = jax.jit(
        lambda w: fedepm.global_grad_sq_norm(loss, w, batches))

    f_hist: list[float] = []
    rounds_run = 0

    def report(m, f):
        if not args.quiet:
            print(f"round {m.round_idx:3d}  f/m={f / args.m:.6f}  "
                  f"t={m.t_total:9.4f}s (+{m.t_round:.4f})  "
                  f"agg={m.n_aggregated}/{m.n_contacted} "
                  f"drop={m.n_dropped}  "
                  f"up={m.bytes_up/1e3:.1f}kB down={m.bytes_down/1e3:.1f}kB"
                  + ("  ABANDONED" if m.abandoned else ""), flush=True)

    def terminated() -> bool:
        # the paper's variance criterion fires spuriously on a flat start
        # (abandoned rounds leave f_hist at f(w0)): require history AND at
        # least one aggregated round before trusting it -- an all-abandoned
        # run reaches the round cap and shows abandoned_rounds == rounds
        progressed = any(not mm.abandoned for mm in sim.metrics)
        return (args.terminate and progressed and len(f_hist) >= 8
                and termination_reached(
                    f_hist, float(gsq(sim.state.w_tau)), aux["n"]))

    if args.engine == "scan":
        # fused scan engine: rounds execute in compiled on-device chunks
        # (bit-identical trajectory; async falls back to the event path
        # inside run_rounds). Termination is checked at chunk granularity
        # -- per-round under --terminate via chunk=1-sized budget of 8.
        chunk = 8 if args.terminate else args.rounds
        while rounds_run < args.rounds:
            todo = min(chunk, args.rounds - rounds_run)
            res = run_rounds(sim, todo, collect_w_tau=True)
            for m, w in zip(res.metrics, res.w_tau):
                f_hist.append(float(fobj(jnp.asarray(w))))
                report(m, f_hist[-1])
            rounds_run += todo
            if terminated():
                break
    else:
        for r in range(args.rounds):
            m = sim.step()
            rounds_run += 1
            f_hist.append(float(fobj(sim.state.w_tau)))
            report(m, f_hist[-1])
            if terminated():
                break

    acc = float(accuracy_logistic(sim.state.w_tau, jnp.asarray(aux["X"]),
                                  jnp.asarray(aux["y"])))
    dropped = sum(m.n_dropped for m in sim.metrics)
    summary = {
        "alg": args.alg, "policy": args.aggregation, "engine": args.engine,
        "latency": args.latency,
        "rounds": rounds_run, "f_final": f_hist[-1] / args.m,
        "accuracy": acc, "sim_time_s": sim.t,
        "stragglers_dropped": dropped,
        "abandoned_rounds": sum(m.abandoned for m in sim.metrics),
        "bytes_up": sim.ledger.total_up, "bytes_down": sim.ledger.total_down,
        "bytes_total": sim.ledger.total,
        "up_bytes_per_client_round": sim.up_bytes_per_client,
    }
    if args.aggregation == "async":
        summary["staleness_max"] = max(m.staleness_max for m in sim.metrics)
        summary["staleness_mean"] = float(np.mean(
            [m.staleness_mean for m in sim.metrics if not m.abandoned]
            or [0.0]))
    if not args.quiet:
        print("\nsummary:")
        for k, v in summary.items():
            print(f"  {k:28s} {v}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Federated systems simulation (stragglers, deadlines, "
                    "byte ledger) on the paper logreg task")
    ap.add_argument("--alg", default="fedepm",
                    choices=["fedepm", "sfedavg", "sfedprox"])
    ap.add_argument("--aggregation", "--policy", dest="aggregation",
                    default="sync",
                    choices=["sync", "deadline", "adaptive", "overselect",
                             "async"],
                    help="aggregation mode (--policy is an alias)")
    ap.add_argument("--engine", default="eager", choices=["eager", "scan"],
                    help="round execution engine: 'eager' dispatches one "
                         "jit call per round (the semantic reference); "
                         "'scan' compiles multi-round chunks into one "
                         "on-device lax.scan with donated state buffers -- "
                         "bit-identical trajectory, far fewer host syncs "
                         "(docs/perf.md). async aggregation always runs "
                         "the event engine; --terminate is checked per "
                         "8-round chunk under scan")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="deadline policy cutoff in simulated seconds "
                         "(<= 0 means infinite)")
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="async: contributions per aggregation event "
                         "(0 = cohort size, which recovers sync exactly)")
    ap.add_argument("--staleness-exp", type=float, default=0.5,
                    help="async: stale merges weighted (1+s)^-exp")
    ap.add_argument("--max-concurrency", type=int, default=0,
                    help="async: cap on in-flight clients; dispatches past "
                         "the cap queue until an upload frees a slot "
                         "(0 = unlimited, which dispatches whole cohorts)")
    ap.add_argument("--deadline-slack", type=float, default=2.0,
                    help="adaptive: per-client wait budget = slack * EWMA")
    ap.add_argument("--ewma-beta", type=float, default=0.3,
                    help="adaptive: EWMA weight of the newest latency")
    ap.add_argument("--overselect", type=float, default=1.5,
                    help="over-selection factor: contact a uniform "
                         "candidate set at rate rho*f, keep the first "
                         "ceil(rho*m) arrivals")
    ap.add_argument("--latency", default="deterministic",
                    choices=["deterministic", "lognormal", "pareto"])
    ap.add_argument("--latency-sigma", type=float, default=0.5)
    ap.add_argument("--latency-alpha", type=float, default=1.2)
    ap.add_argument("--availability", type=float, default=1.0,
                    help="P(client reachable per round) for the synthetic "
                         "profiles; a --trace-file fleet carries its own "
                         "availability column instead")
    ap.add_argument("--trace-file", default=None,
                    help="CSV/JSON device trace; the fleet is resampled "
                         "from it instead of the synthetic lognormal "
                         "profiles (schema: sim/clients.py::LatencyTrace; "
                         "overrides --availability)")
    ap.add_argument("--m", type=int, default=50)
    ap.add_argument("--n", type=int, default=14)
    ap.add_argument("--d", type=int, default=4000,
                    help="dataset size (4000 = reduced task; paper: 45222)")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--rho", type=float, default=0.5)
    ap.add_argument("--k0", type=int, default=8)
    ap.add_argument("--eps", type=float, default=0.0,
                    help="DP epsilon (0 disables noise)")
    ap.add_argument("--topk", type=float, default=1.0,
                    help="codec: fraction of coordinates uploaded")
    ap.add_argument("--bits", type=int, default=0,
                    help="codec: quantization bits (0 = raw values)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="codec: EF21-style memory (compress residuals "
                         "against the shared reconstruction)")
    ap.add_argument("--quant-impl", default="ref",
                    choices=["ref", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--terminate", action="store_true",
                    help="stop at the paper's termination rule")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary dict to this path")
    args = ap.parse_args(argv)
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")
    if args.error_feedback and args.topk >= 1.0 and args.bits == 0:
        ap.error("--error-feedback needs a lossy codec: set --topk < 1 "
                 "and/or --bits > 0")
    if args.trace_file and args.availability != 1.0:
        ap.error("--availability conflicts with --trace-file: the trace's "
                 "own availability column defines the fleet")

    summary = run(args)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
