"""CLI for the federated systems simulation on the paper's logreg task.

Runs one algorithm under one aggregation policy over simulated wall-clock
time and reports per-round and summary systems metrics (simulated time,
stragglers dropped, bytes moved) alongside the algorithmic ones (objective,
accuracy). The algorithm math is exactly core/'s -- the sim only decides
WHO participates (from simulated arrival times) and WHAT the server holds
(dequantized uploads when the codec is on).

The CLI is a thin shim over the declarative experiment spec layer
(``repro.spec``, docs/spec.md): legacy flags are mapped onto an
``ExperimentSpec`` and built through the same ``spec.build()`` path a
``--spec`` file takes, with bit-for-bit identical trajectories either way.

Usage:
  python -m repro.launch.simulate --spec examples/specs/fig7_async.toml
  python -m repro.launch.simulate --spec examples/specs/golden_sync.toml \
      --engine scan --rounds 50              # spec file + overrides
  python -m repro.launch.simulate --alg fedepm --aggregation deadline \
      --deadline 0.002 --latency pareto --m 50 --rounds 30 --d 4000
  python -m repro.launch.simulate --alg fedepm --aggregation sync \
      --topk 0.25 --bits 8 --error-feedback   # compressed, EF memory
  python -m repro.launch.simulate --alg fedepm --aggregation async \
      --buffer-size 8 --latency pareto        # FedBuff-style buffered
  python -m repro.launch.simulate --alg sfedavg --aggregation async \
      --max-concurrency 6 --buffer-size 4 \
      --trace-file tests/fixtures/device_trace.csv   # client-level dispatch
  python -m repro.launch.simulate --alg sfedavg --aggregation overselect \
      --overselect 1.5 --latency lognormal
  python -m repro.launch.simulate --alg fedepm --aggregation deadline \
      --deadline 0.002 --fault-drop 0.1 --fault-transient 0.2 \
      --fault-corrupt 0.05                    # lossy uplink (docs/sim.md)

Aggregation modes: sync (wait for all), deadline (drop stragglers past
--deadline, eq. (22) carry-through), adaptive (per-client EWMA-learned
deadlines), overselect (contact a uniform candidate set at rate
rho*--overselect, keep the first ceil(rho*m) arrivals), async (client-
level dispatch: per-client start/upload events with an optional
--max-concurrency in-flight cap, aggregate every --buffer-size arrivals
with staleness-weighted merges; one reported "round" = one aggregation
event; all three algorithms run under identical async semantics).
``--policy`` is accepted as an alias of ``--aggregation``. A knob that
belongs to a different policy than the one selected is an ERROR, not
silently ignored (the spec layer enforces the same ownership rules).
Device fleets come from --trace-file (resampled real logs) or the
synthetic lognormal profiles. Full semantics: docs/sim.md.

``--engine scan`` runs EVERY policy through the fused on-device round
engine (repro.sim.engine). Clocked policies compile K rounds into one
``lax.scan`` with donated state buffers and the participation-mask stream
precomputed; the async policy records its event loop per chunk and
replays it as one compiled scan over a fixed-capacity payload table. Both
reproduce the eager trajectory bit-for-bit -- states, metrics, byte
ledger and telemetry event stream -- at a fraction of the host dispatch
overhead, and ``--terminate`` stops at exactly the eager stopping round
(docs/perf.md, benchmarks/bench_engine.py):

  python -m repro.launch.simulate --alg fedepm --aggregation sync \
      --engine scan --m 50 --rounds 200
  python -m repro.launch.simulate --alg fedepm --aggregation async \
      --buffer-size 4 --engine scan --rounds 200
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.spec import (
    AlgorithmSpec,
    CodecSpec,
    EngineSpec,
    ExperimentSpec,
    FaultSpec,
    FleetSpec,
    PolicySpec,
    PrivacySpec,
    SpecError,
    TaskSpec,
)
from repro.spec.build import SIM_KNOB_DEFAULTS
from repro.spec.registry import ASYNC_KNOBS

# argparse defaults for the policy-scoped knobs -- the SINGLE source both
# for ap.add_argument(default=...) and for the unset test in
# spec_from_args (a value AT its default is treated as "unset", so the
# ownership validation only fires for knobs the user actually supplied;
# the async knobs use None sentinels instead -- passing their literal
# default to the wrong policy must still error). The values themselves
# come from SimConfig's dataclass defaults (repro.spec.build), except
# --deadline whose CLI surface keeps the historical "<= 0 means
# infinite" encoding of SimConfig's inf default.
_KNOB_DEFAULTS = {
    "deadline": 0.0,
    "overselect": SIM_KNOB_DEFAULTS["overselect_factor"],
    "deadline_slack": SIM_KNOB_DEFAULTS["deadline_slack"],
    "ewma_beta": SIM_KNOB_DEFAULTS["ewma_beta"],
}


def spec_from_args(args) -> ExperimentSpec:
    """Map the legacy flag surface onto an ExperimentSpec.

    The mapping is exact: building the returned spec reproduces the
    trajectory the historical ``build_sim`` flag plumbing produced,
    bit-for-bit (tests/test_spec.py).
    """
    policy_kw = {}
    if args.deadline > 0:                          # <= 0 means infinite
        policy_kw["deadline"] = args.deadline      # misplaced -> SpecError
    if args.aggregation == "overselect" \
            or args.overselect != _KNOB_DEFAULTS["overselect"]:
        policy_kw["overselect_factor"] = args.overselect
    if args.aggregation == "adaptive":
        policy_kw["deadline_slack"] = args.deadline_slack
        policy_kw["ewma_beta"] = args.ewma_beta
    else:
        for knob in ("deadline_slack", "ewma_beta"):
            if getattr(args, knob) != _KNOB_DEFAULTS[knob]:
                policy_kw[knob] = getattr(args, knob)
    for knob in sorted(ASYNC_KNOBS):               # None = not passed
        if getattr(args, knob) is not None:
            policy_kw[knob] = getattr(args, knob)

    if args.trace_file:
        fleet = FleetSpec(kind="trace", trace_file=args.trace_file,
                          latency=args.latency,
                          latency_sigma=args.latency_sigma,
                          latency_alpha=args.latency_alpha)
    else:
        fleet = FleetSpec(
            kind="synthetic",
            availability=args.availability if args.availability != 1.0
            else None,
            latency=args.latency, latency_sigma=args.latency_sigma,
            latency_alpha=args.latency_alpha)

    # getattr default: hand-built Namespaces (tests, library callers)
    # predate the fault flags and simply get the fault-free defaults
    fault_kw = {spec_field: getattr(args, flag, None)
                for flag, spec_field in _FAULT_FLAGS.items()
                if getattr(args, flag, None) is not None}

    privacy_kw = {}
    if getattr(args, "dp_eps", None) is not None:
        privacy_kw["eps"] = args.dp_eps
    if getattr(args, "dp_clip", None) is not None:
        # an explicit clip bound selects the enforced-clip sensitivity
        # mode (the surrogate mode never clips)
        privacy_kw["sensitivity"] = "clip"
        privacy_kw["clip"] = args.dp_clip
    if getattr(args, "secure_agg", False):
        privacy_kw["secure_agg"] = True
    if getattr(args, "privacy_seed", None) is not None:
        privacy_kw["seed"] = args.privacy_seed

    return ExperimentSpec(
        name=f"cli/{args.alg}-{args.aggregation}",
        seed=args.seed,
        task=TaskSpec(kind="logreg", d=args.d, n=args.n, m=args.m),
        algorithm=AlgorithmSpec(name=args.alg, rho=args.rho, k0=args.k0,
                                eps_dp=args.eps),
        fleet=fleet,
        policy=PolicySpec(name=args.aggregation, **policy_kw),
        codec=CodecSpec(topk_frac=args.topk, bits=args.bits,
                        impl=args.quant_impl,
                        error_feedback=args.error_feedback),
        faults=FaultSpec(**fault_kw),
        privacy=PrivacySpec(**privacy_kw),
        engine=EngineSpec(name=args.engine, rounds=args.rounds,
                          terminate=args.terminate))


# CLI fault flags (args attribute -> FaultSpec field). None sentinels: an
# unset flag leaves the FaultSpec default (all rates zero -> no fault
# model, the exact pre-fault simulation).
_FAULT_FLAGS = {
    "fault_drop": "drop_rate",
    "fault_transient": "transient_rate",
    "fault_corrupt": "corrupt_rate",
    "fault_duplicate": "duplicate_rate",
    "fault_max_retries": "max_retries",
    "fault_seed": "seed",
}


def _telemetry_overrides(args) -> dict:
    """--telemetry/--events-out/--trace-out/--jax-profile -> dotted spec
    overrides. Any sink flag implies telemetry.enabled (a sink without a
    recorder would be a guaranteed validation error)."""
    overrides = {}
    if args.events_out:
        overrides["telemetry.events_jsonl"] = args.events_out
    if args.trace_out:
        overrides["telemetry.trace_out"] = args.trace_out
    if args.jax_profile:
        overrides["telemetry.jax_profiler_dir"] = args.jax_profile
    if args.telemetry or overrides:
        overrides["telemetry.enabled"] = True
    return overrides


def resolve_spec(args) -> ExperimentSpec:
    """--spec file (plus explicit overrides) or the legacy-flag mapping."""
    if not args.spec:
        exp = spec_from_args(args)
        overrides = _telemetry_overrides(args)
        return (exp.replace(**overrides) if overrides else exp).validate()
    exp = ExperimentSpec.load(args.spec)
    overrides = _telemetry_overrides(args)
    if args.engine_flag is not None:
        overrides["engine.name"] = args.engine_flag
    if args.rounds_flag is not None:
        overrides["engine.rounds"] = args.rounds_flag
    if args.terminate_flag:
        overrides["engine.terminate"] = True
    if args.seed_flag is not None:
        overrides["seed"] = args.seed_flag
    return (exp.replace(**overrides) if overrides else exp).validate()


def run(args) -> dict:
    exp = resolve_spec(args)
    handle = exp.build()
    m = exp.task.m

    def report(met, f):
        if args.quiet:
            return
        head = (f"round {met.round_idx:3d}  f/m={f / m:.6f}  " if f is not None
                else f"round {met.round_idx:3d}  ")
        print(head
              + f"t={met.t_total:9.4f}s (+{met.t_round:.4f})  "
                f"agg={met.n_aggregated}/{met.n_contacted} "
                f"drop={met.n_dropped}  "
                f"up={met.bytes_up/1e3:.1f}kB "
                f"down={met.bytes_down/1e3:.1f}kB"
              + ("  ABANDONED" if met.abandoned else ""), flush=True)

    summary = handle.run(report=report)
    if not args.quiet:
        print("\nsummary:")
        for k, v in summary.items():
            print(f"  {k:28s} {v}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Federated systems simulation (stragglers, deadlines, "
                    "byte ledger) on the paper logreg task")
    ap.add_argument("--spec", default=None,
                    help="ExperimentSpec file (.toml/.json, docs/spec.md); "
                         "replaces the legacy flags below -- only "
                         "--engine/--rounds/--terminate/--seed and the "
                         "telemetry flags override the file, plus "
                         "--quiet/--json")
    ap.add_argument("--alg", default="fedepm",
                    choices=["fedepm", "sfedavg", "sfedprox"])
    ap.add_argument("--aggregation", "--policy", dest="aggregation",
                    default="sync",
                    choices=["sync", "deadline", "adaptive", "overselect",
                             "async"],
                    help="aggregation mode (--policy is an alias)")
    ap.add_argument("--engine", dest="engine_flag", default=None,
                    choices=["eager", "scan"],
                    help="round execution engine: 'eager' dispatches one "
                         "jit call per round (the semantic reference); "
                         "'scan' compiles multi-round chunks into one "
                         "on-device lax.scan with donated state buffers -- "
                         "bit-identical trajectory, far fewer host syncs "
                         "(docs/perf.md). async aggregation record/replays "
                         "its event loop through the same compiled path; "
                         "--terminate stops at exactly the eager stopping "
                         "round. Default: eager, or the spec file's engine")
    ap.add_argument("--deadline", type=float,
                    default=_KNOB_DEFAULTS["deadline"],
                    help="deadline policy cutoff in simulated seconds "
                         "(<= 0 means infinite)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="async: contributions per aggregation event "
                         "(0 = cohort size, which recovers sync exactly)")
    ap.add_argument("--staleness-exp", type=float, default=None,
                    help="async: stale merges weighted (1+s)^-exp "
                         "(default 0.5)")
    ap.add_argument("--max-concurrency", type=int, default=None,
                    help="async: cap on in-flight clients; dispatches past "
                         "the cap queue until an upload frees a slot "
                         "(0 = unlimited, which dispatches whole cohorts)")
    ap.add_argument("--deadline-slack", type=float,
                    default=_KNOB_DEFAULTS["deadline_slack"],
                    help="adaptive: per-client wait budget = slack * EWMA")
    ap.add_argument("--ewma-beta", type=float,
                    default=_KNOB_DEFAULTS["ewma_beta"],
                    help="adaptive: EWMA weight of the newest latency")
    ap.add_argument("--overselect", type=float,
                    default=_KNOB_DEFAULTS["overselect"],
                    help="over-selection factor: contact a uniform "
                         "candidate set at rate rho*f, keep the first "
                         "ceil(rho*m) arrivals")
    ap.add_argument("--latency", default="deterministic",
                    choices=["deterministic", "lognormal", "pareto"])
    ap.add_argument("--latency-sigma", type=float, default=0.5)
    ap.add_argument("--latency-alpha", type=float, default=1.2)
    ap.add_argument("--availability", type=float, default=1.0,
                    help="P(client reachable per round) for the synthetic "
                         "profiles; a --trace-file fleet carries its own "
                         "availability column instead")
    ap.add_argument("--trace-file", default=None,
                    help="CSV/JSON device trace; the fleet is resampled "
                         "from it instead of the synthetic lognormal "
                         "profiles (schema: sim/clients.py::LatencyTrace; "
                         "overrides --availability)")
    ap.add_argument("--m", type=int, default=50)
    ap.add_argument("--n", type=int, default=14)
    ap.add_argument("--d", type=int, default=4000,
                    help="dataset size (4000 = reduced task; paper: 45222)")
    ap.add_argument("--rounds", dest="rounds_flag", type=int, default=None,
                    help="round budget (default 30, or the spec file's)")
    ap.add_argument("--rho", type=float, default=0.5)
    ap.add_argument("--k0", type=int, default=8)
    ap.add_argument("--eps", type=float, default=0.0,
                    help="DP epsilon (0 disables noise)")
    ap.add_argument("--topk", type=float, default=1.0,
                    help="codec: fraction of coordinates uploaded")
    ap.add_argument("--bits", type=int, default=0,
                    help="codec: quantization bits (0 = raw values)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="codec: EF21-style memory (compress residuals "
                         "against the shared reconstruction)")
    ap.add_argument("--quant-impl", default="ref",
                    choices=["ref", "pallas"])
    ap.add_argument("--fault-drop", type=float, default=None,
                    help="fault injection: P(an upload attempt is lost "
                         "mid-flight) -- billed but never arrives "
                         "(docs/sim.md fault model)")
    ap.add_argument("--fault-transient", type=float, default=None,
                    help="fault injection: P(an upload attempt fails "
                         "transiently); the server retries with "
                         "exponential backoff, each attempt billed")
    ap.add_argument("--fault-corrupt", type=float, default=None,
                    help="fault injection: P(an upload arrives corrupted); "
                         "the server screens and rejects it, repeat "
                         "offenders are quarantined")
    ap.add_argument("--fault-duplicate", type=float, default=None,
                    help="fault injection: P(a successful upload is "
                         "delivered twice); the server dedups by sequence "
                         "number, the copy is billed and discarded")
    ap.add_argument("--fault-max-retries", type=int, default=None,
                    help="fault injection: retry budget per contribution "
                         "before the client is abandoned for the round "
                         "(default 2)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="fault injection: dedicated RNG seed (default: "
                         "derived from --seed; fault draws never perturb "
                         "the latency stream)")
    ap.add_argument("--dp-eps", type=float, default=None,
                    help="upload privacy: per-round per-client DP epsilon "
                         "budget; uploads are Laplace-noised on the wire "
                         "and the accountant tracks spent budget "
                         "(docs/privacy.md). Distinct from the "
                         "in-algorithm --eps noise")
    ap.add_argument("--dp-clip", type=float, default=None,
                    help="upload privacy: enforce ||z||_1 <= clip before "
                         "noising and use the data-independent 2*clip "
                         "sensitivity (default: the paper's 2*||z||_1 "
                         "surrogate; requires --dp-eps)")
    ap.add_argument("--secure-agg", action="store_true",
                    help="upload privacy: bill one pairwise-mask exchange "
                         "per upload attempt that reaches the wire "
                         "(32 bytes each; composes with --fault-* retries)")
    ap.add_argument("--privacy-seed", type=int, default=None,
                    help="upload privacy: dedicated noise-stream seed "
                         "(default: derived from --seed; noise draws never "
                         "perturb the latency or codec streams; requires "
                         "--dp-eps or --secure-agg)")
    ap.add_argument("--seed", dest="seed_flag", type=int, default=None,
                    help="master seed (default 0, or the spec file's)")
    ap.add_argument("--terminate", dest="terminate_flag",
                    action="store_true",
                    help="stop at the paper's termination rule")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach the run-telemetry recorder (events + "
                         "metrics; docs/observability.md). The trajectory "
                         "is bit-for-bit unchanged; the summary gains a "
                         "'telemetry' block. Implied by any sink flag "
                         "below. Composes with --spec")
    ap.add_argument("--events-out", default=None,
                    help="telemetry sink: write the event stream as JSONL "
                         "(one event per line; implies --telemetry)")
    ap.add_argument("--trace-out", default=None,
                    help="telemetry sink: write a Perfetto/Chrome "
                         "trace_event JSON of the simulated timeline -- "
                         "one track per client -- loadable in "
                         "ui.perfetto.dev (implies --telemetry)")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="wrap the run in jax.profiler for a real "
                         "wall-time trace under DIR (implies --telemetry)")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary dict to this path")
    args = ap.parse_args(argv)

    # legacy-surface defaults (the spec file's values win under --spec)
    args.engine = args.engine_flag or "eager"
    args.rounds = args.rounds_flag if args.rounds_flag is not None else 30
    args.seed = args.seed_flag if args.seed_flag is not None else 0
    args.terminate = args.terminate_flag

    if args.rounds < 1:
        ap.error("--rounds must be >= 1")
    if args.buffer_size is not None and args.buffer_size < 0:
        ap.error("--buffer-size must be >= 0 (0 = cohort size)")
    if args.max_concurrency is not None and args.max_concurrency < 0:
        ap.error("--max-concurrency must be >= 0 (0 = unlimited)")
    if args.staleness_exp is not None and args.staleness_exp < 0:
        ap.error("--staleness-exp must be >= 0")
    if args.spec:
        # the spec file IS the experiment; a legacy flag alongside it
        # would be silently ignored, which the spec layer forbids --
        # detectably-supplied ones (off-default) are hard errors
        ignored = [f"--{k.replace('_', '-')}"
                   for k in ("alg", "aggregation", "deadline", "overselect",
                             "deadline_slack", "ewma_beta", "latency",
                             "latency_sigma", "latency_alpha",
                             "availability", "trace_file", "m", "n", "d",
                             "rho", "k0", "eps", "topk", "bits",
                             "error_feedback", "quant_impl",
                             *sorted(_FAULT_FLAGS),
                             "dp_eps", "dp_clip", "secure_agg",
                             "privacy_seed",
                             *sorted(ASYNC_KNOBS))
                   if getattr(args, k) != ap.get_default(k)]
        if ignored:
            ap.error(f"{', '.join(ignored)} cannot be combined with "
                     f"--spec (the file defines the experiment; only "
                     f"--engine/--rounds/--terminate/--seed override it)")
    elif args.aggregation != "async":
        passed = [f"--{k.replace('_', '-')}" for k in sorted(ASYNC_KNOBS)
                  if getattr(args, k) is not None]
        if passed:
            ap.error(f"{', '.join(passed)} only valid with "
                     f"--aggregation async; got --aggregation "
                     f"{args.aggregation}")
    if args.error_feedback and args.topk >= 1.0 and args.bits == 0:
        ap.error("--error-feedback needs a lossy codec: set --topk < 1 "
                 "and/or --bits > 0")
    # privacy knob ownership, mirroring the spec layer: a knob supplied
    # without the state it configures is an error, never silently unused
    if args.dp_clip is not None and not (args.dp_eps and args.dp_eps > 0):
        ap.error("--dp-clip bounds the DP noise sensitivity; it requires "
                 "--dp-eps > 0")
    if args.privacy_seed is not None and not (
            (args.dp_eps and args.dp_eps > 0) or args.secure_agg):
        ap.error("--privacy-seed keys the privacy noise stream; it "
                 "requires --dp-eps > 0 or --secure-agg")
    if args.trace_file and args.availability != 1.0:
        ap.error("--availability conflicts with --trace-file: the trace's "
                 "own availability column defines the fleet")

    try:
        summary = run(args)
    except SpecError as e:
        ap.error(str(e))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
