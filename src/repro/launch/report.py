"""Build the EXPERIMENTS.md §Dry-run and §Roofline tables from the
artifacts/dryrun JSON records.

    PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def _fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def load_records(root: str, mesh: str):
    d = os.path.join(root, mesh)
    recs = []
    if not os.path.isdir(d):
        return recs
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json") and "__" in fn:
            with open(os.path.join(d, fn)) as f:
                recs.append(json.load(f))
    return recs


def dryrun_table(recs):
    from repro.models.config import INPUT_SHAPES
    lines = [
        "| arch | shape | status | peak GB/dev | HLO flops (raw) | "
        "compile s | notes |",
        "|---|---|---|---|---|---|---|",
    ]
    shape_order = list(INPUT_SHAPES)
    recs = sorted(recs, key=lambda r: (r["arch"],
                                       shape_order.index(r["shape"])))
    for r in recs:
        if r.get("tag"):
            continue
        if r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{_fmt_bytes(r['memory']['peak_bytes'])} | "
                f"{r['cost'].get('flops', 0):.3e} | "
                f"{r.get('compile_s', 0):.0f} | {r.get('notes', '')} |")
        elif r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - |"
                         f" {r['reason']} |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - |"
                         f" {r['error'][:80]} |")
    return "\n".join(lines)


def roofline_table(recs, root: str, mesh: str):
    from repro.launch.roofline import analyse
    from repro.launch.steps import resolve_arch
    from repro.models.config import INPUT_SHAPES

    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MODEL_FLOPS | useful | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    shape_order = list(INPUT_SHAPES)
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         shape_order.index(r["shape"]))):
        if r.get("tag") or r["status"] != "ok":
            continue
        shape = INPUT_SHAPES[r["shape"]]
        cfg = resolve_arch(r["arch"], shape)[0]
        a = analyse(r, cfg, shape)
        rows.append(a)
        dom = {"compute": a.compute_s, "memory": a.memory_s,
               "collective": a.collective_s}[a.bottleneck]
        note = ""
        if a.bottleneck == "compute":
            note = "more chips / lower-precision matmuls"
        elif a.bottleneck == "memory":
            note = "fuse elementwise passes / quantise state"
        else:
            note = "coordinate-sharded ENS (a2a) / overlap"
        lines.append(
            f"| {a.arch} | {a.shape} | {_fmt_s(a.compute_s)} | "
            f"{_fmt_s(a.memory_s)} | {_fmt_s(a.collective_s)} | "
            f"**{a.bottleneck}** | {a.model_flops:.3e} | "
            f"{a.useful_ratio:.2f} | {note} |")
    return "\n".join(lines), rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--kind", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args(argv)
    recs = load_records(args.dir, args.mesh)
    if args.kind in ("dryrun", "both"):
        print(f"### Dry-run table ({args.mesh} mesh, "
              f"{'2x16x16' if args.mesh == 'multi' else '16x16'})\n")
        print(dryrun_table(recs))
        print()
    if args.kind in ("roofline", "both"):
        print(f"### Roofline table ({args.mesh} mesh)\n")
        t, _ = roofline_table(recs, args.dir, args.mesh)
        print(t)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
