"""Production training launcher: FedEPM as the distributed optimizer.

On a real TPU slice this runs under jax.distributed with the production
mesh; on this CPU host, pass --devices N to simulate N devices and a
proportionally reduced mesh (the same code path: pjit + shardings from
launch/steps.py).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --devices 8 --mesh-shape 4,2 --rounds 3 --reduced

Federated mode (``--spec``): an lm-kind ExperimentSpec (docs/spec.md)
runs the arch through the SAME FedSim round loop as the logreg sim --
aggregation policies, device fleets, upload codecs, and the fused scan
engine all apply to the LM task, closing the "wire the sim into the
LM-scale launch path" roadmap item:

    PYTHONPATH=src python -m repro.launch.train \
        --spec examples/specs/lm_federated.toml
"""
import argparse
import os
import sys


def run_spec(args) -> int:
    """Federated-simulation mode: drive the spec's LM arch through
    FedSim/the scan engine (repro.spec.build.RunHandle)."""
    import time

    from repro.spec import ExperimentSpec, SpecError

    try:
        exp = ExperimentSpec.load(args.spec)
        if args.rounds_flag is not None:
            exp = exp.replace(**{"engine.rounds": args.rounds_flag})
        if args.engine_flag is not None:
            exp = exp.replace(**{"engine.name": args.engine_flag})
        exp.validate()
        if exp.task.kind != "lm":
            raise SpecError(
                f"train --spec expects an lm-kind task (this is the "
                f"LM-scale launcher); got kind={exp.task.kind!r} -- run "
                f"logreg specs via python -m repro.launch.simulate --spec")
        handle = exp.build()
    except SpecError as e:
        print(f"SPEC ERROR: {e}", file=sys.stderr)
        return 2
    import jax

    cfg = handle.data.aux["arch_cfg"]
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        handle.data.params0))
    print(f"spec={exp.name} arch={cfg.name} params={n_params/1e6:.2f}M "
          f"m={exp.task.m} alg={exp.algorithm.name} "
          f"policy={exp.policy.name} engine={exp.engine.name} "
          f"rounds={exp.engine.rounds}")

    t0 = time.time()

    def report(met, f):
        loss_str = f"loss={f / exp.task.m:.4f}  " if f is not None else ""
        print(f"round {met.round_idx:3d}  {loss_str}"
              f"t_sim={met.t_total:.3f}s  "
              f"agg={met.n_aggregated}/{met.n_contacted}  "
              f"up={met.bytes_up/1e6:.2f}MB  ({time.time()-t0:.1f}s)",
              flush=True)

    summary = handle.run(report=report)
    print(f"\nfinal loss/m={summary['f_final']:.4f}  "
          f"sim_time={summary['sim_time_s']:.3f}s  "
          f"bytes_total={summary['bytes_total']:.0f}  "
          f"({time.time()-t0:.1f}s wall)")
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
    if args.checkpoint:
        from repro.checkpoint import save
        save(args.checkpoint, jax.device_get(handle.sim.state.w_tau),
             {"arch": cfg.name, "spec": exp.name})
        print("saved", args.checkpoint)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=None,
                    help="lm-kind ExperimentSpec file: run the arch "
                         "FEDERATED through the systems sim (FedSim + "
                         "eager/scan engine) instead of the pjit mesh "
                         "path; --rounds/--engine override the file")
    ap.add_argument("--engine", dest="engine_flag", default=None,
                    choices=["eager", "scan"],
                    help="(--spec only) round engine override")
    ap.add_argument("--json", default=None,
                    help="(--spec only) write the run summary dict here")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--rounds", dest="rounds_flag", type=int, default=None,
                    help="round budget (default: 3, or the --spec file's)")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (0 = real devices)")
    ap.add_argument("--mesh-shape", default="",
                    help="data,model (default: production 16,16)")
    ap.add_argument("--ens", default="gather", choices=["gather", "a2a"])
    ap.add_argument("--k0", type=int, default=4)
    ap.add_argument("--seq", type=int, default=0,
                    help="override seq_len (CPU demos; 0 = production 4096)")
    ap.add_argument("--global-batch", type=int, default=0,
                    help="override global batch (0 = production 256)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args(argv)

    if args.spec:
        # the spec file defines the experiment; a mesh-path flag alongside
        # it would be silently ignored, which the spec layer forbids
        # (same contract as simulate.py) -- only --rounds/--engine
        # override the file, plus --json/--checkpoint outputs
        ignored = [f"--{k.replace('_', '-')}"
                   for k in ("arch", "reduced", "devices", "mesh_shape",
                             "ens", "k0", "seq", "global_batch")
                   if getattr(args, k) != ap.get_default(k)]
        if ignored:
            ap.error(f"{', '.join(ignored)} cannot be combined with "
                     f"--spec (the file defines the experiment; only "
                     f"--rounds/--engine override it)")
        return run_spec(args)
    args.rounds = args.rounds_flag if args.rounds_flag is not None else 3

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_production_mesh

    if args.mesh_shape:
        dd, mm = (int(x) for x in args.mesh_shape.split(","))
        mesh = jax.make_mesh((dd, mm), ("data", "model"))
    else:
        mesh = make_production_mesh()
    print(f"mesh: {dict(mesh.shape)}  devices: {len(jax.devices())}")

    if args.seq or args.global_batch:
        import dataclasses as _dc

        from repro.models.config import INPUT_SHAPES
        base = INPUT_SHAPES["train_4k"]
        INPUT_SHAPES["train_4k"] = _dc.replace(
            base, seq_len=args.seq or base.seq_len,
            global_batch=args.global_batch or base.global_batch)
    if args.reduced:
        real_get = configs.get_config
        configs.get_config = configs.get_reduced
    try:
        bundle = steps_mod.build_train_step(args.arch, mesh, ens=args.ens,
                                            k0=args.k0)
    finally:
        if args.reduced:
            configs.get_config = real_get
    if isinstance(bundle, steps_mod.Skip):
        print("SKIP:", bundle.reason)
        return 1
    cfg = bundle.static["cfg"]
    m = bundle.static["m"]
    b_local = bundle.static["b_local"]
    print(f"arch={cfg.name} fedepm[{bundle.static['mode']}] m={m} "
          f"b_local={b_local} seq={args.seq or 4096} k0={args.k0}")

    # real data + real init (the dry-run path uses ShapeDtypeStructs; the
    # launcher allocates)
    from repro.core import distributed as dist_mod
    from repro.core.fedepm import FedEPMConfig
    from repro.data.lm import federated_token_batches
    from repro.models.registry import get_model

    jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)

    model = get_model(cfg)
    fed_cfg = bundle.static["fed"]
    dist = dist_mod.DistConfig()  # only init_fn is needed here
    init_fn, _, _ = dist_mod.build_fedepm(model, lambda *a: 0.0, fed_cfg,
                                          mesh, dist)
    state = init_fn(jax.random.PRNGKey(0))

    seq = bundle.args[1]["tokens"].shape[-1] if "tokens" in bundle.args[1] \
        else bundle.args[1]["frame_embeds"].shape[-2]
    stream = federated_token_batches(cfg.vocab, m, b_local, seq,
                                     steps=args.rounds)
    import time
    for r, raw in enumerate(stream):
        batch = {}
        for k, spec in bundle.args[1].items():
            if k in raw:
                batch[k] = jnp.asarray(raw[k][..., :spec.shape[-1]])
            else:  # frontend stubs
                batch[k] = jnp.zeros(spec.shape, spec.dtype)
        if "targets" in bundle.args[1] and "targets" in raw:
            tgt_shape = bundle.args[1]["targets"].shape
            t = np.zeros(tgt_shape, np.int32)
            tt = raw["targets"][..., :tgt_shape[-1]]
            t[..., -tt.shape[-1]:] = tt
            batch["targets"] = jnp.asarray(t)
            lm_ = np.zeros(tgt_shape, np.float32)
            lm_[..., -tt.shape[-1]:] = 1.0
            batch["loss_mask"] = jnp.asarray(lm_)
        t0 = time.time()
        state, metrics = jitted(state, batch)
        jax.block_until_ready(metrics.drift)
        print(f"round {r}: drift={float(metrics.drift):.3e} "
              f"snr={float(metrics.snr):.2f} "
              f"sel={int(metrics.selected.sum())}/{m} "
              f"({time.time()-t0:.1f}s)")
    if args.checkpoint:
        from repro.checkpoint import save
        save(args.checkpoint, jax.device_get(state.w_tau),
             {"arch": cfg.name})
        print("saved", args.checkpoint)
    return 0


if __name__ == "__main__":
    sys.exit(main())
