"""Production training launcher: FedEPM as the distributed optimizer.

On a real TPU slice this runs under jax.distributed with the production
mesh; on this CPU host, pass --devices N to simulate N devices and a
proportionally reduced mesh (the same code path: pjit + shardings from
launch/steps.py).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --devices 8 --mesh-shape 4,2 --rounds 3 --reduced
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (0 = real devices)")
    ap.add_argument("--mesh-shape", default="",
                    help="data,model (default: production 16,16)")
    ap.add_argument("--ens", default="gather", choices=["gather", "a2a"])
    ap.add_argument("--k0", type=int, default=4)
    ap.add_argument("--seq", type=int, default=0,
                    help="override seq_len (CPU demos; 0 = production 4096)")
    ap.add_argument("--global-batch", type=int, default=0,
                    help="override global batch (0 = production 256)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_production_mesh

    if args.mesh_shape:
        dd, mm = (int(x) for x in args.mesh_shape.split(","))
        mesh = jax.make_mesh((dd, mm), ("data", "model"))
    else:
        mesh = make_production_mesh()
    print(f"mesh: {dict(mesh.shape)}  devices: {len(jax.devices())}")

    if args.seq or args.global_batch:
        import dataclasses as _dc

        from repro.models.config import INPUT_SHAPES
        base = INPUT_SHAPES["train_4k"]
        INPUT_SHAPES["train_4k"] = _dc.replace(
            base, seq_len=args.seq or base.seq_len,
            global_batch=args.global_batch or base.global_batch)
    if args.reduced:
        real_get = configs.get_config
        configs.get_config = configs.get_reduced
    try:
        bundle = steps_mod.build_train_step(args.arch, mesh, ens=args.ens,
                                            k0=args.k0)
    finally:
        if args.reduced:
            configs.get_config = real_get
    if isinstance(bundle, steps_mod.Skip):
        print("SKIP:", bundle.reason)
        return 1
    cfg = bundle.static["cfg"]
    m = bundle.static["m"]
    b_local = bundle.static["b_local"]
    print(f"arch={cfg.name} fedepm[{bundle.static['mode']}] m={m} "
          f"b_local={b_local} seq={args.seq or 4096} k0={args.k0}")

    # real data + real init (the dry-run path uses ShapeDtypeStructs; the
    # launcher allocates)
    from repro.core import distributed as dist_mod
    from repro.core.fedepm import FedEPMConfig
    from repro.data.lm import federated_token_batches
    from repro.models.registry import get_model

    jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)

    model = get_model(cfg)
    fed_cfg = bundle.static["fed"]
    dist = dist_mod.DistConfig()  # only init_fn is needed here
    init_fn, _, _ = dist_mod.build_fedepm(model, lambda *a: 0.0, fed_cfg,
                                          mesh, dist)
    state = init_fn(jax.random.PRNGKey(0))

    seq = bundle.args[1]["tokens"].shape[-1] if "tokens" in bundle.args[1] \
        else bundle.args[1]["frame_embeds"].shape[-2]
    stream = federated_token_batches(cfg.vocab, m, b_local, seq,
                                     steps=args.rounds)
    import time
    for r, raw in enumerate(stream):
        batch = {}
        for k, spec in bundle.args[1].items():
            if k in raw:
                batch[k] = jnp.asarray(raw[k][..., :spec.shape[-1]])
            else:  # frontend stubs
                batch[k] = jnp.zeros(spec.shape, spec.dtype)
        if "targets" in bundle.args[1] and "targets" in raw:
            tgt_shape = bundle.args[1]["targets"].shape
            t = np.zeros(tgt_shape, np.int32)
            tt = raw["targets"][..., :tgt_shape[-1]]
            t[..., -tt.shape[-1]:] = tt
            batch["targets"] = jnp.asarray(t)
            lm_ = np.zeros(tgt_shape, np.float32)
            lm_[..., -tt.shape[-1]:] = 1.0
            batch["loss_mask"] = jnp.asarray(lm_)
        t0 = time.time()
        state, metrics = jitted(state, batch)
        jax.block_until_ready(metrics.drift)
        print(f"round {r}: drift={float(metrics.drift):.3e} "
              f"snr={float(metrics.snr):.2f} "
              f"sel={int(metrics.selected.sum())}/{m} "
              f"({time.time()-t0:.1f}s)")
    if args.checkpoint:
        from repro.checkpoint import save
        save(args.checkpoint, jax.device_get(state.w_tau),
             {"arch": cfg.name})
        print("saved", args.checkpoint)
    return 0


if __name__ == "__main__":
    sys.exit(main())
