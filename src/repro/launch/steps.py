"""Step builders: (architecture x input shape x mesh) -> jit-ready step.

This is the piece the dry-run, the roofline tool, and the real launchers
all share. For every assigned (arch, shape) pair it produces a
``StepBundle``: the step callable, abstract arguments (ShapeDtypeStruct --
no allocation), and the in/out shardings for the production mesh.

Shape semantics (assignment spec):
  train_4k    -> ONE FedEPM communication round (the paper's technique is
                 the trainer; k0 inner iterations + ENS aggregation + DP
                 upload). Client layout per configs.fed_plan (spatial /
                 temporal, DESIGN.md §2a).
  prefill_32k -> serve_prefill: full forward over the prompt, returns
                 next-token logits + decode state.
  decode_32k, long_500k -> serve_decode: ONE token through a KV/recurrent
                 cache of seq_len. long_500k on full-attention archs uses
                 the sliding-window VARIANT (window 4096); encoder-only
                 archs skip decode shapes (both recorded in notes/skips).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import distributed as dist_mod
from repro.core.fedepm import FedEPMConfig
from repro.core.tasks import make_chunked_lm_loss
from repro.launch.mesh import client_axes, n_client_groups
from repro.sharding.rules import DEFAULT_RULES, axis_rules
from repro.models import dense as dense_mod
from repro.models.config import INPUT_SHAPES, ArchConfig, InputShape
from repro.models.registry import Model, get_model

SWA_WINDOW = 4096  # sliding-window width for the long_500k dense variant

# serving params above this many bytes-per-chip (TP-only) switch to
# FSDP(+TP) storage so one copy fits HBM
_SERVE_FSDP_THRESHOLD = 8 << 30


@dataclasses.dataclass
class StepBundle:
    arch: str
    shape: str
    kind: str                 # "train" | "prefill" | "decode"
    fn: Callable              # step(*args)
    args: tuple               # abstract args (ShapeDtypeStruct pytrees)
    in_shardings: tuple
    out_shardings: Any        # None = let XLA choose
    donate_argnums: tuple = ()
    notes: str = ""
    static: dict = dataclasses.field(default_factory=dict)

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        return jitted.lower(*self.args)


@dataclasses.dataclass
class Skip:
    arch: str
    shape: str
    reason: str


# ---------------------------------------------------------------------------
# arch resolution (variants + skips)
# ---------------------------------------------------------------------------

def resolve_arch(name: str, shape: InputShape):
    """Returns (cfg, note) or Skip."""
    cfg = configs.get_config(name)
    note = ""
    if shape.kind == "decode" and cfg.attention == "bidirectional":
        return Skip(name, shape.name,
                    "encoder-only architecture: no decode step exists")
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("xlstm", "hybrid", "ssm") or \
            cfg.sliding_window is not None
        if not sub_quadratic:
            if cfg.family in ("dense", "vlm"):
                cfg = dataclasses.replace(cfg, sliding_window=SWA_WINDOW)
                note = (f"long_500k uses the sliding-window VARIANT "
                        f"(window={SWA_WINDOW}); full attention would need "
                        f"a {shape.seq_len}-token dense cache")
            else:
                return Skip(name, shape.name,
                            "no sub-quadratic variant for this family")
    return cfg, note


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, never allocated)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def lm_batch_specs(cfg: ArchConfig, lead: tuple, seq: int,
                   with_targets: bool = True) -> dict:
    """Batch pytree for one model call; ``lead`` are leading axes
    (e.g. (m, b) for stacked clients, (B,) for serving)."""
    d = {}
    if cfg.family == "audio":
        d["frame_embeds"] = _sds(lead + (seq, cfg.d_model), cfg.dtype)
        t_total = seq
    elif cfg.family == "vlm":
        t_text = max(seq - cfg.n_patches, 16)
        d["tokens"] = _sds(lead + (t_text,), jnp.int32)
        d["patch_embeds"] = _sds(lead + (cfg.n_patches, cfg.d_model),
                                 cfg.dtype)
        t_total = t_text + cfg.n_patches
    else:
        d["tokens"] = _sds(lead + (seq,), jnp.int32)
        t_total = seq
    if with_targets:
        d["targets"] = _sds(lead + (t_total,), jnp.int32)
        d["loss_mask"] = _sds(lead + (t_total,), jnp.float32)
    return d


def train_activation_rules(mesh: Mesh, mode: str,
                           seq_parallel: bool = True) -> dict:
    """Logical-axis rules active while TRACING the train step.

    seq_res -> "model" is Megatron-style sequence parallelism for the
    residual stream: the per-layer saved activations (the only cross-layer
    memory under per-block remat) are sharded 16-way; attention/MLP inputs
    are re-gathered per block. In spatial mode the per-client batch axis is
    unsharded (the client axis is pinned by vmap spmd_axis_name); in
    temporal mode the batch axis shards over the client axes."""
    ca = client_axes(mesh)
    r = dict(DEFAULT_RULES)
    r.update({
        "batch": None if mode == "spatial" else ca,
        "seq": None,
        "seq_res": ("model",) if seq_parallel else None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": None,
    })
    return r


def serve_activation_rules(mesh: Mesh) -> dict:
    ca = client_axes(mesh)
    r = dict(DEFAULT_RULES)
    r.update({
        "batch": ca,
        "seq": None,
        "seq_res": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": None,
    })
    return r


def _unembed_chunk(cfg: ArchConfig):
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        return lambda h, params: dense_mod.unembed(h, params, cfg)
    return lambda h, params: jnp.einsum(
        "btd,dv->btv", h, params["unembed"].astype(h.dtype))


# ---------------------------------------------------------------------------
# serve-state spec heuristic
# ---------------------------------------------------------------------------

def auto_state_specs(abstract_state, mesh: Mesh, batch_size: int,
                     batch_axes: tuple, model_axis: str = "model"):
    """Per-leaf: first axis (among the leading two) equal to batch_size ->
    batch axes; then the largest remaining divisible axis -> model axis.
    Tiny leaves stay replicated."""
    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    bsz = int(np.prod([mesh.shape[a] for a in
                       (batch_axes if isinstance(ba, tuple) else (ba,))]))
    ms = mesh.shape[model_axis]

    def one(leaf):
        parts = [None] * leaf.ndim
        if batch_size > 1:
            for i in range(min(2, leaf.ndim)):
                if leaf.shape[i] == batch_size and batch_size % bsz == 0:
                    parts[i] = ba
                    break
        best, best_dim = -1, 0
        for i in range(leaf.ndim):
            if parts[i] is None and leaf.shape[i] % ms == 0 \
                    and leaf.shape[i] >= max(ms, 64) \
                    and leaf.shape[i] > best_dim:
                best, best_dim = i, leaf.shape[i]
        if best >= 0 and leaf.size >= (1 << 16):
            parts[best] = model_axis
        return P(*parts)

    return jax.tree_util.tree_map(one, abstract_state)


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# train step (FedEPM round)
# ---------------------------------------------------------------------------

def build_train_step(arch: str, mesh: Mesh, *, ens: str = "gather",
                     k0: int = 4, eps_dp: float = 0.1, rho: float = 0.5,
                     remat: bool = False, loss_chunk: int = 512):
    # per-BLOCK remat is on by default via ArchConfig.remat; ``remat`` here
    # additionally remats the WHOLE loss (rarely needed).
    shape = INPUT_SHAPES["train_4k"]
    res = resolve_arch(arch, shape)
    if isinstance(res, Skip):
        return res
    cfg, note = res
    plan = configs.fed_plan(arch)
    ca = client_axes(mesh)
    if plan["mode"] == "spatial":
        m = n_client_groups(mesh)
        dist = dist_mod.DistConfig(
            mode="spatial", ens=ens, client_axes=ca, fsdp_axes=(),
            state_dtype=jnp.bfloat16
            if plan.get("state_dtype") == "bfloat16" else None,
            remat=remat)
        # tiny models: tensor parallelism over 16 chips costs more in
        # per-layer activation collectives than it saves (smollm: X=567ms
        # vs C=37ms); instead replicate weights inside the client group
        # and use the "model" axis as intra-client BATCH parallelism
        # (EXPERIMENTS.md §Perf 1.6)
        from repro.launch.roofline import total_param_bytes
        tiny = total_param_bytes(cfg) // mesh.shape["model"] < (128 << 20)
    else:
        m = int(plan["m"])
        b_client = shape.global_batch // m
        # batch axes: largest suffix of the client axes whose product
        # divides the per-client batch (multi-pod: 16-seq clients cannot
        # shard over pod x data = 32)
        batch_axes = ca
        while batch_axes and b_client % int(np.prod(
                [mesh.shape[a] for a in batch_axes])):
            batch_axes = batch_axes[1:]
        batch_axes = batch_axes or ("data",)
        # cap microbatching so the per-step batch still covers the batch
        # mesh axes: if b_step < |axes| XLA cannot batch-partition the
        # attention and falls back to contraction sharding, inserting an
        # all-reduce PER ATTENTION CHUNK (measured: x61440 on llava,
        # EXPERIMENTS.md §Perf 1.1)
        ca_size = int(np.prod([mesh.shape[a] for a in batch_axes]))
        mb = min(int(plan.get("microbatch", 1)),
                 max(1, b_client // ca_size))
        dist = dist_mod.DistConfig(
            mode="temporal", ens="gather", client_axes=batch_axes,
            fsdp_axes=("data",), state_dtype=None, remat=remat,
            microbatch=mb)
    if shape.global_batch % m:
        raise ValueError(f"global_batch {shape.global_batch} % m {m}")
    b_local = shape.global_batch // m

    model = get_model(cfg)
    family = type(model)  # noqa: F841
    from repro.models import registry as _r  # family module for hidden()
    mod = _r._FAMILY_MODULES[cfg.family]
    hidden_fn = lambda params, batch: mod.hidden(params, batch, cfg)  # noqa
    loss_fn = make_chunked_lm_loss(hidden_fn, _unembed_chunk(cfg),
                                   chunk=loss_chunk)

    fed_cfg = FedEPMConfig.paper_defaults(m=m, rho=rho, k0=k0,
                                          eps_dp=eps_dp)
    init_fn, step_fn, sspecs_fn = dist_mod.build_fedepm(
        model, loss_fn, fed_cfg, mesh, dist)

    abstract_state = jax.eval_shape(init_fn, jax.ShapeDtypeStruct(
        (2,), jnp.uint32))
    sspecs = sspecs_fn(abstract_state)
    batch = lm_batch_specs(cfg, (m, b_local), shape.seq_len)
    bspecs = dist_mod.batch_specs(batch, dist)

    # sequence-parallel residuals pay a per-layer all-gather; only worth
    # it when the stored residual stream would otherwise threaten HBM
    # (measured: smollm paid 40 GB/device of gathers to save 2 GB of
    # storage -- EXPERIMENTS.md §Perf 1.2)
    b_step = b_local if dist.mode == "spatial" \
        else (shape.global_batch // m) // max(dist.microbatch, 1)
    resid_bytes = cfg.n_layers * b_step * shape.seq_len * cfg.d_model * 2
    rules = train_activation_rules(mesh, dist.mode,
                                   seq_parallel=resid_bytes > 4e9)
    if dist.mode == "spatial" and tiny and b_local % mesh.shape["model"] == 0:
        rules.update({"batch": ("model",), "heads": None, "kv_heads": None,
                      "mlp": None, "vocab": None, "seq_res": None})
        # feature storage fully replicated too: a model-sharded weight
        # consumed INSIDE a recurrent scan inserts a collective per
        # timestep (xlstm sLSTM: 2.4 MB all-reduce x 4096 steps x 3
        # layers -- EXPERIMENTS.md §Perf 1.7)
        def _m_only(spec):
            return P(spec[0]) if len(spec) else P()
        sspecs = sspecs._replace(
            w_tau=jax.tree_util.tree_map(
                lambda _: P(), sspecs.w_tau,
                is_leaf=lambda x: isinstance(x, P)),
            W=jax.tree_util.tree_map(
                _m_only, sspecs.W, is_leaf=lambda x: isinstance(x, P)),
            Z=jax.tree_util.tree_map(
                _m_only, sspecs.Z, is_leaf=lambda x: isinstance(x, P)))

    def fn(state, batches):
        with axis_rules(mesh, rules):
            return step_fn(state, batches, sspecs)

    in_sh = (_named(sspecs, mesh), _named(bspecs, mesh))
    out_sh = (_named(sspecs, mesh), None)
    return StepBundle(
        arch=arch, shape=shape.name, kind="train", fn=fn,
        args=(abstract_state, batch), in_shardings=in_sh,
        out_shardings=out_sh, donate_argnums=(0,),
        notes="; ".join(filter(None, [note, f"fedepm[{dist.mode}] m={m} "
                                            f"k0={k0} ens={dist.ens}"])),
        static={"mode": dist.mode, "m": m, "k0": k0, "b_local": b_local,
                "ens": dist.ens, "cfg": cfg, "fed": fed_cfg})


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def _serve_param_setup(cfg: ArchConfig, mesh: Mesh):
    """Abstract params + storage specs (TP, +FSDP if one copy is too big)."""
    model = get_model(cfg)
    abstract_params = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    dist_tp = dist_mod.DistConfig(mode="spatial", fsdp_axes=())
    pspecs = dist_mod.param_specs(cfg, abstract_params, mesh, dist_tp)
    per_chip = 0
    for sp, leaf in zip(
            jax.tree_util.tree_leaves(pspecs,
                                      is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves(abstract_params)):
        div = 1
        for e in sp:
            if e is None:
                continue
            for a in (e if isinstance(e, tuple) else (e,)):
                div *= mesh.shape[a]
        per_chip += leaf.size * leaf.dtype.itemsize // div
    fsdp = per_chip > _SERVE_FSDP_THRESHOLD
    if fsdp:
        dist_f = dist_mod.DistConfig(mode="temporal", fsdp_axes=("data",))
        pspecs = dist_mod.param_specs(cfg, abstract_params, mesh, dist_f)
    return model, abstract_params, pspecs, fsdp


def build_prefill_step(arch: str, mesh: Mesh):
    shape = INPUT_SHAPES["prefill_32k"]
    res = resolve_arch(arch, shape)
    if isinstance(res, Skip):
        return res
    cfg, note = res
    model, aparams, pspecs, fsdp = _serve_param_setup(cfg, mesh)
    ca = client_axes(mesh)
    B = shape.global_batch
    batch = lm_batch_specs(cfg, (B,), shape.seq_len, with_targets=False)
    ca_spec = ca if len(ca) > 1 else ca[0]
    bspecs = jax.tree_util.tree_map(
        lambda x: P(ca_spec, *([None] * (x.ndim - 1))), batch)

    rules = serve_activation_rules(mesh)
    if cfg.attention == "bidirectional":
        # encoder: prefill == full encode (logits for every frame)
        def fn(params, b):
            with axis_rules(mesh, rules):
                return model.apply(params, b)
    else:
        def fn(params, b):
            with axis_rules(mesh, rules):
                return model.prefill(params, b, max_len=shape.seq_len)
    in_sh = (_named(pspecs, mesh), _named(bspecs, mesh))
    return StepBundle(
        arch=arch, shape=shape.name, kind="prefill", fn=fn,
        args=(aparams, batch), in_shardings=in_sh, out_shardings=None,
        notes="; ".join(filter(None, [note, "fsdp-params" if fsdp else ""])),
        static={"B": B, "fsdp": fsdp, "cfg": cfg})


def build_decode_step(arch: str, mesh: Mesh, shape_name: str):
    shape = INPUT_SHAPES[shape_name]
    res = resolve_arch(arch, shape)
    if isinstance(res, Skip):
        return res
    cfg, note = res
    model = get_model(cfg)
    if not model.has_decode:
        return Skip(arch, shape.name, "encoder-only: no decode step")
    model, aparams, pspecs, fsdp = _serve_param_setup(cfg, mesh)
    ca = client_axes(mesh)
    B = shape.global_batch
    plen = jnp.ones((), jnp.int32) * (shape.seq_len - 1)
    astate = jax.eval_shape(
        lambda: model.init_decode_state(B, shape.seq_len, plen))
    stspecs = auto_state_specs(astate, mesh, B, ca)
    batch = {"tokens": _sds((B, 1), jnp.int32)}
    ca_spec = ca if len(ca) > 1 else ca[0]
    bspec = {"tokens": P(ca_spec, None) if B > 1 else P(None, None)}

    rules = serve_activation_rules(mesh)
    if fsdp:
        # weight-stationary decode: leave per-token activations
        # unconstrained so XLA partial-sums over the weights' fsdp axis
        # instead of all-gathering every layer's weights per token
        rules["batch"] = None

    def fn(params, state, b):
        with axis_rules(mesh, rules):
            return model.decode_step(params, state, b)

    in_sh = (_named(pspecs, mesh), _named(stspecs, mesh),
             _named(bspec, mesh))
    # logits sharding unconstrained; state out matches state in (donated)
    out_sh = (None, _named(stspecs, mesh))
    return StepBundle(
        arch=arch, shape=shape.name, kind="decode", fn=fn,
        args=(aparams, astate, batch), in_shardings=in_sh,
        out_shardings=out_sh, donate_argnums=(1,),
        notes="; ".join(filter(None, [note, "fsdp-params" if fsdp else ""])),
        static={"B": B, "fsdp": fsdp, "cfg": cfg, "S": shape.seq_len})


def build_step(arch: str, shape_name: str, mesh: Mesh, **kw):
    if shape_name == "train_4k":
        return build_train_step(arch, mesh, **kw)
    if shape_name == "prefill_32k":
        return build_prefill_step(arch, mesh)
    return build_decode_step(arch, mesh, shape_name)
