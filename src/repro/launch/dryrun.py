import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination: build the step
(launch/steps.py), ``.lower().compile()`` it against the production mesh,
and record

  * ``compiled.memory_analysis()``  -- proves the program fits HBM,
  * ``compiled.cost_analysis()``    -- HLO FLOPs/bytes (NOTE: XLA counts a
    while-loop body ONCE; launch/roofline.py applies the trip-count
    corrections and the analytic model),
  * a collective census parsed from the compiled HLO text (op kind,
    operand bytes, whether it sits inside a while body),

into artifacts/dryrun/<mesh>/<arch>__<shape>.json. Skips (encoder decode,
non-sub-quadratic long-context) are recorded with their reason.

Usage:
  python -m repro.launch.dryrun [--arch A] [--shape S] [--mesh single|multi|both]
        [--ens gather|a2a] [--force]
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../artifacts/dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,128,512]{...}' -> bytes. Tuple shapes handled by caller."""
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_census(hlo_text: str):
    """Parse collective ops from HLO text.

    Returns a list of dicts: {op, bytes, computation, count}. Bytes are the
    OUTPUT shape bytes of the op (a good proxy for data moved per device
    for AG/AR; for reduce-scatter/all-to-all it is the shard output).
    Loop multiplicity is resolved by launch/roofline.py using known static
    trip counts.
    """
    ops = []
    current_comp = "<module>"
    for line in hlo_text.splitlines():
        mc = re.match(r"\s*%?([\w\.\-]+)\s*\([^)]*\)\s*->", line)
        if line and not line[0].isspace():
            mname = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if mname and ("{" in line or "->" in line):
                current_comp = mname.group(1)
        for kind in _COLLECTIVES:
            # match '<shape> <kind>(' or '<kind>-start('
            m = re.search(
                r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\]\S*))\s+%?"
                + kind + r"(?:-start)?\(", line)
            if m:
                shape_str = m.group(1)
                if shape_str.startswith("("):
                    total = sum(_shape_bytes(s.strip())
                                for s in shape_str[1:-1].split(","))
                else:
                    total = _shape_bytes(shape_str)
                ops.append({"op": kind, "bytes": total,
                            "computation": current_comp})
    return ops


def while_loop_info(hlo_text: str):
    """(trips, parents) via launch/roofline.parse_hlo_loops."""
    from repro.launch.roofline import parse_hlo_loops
    return parse_hlo_loops(hlo_text)


def run_one(arch: str, shape: str, mesh_kind: str, *, ens: str = "gather",
            force: bool = False, out_dir: str = ARTIFACT_DIR,
            tag: str = ""):
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps as steps_mod

    os.makedirs(os.path.join(out_dir, mesh_kind), exist_ok=True)
    stem = f"{arch}__{shape}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, mesh_kind, stem + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "ens": ens, "tag": tag,
           "timestamp": time.time()}
    t0 = time.time()
    try:
        kw = {"ens": ens} if shape == "train_4k" else {}
        bundle = steps_mod.build_step(arch, shape, mesh, **kw)
        if isinstance(bundle, steps_mod.Skip):
            rec.update(status="skip", reason=bundle.reason)
        else:
            lowered = bundle.lower()
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            census = collective_census(hlo)
            trips, parents = while_loop_info(hlo)
            static = dict(bundle.static)
            cfg = static.pop("cfg", None)
            fed = static.pop("fed", None)
            rec.update(
                status="ok",
                notes=bundle.notes,
                kind=bundle.kind,
                lower_s=round(t1 - t0, 1),
                compile_s=round(t2 - t1, 1),
                memory={
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "alias_bytes": int(ma.alias_size_in_bytes),
                    "peak_bytes": int(ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
                },
                cost={k: float(v) for k, v in ca.items()
                      if isinstance(v, (int, float))},
                collectives=census,
                while_trips=trips,
                while_parents=parents,
                static=static,
                cfg_summary=None if cfg is None else {
                    "n_layers": cfg.n_layers, "d_model": cfg.d_model,
                    "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
                    "d_ff": cfg.d_ff, "vocab": cfg.vocab,
                    "family": cfg.family,
                    "sliding_window": cfg.sliding_window,
                    "n_experts": cfg.n_experts, "top_k": cfg.top_k,
                },
            )
    except Exception as e:  # noqa: BLE001 -- a failed combo is a data point
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:],
                   elapsed_s=round(time.time() - t0, 1))
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    from repro import configs
    from repro.models.config import INPUT_SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id (default: all ten)")
    ap.add_argument("--shape", default=None,
                    help="input shape (default: all four)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--ens", default="gather", choices=["gather", "a2a"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else configs.ALL_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_one(arch, shape, mesh_kind, ens=args.ens,
                              force=args.force, tag=args.tag)
                status = rec["status"]
                if status == "ok":
                    n_ok += 1
                    pk = rec["memory"]["peak_bytes"] / 1e9
                    print(f"[{mesh_kind}] {arch:18s} {shape:12s} OK    "
                          f"peak/dev={pk:7.2f}GB "
                          f"flops={rec['cost'].get('flops', 0):.3e} "
                          f"compile={rec.get('compile_s', 0):.0f}s",
                          flush=True)
                elif status == "skip":
                    n_skip += 1
                    print(f"[{mesh_kind}] {arch:18s} {shape:12s} SKIP  "
                          f"{rec['reason']}", flush=True)
                else:
                    n_fail += 1
                    print(f"[{mesh_kind}] {arch:18s} {shape:12s} FAIL  "
                          f"{rec['error'][:160]}", flush=True)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
