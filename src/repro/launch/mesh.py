"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state, so tests and benches keep their 1-CPU view.
The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import (launch/dryrun.py lines 1-2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *,
                   multi_pod: bool = False):
    """Small mesh for CPU tests (requires forced host device count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def client_axes(mesh) -> tuple:
    """Mesh axes that carry the FedEPM client / batch axis."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_client_groups(mesh) -> int:
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n
