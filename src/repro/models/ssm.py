"""Mamba2 (SSD) layers and the Zamba2-style hybrid (arXiv:2411.15242).

Mamba2 layer (State-Space Duality form):
  in_proj -> (z, x, B, C, dt); short causal depthwise conv on (x, B, C);
  per-head scalar decay A (A = -exp(A_log)); chunked SSD scan
      h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,   y_t = C_t^T h_t + D x_t
  gated RMSNorm; out_proj. The chunk dimension is a ``lax.scan``; intra-chunk
  interaction is dense (chunk x chunk) matmuls -- the same TPU-native
  pattern as the mLSTM in models/xlstm.py, but without log-domain
  stabilisation (decays are <= 1, dt is bounded, so plain exp is safe).

Zamba2 hybrid: a backbone of Mamba2 layers with ONE shared transformer
block (GQA attention + SwiGLU MLP, weights reused) applied every
``shared_attn_every`` layers. The real Zamba2 concatenates the block input
with the original embeddings and uses LoRA-specialised copies; we implement
the shared-weights core (the memory-saving insight) and note the
simplification in DESIGN.md. Decode state is O(1) per mamba layer
(conv tail + SSD state) plus one KV cache per shared-attn application,
which is what makes ``long_500k`` feasible for the hybrid.

Layer stacking: mamba layers are stacked (leading L axis) and applied with
``lax.scan`` *per segment* between shared-attn applications, keeping the
HLO size O(segments), not O(layers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import dense
from repro.models.config import ArchConfig
from repro.models.layers import (
    CacheSpec,
    apply_mlp,
    apply_norm,
    cache_append,
    cache_from_prefill,
    decode_attention,
    dense_init,
    embed_init,
    flash_attention,
    init_attention,
    init_cache,
    init_mlp,
    init_norm,
    maybe_remat,
    out_proj,
    qkv_proj,
    rope,
)
from repro.sharding.rules import constrain

_CONV_W = 4  # mamba2 depthwise conv width


# ---------------------------------------------------------------------------
# dims
# ---------------------------------------------------------------------------

def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads if cfg.ssm_heads else d_in // 64
    hd = d_in // H
    N = cfg.ssm_state
    return d_in, H, hd, N


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mamba_layer(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, H, hd, N = _dims(cfg)
    conv_ch = d_in + 2 * N  # x + B + C (ngroups = 1)
    ks = jax.random.split(key, 5)
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[3], (H,), minval=jnp.log(1e-3),
                                   maxval=jnp.log(1e-1)))))
    return {
        "ln": init_norm(cfg.norm, d, cfg.param_dtype),
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H),
                              cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (_CONV_W, conv_ch)) * 0.2
                   ).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(cfg.param_dtype),
        "D": jnp.ones((H,), cfg.param_dtype),
        "dt_bias": dt_bias.astype(cfg.param_dtype),
        "ln_out": init_norm("rmsnorm", d_in, cfg.param_dtype),
        "out_proj": dense_init(ks[2], (d_in, d), cfg.param_dtype),
    }


def init_shared_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.hd, cfg.bias,
                               cfg.param_dtype),
        "ln_mlp": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, cfg.bias,
                        cfg.param_dtype),
    }


def _segments(cfg: ArchConfig):
    """Static segmentation: shared attn runs before mamba layer i when
    i % shared_attn_every == 0. Returns list of (attn_before, n_mamba)."""
    if cfg.shared_attn_every <= 0:
        return [(False, cfg.n_layers)]
    segs = []
    i = 0
    while i < cfg.n_layers:
        n = min(cfg.shared_attn_every, cfg.n_layers - i)
        segs.append((True, n))
        i += n
    return segs


def init(key, cfg: ArchConfig):
    k_emb, k_layers, k_shared, k_out = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_mamba_layer(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "mamba_layers": layers,
        "ln_f": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "unembed": dense_init(k_out, (cfg.d_model, cfg.vocab),
                              cfg.param_dtype),
    }
    if cfg.shared_attn_every > 0:
        params["shared_attn"] = init_shared_block(k_shared, cfg)
    return params


# ---------------------------------------------------------------------------
# depthwise causal conv (width 4, implemented as shifted adds)
# ---------------------------------------------------------------------------

def causal_conv(x, w, b, tail=None):
    """x: (B, T, C); w: (W, C); tail: (B, W-1, C) previous inputs or None.

    Returns (y, new_tail). y[t] = sum_k w[k] * x[t - (W-1) + k] + b.
    """
    B, T, C = x.shape
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, T+W-1, C)
    y = jnp.zeros_like(x)
    for k in range(W):
        y = y + xp[:, k:k + T] * w[k].astype(x.dtype)
    new_tail = xp[:, T:, :] if W > 1 else tail
    return jax.nn.silu(y + b.astype(x.dtype)), new_tail


# ---------------------------------------------------------------------------
# chunked SSD scan
# ---------------------------------------------------------------------------

def _ssd_scan(x, Bm, Cm, dt, A, chunk: int, h0=None):
    """Chunked SSD. x: (B, T, H, hd); Bm, Cm: (B, T, N); dt: (B, T, H);
    A: (H,) negative. h0: (B, H, hd, N) or None. Returns (y, h_final)."""
    B, T, H, hd = x.shape
    N = Bm.shape[-1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 => identity step
    Tp = x.shape[1]
    nC = Tp // chunk

    xc = jnp.moveaxis(x.reshape(B, nC, chunk, H, hd), 1, 0)      # (nC,B,c,H,hd)
    Bc = jnp.moveaxis(Bm.reshape(B, nC, chunk, N), 1, 0)         # (nC,B,c,N)
    Cc = jnp.moveaxis(Cm.reshape(B, nC, chunk, N), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B, nC, chunk, H), 1, 0)        # (nC,B,c,H)

    if h0 is None:
        h0 = jnp.zeros((B, H, hd, N), jnp.float32)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def per_chunk(h, xs):
        xb, Bb, Cb, dtb = xs
        xf = xb.astype(jnp.float32)
        dtf = dtb.astype(jnp.float32)
        a = dtf * A[None, None, :]                  # (B,c,H) log decay steps
        A_cum = jnp.cumsum(a, axis=1)               # (B,c,H)
        # intra-chunk: L[t,s] = exp(A_t - A_s) * dt_s, causal
        diff = A_cum[:, :, None, :] - A_cum[:, None, :, :]  # (B,t,s,H)
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0) \
            * dtf[:, None, :, :]                    # (B,t,s,H)
        G = jnp.einsum("btn,bsn->bts", Cb.astype(jnp.float32),
                       Bb.astype(jnp.float32))      # (B,t,s)
        W = G[..., None] * L                        # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshd->bthd", W, xf)
        # state contribution: exp(A_t) C_t . h
        y_state = jnp.einsum("btn,bhdn,bth->bthd",
                             Cb.astype(jnp.float32), h, jnp.exp(A_cum))
        y = y_intra + y_state
        # state update
        A_tot = A_cum[:, -1, :]                     # (B,H)
        w_src = jnp.exp(A_tot[:, None, :] - A_cum) * dtf   # (B,c,H)
        h_new = jnp.exp(A_tot)[:, :, None, None] * h + jnp.einsum(
            "bshd,bsn,bsh->bhdn", xf, Bb.astype(jnp.float32), w_src)
        return h_new, y

    h, ys = lax.scan(per_chunk, h0, (xc, Bc, Cc, dtc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Tp, H, hd)
    return y[:, :T], h


def _ssd_step(x1, B1, C1, dt1, A, h):
    """One decode step. x1: (B, H, hd); B1, C1: (B, N); dt1: (B, H)."""
    a = jnp.exp(dt1.astype(jnp.float32) * A[None, :])      # (B,H)
    upd = jnp.einsum("bhd,bn,bh->bhdn", x1.astype(jnp.float32),
                     B1.astype(jnp.float32), dt1.astype(jnp.float32))
    h = a[..., None, None] * h + upd
    y = jnp.einsum("bn,bhdn->bhd", C1.astype(jnp.float32), h)
    return y, h


# ---------------------------------------------------------------------------
# mamba block forward / step
# ---------------------------------------------------------------------------

def _in_proj(x, p, cfg: ArchConfig):
    d_in, H, hd, N = _dims(cfg)
    proj = x @ p["in_proj"].astype(x.dtype)
    z = proj[..., :d_in]
    xBC = proj[..., d_in:2 * d_in + 2 * N]
    dt_pre = proj[..., 2 * d_in + 2 * N:]
    return z, xBC, dt_pre


def mamba_block(x, p, cfg: ArchConfig, state=None):
    """x: (B, T, d) -> (y, state'). state = (conv_tail, h)."""
    d_in, H, hd, N = _dims(cfg)
    B, T, d = x.shape
    hx = apply_norm(x, p["ln"], cfg.norm)
    z, xBC, dt_pre = _in_proj(hx, p, cfg)
    tail = state[0] if state is not None else None
    xBC, new_tail = causal_conv(xBC, p["conv_w"], p["conv_b"], tail)
    xs = xBC[..., :d_in].reshape(B, T, H, hd)
    Bm = xBC[..., d_in:d_in + N]
    Cm = xBC[..., d_in + N:]
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h0 = state[1] if state is not None else None
    xs = constrain(xs, "batch", "seq", "heads", None)
    y, h = _ssd_scan(xs, Bm, Cm, dt, A, cfg.ssm_chunk, h0)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :,
                                                                None]
    y = y.reshape(B, T, d_in).astype(x.dtype)
    y = apply_norm(y, p["ln_out"], "rmsnorm") * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return x + out, (new_tail, h)


def mamba_block_step(x1, p, cfg: ArchConfig, state):
    d_in, H, hd, N = _dims(cfg)
    B = x1.shape[0]
    hx = apply_norm(x1, p["ln"], cfg.norm)
    z, xBC, dt_pre = _in_proj(hx, p, cfg)
    tail, h = state
    xBC, new_tail = causal_conv(xBC, p["conv_w"], p["conv_b"], tail)
    xs = xBC[:, 0, :d_in].reshape(B, H, hd)
    B1 = xBC[:, 0, d_in:d_in + N]
    C1 = xBC[:, 0, d_in + N:]
    dt1 = jax.nn.softplus(dt_pre[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = _ssd_step(xs, B1, C1, dt1, A, h)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_in).astype(x1.dtype)
    y = apply_norm(y, p["ln_out"], "rmsnorm") * jax.nn.silu(z)
    return x1 + y @ p["out_proj"].astype(x1.dtype), (new_tail, h)


# ---------------------------------------------------------------------------
# shared attention block
# ---------------------------------------------------------------------------

def shared_block(x, p, cfg: ArchConfig, positions):
    h = apply_norm(x, p["ln_attn"], cfg.norm)
    q, k, v = qkv_proj(h, p["attn"])
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, mode="causal", window=cfg.sliding_window,
                        q_positions=positions, kv_positions=positions)
    x = x + out_proj(o, p["attn"])
    h2 = apply_norm(x, p["ln_mlp"], cfg.norm)
    x = x + apply_mlp(h2, p["mlp"], cfg.mlp)
    return constrain(x, "batch", "seq_res", "embed"), (k, v)


def shared_block_step(x1, p, cfg: ArchConfig, cache, pos):
    positions = pos[:, None]
    h = apply_norm(x1, p["ln_attn"], cfg.norm)
    q, k, v = qkv_proj(h, p["attn"])
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    cache = cache_append(cache, k, v)
    o = decode_attention(q, cache["k"], cache["v"], cache["pos"],
                         window=cfg.sliding_window, q_position=pos)
    x1 = x1 + out_proj(o, p["attn"])
    h2 = apply_norm(x1, p["ln_mlp"], cfg.norm)
    x1 = x1 + apply_mlp(h2, p["mlp"], cfg.mlp)
    return x1, cache


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def _layer_slice(layers, a, b):
    return jax.tree_util.tree_map(lambda x: x[a:b], layers)


def _backbone(params, x, cfg: ArchConfig, positions, states=None,
              collect_states=False):
    """Run segments of scanned mamba layers with shared attn interleaved."""
    segs = _segments(cfg)
    idx = 0
    out_states = []
    caches = []
    shared = maybe_remat(
        lambda h, sp: shared_block(h, sp, cfg, positions)[0], cfg)
    mamba = maybe_remat(
        lambda h, lp: constrain(mamba_block(h, lp, cfg, None)[0],
                                "batch", "seq_res", "embed"), cfg)

    def mamba_stack(h, layers):
        def body(hh, lp):
            return mamba(hh, lp), None
        h, _ = lax.scan(body, h, layers)
        return h

    if not collect_states and cfg.shared_attn_every > 0:
        # TRAINING path: scan over the full-size segments so the 6-7
        # shared-attn applications are ONE loop body (not unrolled --
        # unrolling co-schedules all their backward buffers: measured
        # +14 GB/device on zamba2). The ragged tail segment runs once.
        k = cfg.shared_attn_every
        n_full = cfg.n_layers // k
        tail = cfg.n_layers - n_full * k
        main = _layer_slice(params["mamba_layers"], 0, n_full * k)
        grouped = jax.tree_util.tree_map(
            lambda l: l.reshape((n_full, k) + l.shape[1:]), main)

        def seg_body(h, seg_layers):
            h = shared(h, params["shared_attn"])
            return mamba_stack(h, seg_layers), None

        x, _ = lax.scan(seg_body, x, grouped)
        if tail:
            x = shared(x, params["shared_attn"])
            x = mamba_stack(x, _layer_slice(params["mamba_layers"],
                                            n_full * k, cfg.n_layers))
        return x, out_states, caches

    for si, (attn_before, n) in enumerate(segs):
        if attn_before and cfg.shared_attn_every > 0:
            if collect_states:
                x, kv = shared_block(x, params["shared_attn"], cfg,
                                     positions)
                caches.append(kv)
            else:
                x = shared(x, params["shared_attn"])
        seg_layers = _layer_slice(params["mamba_layers"], idx, idx + n)

        if collect_states:
            def body(h, lp):
                h, st = mamba_block(h, lp, cfg, None)
                return h, st

            x, seg_states = lax.scan(body, x, seg_layers)
            out_states.append(seg_states)
        else:
            x = mamba_stack(x, seg_layers)
        idx += n
    return x, out_states, caches


def hidden(params, batch, cfg: ArchConfig):
    x = params["embed"][batch["tokens"]].astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])
    x, _, _ = _backbone(params, x, cfg, positions)
    return apply_norm(x, params["ln_f"], cfg.norm)


def apply(params, batch, cfg: ArchConfig):
    x = hidden(params, batch, cfg)
    return jnp.einsum("btd,dv->btv", x,
                      params["unembed"].astype(x.dtype))


def init_decode_state(cfg: ArchConfig, batch_size: int, seq_len: int,
                      prefill_len=None):
    d_in, H, hd, N = _dims(cfg)
    conv_ch = d_in + 2 * N
    segs = _segments(cfg)
    mamba_states = [
        (jnp.zeros((n, batch_size, _CONV_W - 1, conv_ch), cfg.dtype),
         jnp.zeros((n, batch_size, H, hd, N), jnp.float32))
        for _, n in segs
    ]
    caches = []
    if cfg.shared_attn_every > 0:
        size = seq_len if cfg.sliding_window is None else min(
            seq_len, cfg.sliding_window)
        spec = CacheSpec(batch=batch_size, size=size,
                         kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                         dtype=cfg.dtype)
        caches = [init_cache(spec) for s in segs if s[0]]
    return {"mamba": mamba_states, "caches": caches,
            "pos": jnp.zeros((batch_size,), jnp.int32)}


def prefill(params, batch, cfg: ArchConfig, max_len=None):
    x = params["embed"][batch["tokens"]].astype(cfg.dtype)
    B, T = x.shape[0], x.shape[1]
    positions = jnp.arange(T)
    plen = batch.get("prefill_len", jnp.full((B,), T, jnp.int32))
    segs = _segments(cfg)
    size = max_len or T
    if cfg.sliding_window is not None:
        size = min(size, cfg.sliding_window)
    spec = CacheSpec(batch=B, size=size, kv_heads=cfg.n_kv_heads,
                     head_dim=cfg.hd, dtype=cfg.dtype)
    idx = 0
    mamba_states, caches = [], []
    for attn_before, n in segs:
        if attn_before and cfg.shared_attn_every > 0:
            x, (k, v) = shared_block(x, params["shared_attn"], cfg, positions)
            caches.append(cache_from_prefill(k, v, spec, plen))
        seg_layers = _layer_slice(params["mamba_layers"], idx, idx + n)

        def body(h, lp):
            h, st = mamba_block(h, lp, cfg, None)
            return h, st

        x, seg_states = lax.scan(body, x, seg_layers)
        # keep only the (conv_tail, h) final states; cast tail to dtype
        mamba_states.append((seg_states[0].astype(cfg.dtype), seg_states[1]))
        idx += n
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("btd,dv->btv", x[:, -1:],
                        params["unembed"].astype(x.dtype))
    return logits, {"mamba": mamba_states, "caches": caches,
                    "pos": plen.astype(jnp.int32)}


def decode_step(params, state, batch, cfg: ArchConfig):
    x = params["embed"][batch["tokens"]].astype(cfg.dtype)
    pos = state["pos"]
    segs = _segments(cfg)
    idx = 0
    ci = 0
    new_mamba, new_caches = [], []
    for si, (attn_before, n) in enumerate(segs):
        if attn_before and cfg.shared_attn_every > 0:
            x, cache = shared_block_step(x, params["shared_attn"], cfg,
                                         state["caches"][ci], pos)
            new_caches.append(cache)
            ci += 1
        seg_layers = _layer_slice(params["mamba_layers"], idx, idx + n)

        def body(h, layer_in):
            lp, st = layer_in
            h, st = mamba_block_step(h, lp, cfg, st)
            return h, st

        x, seg_states = lax.scan(body, x, (seg_layers, state["mamba"][si]))
        new_mamba.append(seg_states)
        idx += n
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("btd,dv->btv", x, params["unembed"].astype(x.dtype))
    return logits, {"mamba": new_mamba, "caches": new_caches,
                    "pos": pos + 1}
