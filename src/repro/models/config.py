"""Unified architecture config consumed by every model family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | xlstm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    mlp: str = "swiglu"                     # swiglu | gelu
    bias: bool = False
    rope_theta: float = 10000.0
    parallel_block: bool = False            # command-r style attn+ffn in parallel
    tie_embeddings: bool = False
    logit_scale: float = 1.0
    # attention extents
    attention: str = "causal"               # causal | bidirectional
    sliding_window: Optional[int] = None    # SWA width if any (mixtral: 4096)
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # SSM / xLSTM / hybrid
    ssm_state: int = 0                      # mamba2 N
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 64
    slstm_every: int = 0                    # xlstm: sLSTM block every k layers
    shared_attn_every: int = 0              # zamba2: shared attn block period
    # VLM / audio frontends (stubs per spec): extra embedding inputs
    n_patches: int = 0                      # vlm: image patch tokens per sample
    frontend_dim: int = 0                   # stub embedding dim
    # numerics
    dtype: object = jnp.bfloat16
    param_dtype: object = jnp.float32
    # training-memory policy: rematerialise each block in backward
    remat: bool = True
    # citation for the assigned config
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.family in ("dense", "vlm", "audio"):
            mlp = d * ff * (3 if self.mlp == "swiglu" else 2)
            block = attn + mlp
        elif self.family == "moe":
            mlp = self.n_experts * d * ff * 3 + d * self.n_experts
            block = attn + mlp
        elif self.family == "xlstm":
            di = self.ssm_expand * d
            block = 4 * d * di + 2 * d * d  # rough: qkv/gates + projections
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            block = 2 * d * di + di * (2 * self.ssm_state) + di * d
        else:
            block = attn + d * ff * 3
        emb = V * d * (1 if self.tie_embeddings else 2)
        return emb + L * block

    def n_active_params(self) -> int:
        if self.family != "moe":
            return self.n_params()
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        attn = d * self.hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        mlp = self.top_k * d * ff * 3 + d * self.n_experts
        emb = V * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + mlp)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
