"""Shared neural-net layers for the assigned architectures.

Design notes:
  * Parameters are plain nested dicts; layer stacks store leaves with a
    leading L axis and are applied with ``lax.scan`` to keep HLO size (and
    512-device dry-run compile time) independent of depth.
  * Attention is a chunked online-softmax ("flash-style") implementation in
    pure jnp: scan over KV chunks with running (max, denom, acc). This bounds
    live memory to O(q_chunk * kv_chunk) scores, which is what makes the
    prefill_32k and long_500k dry-runs fit; XLA sees a scan, so
    cost_analysis still counts the full FLOPs.
  * GQA is explicit: q heads H = Hkv * R; scores are computed in grouped
    layout (B, Hkv, R, Tq, Tk) so the kv_heads axis stays shardable.
  * Sliding-window attention uses a *ring-buffer* KV cache of size window,
    giving O(window) decode state -- the sub-quadratic variant used for
    long_500k on attention archs (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.sharding.rules import constrain

def maybe_remat(fn, cfg):
    """Per-block rematerialisation: backward recomputes the block forward,
    so only the residual stream is stored across layers (MaxText-style)."""
    return jax.remat(fn) if getattr(cfg, "remat", False) else fn


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype=jnp.float32, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, vocab, dim, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p.get("bias"))


def init_norm(kind: str, dim: int, dtype=jnp.float32):
    p = {"scale": jnp.zeros((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., T, H, D) with D even; positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# flash-style chunked attention
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def _mask_bias(q_pos, kv_pos, mode: str, window: Optional[int]):
    """q_pos: (Tq,), kv_pos: (Tk,) -> additive bias (Tq, Tk)."""
    valid = kv_pos[None, :] >= 0
    if mode == "causal":
        valid &= kv_pos[None, :] <= q_pos[:, None]
    elif mode == "bidirectional":
        pass
    else:
        raise ValueError(f"unknown attention mode {mode!r}")
    if window is not None:
        valid &= (q_pos[:, None] - kv_pos[None, :]) < window
    return jnp.where(valid, 0.0, _NEG_INF)


def _flash_fwd_scan(qg, kg, vg, qp, kp, mode, window, scale):
    """Online-softmax forward. Shapes:
    qg (nq, B, qc, Hkv, R, D); kg/vg (nk, B, kc, Hkv, D); qp (nq, qc);
    kp (nk, kc). Returns out (nq, B, qc, Hkv, R, D) fp32 and
    lse (nq, B, Hkv, R, qc) fp32.
    """
    nq, B, qc, Hkv, R, D = qg.shape

    def per_q_chunk(carry, qi):
        qcb, qpos = qi

        def per_kv_chunk(acc, ki):
            m, l, o = acc
            kc_, vc_, kpos = ki
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qcb, kc_) * scale
            s = s + _mask_bias(qpos, kpos, mode, window)[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhrqk,bkhd->bhrqd", p, vc_)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hkv, R, qc), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, R, qc), jnp.float32)
        o0 = jnp.zeros((B, Hkv, R, qc, D), jnp.float32)
        (m, l, o), _ = lax.scan(per_kv_chunk, (m0, l0, o0), (kg, vg, kp))
        out = o / jnp.maximum(l, 1e-30)[..., None]   # (B,Hkv,R,qc,D)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        out = jnp.transpose(out, (0, 3, 1, 2, 4))     # (B,qc,Hkv,R,D)
        return carry, (out, lse)

    _, (outs, lses) = lax.scan(per_q_chunk, None, (qg, qp))
    return outs, lses


def _group(q, k, v, q_positions, kv_positions, q_chunk, kv_chunk):
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    R = H // Hkv
    nq, nk = Tq // q_chunk, Tk // kv_chunk
    qg = jnp.moveaxis(
        q.reshape(B, nq, q_chunk, Hkv, R, D), 1, 0).astype(jnp.float32)
    kg = jnp.moveaxis(
        k.reshape(B, nk, kv_chunk, Hkv, D), 1, 0).astype(jnp.float32)
    vg = jnp.moveaxis(
        v.reshape(B, nk, kv_chunk, Hkv, D), 1, 0).astype(jnp.float32)
    qp = q_positions.reshape(nq, q_chunk)
    kp = kv_positions.reshape(nk, kv_chunk)
    return qg, kg, vg, qp, kp


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, q_positions, kv_positions, mode, window,
           q_chunk, kv_chunk):
    """Flash attention with O(T) residuals: the backward pass RECOMPUTES
    the (chunked) probability tiles instead of storing the T^2 attention
    matrix -- this is what makes seq-4096 training of 40-layer models fit
    HBM (and is the standard FlashAttention-2 recurrence, expressed as
    nested lax.scans so the TPU sees static control flow)."""
    qg, kg, vg, qp, kp = _group(q, k, v, q_positions, kv_positions,
                                q_chunk, kv_chunk)
    scale = 1.0 / jnp.sqrt(q.shape[-1])
    outs, _ = _flash_fwd_scan(qg, kg, vg, qp, kp, mode, window, scale)
    B, Tq, H, D = q.shape
    nq = Tq // q_chunk
    return jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H, D).astype(q.dtype)


def _flash_fwd(q, k, v, q_positions, kv_positions, mode, window,
               q_chunk, kv_chunk):
    qg, kg, vg, qp, kp = _group(q, k, v, q_positions, kv_positions,
                                q_chunk, kv_chunk)
    scale = 1.0 / jnp.sqrt(q.shape[-1])
    outs, lses = _flash_fwd_scan(qg, kg, vg, qp, kp, mode, window, scale)
    B, Tq, H, D = q.shape
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H, D).astype(q.dtype)
    # residuals: inputs + per-row LSE + output (O(T), never O(T^2))
    res = (q, k, v, q_positions, kv_positions, out, lses)
    return out, res


def _flash_bwd(mode, window, q_chunk, kv_chunk, res, dout):
    q, k, v, q_positions, kv_positions, out, lses = res
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    R = H // Hkv
    scale = 1.0 / jnp.sqrt(D)
    qg, kg, vg, qp, kp = _group(q, k, v, q_positions, kv_positions,
                                q_chunk, kv_chunk)
    nq, nk = Tq // q_chunk, Tk // kv_chunk
    dog = jnp.moveaxis(
        dout.reshape(B, nq, q_chunk, Hkv, R, D), 1, 0).astype(jnp.float32)
    og = jnp.moveaxis(
        out.reshape(B, nq, q_chunk, Hkv, R, D), 1, 0).astype(jnp.float32)

    def per_q_chunk(carry, xs):
        dk_acc, dv_acc = carry
        qcb, docb, ocb, lse_cb, qpos = xs
        # D_i = sum_d dout_i * out_i, computed PER CHUNK: the big
        # (nq, B, qc, H, D) einsum outside the scan hits an SPMD layout
        # transition the partitioner can only solve by full replication
        # ("involuntary full rematerialization", ~9 GB/device of gathers
        # on smollm train -- EXPERIMENTS.md §Perf 1.5)
        Dcb = jnp.einsum("bqhrd,bqhrd->bhrq", docb, ocb)

        def per_kv_chunk(dq, ki):
            kc_, vc_, kpos = ki
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qcb, kc_) * scale
            s = s + _mask_bias(qpos, kpos, mode, window)[None, None, None]
            p = jnp.exp(s - lse_cb[..., None])            # (B,Hkv,R,qc,kc)
            dp = jnp.einsum("bqhrd,bkhd->bhrqk", docb, vc_)
            ds = p * (dp - Dcb[..., None])
            dq = dq + jnp.einsum("bhrqk,bkhd->bqhrd", ds, kc_) * scale
            dk_c = jnp.einsum("bhrqk,bqhrd->bkhd", ds, qcb) * scale
            dv_c = jnp.einsum("bhrqk,bqhrd->bkhd", p, docb)
            return dq, (dk_c, dv_c)

        dq0 = jnp.zeros_like(qcb)
        dq, (dk_cs, dv_cs) = lax.scan(per_kv_chunk, dq0, (kg, vg, kp))
        return (dk_acc + dk_cs, dv_acc + dv_cs), dq

    dk0 = jnp.zeros((nk, B, kv_chunk, Hkv, D), jnp.float32)
    dv0 = jnp.zeros((nk, B, kv_chunk, Hkv, D), jnp.float32)
    (dkg, dvg), dqg = lax.scan(per_q_chunk, (dk0, dv0),
                               (qg, dog, og, lses, qp))
    dq = jnp.moveaxis(dqg, 0, 1).reshape(B, Tq, H, D).astype(q.dtype)
    dk = jnp.moveaxis(dkg, 0, 1).reshape(B, Tk, Hkv, D).astype(k.dtype)
    dv = jnp.moveaxis(dvg, 0, 1).reshape(B, Tk, Hkv, D).astype(v.dtype)
    zq = np.zeros(q_positions.shape, jax.dtypes.float0)
    zk = np.zeros(kv_positions.shape, jax.dtypes.float0)
    return dq, dk, dv, zq, zk


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, mode="causal", window=None,
                    q_positions=None, kv_positions=None,
                    q_chunk=512, kv_chunk=1024):
    """Chunked online-softmax attention with GQA and an O(T)-memory
    custom VJP. q: (B, Tq, H, D); k, v: (B, Tk, Hkv, D); H = Hkv * R.
    Returns (B, Tq, H, D) in q.dtype.
    """
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    if q_positions is None:
        q_positions = jnp.arange(Tq)
    if kv_positions is None:
        kv_positions = jnp.arange(Tk)
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    # pad to chunk multiples; padded kv positions are -1 => masked out;
    # padded q rows are sliced away after
    pq = (-Tq) % q_chunk
    pk = (-Tk) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pk), constant_values=-1)
    out = _flash(q, k, v, q_positions, kv_positions, mode, window,
                 q_chunk, kv_chunk)
    return out[:, :Tq]


def decode_attention(q1, cache_k, cache_v, kv_positions, *,
                     window=None, q_position=None):
    """Single-step decode: q1 (B, 1, H, D) over a (possibly ring) cache.

    cache_k/v: (B, S, Hkv, D); kv_positions: (B, S) absolute positions,
    -1 for unwritten slots. Ring semantics are encoded entirely in
    kv_positions, so full and sliding-window caches share this path.
    """
    B, S, Hkv, D = cache_k.shape
    H = q1.shape[2]
    R = H // Hkv
    scale = 1.0 / jnp.sqrt(D)
    qg = q1.reshape(B, Hkv, R, D).astype(jnp.float32)
    s = jnp.einsum("bhrd,bkhd->bhrk", qg, cache_k.astype(jnp.float32)) * scale
    valid = kv_positions >= 0
    if q_position is not None:
        valid &= kv_positions <= q_position[:, None]
        if window is not None:
            valid &= (q_position[:, None] - kv_positions) < window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrk,bkhd->bhrd", p, cache_v.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q1.dtype)


# ---------------------------------------------------------------------------
# KV cache (full or ring/sliding-window)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheSpec:
    batch: int
    size: int          # slots: full seq_len, or window for SWA
    kv_heads: int
    head_dim: int
    dtype: object = jnp.bfloat16


def init_cache(spec: CacheSpec):
    return {
        "k": jnp.zeros((spec.batch, spec.size, spec.kv_heads, spec.head_dim),
                       spec.dtype),
        "v": jnp.zeros((spec.batch, spec.size, spec.kv_heads, spec.head_dim),
                       spec.dtype),
        "pos": jnp.full((spec.batch, spec.size), -1, jnp.int32),
        "next": jnp.zeros((spec.batch,), jnp.int32),  # absolute next position
    }


def cache_append(cache, k1, v1):
    """Append one token (B, 1, Hkv, D) at slot next % size (ring)."""
    B, S = cache["pos"].shape
    nxt = cache["next"]  # (B,)
    slot = nxt % S
    bidx = jnp.arange(B)
    k = cache["k"].at[bidx, slot].set(k1[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[bidx, slot].set(v1[:, 0].astype(cache["v"].dtype))
    pos = cache["pos"].at[bidx, slot].set(nxt)
    return {"k": k, "v": v, "pos": pos, "next": nxt + 1}


def cache_from_prefill(k, v, spec: CacheSpec, prefill_len):
    """Build a cache from full prefill K/V (B, T, Hkv, D), keeping the last
    ``size`` entries (ring layout: slot = pos % size)."""
    B, T = k.shape[0], k.shape[1]
    S = spec.size
    cache = init_cache(spec)
    if T <= S:
        kpad = jnp.pad(k, ((0, 0), (0, S - T), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, S - T), (0, 0), (0, 0)))
        pos = jnp.where(jnp.arange(S)[None, :] < prefill_len[:, None],
                        jnp.arange(S)[None, :], -1)
        return {"k": kpad.astype(spec.dtype), "v": vpad.astype(spec.dtype),
                "pos": pos.astype(jnp.int32),
                "next": prefill_len.astype(jnp.int32)}
    # keep last S tokens; ring slot of absolute position p is p % S
    tail_k = k[:, T - S:]
    tail_v = v[:, T - S:]
    abs_pos = jnp.arange(T - S, T)[None, :] * jnp.ones((B, 1), jnp.int32)
    slot = abs_pos % S
    bidx = jnp.arange(B)[:, None]
    ck = jnp.zeros((B, S) + k.shape[2:], spec.dtype)
    cv = jnp.zeros((B, S) + v.shape[2:], spec.dtype)
    ck = ck.at[bidx, slot].set(tail_k.astype(spec.dtype))
    cv = cv.at[bidx, slot].set(tail_v.astype(spec.dtype))
    pos = jnp.full((B, S), -1, jnp.int32).at[bidx, slot].set(abs_pos)
    return {"k": ck, "v": cv, "pos": pos,
            "next": prefill_len.astype(jnp.int32)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, kind="swiglu", bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {}
    if kind == "swiglu":
        p["wi"] = dense_init(ks[0], (d_model, d_ff), dtype)
        p["wg"] = dense_init(ks[1], (d_model, d_ff), dtype)
    else:  # gelu
        p["wi"] = dense_init(ks[0], (d_model, d_ff), dtype)
    p["wo"] = dense_init(ks[2], (d_ff, d_model), dtype)
    if bias:
        p["bi"] = jnp.zeros((d_ff,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


def apply_mlp(x, p, kind="swiglu"):
    dt = x.dtype
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wi"].astype(dt)) * (x @ p["wg"].astype(dt))
    else:
        h = x @ p["wi"].astype(dt)
        if "bi" in p:
            h = h + p["bi"].astype(dt)
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "mlp")
    y = h @ p["wo"].astype(dt)
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return y


# ---------------------------------------------------------------------------
# Attention projections
# ---------------------------------------------------------------------------

def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, bias=False,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads, head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, n_kv_heads, head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, n_kv_heads, head_dim), dtype),
        "wo": dense_init(ks[3], (n_heads, head_dim, d_model), dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


def qkv_proj(x, p):
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def out_proj(attn_out, p):
    dt = attn_out.dtype
    y = jnp.einsum("bthk,hkd->btd", attn_out, p["wo"].astype(dt))
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return y
