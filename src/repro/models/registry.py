"""Model registry: family -> implementation module.

Every family module exposes:
  init(key, cfg)                      -> params pytree
  apply(params, batch, cfg)           -> logits (B, T, V)
  prefill(params, batch, cfg, max_len)-> (logits, decode_state)
  decode_step(params, state, batch, cfg) -> (logits, decode_state)
  init_decode_state(cfg, batch, seq_len, prefill_len) -> decode_state

``hubert``-style encoder-only archs (attention="bidirectional") have no
decode path; the registry raises for them so callers fail loudly (the
dry-run skips decode shapes for encoder archs, see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax.numpy as jnp

from repro.models import dense, moe, ssm, xlstm
from repro.models.config import ArchConfig

_FAMILY_MODULES = {
    "dense": dense,
    "vlm": dense,
    "audio": dense,
    "moe": moe,
    "xlstm": xlstm,
    "hybrid": ssm,
    "ssm": ssm,
}


class Model(NamedTuple):
    cfg: ArchConfig
    init: Callable
    apply: Callable
    prefill: Callable
    decode_step: Callable
    init_decode_state: Callable

    @property
    def has_decode(self) -> bool:
        return self.cfg.attention != "bidirectional"

    @property
    def is_subquadratic(self) -> bool:
        """True if long-context decode state is bounded (SSM/xLSTM/SWA)."""
        if self.cfg.family in ("xlstm", "hybrid", "ssm"):
            return True
        return self.cfg.sliding_window is not None


def get_model(cfg: ArchConfig) -> Model:
    mod = _FAMILY_MODULES.get(cfg.family)
    if mod is None:
        raise KeyError(f"unknown model family {cfg.family!r}")

    def init(key):
        return mod.init(key, cfg)

    def apply(params, batch):
        return mod.apply(params, batch, cfg)

    def _no_decode(*a, **kw):
        raise NotImplementedError(
            f"{cfg.name} is encoder-only ({cfg.attention}); no decode path")

    if cfg.attention == "bidirectional":
        pre, dec, ids = _no_decode, _no_decode, _no_decode
    else:
        def pre(params, batch, max_len=None):
            return mod.prefill(params, batch, cfg, max_len=max_len)

        def dec(params, state, batch):
            return mod.decode_step(params, state, batch, cfg)

        def ids(batch_size, seq_len, prefill_len):
            return mod.init_decode_state(cfg, batch_size, seq_len,
                                         prefill_len)

    return Model(cfg=cfg, init=init, apply=apply, prefill=pre,
                 decode_step=dec, init_decode_state=ids)
