"""Logical-axis metadata: pytrees congruent with each family's params whose
leaves are tuples of logical axis names (see sharding/specs.py for the
mapping to mesh axes).

Conventions:
  * rank-1 leaves (norm scales, gate biases, per-head scalars) are
    replicated -- they are tiny and sharding them buys nothing;
  * stacked-layer leaves carry a leading "layers" axis;
  * names follow sharding/specs.MODEL_AXIS_RULES. Storage sharding may
    differ from compute layout (e.g. mamba's fused in_proj is stored
    "proj"-sharded; the SSD compute is head-parallel via activation
    constraints) -- XLA's SPMD partitioner bridges the two.
"""
from __future__ import annotations

import jax

from repro.models.config import ArchConfig
from repro.models.ssm import _segments
from repro.models.xlstm import _is_slstm

tmap = jax.tree_util.tree_map

_IS_LOGICAL = lambda x: isinstance(x, tuple) and all(  # noqa: E731
    isinstance(e, (str, type(None))) for e in x)


def _norm(cfg: ArchConfig, dim_name: str = "embed"):
    p = {"scale": (dim_name,)}
    if cfg.norm == "layernorm":
        p["bias"] = (dim_name,)
    return p


def _attn(cfg: ArchConfig):
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.bias:
        p.update({"bq": ("heads", "head_dim"),
                  "bk": ("kv_heads", "head_dim"),
                  "bv": ("kv_heads", "head_dim"),
                  "bo": ("embed",)})
    return p


def _mlp(cfg: ArchConfig):
    p = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.mlp == "swiglu":
        p["wg"] = ("embed", "mlp")
    if cfg.bias:
        p["bi"] = ("mlp",)
        p["bo"] = ("embed",)
    return p


def _stack(layer_tree):
    """Prefix every leaf with the stacked 'layers' axis."""
    return tmap(lambda t: ("layers",) + t, layer_tree, is_leaf=_IS_LOGICAL)


# ---------------------------------------------------------------------------
# per family
# ---------------------------------------------------------------------------

def dense_logical(cfg: ArchConfig):
    layer = {"ln_attn": _norm(cfg), "attn": _attn(cfg), "mlp": _mlp(cfg)}
    if not cfg.parallel_block:
        layer["ln_mlp"] = _norm(cfg)
    out = {
        "embed": ("vocab", "embed"),
        "layers": _stack(layer),
        "ln_f": _norm(cfg),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ("embed", "vocab")
    return out


def moe_logical(cfg: ArchConfig):
    layer = {
        "ln_attn": _norm(cfg),
        "attn": _attn(cfg),
        "ln_mlp": _norm(cfg),
        "moe": {
            "router": ("embed", "experts"),
            "wi": ("experts", "embed", "mlp"),
            "wg": ("experts", "embed", "mlp"),
            "wo": ("experts", "mlp", "embed"),
        },
    }
    return {
        "embed": ("vocab", "embed"),
        "layers": _stack(layer),
        "ln_f": _norm(cfg),
        "unembed": ("embed", "vocab"),
    }


def _mlstm_logical(cfg: ArchConfig):
    return {
        "ln": _norm(cfg),
        "w_up": ("embed", "inner"),
        "w_gate": ("embed", "inner"),
        "w_q": ("inner_in", "inner"),
        "w_k": ("inner_in", "inner"),
        "w_v": ("inner_in", "inner"),
        "w_if": ("inner", "gates"),
        "b_if": ("gates",),
        "ln_out": {"scale": ("inner",)},
        "w_down": ("inner", "embed"),
    }


def _slstm_logical(cfg: ArchConfig):
    # sLSTM is sequential + recurrent; keep its core replicated, shard GLU.
    return {
        "ln": _norm(cfg),
        "w_z": ("embed", "embed2"),
        "w_i": ("embed", "sheads"),
        "w_f": ("embed", "sheads"),
        "w_o": ("embed", "embed2"),
        "r_z": ("embed", "embed2"),
        "b_i": ("sheads",),
        "b_f": ("sheads",),
        "ln_out": {"scale": ("embed",)},
        "w_glu_i": ("embed", "glu"),
        "w_glu_g": ("embed", "glu"),
        "w_glu_o": ("glu", "embed"),
    }


def xlstm_logical(cfg: ArchConfig):
    layers = [
        _slstm_logical(cfg) if _is_slstm(cfg, i) else _mlstm_logical(cfg)
        for i in range(cfg.n_layers)
    ]
    return {
        "embed": ("vocab", "embed"),
        "layers": layers,
        "ln_f": _norm(cfg),
        "unembed": ("embed", "vocab"),
    }


def ssm_logical(cfg: ArchConfig):
    mamba = {
        "ln": _norm(cfg),
        "in_proj": ("embed", "proj"),
        "conv_w": ("convw", "conv"),
        "conv_b": ("conv",),
        "A_log": ("sheads",),
        "D": ("sheads",),
        "dt_bias": ("sheads",),
        "ln_out": {"scale": ("inner",)},
        "out_proj": ("inner", "embed"),
    }
    out = {
        "embed": ("vocab", "embed"),
        "mamba_layers": _stack(mamba),
        "ln_f": _norm(cfg),
        "unembed": ("embed", "vocab"),
    }
    if cfg.shared_attn_every > 0:
        out["shared_attn"] = {
            "ln_attn": _norm(cfg),
            "attn": _attn(cfg),
            "ln_mlp": _norm(cfg),
            "mlp": _mlp(cfg),
        }
    return out


_FAMILY_LOGICAL = {
    "dense": dense_logical,
    "vlm": dense_logical,
    "audio": dense_logical,
    "moe": moe_logical,
    "xlstm": xlstm_logical,
    "hybrid": ssm_logical,
    "ssm": ssm_logical,
}


def param_logical(cfg: ArchConfig):
    return _FAMILY_LOGICAL[cfg.family](cfg)
