"""xLSTM (arXiv:2405.04517): sLSTM + mLSTM residual blocks.

Faithful-to-structure JAX implementation of the two block types:

  * **mLSTM block** (matrix memory, fully parallelisable): pre-norm, up
    projection by ``ssm_expand``, per-head exponentially-gated *linear
    attention* with matrix state C in R^{d_h x d_h} and normaliser n in
    R^{d_h}. We implement the **stabilised chunkwise-parallel form**: within
    a chunk the interaction is a masked (gated) attention matrix; across
    chunks a recurrent (C, n, m) state is carried with log-domain
    stabilisation, exactly the scheme that makes mLSTM trainable at long
    context and O(1)-state at decode. TPU-native: the chunk dimension is a
    ``lax.scan``; intra-chunk math is dense matmuls on (chunk, chunk) tiles
    (MXU-friendly), no data-dependent control flow.
  * **sLSTM block** (scalar memory, inherently sequential): per-head scalar
    state (c, n, m) with exponential input gate and sigmoid/exp forget gate,
    scanned over time. The paper notes sLSTM is not parallelisable -- the
    scan is the honest implementation. A small GLU ("post up-projection" as
    in the paper's sLSTM block, factor 4/3) follows.

Block layout: ``slstm_every`` = s means layer indices {0, s, 2s, ...} are
sLSTM blocks, the rest mLSTM (paper's xLSTM[a:b] notation). Decode state per
layer is the (C, n, m) triple (mLSTM) or (c, n, m) (sLSTM) plus the previous
hidden for the sLSTM recurrent connection -- O(1) in sequence length, which
is why xlstm runs the ``long_500k`` shape natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ArchConfig
from repro.models.layers import apply_norm, dense_init, embed_init, init_norm, maybe_remat
from repro.sharding.rules import constrain

_EPS = 1e-6


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    hd = d_in // H
    return d_in, H, hd


def init_mlstm_layer(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, H, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln": init_norm(cfg.norm, d, cfg.param_dtype),
        "w_up": dense_init(ks[0], (d, d_in), cfg.param_dtype),
        "w_gate": dense_init(ks[1], (d, d_in), cfg.param_dtype),
        "w_q": dense_init(ks[2], (d_in, d_in), cfg.param_dtype),
        "w_k": dense_init(ks[3], (d_in, d_in), cfg.param_dtype),
        "w_v": dense_init(ks[4], (d_in, d_in), cfg.param_dtype),
        "w_if": dense_init(ks[5], (d_in, 2 * H), cfg.param_dtype,
                           scale=1e-2),
        "b_if": jnp.concatenate(
            [jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(cfg.param_dtype),
        "ln_out": init_norm("rmsnorm", d_in, cfg.param_dtype),
        "w_down": dense_init(ks[6], (d_in, d), cfg.param_dtype),
    }


def init_slstm_layer(key, cfg: ArchConfig):
    d = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    d_glu = int(d * 4 / 3)
    return {
        "ln": init_norm(cfg.norm, d, cfg.param_dtype),
        # input projections for (z, i, f, o) gates
        "w_z": dense_init(ks[0], (d, d), cfg.param_dtype),
        "w_i": dense_init(ks[1], (d, H), cfg.param_dtype, scale=1e-2),
        "w_f": dense_init(ks[2], (d, H), cfg.param_dtype, scale=1e-2),
        "w_o": dense_init(ks[3], (d, d), cfg.param_dtype),
        # recurrent (hidden-to-gate) connections, block-diagonal per head
        "r_z": dense_init(ks[4], (d, d), cfg.param_dtype, scale=1e-2),
        "b_i": jnp.zeros((H,), cfg.param_dtype),
        "b_f": (3.0 * jnp.ones((H,))).astype(cfg.param_dtype),
        "ln_out": init_norm("rmsnorm", d, cfg.param_dtype),
        # post-up-projection GLU (paper: factor 4/3)
        "w_glu_i": dense_init(ks[5], (d, d_glu), cfg.param_dtype),
        "w_glu_g": dense_init(ks[6], (d, d_glu), cfg.param_dtype),
        "w_glu_o": dense_init(ks[7], (d_glu, d), cfg.param_dtype),
    }


def _is_slstm(cfg: ArchConfig, idx: int) -> bool:
    return cfg.slstm_every > 0 and idx % cfg.slstm_every == 0


def init(key, cfg: ArchConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.n_layers)
    # heterogeneous list of per-layer dicts; the *kind* of layer i is a
    # static function of cfg (_is_slstm), never stored in the pytree.
    layers = [
        init_slstm_layer(keys[i], cfg) if _is_slstm(cfg, i)
        else init_mlstm_layer(keys[i], cfg)
        for i in range(cfg.n_layers)
    ]
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "ln_f": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "unembed": dense_init(k_out, (cfg.d_model, cfg.vocab),
                              cfg.param_dtype),
    }
    return params


# ---------------------------------------------------------------------------
# mLSTM chunkwise-parallel core
# ---------------------------------------------------------------------------

def _mlstm_scan(q, k, v, i_pre, f_pre, chunk: int, state=None):
    """Stabilised chunkwise mLSTM.

    q, k, v: (B, T, H, hd); i_pre, f_pre: (B, T, H) gate pre-activations.
    state: optional (C, n, m) with C (B, H, hd, hd), n (B, H, hd), m (B, H).
    Returns (out (B, T, H, hd), state').
    """
    B, T, H, hd = q.shape
    pad = (-T) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)   # exp(i)=0: no-op tokens
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=30.0)    # sigmoid(f)=1: keep state
    Tp = q.shape[1]
    nC = Tp // chunk
    scale = 1.0 / jnp.sqrt(hd)

    def rs(x):  # (B, Tp, H, ...) -> (nC, B, H, chunk, ...)
        x = x.reshape(B, nC, chunk, *x.shape[2:])
        return jnp.moveaxis(jnp.swapaxes(x, 2, 3), 1, 0)

    qc, kc, vc = rs(q * scale), rs(k), rs(v)                # (nC,B,H,c,hd)
    ic = jnp.moveaxis(i_pre.reshape(B, nC, chunk, H), 3, 2)  # (B,nC,H,c)
    fc = jnp.moveaxis(f_pre.reshape(B, nC, chunk, H), 3, 2)
    ic = jnp.moveaxis(ic, 1, 0)                              # (nC,B,H,c)
    fc = jnp.moveaxis(fc, 1, 0)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def per_chunk(carry, xs):
        C, n, m = carry
        qb, kb, vb, ib, fb = xs  # (B,H,c,hd) x3, (B,H,c) x2
        logf = jax.nn.log_sigmoid(fb.astype(jnp.float32))   # (B,H,c)
        a = jnp.cumsum(logf, axis=-1)                       # A_t within chunk
        a_total = a[..., -1]                                # (B,H)
        # log weight of state seen by position t: m + a_t
        # log weight of in-chunk source s at target t: a_t - a_s + i_s
        src = ib.astype(jnp.float32) - a                    # (B,H,c): i_s - A_s
        # Stabiliser per target position. State contribution to target t has
        # log-scale m + A_t; intra source s has log-scale A_t - A_s + i_s
        # = A_t + src_s. Factor exp(A_t) is common to numerator and
        # normaliser, so stabilise by m_base = max(m, max_{s<=t} src_s)
        # and divide both by exp(A_t + m_base).
        m_intra = jnp.max(jnp.where(
            jnp.tril(jnp.ones((chunk, chunk), bool))[None, None],
            src[..., None, :], -jnp.inf), axis=-1)          # (B,H,c)
        m_base = jnp.maximum(m_intra, m[..., None])         # (B,H,c)
        # intra-chunk gated attention
        dmat = src[..., None, :] - m_base[..., :, None]     # (B,H,c,c) log D_ts
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(mask[None, None], jnp.exp(dmat), 0.0)
        s_qk = jnp.einsum("bhtd,bhsd->bhts", qb.astype(jnp.float32),
                          kb.astype(jnp.float32))
        w_intra = s_qk * D                                  # (B,H,c,c)
        o_intra = jnp.einsum("bhts,bhsd->bhtd", w_intra, vb.astype(jnp.float32))
        # inter-chunk (state) contribution, relative scale exp(m - m_base)
        w_state = jnp.exp(m[..., None] - m_base)            # (B,H,c)
        o_state = jnp.einsum("bhtd,bhde->bhte", qb.astype(jnp.float32), C)
        n_state = jnp.einsum("bhtd,bhd->bht", qb.astype(jnp.float32), n)
        o = o_intra + w_state[..., None] * o_state
        # normaliser n_t^T q_t: sum_s D_ts (q_t . k_s) = row-sum of w_intra
        nrm = jnp.abs(jnp.sum(w_intra, axis=-1) + w_state * n_state)
        # mLSTM normaliser: max(|n^T q|, exp(-m_t_total)) with the shared
        # exp(A_t + m_base) factor divided out => lower bound exp(-(a+m_base))
        denom = jnp.maximum(nrm, jnp.exp(-(a + m_base)))
        out = o / denom[..., None]
        # ---- state update to end of chunk ----
        # new m' = max(m + a_total, max_s (i_s + A_total - A_s))
        carry_src = ib.astype(jnp.float32) + (a_total[..., None] - a)  # (B,H,c)
        m_new = jnp.maximum(m + a_total, jnp.max(carry_src, axis=-1))
        w_old = jnp.exp(m + a_total - m_new)                # (B,H)
        w_src = jnp.exp(carry_src - m_new[..., None])       # (B,H,c)
        C_new = w_old[..., None, None] * C + jnp.einsum(
            "bhsd,bhse->bhde", kb.astype(jnp.float32) * w_src[..., None],
            vb.astype(jnp.float32))
        n_new = w_old[..., None] * n + jnp.einsum(
            "bhsd,bhs->bhd", kb.astype(jnp.float32), w_src)
        return (C_new, n_new, m_new), out

    (C, n, m), outs = lax.scan(per_chunk, (C0, n0, m0), (qc, kc, vc, ic, fc))
    out = jnp.moveaxis(outs, 0, 1)                    # (B,nC,H,c,hd)
    out = jnp.swapaxes(out, 2, 3).reshape(B, Tp, H, hd)
    return out[:, :T], (C, n, m)


def mlstm_step(q1, k1, v1, i1, f1, state):
    """One decode step. q1,k1,v1: (B, H, hd); i1,f1: (B, H). O(1) state."""
    C, n, m = state
    hd = q1.shape[-1]
    qf = q1.astype(jnp.float32) / jnp.sqrt(hd)
    logf = jax.nn.log_sigmoid(f1.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, i1.astype(jnp.float32))
    w_old = jnp.exp(logf + m - m_new)
    w_in = jnp.exp(i1.astype(jnp.float32) - m_new)
    C = w_old[..., None, None] * C + w_in[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k1.astype(jnp.float32), v1.astype(jnp.float32))
    n = w_old[..., None] * n + w_in[..., None] * k1.astype(jnp.float32)
    o = jnp.einsum("bhd,bhde->bhe", qf, C)
    nrm = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
    denom = jnp.maximum(nrm, jnp.exp(-m_new))
    return o / denom[..., None], (C, n, m_new)


def _mlstm_qkvif(x, p, cfg: ArchConfig):
    d_in, H, hd = _mlstm_dims(cfg)
    up = x @ p["w_up"].astype(x.dtype)
    gate = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    q = (up @ p["w_q"].astype(x.dtype))
    k = (up @ p["w_k"].astype(x.dtype))
    v = (up @ p["w_v"].astype(x.dtype))
    if_pre = up.astype(jnp.float32) @ p["w_if"].astype(jnp.float32) \
        + p["b_if"].astype(jnp.float32)
    i_pre, f_pre = if_pre[..., :H], if_pre[..., H:]
    shp = x.shape[:-1] + (H, hd)
    return q.reshape(shp), k.reshape(shp), v.reshape(shp), i_pre, f_pre, gate


def mlstm_block(x, p, cfg: ArchConfig, state=None):
    """x: (B, T, d). Returns (y, state')."""
    d_in, H, hd = _mlstm_dims(cfg)
    h = apply_norm(x, p["ln"], cfg.norm)
    q, k, v, i_pre, f_pre, gate = _mlstm_qkvif(h, p, cfg)
    q = constrain(q, "batch", "seq", "heads", None)
    out, state = _mlstm_scan(q, k, v, i_pre, f_pre, cfg.ssm_chunk, state)
    B, T = x.shape[0], x.shape[1]
    out = out.reshape(B, T, d_in).astype(x.dtype)
    out = apply_norm(out, p["ln_out"], "rmsnorm") * gate
    y = out @ p["w_down"].astype(x.dtype)
    return x + y, state


def mlstm_block_step(x1, p, cfg: ArchConfig, state):
    """x1: (B, 1, d) decode step."""
    d_in, H, hd = _mlstm_dims(cfg)
    h = apply_norm(x1, p["ln"], cfg.norm)
    q, k, v, i_pre, f_pre, gate = _mlstm_qkvif(h, p, cfg)
    out, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0],
                            f_pre[:, 0], state)
    out = out.reshape(x1.shape[0], 1, d_in).astype(x1.dtype)
    out = apply_norm(out, p["ln_out"], "rmsnorm") * gate
    return x1 + out @ p["w_down"].astype(x1.dtype), state


# ---------------------------------------------------------------------------
# sLSTM (sequential scan)
# ---------------------------------------------------------------------------

def _slstm_cell(carry, xs, H, hd):
    """carry: (c, n, m, h_prev) each (B, H, hd) / (B, H); xs precomputed."""
    c, n, m, h_prev = carry
    z_x, i_x, f_x, o_x, r_z = xs  # projections at time t (+ recurrent weight)
    B = z_x.shape[0]
    h_flat = h_prev.reshape(B, H * hd)
    z = jnp.tanh(z_x + (h_flat @ r_z).reshape(B, H, hd))
    i_pre = i_x  # (B, H)
    f_pre = f_x
    o = jax.nn.sigmoid(o_x).reshape(B, H, hd)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    c_new = f_g[..., None] * c + i_g[..., None] * z
    n_new = f_g[..., None] * n + i_g[..., None]
    h_new = o * (c_new / jnp.maximum(n_new, _EPS))
    return (c_new, n_new, m_new, h_new), h_new


def slstm_block(x, p, cfg: ArchConfig, state=None):
    B, T, d = x.shape
    H = cfg.n_heads
    hd = d // H
    h = apply_norm(x, p["ln"], cfg.norm)
    hf = h.astype(jnp.float32)
    z_x = hf @ p["w_z"].astype(jnp.float32)
    i_x = hf @ p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32)
    f_x = hf @ p["w_f"].astype(jnp.float32) + p["b_f"].astype(jnp.float32)
    o_x = hf @ p["w_o"].astype(jnp.float32)
    if state is None:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
        h0 = jnp.zeros((B, H, hd), jnp.float32)
        state = (c0, n0, m0, h0)
    r_z = p["r_z"].astype(jnp.float32)

    def step(carry, xs):
        zz, ii, ff, oo = xs
        return _slstm_cell(carry, (zz, ii, ff, oo, r_z), H, hd)

    state, hs = lax.scan(
        step, state,
        (jnp.moveaxis(z_x.reshape(B, T, H, hd), 1, 0),
         jnp.moveaxis(i_x, 1, 0), jnp.moveaxis(f_x, 1, 0),
         jnp.moveaxis(o_x.reshape(B, T, H, hd), 1, 0)))
    out = jnp.moveaxis(hs, 0, 1).reshape(B, T, d)
    out = apply_norm(out.astype(x.dtype), p["ln_out"], "rmsnorm")
    y = x + out
    # GLU post-projection
    g = jax.nn.silu(y @ p["w_glu_i"].astype(x.dtype)) * (
        y @ p["w_glu_g"].astype(x.dtype))
    return y + g @ p["w_glu_o"].astype(x.dtype), state


def slstm_block_step(x1, p, cfg: ArchConfig, state):
    B, _, d = x1.shape
    H = cfg.n_heads
    hd = d // H
    h = apply_norm(x1, p["ln"], cfg.norm)
    hf = h[:, 0].astype(jnp.float32)
    z_x = (hf @ p["w_z"].astype(jnp.float32)).reshape(B, H, hd)
    i_x = hf @ p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32)
    f_x = hf @ p["w_f"].astype(jnp.float32) + p["b_f"].astype(jnp.float32)
    o_x = (hf @ p["w_o"].astype(jnp.float32)).reshape(B, H, hd)
    state, h_new = _slstm_cell(
        state, (z_x, i_x, f_x, o_x, p["r_z"].astype(jnp.float32)), H, hd)
    out = h_new.reshape(B, 1, d)
    out = apply_norm(out.astype(x1.dtype), p["ln_out"], "rmsnorm")
    y = x1 + out
    g = jax.nn.silu(y @ p["w_glu_i"].astype(x1.dtype)) * (
        y @ p["w_glu_g"].astype(x1.dtype))
    return y + g @ p["w_glu_o"].astype(x1.dtype), state


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def hidden(params, batch, cfg: ArchConfig):
    x = params["embed"][batch["tokens"]].astype(cfg.dtype)
    sblk = maybe_remat(lambda h, lp: slstm_block(h, lp, cfg)[0], cfg)
    mblk = maybe_remat(lambda h, lp: mlstm_block(h, lp, cfg)[0], cfg)
    for i, lp in enumerate(params["layers"]):
        x = sblk(x, lp) if _is_slstm(cfg, i) else mblk(x, lp)
        x = constrain(x, "batch", "seq_res", "embed")
    return apply_norm(x, params["ln_f"], cfg.norm)


def apply(params, batch, cfg: ArchConfig):
    x = hidden(params, batch, cfg)
    w = params["unembed"].astype(x.dtype)
    return jnp.einsum("btd,dv->btv", x, w)


def init_decode_state(cfg: ArchConfig, batch_size: int, seq_len: int,
                      prefill_len=None):
    d_in, H, hd = _mlstm_dims(cfg)
    Hs, hds = cfg.n_heads, cfg.d_model // cfg.n_heads
    states = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            states.append((
                jnp.zeros((batch_size, Hs, hds), jnp.float32),
                jnp.zeros((batch_size, Hs, hds), jnp.float32),
                jnp.full((batch_size, Hs), -1e30, jnp.float32),
                jnp.zeros((batch_size, Hs, hds), jnp.float32)))
        else:
            states.append((
                jnp.zeros((batch_size, H, hd, hd), jnp.float32),
                jnp.zeros((batch_size, H, hd), jnp.float32),
                jnp.full((batch_size, H), -1e30, jnp.float32)))
    return {"states": states, "pos": jnp.zeros((batch_size,), jnp.int32)}


def prefill(params, batch, cfg: ArchConfig, max_len=None):
    """Forward over the prompt, carrying recurrent state out (O(1) state;
    max_len is accepted for interface uniformity and ignored)."""
    x = params["embed"][batch["tokens"]].astype(cfg.dtype)
    states = []
    for i, lp in enumerate(params["layers"]):
        if _is_slstm(cfg, i):
            x, st = slstm_block(x, lp, cfg)
        else:
            x, st = mlstm_block(x, lp, cfg)
        states.append(st)
        x = constrain(x, "batch", "seq_res", "embed")
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("btd,dv->btv", x[:, -1:],
                        params["unembed"].astype(x.dtype))
    B, T = batch["tokens"].shape
    return logits, {"states": states,
                    "pos": jnp.full((B,), T, jnp.int32)}


def decode_step(params, state, batch, cfg: ArchConfig):
    x = params["embed"][batch["tokens"]].astype(cfg.dtype)  # (B, 1, d)
    new_states = []
    for i, (lp, st) in enumerate(zip(params["layers"], state["states"])):
        if _is_slstm(cfg, i):
            x, st = slstm_block_step(x, lp, cfg, st)
        else:
            x, st = mlstm_block_step(x, lp, cfg, st)
        new_states.append(st)
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("btd,dv->btv", x, params["unembed"].astype(x.dtype))
    return logits, {"states": new_states, "pos": state["pos"] + 1}
