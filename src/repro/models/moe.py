"""Mixture-of-Experts transformer (Mixtral family: 8 experts, top-2, SWA).

Routing is capacity-bounded and sort-based (dropless up to the capacity
factor): token assignments are argsorted by expert, positions within each
expert computed from exclusive-cumsum group starts, and tokens beyond
capacity C = cf * top_k * T / E are dropped (weight renormalised). The
per-expert compute is ONE batched matmul over a dense (E, C, d) buffer, so
HLO_FLOPs ~= cf * top_k * (dense-equivalent FLOPs) and the roofline's
MODEL_FLOPS/HLO_FLOPs ratio stays honest (DESIGN.md §5). Expert weights are
(E, d, ff) with ff sharded over "model"; the dispatch buffer shards over
("pod","data") like the tokens it came from.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import dense
from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_norm,
    cache_append,
    cache_from_prefill,
    decode_attention,
    dense_init,
    init_attention,
    init_norm,
    maybe_remat,
    out_proj,
    qkv_proj,
    rope,
)
from repro.sharding.rules import constrain


def init_moe_mlp(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(ks[0], (d, E), cfg.param_dtype),
        "wi": dense_init(ks[1], (E, d, ff), cfg.param_dtype),
        "wg": dense_init(ks[2], (E, d, ff), cfg.param_dtype),
        "wo": dense_init(ks[3], (E, ff, d), cfg.param_dtype),
    }


def init_layer(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.hd, cfg.bias,
                               cfg.param_dtype),
        "ln_mlp": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "moe": init_moe_mlp(ks[1], cfg),
    }


def init(key, cfg: ArchConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return {
        "embed": dense.embed_init(k_emb, cfg.vocab, cfg.d_model,
                                  cfg.param_dtype),
        "layers": layers,
        "ln_f": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "unembed": dense_init(k_out, (cfg.d_model, cfg.vocab),
                              cfg.param_dtype),
    }


def moe_mlp(x, p, cfg: ArchConfig):
    """x: (B, T, d) -> (B, T, d), plus aux metrics dict.

    When the ambient sharding rules map "batch" onto G > 1 mesh shards,
    routing/dispatch runs PER SHARD (vmap + spmd_axis_name) with capacity
    C/G each: tokens never cross data shards for dispatch, so the global
    argsort does not force an all-gather of the token stream. (Same
    approximation every capacity-based TPU MoE makes; the capacity factor
    absorbs the extra imbalance. Documented in DESIGN.md.)"""
    from repro.sharding.rules import batch_groups
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)
    G, gaxes = batch_groups()
    # group-dispatch only for bulk token streams: for tiny N (decode) the
    # G-way split would pin the "data" axis to token groups and force XLA
    # to ALL-GATHER the data-sharded expert weights instead of
    # partial-summing activations (measured 201 MB x n_layers per decode
    # step on mixtral-8x22b, EXPERIMENTS.md §Perf 1.3)
    if G > 1 and N % G == 0 and (N // G) >= 64:
        xg = xf.reshape(G, N // G, d)
        yg, aux = jax.vmap(
            lambda xx: _moe_dispatch(xx, p, cfg),
            spmd_axis_name=(gaxes if len(gaxes) > 1 else gaxes[0]))(xg)
        aux = jax.tree_util.tree_map(jnp.mean, aux)
        return yg.reshape(B, T, d), aux
    out, aux = _moe_dispatch(xf, p, cfg)
    return out.reshape(B, T, d), aux


def _moe_dispatch(xf, p, cfg: ArchConfig):
    """Capacity-bounded sort-based dispatch for one token block (N, d)."""
    N, d = xf.shape
    E, K = cfg.n_experts, cfg.top_k

    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)  # (N, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # flatten the K assignments
    e_all = top_e.reshape(-1)            # (N*K,)
    p_all = top_p.reshape(-1)
    src = jnp.repeat(jnp.arange(N), K)   # source token of each assignment

    order = jnp.argsort(e_all)           # group by expert
    e_sorted = e_all[order]
    src_sorted = src[order]
    p_sorted = p_all[order]

    counts = jnp.bincount(e_all, length=E)            # (E,)
    starts = jnp.cumsum(counts) - counts              # exclusive cumsum
    pos_in_expert = jnp.arange(N * K) - starts[e_sorted]

    C = max(1, int(cfg.capacity_factor * K * N / E))
    keep = pos_in_expert < C
    slot = e_sorted * C + jnp.minimum(pos_in_expert, C - 1)

    # dispatch into (E*C, d)
    buf = jnp.zeros((E * C, d), xf.dtype)
    vals = jnp.where(keep[:, None], xf[src_sorted], 0.0)
    buf = buf.at[slot].add(jnp.where(keep[:, None], vals, 0.0))
    buf = buf.reshape(E, C, d)
    buf = constrain(buf, "experts", None, "embed")

    # expert FFN: batched matmuls (E, C, d) x (E, d, ff)
    wi = p["wi"].astype(xf.dtype)
    wg = p["wg"].astype(xf.dtype)
    wo = p["wo"].astype(xf.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wi)) * jnp.einsum(
        "ecd,edf->ecf", buf, wg)
    h = constrain(h, "experts", None, "mlp")
    y = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E * C, d)

    # combine back
    gathered = y[slot] * p_sorted[:, None].astype(xf.dtype)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((N, d), xf.dtype).at[src_sorted].add(gathered)

    # aux: load-balance loss ingredients (Switch-style)
    me = jnp.mean(probs, axis=0)                       # mean router prob
    ce = jnp.bincount(e_all, length=E) / (N * K)       # fraction routed
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "dropped": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return out, aux


def block_forward(x, lp, cfg: ArchConfig, positions):
    h = apply_norm(x, lp["ln_attn"], cfg.norm)
    attn_out, k, v = dense._attn_full(h, lp["attn"], cfg, positions)
    x = x + attn_out
    h2 = apply_norm(x, lp["ln_mlp"], cfg.norm)
    mlp_out, aux = moe_mlp(h2, lp["moe"], cfg)
    x = x + mlp_out
    return constrain(x, "batch", "seq_res", "embed"), (k, v, aux)


def hidden(params, batch, cfg: ArchConfig):
    x, positions = dense.embed_inputs(params, batch, cfg)
    blk = maybe_remat(
        lambda h, lp: block_forward(h, lp, cfg, positions)[0], cfg)

    def body(h, lp):
        return blk(h, lp), None

    x, _ = lax.scan(body, x, params["layers"])
    return apply_norm(x, params["ln_f"], cfg.norm)


def apply(params, batch, cfg: ArchConfig):
    return dense.unembed(hidden(params, batch, cfg), params, cfg)


def prefill(params, batch, cfg: ArchConfig, max_len=None):
    x, positions = dense.embed_inputs(params, batch, cfg)
    B, T = x.shape[0], x.shape[1]
    plen = batch.get("prefill_len", jnp.full((B,), T, jnp.int32))
    spec = dense._cache_spec(cfg, B, max_len or T)

    def body(h, lp):
        h, (k, v, _) = block_forward(h, lp, cfg, positions)
        return h, cache_from_prefill(k, v, spec, plen)

    x, caches = lax.scan(body, x, params["layers"])
    x = apply_norm(x, params["ln_f"], cfg.norm)
    return dense.unembed(x[:, -1:], params, cfg), {"caches": caches}


init_decode_state = dense.init_decode_state


def decode_step(params, state, batch, cfg: ArchConfig):
    tok = batch["tokens"]
    x = params["embed"][tok].astype(cfg.dtype)
    pos = state["caches"]["next"][0]
    positions = pos[:, None]

    def body(h, layer_in):
        lp, cache = layer_in
        hn = apply_norm(h, lp["ln_attn"], cfg.norm)
        q, k, v = qkv_proj(hn, lp["attn"])
        if cfg.rope_theta > 0:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        cache = cache_append(cache, k, v)
        o = decode_attention(q, cache["k"], cache["v"], cache["pos"],
                             window=cfg.sliding_window, q_position=pos)
        h = h + out_proj(o, lp["attn"])
        h2 = apply_norm(h, lp["ln_mlp"], cfg.norm)
        mlp_out, _ = moe_mlp(h2, lp["moe"], cfg)
        h = h + mlp_out
        return h, cache

    x, caches = lax.scan(body, x, (params["layers"], state["caches"]))
    x = apply_norm(x, params["ln_f"], cfg.norm)
    return dense.unembed(x, params, cfg), {"caches": caches}
