"""Dense GQA transformer family.

Covers: phi3-mini / phi3-medium (RoPE+SwiGLU+GQA, pre-RMSNorm),
smollm-135m (llama-arch), command-r-35b (parallel attn+ffn block, LayerNorm,
no biases), llava-next-34b (same decoder consuming patch-embedding prefixes),
hubert-xlarge (encoder-only, bidirectional attention, GELU, biases).

Three entry points per model (shared via registry):
  apply(params, batch)            -- full-sequence forward -> logits
  prefill(params, batch)          -- forward + build KV caches
  decode_step(params, state, tok) -- one token through ring/full caches

Layer stacks are scanned (leading L axis on every layer leaf).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ArchConfig
from repro.models.layers import (
    CacheSpec,
    apply_mlp,
    apply_norm,
    cache_append,
    cache_from_prefill,
    decode_attention,
    dense_init,
    embed_init,
    flash_attention,
    init_attention,
    init_cache,
    init_mlp,
    init_norm,
    maybe_remat,
    out_proj,
    qkv_proj,
    rope,
)
from repro.sharding.rules import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.hd, cfg.bias,
                               cfg.param_dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, cfg.bias,
                        cfg.param_dtype),
    }
    if not cfg.parallel_block:
        p["ln_mlp"] = init_norm(cfg.norm, cfg.d_model, cfg.param_dtype)
    return p


def init(key, cfg: ArchConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "ln_f": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k_out, (cfg.d_model, cfg.vocab),
                                       cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def _attn_full(x, p, cfg: ArchConfig, positions):
    q, k, v = qkv_proj(x, p)
    if cfg.rope_theta > 0 and cfg.attention == "causal":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    mode = "bidirectional" if cfg.attention == "bidirectional" else "causal"
    o = flash_attention(q, k, v, mode=mode, window=cfg.sliding_window,
                        q_positions=positions, kv_positions=positions)
    return out_proj(o, p), k, v


def block_forward(x, lp, cfg: ArchConfig, positions):
    h = apply_norm(x, lp["ln_attn"], cfg.norm)
    attn_out, _, _ = _attn_full(h, lp["attn"], cfg, positions)
    if cfg.parallel_block:
        mlp_out = apply_mlp(h, lp["mlp"], cfg.mlp)
        x = x + attn_out + mlp_out
    else:
        x = x + attn_out
        h2 = apply_norm(x, lp["ln_mlp"], cfg.norm)
        x = x + apply_mlp(h2, lp["mlp"], cfg.mlp)
    return constrain(x, "batch", "seq_res", "embed")


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def embed_inputs(params, batch, cfg: ArchConfig):
    """Token embedding, with optional stub-frontend prefix (vlm/audio).

    batch["tokens"]: (B, T) int32. For vlm, batch["patch_embeds"]
    (B, n_patches, d_model) is prepended (anyres tiling stub: the vision
    tower+projector output, per the assignment's carve-out). For audio,
    batch["frame_embeds"] (B, T, d_model) *replaces* token embeds.
    """
    if cfg.family == "audio":
        x = batch["frame_embeds"].astype(cfg.dtype)
        return x, jnp.arange(x.shape[1])
    tok = params["embed"][batch["tokens"]].astype(cfg.dtype)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.dtype)
        x = jnp.concatenate([pe, tok], axis=1)
    else:
        x = tok
    return x, jnp.arange(x.shape[1])


def unembed(x, params, cfg: ArchConfig):
    w = params.get("unembed")
    if w is None:
        w = params["embed"].T
    logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype))
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits * cfg.logit_scale


def hidden(params, batch, cfg: ArchConfig):
    """Forward to the final norm, WITHOUT the unembedding (for chunked CE)."""
    x, positions = embed_inputs(params, batch, cfg)
    blk = maybe_remat(
        lambda h, lp: block_forward(h, lp, cfg, positions), cfg)

    def body(h, lp):
        return blk(h, lp), None

    x, _ = lax.scan(body, x, params["layers"])
    return apply_norm(x, params["ln_f"], cfg.norm)


def apply(params, batch, cfg: ArchConfig):
    return unembed(hidden(params, batch, cfg), params, cfg)


# ---------------------------------------------------------------------------
# prefill + decode
# ---------------------------------------------------------------------------

def _cache_spec(cfg: ArchConfig, batch_size: int, seq_len: int) -> CacheSpec:
    size = seq_len if cfg.sliding_window is None else min(
        seq_len, cfg.sliding_window)
    return CacheSpec(batch=batch_size, size=size, kv_heads=cfg.n_kv_heads,
                     head_dim=cfg.hd, dtype=cfg.dtype)


def init_decode_state(cfg: ArchConfig, batch_size: int, seq_len: int,
                      prefill_len):
    """Abstract decode state: per-layer caches with 'next' = prefill_len."""
    spec = _cache_spec(cfg, batch_size, seq_len)

    def one(_):
        c = init_cache(spec)
        return {**c, "next": jnp.broadcast_to(prefill_len, (batch_size,))}

    return {"caches": jax.vmap(one)(jnp.arange(cfg.n_layers))}


def prefill(params, batch, cfg: ArchConfig, max_len: Optional[int] = None):
    """Full forward; returns (logits, decode_state).

    ``max_len`` (static) sizes the KV cache for subsequent decode steps;
    defaults to the prompt length (no decode headroom).
    """
    x, positions = embed_inputs(params, batch, cfg)
    B, T = x.shape[0], x.shape[1]
    plen = batch.get("prefill_len", jnp.full((B,), T, jnp.int32))
    spec = _cache_spec(cfg, B, max_len or T)

    def body(h, lp):
        hn = apply_norm(h, lp["ln_attn"], cfg.norm)
        attn_out, k, v = _attn_full(hn, lp["attn"], cfg, positions)
        if cfg.parallel_block:
            h = h + attn_out + apply_mlp(hn, lp["mlp"], cfg.mlp)
        else:
            h = h + attn_out
            h2 = apply_norm(h, lp["ln_mlp"], cfg.norm)
            h = h + apply_mlp(h2, lp["mlp"], cfg.mlp)
        cache = cache_from_prefill(k, v, spec, plen)
        return constrain(h, "batch", "seq_res", "embed"), cache

    x, caches = lax.scan(body, x, params["layers"])
    x = apply_norm(x, params["ln_f"], cfg.norm)
    # serving: only the next-token logits are needed -- never materialise
    # the full (B, T, V) prefill logits
    return unembed(x[:, -1:], params, cfg), {"caches": caches}


def decode_step(params, state, batch, cfg: ArchConfig):
    """One-token decode. batch["tokens"]: (B, 1). Returns (logits, state)."""
    tok = batch["tokens"]
    x = params["embed"][tok].astype(cfg.dtype)  # (B, 1, d)
    pos = state["caches"]["next"][0]  # (B,) same for all layers
    positions = pos[:, None]  # (B, 1) absolute position of this token

    def body(h, layer_in):
        lp, cache = layer_in
        hn = apply_norm(h, lp["ln_attn"], cfg.norm)
        q, k, v = qkv_proj(hn, lp["attn"])
        if cfg.rope_theta > 0 and cfg.attention == "causal":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        cache = cache_append(cache, k, v)
        o = decode_attention(q, cache["k"], cache["v"], cache["pos"],
                             window=cfg.sliding_window, q_position=pos)
        attn_out = out_proj(o, lp["attn"])
        if cfg.parallel_block:
            h = h + attn_out + apply_mlp(hn, lp["mlp"], cfg.mlp)
        else:
            h = h + attn_out
            h2 = apply_norm(h, lp["ln_mlp"], cfg.norm)
            h = h + apply_mlp(h2, lp["mlp"], cfg.mlp)
        return h, cache

    x, caches = lax.scan(body, x, (params["layers"], state["caches"]))
    x = apply_norm(x, params["ln_f"], cfg.norm)
    return unembed(x, params, cfg), {"caches": caches}
