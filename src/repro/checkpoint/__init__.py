from repro.checkpoint.npz import save, restore, save_fedepm, restore_fedepm  # noqa: F401
