"""Dependency-free pytree checkpointing (npz + json treedef).

Leaves are stored in one .npz by flattened index; the tree structure, leaf
dtypes, and user metadata go into a sidecar .json. Restores reproduce the
exact pytree (dicts/lists/tuples/NamedTuple-shaped dicts). Good enough for
single-host examples and tests; a production deployment would swap in
tensorstore/orbax behind the same two calls.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    """(skeleton, leaves): one recursion used by BOTH save and restore, so
    leaf indices are self-consistent (jax's tree_leaves sorts dict keys;
    we must not mix the two orders). Dict keys are iterated sorted."""
    leaves: list = []

    def rec(node):
        if isinstance(node, dict):
            return {"__kind__": "dict",
                    "items": {k: rec(node[k]) for k in sorted(node)}}
        if isinstance(node, (list, tuple)):
            kind = "list" if isinstance(node, list) else "tuple"
            return {"__kind__": kind, "items": [rec(v) for v in node]}
        leaves.append(node)
        return {"__kind__": "leaf", "index": len(leaves) - 1}

    return rec(tree), leaves


def _json_to_tree(skel, leaves):
    if skel["__kind__"] == "dict":
        return {k: _json_to_tree(v, leaves) for k, v in skel["items"].items()}
    if skel["__kind__"] == "list":
        return [_json_to_tree(v, leaves) for v in skel["items"]]
    if skel["__kind__"] == "tuple":
        return tuple(_json_to_tree(v, leaves) for v in skel["items"])
    return leaves[skel["index"]]


def save(path: str, tree, metadata: dict | None = None) -> None:
    """Write ``path``.npz + ``path``.json."""
    skeleton, leaves = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path + ".npz", **arrays)
    sidecar = {"skeleton": skeleton,
               "n_leaves": len(leaves),
               "metadata": metadata or {}}
    with open(path + ".json", "w") as f:
        json.dump(sidecar, f)


def restore(path: str):
    """Returns (tree, metadata)."""
    with open(path + ".json") as f:
        sidecar = json.load(f)
    data = np.load(path + ".npz")
    leaves = [jnp.asarray(data[f"leaf_{i}"])
              for i in range(sidecar["n_leaves"])]
    return _json_to_tree(sidecar["skeleton"], leaves), sidecar["metadata"]


def save_fedepm(path: str, state, cfg) -> None:
    """Checkpoint a FedEPMState (+ its config for resumption checks)."""
    import dataclasses
    meta = {"fedepm_config": {k: str(v) for k, v in
                              dataclasses.asdict(cfg).items()}}
    save(path, state._asdict(), metadata=meta)


def restore_fedepm(path: str):
    from repro.core.fedepm import FedEPMState
    tree, meta = restore(path)
    return FedEPMState(**tree), meta
