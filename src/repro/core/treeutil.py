"""Small pytree helpers used across the federated core."""
from __future__ import annotations

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


def tree_add(a, b):
    return tmap(jnp.add, a, b)


def tree_sub(a, b):
    return tmap(jnp.subtract, a, b)


def tree_scale(a, s):
    return tmap(lambda x: x * s, a)


def tree_zeros_like(a):
    return tmap(jnp.zeros_like, a)


def tree_sq_norm(a):
    """||a||^2 summed over all leaves (float32)."""
    leaves = jax.tree_util.tree_leaves(a)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def tree_l1_norm(a):
    leaves = jax.tree_util.tree_leaves(a)
    return sum(jnp.sum(jnp.abs(x.astype(jnp.float32))) for x in leaves)


def tree_inf_norm(a):
    leaves = jax.tree_util.tree_leaves(a)
    return jnp.max(jnp.stack([jnp.max(jnp.abs(x)) for x in leaves]))


def tree_size(a) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(a))


def tree_stack(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return tmap(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_index(tree, i):
    """tree[i] along the leading axis of every leaf."""
    return tmap(lambda x: x[i], tree)


def tree_where(mask_scalar, a, b):
    """Select a or b per-leaf given a scalar/bool (broadcast) mask."""
    return tmap(lambda x, y: jnp.where(mask_scalar, x, y), a, b)


def tree_where_client(mask_m, a, b):
    """Select between stacked client trees with a per-client (m,) mask."""

    def sel(x, y):
        m = mask_m.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)

    return tmap(sel, a, b)


def tree_broadcast_clients(tree, m: int):
    """Tile a tree along a new leading client axis of size m."""
    return tmap(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), tree)
