"""Elastic-net exact-penalty machinery (paper Secs. II-III).

Implements:
  * ``soft``            -- soft-thresholding operator, eq. (2)/(3).
  * ``elastic_net``     -- the penalty phi(z) = lam*||z||_1 + eta/2*||z||^2, eq. (8).
  * ``penalized_objective`` -- F(w, W) of model (7).
  * ``lambda_star``     -- the exact-penalty threshold of Theorem III.1, eq. (11).
  * stationarity residuals for problems (6) and (7) used by the exact-penalty
    validation benchmark / tests.

All functions are pure jnp and jit-safe.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp


def soft(t: jax.Array, a) -> jax.Array:
    """Soft-thresholding, eq. (2): argmin_x (1/2)(x-t)^2 + a|x| (elementwise)."""
    return jnp.sign(t) * jnp.maximum(jnp.abs(t) - a, 0.0)


def elastic_net(z: jax.Array, lam, eta) -> jax.Array:
    """phi(z) = lam*||z||_1 + (eta/2)*||z||^2, eq. (8). Reduces over all axes."""
    return lam * jnp.sum(jnp.abs(z)) + 0.5 * eta * jnp.sum(z * z)


def elastic_net_tree(tree_z, lam, eta):
    """phi applied to a pytree difference, summed over all leaves."""
    leaves = jax.tree_util.tree_leaves(tree_z)
    return sum(elastic_net(z, lam, eta) for z in leaves)


def penalized_objective(
    fs: Sequence[Callable[[jax.Array], jax.Array]],
    w: jax.Array,
    W: jax.Array,
    lam,
    eta,
) -> jax.Array:
    """F(w, W) = sum_i [f_i(w_i) + phi(w_i - w)], eq. (7).

    ``W`` stacks client parameters along axis 0: W[i] = w_i.
    """
    total = jnp.asarray(0.0, dtype=w.dtype)
    for i, fi in enumerate(fs):
        total = total + fi(W[i]) + elastic_net(W[i] - w, lam, eta)
    return total


def lambda_star(grads_at_wstar: jax.Array) -> jax.Array:
    """Exact-penalty threshold, eq. (11).

    lambda* = max_i max_j |(grad f_i(w*))_j| where ``grads_at_wstar`` stacks
    per-client gradients along axis 0.
    """
    return jnp.max(jnp.abs(grads_at_wstar))


# ---------------------------------------------------------------------------
# Stationarity residuals (Definition III.1)
# ---------------------------------------------------------------------------

def stationarity_residual_original(grads: jax.Array, W: jax.Array, w: jax.Array):
    """Residual of the KKT system (9) for the *original* problem (6).

    grads[i] = grad f_i(w_i). With pi_i := -grad f_i(w_i), the three
    conditions collapse to:
      r_consensus = max_i ||w_i - w||_inf
      r_balance   = ||sum_i grad f_i(w_i)||_inf   (since sum_i pi_i = 0)
    Returns (r_consensus, r_balance).
    """
    r_cons = jnp.max(jnp.abs(W - w[None]))
    r_bal = jnp.max(jnp.abs(jnp.sum(grads, axis=0)))
    return r_cons, r_bal


def stationarity_residual_penalty(grads: jax.Array, W: jax.Array, w: jax.Array, lam, eta):
    """Residual of the KKT system (10) for the *penalty* problem (7).

    For each client the condition is
        0 in grad f_i(w_i) + lam*sgn(w_i - w) + eta*(w_i - w),
    i.e. with h_i := grad f_i(w_i) + eta*(w_i - w):
        |h_ij| <= lam               where (w_i - w)_j == 0
        h_ij == -lam*sign(w_i-w)_j  elsewhere.
    The server condition is 0 = sum_i (lam*pi_i + eta*(w_i - w)); taking the
    *minimal-norm* valid subgradient per coordinate we report the residual of
    the best attainable choice:
      per-coordinate client residual:
        d = w_i - w
        r_ij = max(|h_ij| - lam, 0)            if d_ij == 0
             = |h_ij + lam*sign(d_ij)|         otherwise
      server residual: with pi_ij forced to -h_ij/lam on zero coords when
        feasible, sum_i (lam*pi_i + eta*d_i) = sum_i (eta*d_i + clip stuff);
        we report || sum_i (-grad f_i(w_i)) ... || via the equivalent form
        || sum_i (grad f_i(w_i)) ||_inf after noting (10) implies
        sum_i grad f_i(w_i) = 0 at exact stationarity.
    Returns (r_client, r_server).
    """
    d = W - w[None]
    h = grads + eta * d
    zero = d == 0
    r_client = jnp.where(
        zero,
        jnp.maximum(jnp.abs(h) - lam, 0.0),
        jnp.abs(h + lam * jnp.sign(d)),
    )
    r_client = jnp.max(r_client)
    # Summing the first line of (10) over i and using the second line gives
    # sum_i grad f_i(w_i) = 0.
    r_server = jnp.max(jnp.abs(jnp.sum(grads, axis=0)))
    return r_client, r_server
