"""Partial-device participation schedules (paper Sec. IV.C, Setup VI.1).

Two samplers:

``sample_uniform``  -- the paper's experimental scheme: each round select
    |S| = rho*m clients uniformly without replacement (Remark VI.1 shows the
    coverage condition then holds w.h.p.).
``sample_coverage`` -- a deterministic-coverage scheme that *guarantees*
    Setup VI.1/(29): rounds are grouped into windows of s0; within a window a
    random permutation of [m] is dealt out round-robin, so every client is
    selected at least once per window (max selection gap < 2*s0, eq. (30)).

Both return a boolean mask of shape (m,) and are jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_uniform(key: jax.Array, m: int, rho: float) -> jax.Array:
    """|S| = max(1, round(rho*m)) clients uniformly without replacement."""
    n_sel = max(1, int(round(rho * m)))
    perm = jax.random.permutation(key, m)
    mask = jnp.zeros((m,), dtype=bool).at[perm[:n_sel]].set(True)
    return mask


def sample_coverage(key: jax.Array, m: int, rho: float, round_idx,
                    s0: int) -> jax.Array:
    """Coverage-guaranteed sampler satisfying Setup VI.1.

    Window w = round_idx // s0; position p = round_idx % s0. A permutation
    seeded by (key, w) is split into s0 contiguous chunks; round p gets chunk
    p (size >= ceil(m/s0)) padded up to |S| = rho*m with uniform extras.
    """
    n_sel = max(1, int(round(rho * m)))
    chunk = -(-m // s0)  # ceil
    if chunk > n_sel:
        raise ValueError(
            f"coverage sampler needs rho*m >= ceil(m/s0); got |S|={n_sel}, "
            f"ceil(m/s0)={chunk}"
        )
    window = round_idx // s0
    pos = round_idx % s0
    wkey = jax.random.fold_in(key, window)
    perm = jax.random.permutation(wkey, m)
    # mandatory chunk for this round (cyclic so the last chunk is full)
    start = (pos * chunk) % m
    idx = (start + jnp.arange(chunk)) % m
    mask = jnp.zeros((m,), dtype=bool).at[perm[idx]].set(True)
    # top up with uniform extras to reach n_sel
    ekey = jax.random.fold_in(wkey, pos + 1)
    scores = jax.random.uniform(ekey, (m,))
    scores = jnp.where(mask, 2.0, scores)  # already-chosen rank first
    order = jnp.argsort(-scores)
    mask = jnp.zeros((m,), dtype=bool).at[order[:n_sel]].set(True)
    return mask


def arrival_mask(candidates: jax.Array, arrivals: jax.Array,
                 deadline) -> jax.Array:
    """Deadline aggregation: keep candidates whose simulated arrival time is
    within ``deadline`` (seconds of simulated round time). Dropped stragglers
    carry state through via eq. (22) -- the round functions' masked update.

    candidates: (m,) bool; arrivals: (m,) float (inf = never arrives; an
    offline client is dropped even under an infinite deadline).
    ``deadline`` may be a scalar (one cutoff for the cohort) or an (m,)
    array of PER-CLIENT cutoffs -- the adaptive-deadline policy feeds the
    EWMA tracker's per-client budgets through here.
    """
    return candidates & jnp.isfinite(arrivals) & (arrivals <= deadline)


def staleness_weight(staleness, exp: float):
    """FedBuff-style down-weighting of stale async contributions.

    gamma = (1 + s)^(-exp) where s is the number of server model versions
    that elapsed between a client's dispatch and its aggregation. s = 0
    gives EXACTLY 1.0 (any exp), which the async server relies on to
    recover the synchronous trajectory bit-for-bit at buffer = cohort
    size; exp = 0 disables down-weighting. Works on scalars or arrays.
    """
    return (1.0 + staleness) ** (-exp)


def first_arrivals_mask(candidates: jax.Array, arrivals: jax.Array,
                        n_keep: int) -> jax.Array:
    """Over-selection: of the contacted ``candidates``, keep the ``n_keep``
    earliest finite arrivals (ties broken by client index, the argsort
    order). Fewer than n_keep finite arrivals => keep all that arrived.

    candidates: (m,) bool; arrivals: (m,) float. jit-safe.
    """
    t = jnp.where(candidates, arrivals, jnp.inf)
    order = jnp.argsort(t)                    # stable: ties by client index
    rank = jnp.argsort(order)                 # rank[i] = position of i
    return (rank < n_keep) & jnp.isfinite(t)


def max_selection_gap(masks: jax.Array) -> jax.Array:
    """Diagnostic for eq. (30): masks (T, m) -> max gap u - v between
    CONSECUTIVE selections of any client (first selection measured from
    the start, t = -1)."""
    T, m = masks.shape
    t = jnp.arange(T)[:, None]
    latest = jnp.where(masks, t, -1)
    latest = jax.lax.associative_scan(jnp.maximum, latest, axis=0)
    prev = jnp.concatenate(
        [jnp.full((1, m), -1, latest.dtype), latest[:-1]], axis=0)
    gap_at_sel = jnp.where(masks, t - prev, 0)
    return jnp.max(gap_at_sel)
