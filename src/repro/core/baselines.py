"""Benchmark algorithms from the paper: SFedAvg and SFedProx (Algorithm 3).

Both share the Algorithm-3 skeleton: mean aggregation over the *selected*
clients' noisy uploads (34), periodic communication at k in K, Laplace-noised
uploads. They differ in the client update:

  SFedAvg  (35): one full-gradient step per iteration,
                 at the broadcast point when k in K, else locally.
  SFedProx (36)+Alg.4: ell inexact GD steps on
                 f_i(w) + (mu/2)||w - w^{tau}||^2 per iteration.

Step size (38): gamma_i^k = 2 d_i / sqrt(2 k0 + floor(k/k0)); d_i is client
i's sample count (the 1/d_i inside f_i makes this scale sensible).

Noise for baselines: the paper states noise is added on upload but does not
print the baselines' scale. We use the same sensitivity surrogate with a
harmonically-decaying denominator, b_i = 2 * (2||g_i||_1) / (eps_dp * (tau+1))
-- decaying like 1/tau (vs FedEPM's geometric alpha^k via mu), which is the
usual choice for DP-SGD-style baselines and reproduces the paper's relative
SNR ordering. Documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dp
from repro.core.fedepm import Batch, LossFn, Params
from repro.core.participation import sample_uniform
from repro.core.treeutil import (
    tmap,
    tree_broadcast_clients,
    tree_where,
    tree_where_client,
)


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    m: int
    k0: int = 4
    rho: float = 0.5
    eps_dp: float = 0.1
    d_i: float = 1.0          # per-client sample count (for gamma, eq. (38))
    prox_mu: float = 1e-5     # SFedProx inner mu
    prox_ell: int = 3         # SFedProx inner GD steps (Alg. 4)
    gamma_scale: float = 2.0  # the "2 d_i" prefactor knob


class BaselineState(NamedTuple):
    w_tau: Params
    W: Params     # stacked (m, ...)
    Z: Params
    k: jax.Array
    key: jax.Array


class BaselineMetrics(NamedTuple):
    snr: jax.Array
    selected: jax.Array
    grad_l1: jax.Array


def init_state(key: jax.Array, params0: Params, cfg: BaselineConfig) -> BaselineState:
    W = tree_broadcast_clients(params0, cfg.m)
    return BaselineState(w_tau=params0, W=W, Z=W,
                         k=jnp.asarray(0, jnp.int32), key=key)


def default_round_mask(state: BaselineState, cfg: BaselineConfig) -> jax.Array:
    """The mask sfedavg_round/sfedprox_round would draw for ``state``.

    Mirrors the rounds' key split so the systems runtime (repro.sim) can
    supply arrival-aware masks that degrade gracefully to the internal
    selection (same key stream => bit-identical trajectories)."""
    _, k_sel, _ = jax.random.split(state.key, 3)
    return sample_uniform(k_sel, cfg.m, cfg.rho)


def _gamma(cfg: BaselineConfig, k):
    """Eq. (38): gamma = gamma_scale * d_i / sqrt(2 k0 + tau_k)."""
    tau = (k // cfg.k0).astype(jnp.float32)
    return cfg.gamma_scale * cfg.d_i / jnp.sqrt(2.0 * cfg.k0 + tau)


def _aggregate_selected_mean(Z, mask):
    """Eq. (34): mean over selected uploads."""
    cnt = jnp.maximum(jnp.sum(mask), 1).astype(jnp.float32)

    def agg(z):
        mm = mask.reshape((-1,) + (1,) * (z.ndim - 1))
        return jnp.sum(jnp.where(mm, z, 0.0), axis=0) / cnt

    return tmap(agg, Z)


def _noisy_upload(k_noise, W_upd, g, mask, cfg: BaselineConfig, k):
    grad_l1 = jax.vmap(lambda gi: dp.sensitivity_surrogate(gi) / 2.0)(g)
    if cfg.eps_dp <= 0:
        return W_upd, jnp.asarray(jnp.inf), grad_l1
    tau = (k // cfg.k0).astype(jnp.float32)
    scale = 2.0 * (2.0 * grad_l1) / (cfg.eps_dp * (tau + 1.0))
    keys = jax.random.split(k_noise, cfg.m)
    noise = jax.vmap(lambda kk, wi, s: dp.laplace_tree(kk, wi, s))(
        keys, W_upd, scale)
    Z_upd = tmap(jnp.add, W_upd, noise)
    snr_i = jax.vmap(dp.snr_db10)(W_upd, noise)
    snr = jnp.min(jnp.where(mask, snr_i, jnp.inf))
    return Z_upd, snr, grad_l1


def sfedavg_round(state: BaselineState, batches: Batch, loss_fn: LossFn,
                  cfg: BaselineConfig, mask: jax.Array | None = None,
                  agg_mask: jax.Array | None = None):
    """k0 iterations of SFedAvg (Algorithm 3 + eq. (35)).

    ``mask`` optionally supplies the participation set externally (see
    fedepm.fedepm_round); the key split is unchanged either way.
    ``agg_mask`` optionally decouples eq. (34)'s aggregation support from
    the participation set: the broadcast point averages the Z rows of
    ``agg_mask`` (default: ``mask``, the paper's selected-mean) while only
    ``mask`` clients compute and upload. The async client-level scheduler
    (repro.sim) uses this to anchor a sub-cohort dispatch group's broadcast
    on its whole cohort, mirroring how FedEPM's ENS aggregates every
    client's latest upload."""
    key, k_sel, k_noise = jax.random.split(state.key, 3)
    if mask is None:
        mask = sample_uniform(k_sel, cfg.m, cfg.rho)
    w_new = _aggregate_selected_mean(
        state.Z, mask if agg_mask is None else agg_mask)
    grad_fn = jax.grad(loss_fn)

    def client(wi, b):
        # t = 0 is the communication step: start from the broadcast point.
        def step(w, t):
            k = state.k + t
            gamma = _gamma(cfg, k)
            base = tree_where(t == 0, w_new, w)
            gi = grad_fn(base, b)
            w = tmap(lambda a, g_: a - gamma * g_, base, gi)
            return w, None

        w_final, _ = jax.lax.scan(step, wi, jnp.arange(cfg.k0, dtype=jnp.int32))
        g_last = grad_fn(w_final, b)
        return w_final, g_last

    W_upd, g = jax.vmap(client)(state.W, batches)
    W_next = tree_where_client(mask, W_upd, state.W)
    Z_upd, snr, grad_l1 = _noisy_upload(k_noise, W_upd, g, mask, cfg, state.k)
    Z_next = tree_where_client(mask, Z_upd, state.Z)
    new_state = BaselineState(w_tau=w_new, W=W_next, Z=Z_next,
                              k=state.k + jnp.asarray(cfg.k0, jnp.int32),
                              key=key)
    return new_state, BaselineMetrics(snr=snr, selected=mask, grad_l1=grad_l1)


def sfedprox_round(state: BaselineState, batches: Batch, loss_fn: LossFn,
                   cfg: BaselineConfig, mask: jax.Array | None = None,
                   agg_mask: jax.Array | None = None):
    """k0 iterations of SFedProx (Algorithm 3 + (36), inner solver Alg. 4).

    ``mask`` optionally supplies the participation set externally (see
    fedepm.fedepm_round); the key split is unchanged either way.
    ``agg_mask`` decouples eq. (34)'s aggregation support from the
    participation set exactly as in ``sfedavg_round``."""
    key, k_sel, k_noise = jax.random.split(state.key, 3)
    if mask is None:
        mask = sample_uniform(k_sel, cfg.m, cfg.rho)
    w_new = _aggregate_selected_mean(
        state.Z, mask if agg_mask is None else agg_mask)
    grad_fn = jax.grad(loss_fn)

    def client(wi, b):
        def outer(w, t):
            k = state.k + t
            gamma = _gamma(cfg, k)
            # Alg. 4: v^1 = w^{tau} if k in K (t==0) else w_i^k
            v = tree_where(t == 0, w_new, w)

            def inner(vt, _):
                gi = grad_fn(vt, b)
                vt = tmap(
                    lambda vv, g_, wt: vv - gamma * (g_ + cfg.prox_mu * (vv - wt)),
                    vt, gi, w_new)
                return vt, None

            v, _ = jax.lax.scan(inner, v, jnp.arange(cfg.prox_ell))
            return v, None

        w_final, _ = jax.lax.scan(outer, wi, jnp.arange(cfg.k0, dtype=jnp.int32))
        g_last = grad_fn(w_final, b)
        return w_final, g_last

    W_upd, g = jax.vmap(client)(state.W, batches)
    W_next = tree_where_client(mask, W_upd, state.W)
    Z_upd, snr, grad_l1 = _noisy_upload(k_noise, W_upd, g, mask, cfg, state.k)
    Z_next = tree_where_client(mask, Z_upd, state.Z)
    new_state = BaselineState(w_tau=w_new, W=W_next, Z=Z_next,
                              k=state.k + jnp.asarray(cfg.k0, jnp.int32),
                              key=key)
    return new_state, BaselineMetrics(snr=snr, selected=mask, grad_l1=grad_l1)


def scan_round(state: BaselineState, xs, batches: Batch, loss_fn: LossFn,
               cfg: BaselineConfig, round_fn):
    """Scan-compatible round body: ``(carry=state, x=(mask, abandoned))``.

    ``round_fn`` is ``sfedavg_round`` or ``sfedprox_round``. Semantics
    match ``core.fedepm.scan_round``: an abandoned round carries the state
    (and key) through untouched; metrics are emitted shape-stably and must
    be ignored for abandoned rounds. The fused engine (repro.sim.engine)
    scans this body directly in its codec-free path.
    """
    mask, abandoned = xs
    new_state, metrics = round_fn(state, batches, loss_fn, cfg, mask=mask)
    return tree_where(abandoned, state, new_state), metrics


def make_scan_rounds(batches, loss_fn, cfg, round_fn, *, donate: bool = True):
    """Compile K baseline rounds into ONE on-device ``jax.lax.scan``.

    ``round_fn`` is ``sfedavg_round`` or ``sfedprox_round``. Semantics match
    ``core.fedepm.make_scan_rounds``: ``run(state, masks, abandoned)`` scans
    a precomputed (K, m) participation-mask stream, abandoned rounds carry
    the state (and key) through untouched, per-round metrics stack
    on-device, and with ``donate`` the input state's buffers are reused for
    the output instead of copied.
    """
    def run(state, masks, abandoned):
        return jax.lax.scan(
            lambda c, x: scan_round(c, x, batches, loss_fn, cfg, round_fn),
            state, (masks, abandoned))

    return jax.jit(run, donate_argnums=(0,) if donate else ())
