"""FedEPM -- the paper's Algorithm 2, as a composable JAX module.

The round function is pure and jit-safe; it operates on *stacked* client
parameter pytrees (leading axis m), so it can run

  * single-host (vmap over clients) for the paper-scale reproduction, or
  * multi-pod, with the client axis sharded over mesh axes ("pod","data")
    and feature axes over "model" (see repro/launch and core/distributed).

Faithfulness notes
------------------
* Iteration layout follows Algorithm 2 exactly: communication happens at
  k in K = {0, k0, 2k0, ...}. One call to ``round`` advances k0 iterations:
  aggregate current uploads Z via ENS (19), broadcast w^{tau+1}, compute the
  round gradient g_i = grad f_i(w^{tau+1}) once (18), run k0 inner
  closed-form prox iterations (20) with growing mu_{i,k+1}, then DP-noise and
  upload z_i (21). Non-selected clients carry state through, eq. (22).
* mu_{i,k+1} = mu_{i,0} (1 + c_i ||w_i^k - w^{tau+1}||^2) alpha_i^{k+1} is
  recomputed from the *current* iterate at every inner step, as in (20).
* The initial uploads z_i^0 = w_i^0 + eps_i^0: since w_i^0 is data-independent
  (a public constant or PRNG init), no DP noise is required at k=0; we expose
  ``init_noise_scale`` (default 0) to match the paper's optional eps_i^0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dp
from repro.core.participation import sample_coverage, sample_uniform
from repro.core.treeutil import (
    tmap,
    tree_broadcast_clients,
    tree_sq_norm,
    tree_where,
    tree_where_client,
)
from repro.kernels.ens import ops as ens_ops
from repro.kernels.prox import ops as prox_ops

Params = Any
Batch = Any
LossFn = Callable[[Params, Batch], jax.Array]


@dataclasses.dataclass(frozen=True)
class FedEPMConfig:
    m: int                       # number of clients
    k0: int = 4                  # iterations between communications
    lam: float = 1e-5            # elastic-net l1 weight  (lambda)
    eta: float = 2e-5            # elastic-net l2 weight  (eta); paper: lam = eta/2
    mu0: float = 0.05            # mu_{i,0}
    c: float = 1e-8              # c_i
    alpha: float = 1.001         # alpha_i > 1
    rho: float = 0.5             # participation fraction
    eps_dp: float = 0.1          # DP epsilon; <= 0 disables noise
    s0: int = 10                 # coverage window (Setup VI.1)
    sampler: str = "uniform"     # "uniform" | "coverage" | "full"
    ens_impl: str = "ref"        # "ref" | "pallas" | "oracle"
    prox_impl: str = "ref"       # "ref" | "pallas"
    init_noise_scale: float = 0.0
    # beyond-paper hardening: cap the sensitivity surrogate Delta_hat =
    # 2||g||_1 (eq. (39) is calibrated for n=14; at LM scale ||g||_1
    # grows with the parameter count and the un-capped noise overflows
    # fp32 -> NaN). 0 disables. With clipping, eps-DP holds for the
    # CLIPPED mechanism (dp.clip_tree_l1 enforces the bound).
    sensitivity_clip: float = 0.0

    @staticmethod
    def paper_defaults(m: int, rho: float = 0.5, k0: int = 12,
                       eps_dp: float = 0.1, **kw) -> "FedEPMConfig":
        """The paper's Sec. VII.B settings: eta=(0.02m+1)(rho+0.1)1e-5, lam=eta/2."""
        eta = (0.02 * m + 1.0) * (rho + 0.1) * 1e-5
        return FedEPMConfig(m=m, k0=k0, lam=eta / 2.0, eta=eta, rho=rho,
                            eps_dp=eps_dp, **kw)


class FedEPMState(NamedTuple):
    w_tau: Params    # last broadcast point w^{tau_k}
    W: Params        # stacked client iterates, leading axis m
    Z: Params        # stacked (noisy) uploads, leading axis m
    k: jax.Array     # global iteration counter (int32, multiple of k0)
    key: jax.Array


class RoundMetrics(NamedTuple):
    mu_last: jax.Array       # (m,) final mu_{i,k+1} of the round
    grad_l1: jax.Array       # (m,) ||g_i||_1
    snr: jax.Array           # paper SNR: min_i log10(||w_i||/||eps_i||)
    drift: jax.Array         # ||w^{tau+1} - w^{tau}||^2
    selected: jax.Array      # (m,) participation mask
    noise_scale: jax.Array   # (m,) Laplace scale b_i used this round


def init_state(key: jax.Array, params0: Params, cfg: FedEPMConfig) -> FedEPMState:
    """All clients start from the same w_i^0 = params0 (paper: w_i^0 = 0)."""
    W = tree_broadcast_clients(params0, cfg.m)
    if cfg.init_noise_scale > 0:
        key, sub = jax.random.split(key)
        noise = dp.laplace_tree(sub, W, cfg.init_noise_scale)
        Z = tmap(jnp.add, W, noise)
    else:
        Z = W
    return FedEPMState(w_tau=params0, W=W, Z=Z,
                       k=jnp.asarray(0, jnp.int32), key=key)


def _select(key, cfg: FedEPMConfig, round_idx):
    if cfg.sampler == "uniform":
        return sample_uniform(key, cfg.m, cfg.rho)
    if cfg.sampler == "coverage":
        return sample_coverage(key, cfg.m, cfg.rho, round_idx, cfg.s0)
    if cfg.sampler == "full":
        return jnp.ones((cfg.m,), bool)
    raise ValueError(f"unknown sampler {cfg.sampler!r}")


def default_round_mask(state: FedEPMState, cfg: FedEPMConfig) -> jax.Array:
    """The mask ``fedepm_round`` would draw for ``state`` this round.

    Replicates the round's key split so an external scheduler (repro.sim)
    can reproduce the internal selection exactly: supplying this mask via
    ``fedepm_round(..., mask=...)`` yields bit-identical trajectories.
    """
    _, k_sel, _ = jax.random.split(state.key, 3)
    return _select(k_sel, cfg, state.k // cfg.k0)


def _client_inner(wi, w_new, gi, k_start, cfg: FedEPMConfig):
    """k0 closed-form prox iterations (20) for ONE client. Returns (wi, mu_last)."""

    def step(carry, t):
        w = carry
        k = k_start + t  # current global iteration index k
        mu = cfg.mu0 * (1.0 + cfg.c * tree_sq_norm(tmap(jnp.subtract, w, w_new))) \
            * jnp.power(cfg.alpha, (k + 1).astype(jnp.float32))
        w = prox_ops.prox_update_tree(w, w_new, gi, mu, cfg.lam, cfg.eta,
                                      impl=cfg.prox_impl)
        return w, mu

    wi_final, mus = jax.lax.scan(step, wi, jnp.arange(cfg.k0, dtype=jnp.int32))
    return wi_final, mus[-1]


def fedepm_round(state: FedEPMState, batches: Batch, loss_fn: LossFn,
                 cfg: FedEPMConfig, mask: jax.Array | None = None):
    """One communication round = k0 iterations of Algorithm 2.

    ``batches`` is a pytree with a leading client axis m (each client's local
    data or minibatch). Returns (new_state, RoundMetrics).

    ``mask`` optionally supplies the participation set externally (shape (m,)
    bool) -- used by the systems runtime (repro.sim) where selection is a
    function of simulated arrival times. The key split is unchanged whether
    or not a mask is given, so passing ``default_round_mask(state, cfg)``
    reproduces the internal selection bit-for-bit. Non-selected clients
    carry state through either way, eq. (22).
    """
    key, k_sel, k_noise = jax.random.split(state.key, 3)
    round_idx = state.k // cfg.k0
    if mask is None:
        mask = _select(k_sel, cfg, round_idx)

    # ---- server: aggregate uploads via ENS (19) and broadcast ----
    w_new = ens_ops.ens_tree(state.Z, cfg.lam, cfg.eta, impl=cfg.ens_impl)

    # ---- clients: one gradient per round at the broadcast point (18) ----
    grad_fn = jax.grad(loss_fn)
    g = jax.vmap(lambda b: grad_fn(w_new, b))(batches)  # stacked (m, ...)

    # ---- k0 inner prox iterations per client (20) ----
    W_upd, mu_last = jax.vmap(
        lambda wi, gi: _client_inner(wi, w_new, gi, state.k, cfg)
    )(state.W, g)
    W_next = tree_where_client(mask, W_upd, state.W)

    # ---- DP-noised upload (21)/(39) ----
    grad_l1 = jax.vmap(lambda gi: dp.sensitivity_surrogate(gi) / 2.0)(g)
    delta_hat = 2.0 * grad_l1
    if cfg.sensitivity_clip > 0:
        delta_hat = jnp.minimum(delta_hat, cfg.sensitivity_clip)
    if cfg.eps_dp > 0:
        scale = dp.fedepm_noise_scale(delta_hat, cfg.eps_dp, mu_last)  # (m,)
        keys = jax.random.split(k_noise, cfg.m)
        noise = jax.vmap(lambda kk, wi, s: dp.laplace_tree(kk, wi, s))(
            keys, W_upd, scale)
        Z_upd = tmap(jnp.add, W_upd, noise)
        snr_i = jax.vmap(dp.snr_db10)(W_upd, noise)  # (m,)
        snr = jnp.min(jnp.where(mask, snr_i, jnp.inf))
    else:
        scale = jnp.zeros((cfg.m,))
        Z_upd = W_upd
        snr = jnp.asarray(jnp.inf)
    Z_next = tree_where_client(mask, Z_upd, state.Z)

    drift = tree_sq_norm(tmap(jnp.subtract, w_new, state.w_tau))
    new_state = FedEPMState(
        w_tau=w_new, W=W_next, Z=Z_next,
        k=state.k + jnp.asarray(cfg.k0, jnp.int32), key=key)
    metrics = RoundMetrics(mu_last=mu_last, grad_l1=grad_l1, snr=snr,
                           drift=drift, selected=mask, noise_scale=scale)
    return new_state, metrics


def scan_round(state: FedEPMState, xs, batches: Batch, loss_fn: LossFn,
               cfg: FedEPMConfig):
    """Scan-compatible round body: ``(carry=state, x=(mask, abandoned))``.

    One step of ``jax.lax.scan`` over a precomputed participation-mask
    stream (repro.sim.engine). ``abandoned`` is a scalar bool: an abandoned
    round (every contacted client offline) leaves the carried state --
    including the PRNG key -- untouched, exactly like the eager simulation
    path that never calls the round function. Metrics are still emitted
    (shape-stable for stacking) and must be ignored by the caller for
    abandoned rounds.
    """
    mask, abandoned = xs
    new_state, metrics = fedepm_round(state, batches, loss_fn, cfg,
                                      mask=mask)
    return tree_where(abandoned, state, new_state), metrics


def make_scan_rounds(batches: Batch, loss_fn: LossFn, cfg: FedEPMConfig,
                     *, donate: bool = True):
    """Compile K rounds into ONE on-device ``jax.lax.scan``.

    Returns ``run(state, masks, abandoned) -> (state, stacked RoundMetrics)``
    with ``masks`` (K, m) bool and ``abandoned`` (K,) bool. With ``donate``
    the input state's buffers are donated to the XLA call and reused for the
    output state instead of being copied -- the caller must not touch the
    passed-in state afterwards. Per-round metrics are stacked on-device and
    transferred once, not round by round.
    """
    def run(state, masks, abandoned):
        return jax.lax.scan(
            lambda c, x: scan_round(c, x, batches, loss_fn, cfg),
            state, (masks, abandoned))

    return jax.jit(run, donate_argnums=(0,) if donate else ())


def global_objective(loss_fn: LossFn, w: Params, batches: Batch) -> jax.Array:
    """f(w) = sum_i f_i(w) over the stacked client batches (paper eq. (1))."""
    return jnp.sum(jax.vmap(lambda b: loss_fn(w, b))(batches))


def global_grad_sq_norm(loss_fn: LossFn, w: Params, batches: Batch) -> jax.Array:
    """||grad f(w)||^2 for the paper's termination rule."""
    g = jax.grad(lambda p: global_objective(loss_fn, p, batches))(w)
    return tree_sq_norm(g)


def lyapunov(loss_fn: LossFn, state: FedEPMState, batches: Batch,
             cfg: FedEPMConfig) -> jax.Array:
    """The descent quantity F(w^{tau_k}, W^k) of (7) (noise-free part of L^k).

    Used by tests/benchmarks to check Lemma VI.1's monotone-descent claim.
    """
    fvals = jax.vmap(lambda wi, b: loss_fn(wi, b))(state.W, batches)
    pen = jax.vmap(
        lambda wi: cfg.lam * sum(
            jnp.sum(jnp.abs(a - b)) for a, b in zip(
                jax.tree_util.tree_leaves(wi),
                jax.tree_util.tree_leaves(state.w_tau))
        ) + 0.5 * cfg.eta * tree_sq_norm(
            tmap(jnp.subtract, wi, state.w_tau))
    )(state.W)
    return jnp.sum(fvals + pen)
