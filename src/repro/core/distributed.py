"""Distributed FedEPM: the paper's Algorithm 2 as a first-class pjit
optimizer for large models on a TPU mesh (DESIGN.md §2).

Two execution strategies for the same algorithm (tests assert they agree
with the single-host reference to float tolerance):

**spatial** -- clients ARE device groups. The stacked client state
  (W, Z, g) carries a leading m axis sharded over the client mesh axes
  (("pod","data") multi-pod, ("data",) single-pod); feature axes shard over
  "model" (tensor parallel inside each client). Gradients for all clients
  run concurrently (vmap over the client axis). The server step (ENS, eq.
  (19)) is the only cross-client communication:
    * ``ens="gather"``  -- sort along the m axis; XLA all-gathers the
      client-sharded axis (paper-faithful star transport: everyone's z to
      one place). O(m x n) bytes received per device group.
    * ``ens="a2a"``     -- beyond-paper: shard_map all_to_all redistributes
      coordinates so each device group owns n/m coordinates of ALL clients,
      runs ENS locally, and all-gathers the n/m-sized aggregate. O(n) bytes
      per device -- an m/2-fold collective saving (EXPERIMENTS.md §Perf).

**temporal** -- clients are time-multiplexed over the whole pod. Client
  state is coordinate-sharded over ("data","model") jointly (ZeRO-style;
  each leaf keeps its model sharding and gains an fsdp axis), the m axis is
  local, and clients take turns: a lax.scan computes grad f_i(w^tau) with
  the full mesh (batch data-parallel, params FSDP), then runs the k0
  elementwise prox steps (20). ENS becomes COLLECTIVE-FREE (every device
  holds all m values for its coordinates); the only collectives are the
  FSDP all-gathers/reduce-scatters of the gradient step. This is what lets
  a 141B mixtral-8x22b run FedEPM with m=4 on one v5e-256 pod.

The algorithmic semantics (selection, mu schedule, soft-threshold update,
DP noise scale, eq. (22) carry-through) are identical across strategies and
match core/fedepm.fedepm_round.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dp
from repro.core.fedepm import (
    FedEPMConfig,
    FedEPMState,
    RoundMetrics,
    _client_inner,
    _select,
)
from repro.core.treeutil import tmap, tree_sq_norm, tree_where_client
from repro.kernels.ens import ops as ens_ops
from repro.models.logical import param_logical
from repro.sharding import specs as sh


@dataclasses.dataclass(frozen=True)
class DistConfig:
    mode: str = "spatial"            # "spatial" | "temporal"
    ens: str = "gather"              # "gather" | "a2a" (spatial only)
    client_axes: tuple = ("data",)   # mesh axes carrying the client axis
    fsdp_axes: tuple = ("data",)     # temporal: extra param sharding axes
    state_dtype: Any = None          # W/Z storage dtype (None = param dtype)
    remat: bool = True               # rematerialise the per-client loss
    microbatch: int = 1              # temporal: grad-accumulation chunks


# ---------------------------------------------------------------------------
# spec derivation
# ---------------------------------------------------------------------------

def param_specs(cfg_arch, abstract_params, mesh: Mesh, dist: DistConfig):
    """PartitionSpecs for ONE model copy (w_tau / serving params)."""
    logical = param_logical(cfg_arch)
    fsdp = dist.fsdp_axes if dist.mode == "temporal" else ()
    return sh.tree_specs(logical, abstract_params, mesh, fsdp_axes=fsdp)


def client_state_specs(cfg_arch, abstract_params, mesh: Mesh,
                       dist: DistConfig):
    """Specs for the stacked (m, ...) client state W/Z/g."""
    logical = param_logical(cfg_arch)
    if dist.mode == "spatial":
        return sh.tree_specs(logical, abstract_params, mesh,
                             prepend=(dist.client_axes if len(
                                 dist.client_axes) > 1 else
                                 dist.client_axes[0],))
    # temporal: m local; feature dims model+fsdp sharded
    return sh.tree_specs(logical, abstract_params, mesh,
                         fsdp_axes=dist.fsdp_axes, prepend=(None,))


def state_specs(cfg_arch, abstract_state: FedEPMState, mesh: Mesh,
                dist: DistConfig) -> FedEPMState:
    """FedEPMState pytree of PartitionSpecs (w_tau, W, Z, k, key).

    ``abstract_state.W/Z`` carry the stacked (m, ...) leaves so the
    divisibility checks in specs.leaf_spec see the true core shapes.
    """
    return FedEPMState(
        w_tau=param_specs(cfg_arch, abstract_state.w_tau, mesh, dist),
        W=client_state_specs(cfg_arch, abstract_state.W, mesh, dist),
        Z=client_state_specs(cfg_arch, abstract_state.Z, mesh, dist),
        k=P(),
        key=P(),
    )


def batch_specs(batch_tree, dist: DistConfig) -> Any:
    """Stacked client batches (m, b, ...): spatial shards m over client
    axes; temporal keeps m local and shards the inner batch dim."""
    ca = dist.client_axes if len(dist.client_axes) > 1 else \
        dist.client_axes[0]
    if dist.mode == "spatial":
        return tmap(lambda x: P(ca, *([None] * (x.ndim - 1))), batch_tree)
    return tmap(lambda x: P(None, ca, *([None] * (x.ndim - 2))), batch_tree)


# ---------------------------------------------------------------------------
# distributed ENS
# ---------------------------------------------------------------------------

def ens_gather(Z, lam, eta, local_impl: str = "ref"):
    """Baseline transport: sort along the (client-sharded) m axis. Under
    pjit, XLA lowers this to an all-gather of the m axis per device group
    -- the faithful analogue of every client uploading z_i to the server.
    Large leaves are chunked over their layer axis inside ens_tree so the
    (2m+1)-stacked sort buffers stay bounded (see kernels/ens/ops.py).
    """
    return ens_ops.ens_tree(Z, lam, eta, impl=local_impl)


def ens_a2a(Z, lam, eta, mesh: Mesh, zspecs, wspecs, client_axes,
            local_impl: str = "ref"):
    """Coordinate-sharded ENS via shard_map all_to_all (beyond-paper).

    Per leaf (m, ...): each client group holds its own z_i; all_to_all
    swaps the client axis for a coordinate slice, local ENS reduces m -> 1,
    all_gather rebuilds the aggregate. Per-device traffic drops from
    O(m*n_loc) (gather) to O(2*n_loc).
    """
    axis = client_axes if len(client_axes) > 1 else client_axes[0]
    flat_axes = tuple(client_axes)
    groups = int(np.prod([mesh.shape[a] for a in flat_axes]))

    def per_leaf(z, zspec, wspec):
        def local(zl):
            # zl: (m_loc, ...) local block; m_loc = m // groups
            m_loc = zl.shape[0]
            F = int(np.prod(zl.shape[1:]))
            flat = zl.reshape(m_loc, F)
            pad = (-F) % groups
            if pad:
                flat = jnp.pad(flat, ((0, 0), (0, pad)))
            Fp = flat.shape[1]
            # one hop per client mesh axis: split coords, concat clients
            for ax in flat_axes:
                flat = lax.all_to_all(
                    flat.reshape(m_loc, -1), ax, split_axis=1,
                    concat_axis=0, tiled=True)
                m_loc = flat.shape[0]
            # flat: (m, Fp/groups) -- all clients, our coordinate slice
            w_loc = ens_ops.ens(flat, lam, eta, impl=local_impl)  # (Fp/g,)
            for ax in reversed(flat_axes):
                w_loc = lax.all_gather(w_loc, ax, axis=0, tiled=True)
            w = w_loc[:F] if pad else w_loc
            return w.reshape(zl.shape[1:])  # local feature block shape

        return shard_map(
            local, mesh=mesh,
            in_specs=(zspec,), out_specs=wspec,
            check_vma=False)(z)

    return jax.tree_util.tree_map(per_leaf, Z, zspecs, wspecs)


# ---------------------------------------------------------------------------
# rounds
# ---------------------------------------------------------------------------

def _loss_and_grad(loss_fn, remat: bool):
    f = jax.remat(loss_fn) if remat else loss_fn
    return jax.grad(f)


def spatial_round(state: FedEPMState, batches, loss_fn, cfg: FedEPMConfig,
                  mesh: Mesh, dist: DistConfig, sspecs: FedEPMState,
                  arch_cfg):
    """One communication round, clients = device groups (vmap over m)."""
    key, k_sel, k_noise = jax.random.split(state.key, 3)
    round_idx = state.k // cfg.k0
    mask = _select(k_sel, cfg, round_idx)

    # ---- server: ENS aggregation (19) ----
    if dist.ens == "a2a":
        w_new = ens_a2a(state.Z, cfg.lam, cfg.eta, mesh, sspecs.Z,
                        sspecs.w_tau, dist.client_axes,
                        local_impl=cfg.ens_impl if cfg.ens_impl != "oracle"
                        else "ref")
        w_new = tmap(lambda x, z: x.astype(z.dtype), w_new, state.Z)
    else:
        w_new = ens_gather(state.Z, cfg.lam, cfg.eta,
                           local_impl="ref")
    w_new = sh.constrain_tree(w_new, sspecs.w_tau, mesh)
    w_comp = tmap(lambda x: x.astype(arch_cfg.dtype)
                  if x.dtype == jnp.bfloat16 else x, w_new)

    # ---- clients: one gradient per round at w^{tau+1} (18), in parallel --
    # spmd_axis_name pins the vmapped client axis to the client mesh axes,
    # so every per-client intermediate (activations, grads) stays sharded
    # over ("pod","data") instead of silently replicating.
    san = dist.client_axes if len(dist.client_axes) > 1 \
        else dist.client_axes[0]
    grad_fn = _loss_and_grad(loss_fn, dist.remat)
    g = jax.vmap(lambda b: grad_fn(w_comp, b), spmd_axis_name=san)(batches)
    g = sh.constrain_tree(g, sspecs.W, mesh)

    # ---- k0 inner prox iterations (20), vmapped over clients ----
    W_upd, mu_last = jax.vmap(
        lambda wi, gi: _client_inner(wi, w_new, gi, state.k, cfg),
        spmd_axis_name=san,
    )(state.W, g)
    sdt = dist.state_dtype
    if sdt is not None:
        W_upd = tmap(lambda x: x.astype(sdt), W_upd)
    W_upd = sh.constrain_tree(W_upd, sspecs.W, mesh)
    W_next = tree_where_client(mask, W_upd, state.W)

    # ---- DP-noised upload (21)/(39) ----
    grad_l1 = jax.vmap(lambda gi: dp.sensitivity_surrogate(gi) / 2.0)(g)
    delta_hat = 2.0 * grad_l1
    if cfg.sensitivity_clip > 0:
        delta_hat = jnp.minimum(delta_hat, cfg.sensitivity_clip)
    if cfg.eps_dp > 0:
        scale = dp.fedepm_noise_scale(delta_hat, cfg.eps_dp, mu_last)
        keys = jax.random.split(k_noise, cfg.m)
        noise = jax.vmap(lambda kk, wi, s: dp.laplace_tree(kk, wi, s),
                         spmd_axis_name=san)(keys, W_upd, scale)
        Z_upd = tmap(jnp.add, W_upd, noise)
        snr_i = jax.vmap(dp.snr_db10)(W_upd, noise)
        snr = jnp.min(jnp.where(mask, snr_i, jnp.inf))
    else:
        scale = jnp.zeros((cfg.m,))
        Z_upd = W_upd
        snr = jnp.asarray(jnp.inf)
    Z_upd = sh.constrain_tree(Z_upd, sspecs.Z, mesh)
    Z_next = tree_where_client(mask, Z_upd, state.Z)

    drift = tree_sq_norm(tmap(lambda a, b: a - b, w_new, state.w_tau))
    new_state = FedEPMState(
        w_tau=w_new, W=W_next, Z=Z_next,
        k=state.k + jnp.asarray(cfg.k0, jnp.int32), key=key)
    metrics = RoundMetrics(mu_last=mu_last, grad_l1=grad_l1, snr=snr,
                           drift=drift, selected=mask, noise_scale=scale)
    return new_state, metrics


def temporal_round(state: FedEPMState, batches, loss_fn, cfg: FedEPMConfig,
                   mesh: Mesh, dist: DistConfig, sspecs: FedEPMState,
                   arch_cfg):
    """One communication round, clients time-multiplexed (scan over m).

    Identical math to spatial_round; the m axis is local, so ENS is pure
    per-device compute and peak memory holds ONE client's activations.
    """
    key, k_sel, k_noise = jax.random.split(state.key, 3)
    round_idx = state.k // cfg.k0
    mask = _select(k_sel, cfg, round_idx)

    # ---- server: ENS is local (m unsharded on every device) ----
    w_new = ens_gather(state.Z, cfg.lam, cfg.eta, local_impl="ref")
    w_new = sh.constrain_tree(w_new, sspecs.w_tau, mesh)
    w_comp = tmap(lambda x: x.astype(arch_cfg.dtype)
                  if x.dtype == jnp.bfloat16 else x, w_new)

    grad_fn = _loss_and_grad(loss_fn, dist.remat)
    keys = jax.random.split(k_noise, cfg.m)
    sdt = dist.state_dtype

    def per_client(carry, xs):
        wi, zi, bi, mi, kk, kidx = xs
        # one gradient per round at the broadcast point (18); optionally
        # accumulated over microbatches (fp32 accumulator) so one client's
        # activation footprint is 1/microbatch of its shard
        if dist.microbatch > 1:
            nmb = dist.microbatch

            def split(x):
                return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])

            def mb_step(acc, bmb):
                gmb = grad_fn(w_comp, bmb)
                gmb = sh.constrain_tree(gmb, sspecs.w_tau, mesh)
                return tmap(lambda a, g: a + g.astype(jnp.float32),
                            acc, gmb), None

            acc0 = tmap(lambda x: jnp.zeros(x.shape, jnp.float32), w_comp)
            acc0 = sh.constrain_tree(acc0, sspecs.w_tau, mesh)
            gacc, _ = lax.scan(mb_step, acc0, tmap(split, bi))
            gi = tmap(lambda x: (x / nmb), gacc)
        else:
            gi = grad_fn(w_comp, bi)
        gi = sh.constrain_tree(gi, sspecs.w_tau, mesh)
        wi_upd, mu_last = _client_inner(wi, w_new, gi, state.k, cfg)
        if sdt is not None:
            wi_upd = tmap(lambda x: x.astype(sdt), wi_upd)
        grad_l1 = dp.sensitivity_surrogate(gi) / 2.0
        delta_hat = 2.0 * grad_l1
        if cfg.sensitivity_clip > 0:
            delta_hat = jnp.minimum(delta_hat, cfg.sensitivity_clip)
        if cfg.eps_dp > 0:
            scale = dp.fedepm_noise_scale(delta_hat, cfg.eps_dp, mu_last)
            noise = dp.laplace_tree(kk, wi_upd, scale)
            zi_upd = tmap(jnp.add, wi_upd, noise)
            snr_i = dp.snr_db10(wi_upd, noise)
        else:
            scale = jnp.asarray(0.0)
            zi_upd = wi_upd
            snr_i = jnp.asarray(jnp.inf)
        # eq. (22): carry state through for non-selected clients
        wi_next = tmap(lambda a, b: jnp.where(mi, a, b), wi_upd, wi)
        zi_next = tmap(lambda a, b: jnp.where(mi, a, b), zi_upd, zi)
        return carry, (wi_next, zi_next,
                       (mu_last, grad_l1, jnp.where(mi, snr_i, jnp.inf),
                        scale))

    _, (W_next, Z_next, (mu_last, grad_l1, snr_i, scale)) = lax.scan(
        per_client, None,
        (state.W, state.Z, batches, mask, keys,
         jnp.arange(cfg.m, dtype=jnp.int32)))
    W_next = sh.constrain_tree(W_next, sspecs.W, mesh)
    Z_next = sh.constrain_tree(Z_next, sspecs.Z, mesh)

    snr = jnp.min(snr_i)
    drift = tree_sq_norm(tmap(lambda a, b: a - b, w_new, state.w_tau))
    new_state = FedEPMState(
        w_tau=w_new, W=W_next, Z=Z_next,
        k=state.k + jnp.asarray(cfg.k0, jnp.int32), key=key)
    metrics = RoundMetrics(mu_last=mu_last, grad_l1=grad_l1, snr=snr,
                           drift=drift, selected=mask, noise_scale=scale)
    return new_state, metrics


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

def build_fedepm(model, loss_fn, fed_cfg: FedEPMConfig, mesh: Mesh,
                 dist: DistConfig):
    """Returns (init_fn, step_fn, sspecs_fn).

    init_fn(key)            -> FedEPMState (all clients at the same w0)
    step_fn(state, batches) -> (state, metrics)   [to be jit'd by caller
                               with in/out shardings from sspecs_fn]
    sspecs_fn(abstract_state) -> FedEPMState of PartitionSpecs
    """
    arch_cfg = model.cfg

    def init_fn(key):
        k1, k2 = jax.random.split(key)
        params0 = model.init(k1)
        sdt = dist.state_dtype
        if sdt is not None:
            params_state = tmap(lambda x: x.astype(sdt), params0)
        else:
            params_state = params0
        W = tmap(lambda x: jnp.broadcast_to(x[None],
                                            (fed_cfg.m,) + x.shape),
                 params_state)
        # w_tau lives in the same dtype as the uploads (ENS output dtype),
        # so the state signature is round-invariant (donation-safe)
        return FedEPMState(w_tau=params_state, W=W, Z=W,
                           k=jnp.asarray(0, jnp.int32), key=k2)

    def sspecs_fn(abstract_state):
        return state_specs(arch_cfg, abstract_state, mesh, dist)

    round_fn = spatial_round if dist.mode == "spatial" else temporal_round

    def step_fn(state, batches, sspecs):
        return round_fn(state, batches, loss_fn, fed_cfg, mesh, dist,
                        sspecs, arch_cfg)

    return init_fn, step_fn, sspecs_fn
