"""Differential-privacy machinery (paper Sec. V, Setup V.1, eq. (39)).

Noise model: i.i.d. Laplace perturbation of the uploaded parameters,
z_i = w_i + eps_i. The paper's density convention (25) is
d(e) = 1/(2 nu) exp(-|e| / (2 nu)), i.e. a standard Laplace with *scale
b = 2 nu*. Setup V.1 picks nu = Delta_i / (eps_dp * mu_{i,k+1}) and the
experiments bound the l1 gradient sensitivity by the surrogate
Delta_hat = 2 ||g_i^tau||_1 (their eq. (39), since the true Delta is hard to
compute). We therefore sample Laplace(0, b) with

    b = 2 * Delta_hat / (eps_dp * mu_{i,k+1})

which matches the paper's effective distribution. Because mu_{i,k} grows
geometrically (alpha_i^k), the injected noise decays geometrically -- the
property both the DP guarantee (per-round eps-DP, Thm V.1) and the
convergence proof (Thm VI.1, phi_{i,k} summable) rely on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.treeutil import tmap, tree_l1_norm, tree_sq_norm


def sample_laplace(key: jax.Array, shape, scale, dtype=jnp.float32) -> jax.Array:
    """Laplace(0, scale) via inverse CDF; scale may be a traced scalar."""
    u = jax.random.uniform(key, shape, dtype=jnp.float32,
                           minval=-0.5 + 1e-7, maxval=0.5)
    eps = -jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))
    return (scale * eps).astype(dtype)


def laplace_tree(key: jax.Array, tree, scale):
    """Sample a Laplace-noise pytree shaped like ``tree``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noise = [
        sample_laplace(k, leaf.shape, scale, dtype=leaf.dtype)
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, noise)


def sensitivity_surrogate(g_tree) -> jax.Array:
    """Delta_hat = 2 ||g||_1 (paper eq. (39) commentary)."""
    return 2.0 * tree_l1_norm(g_tree)


def fedepm_noise_scale(delta_hat, eps_dp, mu, factor: float = 1.0) -> jax.Array:
    """Laplace scale b = factor * Delta_hat / (eps_dp * mu).

    ``factor=1`` reads the paper's "Lap(0, nu)" with the *standard* scale
    convention (b = nu). The paper's own density (25) and moments (59) are
    mutually inconsistent (their (25) integrates to 2; their E|eps| = 4 nu
    corresponds to b = 4 nu); factor lets benchmarks reproduce either
    convention. The DP guarantee of Thm V.1 holds for factor >= 2 exactly,
    and for factor = 1 with eps' = 2*eps.
    """
    return factor * delta_hat / (eps_dp * mu)


def snr_db10(w_tree, eps_tree) -> jax.Array:
    """Paper's SNR for one client: log10(||w|| / ||eps||)."""
    wn = jnp.sqrt(tree_sq_norm(w_tree))
    en = jnp.sqrt(tree_sq_norm(eps_tree))
    return jnp.log10(wn / jnp.maximum(en, 1e-30))


def clip_tree_l1(tree, max_l1):
    """Optional l1 clipping to *enforce* a sensitivity bound (beyond-paper

    hardening: the paper assumes Delta is bounded; clipping makes it true).
    """
    n1 = tree_l1_norm(tree)
    factor = jnp.minimum(1.0, max_l1 / jnp.maximum(n1, 1e-30))
    return tmap(lambda x: x * factor, tree)
