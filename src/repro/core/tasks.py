"""Task/loss definitions used by the federated core.

``logistic_loss`` is the paper's Sec. VII.A objective (per client i):

    f_i(w) = (1/d_i) sum_t [ ln(1 + e^{<x_t, w>}) - b_t <x_t, w>
                             + (beta/2) ||w||^2 ]

with beta = 1e-3. Batches carry a validity mask so padded (ragged) federated
shards contribute nothing; the (beta/2)||w||^2 term is averaged exactly like
the paper (inside the 1/d_i sum => effectively (beta/2)||w||^2 per client).

``lm_loss`` is the cross-entropy next-token loss used when FedEPM trains the
assigned transformer architectures (model apply fn is closed over).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_logistic_loss(beta: float = 1e-3) -> Callable:
    def loss(w, batch):
        x, y, mask = batch["x"], batch["y"], batch["mask"]
        logits = x @ w  # (d,)
        # ln(1 + e^z) - b z, numerically stable softplus
        per = jax.nn.softplus(logits) - y * logits
        d_i = jnp.maximum(jnp.sum(mask), 1.0)
        reg = 0.5 * beta * jnp.sum(w * w)
        return jnp.sum(per * mask) / d_i + reg

    return loss


def make_least_squares_loss(beta: float = 0.0) -> Callable:
    def loss(w, batch):
        x, y, mask = batch["x"], batch["y"], batch["mask"]
        r = (x @ w - y) * mask
        d_i = jnp.maximum(jnp.sum(mask), 1.0)
        return 0.5 * jnp.sum(r * r) / d_i + 0.5 * beta * jnp.sum(w * w)

    return loss


def accuracy_logistic(w, X, y) -> jax.Array:
    pred = (X @ w) > 0
    return jnp.mean(pred == (y > 0.5))


def make_lm_loss(apply_fn: Callable) -> Callable:
    """Next-token CE for a decoder model: batch = {tokens, targets, mask}."""

    def loss(params, batch):
        logits = apply_fn(params, batch)  # (B, T, V)
        tgt = batch["targets"]
        mask = batch.get("loss_mask")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        if mask is None:
            return jnp.mean(nll)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll * mask) / denom

    return loss


def make_chunked_lm_loss(hidden_fn: Callable, unembed_fn: Callable,
                         chunk: int = 512) -> Callable:
    """CE loss that never materialises the full (B, T, V) logits.

    ``hidden_fn(params, batch)`` returns the final-norm hidden states
    (B, T, d); ``unembed_fn(h_chunk, params)`` projects a (B, Tc, d) chunk
    to logits. The T axis is processed in ``chunk``-sized pieces under a
    ``lax.scan``, so peak memory holds ONE chunk of logits -- essential for
    seq 4096 x vocab 256000 archs (command-r) where full logits would be
    33 GB per client.
    """

    def loss(params, batch):
        h = hidden_fn(params, batch)  # (B, T, d)
        tgt = batch["targets"]
        mask = batch.get("loss_mask")
        B, T, _ = h.shape
        if mask is None:
            mask = jnp.ones((B, T), jnp.float32)
        c = min(chunk, T)
        pad = (-T) % c
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        n = h.shape[1] // c

        def body(acc, xs):
            hc, tc, mc = xs  # (B, c, d), (B, c), (B, c)
            logits = unembed_fn(jnp.moveaxis(hc, 0, 0), params)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
            return acc + jnp.sum(nll * mc), None

        xs = (jnp.moveaxis(h.reshape(B, n, c, -1), 1, 0),
              jnp.moveaxis(tgt.reshape(B, n, c), 1, 0),
              jnp.moveaxis(mask.reshape(B, n, c), 1, 0))
        total, _ = jax.lax.scan(body, jnp.asarray(0.0, jnp.float32), xs)
        return total / jnp.maximum(jnp.sum(mask), 1.0)

    return loss
