from repro.optim.optimizers import adamw, sgd, OptState  # noqa: F401
