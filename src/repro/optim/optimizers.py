"""Minimal optimizers for non-federated comparisons and serving-side tools.

FedEPM itself needs NO optimizer state (the prox update (20) is closed
form) -- one of its practical selling points vs Adam-based FL. These are
used by the centralized-baseline benchmarks and the quickstart example.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment (or momentum)
    nu: Any          # second moment (adam only)


def sgd(lr: float, momentum: float = 0.9):
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=tmap(jnp.zeros_like, params), nu=None)

    def update(grads, state, params):
        mu = tmap(lambda m, g: momentum * m + g, state.mu, grads)
        new_params = tmap(lambda p, m: p - lr * m, params, mu)
        return new_params, OptState(state.step + 1, mu, None)

    return init, update


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=tmap(jnp.zeros_like, params),
                        nu=tmap(jnp.zeros_like, params))

    def update(grads, state, params):
        step = state.step + 1
        mu = tmap(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = tmap(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + eps)
                             + weight_decay * p)

        return tmap(upd, params, mu, nu), OptState(step, mu, nu)

    return init, update
