"""Byte-accurate communication accounting and the optional upload codec.

Byte ledger
-----------
Wire sizes are derived from the REAL pytree leaf dtypes/shapes of the state
being exchanged (not a hand-waved parameter count): the server->client
broadcast moves one dense copy of w^{tau+1} per contacted client, the
client->server upload moves one (possibly encoded) copy of z_i per client
whose upload completed within the round. ``ByteLedger`` accumulates both
per round and per client, host-side -- in INTEGER units wherever the wire
size is exact (dense and whole-byte quantized payloads), falling back to
float only for fractional sizes (sub-byte bit-packing, top-k index
estimates), so long simulations cannot drift. Wire-size computations are
memoized per (treedef, leaf shapes, codec): repeated calls stop re-walking
the pytree.

Upload codec (top-k sparsification + uniform stochastic quantization)
---------------------------------------------------------------------
``codec_roundtrip`` models what the server RECEIVES when clients compress
uploads: per leaf, each client keeps the top ceil(topk_frac * n) coordinates
by magnitude, snaps the kept values onto a ``bits``-bit uniform grid
(repro.kernels.quant -- Pallas kernel with a bit-identical jnp reference),
and the server dequantizes BEFORE aggregation, substituting the client's
previous upload z_i^{tau-1} on dropped coordinates. ENS then runs on dense
dequantized uploads, so compressed FedEPM keeps the aggregation math of
core/fedepm.py unchanged: with bits=0 the kept coordinates are transmitted
exactly, and with topk_frac=1, bits=0 the codec is the identity. Dropped
coordinates are a per-coordinate analogue of the paper's eq. (22)
carry-through (the server reuses the stalest value it holds).

Batched multi-leaf encode (PR 4)
--------------------------------
The round-trip no longer loops leaf by leaf. Every (leaf, client) pair
becomes one row of a single padded 2-D array (leaves grouped by dtype,
padded to the group's widest flat leaf), so a whole pytree encodes in ONE
top-k + ONE fused ``quantize_cols`` kernel launch (kernels/quant/batch.py;
column-bounded: row i quantizes its leading kcols[i] live columns and
passes the fallback through elsewhere). The padded layout -- per-leaf keep
counts, row offsets -- is planned once per (treedef, leaf shapes, codec)
and cached. The dither stream is drawn per GROUP over the padded layout,
so compressed values differ from the pre-batched per-leaf stream in the
last stochastic bit; all codec laws (unbiasedness, error bounds, exact
top-k touch counts) are unchanged and pinned by tests.

Wire format accounted per client per leaf (n coords, k kept):
    dense  (k == n):  n * bits/8 payload + 4 B scale
    sparse (k <  n):  k * bits/8 payload + k * index_bytes + 4 B scale
with bits=0 meaning raw leaf-dtype values (no scale overhead when dense).

Error feedback (``CodecConfig.error_feedback`` + ``ef_roundtrip``)
------------------------------------------------------------------
The memoryless round-trip above silently BIASES the eq. (22) update: the
dropped/rounded-away part of every upload is lost each round. With error
feedback, client and server share a codec memory h_i; the wire carries
C(z_i - h_i) and both sides accumulate h_i <- h_i + C(z_i - h_i)
(kernels/quant fused ``ef_accumulate`` pair, run over the same stacked
multi-leaf rows), so compressed trajectories converge to the uncompressed
objective (tests/test_sim_async.py pins the contraction). Same wire
format, same byte accounting.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant import ops as quant_ops
from repro.kernels.quant.ref import laplace_from_u32
from repro.telemetry.events import NULL_RECORDER

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------

def _leaf_meta(leaves) -> tuple:
    """Hashable (shape, dtype) signature of a flattened pytree."""
    return tuple((tuple(x.shape), str(x.dtype)) for x in leaves)


# wire-size memos: keyed by (treedef, leaf signature[, codec]) -- a process
# touches a handful of state trees, so these stay tiny, but each hit saves
# a full pytree walk on the dispatch path
_DENSE_BYTES_CACHE: dict = {}
_STACKED_BYTES_CACHE: dict = {}
_ENCODED_BYTES_CACHE: dict = {}


def tree_client_bytes(tree) -> int:
    """Dense wire bytes of ONE client's pytree (leaves without client axis)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = (treedef, _leaf_meta(leaves))
    got = _DENSE_BYTES_CACHE.get(key)
    if got is None:
        got = _DENSE_BYTES_CACHE[key] = sum(
            x.size * x.dtype.itemsize for x in leaves)
    return got


def stacked_client_bytes(tree) -> int:
    """Dense wire bytes of ONE client's slice of a stacked (m, ...) pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = (treedef, _leaf_meta(leaves))
    got = _STACKED_BYTES_CACHE.get(key)
    if got is None:
        got = _STACKED_BYTES_CACHE[key] = sum(
            (x.size // x.shape[0]) * x.dtype.itemsize for x in leaves)
    return got


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Upload compression: keep top-k by magnitude, quantize kept values.

    topk_frac: fraction of each leaf's coordinates kept (1.0 = dense).
    bits: wire bits per kept value (>= 2), or 0 to send kept values raw.
    stochastic: unbiased dithered rounding (True) vs round-half-up.
    impl: quantizer implementation, "ref" (jnp) or "pallas".
    index_bytes: per-kept-coordinate index cost when sparse (k < n).
    error_feedback: EF21-style codec memory -- compress the RESIDUAL
        against a shared reconstruction h_i instead of z_i itself (see
        ``ef_roundtrip``). Wire format and byte accounting are unchanged.
    """

    topk_frac: float = 1.0
    bits: int = 8
    stochastic: bool = True
    impl: str = "ref"
    index_bytes: int = 4
    error_feedback: bool = False

    def __post_init__(self):
        if not (0.0 < self.topk_frac <= 1.0):
            raise ValueError(f"topk_frac must be in (0, 1]; got {self.topk_frac}")
        if self.bits != 0 and self.bits < 2:
            raise ValueError(f"bits must be 0 (raw) or >= 2; got {self.bits}")


def _leaf_k(n: int, frac: float) -> int:
    return n if frac >= 1.0 else max(1, math.ceil(frac * n))


def encoded_client_bytes(tree, codec: CodecConfig | None) -> float:
    """Wire bytes of ONE client's (possibly encoded) upload of a stacked tree.

    Memoized per (treedef, leaf shapes/dtypes, codec). FedSim snapshots
    this size once per construction -- the per-dispatch billing uses that
    float -- so the memo pays off where sims are built in bulk over the
    same trees (benchmark grids, test suites) and where trees have many
    leaves (LM-scale states), not on the round hot path.
    """
    if codec is None:
        return float(stacked_client_bytes(tree))
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = (treedef, _leaf_meta(leaves), codec)
    got = _ENCODED_BYTES_CACHE.get(key)
    if got is not None:
        return got
    total = 0.0
    for x in leaves:
        n = x.size // x.shape[0]
        k = _leaf_k(n, codec.topk_frac)
        payload = k * (codec.bits / 8.0 if codec.bits else x.dtype.itemsize)
        index = 0.0 if k == n else k * codec.index_bytes
        scale = 4.0 if codec.bits else (0.0 if k == n else 4.0)
        total += payload + index + scale
    _ENCODED_BYTES_CACHE[key] = total
    return total


def codec_event_attrs(codec: CodecConfig, *, n_clients: int,
                      up_bytes) -> dict:
    """Attrs dict for a telemetry ``codec_encode`` event."""
    return {"clients": int(n_clients),
            "bytes": float(up_bytes) * int(n_clients),
            "topk_frac": codec.topk_frac, "bits": codec.bits,
            "error_feedback": codec.error_feedback}


class LedgerSnapshot(NamedTuple):
    """O(1) running-total snapshot of a :class:`ByteLedger`.

    Integer and float accumulators are kept separate so deltas between two
    snapshots are exact on the integer paths (no float cancellation).
    """

    up_i: int
    down_i: int
    up_f: float
    down_f: float

    @property
    def up(self) -> float:
        return float(self.up_i + self.up_f)

    @property
    def down(self) -> float:
        return float(self.down_i + self.down_f)


class ByteLedger:
    """Per-round, per-client cumulative communication record (host-side).

    Per-client byte counters accumulate in int64 whenever the per-transfer
    wire size is a whole number of bytes (dense trees, whole-byte quantized
    payloads) and in float64 only otherwise (sub-byte packing / fractional
    top-k estimates), so integer-exact paths cannot accumulate float
    rounding drift over long runs. ``up``/``down`` expose the combined
    float64 view; totals are bit-identical to the all-float accumulation
    for every size below 2^53.

    Scalar running totals are maintained alongside the per-client arrays,
    so ``total_up``/``total_down`` and ``snapshot()``/``delta()`` are O(1)
    -- consumers (telemetry counters, run summaries) no longer re-sum the
    (m,) arrays each round. With a telemetry recorder attached, every
    record call that carries a ``ts`` emits a ``ledger_record`` event with
    the round's byte delta and the running totals.
    """

    def __init__(self, m: int, *, telemetry=None):
        self.m = m
        self.telemetry = NULL_RECORDER if telemetry is None else telemetry
        self._up_i = np.zeros(m, np.int64)
        self._down_i = np.zeros(m, np.int64)
        self._up_f = np.zeros(m, np.float64)
        self._down_f = np.zeros(m, np.float64)
        self._tot_up_i = 0
        self._tot_down_i = 0
        self._tot_up_f = 0.0
        self._tot_down_f = 0.0
        self.rounds: list[dict] = []

    @property
    def up(self) -> np.ndarray:
        """(m,) cumulative uplink bytes per client (float64 view)."""
        return self._up_i + self._up_f

    @property
    def down(self) -> np.ndarray:
        """(m,) cumulative downlink bytes per client (float64 view)."""
        return self._down_i + self._down_f

    def record_round(self, *, down_mask: np.ndarray, up_mask: np.ndarray,
                     down_bytes: float, up_bytes, ts: float | None = None,
                     round_idx: int | None = None) -> dict:
        """down_mask: clients the server contacted (they receive the
        broadcast); up_mask: clients whose upload completed; up_bytes:
        scalar or (m,) per-client encoded size."""
        return self.record_counts(
            down_counts=np.asarray(down_mask, bool).astype(np.int64),
            up_counts=np.asarray(up_mask, bool).astype(np.int64),
            down_bytes=down_bytes, up_bytes=up_bytes, ts=ts,
            round_idx=round_idx)

    def record_counts(self, *, down_counts: np.ndarray,
                      up_counts: np.ndarray, down_bytes: float,
                      up_bytes, ts: float | None = None,
                      round_idx: int | None = None) -> dict:
        """Count-based variant for the async server: one aggregation event
        may contact or receive from the same client several times (a client
        can sit in two overlapping cohorts), so transfers are integer COUNTS
        per client rather than boolean masks. n_down/n_up report distinct
        clients; the byte totals weight by the counts.

        ``ts``/``round_idx`` tag the telemetry ``ledger_record`` event
        (simulated time); omitted, the record is silent even with a
        recorder attached."""
        down_counts = np.asarray(down_counts, np.int64)
        up_counts = np.asarray(up_counts, np.int64)
        up_pc = np.broadcast_to(np.asarray(up_bytes, np.float64), (self.m,))
        d = down_counts * float(down_bytes)
        u = up_counts * up_pc
        if float(down_bytes).is_integer():
            di = down_counts * np.int64(down_bytes)
            self._down_i += di
            self._tot_down_i += int(di.sum())
        else:
            self._down_f += d
            self._tot_down_f += float(d.sum())
        if np.all(up_pc == np.floor(up_pc)):
            ui = up_counts * up_pc.astype(np.int64)
            self._up_i += ui
            self._tot_up_i += int(ui.sum())
        else:
            self._up_f += u
            self._tot_up_f += float(u.sum())
        rec = {"round": len(self.rounds), "down": float(d.sum()),
               "up": float(u.sum()), "n_down": int((down_counts > 0).sum()),
               "n_up": int((up_counts > 0).sum())}
        self.rounds.append(rec)
        if self.telemetry.enabled and ts is not None:
            self.telemetry.event(
                "ledger_record", ts=ts,
                round_idx=len(self.rounds) - 1 if round_idx is None
                else round_idx,
                up=rec["up"], down=rec["down"], n_up=rec["n_up"],
                n_down=rec["n_down"], total_up=self.total_up,
                total_down=self.total_down)
        return rec

    def snapshot(self) -> LedgerSnapshot:
        """O(1) copy of the running totals (int/float paths separate)."""
        return LedgerSnapshot(up_i=self._tot_up_i, down_i=self._tot_down_i,
                              up_f=self._tot_up_f, down_f=self._tot_down_f)

    def checkpoint(self) -> dict:
        """Deep copy of the FULL ledger state (per-client arrays, totals,
        round records) -- the rewind anchor ``FedSim.snapshot()`` takes so a
        scan chunk that overshoots a termination rule can be replayed
        exactly. Unlike :meth:`snapshot`, this is O(m + rounds)."""
        return {"up_i": self._up_i.copy(), "down_i": self._down_i.copy(),
                "up_f": self._up_f.copy(), "down_f": self._down_f.copy(),
                "tot": (self._tot_up_i, self._tot_down_i,
                        self._tot_up_f, self._tot_down_f),
                "rounds": [dict(r) for r in self.rounds]}

    def restore(self, chk: dict) -> None:
        """Rewind to a :meth:`checkpoint` (the checkpoint stays reusable)."""
        self._up_i = chk["up_i"].copy()
        self._down_i = chk["down_i"].copy()
        self._up_f = chk["up_f"].copy()
        self._down_f = chk["down_f"].copy()
        (self._tot_up_i, self._tot_down_i,
         self._tot_up_f, self._tot_down_f) = chk["tot"]
        self.rounds = [dict(r) for r in chk["rounds"]]

    def delta(self, since: LedgerSnapshot) -> dict:
        """Bytes moved since ``since`` -- exact on the integer paths."""
        return {"up": float((self._tot_up_i - since.up_i)
                            + (self._tot_up_f - since.up_f)),
                "down": float((self._tot_down_i - since.down_i)
                              + (self._tot_down_f - since.down_f))}

    @property
    def total_up(self) -> float:
        return float(self._tot_up_i + self._tot_up_f)

    @property
    def total_down(self) -> float:
        return float(self._tot_down_i + self._tot_down_f)

    @property
    def total(self) -> float:
        return self.total_up + self.total_down


# ---------------------------------------------------------------------------
# batched multi-leaf encode plan (cached per treedef/shapes/codec)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _GroupPlan:
    """One dtype group of the padded 2-D layout.

    ``index``/``shape``/``n``/``k`` are per-leaf (flattened-tree position,
    stacked shape, flat coordinate count, keep count); rows of the stacked
    array are leaf-major: rows [l*m, (l+1)*m) belong to leaf l.
    """

    index: tuple[int, ...]
    shape: tuple[tuple[int, ...], ...]
    n: tuple[int, ...]
    k: tuple[int, ...]
    n_max: int
    k_max: int
    dense: bool       # every leaf keeps all coordinates (k == n)


_PLAN_CACHE: dict = {}


def _codec_plan(treedef, leaves, codec: CodecConfig) -> tuple[_GroupPlan, ...]:
    key = (treedef, _leaf_meta(leaves), codec)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        return plan
    by_dtype: dict[str, list[int]] = {}
    for i, x in enumerate(leaves):
        by_dtype.setdefault(str(x.dtype), []).append(i)
    groups = []
    for idxs in by_dtype.values():
        ns = tuple(leaves[i].size // leaves[i].shape[0] for i in idxs)
        ks = tuple(_leaf_k(n, codec.topk_frac) for n in ns)
        groups.append(_GroupPlan(
            index=tuple(idxs),
            shape=tuple(tuple(leaves[i].shape) for i in idxs),
            n=ns, k=ks, n_max=max(ns), k_max=max(ks),
            dense=all(k == n for k, n in zip(ks, ns))))
    plan = _PLAN_CACHE[key] = tuple(groups)
    return plan


def _stack_rows(leaves, gp: _GroupPlan) -> jax.Array:
    """Group leaves -> (len(gp.index) * m, n_max) leaf-major row stack."""
    m = leaves[0].shape[0]
    rows = []
    for x, n in zip(leaves, gp.n):
        flat = x.reshape(m, -1)
        if n < gp.n_max:
            flat = jnp.pad(flat, ((0, 0), (0, gp.n_max - n)))
        rows.append(flat)
    return jnp.concatenate(rows, axis=0)


def _unstack_rows(rows: jax.Array, gp: _GroupPlan, m: int) -> list:
    return [rows[i * m:(i + 1) * m, :n].reshape(shape)
            for i, (n, shape) in enumerate(zip(gp.n, gp.shape))]


def _group_cols(gp: _GroupPlan, m: int):
    """Per-row live-coordinate and keep counts, (R,) int32 device consts."""
    ncols = jnp.asarray(np.repeat(np.asarray(gp.n, np.int32), m))
    kcols = jnp.asarray(np.repeat(np.asarray(gp.k, np.int32), m))
    return ncols, kcols


def _topk_rows(rows: jax.Array, live32: jax.Array, gp: _GroupPlan):
    """Top-k_max magnitudes per row over the live columns only.

    ``live32`` masks real coordinates (padding gets magnitude -1, so it is
    never selected while k <= n). lax.top_k sorts descending with ties
    broken by lowest index, so truncating a row to its leading k_l columns
    yields exactly that leaf's per-leaf top-k -- the same set the old
    leaf-by-leaf encode picked.
    """
    mag = jnp.where(live32, jnp.abs(rows.astype(jnp.float32)), -1.0)
    _, idx = jax.lax.top_k(mag, gp.k_max)
    return idx


# ---------------------------------------------------------------------------
# codec round-trip (what the server holds after dequantization)
# ---------------------------------------------------------------------------

def _codec_group(z_leaves, fb_leaves, key, codec: CodecConfig,
                 gp: _GroupPlan):
    """Fused round-trip of one dtype group; returns decoded leaves."""
    m = z_leaves[0].shape[0]
    if gp.dense and not codec.bits:
        return z_leaves  # every coordinate kept and sent raw: identity
    R = len(gp.index) * m
    z_rows = _stack_rows(z_leaves, gp)
    ncols, kcols = _group_cols(gp, m)

    if gp.dense:
        # no coordinate dropping: quantize the live columns in place (the
        # fallback operand passes padding through; it is sliced away)
        scale = jnp.max(jnp.abs(z_rows.astype(jnp.float32)), axis=1)
        u32 = (jax.random.bits(key, (R, gp.n_max), dtype=jnp.uint32)
               if codec.stochastic else None)
        out_rows = quant_ops.quantize_cols(z_rows, z_rows, scale, ncols,
                                           codec.bits, u32, impl=codec.impl)
        return _unstack_rows(out_rows, gp, m)

    fb_rows = _stack_rows(fb_leaves, gp)
    col_n = jnp.arange(gp.n_max, dtype=jnp.int32)[None, :]
    idx = _topk_rows(z_rows, col_n < ncols[:, None], gp)
    vals = jnp.take_along_axis(z_rows, idx, axis=1)       # (R, k_max)
    fbv = jnp.take_along_axis(fb_rows, idx, axis=1)       # (R, k_max)
    col_k = jnp.arange(gp.k_max, dtype=jnp.int32)[None, :]
    live = col_k < kcols[:, None]
    if codec.bits:
        scale = jnp.max(
            jnp.where(live, jnp.abs(vals.astype(jnp.float32)), 0.0), axis=1)
        u32 = (jax.random.bits(key, (R, gp.k_max), dtype=jnp.uint32)
               if codec.stochastic else None)
        enc = quant_ops.quantize_cols(vals, fbv, scale, kcols, codec.bits,
                                      u32, impl=codec.impl)
    else:
        enc = jnp.where(live, vals, fbv)
    # columns past a row's keep count scatter its fallback value back onto
    # its own index -- a no-op -- so one scatter serves every row width
    out_rows = jax.vmap(lambda f, i, v: f.at[i].set(v))(fb_rows, idx, enc)
    return _unstack_rows(out_rows, gp, m)


def codec_roundtrip(tree_z, tree_fallback, key: jax.Array,
                    codec: CodecConfig | None):
    """Encode + decode every client's upload; stacked (m, ...) pytrees.

    ``tree_fallback`` supplies dropped coordinates (the server's stale copy,
    normally the previous round's Z). Identity when codec is None. The
    whole pytree encodes through the fused multi-leaf path: one top-k and
    one ``quantize_cols`` launch per dtype group, not one of each per leaf.
    """
    if codec is None:
        return tree_z
    if codec.topk_frac >= 1.0 and not codec.bits:
        return tree_z  # identity codec
    leaves, treedef = jax.tree_util.tree_flatten(tree_z)
    fb_leaves = jax.tree_util.tree_leaves(tree_fallback)
    plan = _codec_plan(treedef, leaves, codec)
    keys = jax.random.split(key, len(plan))
    out = list(leaves)
    for gp, gkey in zip(plan, keys):
        dec = _codec_group([leaves[i] for i in gp.index],
                           [fb_leaves[i] for i in gp.index],
                           gkey, codec, gp)
        for i, leaf in zip(gp.index, dec):
            out[i] = leaf
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# error-feedback round-trip (EF21-style codec memory)
# ---------------------------------------------------------------------------

def _ef_group(z_leaves, h_leaves, key, codec: CodecConfig, gp: _GroupPlan):
    """Fused EF step of one dtype group; returns the new shared memories."""
    m = z_leaves[0].shape[0]
    if gp.dense and not codec.bits:
        # wire carries the full residual exactly: bit-exact identity
        # (h + (z - h) would re-associate in floating point)
        return z_leaves
    R = len(gp.index) * m
    z_rows = _stack_rows(z_leaves, gp)
    h_rows = _stack_rows(h_leaves, gp)
    ncols, kcols = _group_cols(gp, m)

    if gp.dense:
        # fused accumulate H + Q(Z - H) over the whole group's rows;
        # padding columns have z = h = 0, so they quantize to exactly 0
        r = z_rows - h_rows
        scale = jnp.max(jnp.abs(r.astype(jnp.float32)), axis=1)
        u32 = (jax.random.bits(key, (R, gp.n_max), dtype=jnp.uint32)
               if codec.stochastic else None)
        out_rows = quant_ops.ef_accumulate(z_rows, h_rows, scale,
                                           codec.bits, u32, impl=codec.impl)
        return _unstack_rows(out_rows, gp, m)

    r_rows = z_rows - h_rows
    col_n = jnp.arange(gp.n_max, dtype=jnp.int32)[None, :]
    idx = _topk_rows(r_rows, col_n < ncols[:, None], gp)
    vals = jnp.take_along_axis(r_rows, idx, axis=1)       # residual values
    col_k = jnp.arange(gp.k_max, dtype=jnp.int32)[None, :]
    live = col_k < kcols[:, None]
    if codec.bits:
        scale = jnp.max(
            jnp.where(live, jnp.abs(vals.astype(jnp.float32)), 0.0), axis=1)
        u32 = (jax.random.bits(key, (R, gp.k_max), dtype=jnp.uint32)
               if codec.stochastic else None)
        enc = quant_ops.quantize_cols(vals, jnp.zeros_like(vals), scale,
                                      kcols, codec.bits, u32,
                                      impl=codec.impl)
    else:
        enc = jnp.where(live, vals, jnp.zeros_like(vals))
    # accumulate the (zero-padded past each row's keep count) residual
    out_rows = jax.vmap(lambda h, i, v: h.at[i].add(v))(h_rows, idx, enc)
    return _unstack_rows(out_rows, gp, m)


def ef_roundtrip(tree_z, tree_h, key: jax.Array, codec: CodecConfig | None):
    """Error-feedback encode + decode; stacked (m, ...) pytrees.

    ``tree_h`` is the shared codec memory (the server's reconstruction after
    the client's last delivered upload; init all-zeros). Returns the NEW
    memory, which is also exactly what the server now holds for each client
    -- callers use it both as the decoded upload and as the next h. Identity
    when codec is None, and bit-exact identity for the dense raw codec
    (k == n, bits == 0): the wire then carries the residual exactly, so
    returning z avoids the h + (z - h) float re-association. Same fused
    multi-leaf layout as ``codec_roundtrip``.
    """
    if codec is None:
        return tree_z
    if codec.topk_frac >= 1.0 and not codec.bits:
        return tree_z  # dense raw residual: exact identity
    leaves, treedef = jax.tree_util.tree_flatten(tree_z)
    h_leaves = jax.tree_util.tree_leaves(tree_h)
    plan = _codec_plan(treedef, leaves, codec)
    keys = jax.random.split(key, len(plan))
    out = list(leaves)
    for gp, gkey in zip(plan, keys):
        dec = _ef_group([leaves[i] for i in gp.index],
                        [h_leaves[i] for i in gp.index],
                        gkey, codec, gp)
        for i, leaf in zip(gp.index, dec):
            out[i] = leaf
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# private round-trip (clip + DP noise in front of the codec)
# ---------------------------------------------------------------------------

def _gaussian_from_u32(u32: jax.Array) -> jax.Array:
    """Unit-scale Gaussian noise from uint32 bits via the inverse CDF.

    Counterpart of ``kernels.quant.ref.laplace_from_u32`` for the gaussian
    mechanism (sequential path only; the fused kernel is Laplace-only).
    The uniform is clamped away from {0, 1} so ndtri stays finite.
    """
    u = u32.astype(jnp.float32) * float(2.0 ** -32)
    u = jnp.clip(u, 1e-7, 1.0 - 1e-7)
    return jax.scipy.special.ndtri(u).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("shapes", "mechanism"))
def _draw_noise_leaves(pkey: jax.Array, *, shapes, mechanism: str):
    """Standalone unit-noise program: one leaf of noise per shape.

    ``pkey`` splits per leaf in flatten order; each leaf's uint32 stream
    maps through the mechanism's inverse CDF. This is its OWN compiled
    program, never inlined into a merge or scan body -- see
    :func:`draw_unit_noise` for why that isolation is load-bearing.
    """
    keys = jax.random.split(pkey, len(shapes))
    out = []
    for shp, k in zip(shapes, keys):
        u32 = jax.random.bits(k, shp, dtype=jnp.uint32)
        out.append(laplace_from_u32(u32) if mechanism == "laplace"
                   else _gaussian_from_u32(u32))
    return out


def draw_unit_noise(pkey: jax.Array, tree_like, privacy):
    """Unit-scale DP noise tree (float32 leaves shaped like ``tree_like``).

    BOTH engines call this from the HOST and feed the result into their
    compiled merge programs as data, exactly like the policy mask streams
    and the quantizer dither planes. The hoisting is a bit-exactness
    requirement, not a convenience: the inverse-CDF transforms
    (``log1p``/``ndtri``) are transcendentals whose last-ulp rounding
    depends on how XLA:CPU vectorizes the fusion cluster they land in, so
    computing them INSIDE the eager merge program and again inside the
    scan chunk program yields values that differ by 1 ulp on some
    elements. Drawn here, the noise comes out of one shared program and
    enters every consumer as an unfusable input buffer, so eager and scan
    see bit-identical draws by construction.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    shapes = tuple(tuple(x.shape) for x in leaves)
    ns = _draw_noise_leaves(pkey, shapes=shapes,
                            mechanism=privacy.mechanism)
    return jax.tree_util.tree_unflatten(treedef, ns)


def _client_l1(leaves, m: int) -> jax.Array:
    """(m,) per-client l1 norm over a stacked tree, float32.

    Summed leaf-by-leaf in flatten order, with the per-leaf row sum
    expressed as abs(x) @ ones rather than ``jnp.sum(axis=1)``. The dot
    form is a bit-exactness requirement, not a style choice: a fusible
    reduce's accumulation order depends on how XLA:CPU tiles the fusion
    it lands in (vectorized partial sums vs in-order scalar), so the
    same row summed inside the eager merge program and inside the scan
    chunk can differ in the last ulp -- and a 1-ulp l1 shift moves the
    clip factor and noise scale, which the trajectory then amplifies. A
    dot is emitted as its own computation over materialized operands in
    every context, so both engines accumulate identically.
    """
    tot = jnp.zeros((m,), jnp.float32)
    for x in leaves:
        a = jnp.abs(x.astype(jnp.float32)).reshape(m, -1)
        tot = tot + a @ jnp.ones((a.shape[1],), jnp.float32)
    return tot


def privacy_row_params(l1: jax.Array, privacy) -> tuple[jax.Array, jax.Array]:
    """Per-client (clip factor, noise scale) from the upload l1 norms.

    ``privacy`` is a ``repro.privacy.PrivacyConfig`` with ``eps > 0``.
    Surrogate mode uses the paper's data-dependent sensitivity
    ``delta_hat = 2 * ||z||_1`` (eq. 39) and never rescales the upload;
    clip mode first enforces ``||z||_1 <= clip`` (the same
    min(1, clip/||z||_1) factor as ``core.dp.clip_tree_l1``) and then
    uses the data-independent bound ``delta_hat = 2 * clip``. Laplace
    scale is ``b = delta_hat / eps``; the gaussian std multiplies in the
    standard ``sqrt(2 ln(1.25/delta))`` calibration (conservative here:
    ``||.||_2 <= ||.||_1`` so the l1 bound covers the l2 sensitivity).
    """
    if privacy.sensitivity == "clip":
        clipf = jnp.minimum(
            1.0, privacy.clip / jnp.maximum(l1, 1e-30)).astype(jnp.float32)
        delta_hat = jnp.full_like(l1, 2.0 * privacy.clip)
    else:
        clipf = jnp.ones_like(l1)
        delta_hat = 2.0 * l1
    b = delta_hat * (1.0 / privacy.eps)
    if privacy.mechanism == "gaussian":
        b = b * math.sqrt(2.0 * math.log(1.25 / privacy.delta))
    return clipf, b


def _clip_noise_tree(tree_z, noise, clipf: jax.Array, b: jax.Array):
    """Sequential clip + noise: z_i <- z_i * clipf_i + b_i * noise, per leaf.

    ``noise`` is the host-drawn unit-noise tree (:func:`draw_unit_noise`,
    shaped like ``tree_z``) -- an input buffer, never computed in-body,
    so both engines consume bit-identical draws.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree_z)
    n_leaves = jax.tree_util.tree_leaves(noise)
    out = []
    for x, n in zip(leaves, n_leaves):
        shp = (x.shape[0],) + (1,) * (x.ndim - 1)
        # barrier the clipped product: the affine has TWO products
        # feeding one add, and which of them XLA contracts into an FMA
        # depends on the surrounding program -- eager's merge program and
        # the scan chunk would round differently whenever clipf != 1.
        # Fencing x*clipf leaves b*n as the only contraction candidate,
        # so every context compiles the same fma(b, n, x*clipf).
        xc = jax.lax.optimization_barrier(
            x.astype(jnp.float32) * clipf.reshape(shp))
        y = xc + b.reshape(shp) * n
        out.append(y.astype(x.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _fused_private(leaves, treedef, key, noise, codec: CodecConfig,
                   clipf: jax.Array, b: jax.Array):
    """Dense-quantized Laplace path: ONE fused clip+noise+quantize launch
    per dtype group (kernels/quant private_quantize_cols)."""
    m = leaves[0].shape[0]
    n_leaves = jax.tree_util.tree_leaves(noise)
    plan = _codec_plan(treedef, leaves, codec)
    keys = jax.random.split(key, len(plan))
    out = list(leaves)
    for gp, gkey in zip(plan, keys):
        z_rows = _stack_rows([leaves[i] for i in gp.index], gp)
        # the host-drawn unit noise stacks into the same leaf-major row
        # layout as the values it perturbs (padding cols get zero noise;
        # they exit through the fallback select regardless)
        lap = _stack_rows([n_leaves[i] for i in gp.index], gp)
        ncols, _ = _group_cols(gp, m)
        R = len(gp.index) * m
        cf_r = jnp.tile(clipf, len(gp.index))
        b_r = jnp.tile(b, len(gp.index))
        # quantizer range covers the CLIPPED pre-noise magnitudes; noisy
        # outliers saturate at the grid edge (bounded-output DP). The
        # scale of a positive row is bit-identical to rowmax(|x * cf|):
        # multiplying by a nonnegative per-row constant is monotone even
        # in floating point.
        scale = jnp.max(jnp.abs(z_rows.astype(jnp.float32)), axis=1) * cf_r
        u32q = (jax.random.bits(gkey, (R, gp.n_max), dtype=jnp.uint32)
                if codec.stochastic
                else jnp.full((R, gp.n_max), 1 << 31, jnp.uint32))
        out_rows = quant_ops.private_quantize_cols(
            z_rows, z_rows, cf_r, b_r, scale, ncols, codec.bits, u32q,
            lap, impl=codec.impl)
        for i, leaf in zip(gp.index, _unstack_rows(out_rows, gp, m)):
            out[i] = leaf
    return jax.tree_util.tree_unflatten(treedef, out)


def private_roundtrip(tree_z, tree_fallback, key: jax.Array,
                      noise, codec: CodecConfig | None, privacy):
    """Clip + DP-noise + codec round-trip; stacked (m, ...) pytrees.

    What the server receives from each client on the private upload path
    (docs/privacy.md): the upload is l1-clipped (clip mode) or taken as-is
    (surrogate mode), perturbed with per-client calibrated noise, then
    pushed through the ordinary codec. ``noise`` is the unit-noise tree
    the HOST drew with :func:`draw_unit_noise` from the dedicated privacy
    key stream (NEVER from the codec key) -- see that docstring for why
    the draws must enter as data. ``privacy`` is a
    ``repro.privacy.PrivacyConfig`` or None; with no noise to add (None
    or eps == 0) this IS ``codec_roundtrip``, bit-for-bit, and ``noise``
    is untouched (callers pass None).

    The dense quantized Laplace configuration -- the paper's mechanism
    under the default codec -- runs as ONE fused kernel launch per dtype
    group (clip + noise + quantize, ``kernels.quant.private_quantize_cols``
    with its quantizer range set by the clipped PRE-noise magnitudes);
    every other configuration (sparse top-k, raw bits=0, no codec,
    gaussian) applies the same clip+noise sequentially and lets the
    existing codec machinery finish the job.
    """
    if privacy is None or privacy.eps <= 0:
        return codec_roundtrip(tree_z, tree_fallback, key, codec)
    leaves, treedef = jax.tree_util.tree_flatten(tree_z)
    m = leaves[0].shape[0]
    clipf, b = privacy_row_params(_client_l1(leaves, m), privacy)
    if (codec is not None and codec.bits >= 2 and codec.topk_frac >= 1.0
            and privacy.mechanism == "laplace"):
        return _fused_private(leaves, treedef, key, noise, codec, clipf, b)
    noisy = _clip_noise_tree(tree_z, noise, clipf, b)
    return codec_roundtrip(noisy, tree_fallback, key, codec)


def private_ef_roundtrip(tree_z, tree_h, key: jax.Array, noise,
                         codec: CodecConfig | None, privacy):
    """Error-feedback variant: EF compresses the NOISY upload's residual.

    Clip+noise runs sequentially in front (the EF accumulate consumes the
    residual against the shared memory h, so the fused quantizer -- whose
    range tracks the raw clipped upload -- does not apply), then
    ``ef_roundtrip`` proceeds unchanged: the codec memory contracts toward
    the noisy z, which is exactly the value the mechanism released. With
    no noise to add this IS ``ef_roundtrip``, bit-for-bit.
    """
    if privacy is None or privacy.eps <= 0:
        return ef_roundtrip(tree_z, tree_h, key, codec)
    leaves, _ = jax.tree_util.tree_flatten(tree_z)
    m = leaves[0].shape[0]
    clipf, b = privacy_row_params(_client_l1(leaves, m), privacy)
    noisy = _clip_noise_tree(tree_z, noise, clipf, b)
    return ef_roundtrip(noisy, tree_h, key, codec)
