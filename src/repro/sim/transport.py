"""Byte-accurate communication accounting and the optional upload codec.

Byte ledger
-----------
Wire sizes are derived from the REAL pytree leaf dtypes/shapes of the state
being exchanged (not a hand-waved parameter count): the server->client
broadcast moves one dense copy of w^{tau+1} per contacted client, the
client->server upload moves one (possibly encoded) copy of z_i per client
whose upload completed within the round. ``ByteLedger`` accumulates both
per round and per client, host-side.

Upload codec (top-k sparsification + uniform stochastic quantization)
---------------------------------------------------------------------
``codec_roundtrip`` models what the server RECEIVES when clients compress
uploads: per leaf, each client keeps the top ceil(topk_frac * n) coordinates
by magnitude, snaps the kept values onto a ``bits``-bit uniform grid
(repro.kernels.quant -- Pallas kernel with a bit-identical jnp reference),
and the server dequantizes BEFORE aggregation, substituting the client's
previous upload z_i^{tau-1} on dropped coordinates. ENS then runs on dense
dequantized uploads, so compressed FedEPM keeps the aggregation math of
core/fedepm.py unchanged: with bits=0 the kept coordinates are transmitted
exactly, and with topk_frac=1, bits=0 the codec is the identity. Dropped
coordinates are a per-coordinate analogue of the paper's eq. (22)
carry-through (the server reuses the stalest value it holds).

Wire format accounted per client per leaf (n coords, k kept):
    dense  (k == n):  n * bits/8 payload + 4 B scale
    sparse (k <  n):  k * bits/8 payload + k * index_bytes + 4 B scale
with bits=0 meaning raw leaf-dtype values (no scale overhead when dense).

Error feedback (``CodecConfig.error_feedback`` + ``ef_roundtrip``)
------------------------------------------------------------------
The memoryless round-trip above silently BIASES the eq. (22) update: the
dropped/rounded-away part of every upload is lost each round. With error
feedback, client and server share a codec memory h_i; the wire carries
C(z_i - h_i) and both sides accumulate h_i <- h_i + C(z_i - h_i)
(kernels/quant fused ``ef_accumulate`` pair), so compressed trajectories
converge to the uncompressed objective (tests/test_sim_async.py pins the
contraction). Same wire format, same byte accounting.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant import ops as quant_ops

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------

def tree_client_bytes(tree) -> int:
    """Dense wire bytes of ONE client's pytree (leaves without client axis)."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def stacked_client_bytes(tree) -> int:
    """Dense wire bytes of ONE client's slice of a stacked (m, ...) pytree."""
    return sum((x.size // x.shape[0]) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Upload compression: keep top-k by magnitude, quantize kept values.

    topk_frac: fraction of each leaf's coordinates kept (1.0 = dense).
    bits: wire bits per kept value (>= 2), or 0 to send kept values raw.
    stochastic: unbiased dithered rounding (True) vs round-half-up.
    impl: quantizer implementation, "ref" (jnp) or "pallas".
    index_bytes: per-kept-coordinate index cost when sparse (k < n).
    error_feedback: EF21-style codec memory -- compress the RESIDUAL
        against a shared reconstruction h_i instead of z_i itself (see
        ``ef_roundtrip``). Wire format and byte accounting are unchanged.
    """

    topk_frac: float = 1.0
    bits: int = 8
    stochastic: bool = True
    impl: str = "ref"
    index_bytes: int = 4
    error_feedback: bool = False

    def __post_init__(self):
        if not (0.0 < self.topk_frac <= 1.0):
            raise ValueError(f"topk_frac must be in (0, 1]; got {self.topk_frac}")
        if self.bits != 0 and self.bits < 2:
            raise ValueError(f"bits must be 0 (raw) or >= 2; got {self.bits}")


def _leaf_k(n: int, frac: float) -> int:
    return n if frac >= 1.0 else max(1, math.ceil(frac * n))


def encoded_client_bytes(tree, codec: CodecConfig | None) -> float:
    """Wire bytes of ONE client's (possibly encoded) upload of a stacked tree."""
    if codec is None:
        return float(stacked_client_bytes(tree))
    total = 0.0
    for x in jax.tree_util.tree_leaves(tree):
        n = x.size // x.shape[0]
        k = _leaf_k(n, codec.topk_frac)
        payload = k * (codec.bits / 8.0 if codec.bits else x.dtype.itemsize)
        index = 0.0 if k == n else k * codec.index_bytes
        scale = 4.0 if codec.bits else (0.0 if k == n else 4.0)
        total += payload + index + scale
    return total


class ByteLedger:
    """Per-round, per-client cumulative communication record (host-side)."""

    def __init__(self, m: int):
        self.m = m
        self.up = np.zeros(m)        # cumulative uplink bytes per client
        self.down = np.zeros(m)      # cumulative downlink bytes per client
        self.rounds: list[dict] = []

    def record_round(self, *, down_mask: np.ndarray, up_mask: np.ndarray,
                     down_bytes: float, up_bytes) -> dict:
        """down_mask: clients the server contacted (they receive the
        broadcast); up_mask: clients whose upload completed; up_bytes:
        scalar or (m,) per-client encoded size."""
        return self.record_counts(
            down_counts=np.asarray(down_mask, bool).astype(np.int64),
            up_counts=np.asarray(up_mask, bool).astype(np.int64),
            down_bytes=down_bytes, up_bytes=up_bytes)

    def record_counts(self, *, down_counts: np.ndarray,
                      up_counts: np.ndarray, down_bytes: float,
                      up_bytes) -> dict:
        """Count-based variant for the async server: one aggregation event
        may contact or receive from the same client several times (a client
        can sit in two overlapping cohorts), so transfers are integer COUNTS
        per client rather than boolean masks. n_down/n_up report distinct
        clients; the byte totals weight by the counts."""
        down_counts = np.asarray(down_counts, np.int64)
        up_counts = np.asarray(up_counts, np.int64)
        up_pc = np.broadcast_to(np.asarray(up_bytes, np.float64), (self.m,))
        d = down_counts * float(down_bytes)
        u = up_counts * up_pc
        self.down += d
        self.up += u
        rec = {"round": len(self.rounds), "down": float(d.sum()),
               "up": float(u.sum()), "n_down": int((down_counts > 0).sum()),
               "n_up": int((up_counts > 0).sum())}
        self.rounds.append(rec)
        return rec

    @property
    def total_up(self) -> float:
        return float(self.up.sum())

    @property
    def total_down(self) -> float:
        return float(self.down.sum())

    @property
    def total(self) -> float:
        return self.total_up + self.total_down


# ---------------------------------------------------------------------------
# codec round-trip (what the server holds after dequantization)
# ---------------------------------------------------------------------------

def _roundtrip_leaf(z, fallback, key, codec: CodecConfig):
    """One stacked leaf (m, ...) -> decoded (m, ...)."""
    m = z.shape[0]
    shape = z.shape
    zf = z.reshape(m, -1)
    n = zf.shape[1]
    k = _leaf_k(n, codec.topk_frac)

    if k < n:
        mag = jnp.abs(zf.astype(jnp.float32))
        _, idx = jax.lax.top_k(mag, k)               # (m, k)
        vals = jnp.take_along_axis(zf, idx, axis=1)  # (m, k)
    else:
        idx = None
        vals = zf

    if codec.bits:
        scale = jnp.max(jnp.abs(vals.astype(jnp.float32)), axis=1)
        u32 = (jax.random.bits(key, vals.shape, dtype=jnp.uint32)
               if codec.stochastic else None)
        vals = quant_ops.quantize(vals, scale, codec.bits, u32,
                                  impl=codec.impl)

    if idx is None:
        return vals.reshape(shape)
    out = jax.vmap(lambda f, i, v: f.at[i].set(v))(
        fallback.reshape(m, -1), idx, vals)
    return out.reshape(shape)


def codec_roundtrip(tree_z, tree_fallback, key: jax.Array,
                    codec: CodecConfig | None):
    """Encode + decode every client's upload; stacked (m, ...) pytrees.

    ``tree_fallback`` supplies dropped coordinates (the server's stale copy,
    normally the previous round's Z). Identity when codec is None.
    """
    if codec is None:
        return tree_z
    leaves, treedef = jax.tree_util.tree_flatten(tree_z)
    fb_leaves = jax.tree_util.tree_leaves(tree_fallback)
    keys = jax.random.split(key, len(leaves))
    out = [_roundtrip_leaf(z, fb, kk, codec)
           for z, fb, kk in zip(leaves, fb_leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# error-feedback round-trip (EF21-style codec memory)
# ---------------------------------------------------------------------------

def _ef_leaf(z, h, key, codec: CodecConfig):
    """One stacked leaf (m, ...) -> updated shared reconstruction (m, ...).

    The client transmits C(z - h) (top-k of the RESIDUAL, quantized against
    the residual's own scale); both sides then hold h' = h + C(z - h). The
    decoded upload IS h', so as z stabilises the residual -- and with it the
    compression error -- contracts to zero instead of being re-paid every
    round. Dense raw (k == n, bits == 0) transmits the residual exactly:
    return z itself so the identity is bit-exact (h + (z - h) re-associates
    in floating point).
    """
    m = z.shape[0]
    shape = z.shape
    zf = z.reshape(m, -1)
    hf = h.reshape(m, -1)
    n = zf.shape[1]
    k = _leaf_k(n, codec.topk_frac)
    r = zf - hf

    if k == n:
        if not codec.bits:
            return z
        scale = jnp.max(jnp.abs(r.astype(jnp.float32)), axis=1)
        u32 = (jax.random.bits(key, r.shape, dtype=jnp.uint32)
               if codec.stochastic else None)
        h_new = quant_ops.ef_accumulate(zf, hf, scale, codec.bits, u32,
                                        impl=codec.impl)
        return h_new.reshape(shape)

    mag = jnp.abs(r.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)                # (m, k)
    vals = jnp.take_along_axis(r, idx, axis=1)    # (m, k) residual values
    if codec.bits:
        scale = jnp.max(jnp.abs(vals.astype(jnp.float32)), axis=1)
        u32 = (jax.random.bits(key, vals.shape, dtype=jnp.uint32)
               if codec.stochastic else None)
        vals = quant_ops.quantize(vals, scale, codec.bits, u32,
                                  impl=codec.impl)
    h_new = jax.vmap(lambda f, i, v: f.at[i].add(v))(hf, idx, vals)
    return h_new.reshape(shape)


def ef_roundtrip(tree_z, tree_h, key: jax.Array, codec: CodecConfig | None):
    """Error-feedback encode + decode; stacked (m, ...) pytrees.

    ``tree_h`` is the shared codec memory (the server's reconstruction after
    the client's last delivered upload; init all-zeros). Returns the NEW
    memory, which is also exactly what the server now holds for each client
    -- callers use it both as the decoded upload and as the next h. Identity
    when codec is None.
    """
    if codec is None:
        return tree_z
    leaves, treedef = jax.tree_util.tree_flatten(tree_z)
    h_leaves = jax.tree_util.tree_leaves(tree_h)
    keys = jax.random.split(key, len(leaves))
    out = [_ef_leaf(z, h, kk, codec)
           for z, h, kk in zip(leaves, h_leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
