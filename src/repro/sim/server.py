"""Event-driven federated server simulation: aggregation over simulated time.

Wraps the UNMODIFIED round functions (core.fedepm.fedepm_round and the
core.baselines rounds) in a client/server timing model: each round the
server contacts a candidate set, clients.round_arrivals draws per-client
completion times from the device profiles, and an aggregation POLICY turns
arrivals into (participation mask, simulated round duration):

  sync        -- wait for every contacted available client; round time is
                 the slowest arrival (stragglers gate the round).
  deadline    -- drop candidates past a wall-clock cutoff; dropped clients
                 carry state through exactly as the paper's eq. (22)
                 non-selected clients do (the mask hook reuses the same
                 tree_where_client carry path). Round time is the deadline
                 when anyone misses it, else the slowest arrival.
  overselect  -- contact a uniform candidate set drawn at rate rho*factor
                 (the sampler's |S| = round(rho*factor*m) convention),
                 aggregate the first ceil(rho*m) arrivals; round time is
                 the last kept arrival.

The mask is fed into the round via ``fedepm_round(..., mask=...)`` -- the
selection key stream is unchanged, so with policy="sync", full availability,
deterministic latency and no codec the simulated trajectory is BIT-FOR-BIT
the one core.fedepm produces on its own (tests/test_sim.py asserts this).

A round in which no candidate reports before the cutoff is ABANDONED: the
algorithm state is untouched (no key advance -- the server never aggregated),
the wasted broadcast bytes are still charged, and simulated time advances to
the deadline-policy cutoff, matching min-report-count behaviour of
production FL servers. (A sync round with every contacted client offline
has no cutoff to wait for and costs zero simulated time.)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fedepm, participation
from repro.core.treeutil import tree_size, tree_where_client
from repro.sim import clients as simclients
from repro.sim.transport import (
    ByteLedger,
    CodecConfig,
    codec_roundtrip,
    encoded_client_bytes,
    tree_client_bytes,
)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    policy: str = "sync"            # "sync" | "deadline" | "overselect"
    deadline: float = math.inf      # seconds, deadline policy cutoff
    overselect_factor: float = 1.5  # candidate draw rate = rho * factor
    latency: str = "deterministic"  # clients.make_latency_model kind
    latency_sigma: float = 0.5
    latency_alpha: float = 1.2
    seed: int = 0
    codec: CodecConfig | None = None


class SimMetrics(NamedTuple):
    round_idx: int
    t_round: float       # simulated duration of this round (s)
    t_total: float       # cumulative simulated wall-clock (s)
    n_contacted: int     # candidates the server broadcast to
    n_aggregated: int    # uploads that made it into the aggregate
    n_dropped: int       # contacted but not aggregated (stragglers/offline)
    bytes_down: float
    bytes_up: float
    abandoned: bool      # nobody reported before the cutoff


def client_work_flops(alg: str, *, k0: int, n_params: int, d_local: float,
                      prox_ell: int = 3) -> float:
    """Rough per-round client compute model (flops), for arrival times only.

    One loss gradient over d_local samples of an n_params model is ~4
    flops/sample/param (forward + backward matvec); FedEPM adds k0 cheap
    closed-form prox steps (~12 flops/param incl. the mu norm), the
    baselines re-evaluate the gradient every inner step (eqs. (35)/(36)).
    """
    grad = 4.0 * d_local * n_params
    if alg == "fedepm":
        return grad + k0 * 12.0 * n_params
    if alg == "sfedavg":
        return k0 * grad
    if alg == "sfedprox":
        return k0 * prox_ell * grad
    raise ValueError(f"unknown alg {alg!r}")


def _batches_d_local(batches) -> float:
    """Mean per-client sample count, from the validity mask when present."""
    if isinstance(batches, dict) and "mask" in batches:
        msk = np.asarray(batches["mask"])
        return float(msk.reshape(msk.shape[0], -1).sum(axis=1).mean())
    leaves = jax.tree_util.tree_leaves(batches)
    return float(leaves[0].shape[1]) if leaves and leaves[0].ndim > 1 else 1.0


_ALGS: dict[str, tuple[Callable, Callable]] = {
    "fedepm": (fedepm.fedepm_round, fedepm.default_round_mask),
    "sfedavg": (baselines.sfedavg_round, baselines.default_round_mask),
    "sfedprox": (baselines.sfedprox_round, baselines.default_round_mask),
}


class FedSim:
    """Drives one algorithm under one aggregation policy over simulated time.

    Parameters
    ----------
    alg : "fedepm" | "sfedavg" | "sfedprox"
    cfg : the algorithm's own config (FedEPMConfig / BaselineConfig) --
          the sim never alters it, so the math stays core/'s.
    state : initial algorithm state (init_state of the respective module).
    batches, loss_fn : as taken by the round functions.
    profiles : device heterogeneity (clients.make_profiles); default uniform.
    sim : SimConfig policy/latency/codec settings.
    work_flops : override the per-round client compute estimate.
    """

    def __init__(self, *, alg: str, cfg: Any, state: Any, batches: Any,
                 loss_fn: Callable, profiles=None,
                 sim: SimConfig = SimConfig(),
                 work_flops: float | None = None):
        if alg not in _ALGS:
            raise ValueError(f"unknown alg {alg!r}")
        round_fn, mask_fn = _ALGS[alg]
        self.alg = alg
        self.cfg = cfg
        self.sim = sim
        self.state = state
        self.profiles = profiles if profiles is not None \
            else simclients.uniform_profiles(cfg.m)
        if self.profiles.m != cfg.m:
            raise ValueError(
                f"profiles for m={self.profiles.m} but cfg.m={cfg.m}")
        self._latency = simclients.make_latency_model(
            sim.latency, sigma=sim.latency_sigma, alpha=sim.latency_alpha)
        self._rng = np.random.default_rng(sim.seed)
        self._codec_key = jax.random.PRNGKey(sim.seed ^ 0x5EED)

        self._step = jax.jit(
            lambda s, mask: round_fn(s, batches, loss_fn, cfg, mask))
        self._default_mask = jax.jit(lambda s: mask_fn(s, cfg))
        if sim.policy == "overselect":
            # over-selection draws its own (bigger) uniform candidate set;
            # a coverage/full sampler's guarantee would be silently lost,
            # so refuse rather than mislead
            if getattr(cfg, "sampler", "uniform") != "uniform":
                raise ValueError(
                    "policy='overselect' only supports the uniform sampler; "
                    f"got cfg.sampler={cfg.sampler!r}")
            rho_eff = min(1.0, cfg.rho * sim.overselect_factor)

            def cand(s):
                _, k_sel, _ = jax.random.split(s.key, 3)
                return participation.sample_uniform(k_sel, cfg.m, rho_eff)

            self._candidates = jax.jit(cand)
        else:
            self._candidates = self._default_mask
        self._n_keep = min(cfg.m, max(1, math.ceil(cfg.rho * cfg.m)))

        # byte model from the real state trees
        self._down_bytes = float(tree_client_bytes(state.w_tau))
        self._up_bytes = float(encoded_client_bytes(state.Z, sim.codec))
        self.ledger = ByteLedger(cfg.m)

        if sim.codec is not None:
            codec = sim.codec

            @jax.jit
            def codec_merge(z_new, z_prev, mask, key):
                z_dec = codec_roundtrip(z_new, z_prev, key, codec)
                return tree_where_client(mask, z_dec, z_prev)

            self._codec_merge = codec_merge

        self._work = work_flops if work_flops is not None else \
            client_work_flops(alg, k0=cfg.k0,
                              n_params=tree_size(state.w_tau),
                              d_local=_batches_d_local(batches))
        self.t = 0.0
        self.round_idx = 0
        self.metrics: list[SimMetrics] = []
        self.last_round_metrics = None  # algorithm RoundMetrics of last round

    @property
    def up_bytes_per_client(self) -> float:
        """Encoded uplink wire bytes one client sends per round."""
        return self._up_bytes

    @property
    def down_bytes_per_client(self) -> float:
        """Dense broadcast wire bytes one contacted client receives."""
        return self._down_bytes

    # -- policy -------------------------------------------------------------

    def _apply_policy(self, candidates: np.ndarray, arrivals: np.ndarray):
        """-> (mask (m,) bool, round duration seconds).

        Mask semantics live in core.participation (arrival_mask /
        first_arrivals_mask) so the jit-safe helpers and the sim cannot
        drift; only the round-duration bookkeeping is computed here.
        """
        pol = self.sim.policy
        cand_j = jnp.asarray(candidates)
        arr_j = jnp.asarray(arrivals)
        t_cand = np.where(candidates, arrivals, np.inf)
        if pol == "sync":
            # wait for every contacted client that is alive; an all-offline
            # round has no natural duration (sync has no cutoff) => 0.0
            mask = np.asarray(participation.arrival_mask(
                cand_j, arr_j, np.inf))
            dur = float(t_cand[mask].max()) if mask.any() else 0.0
            return mask, dur
        if pol == "deadline":
            dl = self.sim.deadline
            mask = np.asarray(participation.arrival_mask(cand_j, arr_j, dl))
            if not candidates.any():
                return mask, 0.0
            finite = t_cand[np.isfinite(t_cand)]
            if np.isfinite(t_cand[candidates]).all() \
                    and (t_cand[candidates] <= dl).all():
                return mask, float(t_cand[candidates].max())  # all beat it
            if np.isfinite(dl):                     # someone missed it
                return mask, float(dl)
            # infinite deadline but offline candidates: wait out the finite
            return mask, float(finite.max()) if finite.size else 0.0
        if pol == "overselect":
            mask = np.asarray(participation.first_arrivals_mask(
                cand_j, arr_j, self._n_keep))
            dur = float(t_cand[mask].max()) if mask.any() else 0.0
            return mask, dur
        raise ValueError(f"unknown policy {pol!r}")

    # -- one simulated round ------------------------------------------------

    def step(self) -> SimMetrics:
        candidates = np.asarray(self._candidates(self.state))
        arrivals = simclients.round_arrivals(
            self.profiles, self._rng, self._latency,
            work_flops=self._work, down_bytes=self._down_bytes,
            up_bytes=self._up_bytes)
        mask, dur = self._apply_policy(candidates, arrivals)

        abandoned = candidates.any() and not mask.any()
        if abandoned:
            # server waited out the round (dur from the policy) and nobody
            # reported: algorithm state untouched, broadcast bytes spent
            rec_up = np.zeros(self.cfg.m, bool)
        else:
            prev_state = self.state
            new_state, rmetrics = self._step(
                self.state, jnp.asarray(mask))
            if self.sim.codec is not None:
                key = jax.random.fold_in(self._codec_key, self.round_idx)
                new_state = new_state._replace(Z=self._codec_merge(
                    new_state.Z, prev_state.Z, jnp.asarray(mask), key))
            self.state = new_state
            self.last_round_metrics = rmetrics
            # uploads that completed within the round window (kept clients
            # plus over-selection ties); stragglers cut at the deadline
            # never finish their upload, offline clients never start one
            rec_up = np.asarray(candidates & np.isfinite(arrivals)
                                & (arrivals <= dur + 1e-12))

        brec = self.ledger.record_round(
            down_mask=candidates, up_mask=rec_up,
            down_bytes=self._down_bytes, up_bytes=self._up_bytes)
        self.t += dur
        m = SimMetrics(
            round_idx=self.round_idx, t_round=dur, t_total=self.t,
            n_contacted=int(candidates.sum()),
            n_aggregated=int(mask.sum()),
            n_dropped=int(candidates.sum()) - int(mask.sum()),
            bytes_down=brec["down"], bytes_up=brec["up"],
            abandoned=bool(abandoned))
        self.metrics.append(m)
        self.round_idx += 1
        return m

    def run(self, rounds: int) -> list[SimMetrics]:
        return [self.step() for _ in range(rounds)]
