"""Event-driven federated server simulation: aggregation over simulated time.

Wraps the UNMODIFIED round functions (core.fedepm.fedepm_round and the
core.baselines rounds) in a client/server timing model: each round the
server contacts a candidate set, clients.round_arrivals draws per-client
completion times from the device profiles, and an aggregation POLICY turns
arrivals into (participation mask, simulated round duration):

  sync        -- wait for every contacted available client; round time is
                 the slowest arrival (stragglers gate the round).
  deadline    -- drop candidates past a wall-clock cutoff; dropped clients
                 carry state through exactly as the paper's eq. (22)
                 non-selected clients do (the mask hook reuses the same
                 tree_where_client carry path). Round time is the deadline
                 when anyone misses it, else the slowest arrival.
  adaptive    -- per-client deadlines learned online: an EWMA of observed
                 report latencies (clients.AdaptiveDeadlines) budgets each
                 round's wait for client i at slack*ewma_i; never-observed
                 clients get an infinite budget, so round 1 degrades to
                 sync and the cutoffs tighten as evidence arrives. Dropped
                 clients carry through via eq. (22) as under ``deadline``.
  overselect  -- contact a uniform candidate set drawn at rate rho*factor
                 (the sampler's |S| = round(rho*factor*m) convention),
                 aggregate the first ceil(rho*m) arrivals; round time is
                 the last kept arrival.
  async       -- FedBuff-style buffered asynchrony; see below.

Asynchronous client-level dispatch (policy="async")
---------------------------------------------------
The server no longer runs in rounds, and -- since the client-level
refactor -- no longer dispatches in cohort lockstep either. It keeps ONE
time-ordered event queue of per-client events:

  start  -- client i receives the broadcast and begins local work. Fires
            only while the number of in-flight clients is below
            ``max_concurrency`` (0 = unlimited); slot-blocked starts wait
            in a FIFO and fire the moment an upload frees a slot.
  upload -- client i's contribution arrives at the server and is appended
            to the aggregation buffer.

Selection stays on the SAME key stream as sync: whenever the system runs
below one cohort of work (at step entry, or when the queue drains
mid-fill) the server draws the next cohort mask and queues one start
event per live member; unreachable members cost their broadcast bytes
immediately and never occupy a slot. Start events that fire at the same
instant batch into one round-function call (one key advance), so an
uncapped server dispatches whole cohorts exactly like the old cohort
engine, while a capped server trickles the cohort out client by client --
each later client trains on the broadcast CURRENT at its own start time,
not the one its cohort-mates saw.

An aggregation applies once ``buffer_size`` contributions are in. Clients
therefore train on STALE broadcasts: a contribution dispatched at server
version v and merged at version v' has staleness s = v' - v and is folded
into the server's Z with weight gamma = (1 + s)^(-staleness_exp)
(participation.staleness_weight, the FedBuff convention), i.e.
Z_i <- gamma * z_i + (1 - gamma) * Z_i. One ``step()`` is one aggregation
event. For the BASELINE algorithms each dispatch group additionally
anchors its broadcast on the live membership of the newest cohort draw
(``agg_mask`` hook in core/baselines.py): eq. (34)'s selected-mean then
averages over the whole cohort's latest uploads instead of degenerating
to a one-client mean when the concurrency cap splits a cohort.

With max_concurrency >= cohort, buffer_size = cohort, full availability
and no codec, every start fires instantly, every contribution merges at
staleness 0 (gamma = 1 exactly), and the event sequence degenerates to
dispatch -> drain -> merge -> dispatch: the trajectory is BIT-FOR-BIT the
synchronous one (tests/test_sim_async.py, for FedEPM and the baselines).
A cohort draw that is entirely offline leaves the algorithm state
(including the key) untouched, exactly like an abandoned sync round; after
_MAX_DRY_DISPATCHES consecutive such draws the step gives up and reports
abandoned=True.

Both engines run this SAME event loop. All device work routes through a
three-method executor seam (draw_candidates / fire / merge): the eager
executor below performs it at each event, while the scan engine
(repro.sim.engine) swaps in a recording executor that defers fires and
merges into an op program one compiled ``lax.scan`` replays over a
fixed-capacity payload table. Every host-side quantity -- clock, metrics,
ledger, staleness, telemetry -- is computed by identical pump code either
way, which is what makes scan async bit-for-bit comparable to eager
(tests/test_engine_async.py).

The mask is fed into the round via ``fedepm_round(..., mask=...)`` -- the
selection key stream is unchanged, so with policy="sync", full availability,
deterministic latency and no codec the simulated trajectory is BIT-FOR-BIT
the one core.fedepm produces on its own (tests/test_sim.py asserts this).

A round in which no candidate reports before the cutoff is ABANDONED: the
algorithm state is untouched (no key advance -- the server never aggregated),
the wasted broadcast bytes are still charged, and simulated time advances to
the deadline-policy cutoff, matching min-report-count behaviour of
production FL servers. (A sync round with every contacted client offline
has no cutoff to wait for and costs zero simulated time.)

Fault injection (SimConfig.faults, repro.sim.faults)
----------------------------------------------------
With a ``FaultConfig`` attached the server consults a seeded
``FaultModel`` at its arrival points and runs the defenses in the shared
host code: quarantined clients are removed from the candidate set before
dispatch; each upload runs an attempt chain (mid-flight drop / transient
failure with retry + exponential backoff / corruption screened and
counted toward quarantine / clean delivery, possibly duplicated and
deduped); every fired attempt is billed to the byte ledger via the count
path. A round that loses its whole cohort to faults is abandoned exactly
like a deadline miss. All decisions are host-side and replayed
identically by the scan engine, so fault-injected runs stay bit-for-bit
across engines; ``faults=None`` (any zero-rate config) leaves every path
above byte-identical to the fault-free simulator.

Upload privacy (SimConfig.privacy, repro.privacy)
-------------------------------------------------
With a ``PrivacyConfig`` attached the upload path runs through
``transport.private_roundtrip`` (clip + calibrated DP noise in front of
the codec, fused into one kernel launch on the dense quantized Laplace
configuration), a host-side per-client accountant charges ``eps`` for
every MERGED contribution (``privacy_charge`` telemetry events), and --
with secure aggregation on -- every upload attempt that reaches the wire
carries ``mask_bytes`` of pairwise-mask exchange, folded into the
per-upload wire size so the ByteLedger bills masks under exactly the
same rule as payloads (clean arrivals + retries + discarded duplicates).
Noise is drawn from a dedicated privacy key stream
(``fold_in(privacy_key, round_idx)`` clocked, ``fold_in(privacy_key,
serial)`` async), so both engines reproduce every draw bit-for-bit;
``privacy=None`` (or any inert config) leaves every path above
byte-identical to the pre-privacy simulator.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import functools
import heapq
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fedepm, participation
from repro.core.treeutil import tmap, tree_size, tree_where_client
from repro.privacy import PrivacyConfig, build_privacy_model
from repro.sim import clients as simclients
from repro.sim.faults import FaultConfig, FaultRoundOutcome, build_fault_model
from repro.sim.transport import (
    ByteLedger,
    CodecConfig,
    codec_event_attrs,
    codec_roundtrip,
    draw_unit_noise,
    ef_roundtrip,
    encoded_client_bytes,
    private_ef_roundtrip,
    private_roundtrip,
    tree_client_bytes,
)
from repro.telemetry.events import NULL_RECORDER

_POLICIES = ("sync", "deadline", "adaptive", "overselect", "async")

# async: consecutive all-offline cohort draws before a step gives up
_MAX_DRY_DISPATCHES = 3

# fault injection only: in-loop cohort draws one aggregation event may
# make before it stops waiting for a full buffer and merges what it has.
# Under heavy loss every draw can come up live-but-lost -- the dry counter
# above never trips (the cohorts ARE live) yet the buffer never fills, so
# without this cap a drop_rate=1.0 run would pump forever.
_MAX_FAULT_SELECTS = 8

# event-queue kinds (heap entries sort by (time, push sequence, kind))
_EV_START = 0    # payload: (client index, round-trip duration seconds)
_EV_UPLOAD = 1   # payload: _Contribution


@dataclasses.dataclass(frozen=True)
class SimConfig:
    policy: str = "sync"            # one of _POLICIES
    deadline: float = math.inf      # seconds, deadline policy cutoff
    overselect_factor: float = 1.5  # candidate draw rate = rho * factor
    latency: str = "deterministic"  # clients.make_latency_model kind
    latency_sigma: float = 0.5
    latency_alpha: float = 1.2
    seed: int = 0
    codec: CodecConfig | None = None
    # async (buffered) aggregation
    buffer_size: int = 0            # contributions per aggregation; 0 = cohort
    staleness_exp: float = 0.5      # gamma = (1 + staleness)^-exp
    max_concurrency: int = 0        # async: in-flight client cap; 0 = no cap
    # adaptive per-client deadlines
    deadline_slack: float = 2.0     # wait budget = slack * ewma_i
    ewma_beta: float = 0.3          # EWMA weight of the newest observation
    # fault injection (repro.sim.faults); None = the fault-free simulator
    faults: FaultConfig | None = None
    # upload privacy (repro.privacy); None = the pre-privacy simulator
    privacy: PrivacyConfig | None = None


class SimMetrics(NamedTuple):
    round_idx: int
    t_round: float       # simulated duration of this round (s)
    t_total: float       # cumulative simulated wall-clock (s)
    n_contacted: int     # candidates the server broadcast to
    n_aggregated: int    # uploads that made it into the aggregate
    n_dropped: int       # contacted but not aggregated (stragglers/offline)
    bytes_down: float
    bytes_up: float
    abandoned: bool      # nobody reported before the cutoff
    staleness_mean: float = 0.0  # async: mean versions-behind of the merge
    staleness_max: int = 0       # async: worst versions-behind of the merge


def make_sim_metrics(*, round_idx: int, t_round: float, t_total: float,
                     n_contacted: int, n_aggregated: int, brec: dict,
                     abandoned: bool, staleness=(),
                     n_dropped: int | None = None) -> SimMetrics:
    """The ONE SimMetrics constructor both engines use.

    The eager server and the scan engine's host bookkeeping loop build
    their per-round metrics through this helper, so the two paths cannot
    drift apart field-by-field (tests/test_engine.py pins schema equality).
    ``brec`` is the ByteLedger record of the round; ``staleness`` the
    per-merged-contribution versions-behind sequence (clocked rounds merge
    at staleness 0 and pass the default).
    """
    staleness = list(staleness)
    return SimMetrics(
        round_idx=round_idx, t_round=t_round, t_total=t_total,
        n_contacted=int(n_contacted), n_aggregated=int(n_aggregated),
        n_dropped=int(n_contacted) - int(n_aggregated)
        if n_dropped is None else int(n_dropped),
        bytes_down=brec["down"], bytes_up=brec["up"],
        abandoned=bool(abandoned),
        staleness_mean=float(np.mean(staleness)) if staleness else 0.0,
        staleness_max=int(max(staleness)) if staleness else 0)


def emit_clocked_round_events(rec, *, policy: str, round_idx: int,
                              t0: float, candidates: np.ndarray,
                              arrivals: np.ndarray, mask: np.ndarray,
                              dur: float, rec_up: np.ndarray,
                              abandoned: bool,
                              codec: CodecConfig | None,
                              up_bytes: float,
                              faults: "FaultRoundOutcome | None" = None
                              ) -> None:
    """Emit one clocked round's telemetry events (sync/deadline/adaptive/
    overselect; policy="async" has its own event-loop instrumentation).

    Called with the round's already-computed host arrays by BOTH the eager
    server and the scan engine's bookkeeping loop -- the same inputs
    produce the same stream, which is what makes eager and scan runs
    comparable event-for-event (tests/test_telemetry.py pins it).
    Timestamps: dispatches at the round's start ``t0``, each upload at
    ``t0 + min(arrival, dur)`` (a straggler's upload is cut at the round
    end), merge/abandon at ``t0 + dur``.
    """
    rec.event("round_start", ts=t0, round_idx=round_idx, policy=policy)
    for i in np.flatnonzero(candidates):
        a = float(arrivals[i])
        if math.isfinite(a):
            rec.event("dispatch", ts=t0, round_idx=round_idx, client=int(i),
                      arrival_s=a)
        else:
            rec.event("dispatch", ts=t0, round_idx=round_idx, client=int(i),
                      live=False)
    for i in np.flatnonzero(rec_up):
        rec.event("upload_arrival", ts=t0 + min(float(arrivals[i]), dur),
                  round_idx=round_idx, client=int(i))
    t_end = t0 + dur
    if faults is not None:
        # fault resolution happened DURING the round: events carry the
        # attempt-chain times relative to the round start (a lost upload's
        # timestamp may exceed ``dur`` -- the server had already moved on)
        for cl, t_ev, att in faults.retries:
            rec.event("retry", ts=t0 + t_ev, round_idx=round_idx,
                      client=cl, attempt=att)
        for cl, t_ev, reason in faults.drops:
            rec.event("upload_drop", ts=t0 + t_ev, round_idx=round_idx,
                      client=cl, reason=reason)
        for cl, t_ev in faults.duplicates:
            rec.event("duplicate_discard", ts=t0 + t_ev,
                      round_idx=round_idx, client=cl)
        for cl, until in faults.quarantines:
            rec.event("quarantine", ts=t_end, round_idx=round_idx,
                      client=cl, until_round=until)
    if abandoned:
        rec.event("abandon", ts=t_end, round_idx=round_idx,
                  n_contacted=int(candidates.sum()))
        return
    n_agg = int(mask.sum())
    if codec is not None and n_agg:
        rec.event("codec_encode", ts=t_end, round_idx=round_idx,
                  **codec_event_attrs(codec, n_clients=n_agg,
                                      up_bytes=up_bytes))
    rec.event("merge", ts=t_end, round_idx=round_idx, n=n_agg, t_round=dur)


def apply_clocked_privacy(privacy, rec, *, round_idx: int, t_end: float,
                          mask: np.ndarray, rec_up: np.ndarray,
                          faults: "FaultRoundOutcome | None" = None) -> None:
    """One clocked round's privacy bookkeeping (accountant + mask billing).

    Shared by the eager server and the scan engine's host loop, called
    right after ``emit_clocked_round_events`` with the same host arrays,
    so accountant totals and the ``privacy_charge``/``mask_exchange``
    event stream are identical between engines. ``privacy`` is the
    ``PrivacyModel`` (None = no-op). Mask attempts equal the round's
    billed upload count -- delivered uploads plus every fault attempt
    that reached the wire -- which is exactly what the ByteLedger's count
    path charges, so mask bytes and ledger bytes cannot drift. Charges
    apply to MERGED clients only (the mask), never to stragglers or
    fault-lost uploads: their noisy payloads were never consumed.
    """
    if privacy is None:
        return
    cfg = privacy.cfg
    attempts = int(np.asarray(rec_up).sum())
    if faults is not None:
        attempts += int(faults.extra_up.sum())
    mbytes = privacy.bill_masks(attempts)
    if cfg.secure_agg and attempts and rec.enabled:
        rec.event("mask_exchange", ts=t_end, round_idx=round_idx,
                  attempts=attempts, bytes=mbytes)
    if cfg.eps > 0:
        for i in np.flatnonzero(np.asarray(mask)):
            tot = privacy.charge(int(i))
            if rec.enabled:
                rec.event("privacy_charge", ts=t_end, round_idx=round_idx,
                          client=int(i), eps=cfg.eps, eps_total=tot)


@dataclasses.dataclass
class _Contribution:
    """One in-flight client upload (async policy).

    The dispatch group's uploaded rows are gathered into ONE stacked batch
    per group (``_fire_group``); each contribution references its row of
    that shared batch instead of holding a privately sliced (1, ...) copy,
    so a g-client dispatch costs one gather per leaf, not 2g slice ops.

    Under the scan engine (repro.sim.engine) the batch is the engine's
    fixed-capacity payload TABLE instead of a per-group gather: ``slot`` is
    the table row holding this upload, ``z_batch``/``w_batch`` point at the
    table trees once the recording chunk has been replayed (None while the
    upload only exists as a recorded fire op). A table IS a batch, so a
    later eager ``step()`` merges a table-backed contribution through the
    exact same ``merge_contribution`` path.
    """

    client: int
    version: int   # server version at dispatch (staleness anchor)
    serial: int    # global upload serial (codec dither stream)
    z_batch: Any   # (g_pad, ...) stacked upload rows of the dispatch group
    w_batch: Any   # (g_pad, ...) stacked iterate rows of the dispatch group
    row: int       # this client's row within the batch
    slot: int = -1  # scan engine: payload-table row (-1 = eager batch mode)
    attempt: int = 1  # fault injection: delivery attempt (1 = original)
    dup: bool = False  # fault injection: duplicate ghost (never merged;
    #                    carries no batch refs and owns no table slot)


def merge_contribution(Z, W, H, z_batch, w_batch, batch_row, idx, gamma,
                       key, noise, *, codec: CodecConfig | None, ef: bool,
                       privacy: PrivacyConfig | None = None):
    """Fold one arrived upload into the server's stacked state (PURE).

    The ONE merge/staleness function both engines call: the eager event
    loop dispatches it through the jitted ``_merge_contribution`` wrapper
    below, and the scan engine (repro.sim.engine) traces it directly inside
    its compiled async chunk with the payload table as the batch -- one
    definition, so the two paths cannot drift.

    ``batch_row`` selects the contribution's row out of its dispatch
    group's shared (g_pad, ...) batch (a dynamic slice, so one compiled
    program serves every row; group batches are padded to power-of-two
    sizes, bounding recompiles to log2 of the cohort). The upload is
    decoded first (codec memoryless fallback = the server's CURRENT stale
    row; with error feedback the shared memory row in H), then
    staleness-merged: Z_i <- gamma * z_hat + (1 - gamma) * Z_i. The
    gamma >= 1 branch replaces the row EXACTLY (no arithmetic), which is
    what makes the zero-staleness trajectory bit-identical to sync. W_i is
    replaced outright -- it is the client's own iterate, which the client
    reports authoritatively; only the aggregate-facing Z is down-weighted.

    With a noisy ``privacy`` config the decode runs through the private
    round-trips instead (clip + DP noise in front of the codec); ``noise``
    is the contribution's (1, ...) unit-noise tree, host-drawn from the
    privacy stream folded on the upload serial
    (``transport.draw_unit_noise`` -- data, so eager and scan consume
    bit-identical draws). Privacy None (or eps == 0) reduces every branch
    to the historical path bit-for-bit and ``noise`` is unused (callers
    pass None).
    """
    def row(tree):
        return tmap(
            lambda x: jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=0), tree)

    def batch(tree):
        return tmap(
            lambda x: jax.lax.dynamic_slice_in_dim(x, batch_row, 1, axis=0),
            tree)

    def set_row(tree, r):
        return tmap(
            lambda x, rr: jax.lax.dynamic_update_slice_in_dim(
                x, rr.astype(x.dtype), idx, axis=0), tree, r)

    z_row = batch(z_batch)
    w_row = batch(w_batch)

    noisy = privacy is not None and privacy.eps > 0
    if codec is None and not noisy:
        z_hat = z_row
        H_new = H
    elif ef:
        z_hat = (private_ef_roundtrip(z_row, row(H), key, noise, codec,
                                      privacy) if noisy
                 else ef_roundtrip(z_row, row(H), key, codec))
        H_new = set_row(H, z_hat)
    else:
        z_hat = (private_roundtrip(z_row, row(Z), key, noise, codec, privacy)
                 if noisy
                 else codec_roundtrip(z_row, row(Z), key, codec))
        H_new = H

    def zmerge(zl, r):
        cur = jax.lax.dynamic_slice_in_dim(zl, idx, 1, axis=0)
        new = jnp.where(gamma >= 1.0, r, gamma * r + (1.0 - gamma) * cur)
        return jax.lax.dynamic_update_slice_in_dim(
            zl, new.astype(zl.dtype), idx, axis=0)

    return tmap(zmerge, Z, z_hat), set_row(W, w_row), H_new


#: jitted entry point of :func:`merge_contribution` (the eager path)
_merge_contribution = functools.partial(
    jax.jit, static_argnames=("codec", "ef", "privacy"))(merge_contribution)


def copy_tree(tree):
    """Fresh device copies of every leaf (donation/snapshot safety)."""
    return tmap(lambda x: jnp.array(x, copy=True), tree)


def client_work_flops(alg: str, *, k0: int, n_params: int, d_local: float,
                      prox_ell: int = 3) -> float:
    """Rough per-round client compute model (flops), for arrival times only.

    One loss gradient over d_local samples of an n_params model is ~4
    flops/sample/param (forward + backward matvec); FedEPM adds k0 cheap
    closed-form prox steps (~12 flops/param incl. the mu norm), the
    baselines re-evaluate the gradient every inner step (eqs. (35)/(36)).
    """
    grad = 4.0 * d_local * n_params
    if alg == "fedepm":
        return grad + k0 * 12.0 * n_params
    if alg == "sfedavg":
        return k0 * grad
    if alg == "sfedprox":
        return k0 * prox_ell * grad
    raise ValueError(f"unknown alg {alg!r}")


def _batches_d_local(batches) -> float:
    """Mean per-client sample count, from the validity mask when present."""
    if isinstance(batches, dict) and "mask" in batches:
        msk = np.asarray(batches["mask"])
        return float(msk.reshape(msk.shape[0], -1).sum(axis=1).mean())
    leaves = jax.tree_util.tree_leaves(batches)
    return float(leaves[0].shape[1]) if leaves and leaves[0].ndim > 1 else 1.0


_ALGS: dict[str, tuple[Callable, Callable]] = {
    "fedepm": (fedepm.fedepm_round, fedepm.default_round_mask),
    "sfedavg": (baselines.sfedavg_round, baselines.default_round_mask),
    "sfedprox": (baselines.sfedprox_round, baselines.default_round_mask),
}

# jitted-program cache shared ACROSS FedSim instances (bounded FIFO): a
# fresh per-instance ``jax.jit(lambda ...)`` re-traces on every
# construction, so benchmark/test code that builds many sims over the same
# (round fn, loss fn, config, batches) pays a full trace+compile per
# instance. Batches are keyed by identity; the cached closure keeps them
# alive, so the id cannot be recycled while the entry exists.
# ``fifo_cache_get`` is the one get-or-build-with-eviction helper; the
# engine's compiled-chunk caches (repro.sim.engine) use it too.
_JIT_CACHE: dict = {}


def fifo_cache_get(cache: dict, key, build: Callable, *, cap: int = 64):
    """Bounded memo: build-on-miss, FIFO eviction once ``cap`` is reached.

    Entries hold compiled closures that may pin device buffers (batches),
    so the bound is what keeps long sweeps over many tasks from leaking
    one dataset per cache entry.
    """
    fn = cache.get(key)
    if fn is None:
        if len(cache) >= cap:
            cache.pop(next(iter(cache)))
        fn = cache[key] = build()
    return fn


def _shared_jit(key, build: Callable):
    return fifo_cache_get(_JIT_CACHE, key, build)


class _EagerAsyncExec:
    """Device-work executor behind the async event loop (the reference).

    ``_pump_async`` is ONE scheduling implementation shared by both
    engines; everything that touches a jax array routes through this
    three-method seam. The eager executor performs the device work at the
    event, exactly as the pre-refactor event loop did. The scan engine
    (repro.sim.engine) swaps in a RECORDING executor that replays candidate
    draws from a precomputed key stream and defers fires/merges into a
    program one compiled ``lax.scan`` executes -- every host-side quantity
    (clock, metrics, ledger, telemetry, staleness) is computed by the same
    pump code either way, which is what makes the two engines comparable
    event-for-event.
    """

    recording = False

    def draw_candidates(self, sim) -> np.ndarray:
        cand = np.asarray(sim._candidates(sim.state))
        sim.host_syncs += 1
        return cand

    def fire(self, sim, group, mask: np.ndarray, contribs) -> None:
        """Run the round function for a dispatch group NOW; gather the
        group's upload/iterate rows into a shared batch and attach them to
        the group's contributions."""
        if sim._step_agg is not None:
            # baselines: anchor eq. (34)'s mean on the whole live cohort so
            # a capped sub-group dispatch still mixes across clients (the
            # uncapped group IS the cohort, recovering sync exactly). The
            # union with the group keeps the anchor non-empty even when a
            # NEWER cohort draw came up all-offline while this group sat
            # stalled (an empty mean would broadcast a zero vector).
            new_state, rmetrics = sim._step_agg(
                sim.state, sim._dev_mask(mask),
                sim._dev_mask(sim._cohort_live | mask))
        else:
            new_state, rmetrics = sim._step(sim.state, sim._dev_mask(mask))
        sim.state = sim.state._replace(
            w_tau=new_state.w_tau, k=new_state.k, key=new_state.key)
        sim.last_round_metrics = rmetrics
        # one gather per leaf for the whole group's upload/iterate rows
        # (vs 2 slice ops per CLIENT); indices pad to the next power of two
        # (repeating the last) so _merge_contribution compiles per pow2
        # bucket, not per group size
        idx = np.fromiter((i for i, _ in group), np.int64, len(group))
        pad = 1 << (len(group) - 1).bit_length() if len(group) > 1 else 1
        rows = jnp.asarray(np.concatenate(
            [idx, np.full(pad - len(group), idx[-1], np.int64)]))
        z_batch = tmap(lambda x: x[rows], new_state.Z)
        w_batch = tmap(lambda x: x[rows], new_state.W)
        for j, c in enumerate(contribs):
            c.z_batch, c.w_batch, c.row = z_batch, w_batch, j

    def merge(self, sim, c: "_Contribution", staleness: int,
              gamma: float) -> None:
        """Staleness-merge one arrived contribution into the server state."""
        key = jax.random.fold_in(sim._codec_key, c.serial)
        # the privacy stream folds on the same serial; the unit-noise
        # draw happens host-side in its own program (draw_unit_noise) so
        # the scan engine's replayed merges consume bit-identical noise
        noise = (draw_unit_noise(
            jax.random.fold_in(sim._privacy_key, c.serial),
            sim._noise_row_like, sim._privacy_tx)
            if sim._privacy_tx is not None else None)
        Z, W, H = _merge_contribution(
            sim.state.Z, sim.state.W, sim._H, c.z_batch, c.w_batch,
            jnp.asarray(c.row, jnp.int32),
            jnp.asarray(c.client, jnp.int32),
            jnp.asarray(gamma, jnp.float32), key, noise,
            codec=sim.sim.codec, ef=sim._ef, privacy=sim._privacy_tx)
        sim.state = sim.state._replace(Z=Z, W=W)
        sim._H = H
        if c.slot >= 0 and sim._async_table is not None:
            # table-backed contribution (dispatched under the scan engine,
            # merged eagerly): its payload slot is free again
            sim._async_table.free(c.slot)
            c.slot = -1

    def release(self, sim, c: "_Contribution") -> None:
        """Discard an in-flight contribution WITHOUT merging it (fault
        injection: the upload was lost or rejected) -- reclaim whatever
        payload storage it holds. Eager batch refs just drop with the
        contribution; table-backed slots are freed explicitly."""
        if c.slot >= 0 and sim._async_table is not None:
            sim._async_table.free(c.slot)
            c.slot = -1


#: shared stateless default executor (the eager reference semantics)
_EAGER_ASYNC_EXEC = _EagerAsyncExec()


class FedSim:
    """Drives one algorithm under one aggregation policy over simulated time.

    Parameters
    ----------
    alg : "fedepm" | "sfedavg" | "sfedprox"
    cfg : the algorithm's own config (FedEPMConfig / BaselineConfig) --
          the sim never alters it, so the math stays core/'s.
    state : initial algorithm state (init_state of the respective module).
    batches, loss_fn : as taken by the round functions.
    profiles : device heterogeneity (clients.make_profiles); default uniform.
    sim : SimConfig policy/latency/codec settings.
    work_flops : override the per-round client compute estimate.
    telemetry : an EventRecorder (repro.telemetry), or None for the shared
        no-op NULL_RECORDER. Recording is observational only -- it never
        draws RNG or dispatches jit work, so trajectories are bit-for-bit
        independent of it.
    """

    def __init__(self, *, alg: str, cfg: Any, state: Any, batches: Any,
                 loss_fn: Callable, profiles=None,
                 sim: SimConfig = SimConfig(),
                 work_flops: float | None = None, telemetry=None):
        if alg not in _ALGS:
            raise ValueError(f"unknown alg {alg!r}")
        if sim.policy not in _POLICIES:
            raise ValueError(
                f"unknown policy {sim.policy!r}; expected one of {_POLICIES}")
        if sim.buffer_size < 0:
            raise ValueError(f"buffer_size must be >= 0 (0 = cohort size); "
                             f"got {sim.buffer_size}")
        if sim.max_concurrency < 0:
            raise ValueError(f"max_concurrency must be >= 0 (0 = unlimited); "
                             f"got {sim.max_concurrency}")
        round_fn, mask_fn = _ALGS[alg]
        self.alg = alg
        self.cfg = cfg
        self.sim = sim
        self.state = state
        # raw round ingredients for the fused scan engine (repro.sim.engine
        # traces its own multi-round body over them) plus a device->host
        # transfer counter both engines report in BENCH_engine.json
        self._round_fn = round_fn
        self._batches = batches
        self._loss_fn = loss_fn
        self.host_syncs = 0
        self._mask_cache: dict[bytes, jax.Array] = {}
        self.profiles = profiles if profiles is not None \
            else simclients.uniform_profiles(cfg.m)
        if self.profiles.m != cfg.m:
            raise ValueError(
                f"profiles for m={self.profiles.m} but cfg.m={cfg.m}")
        self._latency = simclients.make_latency_model(
            sim.latency, sigma=sim.latency_sigma, alpha=sim.latency_alpha)
        self._rng = np.random.default_rng(sim.seed)
        self._codec_key = jax.random.PRNGKey(sim.seed ^ 0x5EED)
        # fault model on its OWN seeded stream -- never the arrival stream,
        # whose draw ORDER differs between engines (the scan engine batches
        # arrival draws per chunk); None whenever no fault process can fire
        self._faults = build_fault_model(sim.faults, cfg.m)
        # privacy accountant (None whenever the config is inert) and the
        # noise-transform config: eps == 0 privacy (secure-agg only) bills
        # masks but never perturbs values, so the transform -- a static
        # operand of the merge programs -- stays None and every device
        # path stays byte-identical to the pre-privacy simulator
        self._privacy = build_privacy_model(sim.privacy, cfg.m)
        self._privacy_tx = (sim.privacy if self._privacy is not None
                            and sim.privacy.eps > 0 else None)
        self._privacy_key = jax.random.PRNGKey(
            (sim.privacy.seed if sim.privacy is not None else 0) ^ 0x9D1A)
        # shape donor for per-contribution noise draws under the async
        # policy: one (1, ...) row per Z leaf (shapes only, never
        # materialized -- draw_unit_noise reads .shape)
        self._noise_row_like = (tmap(
            lambda x: jax.ShapeDtypeStruct((1,) + x.shape[1:], x.dtype),
            state.Z) if self._privacy_tx is not None else None)

        jit_key = (round_fn, loss_fn, cfg, id(batches))
        self._step = _shared_jit(
            ("step", *jit_key),
            lambda: jax.jit(
                lambda s, mask: round_fn(s, batches, loss_fn, cfg, mask)))
        # baselines accept a decoupled aggregation anchor (agg_mask) so the
        # async client-level scheduler can average eq. (34) over the whole
        # cohort while only a sub-group computes; fedepm's ENS already
        # aggregates every Z row, so no anchor is needed there
        if alg == "fedepm":
            self._step_agg = None
        else:
            self._step_agg = _shared_jit(
                ("step_agg", *jit_key),
                lambda: jax.jit(
                    lambda s, mask, agg: round_fn(s, batches, loss_fn, cfg,
                                                  mask, agg_mask=agg)))
        self._default_mask = _shared_jit(
            ("mask", mask_fn, cfg),
            lambda: jax.jit(lambda s: mask_fn(s, cfg)))
        if sim.policy == "overselect":
            # over-selection draws its own (bigger) uniform candidate set;
            # a coverage/full sampler's guarantee would be silently lost,
            # so refuse rather than mislead
            if getattr(cfg, "sampler", "uniform") != "uniform":
                raise ValueError(
                    "policy='overselect' only supports the uniform sampler; "
                    f"got cfg.sampler={cfg.sampler!r}")
            rho_eff = min(1.0, cfg.rho * sim.overselect_factor)

            def build_cand():
                def cand(s):
                    _, k_sel, _ = jax.random.split(s.key, 3)
                    return participation.sample_uniform(k_sel, cfg.m,
                                                        rho_eff)
                return jax.jit(cand)

            self._candidates = _shared_jit(
                ("cand_over", cfg.m, rho_eff), build_cand)
        else:
            self._candidates = self._default_mask
        self._n_keep = min(cfg.m, max(1, math.ceil(cfg.rho * cfg.m)))

        # byte model from the real state trees
        self._down_bytes = float(tree_client_bytes(state.w_tau))
        self._up_bytes = float(encoded_client_bytes(state.Z, sim.codec))
        if self._privacy is not None:
            # the pairwise-mask exchange rides every upload attempt:
            # folding it into the per-upload wire size makes the ledger
            # bill masks under exactly the PR 9 fault-billing rule (clean
            # arrivals + retries + duplicates) and slows the modeled
            # upload transfer accordingly; 0 when secure-agg is off
            self._up_bytes += self._privacy.mask_overhead
        self.telemetry = NULL_RECORDER if telemetry is None else telemetry
        self.ledger = ByteLedger(cfg.m, telemetry=self.telemetry)

        # error-feedback codec memory: the reconstruction h_i both sides
        # hold after client i's last DELIVERED upload (init: zeros, i.e.
        # the first upload is encoded in full against an empty memory)
        self._ef = sim.codec is not None and sim.codec.error_feedback
        self._H = tmap(jnp.zeros_like, state.Z) if self._ef else None

        if self._privacy_tx is not None:
            # noisy merge programs: the private round-trips in front of
            # (or instead of) the codec, keyed on (codec, privacy) so the
            # no-noise builders below keep their historical cache entries
            codec, privacy = sim.codec, self._privacy_tx
            if self._ef:

                def build_merge_ef_priv():
                    @jax.jit
                    def codec_merge_ef(z_new, H, z_prev, mask, key, noise):
                        dec = private_ef_roundtrip(z_new, H, key, noise,
                                                   codec, privacy)
                        return (tree_where_client(mask, dec, z_prev),
                                tree_where_client(mask, dec, H))
                    return codec_merge_ef

                self._codec_merge_ef = _shared_jit(
                    ("codec_merge_ef", codec, privacy), build_merge_ef_priv)
            else:

                def build_merge_priv():
                    @jax.jit
                    def codec_merge(z_new, z_prev, mask, key, noise):
                        z_dec = private_roundtrip(z_new, z_prev, key, noise,
                                                  codec, privacy)
                        return tree_where_client(mask, z_dec, z_prev)
                    return codec_merge

                self._codec_merge = _shared_jit(
                    ("codec_merge", codec, privacy), build_merge_priv)
        elif sim.codec is not None:
            codec = sim.codec
            if codec.error_feedback:

                def build_merge_ef():
                    @jax.jit
                    def codec_merge_ef(z_new, H, z_prev, mask, key):
                        dec = ef_roundtrip(z_new, H, key, codec)
                        return (tree_where_client(mask, dec, z_prev),
                                tree_where_client(mask, dec, H))
                    return codec_merge_ef

                self._codec_merge_ef = _shared_jit(
                    ("codec_merge_ef", codec), build_merge_ef)
            else:

                def build_merge():
                    @jax.jit
                    def codec_merge(z_new, z_prev, mask, key):
                        z_dec = codec_roundtrip(z_new, z_prev, key, codec)
                        return tree_where_client(mask, z_dec, z_prev)
                    return codec_merge

                self._codec_merge = _shared_jit(
                    ("codec_merge", codec), build_merge)

        if sim.policy == "adaptive":
            self.deadlines = simclients.AdaptiveDeadlines(
                cfg.m, beta=sim.ewma_beta, slack=sim.deadline_slack)

        if sim.policy == "async":
            # cohort size of the (uniform/full) selection stream; also the
            # in-system top-up target and the default buffer size
            self._cohort = max(
                1, int(np.asarray(self._default_mask(state)).sum()))
            self._buffer_k = sim.buffer_size or self._cohort
            self._max_conc = sim.max_concurrency or math.inf
            self._version = 0          # server model version (aggregations)
            self._serial = 0           # upload serial (codec dither stream)
            self._eseq = 0             # event push sequence (heap tie-break)
            self._events: list = []    # heap of (t, eseq, kind, payload)
            self._stalled: collections.deque = collections.deque()
            self._n_inflight = 0       # started clients awaiting arrival
            self._n_queued_starts = 0  # start events sitting in the heap
            self._cohort_live = np.zeros(cfg.m, bool)  # newest draw, live
            self._exec = _EAGER_ASYNC_EXEC  # device-work executor seam
            self._async_table = None   # scan engine's payload table

        self._work = work_flops if work_flops is not None else \
            client_work_flops(alg, k0=cfg.k0,
                              n_params=tree_size(state.w_tau),
                              d_local=_batches_d_local(batches))
        self.t = 0.0
        self.round_idx = 0
        self.metrics: list[SimMetrics] = []
        self.last_round_metrics = None  # algorithm RoundMetrics of last round

    def attach_telemetry(self, recorder) -> None:
        """Point the sim (and its byte ledger) at a telemetry recorder."""
        self.telemetry = recorder
        self.ledger.telemetry = recorder

    @property
    def up_bytes_per_client(self) -> float:
        """Encoded uplink wire bytes one client sends per round."""
        return self._up_bytes

    @property
    def down_bytes_per_client(self) -> float:
        """Dense broadcast wire bytes one contacted client receives."""
        return self._down_bytes

    def _dev_mask(self, mask: np.ndarray) -> jax.Array:
        """Device copy of a host boolean mask, cached by value.

        The async event path re-dispatches the same masks over and over
        (singleton groups under a concurrency cap, the live-cohort anchor
        between draws); uploading each occurrence anew costs one allocation
        + transfer per EVENT. The cache keys on the mask bytes, so each
        distinct mask is uploaded once per simulation (bounded FIFO, masks
        are m bools each).
        """
        key = mask.tobytes()
        buf = self._mask_cache.get(key)
        if buf is None:
            if len(self._mask_cache) >= 1024:
                self._mask_cache.pop(next(iter(self._mask_cache)))
            buf = jnp.asarray(mask)
            self._mask_cache[key] = buf
        return buf

    # -- policy -------------------------------------------------------------

    def _apply_policy(self, candidates: np.ndarray, arrivals: np.ndarray):
        """-> (mask (m,) bool, round duration seconds).

        Mask semantics live in core.participation (arrival_mask /
        first_arrivals_mask) so the jit-safe helpers and the sim cannot
        drift; only the round-duration bookkeeping is computed here.
        """
        pol = self.sim.policy
        self.host_syncs += 1  # each branch transfers one jit'd mask back
        cand_j = jnp.asarray(candidates)
        arr_j = jnp.asarray(arrivals)
        t_cand = np.where(candidates, arrivals, np.inf)
        if pol == "sync":
            # wait for every contacted client that is alive; an all-offline
            # round has no natural duration (sync has no cutoff) => 0.0
            mask = np.asarray(participation.arrival_mask(
                cand_j, arr_j, np.inf))
            dur = float(t_cand[mask].max()) if mask.any() else 0.0
            return mask, dur
        if pol == "deadline":
            dl = self.sim.deadline
            mask = np.asarray(participation.arrival_mask(cand_j, arr_j, dl))
            if not candidates.any():
                return mask, 0.0
            finite = t_cand[np.isfinite(t_cand)]
            if np.isfinite(t_cand[candidates]).all() \
                    and (t_cand[candidates] <= dl).all():
                return mask, float(t_cand[candidates].max())  # all beat it
            if np.isfinite(dl):                     # someone missed it
                return mask, float(dl)
            # infinite deadline but offline candidates: wait out the finite
            return mask, float(finite.max()) if finite.size else 0.0
        if pol == "adaptive":
            cut = self.deadlines.cutoffs()
            mask = np.asarray(participation.arrival_mask(
                cand_j, arr_j, jnp.asarray(cut)))
            # the server listens to candidate i until min(arrival_i, cut_i):
            # round time is the last moment it is still waiting for anyone
            wait = np.where(candidates, np.minimum(arrivals, cut), np.inf)
            finite = wait[np.isfinite(wait)]
            dur = float(finite.max()) if finite.size else 0.0
            self.deadlines.observe(candidates, arrivals)
            return mask, dur
        if pol == "overselect":
            mask = np.asarray(participation.first_arrivals_mask(
                cand_j, arr_j, self._n_keep))
            dur = float(t_cand[mask].max()) if mask.any() else 0.0
            return mask, dur
        raise ValueError(f"unknown policy {pol!r}")

    # -- one simulated round ------------------------------------------------

    def step(self) -> SimMetrics:
        if self.sim.policy == "async":
            return self._step_async()
        candidates = np.asarray(self._candidates(self.state))
        self.host_syncs += 1
        arrivals = simclients.round_arrivals(
            self.profiles, self._rng, self._latency,
            work_flops=self._work, down_bytes=self._down_bytes,
            up_bytes=self._up_bytes)
        fo = None
        if self._faults is not None:
            # resolve fault chains BEFORE the policy: the policy then sees
            # the effective candidate set (quarantine removed) and arrival
            # times (retry-delayed / lost), so every defense downstream --
            # masking, abandonment, adaptive EWMA observation -- is the
            # existing code operating on what actually reached the server
            fo = self._faults.apply_clocked(
                round_idx=self.round_idx, candidates=candidates,
                arrivals=arrivals,
                cutoff=self.sim.deadline
                if self.sim.policy == "deadline" else math.inf)
            candidates, arrivals = fo.candidates, fo.arrivals
        mask, dur = self._apply_policy(candidates, arrivals)

        abandoned = candidates.any() and not mask.any()
        if abandoned:
            # server waited out the round (dur from the policy) and nobody
            # reported: algorithm state untouched, broadcast bytes spent
            rec_up = np.zeros(self.cfg.m, bool)
        else:
            prev_state = self.state
            new_state, rmetrics = self._step(
                self.state, jnp.asarray(mask))
            if self._privacy_tx is not None:
                key = jax.random.fold_in(self._codec_key, self.round_idx)
                # host-drawn unit noise, privacy stream folded on the
                # round index (the scan chunk feeds the SAME draws in as
                # xs, so the two engines perturb bit-identically)
                noise = draw_unit_noise(
                    jax.random.fold_in(self._privacy_key, self.round_idx),
                    prev_state.Z, self._privacy_tx)
                if self._ef:
                    Z_dec, self._H = self._codec_merge_ef(
                        new_state.Z, self._H, prev_state.Z,
                        jnp.asarray(mask), key, noise)
                    new_state = new_state._replace(Z=Z_dec)
                else:
                    new_state = new_state._replace(Z=self._codec_merge(
                        new_state.Z, prev_state.Z, jnp.asarray(mask), key,
                        noise))
            elif self.sim.codec is not None:
                key = jax.random.fold_in(self._codec_key, self.round_idx)
                if self._ef:
                    Z_dec, self._H = self._codec_merge_ef(
                        new_state.Z, self._H, prev_state.Z,
                        jnp.asarray(mask), key)
                    new_state = new_state._replace(Z=Z_dec)
                else:
                    new_state = new_state._replace(Z=self._codec_merge(
                        new_state.Z, prev_state.Z, jnp.asarray(mask), key))
            self.state = new_state
            self.last_round_metrics = rmetrics
            # uploads that completed within the round window (kept clients
            # plus over-selection ties); stragglers cut at the deadline
            # never finish their upload, offline clients never start one
            rec_up = np.asarray(candidates & np.isfinite(arrivals)
                                & (arrivals <= dur + 1e-12))
            if self.sim.policy == "adaptive":
                # per-client cutoffs: the server hangs up on client i at
                # cut_i, so only kept uploads were actually received
                rec_up = mask

        if self.telemetry.enabled:
            emit_clocked_round_events(
                self.telemetry, policy=self.sim.policy,
                round_idx=self.round_idx, t0=self.t, candidates=candidates,
                arrivals=arrivals, mask=mask, dur=dur, rec_up=rec_up,
                abandoned=bool(abandoned), codec=self.sim.codec,
                up_bytes=self._up_bytes, faults=fo)
        apply_clocked_privacy(
            self._privacy, self.telemetry, round_idx=self.round_idx,
            t_end=self.t + dur, mask=mask, rec_up=rec_up, faults=fo)
        if fo is None:
            brec = self.ledger.record_round(
                down_mask=candidates, up_mask=rec_up,
                down_bytes=self._down_bytes, up_bytes=self._up_bytes,
                ts=self.t + dur, round_idx=self.round_idx)
        else:
            # failed attempts and discarded duplicates sent real bytes:
            # bill them on top of the delivered-upload mask via the count
            # path (record_round is the counts==mask special case)
            brec = self.ledger.record_counts(
                down_counts=candidates.astype(np.int64),
                up_counts=rec_up.astype(np.int64) + fo.extra_up,
                down_bytes=self._down_bytes, up_bytes=self._up_bytes,
                ts=self.t + dur, round_idx=self.round_idx)
        self.t += dur
        m = make_sim_metrics(
            round_idx=self.round_idx, t_round=dur, t_total=self.t,
            n_contacted=int(candidates.sum()), n_aggregated=int(mask.sum()),
            brec=brec, abandoned=bool(abandoned))
        self.metrics.append(m)
        self.round_idx += 1
        return m

    # -- asynchronous client-level dispatch (policy="async") ----------------

    def _free_slots(self) -> float:
        return self._max_conc - self._n_inflight

    def _in_system(self) -> int:
        """Clients the server currently owes work to: in flight, stalled on
        a concurrency slot, or queued as unfired start events."""
        return self._n_inflight + len(self._stalled) + self._n_queued_starts

    def _select_cohort(self) -> int:
        """Draw the next cohort from the algorithm's key stream and queue
        one start event per LIVE member at the current simulated time.
        Returns the live count.

        Unreachable members cost their broadcast immediately (the contact
        RPC fails; a wasted broadcast, like an abandoned sync round) and
        never occupy a concurrency slot. The live mask is remembered as the
        aggregation anchor the baselines' agg_mask hook receives.
        """
        candidates = self._exec.draw_candidates(self)
        if self._faults is not None:
            # quarantined clients are not contacted at all: no broadcast
            # bytes, no slot, no dispatch event (the draw itself still
            # advances nothing -- selection is a pure key-stream read)
            candidates = candidates \
                & ~self._faults.quarantine_mask(self.round_idx)
        durations = simclients.round_arrivals(
            self.profiles, self._rng, self._latency,
            work_flops=self._work, down_bytes=self._down_bytes,
            up_bytes=self._up_bytes)
        live = candidates & np.isfinite(durations)
        self._cohort_live = live
        offline = candidates & ~live
        self._ev_contacted += int(offline.sum())
        self._ev_dropped += int(offline.sum())
        self._ev_down += offline.astype(np.int64)
        if self.telemetry.enabled:
            for i in np.flatnonzero(offline):
                self.telemetry.event("dispatch", ts=self.t,
                                     round_idx=self.round_idx,
                                     client=int(i), live=False)
        live_idx = np.flatnonzero(live)
        if live_idx.size:
            base = self._eseq
            entries = [(self.t, base + j, _EV_START,
                        (int(i), float(durations[i])))
                       for j, i in enumerate(live_idx)]
            # batched insert: extend + one O(n) heapify when the group is
            # a sizeable fraction of the heap; per-entry O(log n) pushes
            # when it is not (heapify re-sifts the WHOLE heap, a loss for
            # a singleton draw into a deep queue)
            n_heap = len(self._events)
            if live_idx.size * max(1, n_heap.bit_length()) >= n_heap:
                self._events.extend(entries)
                heapq.heapify(self._events)
            else:
                for e in entries:
                    heapq.heappush(self._events, e)
            self._eseq += int(live_idx.size)
            self._n_queued_starts += int(live_idx.size)
        return int(live_idx.size)

    def _fire_group(self, group: list[tuple[int, float]]) -> None:
        """Broadcast to ``group`` NOW: run the round function once over its
        members (clients compute against the broadcast they just received),
        which advances w_tau/k/key; the resulting W/Z rows only reach the
        server's state when their upload events are merged. Causality note:
        the broadcast aggregates state.Z, i.e. ONLY uploads already merged
        -- the group's own uploads live in the discarded new_state.Z until
        their arrivals merge, so no dispatch ever sees an in-flight upload.
        """
        mask = np.zeros(self.cfg.m, bool)
        mask[[i for i, _ in group]] = True
        self._ev_contacted += len(group)
        self._ev_down += mask.astype(np.int64)
        contribs = [
            _Contribution(client=i, version=self._version,
                          serial=self._serial + j, z_batch=None,
                          w_batch=None, row=j)
            for j, (i, _) in enumerate(group)]
        self._serial += len(group)
        # device work (round fn + row gather) routes through the executor:
        # the eager executor runs it now, the scan engine's recording
        # executor defers it into the compiled chunk program
        self._exec.fire(self, group, mask, contribs)
        self._n_inflight += len(group)
        if self.telemetry.enabled:
            for i, dur in group:
                self.telemetry.event(
                    "dispatch", ts=self.t, round_idx=self.round_idx,
                    client=int(i), dur_s=float(dur), version=self._version,
                    in_flight=self._n_inflight,
                    stalled=len(self._stalled))
        for (i, dur), c in zip(group, contribs):
            heapq.heappush(self._events,
                           (self.t + dur, self._eseq, _EV_UPLOAD, c))
            self._eseq += 1

    def _handle_faulty_upload(self, c: _Contribution) -> bool:
        """Resolve one popped upload event against the fault model.

        Returns True when the event was consumed here (lost, retried,
        rejected or deduped) and must NOT be buffered; False for a clean
        delivery the pump buffers as usual. Every attempt that reached the
        wire -- duplicates and rejected payloads included -- bills one
        upload to the count ledger. Runs identically under both engines
        (the pump is shared and the model's stream is its own), so the
        scan recording pass reproduces every decision made here.
        """
        fm = self._faults
        tel = self.telemetry.enabled
        if c.dup or (c.client, c.serial, c.attempt) in fm.seen:
            # duplicate delivery: billed, deduped on the (client, serial,
            # attempt) sequence number, never merged. Ghosts hold no batch
            # refs and never occupied a slot, so in-flight is untouched.
            # Counted here -- at discard/billing time -- so the counter
            # can never drift from the byte ledger (a ghost still queued
            # at run end is neither billed nor counted).
            self._ev_up[c.client] += 1
            fm.total_duplicates += 1
            if tel:
                self.telemetry.event(
                    "duplicate_discard", ts=self.t,
                    round_idx=self.round_idx, client=int(c.client))
            return True
        fate = fm.draw_outcome()
        if fate == "ok":
            delay = fm.draw_duplicate()
            if delay is not None:
                # the duplicate arrives reorder_jitter*U[0,1) late: a
                # payload-free ghost event dedup will discard on arrival
                ghost = dataclasses.replace(c, dup=True, slot=-1,
                                            z_batch=None, w_batch=None)
                heapq.heappush(self._events, (self.t + delay, self._eseq,
                                              _EV_UPLOAD, ghost))
                self._eseq += 1
            return False
        self._ev_up[c.client] += 1   # the failed attempt sent real bytes
        if fate == "transient" and c.attempt <= fm.cfg.max_retries:
            fm.total_retries += 1
            if tel:
                self.telemetry.event(
                    "retry", ts=self.t, round_idx=self.round_idx,
                    client=int(c.client), attempt=c.attempt + 1)
            delay = fm.backoff(c.attempt)
            c.attempt += 1
            # still in flight (the slot stays held): same contribution,
            # redelivered after exponential backoff
            heapq.heappush(self._events,
                           (self.t + delay, self._eseq, _EV_UPLOAD, c))
            self._eseq += 1
            return True
        # lost for good: mid-flight drop, retry budget exhausted, or
        # rejected by the corruption screen
        reason = {"drop": "drop", "transient": "exhausted",
                  "corrupt": "corrupt"}[fate]
        self._n_inflight -= 1
        self._ev_dropped += 1
        self._exec.release(self, c)
        fm.total_drops += 1
        if fate == "corrupt":
            fm.total_corrupt += 1
            until = fm.record_offense(int(c.client), self.round_idx)
            if until is not None and tel:
                self.telemetry.event(
                    "quarantine", ts=self.t, round_idx=self.round_idx,
                    client=int(c.client), until_round=until)
        if tel:
            self.telemetry.event(
                "upload_drop", ts=self.t, round_idx=self.round_idx,
                client=int(c.client), reason=reason,
                in_flight=self._n_inflight, stalled=len(self._stalled))
        return True

    def _step_async(self) -> SimMetrics:
        """One aggregation event: pump the per-client event queue until the
        buffer holds ``buffer_size`` contributions, staleness-merge them in
        arrival order, and advance the server version.

        Event lifecycle: select (key-stream cohort draw) -> start (slot
        permitting; same-instant starts batch into one round-function call)
        -> upload (arrival frees a slot, which immediately un-stalls the
        oldest waiting dispatch). Fresh cohorts are drawn at step entry
        whenever the system holds less than one cohort of work, and
        mid-fill whenever the queue runs dry -- both on the sync key
        stream, which is what keeps max_concurrency >= cohort +
        buffer == cohort bit-identical to sync.run(N).
        """
        t_start = self.t
        self._ev_down = np.zeros(self.cfg.m, np.int64)
        self._ev_up = np.zeros(self.cfg.m, np.int64)
        self._ev_contacted = 0
        self._ev_dropped = 0
        if self.telemetry.enabled:
            self.telemetry.event("round_start", ts=self.t,
                                 round_idx=self.round_idx, policy="async",
                                 version=self._version)
        if self._in_system() < self._cohort:
            self._select_cohort()
        buffer: list[_Contribution] = []
        dry = 0
        n_selects = 0
        while len(buffer) < self._buffer_k and dry < _MAX_DRY_DISPATCHES:
            # un-stall slot-blocked dispatches first: they have been waiting
            # since an earlier instant and outrank anything queued later
            if self._stalled and self._free_slots() >= 1:
                group = [self._stalled.popleft()]
                while self._stalled and len(group) < self._free_slots():
                    group.append(self._stalled.popleft())
                self._fire_group(group)
                continue
            if not self._events:
                if self._faults is not None \
                        and n_selects >= _MAX_FAULT_SELECTS:
                    # graceful degradation under heavy loss: stop waiting
                    # for a full buffer and merge whatever survived (an
                    # empty buffer abandons the event, like a missed
                    # deadline)
                    break
                n_selects += 1
                # nothing in flight and nothing startable: draw fresh work
                dry = dry + 1 if self._select_cohort() == 0 else 0
                continue
            t_ev, _, kind, payload = heapq.heappop(self._events)
            self.t = max(self.t, t_ev)
            if kind == _EV_START:
                self._n_queued_starts -= 1
                if self._free_slots() < 1:
                    self._stalled.append(payload)
                    continue
                group = [payload]
                # same-instant starts batch into ONE dispatch (one round
                # function call, one key advance) while slots allow --
                # an uncapped server therefore dispatches whole cohorts
                while (self._events and len(group) < self._free_slots()
                       and self._events[0][0] == t_ev
                       and self._events[0][2] == _EV_START):
                    group.append(heapq.heappop(self._events)[3])
                    self._n_queued_starts -= 1
                self._fire_group(group)
                continue
            c = payload
            if self._faults is not None and self._handle_faulty_upload(c):
                continue
            self._n_inflight -= 1
            self._ev_up[c.client] += 1
            buffer.append(c)
            if self.telemetry.enabled:
                self.telemetry.event(
                    "upload_arrival", ts=self.t, round_idx=self.round_idx,
                    client=int(c.client), version=c.version,
                    in_flight=self._n_inflight,
                    stalled=len(self._stalled))

        staleness = [self._version - c.version for c in buffer]
        for c, s in zip(buffer, staleness):
            gamma = participation.staleness_weight(s, self.sim.staleness_exp)
            if self._faults is not None:
                # dedup sequence number of the merged delivery: any later
                # redelivery of the same attempt is discarded at arrival
                self._faults.seen.add((c.client, c.serial, c.attempt))
            self._exec.merge(self, c, s, gamma)
            if self.telemetry.enabled:
                if self.sim.codec is not None:
                    self.telemetry.event(
                        "codec_encode", ts=self.t, round_idx=self.round_idx,
                        client=int(c.client),
                        **codec_event_attrs(self.sim.codec, n_clients=1,
                                            up_bytes=self._up_bytes))
                self.telemetry.event(
                    "merge", ts=self.t, round_idx=self.round_idx,
                    client=int(c.client), staleness=int(s),
                    gamma=float(gamma))
            if self._privacy is not None and self.sim.privacy.eps > 0:
                # charged at MERGE time -- when the noisy payload is
                # consumed; staleness keeps the charge attributable to
                # its dispatch round in the event stream
                tot = self._privacy.charge(int(c.client))
                if self.telemetry.enabled:
                    self.telemetry.event(
                        "privacy_charge", ts=self.t,
                        round_idx=self.round_idx, client=int(c.client),
                        eps=self.sim.privacy.eps, eps_total=tot,
                        staleness=int(s))
        if buffer:
            self._version += 1
        elif self.telemetry.enabled:
            self.telemetry.event("abandon", ts=self.t,
                                 round_idx=self.round_idx,
                                 n_contacted=self._ev_contacted)

        if self._privacy is not None:
            # every billed upload attempt carried one mask-pair exchange
            # (its bytes are folded into _up_bytes, so the ledger record
            # below charges them; this keeps the model's counters in
            # lockstep with it)
            attempts = int(self._ev_up.sum())
            mbytes = self._privacy.bill_masks(attempts)
            if self.sim.privacy.secure_agg and attempts \
                    and self.telemetry.enabled:
                self.telemetry.event(
                    "mask_exchange", ts=self.t, round_idx=self.round_idx,
                    attempts=attempts, bytes=mbytes)
        brec = self.ledger.record_counts(
            down_counts=self._ev_down, up_counts=self._ev_up,
            down_bytes=self._down_bytes, up_bytes=self._up_bytes,
            ts=self.t, round_idx=self.round_idx)
        m = make_sim_metrics(
            round_idx=self.round_idx, t_round=self.t - t_start,
            t_total=self.t, n_contacted=self._ev_contacted,
            n_aggregated=len(buffer), n_dropped=self._ev_dropped,
            brec=brec, abandoned=not buffer, staleness=staleness)
        self.metrics.append(m)
        self.round_idx += 1
        return m

    def run(self, rounds: int) -> list[SimMetrics]:
        return [self.step() for _ in range(rounds)]

    # -- exact rewind (scan-engine termination replay) ----------------------

    def snapshot(self) -> dict:
        """Deep copy of EVERYTHING a later :meth:`restore` needs to replay
        the simulation bit-for-bit from this point: algorithm state and
        codec memory (fresh device buffers, so chunk donation cannot
        invalidate them), the host RNG stream, the clock/round counters,
        the byte ledger, the telemetry stream position, and -- under the
        async policy -- the whole event-loop state (heap, stalled FIFO,
        payload table). The snapshot stays valid across multiple restores.
        """
        snap = {
            "state": copy_tree(self.state),
            "H": None if self._H is None else copy_tree(self._H),
            "rng": copy.deepcopy(self._rng.bit_generator.state),
            "t": self.t,
            "round_idx": self.round_idx,
            "n_metrics": len(self.metrics),
            "last_rm": self.last_round_metrics,
            "host_syncs": self.host_syncs,
            "ledger": self.ledger.checkpoint(),
            "tel_mark": self.telemetry.mark(),
        }
        if self.sim.policy == "adaptive":
            snap["ewma"] = self.deadlines.ewma.copy()
        if self._faults is not None:
            snap["faults"] = self._faults.state_snapshot()
        if self._privacy is not None:
            snap["privacy"] = self._privacy.state_snapshot()
        if self.sim.policy == "async":
            snap["async"] = {
                "version": self._version,
                "serial": self._serial,
                "eseq": self._eseq,
                # upload payloads are MUTABLE (the executor rewrites their
                # batch refs), so each gets its own shallow copy; start
                # payloads are immutable (client, duration) tuples
                "events": [
                    (t, seq, kind,
                     dataclasses.replace(p) if kind == _EV_UPLOAD else p)
                    for (t, seq, kind, p) in self._events],
                "stalled": collections.deque(self._stalled),
                "n_inflight": self._n_inflight,
                "n_queued_starts": self._n_queued_starts,
                "cohort_live": self._cohort_live.copy(),
                "table": None if self._async_table is None
                else self._async_table.clone(),
            }
        return snap

    def restore(self, snap: dict) -> None:
        """Rewind to a :meth:`snapshot`; the snapshot remains reusable
        (everything mutable is copied again on the way out)."""
        self.state = copy_tree(snap["state"])
        self._H = None if snap["H"] is None else copy_tree(snap["H"])
        self._rng.bit_generator.state = copy.deepcopy(snap["rng"])
        self.t = snap["t"]
        self.round_idx = snap["round_idx"]
        del self.metrics[snap["n_metrics"]:]
        self.last_round_metrics = snap["last_rm"]
        self.host_syncs = snap["host_syncs"]
        self.ledger.restore(snap["ledger"])
        self.telemetry.rewind(snap["tel_mark"])
        if self.sim.policy == "adaptive":
            self.deadlines.ewma = snap["ewma"].copy()
        if self._faults is not None:
            self._faults.state_restore(snap["faults"])
        if self._privacy is not None:
            self._privacy.state_restore(snap["privacy"])
        if self.sim.policy == "async":
            a = snap["async"]
            self._version = a["version"]
            self._serial = a["serial"]
            self._eseq = a["eseq"]
            self._events = [
                (t, seq, kind,
                 dataclasses.replace(p) if kind == _EV_UPLOAD else p)
                for (t, seq, kind, p) in a["events"]]
            self._stalled = collections.deque(a["stalled"])
            self._n_inflight = a["n_inflight"]
            self._n_queued_starts = a["n_queued_starts"]
            self._cohort_live = a["cohort_live"].copy()
            table = a["table"]
            self._async_table = None if table is None else table.clone()
            if self._async_table is not None:
                # table-backed contributions must reference THIS restore's
                # table clone (the snapshot-time arrays may have been
                # donated into a later chunk before the rewind)
                for _, _, kind, p in self._events:
                    if kind == _EV_UPLOAD and p.slot >= 0:
                        p.z_batch = self._async_table.z
                        p.w_batch = self._async_table.w
                        p.row = p.slot
