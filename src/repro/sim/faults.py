"""Seeded fault injection: drops, retries, duplicates, corruption, quarantine.

The base simulation models stragglers as SLOWNESS only: availability gates
a client at dispatch time, and after that every upload arrives intact,
exactly once, in order. Real federated fleets lose clients mid-round,
retransmit, duplicate, and ship damaged payloads. This module supplies
that fault axis as a declarative, seeded layer the server runtime
(``repro.sim.server``) consults at its arrival points, with the server's
defenses -- retry/backoff, dedup, screening, quarantine -- implemented in
the shared pump/policy code both engines run.

Fault processes (all rates are per upload attempt, drawn i.i.d. from the
model's OWN ``numpy.random.Generator`` stream, never the sim's arrival
stream -- the scan engine batches its arrival draws per chunk, so a shared
stream would interleave differently between engines):

  mid-flight dropout   -- the client was dispatched and sent its upload,
                          but the bytes never reach the server. The upload
                          is billed (bytes actually went out), the
                          in-flight slot is reclaimed, and the client is
                          lost for the round.
  transient failure    -- the upload fails but the client is still
                          reachable: the server schedules a retry after an
                          exponential backoff (``backoff_base *
                          backoff_factor**(attempt-1)`` simulated seconds).
                          EVERY attempt is billed. After ``max_retries``
                          retries the client is abandoned for the round.
  duplicate delivery   -- a successful upload is delivered twice. The
                          duplicate is billed, then DISCARDED by the
                          server's dedup on ``(client, serial, attempt)``
                          sequence numbers; under the async event loop the
                          duplicate arrives ``reorder_jitter * U[0,1)``
                          seconds late, i.e. possibly reordered past other
                          arrivals -- dedup is what makes that harmless.
  corrupted payload    -- the upload arrives bit-damaged (``corrupt_mode``:
                          "nan" = NaN/Inf poisoning, "dither" = large-
                          magnitude bit damage). Both modes are caught with
                          probability 1 by the server's finite/norm screen
                          -- NaN/Inf trips the finite check, dither blows
                          the norm bound -- so the payload is billed,
                          rejected, and never merged; no corrupted value
                          ever reaches the device state (which is also why
                          eager == scan needs no device-side changes).
                          ``quarantine_after`` corrupt arrivals from the
                          same client quarantine it: it is not contacted
                          (no broadcast, no bytes) for the next
                          ``quarantine_rounds`` rounds, then released with
                          its offense counter reset.

Graceful degradation: a round whose every candidate is lost to faults is
ABANDONED exactly like a deadline-miss round (state untouched, broadcast
bytes spent); a partially-filled async buffer merges what it has.

Determinism contract: every decision here is drawn host-side, in event
order, from the one seeded generator -- the scan engine reproduces each
retry/drop/quarantine decision by running this same code inside its
recording pass (clocked policies snapshot/restore the model around the
abandoned-round fixpoint exactly like the adaptive EWMA), so fault-injected
trajectories are bit-for-bit identical between engines, telemetry stream
included (tests/test_faults.py pins it). A ``FaultConfig`` whose four
rates are all zero builds to NO model at all, leaving every existing code
path -- and the golden trajectories -- byte-identical.

Spec surface: ``[faults]`` section (repro.spec.types.FaultSpec, docs
docs/spec.md); telemetry kinds ``upload_drop`` / ``retry`` /
``duplicate_discard`` / ``quarantine`` (docs/observability.md).
"""
from __future__ import annotations

import copy
import dataclasses
import math

import numpy as np

#: corrupt_mode values the screen model knows
CORRUPT_MODES = ("nan", "dither")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Declarative fault-process parameters (all decisions seeded).

    The three failure rates partition each attempt's outcome
    (``drop_rate + transient_rate + corrupt_rate <= 1``; the remainder is
    a clean delivery); ``duplicate_rate`` then applies to clean deliveries
    only. ``seed`` is the fault stream's own seed -- independent of the
    sim seed so the same fleet/arrival realization can be replayed under
    different fault draws.
    """

    drop_rate: float = 0.0        # P(mid-flight loss) per attempt
    transient_rate: float = 0.0   # P(retryable failure) per attempt
    corrupt_rate: float = 0.0     # P(bit-damaged payload) per attempt
    duplicate_rate: float = 0.0   # P(clean delivery arrives twice)
    max_retries: int = 2          # retries after the first attempt
    backoff_base: float = 1e-3    # first retry delay (simulated s)
    backoff_factor: float = 2.0   # exponential backoff multiplier
    reorder_jitter: float = 0.0   # async duplicate delivery delay scale (s)
    quarantine_after: int = 2     # corrupt arrivals before quarantine
    quarantine_rounds: int = 3    # rounds a quarantined client sits out
    corrupt_mode: str = "nan"     # "nan" | "dither" damage model
    seed: int = 0                 # fault-stream seed

    @property
    def enabled(self) -> bool:
        """True when any fault process can actually fire."""
        return (self.drop_rate > 0 or self.transient_rate > 0
                or self.corrupt_rate > 0 or self.duplicate_rate > 0)


@dataclasses.dataclass
class FaultRoundOutcome:
    """One clocked round's fault resolution (host arrays + event records).

    ``candidates``/``arrivals`` are the EFFECTIVE values the policy sees:
    quarantined clients removed from the candidate set, lost uploads at
    +inf, surviving uploads at their (possibly backoff-delayed) completion
    time. ``extra_up`` counts the billed upload attempts BEYOND the one
    the received-upload mask already covers (failed attempts + discarded
    duplicates), per client. The event lists carry ``(client, t, ...)``
    tuples with ``t`` relative to the round start, consumed by
    ``server.emit_clocked_round_events`` so both engines emit the same
    stream from the same outcome.
    """

    candidates: np.ndarray   # (m,) bool, quarantine-filtered
    arrivals: np.ndarray     # (m,) float64 effective completion times
    extra_up: np.ndarray     # (m,) int64 extra billed upload attempts
    drops: list              # (client, t, reason) reason: drop|exhausted|corrupt
    retries: list            # (client, t_retry, attempt)
    duplicates: list         # (client, t)
    quarantines: list        # (client, until_round)


class FaultModel:
    """Seeded runtime state of the fault processes for one simulation.

    Holds the fault RNG stream, the per-client quarantine/offense state,
    the dedup sequence-number set, and the cumulative counters the run
    summary reports. Both engines drive ONE instance through the shared
    server code; :meth:`state_snapshot`/:meth:`state_restore` give the
    scan engine's fixpoint passes and ``--terminate`` rollback the same
    exact-rewind guarantee the sim's host RNG already has.
    """

    def __init__(self, cfg: FaultConfig, m: int):
        if not cfg.enabled:
            raise ValueError("FaultModel needs at least one nonzero rate; "
                             "build None instead for a zero-rate config")
        self.cfg = cfg
        self.m = m
        self._rng = np.random.default_rng(cfg.seed)
        # round index (exclusive) until which client i is quarantined
        self.quarantined_until = np.zeros(m, np.int64)
        self.offenses = np.zeros(m, np.int64)
        self.seen: set[tuple] = set()   # merged (client, serial, attempt)
        self.total_drops = 0            # mid-flight + exhausted + corrupt
        self.total_retries = 0
        self.total_corrupt = 0
        self.total_duplicates = 0
        self.total_quarantines = 0

    # -- shared decision primitives -----------------------------------------

    def quarantine_mask(self, round_idx: int) -> np.ndarray:
        """(m,) bool: clients sitting out ``round_idx`` in quarantine."""
        return self.quarantined_until > round_idx

    def backoff(self, attempt: int) -> float:
        """Retry delay after failed attempt ``attempt`` (1-based)."""
        return self.cfg.backoff_base * self.cfg.backoff_factor ** (attempt - 1)

    def record_offense(self, client: int, round_idx: int) -> int | None:
        """Count one corrupt arrival; returns the quarantine-release round
        when this offense trips the threshold, else None."""
        self.offenses[client] += 1
        if self.offenses[client] >= self.cfg.quarantine_after:
            self.offenses[client] = 0
            until = round_idx + 1 + self.cfg.quarantine_rounds
            self.quarantined_until[client] = max(
                self.quarantined_until[client], until)
            self.total_quarantines += 1
            return int(self.quarantined_until[client])
        return None

    def draw_outcome(self) -> str:
        """One attempt's fate: 'drop' | 'transient' | 'corrupt' | 'ok'."""
        u = self._rng.random()
        c = self.cfg
        if u < c.drop_rate:
            return "drop"
        if u < c.drop_rate + c.transient_rate:
            return "transient"
        if u < c.drop_rate + c.transient_rate + c.corrupt_rate:
            return "corrupt"
        return "ok"

    def draw_duplicate(self) -> float | None:
        """Delivery delay of a duplicate of a clean upload, or None.

        Draws only when ``duplicate_rate > 0`` (a config-static guard, so
        the stream stays engine-independent); the delay draw only fires
        for actual duplicates. ``total_duplicates`` is counted at DISCARD
        time by the caller, not here: the async runtime bills a duplicate
        when its ghost event pops, and a ghost still in the queue when the
        run ends was never billed, so counting at schedule time would let
        the counter drift from the byte ledger.
        """
        c = self.cfg
        if c.duplicate_rate <= 0 or self._rng.random() >= c.duplicate_rate:
            return None
        if c.reorder_jitter > 0:
            return c.reorder_jitter * self._rng.random()
        return 0.0

    # -- clocked policies (sync / deadline / adaptive / overselect) ---------

    def apply_clocked(self, *, round_idx: int, candidates: np.ndarray,
                      arrivals: np.ndarray,
                      cutoff: float = math.inf) -> FaultRoundOutcome:
        """Resolve one clocked round's fault chains -> FaultRoundOutcome.

        ``cutoff`` is the server's listening window (the deadline policy's
        cutoff; +inf for sync/overselect, and for adaptive -- whose
        per-client cutoffs apply AFTER fault resolution, to the effective
        arrivals). Per live candidate, in client-index order, the attempt
        chain runs: each attempt draws one outcome; transients retry with
        exponential backoff while attempts and the listening window allow;
        drops/corruption/exhaustion lose the round (arrival -> +inf). An
        upload whose scheduled completion lands past ``cutoff`` is never
        attempted -- the server already hung up, so no bytes flow (the
        same rule the fault-free ledger applies to stragglers). Every
        attempt that DOES fire is billed through ``extra_up``, except the
        final clean delivery, which the ordinary received-upload mask
        bills exactly as before.

        Mutates the model (RNG stream, offense/quarantine state,
        counters): callers replaying a round range must snapshot/restore
        around passes (see ``engine.run_rounds``'s fixpoint).
        """
        qmask = self.quarantine_mask(round_idx)
        cand = np.asarray(candidates, bool) & ~qmask
        arr = np.asarray(arrivals, np.float64).copy()
        extra = np.zeros(self.m, np.int64)
        drops: list = []
        retries: list = []
        dups: list = []
        quars: list = []
        cfg = self.cfg
        for i in np.flatnonzero(cand):
            t = float(arr[i])
            if not math.isfinite(t) or t > cutoff:
                continue  # offline, or lands after the server hung up
            attempt = 1
            while True:
                fate = self.draw_outcome()
                if fate == "drop":
                    extra[i] += 1
                    arr[i] = np.inf
                    drops.append((int(i), t, "drop"))
                    self.total_drops += 1
                    break
                if fate == "transient":
                    extra[i] += 1
                    if attempt > cfg.max_retries:
                        arr[i] = np.inf
                        drops.append((int(i), t, "exhausted"))
                        self.total_drops += 1
                        break
                    t_next = t + self.backoff(attempt)
                    attempt += 1
                    if t_next > cutoff:
                        # the retry cannot complete in-window: lost, and
                        # the unfired attempt is not billed
                        arr[i] = np.inf
                        drops.append((int(i), min(t_next, cutoff),
                                      "exhausted"))
                        self.total_drops += 1
                        break
                    retries.append((int(i), t_next, attempt))
                    self.total_retries += 1
                    t = t_next
                    continue
                if fate == "corrupt":
                    extra[i] += 1
                    arr[i] = np.inf
                    drops.append((int(i), t, "corrupt"))
                    self.total_drops += 1
                    self.total_corrupt += 1
                    until = self.record_offense(int(i), round_idx)
                    if until is not None:
                        quars.append((int(i), until))
                    break
                # clean delivery at t (includes any backoff delays)
                arr[i] = t
                if self.draw_duplicate() is not None:
                    extra[i] += 1
                    dups.append((int(i), t))
                    self.total_duplicates += 1
                break
        return FaultRoundOutcome(candidates=cand, arrivals=arr,
                                 extra_up=extra, drops=drops,
                                 retries=retries, duplicates=dups,
                                 quarantines=quars)

    # -- exact rewind --------------------------------------------------------

    def state_snapshot(self) -> dict:
        """Everything :meth:`state_restore` needs to replay decisions
        bit-for-bit from this point (the snapshot stays reusable)."""
        return {
            "rng": copy.deepcopy(self._rng.bit_generator.state),
            "quarantined_until": self.quarantined_until.copy(),
            "offenses": self.offenses.copy(),
            "seen": set(self.seen),
            "counters": (self.total_drops, self.total_retries,
                         self.total_corrupt, self.total_duplicates,
                         self.total_quarantines),
        }

    def state_restore(self, snap: dict) -> None:
        self._rng.bit_generator.state = copy.deepcopy(snap["rng"])
        self.quarantined_until = snap["quarantined_until"].copy()
        self.offenses = snap["offenses"].copy()
        self.seen = set(snap["seen"])
        (self.total_drops, self.total_retries, self.total_corrupt,
         self.total_duplicates, self.total_quarantines) = snap["counters"]

    def summary(self) -> dict:
        """JSON-exact cumulative counters for the run summary block."""
        return {
            "upload_drops": int(self.total_drops),
            "retries": int(self.total_retries),
            "corrupt_rejected": int(self.total_corrupt),
            "duplicates_discarded": int(self.total_duplicates),
            "quarantines": int(self.total_quarantines),
        }


def build_fault_model(cfg: "FaultConfig | None", m: int) -> FaultModel | None:
    """FaultConfig -> FaultModel, or None when no process can fire.

    The None return is the zero-rate guarantee: with no model attached the
    server runtime takes exactly its historical code paths, so a zero-rate
    ``[faults]`` section reproduces the golden trajectories byte-for-byte.
    """
    if cfg is None or not cfg.enabled:
        return None
    return FaultModel(cfg, m)
