"""Federated systems runtime: straggler simulation, sync/deadline/adaptive/
overselect/async-buffered aggregation, upload codec with optional error
feedback, seeded fault injection (drops, retries, duplicates, corruption,
quarantine), and a byte-accurate communication ledger around the core
round functions. Architecture notes live in docs/sim.md; the declarative
experiment layer that drives this runtime from TOML/JSON specs is
repro.spec (docs/spec.md)."""
from repro.sim.clients import (          # noqa: F401
    AdaptiveDeadlines,
    ClientProfiles,
    LatencyTrace,
    latency_model_names,
    make_latency_model,
    make_profiles,
    register_latency_model,
    round_arrivals,
    uniform_profiles,
)
from repro.sim.faults import (           # noqa: F401
    FaultConfig,
    FaultModel,
    build_fault_model,
)
from repro.sim.server import (           # noqa: F401
    FedSim,
    SimConfig,
    SimMetrics,
    client_work_flops,
)
from repro.sim.engine import (           # noqa: F401
    EngineResult,
    run_rounds,
    run_to_objective,
)
from repro.sim.transport import (        # noqa: F401
    ByteLedger,
    CodecConfig,
    codec_roundtrip,
    ef_roundtrip,
    encoded_client_bytes,
    stacked_client_bytes,
    tree_client_bytes,
)
