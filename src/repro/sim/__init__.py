"""Federated systems runtime: straggler simulation, deadline aggregation,
and a byte-accurate communication ledger around the core round functions."""
from repro.sim.clients import (          # noqa: F401
    ClientProfiles,
    make_latency_model,
    make_profiles,
    round_arrivals,
    uniform_profiles,
)
from repro.sim.server import (           # noqa: F401
    FedSim,
    SimConfig,
    SimMetrics,
    client_work_flops,
)
from repro.sim.transport import (        # noqa: F401
    ByteLedger,
    CodecConfig,
    codec_roundtrip,
    encoded_client_bytes,
    stacked_client_bytes,
    tree_client_bytes,
)
