"""Per-client device heterogeneity profiles and latency models.

The paper frames FedEPM as addressing four *systems* issues -- communication
efficiency, computational complexity, stragglers, privacy -- but the core
round functions only see a boolean participation mask. This module supplies
the missing device model: each client has a static profile (relative compute
speed, up/down bandwidth, availability) -- synthesized (``make_profiles``)
or resampled from a real device log (``LatencyTrace``) -- and a per-round
stochastic latency multiplier drawn from a pluggable distribution. A round's simulated arrival
time for client i decomposes as

    t_i = down_bytes / bw_down_i                    (receive w^{tau+1})
        + (work_flops / NOMINAL_FLOPS) / speed_i * jitter_i   (local compute)
        + up_bytes_i / bw_up_i                      (upload z_i)

with t_i = inf when the client is unavailable this round. Everything here is
host-side numpy: the simulation decides masks and wall-clock OUTSIDE the
jitted round functions, then feeds the mask in through the round hook
(core.fedepm.fedepm_round(..., mask=...)), so the algorithmic math is never
forked. That host/device split is also what makes the scan engine's
record/replay possible: because every draw here consumes the sim's ONE
``numpy.random.Generator`` in event order, the recording pass
(repro.sim.engine) reproduces arrival times, availability and adaptive
cutoffs exactly by running this same code -- no latency model is ever
re-implemented on device, and snapshot/restore only has to checkpoint the
generator's bit state to replay a chunk deterministically.

Latency distributions (``make_latency_model``):

  deterministic -- jitter = 1 (useful for exactness tests: with an infinite
                   deadline the sim reproduces fedepm_round bit-for-bit)
  lognormal     -- exp(sigma*N - sigma^2/2), mean 1: benign dispersion
  pareto        -- Pareto(x_min=1, alpha): heavy-tail stragglers; alpha
                   around 1.1-1.5 produces the occasional 10-100x outlier
                   that deadline/over-selection policies exist to absorb
"""
from __future__ import annotations

import csv
import dataclasses
import json
from typing import Callable

import numpy as np

# Nominal device throughput used to convert a work estimate (flops) into
# seconds at speed 1.0. Absolute value only sets the time unit; policies
# compare relative times.
NOMINAL_FLOPS = 1e9

LatencyModel = Callable[[np.random.Generator, int], np.ndarray]
LatencyFactory = Callable[..., LatencyModel]  # kwargs: sigma, alpha


@dataclasses.dataclass(frozen=True)
class ClientProfiles:
    """Static per-client device characteristics (all shape (m,))."""

    speed: np.ndarray         # relative compute speed, 1.0 = nominal
    bw_up: np.ndarray         # uplink bytes/s
    bw_down: np.ndarray       # downlink bytes/s
    availability: np.ndarray  # P(client reachable in a given round), (0, 1]

    @property
    def m(self) -> int:
        return len(self.speed)


def make_profiles(m: int, seed: int = 0, *, speed_sigma: float = 0.4,
                  bw_up_mean: float = 1.25e6, bw_down_mean: float = 1e7,
                  bw_sigma: float = 0.6,
                  availability: float = 1.0) -> ClientProfiles:
    """Lognormal fleet: mobile-like up/down asymmetry (~10 Mbit up, ~80 Mbit
    down by default), dispersion controlled by the sigmas. availability may
    be a scalar applied to all clients."""
    availability = float(availability)
    # documented domain is (0, 1]: 0 or NaN would make every client
    # permanently unreachable / poison the per-round Bernoulli draw
    if not (0.0 < availability <= 1.0):
        raise ValueError(f"availability must be in (0, 1]; "
                         f"got {availability}")
    rng = np.random.default_rng(seed)

    def logn(mean, sigma):
        # lognormal with the requested MEAN (not median)
        return mean * np.exp(sigma * rng.standard_normal(m)
                             - 0.5 * sigma * sigma)

    return ClientProfiles(
        speed=logn(1.0, speed_sigma),
        bw_up=logn(bw_up_mean, bw_sigma),
        bw_down=logn(bw_down_mean, bw_sigma),
        availability=np.full(m, float(availability)),
    )


def uniform_profiles(m: int) -> ClientProfiles:
    """Homogeneous fleet (speed = bw = 1-unit): with the deterministic
    latency model, arrival times are identical across clients -- the
    degenerate case the exactness tests pin against core.fedepm."""
    return ClientProfiles(speed=np.ones(m), bw_up=np.full(m, 1.25e6),
                          bw_down=np.full(m, 1e7),
                          availability=np.ones(m))


_TRACE_FIELDS = ("speed", "bw_up", "bw_down", "availability")


@dataclasses.dataclass(frozen=True)
class LatencyTrace:
    """Empirical per-device profile table loaded from real fleet logs.

    A trace is a flat table of device measurements -- one entry per device
    model observed in a production log -- from which a simulated fleet is
    built by RESAMPLING: each of the ``m`` clients is assigned one trace
    entry (without replacement while the trace is large enough, i.i.d.
    bootstrap otherwise), so the simulated speed/bandwidth/availability
    marginals match the measured fleet instead of a parametric lognormal
    (``make_profiles``). Stochastic per-round jitter still comes from the
    latency model on top.

    Schema (CSV header columns / JSON object keys), one row per device:

      device        free-form model name (metadata; optional, default
                    ``device-<row>``)
      speed         relative compute speed, 1.0 = NOMINAL_FLOPS (required)
      bw_up         uplink bytes/s (required)
      bw_down       downlink bytes/s (required)
      availability  P(online in a given round), in (0, 1] (optional,
                    default 1.0)

    JSON files may be either a bare list of such objects or
    ``{"entries": [...]}``. A real-shaped fixture ships at
    ``tests/fixtures/device_trace.csv``.
    """

    device: tuple
    speed: np.ndarray
    bw_up: np.ndarray
    bw_down: np.ndarray
    availability: np.ndarray

    def __post_init__(self):
        n = len(self.device)
        if n == 0:
            raise ValueError("empty trace: no device entries")
        for f in _TRACE_FIELDS:
            v = getattr(self, f)
            if len(v) != n:
                raise ValueError(f"trace field {f!r} has {len(v)} entries, "
                                 f"expected {n}")
            if not np.isfinite(v).all() or (v <= 0).any():
                raise ValueError(f"trace field {f!r} must be finite and > 0")
        if (self.availability > 1.0).any():
            raise ValueError("availability must be in (0, 1]")

    @property
    def n_entries(self) -> int:
        return len(self.device)

    @classmethod
    def from_rows(cls, rows: list[dict]) -> "LatencyTrace":
        """Build from a list of row dicts (the CSV/JSON loaders' target)."""
        def col(f, default=None):
            out = []
            for i, r in enumerate(rows):
                if f in r and r[f] not in (None, ""):
                    out.append(float(r[f]))
                elif default is not None:
                    out.append(default)
                else:
                    raise ValueError(
                        f"trace row {i} is missing required field {f!r}")
            return np.asarray(out, np.float64)

        return cls(
            device=tuple(str(r.get("device", f"device-{i}"))
                         for i, r in enumerate(rows)),
            speed=col("speed"),
            bw_up=col("bw_up"),
            bw_down=col("bw_down"),
            availability=col("availability", default=1.0),
        )

    @classmethod
    def from_csv(cls, path) -> "LatencyTrace":
        with open(path, newline="") as f:
            return cls.from_rows(list(csv.DictReader(f)))

    @classmethod
    def from_json(cls, path) -> "LatencyTrace":
        with open(path) as f:
            data = json.load(f)
        if isinstance(data, dict):
            data = data.get("entries")
        if not isinstance(data, list):
            raise ValueError(f"{path}: expected a JSON list of trace rows "
                             f"or {{'entries': [...]}}")
        return cls.from_rows(data)

    @classmethod
    def load(cls, path) -> "LatencyTrace":
        """Dispatch on file extension: .csv or .json."""
        p = str(path)
        if p.endswith(".csv"):
            return cls.from_csv(path)
        if p.endswith(".json"):
            return cls.from_json(path)
        raise ValueError(f"unknown trace format {path!r} (want .csv/.json)")

    def assign(self, m: int, seed: int = 0,
               replace: bool | None = None) -> np.ndarray:
        """(m,) trace-entry index per client. Without replacement while the
        trace covers the fleet (every client a distinct measured device),
        bootstrap otherwise; ``replace`` forces one or the other."""
        if replace is None:
            replace = m > self.n_entries
        if not replace and m > self.n_entries:
            raise ValueError(f"cannot assign {m} clients from "
                             f"{self.n_entries} entries without replacement")
        rng = np.random.default_rng(seed)
        return rng.choice(self.n_entries, size=m, replace=replace)

    def sample_profiles(self, m: int, seed: int = 0,
                        replace: bool | None = None) -> ClientProfiles:
        """Resample the trace into ``ClientProfiles`` for an m-client fleet."""
        idx = self.assign(m, seed=seed, replace=replace)
        return ClientProfiles(
            speed=self.speed[idx], bw_up=self.bw_up[idx],
            bw_down=self.bw_down[idx],
            availability=self.availability[idx])


# latency-model registry: kind -> factory(sigma=..., alpha=...) -> model.
# The built-ins live here; extensions register via register_latency_model
# and become valid everywhere a latency kind is named (SimConfig.latency,
# the simulate CLI, FleetSpec.latency in repro.spec) without touching any
# of those callers.
_LATENCY_MODELS: dict[str, "LatencyFactory"] = {
    "deterministic": lambda *, sigma, alpha: lambda rng, m: np.ones(m),
    "lognormal": lambda *, sigma, alpha: lambda rng, m: np.exp(
        sigma * rng.standard_normal(m) - 0.5 * sigma * sigma),
    # numpy's pareto returns X - 1 for Pareto(x_min=1, alpha)
    "pareto": lambda *, sigma, alpha: lambda rng, m:
        1.0 + rng.pareto(alpha, size=m),
}


def latency_model_names() -> tuple[str, ...]:
    """Registered latency-model kinds (built-ins + extensions)."""
    return tuple(_LATENCY_MODELS)


def register_latency_model(kind: str, factory) -> None:
    """Register a latency-model factory under ``kind``.

    ``factory`` is called as ``factory(sigma=..., alpha=...)`` and must
    return a ``LatencyModel`` -- a ``(rng, m) -> (m,) multiplier`` callable.
    Re-registering a built-in name is refused so a typo cannot silently
    change the semantics every existing config relies on.
    """
    if kind in _LATENCY_MODELS:
        raise ValueError(f"latency model {kind!r} is already registered")
    _LATENCY_MODELS[kind] = factory


def make_latency_model(kind: str = "deterministic", *, sigma: float = 0.5,
                       alpha: float = 1.2) -> LatencyModel:
    """Per-round multiplicative compute jitter, shape (m,), >= 0."""
    factory = _LATENCY_MODELS.get(kind)
    if factory is None:
        raise ValueError(f"unknown latency model {kind!r}; registered: "
                         f"{latency_model_names()}")
    return factory(sigma=sigma, alpha=alpha)


class AdaptiveDeadlines:
    """Per-client EWMA of observed report latencies -> per-client cutoffs.

    A production FL server does not know a fixed straggler deadline up
    front; it learns one from the report times it observes. This tracker
    keeps, per client, an exponentially weighted moving average of the
    latencies the server has seen and budgets each round's wait for client
    i at ``slack * ewma_i``. Clients never observed yet get an infinite
    budget (the server has no basis to cut them off), so the first round
    behaves exactly like sync and the policy tightens as evidence arrives.

    Observations are CENSORED at the cutoff: for a client dropped at its
    budget the server only knows the report took longer than the budget it
    waited, so the budget itself (not the unobserved true arrival) feeds
    the EWMA -- this keeps the estimate finite under heavy-tail latencies
    while still adapting upward after a timeout.
    """

    def __init__(self, m: int, *, beta: float = 0.3, slack: float = 2.0):
        if not (0.0 < beta <= 1.0):
            raise ValueError(f"beta must be in (0, 1]; got {beta}")
        if slack < 1.0:
            raise ValueError(f"slack must be >= 1 (a budget below the "
                             f"estimate drops everyone); got {slack}")
        self.beta = beta
        self.slack = slack
        self.ewma = np.full(m, np.nan)  # nan = never observed

    def cutoffs(self) -> np.ndarray:
        """(m,) per-client wait budget for the coming round (inf = no
        estimate yet)."""
        return np.where(np.isnan(self.ewma), np.inf, self.slack * self.ewma)

    def observe(self, candidates: np.ndarray, arrivals: np.ndarray) -> None:
        """Fold one round's outcomes into the EWMAs.

        candidates: (m,) bool clients the server contacted; arrivals: (m,)
        simulated report times (inf = never arrived). Clients that beat
        their cutoff contribute their true latency; clients cut off
        contribute the (finite) budget the server actually waited; offline
        clients under an infinite budget contribute nothing.
        """
        cut = self.cutoffs()
        obs = np.minimum(np.asarray(arrivals, np.float64), cut)
        ok = np.asarray(candidates, bool) & np.isfinite(obs)
        first = np.isnan(self.ewma)
        new = np.where(first, obs,
                       (1.0 - self.beta) * self.ewma + self.beta * obs)
        self.ewma = np.where(ok, new, self.ewma)


def round_arrivals(profiles: ClientProfiles, rng: np.random.Generator,
                   latency: LatencyModel, *, work_flops: float,
                   down_bytes: float, up_bytes: np.ndarray | float
                   ) -> np.ndarray:
    """Simulated completion time (s) of each client for ONE round, (m,).

    ``up_bytes`` may be per-client (the codec can shrink uploads) or scalar.
    Unavailable clients get +inf (they never check in this round).
    """
    m = profiles.m
    jitter = np.asarray(latency(rng, m), dtype=np.float64)
    compute = (work_flops / NOMINAL_FLOPS) / profiles.speed * jitter
    t = (down_bytes / profiles.bw_down
         + compute
         + np.broadcast_to(np.asarray(up_bytes, np.float64), (m,))
         / profiles.bw_up)
    up = rng.random(m) < profiles.availability
    return np.where(up, t, np.inf)
