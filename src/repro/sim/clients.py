"""Per-client device heterogeneity profiles and latency models.

The paper frames FedEPM as addressing four *systems* issues -- communication
efficiency, computational complexity, stragglers, privacy -- but the core
round functions only see a boolean participation mask. This module supplies
the missing device model: each client has a static profile (relative compute
speed, up/down bandwidth, availability) and a per-round stochastic latency
multiplier drawn from a pluggable distribution. A round's simulated arrival
time for client i decomposes as

    t_i = down_bytes / bw_down_i                    (receive w^{tau+1})
        + (work_flops / NOMINAL_FLOPS) / speed_i * jitter_i   (local compute)
        + up_bytes_i / bw_up_i                      (upload z_i)

with t_i = inf when the client is unavailable this round. Everything here is
host-side numpy: the simulation decides masks and wall-clock OUTSIDE the
jitted round functions, then feeds the mask in through the round hook
(core.fedepm.fedepm_round(..., mask=...)), so the algorithmic math is never
forked.

Latency distributions (``make_latency_model``):

  deterministic -- jitter = 1 (useful for exactness tests: with an infinite
                   deadline the sim reproduces fedepm_round bit-for-bit)
  lognormal     -- exp(sigma*N - sigma^2/2), mean 1: benign dispersion
  pareto        -- Pareto(x_min=1, alpha): heavy-tail stragglers; alpha
                   around 1.1-1.5 produces the occasional 10-100x outlier
                   that deadline/over-selection policies exist to absorb
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

# Nominal device throughput used to convert a work estimate (flops) into
# seconds at speed 1.0. Absolute value only sets the time unit; policies
# compare relative times.
NOMINAL_FLOPS = 1e9

LatencyModel = Callable[[np.random.Generator, int], np.ndarray]


@dataclasses.dataclass(frozen=True)
class ClientProfiles:
    """Static per-client device characteristics (all shape (m,))."""

    speed: np.ndarray         # relative compute speed, 1.0 = nominal
    bw_up: np.ndarray         # uplink bytes/s
    bw_down: np.ndarray       # downlink bytes/s
    availability: np.ndarray  # P(client reachable in a given round), (0, 1]

    @property
    def m(self) -> int:
        return len(self.speed)


def make_profiles(m: int, seed: int = 0, *, speed_sigma: float = 0.4,
                  bw_up_mean: float = 1.25e6, bw_down_mean: float = 1e7,
                  bw_sigma: float = 0.6,
                  availability: float = 1.0) -> ClientProfiles:
    """Lognormal fleet: mobile-like up/down asymmetry (~10 Mbit up, ~80 Mbit
    down by default), dispersion controlled by the sigmas. availability may
    be a scalar applied to all clients."""
    rng = np.random.default_rng(seed)

    def logn(mean, sigma):
        # lognormal with the requested MEAN (not median)
        return mean * np.exp(sigma * rng.standard_normal(m)
                             - 0.5 * sigma * sigma)

    return ClientProfiles(
        speed=logn(1.0, speed_sigma),
        bw_up=logn(bw_up_mean, bw_sigma),
        bw_down=logn(bw_down_mean, bw_sigma),
        availability=np.full(m, float(availability)),
    )


def uniform_profiles(m: int) -> ClientProfiles:
    """Homogeneous fleet (speed = bw = 1-unit): with the deterministic
    latency model, arrival times are identical across clients -- the
    degenerate case the exactness tests pin against core.fedepm."""
    return ClientProfiles(speed=np.ones(m), bw_up=np.full(m, 1.25e6),
                          bw_down=np.full(m, 1e7),
                          availability=np.ones(m))


def make_latency_model(kind: str = "deterministic", *, sigma: float = 0.5,
                       alpha: float = 1.2) -> LatencyModel:
    """Per-round multiplicative compute jitter, shape (m,), >= 0."""
    if kind == "deterministic":
        return lambda rng, m: np.ones(m)
    if kind == "lognormal":
        return lambda rng, m: np.exp(
            sigma * rng.standard_normal(m) - 0.5 * sigma * sigma)
    if kind == "pareto":
        # numpy's pareto returns X - 1 for Pareto(x_min=1, alpha)
        return lambda rng, m: 1.0 + rng.pareto(alpha, size=m)
    raise ValueError(f"unknown latency model {kind!r}")


class AdaptiveDeadlines:
    """Per-client EWMA of observed report latencies -> per-client cutoffs.

    A production FL server does not know a fixed straggler deadline up
    front; it learns one from the report times it observes. This tracker
    keeps, per client, an exponentially weighted moving average of the
    latencies the server has seen and budgets each round's wait for client
    i at ``slack * ewma_i``. Clients never observed yet get an infinite
    budget (the server has no basis to cut them off), so the first round
    behaves exactly like sync and the policy tightens as evidence arrives.

    Observations are CENSORED at the cutoff: for a client dropped at its
    budget the server only knows the report took longer than the budget it
    waited, so the budget itself (not the unobserved true arrival) feeds
    the EWMA -- this keeps the estimate finite under heavy-tail latencies
    while still adapting upward after a timeout.
    """

    def __init__(self, m: int, *, beta: float = 0.3, slack: float = 2.0):
        if not (0.0 < beta <= 1.0):
            raise ValueError(f"beta must be in (0, 1]; got {beta}")
        if slack < 1.0:
            raise ValueError(f"slack must be >= 1 (a budget below the "
                             f"estimate drops everyone); got {slack}")
        self.beta = beta
        self.slack = slack
        self.ewma = np.full(m, np.nan)  # nan = never observed

    def cutoffs(self) -> np.ndarray:
        """(m,) per-client wait budget for the coming round (inf = no
        estimate yet)."""
        return np.where(np.isnan(self.ewma), np.inf, self.slack * self.ewma)

    def observe(self, candidates: np.ndarray, arrivals: np.ndarray) -> None:
        """Fold one round's outcomes into the EWMAs.

        candidates: (m,) bool clients the server contacted; arrivals: (m,)
        simulated report times (inf = never arrived). Clients that beat
        their cutoff contribute their true latency; clients cut off
        contribute the (finite) budget the server actually waited; offline
        clients under an infinite budget contribute nothing.
        """
        cut = self.cutoffs()
        obs = np.minimum(np.asarray(arrivals, np.float64), cut)
        ok = np.asarray(candidates, bool) & np.isfinite(obs)
        first = np.isnan(self.ewma)
        new = np.where(first, obs,
                       (1.0 - self.beta) * self.ewma + self.beta * obs)
        self.ewma = np.where(ok, new, self.ewma)


def round_arrivals(profiles: ClientProfiles, rng: np.random.Generator,
                   latency: LatencyModel, *, work_flops: float,
                   down_bytes: float, up_bytes: np.ndarray | float
                   ) -> np.ndarray:
    """Simulated completion time (s) of each client for ONE round, (m,).

    ``up_bytes`` may be per-client (the codec can shrink uploads) or scalar.
    Unavailable clients get +inf (they never check in this round).
    """
    m = profiles.m
    jitter = np.asarray(latency(rng, m), dtype=np.float64)
    compute = (work_flops / NOMINAL_FLOPS) / profiles.speed * jitter
    t = (down_bytes / profiles.bw_down
         + compute
         + np.broadcast_to(np.asarray(up_bytes, np.float64), (m,))
         / profiles.bw_up)
    up = rng.random(m) < profiles.availability
    return np.where(up, t, np.inf)
