"""Fused on-device round engine: scan-compiled multi-round execution.

The eager simulation driver (``FedSim.step``) pays one full host round-trip
per federated round: a jit dispatch for the selection mask, a device->host
transfer of the candidates, a host->device upload of the participation
mask, a jit dispatch for the round function, and (in the CLI) a blocking
``float(objective)``. At paper scale the round math itself is microseconds
of FLOPs, so wall-clock is dominated by dispatch overhead -- not by
anything the paper analyzes.

``run_rounds`` removes the per-round host synchronization for the clocked
policies (sync / deadline / adaptive / overselect) while reproducing the
eager trajectory BIT-FOR-BIT (state leaves, PRNG key, simulated clock,
byte-ledger totals -- pinned by tests/test_engine.py):

1. **Arrival precompute (host).** Per-round arrival times come from the
   host RNG exactly as in the eager path -- one ``round_arrivals`` draw per
   round, same call order, so the stream is unchanged. For a K-round chunk
   this is one (K, m) float64 array, computed up front.

2. **Candidate-stream scan (device).** The selection key stream is
   deterministic given which rounds abandon (an abandoned round does not
   advance the key), so one jitted ``lax.scan`` over the chunk replays the
   per-round ``split``/sampler calls and returns every round's candidate
   mask in a single transfer. Because abandonment itself depends on the
   masks, the engine iterates candidate-stream -> host policy to a
   fixpoint; each pass can only extend the correct abandoned-prefix, so it
   converges in 1 + (#rounds whose abandoned flag changed) passes --
   one pass in the common no-abandon case.

3. **Policy replay (host, float64).** Mask + round-duration logic is
   replayed in numpy, mirroring ``FedSim._apply_policy`` operation for
   operation (including the float32 casts the jit'd ``arrival_mask``
   helpers apply), so masks, durations, the simulated clock, and the byte
   ledger are bit-identical to eager. This is O(K m) numpy -- negligible.

4. **Round scan (device, donated buffers).** The (K, m) mask stream is
   uploaded once and ``jax.lax.scan`` runs K rounds in one XLA program
   (``core.fedepm.scan_round`` / ``core.baselines.scan_round`` bodies;
   with a codec the merge is fused into an extended body). The carried
   state and EF codec memory are donated (``donate_argnums``), so XLA
   reuses their buffers across chunks instead of copying. Per-round
   metrics stack on-device and transfer in ONE ``jax.device_get`` per
   chunk. Abandoned rounds carry state through via a ``tree_where`` on the
   whole carry -- the round body still runs, its result is discarded
   exactly.

Donation invariant: ``run_rounds`` snapshots the entry state (one copy)
before the first donating call, so references the caller still holds --
e.g. the ``state=s0`` it passed to ``FedSim`` -- stay valid; every
intermediate chunk state is engine-owned and safely donated.

The async policy is event-driven (client-level queue, data-dependent
control flow) and cannot be scan-compiled; ``run_rounds`` falls back to
the eager event path, which PR 4 batched separately (vectorized event
pushes, pow2-bucketed row gathers, cached device masks). Architecture
notes and how to read ``BENCH_engine.json``: docs/perf.md.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fedepm, participation
from repro.core.treeutil import tmap, tree_where, tree_where_client
from repro.sim import clients as simclients
from repro.sim.server import (FedSim, SimMetrics, emit_clocked_round_events,
                              fifo_cache_get, make_sim_metrics)
from repro.sim.transport import codec_roundtrip, ef_roundtrip

_SCAN_POLICIES = ("sync", "deadline", "adaptive", "overselect")


class EngineResult(NamedTuple):
    metrics: list            # SimMetrics, one per round (same as eager)
    w_tau: np.ndarray | None  # (K, ...) per-round broadcast point, host side


# ---------------------------------------------------------------------------
# host-side policy replay (bit-identical to FedSim._apply_policy)
# ---------------------------------------------------------------------------

def _arrival_mask_host(cand: np.ndarray, arr: np.ndarray,
                       deadline) -> np.ndarray:
    """numpy replica of participation.arrival_mask as the eager path calls
    it: arrivals (and per-client cutoffs) pass through jnp.asarray, i.e.
    FLOAT32, before the comparison -- replicate the cast exactly."""
    arr32 = arr.astype(np.float32)
    dl32 = np.asarray(deadline, dtype=np.float32)
    with np.errstate(invalid="ignore"):
        return cand & np.isfinite(arr32) & (arr32 <= dl32)


def _first_arrivals_host(cand: np.ndarray, arr: np.ndarray,
                         n_keep: int) -> np.ndarray:
    """numpy replica of participation.first_arrivals_mask (float32 sort
    keys, stable order -- jnp.argsort's default)."""
    t = np.where(cand, arr.astype(np.float32), np.float32(np.inf))
    order = np.argsort(t, kind="stable")
    rank = np.empty(len(t), np.int64)
    rank[order] = np.arange(len(t))
    return (rank < n_keep) & np.isfinite(t)


def _policy_round_host(sim: FedSim, candidates: np.ndarray,
                       arrivals: np.ndarray):
    """One round of FedSim._apply_policy, replayed host-side.

    Mask semantics use the same float32 comparisons as the jit'd helpers;
    round durations use the same float64 numpy arithmetic as the eager
    driver. Returns (mask, duration); for the adaptive policy this also
    folds the round's observations into sim.deadlines (the caller
    snapshots/restores the EWMA around fixpoint passes).
    """
    pol = sim.sim.policy
    t_cand = np.where(candidates, arrivals, np.inf)
    if pol == "sync":
        mask = _arrival_mask_host(candidates, arrivals, np.inf)
        dur = float(t_cand[mask].max()) if mask.any() else 0.0
        return mask, dur
    if pol == "deadline":
        dl = sim.sim.deadline
        mask = _arrival_mask_host(candidates, arrivals, dl)
        if not candidates.any():
            return mask, 0.0
        finite = t_cand[np.isfinite(t_cand)]
        if np.isfinite(t_cand[candidates]).all() \
                and (t_cand[candidates] <= dl).all():
            return mask, float(t_cand[candidates].max())
        if np.isfinite(dl):
            return mask, float(dl)
        return mask, float(finite.max()) if finite.size else 0.0
    if pol == "adaptive":
        cut = sim.deadlines.cutoffs()
        mask = _arrival_mask_host(candidates, arrivals, cut)
        wait = np.where(candidates, np.minimum(arrivals, cut), np.inf)
        finite = wait[np.isfinite(wait)]
        dur = float(finite.max()) if finite.size else 0.0
        sim.deadlines.observe(candidates, arrivals)
        return mask, dur
    if pol == "overselect":
        mask = _first_arrivals_host(candidates, arrivals, sim._n_keep)
        dur = float(t_cand[mask].max()) if mask.any() else 0.0
        return mask, dur
    raise ValueError(f"unknown policy {pol!r}")


def _policy_stream_host(sim: FedSim, candidates: np.ndarray,
                        arrivals: np.ndarray):
    """Replay C rounds of policy logic -> (masks, durs, abandoned, rec_ups)."""
    C, m = candidates.shape
    masks = np.zeros((C, m), bool)
    rec_ups = np.zeros((C, m), bool)
    durs = np.zeros(C, np.float64)
    abandoned = np.zeros(C, bool)
    for t in range(C):
        cand, arr = candidates[t], arrivals[t]
        mask, dur = _policy_round_host(sim, cand, arr)
        ab = bool(cand.any() and not mask.any())
        if ab:
            rec = np.zeros(m, bool)
        elif sim.sim.policy == "adaptive":
            rec = mask
        else:
            rec = cand & np.isfinite(arr) & (arr <= dur + 1e-12)
        masks[t], durs[t], abandoned[t], rec_ups[t] = mask, dur, ab, rec
    return masks, durs, abandoned, rec_ups


# ---------------------------------------------------------------------------
# device-side streams (compiled once per FedSim, cached on the instance)
# ---------------------------------------------------------------------------

# compiled-function caches, shared ACROSS FedSim instances: two sims with
# the same (round fn, loss fn, algorithm config, codec, batches) -- e.g.
# the eager and scan arms of a benchmark, or consecutive CLI runs in one
# process -- reuse one traced/compiled program instead of re-tracing per
# instance. Batches are keyed by IDENTITY and stay closure-captured like
# the eager driver's jit does: embedding them as XLA constants is what
# keeps the scan bit-identical to eager (constant-vs-argument batches
# change XLA's folding by 1 ulp); the cached closure keeps them alive, so
# the id cannot be recycled while the entry exists. Both caches are
# bounded (server.fifo_cache_get): a chunk-fn closure pins its whole
# dataset on device, so an unbounded cache would leak one dataset per
# swept task.
_CAND_STREAM_CACHE: dict = {}
_CHUNK_FN_CACHE: dict = {}


def _candidate_stream_fn(sim: FedSim):
    key = (sim.cfg, sim.sim.policy, sim.sim.overselect_factor)
    return fifo_cache_get(_CAND_STREAM_CACHE, key,
                          lambda: _build_candidate_stream(sim), cap=32)


def _chunk_fn(sim: FedSim, collect_w_tau: bool):
    key = (sim._round_fn, sim._loss_fn, sim.cfg, sim.sim.codec, sim._ef,
           collect_w_tau, id(sim._batches))
    return fifo_cache_get(_CHUNK_FN_CACHE, key,
                          lambda: _build_chunk_fn(sim, collect_w_tau),
                          cap=32)


def _build_candidate_stream(sim: FedSim):
    """Jitted scan replaying the per-round selection key splits.

    carry = (key, k): the key advances (first output of the round's
    3-way split) and k advances by k0 only on non-abandoned rounds,
    mirroring how the eager driver leaves the state untouched when a round
    is abandoned. Returns the (C, m) candidate-mask stream.
    """
    cfg = sim.cfg
    m, k0 = cfg.m, cfg.k0
    if sim.sim.policy == "overselect":
        rho_eff = min(1.0, cfg.rho * sim.sim.overselect_factor)

        def select(k_sel, k):
            return participation.sample_uniform(k_sel, m, rho_eff)
    else:
        sampler = getattr(cfg, "sampler", "uniform")
        if sampler == "uniform":
            def select(k_sel, k):
                return participation.sample_uniform(k_sel, m, cfg.rho)
        elif sampler == "coverage":
            def select(k_sel, k):
                return participation.sample_coverage(
                    k_sel, m, cfg.rho, k // k0, cfg.s0)
        elif sampler == "full":
            def select(k_sel, k):
                return jnp.ones((m,), bool)
        else:
            raise ValueError(f"unknown sampler {sampler!r}")

    def stream(key, k, abandoned):
        def body(carry, ab):
            key, k = carry
            next_key, k_sel, _ = jax.random.split(key, 3)
            cand = select(k_sel, k)
            key = jnp.where(ab, key, next_key)
            k = jnp.where(ab, k, k + jnp.asarray(k0, k.dtype))
            return (key, k), cand

        _, cands = jax.lax.scan(body, (key, k), abandoned)
        return cands

    return jax.jit(stream)


def _build_chunk_fn(sim: FedSim, collect_w_tau: bool):
    """Jitted K-round scan with donated (state, codec-memory) buffers.

    The body is the scan-compatible round (core.fedepm.scan_round /
    the equivalent baselines body) with the upload-codec merge fused in;
    ys stacks per-round RoundMetrics (and optionally w_tau) on-device.
    """
    round_fn = sim._round_fn
    batches, loss_fn, cfg = sim._batches, sim._loss_fn, sim.cfg
    codec, ef = sim.sim.codec, sim._ef
    if sim.alg == "fedepm":
        def core_body(st, xs):
            return fedepm.scan_round(st, xs, batches, loss_fn, cfg)
    else:
        def core_body(st, xs):
            return baselines.scan_round(st, xs, batches, loss_fn, cfg,
                                        round_fn)

    def chunk(state, H, codec_key, masks, abandoned, round_idx):
        def body(carry, x):
            st, Hc = carry
            mask, ab, ridx = x
            if codec is None:
                st2, rm = core_body(st, (mask, ab))
                ys = (rm, st2.w_tau) if collect_w_tau else (rm,)
                return (st2, Hc), ys
            new_st, rm = round_fn(st, batches, loss_fn, cfg, mask=mask)
            ckey = jax.random.fold_in(codec_key, ridx)
            if ef:
                dec = ef_roundtrip(new_st.Z, Hc, ckey, codec)
                new_st = new_st._replace(
                    Z=tree_where_client(mask, dec, st.Z))
                Hn = tree_where_client(mask, dec, Hc)
            else:
                dec = codec_roundtrip(new_st.Z, st.Z, ckey, codec)
                new_st = new_st._replace(
                    Z=tree_where_client(mask, dec, st.Z))
                Hn = Hc
            st2 = tree_where(ab, st, new_st)
            Hc2 = tree_where(ab, Hc, Hn)
            ys = (rm, st2.w_tau) if collect_w_tau else (rm,)
            return (st2, Hc2), ys

        return jax.lax.scan(body, (state, H), (masks, abandoned, round_idx))

    return jax.jit(chunk, donate_argnums=(0, 1))


def _copy_tree(tree):
    return tmap(lambda x: jnp.array(x, copy=True), tree)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def run_rounds(sim: FedSim, rounds: int, *, chunk: int | None = None,
               collect_w_tau: bool = False) -> EngineResult:
    """Advance ``sim`` by ``rounds`` rounds via the fused scan engine.

    Drop-in replacement for ``sim.run(rounds)``: ``sim.state``, ``sim.t``,
    ``sim.metrics``, ``sim.ledger``, ``sim.round_idx`` and
    ``sim.last_round_metrics`` end up bit-identical to the eager driver's.
    ``chunk`` bounds the rounds compiled into one scan (default: all of
    ``rounds``; each distinct chunk length compiles once per FedSim).
    ``collect_w_tau=True`` additionally stacks every round's broadcast
    point on-device and returns it host-side -- O(rounds * n_params)
    memory, meant for objective evaluation on small tasks (the CLI), not
    for LM-scale states.

    The async policy falls back to the eager event engine (see module
    docstring); metrics/state are whatever that path produces.
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1; got {rounds}")
    if sim.sim.policy == "async":
        mets = []
        w_parts = [] if collect_w_tau else None
        for _ in range(rounds):
            mets.append(sim.step())
            if collect_w_tau:
                w_parts.append(np.asarray(sim.state.w_tau))
                sim.host_syncs += 1
        return EngineResult(
            mets, np.stack(w_parts) if collect_w_tau else None)
    if sim.sim.policy not in _SCAN_POLICIES:
        raise ValueError(f"unknown policy {sim.sim.policy!r}")

    cand_stream = _candidate_stream_fn(sim)
    chunk_fn = _chunk_fn(sim, collect_w_tau)

    # donation invariant: snapshot the entry state once so buffers the
    # caller may still reference are never donated; all later chunk states
    # are engine-owned
    sim.state = _copy_tree(sim.state)
    H = _copy_tree(sim._H) if sim._ef else jnp.zeros((), jnp.float32)

    if chunk is not None and chunk < 1:
        raise ValueError(f"chunk must be >= 1 (None = all rounds in one "
                         f"scan); got {chunk}")
    chunk = rounds if chunk is None else min(chunk, rounds)
    out_metrics: list[SimMetrics] = []
    w_parts: list[np.ndarray] = []
    done = 0
    while done < rounds:
        C = min(chunk, rounds - done)
        # 1. arrivals: same host-RNG stream as C eager steps
        arrivals = np.stack([
            simclients.round_arrivals(
                sim.profiles, sim._rng, sim._latency,
                work_flops=sim._work, down_bytes=sim._down_bytes,
                up_bytes=sim._up_bytes)
            for _ in range(C)])
        # 2./3. candidate-stream + policy replay to the abandoned fixpoint
        ewma0 = sim.deadlines.ewma.copy() \
            if sim.sim.policy == "adaptive" else None
        abandoned = np.zeros(C, bool)
        for _ in range(C + 1):
            cands = np.asarray(cand_stream(
                sim.state.key, sim.state.k, jnp.asarray(abandoned)))
            sim.host_syncs += 1
            if ewma0 is not None:
                sim.deadlines.ewma = ewma0.copy()
            masks, durs, ab_new, rec_ups = _policy_stream_host(
                sim, cands, arrivals)
            if np.array_equal(ab_new, abandoned):
                break
            abandoned = ab_new
        else:  # pragma: no cover - the prefix argument guarantees progress
            raise RuntimeError("abandoned-round fixpoint did not converge")
        # 4. one donated scan over the chunk
        ridx0 = sim.round_idx
        (sim.state, H), ys = chunk_fn(
            sim.state, H, sim._codec_key,
            jnp.asarray(masks), jnp.asarray(abandoned),
            jnp.arange(ridx0, ridx0 + C, dtype=jnp.int32))
        rm_stack = ys[0]
        if collect_w_tau:
            w_parts.append(np.asarray(jax.device_get(ys[1])))
            sim.host_syncs += 1

        # host bookkeeping, identical to C eager steps
        live = np.flatnonzero(~abandoned)
        if live.size:
            sim.last_round_metrics = tmap(
                lambda y: y[int(live[-1])], rm_stack)
        for t in range(C):
            dur = float(durs[t])
            # the scan path reconstructs the SAME event stream the eager
            # driver emits: same helper, same already-computed host arrays
            if sim.telemetry.enabled:
                emit_clocked_round_events(
                    sim.telemetry, policy=sim.sim.policy,
                    round_idx=sim.round_idx, t0=sim.t,
                    candidates=cands[t], arrivals=arrivals[t],
                    mask=masks[t], dur=dur, rec_up=rec_ups[t],
                    abandoned=bool(abandoned[t]), codec=sim.sim.codec,
                    up_bytes=sim._up_bytes)
            brec = sim.ledger.record_round(
                down_mask=cands[t], up_mask=rec_ups[t],
                down_bytes=sim._down_bytes, up_bytes=sim._up_bytes,
                ts=sim.t + dur, round_idx=sim.round_idx)
            sim.t += dur
            m = make_sim_metrics(
                round_idx=sim.round_idx, t_round=dur, t_total=sim.t,
                n_contacted=int(cands[t].sum()),
                n_aggregated=int(masks[t].sum()), brec=brec,
                abandoned=bool(abandoned[t]))
            sim.metrics.append(m)
            out_metrics.append(m)
            sim.round_idx += 1
        done += C
    if sim._ef:
        sim._H = H
    return EngineResult(
        out_metrics, np.concatenate(w_parts) if collect_w_tau else None)


def run_to_objective(sim: FedSim, objective_fn, target: float, *,
                     max_rounds: int, chunk: int = 16) -> tuple:
    """Scan-engine race helper: run until the objective reaches ``target``.

    ``objective_fn`` maps the stacked (C, ...) per-round broadcast points
    to a (C,) vector of objective values -- ONE evaluation per chunk, so
    objective monitoring costs one dispatch per chunk instead of one per
    round (a per-round host ``float(f(w))`` would hand the dispatch
    overhead the engine removed straight back). Returns
    (rounds_to_target, hit: bool, objective at that round).
    """
    total = 0
    f = math.inf
    while total < max_rounds:
        C = min(chunk, max_rounds - total)
        res = run_rounds(sim, C, collect_w_tau=True)
        fs = np.asarray(objective_fn(jnp.asarray(res.w_tau)))
        sim.host_syncs += 1
        for fv in fs:
            total += 1
            f = float(fv)
            if f <= target:
                return total, True, f
    return total, False, f
