"""Fused on-device round engine: scan-compiled multi-round execution.

The eager simulation driver (``FedSim.step``) pays one full host round-trip
per federated round: a jit dispatch for the selection mask, a device->host
transfer of the candidates, a host->device upload of the participation
mask, a jit dispatch for the round function, and (in the CLI) a blocking
``float(objective)``. At paper scale the round math itself is microseconds
of FLOPs, so wall-clock is dominated by dispatch overhead -- not by
anything the paper analyzes.

``run_rounds`` removes the per-round host synchronization for the clocked
policies (sync / deadline / adaptive / overselect) while reproducing the
eager trajectory BIT-FOR-BIT (state leaves, PRNG key, simulated clock,
byte-ledger totals -- pinned by tests/test_engine.py):

1. **Arrival precompute (host).** Per-round arrival times come from the
   host RNG exactly as in the eager path -- one ``round_arrivals`` draw per
   round, same call order, so the stream is unchanged. For a K-round chunk
   this is one (K, m) float64 array, computed up front.

2. **Candidate-stream scan (device).** The selection key stream is
   deterministic given which rounds abandon (an abandoned round does not
   advance the key), so one jitted ``lax.scan`` over the chunk replays the
   per-round ``split``/sampler calls and returns every round's candidate
   mask in a single transfer. Because abandonment itself depends on the
   masks, the engine iterates candidate-stream -> host policy to a
   fixpoint; each pass can only extend the correct abandoned-prefix, so it
   converges in 1 + (#rounds whose abandoned flag changed) passes --
   one pass in the common no-abandon case.

3. **Policy replay (host, float64).** Mask + round-duration logic is
   replayed in numpy, mirroring ``FedSim._apply_policy`` operation for
   operation (including the float32 casts the jit'd ``arrival_mask``
   helpers apply), so masks, durations, the simulated clock, and the byte
   ledger are bit-identical to eager. This is O(K m) numpy -- negligible.

4. **Round scan (device, donated buffers).** The (K, m) mask stream is
   uploaded once and ``jax.lax.scan`` runs K rounds in one XLA program
   (``core.fedepm.scan_round`` / ``core.baselines.scan_round`` bodies;
   with a codec the merge is fused into an extended body). The carried
   state and EF codec memory are donated (``donate_argnums``), so XLA
   reuses their buffers across chunks instead of copying. Per-round
   metrics stack on-device and transfer in ONE ``jax.device_get`` per
   chunk. Abandoned rounds carry state through via a ``tree_where`` on the
   whole carry -- the round body still runs, its result is discarded
   exactly.

Donation invariant: ``run_rounds`` snapshots the entry state (one copy)
before the first donating call, so references the caller still holds --
e.g. the ``state=s0`` it passed to ``FedSim`` -- stay valid; every
intermediate chunk state is engine-owned and safely donated.

Async record/replay (policy="async")
------------------------------------
The async policy is event-driven (client-level queue, data-dependent
control flow), so it cannot be masked into the clocked round scan above.
Instead the engine RECORDS it: ``FedSim._step_async`` -- the one
scheduling pump both engines share -- runs C aggregation events with a
recording executor plugged into its device-work seam. Candidate draws
replay from a precomputed fire-count key stream (``_CandStream``: the
selection key/counter advance only when a dispatch fires, so the mask
stream is a pure function of the chunk-entry state); fires and merges
append host metadata (masks, table slots, staleness weights, codec
serials) to an op program instead of dispatching jit calls. One compiled
``lax.scan`` then replays the program (``_build_async_chunk_fn``), one
step per dispatch: the step runs the unmodified round function and
writes the dispatch group's fresh Z/W rows into a fixed-capacity
on-device payload TABLE (``_AsyncTable`` -- the bounded in-flight set;
slots alloc lowest-first at dispatch, free at merge), then an inner scan
folds the merges recorded before the next dispatch through the shared
``server.merge_contribution`` against the table rows. Both levels are
branch-free -- everything is validity-masked ``tree_where`` selection,
never ``lax.cond``/``lax.switch``, because conditional lowering perturbs
the round's fused reductions by ~1 ulp. State, EF memory, table and the
optional w_tau stack are all donated. Every host-side
quantity (clock, heap order, staleness, metrics, ledger, telemetry) is
computed by the SAME pump code as eager, and every device value is the
same math on the same bits, so the trajectory -- including the telemetry
event stream -- is bit-for-bit the eager one
(tests/test_engine_async.py).

Fault injection (``SimConfig.faults``, repro.sim.faults) is entirely
host-side: the clocked policy replay resolves the fault chains inside
``_policy_stream_host`` (snapshot/restoring the model around fixpoint
passes, like the adaptive EWMA), and the async recording pass runs the
same pump defenses as eager -- no compiled program changes at all, so
fault-injected trajectories and telemetry streams stay bit-for-bit
across engines (tests/test_faults.py).

Upload privacy (``SimConfig.privacy``, repro.privacy) splits the same
way: the clip transform is device work, so a noisy config swaps the
chunk bodies' codec round-trips for the private ones
(``transport.private_roundtrip`` / ``private_ef_roundtrip``), while the
noise DRAWS are host work fed in as data -- ``run_rounds`` stacks one
``transport.draw_unit_noise`` tree per round (privacy stream folded on
the round index) into the clocked scan's xs, and the async replay
stacks one per recorded merge (folded on the upload serial), the exact
draws the eager merge programs consume, so noisy trajectories stay
bit-for-bit across engines (tests/test_privacy.py; see
``draw_unit_noise`` for why in-body transcendentals would break this).
The accountant and secure-agg mask billing are host bookkeeping,
emitted by the SAME ``server.apply_clocked_privacy`` helper the eager
step calls (async charges live inside the shared pump, which the
recording pass runs).

Client-axis sharding: ``run_rounds(..., mesh=...)`` lays the stacked
(m, ...) state leaves out over a device mesh's "data" axis (the repo's
logical rule client -> data, sharding/rules.py + specs.leaf_spec rails)
before the compiled chunks run, so XLA partitions the per-client round
math data-parallel; a single-device mesh is bit-identical to unsharded.
Architecture notes and how to read ``BENCH_engine.json``: docs/perf.md.
"""
from __future__ import annotations

import heapq
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fedepm, participation
from repro.core.treeutil import tmap, tree_where, tree_where_client
from repro.sim import clients as simclients
from repro.sim.server import (_EAGER_ASYNC_EXEC, _EV_UPLOAD, FedSim,
                              SimMetrics, apply_clocked_privacy, copy_tree,
                              emit_clocked_round_events, fifo_cache_get,
                              make_sim_metrics, merge_contribution)
from repro.sim.transport import (codec_roundtrip, draw_unit_noise,
                                 ef_roundtrip, private_ef_roundtrip,
                                 private_roundtrip)

_SCAN_POLICIES = ("sync", "deadline", "adaptive", "overselect")


class EngineResult(NamedTuple):
    metrics: list            # SimMetrics, one per round (same as eager)
    w_tau: np.ndarray | None  # (K, ...) per-round broadcast point, host side


# ---------------------------------------------------------------------------
# host-side policy replay (bit-identical to FedSim._apply_policy)
# ---------------------------------------------------------------------------

def _arrival_mask_host(cand: np.ndarray, arr: np.ndarray,
                       deadline) -> np.ndarray:
    """numpy replica of participation.arrival_mask as the eager path calls
    it: arrivals (and per-client cutoffs) pass through jnp.asarray, i.e.
    FLOAT32, before the comparison -- replicate the cast exactly."""
    arr32 = arr.astype(np.float32)
    dl32 = np.asarray(deadline, dtype=np.float32)
    with np.errstate(invalid="ignore"):
        return cand & np.isfinite(arr32) & (arr32 <= dl32)


def _first_arrivals_host(cand: np.ndarray, arr: np.ndarray,
                         n_keep: int) -> np.ndarray:
    """numpy replica of participation.first_arrivals_mask (float32 sort
    keys, stable order -- jnp.argsort's default)."""
    t = np.where(cand, arr.astype(np.float32), np.float32(np.inf))
    order = np.argsort(t, kind="stable")
    rank = np.empty(len(t), np.int64)
    rank[order] = np.arange(len(t))
    return (rank < n_keep) & np.isfinite(t)


def _policy_round_host(sim: FedSim, candidates: np.ndarray,
                       arrivals: np.ndarray):
    """One round of FedSim._apply_policy, replayed host-side.

    Mask semantics use the same float32 comparisons as the jit'd helpers;
    round durations use the same float64 numpy arithmetic as the eager
    driver. Returns (mask, duration); for the adaptive policy this also
    folds the round's observations into sim.deadlines (the caller
    snapshots/restores the EWMA around fixpoint passes).
    """
    pol = sim.sim.policy
    t_cand = np.where(candidates, arrivals, np.inf)
    if pol == "sync":
        mask = _arrival_mask_host(candidates, arrivals, np.inf)
        dur = float(t_cand[mask].max()) if mask.any() else 0.0
        return mask, dur
    if pol == "deadline":
        dl = sim.sim.deadline
        mask = _arrival_mask_host(candidates, arrivals, dl)
        if not candidates.any():
            return mask, 0.0
        finite = t_cand[np.isfinite(t_cand)]
        if np.isfinite(t_cand[candidates]).all() \
                and (t_cand[candidates] <= dl).all():
            return mask, float(t_cand[candidates].max())
        if np.isfinite(dl):
            return mask, float(dl)
        return mask, float(finite.max()) if finite.size else 0.0
    if pol == "adaptive":
        cut = sim.deadlines.cutoffs()
        mask = _arrival_mask_host(candidates, arrivals, cut)
        wait = np.where(candidates, np.minimum(arrivals, cut), np.inf)
        finite = wait[np.isfinite(wait)]
        dur = float(finite.max()) if finite.size else 0.0
        sim.deadlines.observe(candidates, arrivals)
        return mask, dur
    if pol == "overselect":
        mask = _first_arrivals_host(candidates, arrivals, sim._n_keep)
        dur = float(t_cand[mask].max()) if mask.any() else 0.0
        return mask, dur
    raise ValueError(f"unknown policy {pol!r}")


def _policy_stream_host(sim: FedSim, candidates: np.ndarray,
                        arrivals: np.ndarray):
    """Replay C rounds of policy logic.

    Returns (masks, durs, abandoned, rec_ups, cands_eff, arrs_eff, fouts):
    the EFFECTIVE candidate/arrival streams the policy saw (fault
    resolution applied per round, exactly as the eager ``step()`` does
    before ``_apply_policy``) plus the per-round fault outcomes (None
    entries without a fault model). Mutates the fault model's state in
    round order -- fixpoint callers snapshot/restore it around passes,
    like the adaptive EWMA.
    """
    C, m = candidates.shape
    masks = np.zeros((C, m), bool)
    rec_ups = np.zeros((C, m), bool)
    durs = np.zeros(C, np.float64)
    abandoned = np.zeros(C, bool)
    fm = sim._faults
    cands_eff = np.asarray(candidates, bool).copy()
    arrs_eff = np.asarray(arrivals, np.float64).copy()
    fouts: list = [None] * C
    for t in range(C):
        cand, arr = cands_eff[t], arrs_eff[t]
        if fm is not None:
            fo = fm.apply_clocked(
                round_idx=sim.round_idx + t, candidates=cand, arrivals=arr,
                cutoff=sim.sim.deadline
                if sim.sim.policy == "deadline" else math.inf)
            cand, arr = fo.candidates, fo.arrivals
            cands_eff[t], arrs_eff[t] = cand, arr
            fouts[t] = fo
        mask, dur = _policy_round_host(sim, cand, arr)
        ab = bool(cand.any() and not mask.any())
        if ab:
            rec = np.zeros(m, bool)
        elif sim.sim.policy == "adaptive":
            rec = mask
        else:
            rec = cand & np.isfinite(arr) & (arr <= dur + 1e-12)
        masks[t], durs[t], abandoned[t], rec_ups[t] = mask, dur, ab, rec
    return masks, durs, abandoned, rec_ups, cands_eff, arrs_eff, fouts


# ---------------------------------------------------------------------------
# device-side streams (compiled once per FedSim, cached on the instance)
# ---------------------------------------------------------------------------

# compiled-function caches, shared ACROSS FedSim instances: two sims with
# the same (round fn, loss fn, algorithm config, codec, batches) -- e.g.
# the eager and scan arms of a benchmark, or consecutive CLI runs in one
# process -- reuse one traced/compiled program instead of re-tracing per
# instance. Batches are keyed by IDENTITY and stay closure-captured like
# the eager driver's jit does: embedding them as XLA constants is what
# keeps the scan bit-identical to eager (constant-vs-argument batches
# change XLA's folding by 1 ulp); the cached closure keeps them alive, so
# the id cannot be recycled while the entry exists. Both caches are
# bounded (server.fifo_cache_get): a chunk-fn closure pins its whole
# dataset on device, so an unbounded cache would leak one dataset per
# swept task.
_CAND_STREAM_CACHE: dict = {}
_CHUNK_FN_CACHE: dict = {}


def _candidate_stream_fn(sim: FedSim):
    key = (sim.cfg, sim.sim.policy, sim.sim.overselect_factor)
    return fifo_cache_get(_CAND_STREAM_CACHE, key,
                          lambda: _build_candidate_stream(sim), cap=32)


def _chunk_fn(sim: FedSim, collect_w_tau: bool):
    key = (sim._round_fn, sim._loss_fn, sim.cfg, sim.sim.codec, sim._ef,
           sim._privacy_tx, collect_w_tau, id(sim._batches))
    return fifo_cache_get(_CHUNK_FN_CACHE, key,
                          lambda: _build_chunk_fn(sim, collect_w_tau),
                          cap=32)


def _make_selector(sim: FedSim):
    """Jit-safe candidate selector ``(k_sel, k) -> (m,) bool`` for ``sim``.

    Replicates exactly what the algorithm's default mask function computes
    from the round's 3-way key split -- ONE definition shared by the
    clocked candidate-stream scan and the async fire-count stream, so
    neither replay can drift from the eager ``sim._candidates`` draw.
    """
    cfg = sim.cfg
    m, k0 = cfg.m, cfg.k0
    if sim.sim.policy == "overselect":
        rho_eff = min(1.0, cfg.rho * sim.sim.overselect_factor)

        def select(k_sel, k):
            return participation.sample_uniform(k_sel, m, rho_eff)
        return select
    sampler = getattr(cfg, "sampler", "uniform")
    if sampler == "uniform":
        def select(k_sel, k):
            return participation.sample_uniform(k_sel, m, cfg.rho)
    elif sampler == "coverage":
        def select(k_sel, k):
            return participation.sample_coverage(
                k_sel, m, cfg.rho, k // k0, cfg.s0)
    elif sampler == "full":
        def select(k_sel, k):
            return jnp.ones((m,), bool)
    else:
        raise ValueError(f"unknown sampler {sampler!r}")
    return select


def _build_candidate_stream(sim: FedSim):
    """Jitted scan replaying the per-round selection key splits.

    carry = (key, k): the key advances (first output of the round's
    3-way split) and k advances by k0 only on non-abandoned rounds,
    mirroring how the eager driver leaves the state untouched when a round
    is abandoned. Returns the (C, m) candidate-mask stream.
    """
    k0 = sim.cfg.k0
    select = _make_selector(sim)

    def stream(key, k, abandoned):
        def body(carry, ab):
            key, k = carry
            next_key, k_sel, _ = jax.random.split(key, 3)
            cand = select(k_sel, k)
            key = jnp.where(ab, key, next_key)
            k = jnp.where(ab, k, k + jnp.asarray(k0, k.dtype))
            return (key, k), cand

        _, cands = jax.lax.scan(body, (key, k), abandoned)
        return cands

    return jax.jit(stream)


def _build_chunk_fn(sim: FedSim, collect_w_tau: bool):
    """Jitted K-round scan with donated (state, codec-memory) buffers.

    The body is the scan-compatible round (core.fedepm.scan_round /
    the equivalent baselines body) with the upload-codec merge fused in;
    ys stacks per-round RoundMetrics (and optionally w_tau) on-device.
    """
    round_fn = sim._round_fn
    batches, loss_fn, cfg = sim._batches, sim._loss_fn, sim.cfg
    codec, ef = sim.sim.codec, sim._ef
    privacy = sim._privacy_tx
    if sim.alg == "fedepm":
        def core_body(st, xs):
            return fedepm.scan_round(st, xs, batches, loss_fn, cfg)
    else:
        def core_body(st, xs):
            return baselines.scan_round(st, xs, batches, loss_fn, cfg,
                                        round_fn)

    def chunk(state, H, codec_key, masks, abandoned, round_idx, noise):
        def body(carry, x):
            st, Hc = carry
            mask, ab, ridx, ns = x
            if codec is None and privacy is None:
                st2, rm = core_body(st, (mask, ab))
                ys = (rm, st2.w_tau) if collect_w_tau else (rm,)
                return (st2, Hc), ys
            new_st, rm = round_fn(st, batches, loss_fn, cfg, mask=mask)
            ckey = jax.random.fold_in(codec_key, ridx)
            if privacy is not None:
                # noisy merge: same private round-trips as the eager
                # server's merge programs; the round's unit-noise tree
                # arrives as scan xs (host-drawn by run_rounds from the
                # dedicated privacy stream -- data, so both engines
                # perturb bit-identically)
                if ef:
                    dec = private_ef_roundtrip(new_st.Z, Hc, ckey, ns,
                                               codec, privacy)
                    new_st = new_st._replace(
                        Z=tree_where_client(mask, dec, st.Z))
                    Hn = tree_where_client(mask, dec, Hc)
                else:
                    dec = private_roundtrip(new_st.Z, st.Z, ckey, ns,
                                            codec, privacy)
                    new_st = new_st._replace(
                        Z=tree_where_client(mask, dec, st.Z))
                    Hn = Hc
            elif ef:
                dec = ef_roundtrip(new_st.Z, Hc, ckey, codec)
                new_st = new_st._replace(
                    Z=tree_where_client(mask, dec, st.Z))
                Hn = tree_where_client(mask, dec, Hc)
            else:
                dec = codec_roundtrip(new_st.Z, st.Z, ckey, codec)
                new_st = new_st._replace(
                    Z=tree_where_client(mask, dec, st.Z))
                Hn = Hc
            st2 = tree_where(ab, st, new_st)
            Hc2 = tree_where(ab, Hc, Hn)
            ys = (rm, st2.w_tau) if collect_w_tau else (rm,)
            return (st2, Hc2), ys

        return jax.lax.scan(body, (state, H),
                            (masks, abandoned, round_idx, noise))

    return jax.jit(chunk, donate_argnums=(0, 1))


def _copy_tree(tree):
    return tmap(lambda x: jnp.array(x, copy=True), tree)


# ---------------------------------------------------------------------------
# async record/replay (policy="async")
# ---------------------------------------------------------------------------

#: async candidate masks are computed in blocks of this many fires per
#: device dispatch (one host transfer per block, not per draw)
_ASYNC_STREAM_BLOCK = 64

_ASYNC_STREAM_CACHE: dict = {}


def _async_stream_fn(sim: FedSim):
    def build():
        select = _make_selector(sim)
        k0 = sim.cfg.k0

        def block(key, k):
            def body(carry, _):
                key, k = carry
                next_key, k_sel, _ = jax.random.split(key, 3)
                cand = select(k_sel, k)
                return (next_key, k + jnp.asarray(k0, k.dtype)), cand

            (key, k), cands = jax.lax.scan(
                body, (key, k), None, length=_ASYNC_STREAM_BLOCK)
            return cands, key, k

        return jax.jit(block)

    return fifo_cache_get(_ASYNC_STREAM_CACHE, (sim.cfg, sim.sim.policy),
                          build, cap=32)


class _CandStream:
    """Async candidate masks indexed by FIRE COUNT (host-side cache).

    The selection key and step counter advance ONLY when a dispatch group
    fires (one key split + k0 per round-function call), never on the draw
    itself -- so the whole mask stream of a recording chunk is a pure
    function of the chunk-entry algorithm state: mask ``n`` is what the
    eager server would draw after ``n`` fires. An all-offline cohort's
    retry re-draws the SAME index (no fire happened), reproducing eager's
    repeated draw from the unchanged key with fresh availability.
    """

    def __init__(self, sim: FedSim):
        self._sim = sim
        self._fn = _async_stream_fn(sim)
        self._key = sim.state.key
        self._k = sim.state.k
        self._masks: list[np.ndarray] = []

    def mask(self, n_fires: int) -> np.ndarray:
        while n_fires >= len(self._masks):
            cands, self._key, self._k = self._fn(self._key, self._k)
            self._sim.host_syncs += 1
            self._masks.extend(np.asarray(cands))
        return self._masks[n_fires]


class _AsyncTable:
    """Fixed-capacity on-device payload table: the bounded in-flight set.

    One row per outstanding upload: ``z``/``w`` are (cap, ...) pytrees
    whose row ``slot`` holds a dispatched client's upload/iterate rows,
    written by the fire op that dispatched it and read back by the merge
    op that folds it in. A table IS a ``_Contribution`` batch (``slot`` ==
    batch row), so the eager merge path consumes table-backed
    contributions through the same ``merge_contribution`` call. Slots
    allocate lowest-index-first from a min-heap -- a deterministic rule,
    so recorded slot assignments are reproducible -- and free when their
    contribution merges. With the ``event_table_capacity`` knob pinned the
    table never grows (overflow raises, naming the knob); unset, it
    doubles on demand (each capacity compiles one more chunk program).
    """

    def __init__(self, Z, W, cap: int, *, fixed: bool):
        self.cap = cap
        self.fixed = fixed
        self.z = tmap(lambda x: jnp.zeros((cap,) + x.shape[1:], x.dtype), Z)
        self.w = tmap(lambda x: jnp.zeros((cap,) + x.shape[1:], x.dtype), W)
        self._free = list(range(cap))

    def alloc(self) -> int:
        if not self._free:
            if self.fixed:
                raise ValueError(
                    f"async event table overflow: all {self.cap} slots "
                    f"hold in-flight uploads; raise the engine's "
                    f"event_table_capacity knob (or unset it to let the "
                    f"table grow on demand)")
            grow = self.cap
            self.z = tmap(lambda x: jnp.concatenate(
                [x, jnp.zeros((grow,) + x.shape[1:], x.dtype)]), self.z)
            self.w = tmap(lambda x: jnp.concatenate(
                [x, jnp.zeros((grow,) + x.shape[1:], x.dtype)]), self.w)
            self._free = list(range(self.cap, self.cap + grow))
            self.cap += grow
        return heapq.heappop(self._free)

    def free(self, slot: int) -> None:
        heapq.heappush(self._free, slot)

    def clone(self) -> "_AsyncTable":
        t = object.__new__(_AsyncTable)
        t.cap, t.fixed = self.cap, self.fixed
        t.z, t.w = copy_tree(self.z), copy_tree(self.w)
        t._free = list(self._free)
        return t


class _RecordAsyncExec:
    """Recording executor: defers device work into a replayable op program.

    Plugged into ``FedSim._step_async``'s executor seam for the chunk's C
    steps. Candidate draws replay from the fire-count stream; ``fire``/
    ``merge`` append host metadata only (masks, table slots, staleness
    weights, codec serials) -- no jit dispatch happens until the recorded
    program replays as ONE compiled scan. Slot lifecycle resolves at
    record time (alloc at fire, free at merge); replay executes ops in
    recorded order, so a slot reused by a later fire is always rewritten
    AFTER the merge that read it.
    """

    recording = True

    def __init__(self, stream: _CandStream, table: _AsyncTable):
        self.stream = stream
        self.table = table
        self.ops: list[dict] = []
        self.n_fires = 0
        self.cur_step = 0

    def draw_candidates(self, sim) -> np.ndarray:
        return self.stream.mask(self.n_fires)

    def fire(self, sim, group, mask: np.ndarray, contribs) -> None:
        slots = []
        for c in contribs:
            c.slot = self.table.alloc()
            slots.append((c.slot, c.client))
        self.ops.append({
            "kind": 0, "step": self.cur_step, "mask": mask,
            "agg": (sim._cohort_live | mask)
            if sim._step_agg is not None else mask,
            "slots": slots})
        self.n_fires += 1

    def merge(self, sim, c, staleness: int, gamma: float) -> None:
        self.ops.append({
            "kind": 1, "step": self.cur_step, "slot": c.slot,
            "client": c.client, "serial": c.serial,
            "gamma": np.float32(gamma)})
        self.table.free(c.slot)

    def release(self, sim, c) -> None:
        # fault injection: the upload was lost/rejected -- its table slot
        # frees WITHOUT a merge op, so the replay never reads the row (the
        # non-merge is exact: no op recorded, no device work)
        self.table.free(c.slot)
        c.slot = -1


def _async_chunk_fn(sim: FedSim, collect_w_tau: bool):
    key = ("async", sim._round_fn, sim._loss_fn, sim.cfg, sim.sim.codec,
           sim._ef, sim._privacy_tx, collect_w_tau, id(sim._batches))
    return fifo_cache_get(
        _CHUNK_FN_CACHE, key,
        lambda: _build_async_chunk_fn(sim, collect_w_tau), cap=32)


def _build_async_chunk_fn(sim: FedSim, collect_w_tau: bool):
    """Compiled async replay: ONE ``lax.scan`` over the recorded program.

    The program is GROUPED: one scan step = one dispatch (validity-masked)
    followed by the merges recorded between it and the next dispatch (an
    inner ``lax.scan`` over ``Mmax`` validity-masked merge records). The
    carry is (algorithm state, EF memory, table z, table w, w_tau stack),
    every buffer donated.

    There are NO data-dependent conditionals anywhere in the body -- no
    ``lax.switch``, no ``lax.cond``. Wrapping the round function in either
    changes how XLA fuses its reductions and moves the DP-noise arithmetic
    by ~1 ulp relative to the eager jit; a plain scan body that runs the
    round unconditionally and selects outcomes with ``tree_where`` is
    bit-identical (the same pattern the clocked chunk uses for abandoned
    rounds, and the differential tests pin it). Invalid (padding /
    merge-only) steps therefore still RUN the round on a zero mask and
    discard every output; invalid merge records merge slot 0 and discard.

    A valid step's fire is exactly the eager fire: broadcast/key/counter
    advance, the dispatch group's fresh Z/W rows written into their
    recorded table slots (exact row copies, bit-equal to the eager
    per-group gather). Merges call the shared ``merge_contribution`` with
    the post-fire table as the batch and the recorded slot as the batch
    row. Step counts pad to small buckets so chunk programs compile per
    bucket, not per step count.
    """
    round_fn = sim._round_fn
    batches, loss_fn, cfg = sim._batches, sim._loss_fn, sim.cfg
    codec, ef = sim.sim.codec, sim._ef
    privacy = sim._privacy_tx
    use_agg = sim.alg != "fedepm"

    def chunk(state, H, tz, tw, ws, codec_key, xs):
        def body(carry, x):
            st, Hc, tz, tw, ws = carry
            if use_agg:
                new_st, rm = round_fn(st, batches, loss_fn, cfg,
                                      mask=x["mask"], agg_mask=x["agg"])
            else:
                new_st, rm = round_fn(st, batches, loss_fn, cfg,
                                      mask=x["mask"])
            v = x["fire_valid"]
            st2 = st._replace(
                w_tau=tree_where(v, new_st.w_tau, st.w_tau),
                k=jnp.where(v, new_st.k, st.k),
                key=jnp.where(v, new_st.key, st.key))
            # invalid steps carry slot_src == -1 everywhere: no writes
            src = jnp.clip(x["slot_src"], 0)
            upd = x["slot_src"] >= 0
            tz2 = tree_where_client(
                upd, tmap(lambda a: a[src], new_st.Z), tz)
            tw2 = tree_where_client(
                upd, tmap(lambda a: a[src], new_st.W), tw)
            if collect_w_tau:
                ws2 = tmap(
                    lambda s, w: jax.lax.dynamic_update_index_in_dim(
                        s, w, x["step"], 0), ws, st2.w_tau)
                ws = tree_where(v, ws2, ws)

            def mbody(mc, mx):
                stc, Hcc = mc
                ckey = jax.random.fold_in(codec_key, mx["serial"])
                # this merge's host-drawn unit-noise tree rides the xs
                # row (replayed from the SAME per-serial draws the eager
                # merge executor makes); absent on the no-noise path
                ns = mx["noise"] if privacy is not None else None
                Z, W, Hn = merge_contribution(
                    stc.Z, stc.W, Hcc, tz2, tw2, mx["slot"], mx["client"],
                    mx["gamma"], ckey, ns, codec=codec, ef=ef,
                    privacy=privacy)
                mv = mx["valid"]
                stn = stc._replace(Z=tree_where(mv, Z, stc.Z),
                                   W=tree_where(mv, W, stc.W))
                return (stn, tree_where(mv, Hn, Hcc)), jnp.zeros((),
                                                                 jnp.int32)

            (st3, H2), _ = jax.lax.scan(mbody, (st2, Hc), x["merges"])
            return (st3, H2, tz2, tw2, ws), rm

        carry, rms = jax.lax.scan(body, (state, H, tz, tw, ws), xs)
        return carry + (rms,)

    return jax.jit(chunk, donate_argnums=(0, 1, 2, 3, 4))


def _record_replay_chunk(sim: FedSim, C: int, collect_w_tau: bool,
                         table: _AsyncTable,
                         w_parts: list | None) -> list[SimMetrics]:
    """Record C async aggregation events, then replay them compiled."""
    rec = _RecordAsyncExec(_CandStream(sim), table)
    # contributions dispatched by an earlier EAGER phase enter the table:
    # their gathered batch rows become table rows (exact copies), so the
    # chunk program merges them like any recorded fire's upload
    for _, _, kind, c in sim._events:
        if kind == _EV_UPLOAD and c.slot < 0 and not c.dup:
            # (duplicate ghosts carry no payload at all -- dedup discards
            # them at arrival, so they never need a table row)
            s = table.alloc()
            table.z = tmap(lambda t, b: t.at[s].set(b[c.row]),
                           table.z, c.z_batch)
            table.w = tmap(lambda t, b: t.at[s].set(b[c.row]),
                           table.w, c.w_batch)
            c.slot, c.z_batch, c.w_batch = s, None, None

    sim._exec = rec
    try:
        mets = []
        for t in range(C):
            rec.cur_step = t
            mets.append(sim.step())
    finally:
        sim._exec = _EAGER_ASYNC_EXEC

    fire_steps = {op["step"] for op in rec.ops if op["kind"] == 0}
    entry_w = None
    if collect_w_tau and len(fire_steps) < C:
        # steps without a fire keep the previous broadcast: their stack
        # rows forward-fill host-side, seeded from the chunk-entry w_tau
        # -- fetched BEFORE the donating call consumes it
        entry_w = np.asarray(jax.device_get(sim.state.w_tau))
        sim.host_syncs += 1

    w_np = None
    if rec.ops:
        cap, m = table.cap, sim.cfg.m
        # group the flat op stream: one program step per dispatch, each
        # carrying the merges recorded before the NEXT dispatch (a leading
        # merge-only prefix becomes one fire-invalid step)
        groups: list[dict] = []
        for op in rec.ops:
            if op["kind"] == 0:
                groups.append({"fire": op, "merges": []})
            else:
                if not groups:
                    groups.append({"fire": None, "merges": []})
                groups[-1]["merges"].append(op)
        n_steps = len(groups)
        # steps run a full (possibly discarded) round each, so pad to
        # SMALL buckets: pow2 up to 8, then multiples of 8 -- bounded
        # recompiles, bounded padding waste
        if n_steps <= 8:
            n_pad = 1 << max(0, (n_steps - 1).bit_length())
        else:
            n_pad = -(-n_steps // 8) * 8
        mmax = max((len(g["merges"]) for g in groups), default=0)
        m_pad = (1 << max(0, (mmax - 1).bit_length())) if mmax else 0

        fire_valid = np.zeros(n_pad, bool)
        mask = np.zeros((n_pad, m), bool)
        agg = np.zeros((n_pad, m), bool)
        slot_src = np.full((n_pad, cap), -1, np.int32)
        step = np.zeros(n_pad, np.int32)
        mvalid = np.zeros((n_pad, m_pad), bool)
        mslot = np.zeros((n_pad, m_pad), np.int32)
        mclient = np.zeros((n_pad, m_pad), np.int32)
        mserial = np.zeros((n_pad, m_pad), np.int32)
        mgamma = np.zeros((n_pad, m_pad), np.float32)
        last_fire = -1
        for i, g in enumerate(groups):
            if g["fire"] is not None:
                op = g["fire"]
                fire_valid[i] = True
                mask[i] = op["mask"]
                agg[i] = op["agg"]
                step[i] = op["step"]
                for s, cl in op["slots"]:
                    slot_src[i, s] = cl
                last_fire = i
            for j, op in enumerate(g["merges"]):
                mvalid[i, j] = True
                mslot[i, j] = op["slot"]
                mclient[i, j] = op["client"]
                mserial[i, j] = op["serial"]
                mgamma[i, j] = op["gamma"]
        fn = _async_chunk_fn(sim, collect_w_tau)
        H = sim._H if sim._ef else jnp.zeros((), jnp.float32)
        if collect_w_tau:
            ws0 = tmap(lambda v: jnp.zeros((C,) + v.shape, v.dtype),
                       sim.state.w_tau)
        else:
            ws0 = jnp.zeros((), jnp.float32)
        merges_x = {"valid": jnp.asarray(mvalid),
                    "slot": jnp.asarray(mslot),
                    "client": jnp.asarray(mclient),
                    "serial": jnp.asarray(mserial),
                    "gamma": jnp.asarray(mgamma)}
        if sim._privacy_tx is not None:
            # per-merge unit noise replayed from the SAME standalone
            # program (and the same per-serial key folds) the eager merge
            # executor uses, stacked to (n_pad, m_pad, 1, ...) xs rows;
            # invalid/padded merge slots carry zeros (their merges are
            # masked off, the values never land)
            like = sim._noise_row_like
            zero = tmap(lambda sd: jnp.zeros(sd.shape, sd.dtype), like)
            flat = [draw_unit_noise(
                jax.random.fold_in(sim._privacy_key, int(mserial[i, j])),
                like, sim._privacy_tx) if mvalid[i, j] else zero
                for i in range(n_pad) for j in range(m_pad)]
            if flat:
                merges_x["noise"] = tmap(
                    lambda *ls: jnp.stack(ls).reshape(
                        (n_pad, m_pad) + ls[0].shape), *flat)
            else:
                merges_x["noise"] = tmap(
                    lambda sd: jnp.zeros((n_pad, m_pad) + sd.shape,
                                         sd.dtype), like)
        xs = {"fire_valid": jnp.asarray(fire_valid),
              "mask": jnp.asarray(mask), "agg": jnp.asarray(agg),
              "slot_src": jnp.asarray(slot_src), "step": jnp.asarray(step),
              "merges": merges_x}
        state, H, tz, tw, ws, rms = fn(sim.state, H, table.z, table.w,
                                       ws0, sim._codec_key, xs)
        sim.state = state
        if sim._ef:
            sim._H = H
        table.z, table.w = tz, tw
        if last_fire >= 0:
            sim.last_round_metrics = tmap(lambda y: y[last_fire], rms)
        if collect_w_tau:
            w_np = np.asarray(jax.device_get(ws))
            sim.host_syncs += 1

    # in-flight table-backed contributions now reference the NEW table
    # trees (the old ones were donated into the chunk program)
    for _, _, kind_, c in sim._events:
        if kind_ == _EV_UPLOAD and c.slot >= 0:
            c.z_batch, c.w_batch, c.row = table.z, table.w, c.slot

    if collect_w_tau:
        rows, last = [], entry_w
        for t in range(C):
            if w_np is not None and t in fire_steps:
                last = w_np[t]
            rows.append(last)
        w_parts.append(np.stack(rows))
    return mets


def _run_async_scan(sim: FedSim, rounds: int, *, chunk: int | None,
                    collect_w_tau: bool,
                    event_table_capacity: int | None) -> EngineResult:
    chunk = rounds if chunk is None else min(chunk, rounds)
    # donation invariant: copy the entry state once (the caller may still
    # hold the s0 it passed to FedSim); later states are engine-owned
    sim.state = _copy_tree(sim.state)
    if sim._async_table is None:
        if event_table_capacity is not None:
            cap, fixed = int(event_table_capacity), True
        else:
            # capped: at most max_concurrency in flight + a buffer's worth
            # awaiting merge; uncapped: the pump tops the system up to one
            # cohort, so ~2 cohorts bounds it (growth covers the tail)
            conc = sim._max_conc if math.isfinite(sim._max_conc) \
                else 2 * sim._cohort
            cap, fixed = int(conc) + sim._buffer_k, False
        sim._async_table = _AsyncTable(sim.state.Z, sim.state.W,
                                       max(1, cap), fixed=fixed)
    table = sim._async_table
    mets: list[SimMetrics] = []
    w_parts: list[np.ndarray] | None = [] if collect_w_tau else None
    done = 0
    while done < rounds:
        C = min(chunk, rounds - done)
        mets += _record_replay_chunk(sim, C, collect_w_tau, table, w_parts)
        done += C
    return EngineResult(
        mets, np.concatenate(w_parts) if collect_w_tau else None)


# ---------------------------------------------------------------------------
# client-axis mesh sharding
# ---------------------------------------------------------------------------

def _resolve_mesh(mesh):
    """None | int | jax.sharding.Mesh -> Mesh or None.

    An int builds a (data=mesh, model=1) test mesh via launch.mesh
    (imported lazily -- the sim layer must not depend on launch at module
    load).
    """
    if mesh is None or hasattr(mesh, "axis_names"):
        return mesh
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(n_data=int(mesh), n_model=1)


def _client_sharded(tree, m: int, mesh):
    """device_put: leading-client-axis leaves shard over the mesh's data
    axis (the repo's single-pod logical rule client -> data with
    specs.leaf_spec's divisibility rails); other leaves replicate. On a
    single-device mesh this is semantically a no-op -- which is what pins
    sharded == unsharded bit-for-bit (tests/test_sim_invariants.py).
    """
    from repro.sharding.rules import single_pod_rules
    from repro.sharding.specs import leaf_spec
    rules = single_pod_rules()
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def put(x):
        if getattr(x, "ndim", 0) and x.shape[0] == m:
            spec = leaf_spec(("client",) + (None,) * (x.ndim - 1),
                             x.shape, mesh, rules)
            return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))
        return jax.device_put(x, rep)

    return tmap(put, tree)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def run_rounds(sim: FedSim, rounds: int, *, chunk: int | None = None,
               collect_w_tau: bool = False, mesh=None,
               event_table_capacity: int | None = None) -> EngineResult:
    """Advance ``sim`` by ``rounds`` rounds via the fused scan engine.

    Drop-in replacement for ``sim.run(rounds)``: ``sim.state``, ``sim.t``,
    ``sim.metrics``, ``sim.ledger``, ``sim.round_idx`` and
    ``sim.last_round_metrics`` end up bit-identical to the eager driver's.
    ``chunk`` bounds the rounds compiled into one scan (default: all of
    ``rounds``; each distinct chunk length compiles once per FedSim).
    ``collect_w_tau=True`` additionally stacks every round's broadcast
    point on-device and returns it host-side -- O(rounds * n_params)
    memory, meant for objective evaluation on small tasks (the CLI), not
    for LM-scale states.

    The async policy runs the record/replay engine (module docstring):
    C aggregation events record through the shared scheduling pump, then
    replay as one compiled scan over the event table.
    ``event_table_capacity`` (async only) pins the table size -- overflow
    then raises instead of growing. ``mesh`` (None | int | Mesh) shards
    the client axis of the state over the mesh's "data" axis before the
    compiled chunks run; an int n builds an (n, 1) test mesh. A
    single-device mesh is bit-identical to no mesh.
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1; got {rounds}")
    if chunk is not None and chunk < 1:
        raise ValueError(f"chunk must be >= 1 (None = all rounds in one "
                         f"scan); got {chunk}")
    if event_table_capacity is not None and event_table_capacity < 1:
        raise ValueError(f"event_table_capacity must be >= 1; "
                         f"got {event_table_capacity}")
    mesh = _resolve_mesh(mesh)
    if mesh is not None:
        sim.state = _client_sharded(sim.state, sim.cfg.m, mesh)
        if sim._ef:
            sim._H = _client_sharded(sim._H, sim.cfg.m, mesh)
    if sim.sim.policy == "async":
        return _run_async_scan(sim, rounds, chunk=chunk,
                               collect_w_tau=collect_w_tau,
                               event_table_capacity=event_table_capacity)
    if event_table_capacity is not None:
        raise ValueError("event_table_capacity is owned by policy='async'; "
                         f"policy is {sim.sim.policy!r}")
    if sim.sim.policy not in _SCAN_POLICIES:
        raise ValueError(f"unknown policy {sim.sim.policy!r}")

    cand_stream = _candidate_stream_fn(sim)
    chunk_fn = _chunk_fn(sim, collect_w_tau)

    # donation invariant: snapshot the entry state once so buffers the
    # caller may still reference are never donated; all later chunk states
    # are engine-owned
    sim.state = _copy_tree(sim.state)
    H = _copy_tree(sim._H) if sim._ef else jnp.zeros((), jnp.float32)

    chunk = rounds if chunk is None else min(chunk, rounds)
    out_metrics: list[SimMetrics] = []
    w_parts: list[np.ndarray] = []
    done = 0
    while done < rounds:
        C = min(chunk, rounds - done)
        # 1. arrivals: same host-RNG stream as C eager steps
        arrivals = np.stack([
            simclients.round_arrivals(
                sim.profiles, sim._rng, sim._latency,
                work_flops=sim._work, down_bytes=sim._down_bytes,
                up_bytes=sim._up_bytes)
            for _ in range(C)])
        # 2./3. candidate-stream + policy replay to the abandoned fixpoint
        ewma0 = sim.deadlines.ewma.copy() \
            if sim.sim.policy == "adaptive" else None
        # the fault model's stream/quarantine state rewinds with each pass
        # (exactly the EWMA pattern above): every pass replays the chunk's
        # fault decisions from the same point, and the state the LAST pass
        # leaves behind is what C eager steps would have left
        fstate0 = sim._faults.state_snapshot() \
            if sim._faults is not None else None
        abandoned = np.zeros(C, bool)
        for _ in range(C + 1):
            cands = np.asarray(cand_stream(
                sim.state.key, sim.state.k, jnp.asarray(abandoned)))
            sim.host_syncs += 1
            if ewma0 is not None:
                sim.deadlines.ewma = ewma0.copy()
            if fstate0 is not None:
                sim._faults.state_restore(fstate0)
            (masks, durs, ab_new, rec_ups, cands_eff, arrs_eff,
             fouts) = _policy_stream_host(sim, cands, arrivals)
            if np.array_equal(ab_new, abandoned):
                break
            abandoned = ab_new
        else:  # pragma: no cover - the prefix argument guarantees progress
            raise RuntimeError("abandoned-round fixpoint did not converge")
        # 4. one donated scan over the chunk
        ridx0 = sim.round_idx
        if sim._privacy_tx is not None:
            # per-round unit noise, drawn host-side through the SAME
            # standalone program the eager step uses (one draw per round,
            # privacy stream folded on the round index), stacked as xs --
            # see transport.draw_unit_noise for why the draws must enter
            # the chunk as data rather than be computed in-body
            draws = [draw_unit_noise(
                jax.random.fold_in(sim._privacy_key, r),
                sim.state.Z, sim._privacy_tx)
                for r in range(ridx0, ridx0 + C)]
            noise = tmap(lambda *ls: jnp.stack(ls), *draws)
        else:
            noise = None
        (sim.state, H), ys = chunk_fn(
            sim.state, H, sim._codec_key,
            jnp.asarray(masks), jnp.asarray(abandoned),
            jnp.arange(ridx0, ridx0 + C, dtype=jnp.int32), noise)
        rm_stack = ys[0]
        if collect_w_tau:
            w_parts.append(np.asarray(jax.device_get(ys[1])))
            sim.host_syncs += 1

        # host bookkeeping, identical to C eager steps
        live = np.flatnonzero(~abandoned)
        if live.size:
            sim.last_round_metrics = tmap(
                lambda y: y[int(live[-1])], rm_stack)
        for t in range(C):
            dur = float(durs[t])
            # the scan path reconstructs the SAME event stream the eager
            # driver emits: same helper, same already-computed host arrays
            if sim.telemetry.enabled:
                emit_clocked_round_events(
                    sim.telemetry, policy=sim.sim.policy,
                    round_idx=sim.round_idx, t0=sim.t,
                    candidates=cands_eff[t], arrivals=arrs_eff[t],
                    mask=masks[t], dur=dur, rec_up=rec_ups[t],
                    abandoned=bool(abandoned[t]), codec=sim.sim.codec,
                    up_bytes=sim._up_bytes, faults=fouts[t])
            apply_clocked_privacy(
                sim._privacy, sim.telemetry, round_idx=sim.round_idx,
                t_end=sim.t + dur, mask=masks[t], rec_up=rec_ups[t],
                faults=fouts[t])
            if fouts[t] is None:
                brec = sim.ledger.record_round(
                    down_mask=cands_eff[t], up_mask=rec_ups[t],
                    down_bytes=sim._down_bytes, up_bytes=sim._up_bytes,
                    ts=sim.t + dur, round_idx=sim.round_idx)
            else:
                # same count-path billing as the eager step: delivered
                # uploads + failed attempts + discarded duplicates
                brec = sim.ledger.record_counts(
                    down_counts=cands_eff[t].astype(np.int64),
                    up_counts=rec_ups[t].astype(np.int64)
                    + fouts[t].extra_up,
                    down_bytes=sim._down_bytes, up_bytes=sim._up_bytes,
                    ts=sim.t + dur, round_idx=sim.round_idx)
            sim.t += dur
            m = make_sim_metrics(
                round_idx=sim.round_idx, t_round=dur, t_total=sim.t,
                n_contacted=int(cands_eff[t].sum()),
                n_aggregated=int(masks[t].sum()), brec=brec,
                abandoned=bool(abandoned[t]))
            sim.metrics.append(m)
            out_metrics.append(m)
            sim.round_idx += 1
        done += C
    if sim._ef:
        sim._H = H
    return EngineResult(
        out_metrics, np.concatenate(w_parts) if collect_w_tau else None)


def run_to_objective(sim: FedSim, objective_fn, target: float, *,
                     max_rounds: int, chunk: int = 16) -> tuple:
    """Scan-engine race helper: run until the objective reaches ``target``.

    ``objective_fn`` maps the stacked (C, ...) per-round broadcast points
    to a (C,) vector of objective values -- ONE evaluation per chunk, so
    objective monitoring costs one dispatch per chunk instead of one per
    round (a per-round host ``float(f(w))`` would hand the dispatch
    overhead the engine removed straight back). Returns
    (rounds_to_target, hit: bool, objective at that round).
    """
    total = 0
    f = math.inf
    while total < max_rounds:
        C = min(chunk, max_rounds - total)
        res = run_rounds(sim, C, collect_w_tau=True)
        fs = np.asarray(objective_fn(jnp.asarray(res.w_tau)))
        sim.host_syncs += 1
        for fv in fs:
            total += 1
            f = float(fv)
            if f <= target:
                return total, True, f
    return total, False, f
