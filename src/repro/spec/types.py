"""Typed experiment-spec dataclasses: the one declarative config surface.

An :class:`ExperimentSpec` is a frozen, hashable, serializable description
of ONE experiment cell -- which task, which algorithm with which paper
hyper-parameters, which device fleet, which aggregation policy, which
upload codec, and which execution engine. It replaces the hand-threaded
argparse-flag plumbing of ``launch/simulate.py`` and the per-benchmark
``_build`` helpers with a single composition:

    spec = ExperimentSpec(
        task=TaskSpec(kind="logreg", d=4000, n=14, m=50),
        algorithm=AlgorithmSpec(name="fedepm", rho=0.5, k0=8),
        fleet=FleetSpec(latency="pareto"),
        policy=PolicySpec(name="deadline", deadline=0.002),
        engine=EngineSpec(name="scan", rounds=60),
    )
    handle = spec.build()        # -> repro.spec.build.RunHandle
    summary = handle.run()

Design rules
------------
* **Policy-scoped knobs are Optional.** A knob that belongs to one policy
  (e.g. ``buffer_size`` to ``async``) defaults to ``None``; setting it under
  any other policy is a validation ERROR, never silently ignored. The
  builder fills the documented default for unset knobs, so an all-``None``
  spec reproduces the CLI's historical behaviour bit-for-bit.
* **Strict deserialization.** ``from_dict`` rejects unknown sections and
  unknown keys; enum-like strings are validated against the registries in
  ``repro.spec.registry``, so new algorithms/policies/latency models/codecs
  plug in without touching this module.
* **Round-trippable.** ``to_dict`` omits unset (``None``) fields;
  ``from_dict(to_dict(s)) == s`` exactly (dataclass equality), and the
  TOML/JSON files produced by :meth:`ExperimentSpec.dump` reload equal.

Schema reference with every field's meaning: docs/spec.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping


class SpecError(ValueError):
    """A spec failed validation or deserialization (message names the
    offending section/field)."""


# ---------------------------------------------------------------------------
# section dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """What is being optimized: the paper's logreg task or an LM arch.

    kind="logreg": synthetic Adult-income stand-in (data/synth.py), dealt
    IID to ``m`` clients; ``d`` samples of ``n`` features.
    kind="lm": an arch from repro.configs (``arch`` in configs.ALL_ARCHS),
    reduced() by default so it runs on a CPU host, with synthetic federated
    token shards (data/lm.py) of ``batch_per_client`` sequences of
    ``seq_len`` tokens per client, topic-skewed when ``heterogeneous``.
    ``seed`` defaults to the experiment seed (data + partition stream).
    """

    kind: str = "logreg"
    m: int = 50                      # clients
    seed: int | None = None          # data/partition seed (None = exp seed)
    # logreg
    d: int = 4000                    # dataset size (paper: 45222)
    n: int = 14                      # features
    # lm
    arch: str | None = None          # repro.configs arch id
    reduced: bool = True             # reduced() CPU-sized config
    batch_per_client: int = 2        # sequences per client shard
    seq_len: int = 32                # tokens per sequence
    heterogeneous: bool = True       # topic-skewed client shards


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """Which algorithm and its paper hyper-parameters.

    ``name`` is a key of registry.ALGORITHMS ("fedepm" | "sfedavg" |
    "sfedprox" built in). ``rho``/``k0``/``eps_dp`` are the paper's shared
    knobs; the Optional fields are per-family overrides -- setting a knob
    the named algorithm does not take is a validation error (e.g.
    ``mu0`` on sfedavg, ``prox_mu`` on fedepm).
    """

    name: str = "fedepm"
    rho: float = 0.5                 # participation fraction
    k0: int = 8                      # iterations between communications
    eps_dp: float = 0.0              # DP epsilon; <= 0 disables noise
    # fedepm-only overrides (None = FedEPMConfig.paper_defaults value)
    mu0: float | None = None         # inverse-lr prox weight mu_{i,0}
    alpha: float | None = None       # mu growth factor alpha_i > 1
    c: float | None = None           # c_i in the mu recurrence
    s0: int | None = None            # coverage window (Setup VI.1)
    sampler: str | None = None       # "uniform" | "coverage" | "full"
    sensitivity_clip: float | None = None  # Delta_hat cap (LM-scale DP)
    init_noise_scale: float | None = None
    ens_impl: str | None = None      # "ref" | "pallas" | "oracle"
    prox_impl: str | None = None     # "ref" | "pallas"
    # baseline-only overrides (None = BaselineConfig default)
    prox_mu: float | None = None     # sfedprox inner mu
    prox_ell: int | None = None      # sfedprox inner GD steps
    gamma_scale: float | None = None  # the "2 d_i" prefactor knob


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Device fleet: where heterogeneity and latency jitter come from.

    kind="synthetic": lognormal profiles (sim/clients.py::make_profiles)
    with reachability ``availability``; kind="trace": the fleet is
    RESAMPLED from a real device log (``trace_file``, schema in
    sim/clients.py::LatencyTrace -- the trace's own availability column
    applies, so setting ``availability`` too is an error); kind="uniform":
    the homogeneous fleet the exactness tests use. ``latency`` names a
    registered per-round jitter model (sim/clients.py built-ins:
    deterministic / lognormal / pareto). ``seed`` is the PROFILE seed
    (None = experiment seed) -- the golden fixture pins profile seed 5
    under experiment seed 0, which is why it is separate.
    """

    kind: str = "synthetic"
    trace_file: str | None = None
    availability: float | None = None  # P(reachable); synthetic only
    latency: str = "deterministic"
    latency_sigma: float = 0.5
    latency_alpha: float = 1.2
    seed: int | None = None


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Aggregation policy plus its policy-scoped knobs.

    ``name`` is a key of registry.POLICIES. Each knob below belongs to
    exactly one policy (the registry records the ownership); a knob set
    (non-None) under a policy that does not own it FAILS validation --
    the spec layer never silently ignores a knob, mirroring the CLI's
    rejection of async-only flags under clocked policies.
    """

    name: str = "sync"
    deadline: float | None = None          # deadline: cutoff seconds (> 0)
    overselect_factor: float | None = None  # overselect: candidate rate
    deadline_slack: float | None = None    # adaptive: budget = slack*ewma
    ewma_beta: float | None = None         # adaptive: newest-obs weight
    buffer_size: int | None = None         # async: merges per aggregation
    staleness_exp: float | None = None     # async: gamma = (1+s)^-exp
    max_concurrency: int | None = None     # async: in-flight client cap


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """Upload compression (sim/transport.py::CodecConfig surface).

    ``name`` is a key of registry.CODECS ("topk_quant" built in). The
    default field values describe the identity codec; a spec whose codec
    section is entirely default builds with NO codec attached (raw float32
    uploads), exactly like the CLI without --topk/--bits.
    """

    name: str = "topk_quant"
    topk_frac: float = 1.0           # fraction of coordinates uploaded
    bits: int = 0                    # wire bits per kept value (0 = raw)
    stochastic: bool = True          # dithered (unbiased) rounding
    impl: str = "ref"                # "ref" | "pallas"
    index_bytes: int = 4             # per-kept-coordinate index cost
    error_feedback: bool = False     # EF21-style codec memory


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Run telemetry (repro.telemetry): event tracing, metrics, sinks.

    ``enabled`` attaches an event recorder to the run -- observational
    only, so trajectories are bit-for-bit identical either way (pinned in
    tests/test_telemetry.py). The sink paths are each optional and REQUIRE
    ``enabled = true`` (a sink on a disabled recorder would silently write
    nothing -- that is a validation error, not a no-op):

    events_jsonl: write the event stream as JSONL (one event per line).
    trace_out: write a Perfetto/Chrome ``trace_event`` JSON timeline
        (one track per client, one per server policy).
    jax_profiler_dir: wrap the run in ``jax.profiler`` for a real
        wall-time trace of the engine (TensorBoard/Perfetto format).
    """

    enabled: bool = False
    events_jsonl: str | None = None
    trace_out: str | None = None
    jax_profiler_dir: str | None = None


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Fault injection (repro.sim.faults): seeded per-upload fault
    processes plus the server-defense knobs.

    The four rates are per upload attempt: ``drop_rate`` (lost mid-flight),
    ``transient_rate`` (retryable failure; every attempt is billed,
    retried after ``backoff_base * backoff_factor**(attempt-1)`` seconds,
    at most ``max_retries`` retries), ``corrupt_rate`` (payload damaged
    per ``corrupt_mode``; screened, counted toward quarantine --
    ``quarantine_after`` offenses sideline the client for
    ``quarantine_rounds`` rounds), ``duplicate_rate`` (a clean delivery
    arrives twice; the duplicate is deduped, delayed ``reorder_jitter *
    U[0,1)`` seconds under the async policy). The three failure rates must
    sum to <= 1. A spec with all four rates zero is EXACTLY the fault-free
    simulator (no model is built at all). ``seed`` seeds the fault
    stream's own RNG (None = derived from the experiment seed).
    """

    drop_rate: float = 0.0
    transient_rate: float = 0.0
    corrupt_rate: float = 0.0
    duplicate_rate: float = 0.0
    max_retries: int = 2
    backoff_base: float = 1e-3
    backoff_factor: float = 2.0
    reorder_jitter: float = 0.0
    quarantine_after: int = 2
    quarantine_rounds: int = 3
    corrupt_mode: str = "nan"
    seed: int | None = None


@dataclasses.dataclass(frozen=True)
class PrivacySpec:
    """Upload privacy (repro.privacy): per-round DP noise on the upload
    path, a per-client accountant, and secure-aggregation masking.

    ``eps`` is the per-round, per-client budget; ``eps = 0`` disables the
    clip/noise transform. ``sensitivity`` picks the noise scale's
    sensitivity source: ``"surrogate"`` uses the paper's data-dependent
    ``2 * ||z||_1`` (eq. 39), ``"clip"`` enforces ``||z||_1 <= clip``
    first and then uses the data-independent ``2 * clip`` (``clip`` must
    be set -- and may ONLY be set -- in clip mode). ``mechanism`` is
    Laplace (the paper's, Thm V.1) or Gaussian with ``delta``.
    ``secure_agg`` bills one pairwise-mask exchange of ``mask_bytes``
    bytes per upload attempt that reaches the wire (billed exactly like
    the payload bytes: clean arrivals + retries + discarded duplicates).
    ``seed`` keys the privacy noise stream (None = derived from the
    experiment seed). The all-default section builds NO privacy state at
    all -- byte-identical to the pre-privacy simulator, golden-pinned.
    """

    mechanism: str = "laplace"       # "laplace" | "gaussian"
    eps: float = 0.0                 # per-round eps budget (0 = no noise)
    delta: float = 1e-5              # gaussian mechanism delta
    sensitivity: str = "surrogate"   # "surrogate" | "clip"
    clip: float = 0.0                # l1 clip bound (sensitivity="clip")
    secure_agg: bool = False         # pairwise-mask exchange on uploads
    mask_bytes: int = 32             # bytes per mask-pair exchange
    seed: int | None = None          # noise-stream seed (None = exp seed)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """How rounds execute: engine choice, budget, chunking, termination.

    ``name`` is a key of registry.ENGINES -- "eager" (one jit dispatch per
    round, the semantic reference) or "scan" (multi-round chunks compiled
    into one donated lax.scan; bit-identical trajectory). ``chunk`` bounds
    rounds per compiled scan (scan-only knob; None = the documented
    default). ``terminate`` applies the paper's variance stopping rule
    (logreg tasks only -- the rule is calibrated for that objective);
    under scan it stops at exactly the eager stopping round via
    snapshot/rollback at chunk granularity. ``mesh`` shards the stacked
    client axis over that many devices (scan-only; None = unsharded; a
    1-device mesh is bit-identical to unsharded). ``event_table_capacity``
    pins the scan async engine's in-flight payload table to a fixed slot
    count (scan + async only; overflow is an error instead of growth).
    """

    name: str = "eager"
    rounds: int = 30
    chunk: int | None = None
    terminate: bool = False
    mesh: int | None = None
    event_table_capacity: int | None = None


# ---------------------------------------------------------------------------
# the composed experiment
# ---------------------------------------------------------------------------

_SECTIONS: dict[str, type] = {
    "task": TaskSpec,
    "algorithm": AlgorithmSpec,
    "fleet": FleetSpec,
    "policy": PolicySpec,
    "codec": CodecSpec,
    "engine": EngineSpec,
    "telemetry": TelemetrySpec,
    "faults": FaultSpec,
    "privacy": PrivacySpec,
}


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment cell: task x algorithm x fleet x policy x codec x
    engine, plus the master ``seed`` every unset section seed inherits."""

    task: TaskSpec = TaskSpec()
    algorithm: AlgorithmSpec = AlgorithmSpec()
    fleet: FleetSpec = FleetSpec()
    policy: PolicySpec = PolicySpec()
    codec: CodecSpec = CodecSpec()
    engine: EngineSpec = EngineSpec()
    telemetry: TelemetrySpec = TelemetrySpec()
    faults: FaultSpec = FaultSpec()
    privacy: PrivacySpec = PrivacySpec()
    name: str = "experiment"
    seed: int = 0

    # -- validation / construction -----------------------------------------

    def validate(self) -> "ExperimentSpec":
        """Raise SpecError on any inconsistency; return self for chaining.

        Delegates to repro.spec.registry so registered extensions validate
        through the same gate as the built-ins.
        """
        from repro.spec import registry
        registry.validate_spec(self)
        return self

    def replace(self, **kw) -> "ExperimentSpec":
        """dataclasses.replace with section-aware dotted keys.

        ``spec.replace(**{"policy.deadline": 0.01, "seed": 3})`` replaces
        nested fields without hand-written dataclasses.replace chains.
        """
        flat: dict[str, Any] = {}
        nested: dict[str, dict[str, Any]] = {}
        for key, val in kw.items():
            if "." in key:
                sec, _, field = key.partition(".")
                if sec not in _SECTIONS:
                    raise SpecError(f"unknown spec section {sec!r} in "
                                    f"replace key {key!r}")
                nested.setdefault(sec, {})[field] = val
            else:
                flat[key] = val
        for sec, fields in nested.items():
            if sec in flat:
                raise SpecError(f"replace got both {sec!r} and dotted "
                                f"{sec}.* keys")
            known = {f.name for f in
                     dataclasses.fields(_SECTIONS[sec])}
            unknown = set(fields) - known
            if unknown:
                raise SpecError(f"[{sec}]: unknown field(s) "
                                f"{sorted(unknown)} in replace; "
                                f"known: {sorted(known)}")
            flat[sec] = dataclasses.replace(getattr(self, sec), **fields)
        unknown = set(flat) - {"name", "seed", *_SECTIONS}
        if unknown:
            raise SpecError(f"unknown spec field(s) {sorted(unknown)} "
                            f"in replace")
        return dataclasses.replace(self, **flat)

    # -- dict round-trip ---------------------------------------------------

    def to_dict(self) -> dict:
        """Nested plain-dict form; unset (None) fields are omitted."""
        out: dict[str, Any] = {"name": self.name, "seed": self.seed}
        for sec in _SECTIONS:
            body = {f.name: v for f in dataclasses.fields(getattr(self, sec))
                    if (v := getattr(getattr(self, sec), f.name)) is not None}
            out[sec] = body
        return out

    @classmethod
    def from_dict(cls, d: Mapping) -> "ExperimentSpec":
        """Strict inverse of to_dict: unknown sections/keys are errors."""
        if not isinstance(d, Mapping):
            raise SpecError(f"spec root must be a table/object, "
                            f"got {type(d).__name__}")
        known_top = {"name", "seed", *_SECTIONS}
        unknown = set(d) - known_top
        if unknown:
            raise SpecError(f"unknown spec section(s)/key(s) "
                            f"{sorted(unknown)}; known: {sorted(known_top)}")
        kw: dict[str, Any] = {}
        for key in ("name", "seed"):
            if key in d:
                kw[key] = _coerce(key, d[key],
                                  str if key == "name" else int)
        for sec, typ in _SECTIONS.items():
            if sec in d:
                kw[sec] = _section_from_dict(sec, typ, d[sec])
        return cls(**kw)

    # -- file round-trip / execution (thin delegators) ---------------------

    @classmethod
    def load(cls, path, *, validate: bool = True) -> "ExperimentSpec":
        """Read a .toml or .json spec file (see repro.spec.serialize)."""
        from repro.spec import serialize
        spec = cls.from_dict(serialize.read_spec_file(path))
        return spec.validate() if validate else spec

    def dump(self, path) -> None:
        """Write this spec as .toml or .json (by file extension)."""
        from repro.spec import serialize
        serialize.write_spec_file(path, self.to_dict())

    def build(self):
        """Validate and build -> repro.spec.build.RunHandle."""
        from repro.spec.build import build as build_fn
        return build_fn(self.validate())

    def sweep(self, axes: Mapping, *, seeds=None) -> list["ExperimentSpec"]:
        """Cross-product expansion over dotted-path axes (repro.spec.sweep)."""
        from repro.spec.sweep import sweep as sweep_fn
        return sweep_fn(self, axes, seeds=seeds)


# ---------------------------------------------------------------------------
# strict per-section deserialization
# ---------------------------------------------------------------------------

def _coerce(where: str, value: Any, typ: type):
    """Check/convert one scalar. TOML/JSON integers satisfy float fields
    (``deadline = 1`` means 1.0); everything else must match exactly --
    notably bool is NOT accepted for int/float (it would mask typos like
    ``bits = true``)."""
    if typ is float and isinstance(value, int) and not isinstance(value, bool):
        return float(value)
    if typ is bool or isinstance(value, bool):
        if typ is not bool or not isinstance(value, bool):
            raise SpecError(f"{where}: expected {typ.__name__}, "
                            f"got {value!r}")
        return value
    if not isinstance(value, typ):
        raise SpecError(f"{where}: expected {typ.__name__}, got {value!r} "
                        f"({type(value).__name__})")
    return value


_FIELD_TYPES = {"str": str, "int": int, "float": float, "bool": bool}


def _section_from_dict(sec: str, typ: type, body: Any):
    if not isinstance(body, Mapping):
        raise SpecError(f"[{sec}] must be a table/object, "
                        f"got {type(body).__name__}")
    fields = {f.name: f for f in dataclasses.fields(typ)}
    unknown = set(body) - set(fields)
    if unknown:
        raise SpecError(f"[{sec}]: unknown key(s) {sorted(unknown)}; "
                        f"known: {sorted(fields)}")
    kw = {}
    for key, val in body.items():
        ann = fields[key].type.replace(" ", "")
        base = ann.split("|")[0]
        if val is None:
            if "None" not in ann:
                raise SpecError(f"[{sec}] {key}: may not be null")
            continue  # None == unset == omitted
        kw[key] = _coerce(f"[{sec}] {key}", val, _FIELD_TYPES[base])
    return typ(**kw)
