"""Spec -> runnable experiment: the one builder behind every entry point.

``build(spec)`` materializes an :class:`~repro.spec.types.ExperimentSpec`
into a :class:`RunHandle`: the task data, the algorithm config/state, the
device fleet, and a configured :class:`repro.sim.FedSim` -- the same
construction the simulate CLI's historical ``build_sim`` performed from
argparse flags, executed through the registries so registered extensions
build through the same path as the built-ins. Trajectories are bit-for-bit
identical to the legacy flag path (tests/test_spec.py pins this against
the golden NPZ).

Task data is memoized per resolved :class:`TaskSpec` (bounded FIFO): two
cells of a sweep over the same task share ONE device copy of the batches,
which also keeps ``id(batches)`` stable so the jit caches in
``repro.sim.server``/``repro.sim.engine`` hit across ``build()`` calls --
a grid of sims compiles each program once, not once per cell.

``RunHandle.run`` owns the execution loop both CLIs and the benchmarks
reuse: the eager per-round path and the fused scan-chunk path (identical
trajectories, docs/perf.md), per-round objective tracking where the
broadcast point is a flat vector (the logreg task; LM pytrees are
evaluated at chunk boundaries instead), and the paper's termination rule
under ``engine.terminate``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedepm
from repro.sim import FedSim, SimConfig, run_rounds
from repro.sim.server import fifo_cache_get
from repro.spec import registry
from repro.spec.types import ExperimentSpec

# task-data memo: resolved TaskSpec -> TaskData. Bounded: each entry pins
# a full dataset on device (the same reason the sim's jit caches are
# bounded), so a long sweep over many tasks cannot leak one per cell.
_TASK_CACHE: dict = {}
# jitted objective/grad-norm programs keyed by (loss_fn, batches identity);
# stable across RunHandles because _TASK_CACHE keeps both alive
_OBJ_CACHE: dict = {}


def task_data(spec: ExperimentSpec) -> registry.TaskData:
    """Materialize (memoized) the spec's task."""
    task = spec.task
    resolved = dataclasses.replace(
        task, seed=task.seed if task.seed is not None else spec.seed)
    entry = registry.TASKS[resolved.kind]
    return fifo_cache_get(_TASK_CACHE, resolved,
                          lambda: entry.build(resolved, resolved.seed),
                          cap=8)


# SimConfig's own dataclass defaults are the single source for unset
# policy knobs (deadline=inf, overselect_factor, buffer_size, ...): an
# all-None spec is exactly the historical CLI behaviour, and a default
# changed in sim/server.py propagates here without a second edit
SIM_KNOB_DEFAULTS: dict = {
    f.name: f.default for f in dataclasses.fields(SimConfig)}


def _sim_config(spec: ExperimentSpec) -> SimConfig:
    """PolicySpec/FleetSpec/CodecSpec -> SimConfig, filling SimConfig's
    own default for every unset policy knob."""
    pol, fleet = spec.policy, spec.fleet
    codec = registry.CODECS[spec.codec.name].build(spec.codec)

    def default(knob):
        v = getattr(pol, knob)
        return SIM_KNOB_DEFAULTS[knob] if v is None else v

    return SimConfig(
        policy=pol.name,
        deadline=default("deadline"),
        overselect_factor=default("overselect_factor"),
        latency=fleet.latency, latency_sigma=fleet.latency_sigma,
        latency_alpha=fleet.latency_alpha, seed=spec.seed, codec=codec,
        buffer_size=default("buffer_size"),
        staleness_exp=default("staleness_exp"),
        max_concurrency=default("max_concurrency"),
        deadline_slack=default("deadline_slack"),
        ewma_beta=default("ewma_beta"),
        faults=_fault_config(spec),
        privacy=_privacy_config(spec))


def _fault_config(spec: ExperimentSpec):
    """[faults] -> FaultConfig, or None when every fault rate is zero (the
    zero-rate spec builds the exact pre-fault sim, golden-pinned)."""
    fl = spec.faults
    if not (fl.drop_rate > 0 or fl.transient_rate > 0
            or fl.corrupt_rate > 0 or fl.duplicate_rate > 0):
        return None
    from repro.sim.faults import FaultConfig
    # dedicated stream, decorrelated from the arrival RNG by default so
    # fault decisions never perturb (or depend on) the latency draws
    seed = fl.seed if fl.seed is not None else spec.seed ^ 0xFA17
    return FaultConfig(
        drop_rate=fl.drop_rate, transient_rate=fl.transient_rate,
        corrupt_rate=fl.corrupt_rate, duplicate_rate=fl.duplicate_rate,
        max_retries=fl.max_retries, backoff_base=fl.backoff_base,
        backoff_factor=fl.backoff_factor, reorder_jitter=fl.reorder_jitter,
        quarantine_after=fl.quarantine_after,
        quarantine_rounds=fl.quarantine_rounds,
        corrupt_mode=fl.corrupt_mode, seed=seed)


def _privacy_config(spec: ExperimentSpec):
    """[privacy] -> PrivacyConfig, or None when the section is inert (no
    noise budget and no secure aggregation: the inert spec builds the
    exact pre-privacy sim, golden-pinned)."""
    pv = spec.privacy
    if not (pv.eps > 0 or pv.secure_agg):
        return None
    from repro.privacy import PrivacyConfig
    # the server XORs this with its own privacy tag (0x9D1A) to key the
    # noise stream, decorrelating it from the arrival and codec RNGs, so
    # the experiment seed passes through plain here
    seed = pv.seed if pv.seed is not None else spec.seed
    return PrivacyConfig(
        mechanism=pv.mechanism, eps=pv.eps, delta=pv.delta,
        sensitivity=pv.sensitivity, clip=pv.clip,
        secure_agg=pv.secure_agg, mask_bytes=pv.mask_bytes, seed=seed)


def build(spec: ExperimentSpec) -> "RunHandle":
    """Materialize a validated spec into a RunHandle."""
    data = task_data(spec)
    alg_entry = registry.ALGORITHMS[spec.algorithm.name]
    cfg, state = alg_entry.build(spec.algorithm, spec.task.m, data.params0,
                                 jax.random.PRNGKey(spec.seed))
    fleet_seed = spec.fleet.seed if spec.fleet.seed is not None \
        else spec.seed
    profiles = registry.FLEETS[spec.fleet.kind].build(
        spec.fleet, spec.task.m, fleet_seed)
    telemetry = None
    if spec.telemetry.enabled:
        from repro.telemetry import EventRecorder
        telemetry = EventRecorder()
    sim = FedSim(alg=alg_entry.sim_alg, cfg=cfg, state=state,
                 batches=data.batches, loss_fn=data.loss_fn,
                 profiles=profiles, sim=_sim_config(spec),
                 telemetry=telemetry)
    return RunHandle(spec=spec, sim=sim, data=data)


@dataclasses.dataclass
class RunHandle:
    """A built experiment: the FedSim plus the task-aware helpers every
    driver (CLI, train launcher, benchmarks) needs around it."""

    spec: ExperimentSpec
    sim: FedSim
    data: registry.TaskData

    def __post_init__(self):
        loss, batches = self.data.loss_fn, self.data.batches
        key = (loss, id(batches))
        # cap matches _TASK_CACHE's intent (2 entries per task): these
        # closures pin the task's device batches, so a larger bound would
        # keep evicted tasks' datasets alive behind the task memo's back
        self._fobj = fifo_cache_get(
            _OBJ_CACHE, ("fobj", *key),
            lambda: jax.jit(
                lambda w: fedepm.global_objective(loss, w, batches)),
            cap=16)
        self._gsq = fifo_cache_get(
            _OBJ_CACHE, ("gsq", *key),
            lambda: jax.jit(
                lambda w: fedepm.global_grad_sq_norm(loss, w, batches)),
            cap=16)
        # per-round broadcast points can be stacked/tracked only when the
        # parameter pytree is one flat vector (the logreg task); LM pytrees
        # are evaluated at chunk boundaries instead
        self._w_stackable = isinstance(self.data.params0, jax.Array)

    # -- task-aware helpers --------------------------------------------------

    def objective(self, w) -> jax.Array:
        """f(w) = sum_i f_i(w) over the spec task's client batches."""
        return self._fobj(w)

    def grad_sq_norm(self, w) -> jax.Array:
        """||grad f(w)||^2 (the termination rule's input)."""
        return self._gsq(w)

    def accuracy(self) -> float | None:
        """Task accuracy at the current broadcast point (logreg only)."""
        if not self.data.supports_accuracy:
            return None
        from repro.core.tasks import accuracy_logistic
        return float(accuracy_logistic(
            self.sim.state.w_tau, jnp.asarray(self.data.aux["X"]),
            jnp.asarray(self.data.aux["y"])))

    # -- the execution loop --------------------------------------------------

    def _terminated(self, f_hist: list, *, w, metrics) -> bool:
        # the paper's variance criterion fires spuriously on a flat start
        # (abandoned rounds leave f_hist at f(w0)): require history AND at
        # least one aggregated round before trusting it. ``w``/``metrics``
        # are the broadcast point and SimMetrics prefix AS OF the round
        # being tested, so the scan engine can evaluate the rule
        # mid-chunk with exactly the eager loop's inputs.
        if not self.spec.engine.terminate or len(f_hist) < 8:
            return False
        if not any(not mm.abandoned for mm in metrics):
            return False
        from repro.configs.paper_logreg import termination_reached
        return termination_reached(
            f_hist, float(self._gsq(w)), self.data.n_features)

    def run(self, report: Callable | None = None) -> dict:
        """Execute the spec's engine for its round budget -> summary dict.

        ``report(metrics, f)`` is called once per round with that round's
        SimMetrics and the objective at its broadcast point (None when the
        engine cannot track per-round objectives, i.e. scan/async over an
        LM parameter pytree). The summary is the simulate CLI's historical
        schema -- alg/policy/engine/latency, rounds, f_final, accuracy,
        simulated time, straggler/byte ledger totals, and the staleness
        stats under the async policy. With ``spec.telemetry.enabled`` the
        summary additionally carries a ``"telemetry"`` block (metric
        snapshot + series, repro.telemetry.sinks.telemetry_summary) and the
        configured sinks are written at run end; a telemetry-off summary
        is byte-identical to previous releases.
        """
        eng = self.spec.engine
        entry = registry.ENGINES[eng.name]
        if entry.runner is not None:     # registered extension engine
            return entry.runner(self, report)
        sim = self.sim
        tel = self.spec.telemetry
        f_hist: list[float] = []
        rounds_run = 0
        wall0 = time.perf_counter() if tel.enabled else None
        with contextlib.ExitStack() as stack:
            if tel.enabled and tel.jax_profiler_dir:
                from repro.telemetry import jax_profile
                stack.enter_context(jax_profile(tel.jax_profiler_dir))
            if eng.name == "eager":
                for _ in range(eng.rounds):
                    met = sim.step()
                    rounds_run += 1
                    f_hist.append(float(self._fobj(sim.state.w_tau)))
                    if report is not None:
                        report(met, f_hist[-1])
                    if self._terminated(f_hist, w=sim.state.w_tau,
                                        metrics=sim.metrics):
                        break
            else:                        # scan: fused multi-round chunks
                collect = self._w_stackable
                chunk = eng.chunk if eng.chunk is not None \
                    else (8 if eng.terminate else eng.rounds)
                check = eng.terminate and collect
                stopped = False
                while rounds_run < eng.rounds and not stopped:
                    todo = min(chunk, eng.rounds - rounds_run)
                    # --terminate parity: snapshot before the chunk so an
                    # overshooting chunk can roll back (state, RNG, clock,
                    # ledger, telemetry) and re-run exactly the rounds the
                    # eager loop would have -- the stopping round is
                    # decided from the chunk's per-round broadcast stream
                    snap = sim.snapshot() if check else None
                    res = run_rounds(sim, todo, collect_w_tau=collect,
                                     mesh=eng.mesh,
                                     event_table_capacity=(
                                         eng.event_table_capacity))
                    if collect:
                        for i, (met, w) in enumerate(
                                zip(res.metrics, res.w_tau)):
                            w = jnp.asarray(w)
                            f_hist.append(float(self._fobj(w)))
                            if report is not None:
                                report(met, f_hist[-1])
                            if check and self._terminated(
                                    f_hist, w=w,
                                    metrics=sim.metrics[:rounds_run + i
                                                        + 1]):
                                keep = i + 1
                                if keep < todo:
                                    sim.restore(snap)
                                    run_rounds(
                                        sim, keep, collect_w_tau=False,
                                        mesh=eng.mesh,
                                        event_table_capacity=(
                                            eng.event_table_capacity))
                                rounds_run += keep
                                stopped = True
                                break
                    else:
                        for met in res.metrics:
                            if report is not None:
                                report(met, None)
                    if not stopped:
                        rounds_run += todo
        summary = self._summary(f_hist, rounds_run)
        if tel.enabled:
            from repro.telemetry import (telemetry_summary,
                                         write_events_jsonl, write_trace)
            recorder = sim.telemetry
            summary["telemetry"] = telemetry_summary(
                recorder, objective=f_hist, rounds=rounds_run,
                wall_s=time.perf_counter() - wall0,
                host_syncs=sim.host_syncs)
            if tel.events_jsonl:
                write_events_jsonl(recorder.events, tel.events_jsonl)
            if tel.trace_out:
                write_trace(recorder.events, tel.trace_out,
                            label=self.spec.name)
        return summary

    def _summary(self, f_hist: list, rounds_run: int) -> dict:
        sim, spec = self.sim, self.spec
        f_final = f_hist[-1] if f_hist \
            else float(self._fobj(sim.state.w_tau))
        summary = {
            "spec_name": spec.name,
            "alg": spec.algorithm.name, "policy": spec.policy.name,
            "engine": spec.engine.name, "latency": spec.fleet.latency,
            "rounds": rounds_run, "f_final": f_final / spec.task.m,
            "accuracy": self.accuracy(), "sim_time_s": sim.t,
            "stragglers_dropped": sum(mm.n_dropped for mm in sim.metrics),
            "abandoned_rounds": sum(mm.abandoned for mm in sim.metrics),
            "bytes_up": sim.ledger.total_up,
            "bytes_down": sim.ledger.total_down,
            "bytes_total": sim.ledger.total,
            "up_bytes_per_client_round": sim.up_bytes_per_client,
        }
        if spec.policy.name == "async":
            summary["staleness_max"] = max(
                (mm.staleness_max for mm in sim.metrics), default=0)
            summary["staleness_mean"] = float(np.mean(
                [mm.staleness_mean for mm in sim.metrics
                 if not mm.abandoned] or [0.0]))
        if sim._faults is not None:
            summary["faults"] = sim._faults.summary()
        if sim._privacy is not None:
            summary["privacy"] = sim._privacy.summary()
        return summary
