"""Declarative experiment specs: one typed config surface for the repo.

The paper's four axes -- communication efficiency, computation,
stragglers, privacy -- compose here as ONE frozen, serializable
:class:`ExperimentSpec` (task x algorithm x fleet x policy x codec x
engine) instead of ~25 hand-threaded CLI flags:

    from repro import spec as xspec

    exp = xspec.ExperimentSpec.load("examples/specs/fig7_async.toml")
    summary = exp.build().run()

    grid = xspec.sweep(exp, {"algorithm.name": ["fedepm", "sfedavg"]},
                       seeds=[0, 1, 2])

Module map: ``types`` (the dataclasses + strict dict round-trip),
``registry`` (string-keyed extension points: algorithms, tasks, fleets,
policies, codecs, engines), ``serialize`` (TOML/JSON files), ``build``
(spec -> FedSim-backed RunHandle), ``sweep`` (cross-product grids).
Schema reference and extension recipes: docs/spec.md.
"""
from repro.spec.build import RunHandle, build          # noqa: F401
from repro.spec.registry import (                      # noqa: F401
    register_algorithm,
    register_codec,
    register_engine,
    register_fleet,
    register_policy,
    register_task,
)
from repro.spec.sweep import load_sweep, sweep         # noqa: F401
from repro.spec.types import (                         # noqa: F401
    AlgorithmSpec,
    CodecSpec,
    EngineSpec,
    ExperimentSpec,
    FaultSpec,
    FleetSpec,
    PolicySpec,
    PrivacySpec,
    SpecError,
    TaskSpec,
    TelemetrySpec,
)
