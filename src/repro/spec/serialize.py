"""TOML/JSON (de)serialization for experiment specs.

One spec file == one :class:`~repro.spec.types.ExperimentSpec` in its
``to_dict`` shape: top-level ``name``/``seed`` scalars plus one table per
section (``[task]``, ``[algorithm]``, ``[fleet]``, ``[policy]``,
``[codec]``, ``[engine]``)::

    name = "fig6-deadline-cell"
    seed = 0

    [task]
    kind = "logreg"
    d = 4000
    ...

The format is chosen by file extension: ``.toml`` or ``.json``. TOML
reading uses the stdlib ``tomllib`` (Python >= 3.11) or the ``tomli``
backport; TOML writing is a small emitter here (neither library writes),
restricted to the value shapes a spec can contain -- strings, bools, ints,
floats, and flat lists. The emitter is exact: ``loads(dumps(d)) == d``,
which is what makes ``ExperimentSpec.dump``/``load`` idempotent
(tests/test_spec.py pins this).
"""
from __future__ import annotations

import json
import pathlib

from repro.spec.types import SpecError

try:
    import tomllib as _toml_reader          # Python >= 3.11
except ModuleNotFoundError:                 # pragma: no cover - version dep
    try:
        import tomli as _toml_reader        # the declared backport
    except ModuleNotFoundError:
        _toml_reader = None


# ---------------------------------------------------------------------------
# minimal exact TOML emitter (spec-shaped dicts only)
# ---------------------------------------------------------------------------

_BARE_KEY = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_-")


def _toml_key(key: str) -> str:
    if key and set(key) <= _BARE_KEY:
        return key
    return _toml_str(key)


def _toml_str(s: str) -> str:
    out = s.replace("\\", "\\\\").replace('"', '\\"')
    out = out.replace("\n", "\\n").replace("\r", "\\r").replace("\t", "\\t")
    return f'"{out}"'


def _toml_value(where: str, v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, int):
        return str(v)
    if isinstance(v, float):
        if v != v or v in (float("inf"), float("-inf")):
            raise SpecError(f"{where}: non-finite float {v!r} is not "
                            f"serializable; omit the field instead "
                            f"(None means 'no cutoff')")
        r = repr(v)
        return r if ("." in r or "e" in r or "E" in r) else r + ".0"
    if isinstance(v, str):
        return _toml_str(v)
    if isinstance(v, (list, tuple)):
        return "[" + ", ".join(_toml_value(where, x) for x in v) + "]"
    raise SpecError(f"{where}: {type(v).__name__} is not TOML-serializable")


def toml_dumps(d: dict) -> str:
    """Emit a spec-shaped dict (scalars at top level, one flat table per
    section) as TOML text."""
    lines = []
    sections = []
    for key, val in d.items():
        if isinstance(val, dict):
            sections.append((key, val))
        else:
            lines.append(f"{_toml_key(key)} = {_toml_value(key, val)}")
    for sec, body in sections:
        lines.append("")
        lines.append(f"[{_toml_key(sec)}]")
        for key, val in body.items():
            if isinstance(val, dict):
                raise SpecError(f"[{sec}] {key}: nested tables are not "
                                f"part of the spec schema")
            lines.append(f"{_toml_key(key)} = "
                         f"{_toml_value(f'[{sec}] {key}', val)}")
    return "\n".join(lines) + "\n"


def toml_loads(text: str) -> dict:
    if _toml_reader is None:                # pragma: no cover - env dep
        raise SpecError(
            "no TOML reader available: install 'tomli' (Python < 3.11) or "
            "use a .json spec file")
    return _toml_reader.loads(text)


# ---------------------------------------------------------------------------
# file IO
# ---------------------------------------------------------------------------


def read_spec_file(path) -> dict:
    """Read a .toml/.json spec file into its plain-dict form."""
    p = pathlib.Path(path)
    if not p.exists():
        raise SpecError(f"spec file not found: {p}")
    text = p.read_text()
    if p.suffix == ".toml":
        try:
            return toml_loads(text)
        except SpecError:
            raise
        except Exception as e:
            raise SpecError(f"{p}: invalid TOML: {e}") from e
    if p.suffix == ".json":
        try:
            return json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"{p}: invalid JSON: {e}") from e
    raise SpecError(f"{p}: unknown spec extension {p.suffix!r} "
                    f"(expected .toml or .json)")


def write_spec_file(path, d: dict) -> None:
    """Write the plain-dict form as .toml or .json (by extension)."""
    p = pathlib.Path(path)
    if p.suffix == ".toml":
        p.write_text(toml_dumps(d))
    elif p.suffix == ".json":
        p.write_text(json.dumps(d, indent=1) + "\n")
    else:
        raise SpecError(f"{p}: unknown spec extension {p.suffix!r} "
                        f"(expected .toml or .json)")
