"""String-keyed registries behind the declarative experiment spec.

Every enum-like string in an :class:`~repro.spec.types.ExperimentSpec`
resolves through a registry in this module, so new algorithms, tasks,
fleets, policies, codecs, latency models, and engines plug in WITHOUT
touching the builder (``repro.spec.build``):

    from repro.spec import registry

    registry.register_algorithm(
        "myalg", sim_alg="myalg", knobs=frozenset({"mu0"}),
        build=my_cfg_and_state_builder)

    registry.register_codec("presets/aggressive",
                            build=lambda c: CodecConfig(topk_frac=.1, bits=4))

Latency models register through ``repro.sim.register_latency_model`` (the
sim runtime owns that namespace; the spec layer validates against it).
Policies registered here pass spec validation and reach ``SimConfig``
unchanged -- the aggregation semantics themselves must exist in
``repro.sim.server`` (its ``_POLICIES`` gate), so a policy registration is
the spec-surface half of a two-sided extension. Engines registered with a
``runner`` callable take over the whole execution loop (see
``repro.spec.build.RunHandle.run``).

``validate_spec`` is the single validation gate ``ExperimentSpec.validate``
delegates to: section-by-section range checks, knob-ownership checks (a
policy-scoped or algorithm-scoped knob set under an owner that does not
take it is an ERROR, never silently ignored), and the cross-field rules
(terminate is logreg-only, trace fleets carry their own availability,
over-selection needs the uniform sampler, error feedback needs a lossy
codec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import baselines, fedepm
from repro.spec.types import (
    AlgorithmSpec,
    CodecSpec,
    ExperimentSpec,
    FleetSpec,
    SpecError,
    TaskSpec,
)

# ---------------------------------------------------------------------------
# tasks
# ---------------------------------------------------------------------------


class TaskData(NamedTuple):
    """Everything the builder needs from a materialized task."""

    batches: Any            # device pytree, leading client axis m
    loss_fn: Callable       # (params, client_batch) -> scalar
    params0: Any            # initial broadcast point w^0
    n_features: int | None  # logreg feature count (termination rule input)
    aux: dict               # task extras (X/y for accuracy, arch cfg, ...)
    supports_accuracy: bool
    supports_termination: bool


class TaskEntry(NamedTuple):
    build: Callable[[TaskSpec, int], TaskData]  # (spec, resolved seed)


def _build_logreg(task: TaskSpec, seed: int) -> TaskData:
    # identical call sequence to the historical launch/simulate.build_sim,
    # so spec-built trajectories are bit-for-bit the legacy-flag ones
    from repro.core.tasks import make_logistic_loss
    from repro.data import synth
    from repro.data.partition import partition_iid

    X, y = synth.adult_like(d=task.d, n=task.n, seed=seed)
    batches = jax.tree_util.tree_map(
        jnp.asarray, partition_iid(X, y, m=task.m, seed=seed))
    return TaskData(batches=batches, loss_fn=make_logistic_loss(),
                    params0=jnp.zeros(task.n), n_features=task.n,
                    aux={"X": X, "y": y},
                    supports_accuracy=True, supports_termination=True)


def _build_lm(task: TaskSpec, seed: int) -> TaskData:
    # one fixed federated token batch is each client's local dataset --
    # the FedSim contract (static batches), mirroring the IID partition
    # of the logreg task rather than train.py's per-round streams
    from repro import configs
    from repro.core.tasks import make_lm_loss
    from repro.data.lm import federated_token_batches
    from repro.models import registry as model_registry

    arch_cfg = (configs.get_reduced(task.arch) if task.reduced
                else configs.get_config(task.arch))
    model = model_registry.get_model(arch_cfg)
    raw = next(federated_token_batches(
        arch_cfg.vocab, task.m, task.batch_per_client, task.seq_len,
        steps=1, seed=seed, heterogeneous=task.heterogeneous))
    batches = jax.tree_util.tree_map(jnp.asarray, raw)
    params0 = model.init(jax.random.PRNGKey(seed))
    return TaskData(batches=batches, loss_fn=make_lm_loss(model.apply),
                    params0=params0, n_features=None,
                    aux={"arch_cfg": arch_cfg},
                    supports_accuracy=False, supports_termination=False)


TASKS: dict[str, TaskEntry] = {
    "logreg": TaskEntry(build=_build_logreg),
    "lm": TaskEntry(build=_build_lm),
}


def register_task(kind: str, *, build) -> None:
    """Register a task kind: ``build(TaskSpec, seed) -> TaskData``."""
    if kind in TASKS:
        raise ValueError(f"task kind {kind!r} is already registered")
    TASKS[kind] = TaskEntry(build=build)


# ---------------------------------------------------------------------------
# algorithms
# ---------------------------------------------------------------------------


class AlgorithmEntry(NamedTuple):
    sim_alg: str             # FedSim's alg key (round-function pair)
    knobs: frozenset         # AlgorithmSpec Optional fields this alg takes
    build: Callable          # (AlgorithmSpec, m, params0, key)->(cfg, state)


_FEDEPM_KNOBS = frozenset({
    "mu0", "alpha", "c", "s0", "sampler", "sensitivity_clip",
    "init_noise_scale", "ens_impl", "prox_impl"})
_BASELINE_KNOBS = frozenset({"prox_mu", "prox_ell", "gamma_scale"})


def _overrides(alg: AlgorithmSpec, knobs: frozenset) -> dict:
    return {k: v for k in knobs if (v := getattr(alg, k)) is not None}


def _build_fedepm(alg: AlgorithmSpec, m: int, params0, key):
    cfg = fedepm.FedEPMConfig.paper_defaults(
        m=m, rho=alg.rho, k0=alg.k0, eps_dp=alg.eps_dp,
        **_overrides(alg, _FEDEPM_KNOBS))
    return cfg, fedepm.init_state(key, params0, cfg)


def _build_baseline(alg: AlgorithmSpec, m: int, params0, key):
    cfg = baselines.BaselineConfig(
        m=m, k0=alg.k0, rho=alg.rho, eps_dp=alg.eps_dp,
        **_overrides(alg, _BASELINE_KNOBS))
    return cfg, baselines.init_state(key, params0, cfg)


ALGORITHMS: dict[str, AlgorithmEntry] = {
    "fedepm": AlgorithmEntry("fedepm", _FEDEPM_KNOBS, _build_fedepm),
    "sfedavg": AlgorithmEntry("sfedavg", _BASELINE_KNOBS, _build_baseline),
    "sfedprox": AlgorithmEntry("sfedprox", _BASELINE_KNOBS, _build_baseline),
}


def register_algorithm(name: str, *, sim_alg: str, knobs: frozenset,
                       build) -> None:
    """Register an algorithm the spec surface accepts. ``sim_alg`` must be
    a round-function pair FedSim knows (repro.sim.server)."""
    if name in ALGORITHMS:
        raise ValueError(f"algorithm {name!r} is already registered")
    ALGORITHMS[name] = AlgorithmEntry(sim_alg, frozenset(knobs), build)


# ---------------------------------------------------------------------------
# fleets
# ---------------------------------------------------------------------------


class FleetEntry(NamedTuple):
    build: Callable  # (FleetSpec, m, resolved seed) -> ClientProfiles


def _build_synthetic(fleet: FleetSpec, m: int, seed: int):
    from repro.sim import clients
    avail = 1.0 if fleet.availability is None else fleet.availability
    return clients.make_profiles(m, seed=seed, availability=avail)


def _build_trace(fleet: FleetSpec, m: int, seed: int):
    from repro.sim import clients
    return clients.LatencyTrace.load(fleet.trace_file).sample_profiles(
        m, seed=seed)


def _build_uniform(fleet: FleetSpec, m: int, seed: int):
    from repro.sim import clients
    return clients.uniform_profiles(m)


FLEETS: dict[str, FleetEntry] = {
    "synthetic": FleetEntry(build=_build_synthetic),
    "trace": FleetEntry(build=_build_trace),
    "uniform": FleetEntry(build=_build_uniform),
}


def register_fleet(kind: str, *, build) -> None:
    """Register a fleet kind: ``build(FleetSpec, m, seed) -> profiles``."""
    if kind in FLEETS:
        raise ValueError(f"fleet kind {kind!r} is already registered")
    FLEETS[kind] = FleetEntry(build=build)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


class PolicyEntry(NamedTuple):
    knobs: frozenset  # PolicySpec Optional fields this policy owns


POLICIES: dict[str, PolicyEntry] = {
    "sync": PolicyEntry(frozenset()),
    "deadline": PolicyEntry(frozenset({"deadline"})),
    "adaptive": PolicyEntry(frozenset({"deadline_slack", "ewma_beta"})),
    "overselect": PolicyEntry(frozenset({"overselect_factor"})),
    "async": PolicyEntry(frozenset({"buffer_size", "staleness_exp",
                                    "max_concurrency"})),
}

# knobs owned by async (shared with the CLI's flag validation so the two
# surfaces cannot drift)
ASYNC_KNOBS = POLICIES["async"].knobs


def register_policy(name: str, *, knobs: frozenset) -> None:
    """Register a policy name + its knob ownership on the spec surface.
    The aggregation semantics must also exist in repro.sim.server."""
    if name in POLICIES:
        raise ValueError(f"policy {name!r} is already registered")
    POLICIES[name] = PolicyEntry(frozenset(knobs))


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


class CodecEntry(NamedTuple):
    build: Callable  # (CodecSpec) -> CodecConfig | None


def _build_topk_quant(codec: CodecSpec):
    from repro.sim.transport import CodecConfig
    if codec.topk_frac >= 1.0 and codec.bits == 0:
        return None  # identity codec: raw float32 uploads, no ledger change
    return CodecConfig(topk_frac=codec.topk_frac, bits=codec.bits,
                       stochastic=codec.stochastic, impl=codec.impl,
                       index_bytes=codec.index_bytes,
                       error_feedback=codec.error_feedback)


CODECS: dict[str, CodecEntry] = {
    "topk_quant": CodecEntry(build=_build_topk_quant),
}


def register_codec(name: str, *, build) -> None:
    """Register a codec: ``build(CodecSpec) -> CodecConfig | None``."""
    if name in CODECS:
        raise ValueError(f"codec {name!r} is already registered")
    CODECS[name] = CodecEntry(build=build)


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------


class EngineEntry(NamedTuple):
    knobs: frozenset          # EngineSpec fields beyond name/rounds/terminate
    runner: Callable | None   # None = built into RunHandle.run


ENGINES: dict[str, EngineEntry] = {
    "eager": EngineEntry(frozenset(), None),
    "scan": EngineEntry(frozenset({"chunk", "mesh",
                                   "event_table_capacity"}), None),
}


def register_engine(name: str, *, runner, knobs: frozenset = frozenset()):
    """Register an execution engine: ``runner(handle, report) -> summary``
    takes over RunHandle.run entirely."""
    if name in ENGINES:
        raise ValueError(f"engine {name!r} is already registered")
    ENGINES[name] = EngineEntry(frozenset(knobs), runner)


# ---------------------------------------------------------------------------
# the validation gate
# ---------------------------------------------------------------------------


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


def _validate_task(task: TaskSpec) -> None:
    _require(task.kind in TASKS,
             f"[task] unknown kind {task.kind!r}; "
             f"registered: {sorted(TASKS)}")
    _require(task.m >= 1, f"[task] m must be >= 1; got {task.m}")
    if task.kind == "logreg":
        _require(task.d >= 1, f"[task] d must be >= 1; got {task.d}")
        _require(task.n >= 1, f"[task] n must be >= 1; got {task.n}")
        _require(task.arch is None,
                 "[task] arch is an lm-task field; kind is 'logreg'")
    if task.kind == "lm":
        from repro import configs
        _require(task.arch is not None,
                 "[task] kind='lm' requires arch (one of "
                 f"{configs.ALL_ARCHS})")
        _require(task.arch in configs.ALL_ARCHS,
                 f"[task] unknown arch {task.arch!r}; "
                 f"known: {configs.ALL_ARCHS}")
        _require(task.batch_per_client >= 1,
                 f"[task] batch_per_client must be >= 1; "
                 f"got {task.batch_per_client}")
        _require(task.seq_len >= 1,
                 f"[task] seq_len must be >= 1; got {task.seq_len}")


def _validate_algorithm(spec: ExperimentSpec) -> None:
    alg = spec.algorithm
    _require(alg.name in ALGORITHMS,
             f"[algorithm] unknown name {alg.name!r}; "
             f"registered: {sorted(ALGORITHMS)}")
    _require(0.0 < alg.rho <= 1.0,
             f"[algorithm] rho must be in (0, 1]; got {alg.rho}")
    _require(alg.k0 >= 1, f"[algorithm] k0 must be >= 1; got {alg.k0}")
    entry = ALGORITHMS[alg.name]
    all_knobs = _FEDEPM_KNOBS | _BASELINE_KNOBS
    for knob in sorted(all_knobs - entry.knobs):
        _require(getattr(alg, knob, None) is None,
                 f"[algorithm] {knob!r} does not apply to "
                 f"{alg.name!r} (accepted: {sorted(entry.knobs)})")
    if alg.sampler is not None:
        _require(alg.sampler in ("uniform", "coverage", "full"),
                 f"[algorithm] unknown sampler {alg.sampler!r}")
        _require(spec.policy.name != "overselect" or alg.sampler == "uniform",
                 "[algorithm] policy='overselect' only supports the "
                 f"uniform sampler; got sampler={alg.sampler!r}")


def _validate_fleet(fleet: FleetSpec) -> None:
    from repro.sim import clients
    _require(fleet.kind in FLEETS,
             f"[fleet] unknown kind {fleet.kind!r}; "
             f"registered: {sorted(FLEETS)}")
    _require(fleet.latency in clients.latency_model_names(),
             f"[fleet] unknown latency model {fleet.latency!r}; "
             f"registered: {clients.latency_model_names()}")
    _require(fleet.latency_sigma >= 0,
             f"[fleet] latency_sigma must be >= 0; "
             f"got {fleet.latency_sigma}")
    _require(fleet.latency_alpha > 0,
             f"[fleet] latency_alpha must be > 0; got {fleet.latency_alpha}")
    if fleet.kind == "trace":
        _require(fleet.trace_file is not None,
                 "[fleet] kind='trace' requires trace_file")
        _require(fleet.availability is None,
                 "[fleet] availability conflicts with a trace fleet: the "
                 "trace's own availability column defines the fleet")
    else:
        _require(fleet.trace_file is None,
                 f"[fleet] trace_file requires kind='trace'; "
                 f"kind is {fleet.kind!r}")
    if fleet.availability is not None:
        _require(0.0 < fleet.availability <= 1.0,
                 f"[fleet] availability must be in (0, 1]; "
                 f"got {fleet.availability}")


def _validate_policy(spec: ExperimentSpec) -> None:
    pol = spec.policy
    _require(pol.name in POLICIES,
             f"[policy] unknown name {pol.name!r}; "
             f"registered: {sorted(POLICIES)}")
    owned = POLICIES[pol.name].knobs
    all_knobs = frozenset().union(*(e.knobs for e in POLICIES.values()))
    for knob in sorted(all_knobs - owned):
        _require(getattr(pol, knob, None) is None,
                 f"[policy] {knob!r} does not apply to policy "
                 f"{pol.name!r} (owned knobs: {sorted(owned) or 'none'})")
    if pol.deadline is not None:
        _require(pol.deadline > 0,
                 f"[policy] deadline must be > 0 seconds; "
                 f"got {pol.deadline}")
    if pol.overselect_factor is not None:
        _require(pol.overselect_factor > 0,
                 f"[policy] overselect_factor must be > 0; "
                 f"got {pol.overselect_factor}")
    if pol.deadline_slack is not None:
        _require(pol.deadline_slack > 0,
                 f"[policy] deadline_slack must be > 0; "
                 f"got {pol.deadline_slack}")
    if pol.ewma_beta is not None:
        _require(0.0 < pol.ewma_beta <= 1.0,
                 f"[policy] ewma_beta must be in (0, 1]; "
                 f"got {pol.ewma_beta}")
    if pol.buffer_size is not None:
        _require(pol.buffer_size >= 0,
                 f"[policy] buffer_size must be >= 0 (0 = cohort size); "
                 f"got {pol.buffer_size}")
    if pol.staleness_exp is not None:
        _require(pol.staleness_exp >= 0,
                 f"[policy] staleness_exp must be >= 0; "
                 f"got {pol.staleness_exp}")
    if pol.max_concurrency is not None:
        _require(pol.max_concurrency >= 0,
                 f"[policy] max_concurrency must be >= 0 (0 = unlimited); "
                 f"got {pol.max_concurrency}")


def _validate_codec(codec: CodecSpec) -> None:
    _require(codec.name in CODECS,
             f"[codec] unknown name {codec.name!r}; "
             f"registered: {sorted(CODECS)}")
    _require(0.0 < codec.topk_frac <= 1.0,
             f"[codec] topk_frac must be in (0, 1]; got {codec.topk_frac}")
    _require(codec.bits == 0 or codec.bits >= 2,
             f"[codec] bits must be 0 (raw) or >= 2; got {codec.bits}")
    _require(codec.impl in ("ref", "pallas"),
             f"[codec] unknown impl {codec.impl!r}")
    _require(codec.index_bytes >= 0,
             f"[codec] index_bytes must be >= 0; got {codec.index_bytes}")
    _require(not (codec.error_feedback
                  and codec.topk_frac >= 1.0 and codec.bits == 0),
             "[codec] error_feedback needs a lossy codec: set "
             "topk_frac < 1 and/or bits >= 2")


def _validate_engine(spec: ExperimentSpec) -> None:
    eng = spec.engine
    _require(eng.name in ENGINES,
             f"[engine] unknown name {eng.name!r}; "
             f"registered: {sorted(ENGINES)}")
    _require(eng.rounds >= 1,
             f"[engine] rounds must be >= 1; got {eng.rounds}")
    for knob in ("chunk", "mesh", "event_table_capacity"):
        val = getattr(eng, knob)
        if val is None:
            continue
        _require(knob in ENGINES[eng.name].knobs,
                 f"[engine] {knob!r} does not apply to engine {eng.name!r}")
        _require(val >= 1,
                 f"[engine] {knob} must be >= 1; got {val}")
    if eng.event_table_capacity is not None:
        _require(spec.policy.name == "async",
                 "[engine] event_table_capacity sizes the async engine's "
                 "in-flight payload table; policy is "
                 f"{spec.policy.name!r}")
    if eng.terminate:
        _require(spec.task.kind == "logreg",
                 "[engine] terminate uses the paper's logreg variance "
                 f"rule; task kind is {spec.task.kind!r}")


def _validate_telemetry(spec: ExperimentSpec) -> None:
    tel = spec.telemetry
    for field in ("events_jsonl", "trace_out", "jax_profiler_dir"):
        val = getattr(tel, field)
        if val is None:
            continue
        _require(isinstance(val, str) and val != "",
                 f"[telemetry] {field} must be a non-empty path; "
                 f"got {val!r}")
        _require(tel.enabled,
                 f"[telemetry] {field} requires enabled = true (a sink on "
                 "a disabled recorder would silently write nothing)")


def _validate_faults(spec: ExperimentSpec) -> None:
    from repro.sim.faults import CORRUPT_MODES
    fl = spec.faults
    for field in ("drop_rate", "transient_rate", "corrupt_rate",
                  "duplicate_rate"):
        v = getattr(fl, field)
        # NaN fails both comparisons, so it is rejected here too
        _require(0.0 <= v <= 1.0,
                 f"[faults] {field} must be in [0, 1]; got {v}")
    _require(fl.drop_rate + fl.transient_rate + fl.corrupt_rate <= 1.0,
             "[faults] drop_rate + transient_rate + corrupt_rate must be "
             f"<= 1 (they partition one attempt's outcome); got "
             f"{fl.drop_rate + fl.transient_rate + fl.corrupt_rate}")
    _require(fl.max_retries >= 0,
             f"[faults] max_retries must be >= 0; got {fl.max_retries}")
    _require(fl.backoff_base > 0,
             f"[faults] backoff_base must be > 0 seconds; "
             f"got {fl.backoff_base}")
    _require(fl.backoff_factor >= 1.0,
             f"[faults] backoff_factor must be >= 1; "
             f"got {fl.backoff_factor}")
    _require(0.0 <= fl.reorder_jitter < float("inf"),
             f"[faults] reorder_jitter must be a finite value >= 0 "
             f"seconds; got {fl.reorder_jitter}")
    _require(fl.quarantine_after >= 1,
             f"[faults] quarantine_after must be >= 1; "
             f"got {fl.quarantine_after}")
    _require(fl.quarantine_rounds >= 1,
             f"[faults] quarantine_rounds must be >= 1; "
             f"got {fl.quarantine_rounds}")
    _require(fl.corrupt_mode in CORRUPT_MODES,
             f"[faults] unknown corrupt_mode {fl.corrupt_mode!r}; "
             f"known: {CORRUPT_MODES}")


def _validate_privacy(spec: ExperimentSpec) -> None:
    import math

    from repro.privacy import MECHANISMS, SENSITIVITY_MODES
    pv = spec.privacy
    _require(pv.mechanism in MECHANISMS,
             f"[privacy] unknown mechanism {pv.mechanism!r}; "
             f"known: {MECHANISMS}")
    _require(pv.eps >= 0 and math.isfinite(pv.eps),
             f"[privacy] eps must be a finite value >= 0 "
             f"(0 = no noise); got {pv.eps}")
    _require(0.0 < pv.delta < 1.0,
             f"[privacy] delta must be in (0, 1); got {pv.delta}")
    _require(pv.sensitivity in SENSITIVITY_MODES,
             f"[privacy] unknown sensitivity {pv.sensitivity!r}; "
             f"known: {SENSITIVITY_MODES}")
    if pv.sensitivity == "clip":
        _require(pv.clip > 0 and math.isfinite(pv.clip),
                 "[privacy] sensitivity='clip' requires a finite "
                 f"clip > 0; got {pv.clip}")
    else:
        _require(pv.clip == 0.0,
                 "[privacy] clip requires sensitivity='clip' (the "
                 "surrogate mode's sensitivity is 2*||z||_1, never "
                 f"clipped); got clip={pv.clip}")
    _require(pv.mask_bytes >= 1,
             f"[privacy] mask_bytes must be >= 1; got {pv.mask_bytes}")


def validate_spec(spec: ExperimentSpec) -> None:
    """Raise SpecError on the first inconsistency found."""
    from repro.spec.types import _SECTIONS
    for field, typ in _SECTIONS.items():
        _require(isinstance(getattr(spec, field), typ),
                 f"[{field}] must be a {typ.__name__}")
    _require(isinstance(spec.seed, int) and not isinstance(spec.seed, bool)
             and spec.seed >= 0,
             f"seed must be a non-negative int; got {spec.seed!r}")
    for sec in ("task", "fleet", "faults", "privacy"):
        sub_seed = getattr(spec, sec).seed
        _require(sub_seed is None or sub_seed >= 0,
                 f"[{sec}] seed must be >= 0 (None = experiment seed); "
                 f"got {sub_seed}")
    _require(isinstance(spec.name, str) and spec.name != "",
             f"name must be a non-empty string; got {spec.name!r}")
    for sec in ("task", "algorithm", "fleet", "policy", "codec", "engine",
                "telemetry", "faults", "privacy"):
        for f in dataclasses.fields(getattr(spec, sec)):
            val = getattr(getattr(spec, sec), f.name)
            _require(not isinstance(val, bool) or "bool" in f.type,
                     f"[{sec}] {f.name}: bool is not a valid value")
    _validate_task(spec.task)
    _validate_algorithm(spec)
    _validate_fleet(spec.fleet)
    _validate_policy(spec)
    _validate_codec(spec.codec)
    _validate_engine(spec)
    _validate_telemetry(spec)
    _validate_faults(spec)
    _validate_privacy(spec)
