"""Cross-product sweep expansion over experiment specs.

``sweep(base, axes, seeds=...)`` turns one base
:class:`~repro.spec.types.ExperimentSpec` plus a mapping of dotted-path
axes into the full grid of validated cells, the way the benchmark modules
define their figure grids::

    cells = sweep(
        base,
        {"algorithm.name": ["fedepm", "sfedavg"],
         "policy": [PolicySpec(name="sync"),
                    PolicySpec(name="deadline", deadline=0.002)]},
        seeds=[0, 1, 2])

Axis keys are either a dotted section field (``"policy.deadline"``) or a
whole section (``"policy"``, replacing the sub-spec object). The product
iterates in axis-insertion order with the LAST axis fastest (row-major,
like ``itertools.product``); ``seeds`` appends a final per-cell seed axis
setting the experiment's master ``seed``. Every cell is validated before
the list is returned, and cell names extend the base name with
``axis=value`` segments (plus ``s<seed>``), so a grid's JSON artifacts are
self-describing; when a whole-section axis makes two cells share a name
(two ``CodecSpec`` values share one ``.name``), each collision gets a
stable ``#<ordinal>`` suffix so names stay unique.
"""
from __future__ import annotations

import itertools
from typing import Mapping, Sequence

from repro.spec.types import ExperimentSpec, SpecError


def _segment(path: str, value) -> str:
    if hasattr(value, "name") and not isinstance(value, str):
        return f"{path}={value.name}"       # a whole sub-spec: use its name
    return f"{path}={value}"


def sweep(base: ExperimentSpec, axes: Mapping[str, Sequence], *,
          seeds: Sequence[int] | None = None) -> list[ExperimentSpec]:
    """Expand ``base`` over ``axes`` (x ``seeds``) -> validated cells."""
    for path, values in axes.items():
        if isinstance(values, (str, bytes)) or not isinstance(
                values, Sequence):
            raise SpecError(f"sweep axis {path!r} must be a sequence of "
                            f"values; got {type(values).__name__}")
        if len(values) == 0:
            raise SpecError(f"sweep axis {path!r} is empty")
    combos: list[tuple[ExperimentSpec, str]] = []
    paths = list(axes)
    for combo in itertools.product(*(axes[p] for p in paths)):
        spec = base
        segments = []
        for path, value in zip(paths, combo):
            spec = spec.replace(**{path: value})
            segments.append(_segment(path, value))
        name = "/".join([base.name, *segments]) if segments else base.name
        combos.append((spec, name))
    # a whole-section axis can yield colliding names (two CodecSpecs share
    # one .name); artifacts keyed by cell name must never overwrite each
    # other, so collisions get a stable per-duplicate ordinal
    counts: dict[str, int] = {}
    for _, name in combos:
        counts[name] = counts.get(name, 0) + 1
    seen: dict[str, int] = {}
    cells: list[ExperimentSpec] = []
    for spec, name in combos:
        if counts[name] > 1:
            k = seen[name] = seen.get(name, -1) + 1
            name = f"{name}#{k}"
        for seed in (seeds if seeds is not None else [None]):
            cell = spec if seed is None else spec.replace(seed=seed)
            cell = cell.replace(
                name=name if seed is None else f"{name}/s{seed}")
            cells.append(cell.validate())
    return cells
