"""Cross-product sweep expansion over experiment specs.

``sweep(base, axes, seeds=...)`` turns one base
:class:`~repro.spec.types.ExperimentSpec` plus a mapping of dotted-path
axes into the full grid of validated cells, the way the benchmark modules
define their figure grids::

    cells = sweep(
        base,
        {"algorithm.name": ["fedepm", "sfedavg"],
         "policy": [PolicySpec(name="sync"),
                    PolicySpec(name="deadline", deadline=0.002)]},
        seeds=[0, 1, 2])

Axis keys are either a dotted section field (``"policy.deadline"``) or a
whole section (``"policy"``, replacing the sub-spec object). The product
iterates in axis-insertion order with the LAST axis fastest (row-major,
like ``itertools.product``); ``seeds`` appends a final per-cell seed axis
setting the experiment's master ``seed``. Every cell is validated before
the list is returned, and cell names extend the base name with
``axis=value`` segments (plus ``s<seed>``), so a grid's JSON artifacts are
self-describing; when a whole-section axis makes two cells share a name
(two ``CodecSpec`` values share one ``.name``), each collision gets a
stable ``#<ordinal>`` suffix so names stay unique.

Numeric axis values are normalized before entering a name: floats print
as their shortest 12-significant-digit form (so a computed grid value
like ``0.1 * 3`` names the cell ``policy.deadline=0.3``, not
``...=0.30000000000000004``), bools print TOML-style ``true``/``false``.
Two axis values that normalize to the same text fall into the same
``#<ordinal>`` collision handling as sub-spec axes, so names stay unique
regardless.

``load_sweep(path)`` reads a spec FILE carrying an optional ``[sweep]``
table (dotted-path axes + ``seeds``) and returns the expanded grid --
the input surface of the multi-cell driver
(:mod:`repro.launch.sweep_run`, docs/spec.md).
"""
from __future__ import annotations

import itertools
from typing import Mapping, Sequence

from repro.spec.types import ExperimentSpec, SpecError


def _fmt_value(value) -> str:
    """Normalize one scalar axis value for use inside a cell name."""
    if isinstance(value, bool):
        return "true" if value else "false"
    if isinstance(value, float):
        # shortest-readable, not shortest-roundtrip: 12 significant digits
        # absorbs binary-float artifacts (0.1 * 3) that would otherwise
        # leak 17-digit noise into artifact keys
        return format(value, ".12g")
    return str(value)


def _segment(path: str, value) -> str:
    if hasattr(value, "name") and not isinstance(value, str):
        return f"{path}={value.name}"       # a whole sub-spec: use its name
    return f"{path}={_fmt_value(value)}"


def sweep(base: ExperimentSpec, axes: Mapping[str, Sequence], *,
          seeds: Sequence[int] | None = None) -> list[ExperimentSpec]:
    """Expand ``base`` over ``axes`` (x ``seeds``) -> validated cells."""
    for path, values in axes.items():
        if isinstance(values, (str, bytes)) or not isinstance(
                values, Sequence):
            raise SpecError(f"sweep axis {path!r} must be a sequence of "
                            f"values; got {type(values).__name__}")
        if len(values) == 0:
            raise SpecError(f"sweep axis {path!r} is empty")
    combos: list[tuple[ExperimentSpec, str]] = []
    paths = list(axes)
    for combo in itertools.product(*(axes[p] for p in paths)):
        spec = base
        segments = []
        for path, value in zip(paths, combo):
            spec = spec.replace(**{path: value})
            segments.append(_segment(path, value))
        name = "/".join([base.name, *segments]) if segments else base.name
        combos.append((spec, name))
    # a whole-section axis can yield colliding names (two CodecSpecs share
    # one .name); artifacts keyed by cell name must never overwrite each
    # other, so collisions get a stable per-duplicate ordinal
    counts: dict[str, int] = {}
    for _, name in combos:
        counts[name] = counts.get(name, 0) + 1
    seen: dict[str, int] = {}
    cells: list[ExperimentSpec] = []
    for spec, name in combos:
        if counts[name] > 1:
            k = seen[name] = seen.get(name, -1) + 1
            name = f"{name}#{k}"
        for seed in (seeds if seeds is not None else [None]):
            cell = spec if seed is None else spec.replace(seed=seed)
            cell = cell.replace(
                name=name if seed is None else f"{name}/s{seed}")
            cells.append(cell.validate())
    return cells


# ---------------------------------------------------------------------------
# [sweep] spec files
# ---------------------------------------------------------------------------

_SCALARS = (str, int, float, bool)


def parse_sweep_table(table) -> tuple[dict, list | None]:
    """Validate a raw ``[sweep]`` table -> (axes, seeds).

    Every key except ``seeds`` is an axis: a dotted section field (quoted
    in TOML, e.g. ``"policy.deadline"``) or a top-level spec field, mapped
    to a non-empty list of scalars. Axis order is the table's key order
    (last axis fastest, matching :func:`sweep`); ``seeds`` must be a list
    of ints and always expands innermost. Whole-section axes (sub-spec
    values) are a Python-API-only feature -- a table value must be a flat
    scalar list.
    """
    if not isinstance(table, Mapping):
        raise SpecError(f"[sweep] must be a table/object, "
                        f"got {type(table).__name__}")
    axes: dict = {}
    seeds = None
    for key, values in table.items():
        if not isinstance(values, Sequence) or isinstance(values,
                                                          (str, bytes)):
            raise SpecError(f"[sweep] {key}: expected a list of values, "
                            f"got {type(values).__name__}")
        if len(values) == 0:
            raise SpecError(f"[sweep] {key}: axis is empty")
        if key == "seeds":
            bad = [v for v in values
                   if not isinstance(v, int) or isinstance(v, bool)]
            if bad:
                raise SpecError(f"[sweep] seeds: expected ints, "
                                f"got {bad[0]!r}")
            seeds = list(values)
            continue
        bad = [v for v in values if not isinstance(v, _SCALARS)]
        if bad:
            raise SpecError(f"[sweep] {key}: axis values must be scalars "
                            f"(str/int/float/bool), got {bad[0]!r}")
        axes[key] = list(values)
    return axes, seeds


def load_sweep(path) -> tuple[ExperimentSpec, list[ExperimentSpec]]:
    """Read a spec file with an optional ``[sweep]`` table -> (base, cells).

    Without a ``[sweep]`` table the file is an ordinary single-cell spec
    and the grid is ``[base]`` (validated). With one, the remaining
    sections form the base cell and the grid is its :func:`sweep`
    cross-product -- each cell validated, each named
    ``<base>/<axis>=<value>/.../s<seed>``. Unknown axis paths surface as
    :class:`~repro.spec.types.SpecError` exactly like
    ``ExperimentSpec.replace`` misuse.
    """
    from repro.spec import serialize
    d = dict(serialize.read_spec_file(path))
    table = d.pop("sweep", None)
    base = ExperimentSpec.from_dict(d)
    if table is None:
        return base, [base.validate()]
    axes, seeds = parse_sweep_table(table)
    if not axes and seeds is None:
        raise SpecError(f"{path}: [sweep] table defines no axes and no "
                        f"seeds")
    return base, sweep(base, axes, seeds=seeds)
