"""Perfetto/Chrome ``trace_event`` exporter for the simulated timeline.

Renders a telemetry event stream (:mod:`repro.telemetry.events`) as Chrome
Trace Event Format JSON -- loadable in ``chrome://tracing`` or
https://ui.perfetto.dev -- with the simulated clock mapped onto trace
microseconds:

  * pid 2 ("clients"): ONE TRACK PER CLIENT (tid = client index). Every
    live dispatch becomes a complete-span ("X") named ``train+upload``
    covering the client's round trip, so a straggler shows up as the one
    long span gating its round; upload arrivals, offline contacts and
    fault events (upload_drop / retry / duplicate_discard / quarantine)
    are instants on the same track.
  * pid 1 ("server"): one track per server policy (tid 0, named after the
    policy). Each round is a complete-span from its round_start to the
    last event it produced; merges, abandons and codec encodes are
    instants on the track.
  * counter tracks ("C" events, pid 1): ``bytes`` (running ledger up/down
    totals from ledger_record events) and, under the async event loop,
    ``in_flight`` occupancy and the ``stalled`` dispatch-FIFO depth -- a
    stalled-dispatch backlog is visible as a plateau in the counter while
    client spans queue up behind the concurrency cap.

``validate_trace`` checks the exported object against the format's
required keys (``REQUIRED_KEYS``); tests and the CI telemetry smoke job
run every exported artifact through it.
"""
from __future__ import annotations

import json

#: keys the Chrome trace_event format requires on every event record
REQUIRED_KEYS = frozenset({"name", "ph", "ts", "pid", "tid"})

_SERVER_PID = 1
_CLIENT_PID = 2
_US = 1e6   # simulated seconds -> trace microseconds


def to_trace(events, *, label: str = "run") -> dict:
    """Event stream -> ``{"traceEvents": [...]}`` (Chrome JSON format)."""
    out: list[dict] = []
    clients_seen: set[int] = set()
    # per-round span bounds on the server track: round -> [t0, t_end]
    bounds: dict[int, list[float]] = {}
    policy = label

    def emit(name, ph, ts, pid, tid, **extra):
        out.append({"name": name, "ph": ph, "ts": ts * _US,
                    "pid": pid, "tid": tid, **extra})

    for ev in events:
        b = bounds.setdefault(ev.round_idx, [ev.ts, ev.ts])
        b[0] = min(b[0], ev.ts)
        b[1] = max(b[1], ev.ts)
        if ev.client is not None:
            clients_seen.add(ev.client)
        args = {"round": ev.round_idx, **ev.attrs}
        if ev.kind == "round_start":
            policy = ev.attrs.get("policy", policy)
        elif ev.kind == "dispatch":
            dur = ev.attrs.get("dur_s", ev.attrs.get("arrival_s"))
            if dur is not None:
                emit("train+upload", "X", ev.ts, _CLIENT_PID, ev.client,
                     dur=dur * _US, args=args)
            else:   # unreachable contact: the broadcast RPC failed
                emit("offline", "i", ev.ts, _CLIENT_PID, ev.client,
                     s="t", args=args)
        elif ev.kind == "upload_arrival":
            emit("upload", "i", ev.ts, _CLIENT_PID, ev.client,
                 s="t", args=args)
        elif ev.kind in ("merge", "abandon", "codec_encode"):
            emit(ev.kind, "i", ev.ts, _SERVER_PID, 0, s="t", args=args)
        elif ev.kind in ("upload_drop", "retry", "duplicate_discard",
                         "quarantine"):
            # fault events land on the affected client's track so a lossy
            # client reads as a run of drop/retry instants; server-scoped
            # fallbacks (client=None) go to the policy track
            if ev.client is not None:
                emit(ev.kind, "i", ev.ts, _CLIENT_PID, ev.client,
                     s="t", args=args)
            else:
                emit(ev.kind, "i", ev.ts, _SERVER_PID, 0, s="t", args=args)
        elif ev.kind == "ledger_record":
            if "total_up" in ev.attrs:
                emit("bytes", "C", ev.ts, _SERVER_PID, 0,
                     args={"up": ev.attrs["total_up"],
                           "down": ev.attrs.get("total_down", 0.0)})
        if "in_flight" in ev.attrs:
            emit("in_flight", "C", ev.ts, _SERVER_PID, 0,
                 args={"in_flight": ev.attrs["in_flight"]})
        if "stalled" in ev.attrs:
            emit("stalled", "C", ev.ts, _SERVER_PID, 0,
                 args={"stalled": ev.attrs["stalled"]})

    # one span per round on the server policy track
    for r, (t0, t1) in sorted(bounds.items()):
        emit(f"round {r}", "X", t0, _SERVER_PID, 0, dur=(t1 - t0) * _US,
             args={"round": r})

    # track naming metadata ("M" events)
    meta = [
        {"name": "process_name", "ph": "M", "ts": 0, "pid": _SERVER_PID,
         "tid": 0, "args": {"name": f"server ({label})"}},
        {"name": "thread_name", "ph": "M", "ts": 0, "pid": _SERVER_PID,
         "tid": 0, "args": {"name": f"policy:{policy}"}},
        {"name": "process_name", "ph": "M", "ts": 0, "pid": _CLIENT_PID,
         "tid": 0, "args": {"name": "clients"}},
    ]
    for c in sorted(clients_seen):
        meta.append({"name": "thread_name", "ph": "M", "ts": 0,
                     "pid": _CLIENT_PID, "tid": c,
                     "args": {"name": f"client {c}"}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def write_trace(events, path, *, label: str = "run") -> None:
    """Export the event stream as a trace JSON file (see :func:`to_trace`)."""
    with open(path, "w") as f:
        json.dump(to_trace(events, label=label), f)


def validate_trace(obj) -> list[str]:
    """Check a trace object against the required-key set; [] when valid."""
    errors: list[str] = []
    evs = obj.get("traceEvents") if isinstance(obj, dict) else None
    if not isinstance(evs, list) or not evs:
        return ["traceEvents must be a non-empty list"]
    for i, e in enumerate(evs):
        missing = REQUIRED_KEYS - set(e)
        if missing:
            errors.append(f"event {i}: missing key(s) {sorted(missing)}")
            continue
        if not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
            errors.append(f"event {i}: ts must be a non-negative number")
        if e["ph"] == "X" and not (isinstance(e.get("dur"), (int, float))
                                   and e["dur"] >= 0):
            errors.append(f"event {i}: 'X' span needs a non-negative dur")
    return errors
