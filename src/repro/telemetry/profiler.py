"""Opt-in ``jax.profiler`` hook for real wall-time traces.

Simulated-time telemetry (events.py / trace.py) describes what the modeled
fleet did; this module answers the other question -- where the WALL time of
the scan engine actually goes (compile vs. dispatch vs. device compute).
``jax_profile(trace_dir)`` wraps a run in ``jax.profiler.start_trace`` /
``stop_trace``; the resulting TensorBoard/Perfetto trace lands under
``trace_dir``. A falsy ``trace_dir`` makes it a no-op, and profiler
start/stop failures degrade to a warning rather than killing the run (the
profiler is diagnostics, never a dependency of results).
"""
from __future__ import annotations

import contextlib
import warnings


@contextlib.contextmanager
def jax_profile(trace_dir):
    """Context manager tracing wall time via jax.profiler; no-op if falsy."""
    if not trace_dir:
        yield
        return
    started = False
    try:
        import jax
        jax.profiler.start_trace(str(trace_dir))
        started = True
    except Exception as e:  # pragma: no cover - environment-dependent
        warnings.warn(f"jax.profiler trace could not start: {e}",
                      stacklevel=2)
    try:
        yield
    finally:
        if started:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception as e:  # pragma: no cover
                warnings.warn(f"jax.profiler trace could not stop: {e}",
                              stacklevel=2)
