"""Telemetry sinks: JSONL event logs and the end-of-run summary dict.

JSONL sink
----------
One JSON object per line, schema ``{"ts", "kind", "round", "client",
"attrs"}`` -- exactly the :class:`~repro.telemetry.events.Event` fields.
``read_events_jsonl(write_events_jsonl(events)) == events`` holds exactly:
attrs are JSON scalars (the recorder coerces numpy types on emit) and
Python's float repr round-trips through JSON bit-for-bit.

Summary sink
------------
``telemetry_summary`` merges the metrics-registry snapshot (counters,
gauges, histograms, time series -- bytes up/down, staleness, in-flight
occupancy) with run-level rates: the per-round objective series, wall-clock
rounds/sec of the driving engine, and the engine's host-sync count.
``RunHandle.run`` attaches it under the ``"telemetry"`` key of its
historical summary schema -- only when telemetry is enabled, so
telemetry-off summaries are byte-identical to previous releases.

The Perfetto/Chrome timeline exporter lives in
:mod:`repro.telemetry.trace`; the opt-in wall-time ``jax.profiler`` hook in
:mod:`repro.telemetry.profiler`.
"""
from __future__ import annotations

import json

from repro.telemetry.events import Event


def write_events_jsonl(events: list[Event], path) -> None:
    """Write the event stream as one compact JSON object per line."""
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(
                {"ts": ev.ts, "kind": ev.kind, "round": ev.round_idx,
                 "client": ev.client, "attrs": ev.attrs},
                separators=(",", ":")) + "\n")


def read_events_jsonl(path) -> list[Event]:
    """Exact inverse of :func:`write_events_jsonl`."""
    out: list[Event] = []
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            d = json.loads(line)
            out.append(Event(ts=d["ts"], kind=d["kind"],
                             round_idx=d["round"], client=d["client"],
                             attrs=d["attrs"]))
    return out


def telemetry_summary(recorder, *, objective=(), rounds: int = 0,
                      wall_s: float | None = None,
                      host_syncs: int | None = None) -> dict:
    """Metrics-registry snapshot + run-level rates, JSON-serializable.

    ``objective`` is the per-round objective history (added to the series
    block); ``wall_s`` the wall-clock the engine loop took (rounds/sec is
    derived, so perf trajectories can be read off run summaries); and
    ``host_syncs`` the sim's device->host transfer count.
    """
    out = recorder.registry.summary()
    out["events"] = len(recorder.events)
    out["series"]["objective"] = [float(f) for f in objective]
    if wall_s is not None:
        out["wall_s"] = wall_s
        out["rounds_per_sec_wall"] = rounds / wall_s if wall_s > 0 else None
    if host_syncs is not None:
        out["host_syncs"] = int(host_syncs)
    return out
