"""Run telemetry: typed event tracing, metrics, and timeline export.

The subsystem is observational only -- recorders are handed already-computed
host values, draw no RNG, and trigger no jit dispatch, so enabling
telemetry never changes trajectories (pinned bit-for-bit in
tests/test_telemetry.py). The default recorder is a shared no-op whose cost
is one attribute check per instrumentation site.

Layout:
  events.py   -- the event taxonomy + recorders (Event, EventRecorder,
                 NULL_RECORDER)
  metrics.py  -- counters/gauges/histograms derived from the event stream
  sinks.py    -- JSONL run log + end-of-run summary dict
  trace.py    -- Perfetto/Chrome ``trace_event`` timeline exporter
  profiler.py -- opt-in ``jax.profiler`` wall-time hook

See docs/observability.md for the event taxonomy and metric tables.
"""
from repro.telemetry.events import (EVENT_KINDS, NULL_RECORDER, Event,
                                    EventRecorder, NullRecorder)
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.profiler import jax_profile
from repro.telemetry.sinks import (read_events_jsonl, telemetry_summary,
                                   write_events_jsonl)
from repro.telemetry.trace import (REQUIRED_KEYS, to_trace, validate_trace,
                                   write_trace)

__all__ = [
    "EVENT_KINDS",
    "Event",
    "EventRecorder",
    "MetricsRegistry",
    "NULL_RECORDER",
    "NullRecorder",
    "REQUIRED_KEYS",
    "jax_profile",
    "read_events_jsonl",
    "telemetry_summary",
    "to_trace",
    "validate_trace",
    "write_events_jsonl",
    "write_trace",
]
