"""Typed run-telemetry events with simulated-time timestamps.

The sim emits a small, closed taxonomy of events (``EVENT_KINDS``):

  round_start    -- a server aggregation round/event begins (ts = entry
                    simulated time; attrs carry the policy name).
  dispatch       -- the server broadcasts to one client. Live dispatches
                    carry the client's round-trip duration (``dur_s`` under
                    the async event loop, ``arrival_s`` under the clocked
                    policies); unreachable contacts carry ``live=False``.
  upload_arrival -- one client's upload reaches the server.
  merge          -- the server folds uploads into its state: one event per
                    clocked round (attrs ``n``), one per buffered async
                    contribution (attrs ``staleness``/``gamma``).
  abandon        -- a round closed with nothing aggregated.
  codec_encode   -- uploads crossed the wire through the codec
                    (sim/transport.py; attrs describe the codec + bytes).
  ledger_record  -- the byte ledger recorded the round's transfers (attrs
                    carry the round delta and the running totals).

Fault-injection runs (repro.sim.faults, docs/sim.md) add four kinds:

  upload_drop       -- an upload was billed but never merged: lost
                       mid-flight (``reason="drop"``), retry budget or
                       listening window exhausted (``"exhausted"``), or
                       rejected by the corruption screen (``"corrupt"``).
  retry             -- the server scheduled a retry after a transient
                       upload failure (attrs carry the attempt number).
  duplicate_discard -- dedup discarded a duplicate delivery (billed,
                       never merged).
  quarantine        -- a repeat corruption offender was quarantined
                       (attrs carry the release round).

Private-upload runs (repro.privacy, docs/privacy.md) add two kinds:

  privacy_charge -- the DP accountant charged one merged client's
                    contribution (attrs ``eps`` per round, ``eps_total``
                    running spend; async merges add ``staleness``). The
                    per-client budget trajectory is reconstructible from
                    these events alone (the accountant replay test).
  mask_exchange  -- secure-aggregation pairwise masks crossed the wire
                    (attrs ``attempts``, ``bytes``): one event per round,
                    attempts matching the byte ledger's upload count.

Timestamps are SIMULATED seconds (``FedSim.t``'s clock), not wall time --
the stream describes what the modeled fleet did, and the eager and scan
engines reconstruct identical streams for the clocked policies
(tests/test_telemetry.py pins this). Within one client's track timestamps
are monotone.

Recording is observational only: the recorder is handed already-computed
host values, draws no RNG, and triggers no jit dispatch, so enabling it
cannot perturb trajectories (bit-for-bit pinned in tests). The default
recorder on every ``FedSim`` is the shared ``NULL_RECORDER`` whose
``enabled`` is False -- instrumentation sites guard on that flag, making
the disabled path a single attribute check per round.
"""
from __future__ import annotations

from typing import Any, NamedTuple

EVENT_KINDS = ("round_start", "dispatch", "upload_arrival", "merge",
               "abandon", "codec_encode", "ledger_record",
               "upload_drop", "retry", "duplicate_discard", "quarantine",
               "privacy_charge", "mask_exchange")
_KIND_SET = frozenset(EVENT_KINDS)


class Event(NamedTuple):
    """One telemetry event: simulated timestamp, kind, round, client, attrs.

    ``client`` is None for server-scoped events (round_start, merge under
    the clocked policies, abandon, codec_encode, ledger_record). ``attrs``
    holds JSON-serializable scalars only (the recorder coerces numpy
    scalars), so events round-trip exactly through the JSONL sink.
    """

    ts: float
    kind: str
    round_idx: int
    client: int | None
    attrs: dict


def _scalar(v: Any) -> Any:
    """Coerce numpy scalars to plain Python so events are JSON-exact."""
    if hasattr(v, "item") and not isinstance(v, (bool, int, float, str)):
        return v.item()
    return v


class NullRecorder:
    """Disabled recorder: ``enabled`` is False and ``event`` is a no-op.

    Instrumentation sites guard emission on ``recorder.enabled``, so the
    cost of disabled telemetry is one attribute read per guard -- no event
    construction, no attrs dict, no appends.
    """

    enabled = False

    def event(self, kind: str, *, ts: float, round_idx: int,
              client: int | None = None, **attrs) -> None:
        pass

    def mark(self) -> int:
        return 0

    def rewind(self, mark: int) -> None:
        pass


#: the shared default recorder every FedSim starts with
NULL_RECORDER = NullRecorder()


class EventRecorder:
    """Enabled recorder: appends typed events and feeds the metrics registry.

    ``events`` is the append-only stream (list of :class:`Event`);
    ``registry`` is a :class:`~repro.telemetry.metrics.MetricsRegistry`
    deriving counters/gauges/histograms from the same stream, so every
    metric is reconstructible from the event log alone.
    """

    enabled = True

    def __init__(self):
        from repro.telemetry.metrics import MetricsRegistry
        self.events: list[Event] = []
        self.registry = MetricsRegistry()

    def event(self, kind: str, *, ts: float, round_idx: int,
              client: int | None = None, **attrs) -> None:
        if kind not in _KIND_SET:
            raise ValueError(f"unknown event kind {kind!r}; "
                             f"known: {EVENT_KINDS}")
        ev = Event(ts=float(ts), kind=kind, round_idx=int(round_idx),
                   client=None if client is None else int(client),
                   attrs={k: _scalar(v) for k, v in attrs.items()})
        self.events.append(ev)
        self.registry.observe(ev)

    def mark(self) -> int:
        """Position in the event stream, for :meth:`rewind`."""
        return len(self.events)

    def rewind(self, mark: int) -> None:
        """Truncate the stream back to ``mark`` and rebuild the registry.

        Used by the scan engine's termination replay: a chunk that
        overshoots the stopping round is rolled back and re-run, and the
        overshot rounds' events must vanish with it so the stream equals an
        eager run that stopped at the same round. The registry is derived
        state, so it is rebuilt by re-observing the surviving prefix.
        """
        from repro.telemetry.metrics import MetricsRegistry
        del self.events[mark:]
        self.registry = MetricsRegistry()
        for ev in self.events:
            self.registry.observe(ev)
