"""Metrics registry: named counters, gauges and histograms over the sim run.

The registry derives every metric from the telemetry event stream
(:meth:`MetricsRegistry.observe` is called by the event recorder per
event), so the metric surface cannot drift from the event taxonomy and a
JSONL event log replayed through a fresh registry reproduces the same
summary. Instruments:

  Counter   -- monotone accumulator (rounds, dispatches, bytes up/down);
               passing ``ts`` to ``inc`` additionally tracks the running
               total as a ``(ts, value)`` series (the bytes timelines).
  Gauge     -- last-value instrument with a full ``(ts, value)`` series
               (in-flight occupancy, stalled-dispatch FIFO depth, per-merge
               staleness) -- the series is what makes a backlog visible.
  Histogram -- scalar distribution (staleness): count/mean/min/max plus an
               exact value->count table for small discrete domains.

Built-in metric names (docs/observability.md has the full table):
``rounds``, ``dispatches``, ``uploads``, ``merges``, ``abandoned_rounds``,
``codec_encodes``, ``codec_bytes``, ``bytes_up``, ``bytes_down``; under
fault injection also ``upload_drops``, ``retries``,
``duplicates_discarded``, ``quarantines``; under a live [privacy] config
also ``privacy_charges``, ``eps_spent``, ``mask_exchanges``,
``mask_bytes`` (counters); ``in_flight``, ``stalled``, ``staleness``
(gauges); ``staleness`` (histogram).

Everything is host-side plain Python -- observing a metric never touches
jax or the RNG streams.
"""
from __future__ import annotations


class Counter:
    """Monotone named accumulator, optionally tracked as a time series."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.series: list[tuple[float, float]] = []

    def inc(self, amount: float = 1.0, *, ts: float | None = None) -> None:
        """Add ``amount``; with ``ts``, record the new running total."""
        self.value += amount
        if ts is not None:
            self.series.append((ts, self.value))


class Gauge:
    """Last-value instrument with a full (ts, value) series."""

    def __init__(self, name: str):
        self.name = name
        self.value: float | None = None
        self.series: list[tuple[float, float]] = []

    def set(self, value: float, *, ts: float) -> None:
        self.value = value
        self.series.append((ts, value))


class Histogram:
    """Scalar distribution: count/mean/min/max + exact value counts."""

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self.dist: dict = {}

    def observe(self, value) -> None:
        self.count += 1
        self.total += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)
        self.dist[value] = self.dist.get(value, 0) + 1

    def stats(self) -> dict:
        return {"count": self.count,
                "mean": self.total / self.count if self.count else 0.0,
                "min": self.min, "max": self.max,
                "dist": {str(k): v for k, v in sorted(self.dist.items())}}


class MetricsRegistry:
    """Named instruments + the event->metric derivation rules."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name)
        return h

    # -- event-stream derivation (called by EventRecorder.event) -----------

    def observe(self, ev) -> None:
        """Fold one telemetry event into the derived metrics."""
        kind, attrs = ev.kind, ev.attrs
        if kind == "round_start":
            self.counter("rounds").inc()
        elif kind == "dispatch":
            self.counter("dispatches").inc()
        elif kind == "upload_arrival":
            self.counter("uploads").inc()
        elif kind == "merge":
            self.counter("merges").inc()
            if "staleness" in attrs:
                self.histogram("staleness").observe(attrs["staleness"])
                self.gauge("staleness").set(attrs["staleness"], ts=ev.ts)
        elif kind == "abandon":
            self.counter("abandoned_rounds").inc()
        elif kind == "codec_encode":
            self.counter("codec_encodes").inc()
            self.counter("codec_bytes").inc(attrs.get("bytes", 0.0))
        elif kind == "ledger_record":
            self.counter("bytes_up").inc(attrs.get("up", 0.0), ts=ev.ts)
            self.counter("bytes_down").inc(attrs.get("down", 0.0), ts=ev.ts)
        elif kind == "upload_drop":
            self.counter("upload_drops").inc()
        elif kind == "retry":
            self.counter("retries").inc()
        elif kind == "duplicate_discard":
            self.counter("duplicates_discarded").inc()
        elif kind == "quarantine":
            self.counter("quarantines").inc()
        elif kind == "privacy_charge":
            self.counter("privacy_charges").inc()
            self.counter("eps_spent").inc(attrs.get("eps", 0.0))
        elif kind == "mask_exchange":
            self.counter("mask_exchanges").inc(attrs.get("attempts", 0))
            self.counter("mask_bytes").inc(attrs.get("bytes", 0.0))
        # in-flight occupancy / stalled-FIFO depth ride on dispatch and
        # upload_arrival events under the async event loop
        if "in_flight" in attrs:
            self.gauge("in_flight").set(attrs["in_flight"], ts=ev.ts)
        if "stalled" in attrs:
            self.gauge("stalled").set(attrs["stalled"], ts=ev.ts)

    def summary(self) -> dict:
        """JSON-serializable snapshot: scalar values + the time series."""
        series = {}
        for c in self._counters.values():
            if c.series:
                series[c.name] = [[t, v] for t, v in c.series]
        for g in self._gauges.values():
            if g.series:
                series[g.name] = [[t, v] for t, v in g.series]
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.stats()
                           for n, h in sorted(self._histograms.items())},
            "series": series,
        }
