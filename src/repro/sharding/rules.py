"""Logical-axis sharding: models annotate activations/params with *logical*
axis names; a rules table (set by the launcher for the active mesh) maps them
to mesh axes. Outside any rules context the annotations are no-ops, so the
same model code runs single-device tests and 512-chip dry-runs unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_tls = threading.local()

# Default logical->mesh mapping for the production meshes. "client" is the
# FedEPM client-group axis; everything model-internal shards over "model".
DEFAULT_RULES: dict[str, Optional[tuple]] = {
    # data-ish axes
    "client": ("pod", "data"),
    "batch": ("pod", "data"),
    "seq": None,
    "seq_res": None,   # residual stream; ("model",) = Megatron-style SP
    # parameter axes
    "embed": None,
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": None,
    "head_dim": None,
    "state": None,
    # generic replicated
    None: None,
}


def single_pod_rules() -> dict:
    r = dict(DEFAULT_RULES)
    r["client"] = ("data",)
    r["batch"] = ("data",)
    return r


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Mapping[str, Optional[tuple]]):
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _tls.ctx = prev


def current_rules():
    return getattr(_tls, "ctx", None)


def _spec_for(logical: Sequence[Optional[str]], rules, mesh) -> P:
    parts = []
    used = set()
    for name in logical:
        ax = rules.get(name) if name is not None else None
        if ax is None:
            parts.append(None)
            continue
        ax = tuple(a for a in ax if a in mesh.axis_names and a not in used)
        if not ax:
            parts.append(None)
        else:
            used.update(ax)
            parts.append(ax if len(ax) > 1 else ax[0])
    return P(*parts)


def batch_groups():
    """(G, axes): the number of mesh shards the logical "batch" axis maps
    to under the active rules, and the axis names. (1, ()) outside a rules
    context. Used by data-dependent layers (MoE dispatch) to keep their
    routing LOCAL per shard instead of forcing a global all-gather."""
    ctx = current_rules()
    if ctx is None:
        return 1, ()
    mesh, rules = ctx
    ax = rules.get("batch")
    if not ax:
        return 1, ()
    axes = tuple(a for a in (ax if isinstance(ax, (tuple, list))
                             else (ax,)) if a in mesh.axis_names)
    g = 1
    for a in axes:
        g *= mesh.shape[a]
    return g, axes


def logical_sharding(logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    ctx = current_rules()
    if ctx is None:
        return None
    mesh, rules = ctx
    return NamedSharding(mesh, _spec_for(logical, rules, mesh))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a context.

    Axes whose dim is not divisible by the mapped mesh-axes product are
    dropped (replicated), so models with odd head counts degrade gracefully.
    """
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = _spec_for(logical, rules, mesh)
    parts = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * x.ndim):
        if entry is None:
            parts.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        parts.append(entry if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def param_sharding(logical_tree, abstract_tree):
    """Map a pytree of logical-name-tuples to NamedShardings (or None)."""
    ctx = current_rules()
    if ctx is None:
        return jax.tree_util.tree_map(lambda _: None, abstract_tree)
    mesh, rules = ctx

    def one(logical, leaf):
        return NamedSharding(mesh, _spec_for(logical, rules, mesh))

    return jax.tree_util.tree_map(
        one, logical_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
