"""Parameter sharding: per-family logical axis trees -> PartitionSpecs.

Every model family exposes ``param_logical(cfg)`` (see models/logical.py):
a pytree congruent with its params whose leaves are tuples of logical axis
names. This module maps those to concrete ``PartitionSpec``s for a mesh,
with two safety rails:

  * divisibility -- a logical rule is dropped (axis replicated) when the
    dim is not divisible by the mesh-axes product, so odd head counts
    (smollm 9H) or small dims degrade gracefully instead of failing;
  * once-per-spec -- a mesh axis is used by at most one dim of a leaf.

Modes:
  ``spatial_rules``  -- feature axes -> "model"; client/batch -> client axes.
  ``temporal_rules`` -- feature axes -> "model" PLUS an ``fsdp`` axis
    ("data", and "pod" when requested) assigned greedily to the largest
    still-unsharded dim of each leaf (ZeRO-3-style sharding so one copy of
    a 141B model fits the pod; XLA inserts the per-layer all-gathers).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis name -> preferred mesh axes (tried in order, first that fits)
MODEL_AXIS_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "inner": ("model",),       # xlstm/mamba expanded dim
    "glu": ("model",),
    "proj": ("model",),        # mamba fused in_proj output
    "conv": ("model",),        # mamba conv channels
    "experts": (),             # experts stay unsharded (top-2 of 8)
    "embed": (),               # d_model replicated in spatial mode
    "head_dim": (),
    "state": (),
    "gates": (),
    "layers": (),              # stacked-layer leading axis
}


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


_FALLBACK_MIN_SIZE = 1 << 16  # leaves above this always get "model"-sharded


def leaf_spec(logical: Sequence[Optional[str]], shape: Sequence[int],
              mesh: Mesh, rules: Mapping[str, tuple],
              fsdp_axes: Sequence[str] = ()) -> P:
    """Spec for one leaf.

    Three passes: (1) logical rules; (2) fallback -- if a *large* leaf got
    no "model" sharding (e.g. 9 or 40 heads on a 16-wide model axis),
    assign "model" to its largest divisible dim so storage still scales;
    (3) ``fsdp_axes`` go to the largest remaining dim (ZeRO-style).
    """
    assert len(logical) == len(shape), (logical, shape)
    parts: list = [None] * len(shape)
    used: set = set()
    for i, name in enumerate(logical):
        cand = rules.get(name, ()) if name else ()
        cand = tuple(a for a in cand if a in mesh.axis_names
                     and a not in used)
        if cand and shape[i] % _axes_size(mesh, cand) == 0:
            parts[i] = cand if len(cand) > 1 else cand[0]
            used.update(cand)
    if "model" in mesh.axis_names and "model" not in used \
            and int(np.prod(shape)) >= _FALLBACK_MIN_SIZE:
        ms = mesh.shape["model"]
        best, best_dim = -1, 0
        for i in range(len(shape)):
            if parts[i] is None and shape[i] % ms == 0 and shape[i] >= ms \
                    and shape[i] >= best_dim:
                best, best_dim = i, shape[i]
        if best >= 0:
            parts[best] = "model"
            used.add("model")
    fsdp = tuple(a for a in fsdp_axes if a in mesh.axis_names
                 and a not in used)
    if fsdp:
        fs = _axes_size(mesh, fsdp)
        # largest unsharded, divisible dim (prefer later dims on ties)
        best, best_dim = -1, 0
        for i in range(len(shape)):
            if parts[i] is None and shape[i] % fs == 0 and shape[i] >= fs \
                    and shape[i] >= best_dim:
                best, best_dim = i, shape[i]
        if best >= 0:
            parts[best] = fsdp if len(fsdp) > 1 else fsdp[0]
    return P(*parts)


def tree_specs(logical_tree, abstract_tree, mesh: Mesh,
               rules: Mapping[str, tuple] | None = None,
               fsdp_axes: Sequence[str] = (),
               prepend: Sequence = ()):
    """Map a logical tree + abstract (shaped) tree to PartitionSpecs.

    ``prepend`` adds leading spec entries (e.g. the stacked client axis).
    """
    rules = rules if rules is not None else MODEL_AXIS_RULES

    def one(logical, leaf):
        shape = leaf.shape
        core = shape[len(prepend):]
        sp = leaf_spec(logical, core, mesh, rules, fsdp_axes)
        return P(*prepend, *sp)

    return jax.tree_util.tree_map(
        one, logical_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def named(tree_of_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))


def constrain_tree(tree, tree_of_specs, mesh: Mesh):
    shardings = named(tree_of_specs, mesh)
    return jax.tree_util.tree_map(
        jax.lax.with_sharding_constraint, tree, shardings)
