"""Federated data partitioning.

``partition_iid``     -- the paper's scheme: randomly divide all instances
                         into m parts (sizes d_1..d_m, equal by default).
``partition_dirichlet`` -- non-IID label-skew partitioner (Dirichlet over
                         label proportions), the standard FL heterogeneity
                         knob; used by the beyond-paper robustness benches.

Both return dense stacked arrays (m, d_max, ...) plus a validity mask so the
result is jit/vmap friendly (ragged shards are padded; the mask zeroes the
padded rows' loss contribution).
"""
from __future__ import annotations

import numpy as np


def _stack_ragged(shards_X, shards_y):
    m = len(shards_X)
    d_max = max(len(s) for s in shards_X)
    n = shards_X[0].shape[1]
    X = np.zeros((m, d_max, n), np.float32)
    y = np.zeros((m, d_max), np.float32)
    mask = np.zeros((m, d_max), np.float32)
    for i, (xs, ys) in enumerate(zip(shards_X, shards_y)):
        X[i, : len(xs)] = xs
        y[i, : len(ys)] = ys
        mask[i, : len(xs)] = 1.0
    return {"x": X, "y": y, "mask": mask}


def partition_iid(X: np.ndarray, y: np.ndarray, m: int, seed: int = 0,
                  sizes=None):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(X))
    if sizes is None:
        splits = np.array_split(idx, m)
    else:
        assert sum(sizes) <= len(X)
        splits, start = [], 0
        for s in sizes:
            splits.append(idx[start : start + s])
            start += s
    return _stack_ragged([X[s] for s in splits], [y[s] for s in splits])


def partition_dirichlet(X: np.ndarray, y: np.ndarray, m: int,
                        alpha: float = 0.5, seed: int = 0):
    """Label-skew non-IID partition: p(client | label) ~ Dir(alpha)."""
    rng = np.random.default_rng(seed)
    labels = np.unique(y)
    shards = [[] for _ in range(m)]
    for lab in labels:
        idx = np.where(y == lab)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * m)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            shards[i].extend(part.tolist())
    shards = [np.array(sorted(s)) for s in shards]
    # guarantee non-empty shards
    for i, s in enumerate(shards):
        if len(s) == 0:
            donor = int(np.argmax([len(t) for t in shards]))
            shards[i] = shards[donor][-1:]
            shards[donor] = shards[donor][:-1]
    return _stack_ragged([X[s] for s in shards], [y[s] for s in shards])
