"""Synthetic language-model token streams for the transformer archs.

No internet in the container, so LM training data is synthesised with a
Zipfian unigram mixed with an order-2 Markov structure -- enough signal for
a small model to visibly reduce loss over a few hundred steps (the
examples/ drivers), while being fully deterministic given the seed.

``TokenStream`` yields fixed-shape (batch, seq+1) windows; callers split
into inputs/targets. ``federated_token_batches`` deals a stream into m
client shards with optionally heterogeneous (Dirichlet-skewed topic)
distributions, mirroring data/partition.py for the FL benches.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Deterministic synthetic token source."""

    def __init__(self, vocab: int, seed: int = 0, topics: int = 8):
        self.vocab = int(vocab)
        self.topics = topics
        rng = np.random.default_rng(seed)
        # Zipf unigram per topic, plus a shared order-1 transition bias
        ranks = np.arange(1, self.vocab + 1)
        base = 1.0 / ranks ** 1.1
        self._topic_probs = []
        for _ in range(topics):
            perm = rng.permutation(self.vocab)
            p = base[perm]
            self._topic_probs.append(p / p.sum())
        self._shift = rng.integers(1, self.vocab, size=topics)

    def sample(self, rng: np.random.Generator, batch: int, length: int,
               topic: int | None = None) -> np.ndarray:
        """(batch, length) int32 tokens."""
        out = np.empty((batch, length), np.int32)
        for b in range(batch):
            t = topic if topic is not None else int(rng.integers(self.topics))
            p = self._topic_probs[t]
            toks = rng.choice(self.vocab, size=length, p=p)
            # order-2-ish structure: every 3rd token is a deterministic
            # function of the previous two -> learnable signal
            for i in range(2, length, 3):
                toks[i] = (toks[i - 1] + toks[i - 2] + self._shift[t]) \
                    % self.vocab
            out[b] = toks
        return out


def lm_batches(vocab: int, batch: int, seq: int, steps: int, seed: int = 0):
    """Yield ``steps`` dicts {tokens, targets, loss_mask}."""
    stream = TokenStream(vocab, seed)
    rng = np.random.default_rng(seed + 1)
    for _ in range(steps):
        w = stream.sample(rng, batch, seq + 1)
        yield {
            "tokens": w[:, :-1],
            "targets": w[:, 1:].astype(np.int32),
            "loss_mask": np.ones((batch, seq), np.float32),
        }


def federated_token_batches(vocab: int, m: int, batch_per_client: int,
                            seq: int, steps: int, seed: int = 0,
                            heterogeneous: bool = True):
    """Yield ``steps`` stacked client batches (leading axis m).

    Heterogeneous: client i draws from topic i % topics (label/topic skew);
    homogeneous: uniform topic mix for everyone.
    """
    stream = TokenStream(vocab, seed)
    rng = np.random.default_rng(seed + 1)
    for _ in range(steps):
        toks = np.empty((m, batch_per_client, seq + 1), np.int32)
        for i in range(m):
            topic = (i % stream.topics) if heterogeneous else None
            toks[i] = stream.sample(rng, batch_per_client, seq + 1, topic)
        yield {
            "tokens": toks[:, :, :-1],
            "targets": toks[:, :, 1:].astype(np.int32),
            "loss_mask": np.ones((m, batch_per_client, seq), np.float32),
        }
