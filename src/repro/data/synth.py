"""Synthetic datasets.

``adult_like`` reproduces the *statistical shape* of the paper's processed
UCI Adult-income data (Sec. VII.A): d=45222 instances, n=14 features
(6 continuous + 8 categorical-converted-to-integer), binary labels, and --
crucially for the paper's step-size (38) to make sense -- **attribute-wise
unit-length normalisation** (each feature column scaled to unit Euclidean
norm over the dataset, so entries are O(1/sqrt(d))). The container has no
internet access, so we generate a linearly-separable-ish logistic model with
integer-ised categorical columns and apply the exact same processing
pipeline. Documented as a substitution in DESIGN.md/EXPERIMENTS.md.
"""
from __future__ import annotations

import numpy as np


def adult_like(d: int = 45222, n: int = 14, seed: int = 0,
               n_categorical: int = 8, label_noise: float = 0.05):
    """Returns (X, y): X (d, n) float32 column-unit-normalised, y (d,) {0,1}."""
    rng = np.random.default_rng(seed)
    n_cont = n - n_categorical
    X_cont = rng.standard_normal((d, n_cont))
    # categorical columns: small integer codes, like the paper's step (ii)
    cards = rng.integers(2, 16, size=n_categorical)
    X_cat = np.stack([rng.integers(0, c, size=d) for c in cards], axis=1)
    X = np.concatenate([X_cont, X_cat.astype(np.float64)], axis=1)
    # step (iii): attribute-wise unit-length normalisation -- each COLUMN
    # scaled to unit Euclidean norm over the dataset, the literal reading
    # of the paper. Entries are then O(1/sqrt(d)) and gradients O(1e-3);
    # this is also what makes the paper's DP noise scale (39) sane and its
    # SNR range (Fig. 5: ~0.5-3) reproducible. Consequence (documented in
    # DESIGN.md §8): with beta=1e-3 the regularised optimum has small
    # ||w*||, so objective DECLINES are small in absolute terms and early
    # rounds are noise-dominated at eps=0.1 -- matching the qualitative
    # claims (relative algorithm ordering), which is what a synthetic
    # stand-in can faithfully reproduce.
    Xn = X / (np.linalg.norm(X, axis=0, keepdims=True) + 1e-12)
    # labels from the PROCESSED features so the no-bias model is
    # well-specified; slope gives ~85% attainable accuracy
    w_true = rng.standard_normal(n)
    w_true /= np.linalg.norm(w_true)
    raw = Xn @ w_true
    # centre the label logits so classes are balanced (~50/50) and sign
    # predictions are meaningful even at the small-||w|| regularised
    # optimum this normalisation induces
    logits = 2.5 * (raw - raw.mean()) / (raw.std() + 1e-12)
    p = 1.0 / (1.0 + np.exp(-logits))
    y = (rng.random(d) < p).astype(np.float32)
    flip = rng.random(d) < label_noise
    y[flip] = 1.0 - y[flip]
    return Xn.astype(np.float32), y


def linear_regression(d: int = 1024, n: int = 32, seed: int = 0,
                      noise: float = 0.01):
    """Simple least-squares testbed (gradient-Lipschitz, eq. (4))."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((d, n)).astype(np.float32) / np.sqrt(n)
    w_true = rng.standard_normal(n).astype(np.float32)
    y = X @ w_true + noise * rng.standard_normal(d).astype(np.float32)
    return X, y, w_true
