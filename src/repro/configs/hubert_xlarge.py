"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
-- encoder-only, same arch as wav2vec2 [arXiv:2106.07447].

The conv feature extractor (waveform -> 50 Hz frames) is a STUB per the
assignment carve-out: ``input_specs`` provides precomputed frame embeddings
(B, T, d_model). Encoder-only => bidirectional attention, LayerNorm +
biases, GELU MLP, no decode path (decode shapes skipped, DESIGN.md §4).
vocab=504 is the HuBERT k-means target codebook for masked prediction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    norm="layernorm",
    mlp="gelu",
    bias=True,
    rope_theta=0.0,          # learned/conv positions in the real model; stub
    attention="bidirectional",
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    source="arXiv:2106.07447",
)

FED_PLAN = {"mode": "spatial", "m": None}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=64, dtype=jnp.float32)
