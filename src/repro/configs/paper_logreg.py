"""The paper's own experiment (Sec. VII.A): l2-regularised logistic
regression on (a synthetic stand-in for) UCI Adult income.

d = 45222 instances, n = 14 features, beta = 1e-3; m clients by random
partition; FedEPM hyper-parameters per Sec. VII.B:
  eta = (0.02 m + 1)(rho + 0.1) 1e-5,  lam = eta / 2,
  mu0 = 0.05, c = 1e-8, alpha = 1.001.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperTask:
    d: int = 45222
    n: int = 14
    beta: float = 1e-3
    seed: int = 0

    # experiment grid of the paper
    m_grid: tuple = (50, 100, 128)
    k0_grid: tuple = (4, 8, 12, 16, 20)
    rho_grid: tuple = (0.2, 0.4, 0.5, 0.6, 0.8, 1.0)
    eps_grid: tuple = (0.1, 0.3, 0.5, 0.7, 0.9)


CONFIG = PaperTask()


def termination_reached(f_hist, grad_sq, n: int) -> bool:
    """The paper's stopping rule: ||grad f||^2 < 1e-6 OR variance of the
    last four objective values <= n*1e-8 / (1 + |f|)."""
    import numpy as np
    if grad_sq < 1e-6:
        return True
    if len(f_hist) >= 4:
        last = np.asarray(f_hist[-4:], dtype=np.float64)
        if last.var() <= n * 1e-8 / (1.0 + abs(float(last[-1]))):
            return True
    return False
