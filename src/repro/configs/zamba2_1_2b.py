"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 -- Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

The shared transformer block (32-head MHA + SwiGLU d_ff=8192) is applied
every 6 mamba layers with SHARED weights (the Zamba2 memory insight); we
implement the shared-weights core and note the concat/LoRA simplification
in DESIGN.md. Mamba2: d_inner=4096, headdim=64 -> 64 SSD heads, N=64.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=10000.0,
    attention="causal",
    ssm_state=64,
    ssm_heads=64,
    ssm_expand=2,
    ssm_chunk=64,
    shared_attn_every=6,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    source="arXiv:2411.15242",
)

FED_PLAN = {"mode": "spatial", "m": None}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, ssm_state=16, ssm_heads=4, ssm_chunk=8,
        shared_attn_every=3, dtype=jnp.float32)
