"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 -- anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision frontend (ViT tower + projector, anyres tiling) is a STUB per
the assignment carve-out: ``input_specs`` provides precomputed patch
embeddings (B, n_patches, d_model) which the decoder prepends to the token
stream (models/dense.py: embed_inputs). n_patches=2880 corresponds to
anyres 2x2 tiles + base at 24x24 patches.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    norm="rmsnorm",
    mlp="swiglu",
    bias=False,
    rope_theta=5e6,
    attention="causal",
    n_patches=2880,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

# ~34B params: temporal FedEPM, m=8.
FED_PLAN = {"mode": "temporal", "m": 8, "microbatch": 4}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
        vocab=512, n_patches=16, dtype=jnp.float32, param_dtype=jnp.float32)
