"""Architecture configs assigned to this paper (public-literature pool).

Each module defines ``CONFIG`` (the exact assigned full-scale config, source
cited) and ``reduced()`` (a <=512-dim, 2-layer, <=4-expert variant of the
same family for CPU smoke tests). ``get_config(name)`` /
``get_reduced(name)`` dispatch by arch id; ``ALL_ARCHS`` lists the ten
assigned ids. FedEPM execution hints (client count m and spatial/temporal
strategy, see core/distributed.py) live in ``fed_plan``.
"""
from __future__ import annotations

import importlib

from repro.models.config import INPUT_SHAPES, ArchConfig, InputShape

ALL_ARCHS = [
    "command-r-35b",
    "xlstm-125m",
    "phi3-mini-3.8b",
    "phi3-medium-14b",
    "zamba2-1.2b",
    "mixtral-8x7b",
    "mixtral-8x22b",
    "llava-next-34b",
    "hubert-xlarge",
    "smollm-135m",
]

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_")
            for name in ALL_ARCHS}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_ARCHS}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str) -> ArchConfig:
    return _mod(name).CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _mod(name).reduced()


def fed_plan(name: str) -> dict:
    """FedEPM execution plan for this arch: mode + client count.

    spatial  -- clients = device groups along the ("pod","data") axes;
                ENS is a cross-group collective. For models whose per-client
                copy fits one data-row (16 "model" chips).
    temporal -- client state coordinate-sharded over the WHOLE mesh; clients
                iterated with lax.scan; ENS is collective-free. For models
                whose per-client copy needs the full pod (see DESIGN.md §2a).
    """
    return _mod(name).FED_PLAN
