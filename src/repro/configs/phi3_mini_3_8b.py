"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 -- RoPE SwiGLU GQA [arXiv:2404.14219]. kv=32 => MHA.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    norm="rmsnorm",
    mlp="swiglu",
    bias=False,
    rope_theta=10000.0,
    attention="causal",
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    source="arXiv:2404.14219",
)

FED_PLAN = {"mode": "spatial", "m": None}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, d_ff=256,
        vocab=512, dtype=jnp.float32)
