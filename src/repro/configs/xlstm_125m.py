"""xlstm-125m [ssm]: 12L d_model=768 4H (kv=4) d_ff=0 vocab=50304 --
sLSTM + mLSTM blocks [arXiv:2405.04517].

Block layout follows the paper's xLSTM[a:b] mix: every 4th block is sLSTM
(indices 0, 4, 8), the rest mLSTM; no separate FFN (d_ff=0) -- the blocks
carry their own up/down projections (mLSTM pf=2, sLSTM GLU 4/3).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    norm="rmsnorm",
    ssm_expand=2,
    ssm_chunk=64,
    slstm_every=4,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    source="arXiv:2405.04517",
)

FED_PLAN = {"mode": "spatial", "m": None}  # m = client-axis size of the mesh


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab=512,
        ssm_chunk=8, slstm_every=2, dtype=jnp.float32)
