"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 -- GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

Command-R specifics: parallel attention+FFN block, LayerNorm (no bias),
tied embeddings with logit scaling, no RoPE on... (it does use RoPE);
sliding-window *variant* is what we lower for long_500k (DESIGN.md §4).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    norm="layernorm",
    mlp="swiglu",
    bias=False,
    parallel_block=True,
    tie_embeddings=True,
    logit_scale=0.0625,
    rope_theta=10000.0,
    attention="causal",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    source="hf:CohereForAI/c4ai-command-r-v01",
)

# FedEPM: ~30B params -> per-client copy does not fit a 16-chip data row;
# temporal (coordinate-sharded) execution with m=8 clients.
FED_PLAN = {"mode": "temporal", "m": 8, "microbatch": 4}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
        vocab=512, dtype=jnp.float32, param_dtype=jnp.float32)
