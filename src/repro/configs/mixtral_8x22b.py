"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention [arXiv:2401.04088].
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    norm="rmsnorm",
    mlp="swiglu",
    bias=False,
    rope_theta=1e6,
    attention="causal",
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    capacity_factor=1.25,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    source="arXiv:2401.04088",
)

# 141B total params: the largest assigned arch. Temporal FedEPM with m=4;
# even a single bf16 copy needs the whole mesh (FSDP over data x model).
FED_PLAN = {"mode": "temporal", "m": 4, "microbatch": 8}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
        vocab=512, n_experts=4, top_k=2, sliding_window=16,
        dtype=jnp.float32, param_dtype=jnp.float32)
