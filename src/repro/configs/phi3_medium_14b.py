"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 -- RoPE SwiGLU GQA [arXiv:2404.14219].
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    norm="rmsnorm",
    mlp="swiglu",
    bias=False,
    rope_theta=10000.0,
    attention="causal",
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    source="arXiv:2404.14219",
)

# 14B: the spatial layout fits the persistent state (bf16 W+Z+g ~5.5
# GB/chip) but the ENS sort + DP-noise TRANSIENTS of 16 stacked clients
# push peak past 16 GB HBM (measured in the dry-run) -> temporal mode,
# where the sort is local per coordinate shard and transients are 1/256.
FED_PLAN = {"mode": "temporal", "m": 16, "microbatch": 2}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=160, n_heads=8, n_kv_heads=2, d_ff=320,
        vocab=512, dtype=jnp.float32)
