"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
-- llama-arch small [hf:HuggingFaceTB/SmolLM-135M].
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    norm="rmsnorm",
    mlp="swiglu",
    bias=False,
    rope_theta=10000.0,
    attention="causal",
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

FED_PLAN = {"mode": "spatial", "m": None}


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=3, n_kv_heads=3, vocab=512,
        d_ff=256, dtype=jnp.float32)
