"""Privacy subsystem: DP accounting, noise/clip config, secure-agg masking.

This package owns the transport-layer privacy axis of the simulation
(paper Sec. V, Setup V.1, Thm V.1): a declarative ``[privacy]`` spec
section (``repro.spec.types.PrivacySpec``) builds a
:class:`~repro.privacy.accounting.PrivacyModel` that the server runtime
(``repro.sim.server``) consults at merge and billing points, while the
actual clip/noise/quantize transform runs device-side through
``repro.sim.transport.private_roundtrip`` and the fused kernel in
``repro.kernels.quant``.

An all-default (or otherwise inert) config builds NO model at all --
``build_privacy_model`` returns None -- so the pre-privacy code paths and
golden trajectories stay byte-identical (tests/test_privacy.py pins it).
"""
from __future__ import annotations

from repro.privacy.accounting import (  # noqa: F401
    MECHANISMS,
    SENSITIVITY_MODES,
    PrivacyConfig,
    PrivacyModel,
    build_privacy_model,
)
