"""Per-client DP accountant and secure-aggregation byte accounting.

The accountant is deliberately host-side and RNG-free: every number it
tracks is a deterministic function of which uploads the server actually
MERGED (and, with secure aggregation on, which attempts reached the
wire), so both engines drive one instance through the shared server code
and land on identical totals. The noise itself is drawn by the sim HOST
in one standalone jitted program (``repro.sim.transport.draw_unit_noise``
on the dedicated privacy PRNGKey: ``fold_in(privacy_key, round_idx)``
clocked, ``fold_in(privacy_key, serial)`` async) and fed to the engines
as data, never from here.

Accounting semantics (docs/privacy.md):

  per-round charge    -- a client that contributes one merged update in a
                         round spends ``eps`` of budget for that round
                         (Setup V.1: the mechanism is applied once per
                         participating client per round). Clients that
                         were never selected, dropped out, missed the
                         deadline, or were lost to faults spend NOTHING
                         -- the accountant composes over *simulated
                         participation*, not over wall-clock rounds.
  async staleness     -- an async contribution is charged when it MERGES
                         (that is when its noisy payload is consumed);
                         the ``privacy_charge`` telemetry event carries
                         the contribution's staleness so the charge
                         remains attributable to its dispatch round.
  secure aggregation  -- each upload attempt that reaches the wire also
                         carries one pairwise-mask exchange of
                         ``mask_bytes`` bytes, billed to the ByteLedger
                         exactly like the payload bytes it escorts
                         (clean arrivals + retries + discarded
                         duplicates; never attempts the server cut off
                         before they fired -- PR 9's billing rule).

Replayability: the accountant's full per-client state is reconstructible
from the telemetry stream alone by summing ``privacy_charge`` events per
client (tests/test_privacy.py replays a JSONL export and checks it).
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: noise mechanisms the transform knows
MECHANISMS = ("laplace", "gaussian")
#: sensitivity modes: paper surrogate 2||g||_1 (eq. 39) vs enforced l1 clip
SENSITIVITY_MODES = ("surrogate", "clip")


@dataclasses.dataclass(frozen=True)
class PrivacyConfig:
    """Declarative privacy parameters (hashable; jit-static).

    ``eps`` is the per-round, per-client budget; ``eps == 0`` disables
    the noise/clip transform entirely. ``sensitivity`` picks how the
    noise scale's sensitivity estimate is obtained: ``"surrogate"`` uses
    the paper's data-dependent ``2 * ||z||_1`` (eq. 39) per client,
    ``"clip"`` first enforces ``||z||_1 <= clip`` and then uses the
    data-independent bound ``2 * clip``. ``seed`` keys the privacy noise
    stream, independent of the sim seed so the same trajectory can be
    replayed under different noise draws.
    """

    mechanism: str = "laplace"      # "laplace" | "gaussian"
    eps: float = 0.0                # per-round eps budget (0 = no noise)
    delta: float = 1e-5             # gaussian mechanism delta
    sensitivity: str = "surrogate"  # "surrogate" | "clip"
    clip: float = 0.0               # l1 clip bound (sensitivity="clip")
    secure_agg: bool = False        # pairwise-mask exchange on uploads
    mask_bytes: int = 32            # bytes per mask-pair exchange
    seed: int = 0                   # privacy noise-stream seed

    @property
    def enabled(self) -> bool:
        """True when the config creates any privacy state at all."""
        return self.eps > 0 or self.secure_agg


class PrivacyModel:
    """Runtime accountant state for one simulation.

    Tracks per-client spent budget (float64, exact under both engines'
    identical charge order), participation counts, and secure-agg mask
    counters. :meth:`state_snapshot`/:meth:`state_restore` give the scan
    engine's fixpoint passes and ``--terminate`` rollback the same
    exact-rewind guarantee the fault model has.
    """

    def __init__(self, cfg: PrivacyConfig, m: int):
        if not cfg.enabled:
            raise ValueError("PrivacyModel needs eps > 0 or secure_agg; "
                             "build None instead for an inert config")
        self.cfg = cfg
        self.m = m
        self.eps_spent = np.zeros(m, np.float64)
        self.participation = np.zeros(m, np.int64)
        self.total_charges = 0
        self.total_mask_attempts = 0
        self.total_mask_bytes = 0

    # -- accounting ----------------------------------------------------------

    def charge(self, client: int) -> float:
        """Charge one merged contribution; returns the new spent total."""
        self.eps_spent[client] += self.cfg.eps
        self.participation[client] += 1
        self.total_charges += 1
        return float(self.eps_spent[client])

    def bill_masks(self, attempts: int) -> int:
        """Count ``attempts`` mask-pair exchanges; returns the bytes they
        add to the wire (0 when secure aggregation is off)."""
        if not self.cfg.secure_agg or attempts <= 0:
            return 0
        self.total_mask_attempts += int(attempts)
        bytes_ = int(attempts) * int(self.cfg.mask_bytes)
        self.total_mask_bytes += bytes_
        return bytes_

    @property
    def mask_overhead(self) -> float:
        """Per-upload wire overhead in bytes (0 when secure-agg is off)."""
        return float(self.cfg.mask_bytes) if self.cfg.secure_agg else 0.0

    # -- exact rewind --------------------------------------------------------

    def state_snapshot(self) -> dict:
        """Everything :meth:`state_restore` needs to rewind exactly
        (the snapshot stays reusable)."""
        return {
            "eps_spent": self.eps_spent.copy(),
            "participation": self.participation.copy(),
            "counters": (self.total_charges, self.total_mask_attempts,
                         self.total_mask_bytes),
        }

    def state_restore(self, snap: dict) -> None:
        self.eps_spent = snap["eps_spent"].copy()
        self.participation = snap["participation"].copy()
        (self.total_charges, self.total_mask_attempts,
         self.total_mask_bytes) = snap["counters"]

    def summary(self) -> dict:
        """JSON-exact accountant totals for the run summary block."""
        return {
            "eps_per_round": float(self.cfg.eps),
            "eps_spent_max": float(self.eps_spent.max()),
            "eps_spent_mean": float(self.eps_spent.mean()),
            "charges": int(self.total_charges),
            "mask_attempts": int(self.total_mask_attempts),
            "mask_bytes": int(self.total_mask_bytes),
        }


def build_privacy_model(cfg: "PrivacyConfig | None",
                        m: int) -> PrivacyModel | None:
    """PrivacyConfig -> PrivacyModel, or None when the config is inert.

    The None return is the inertness guarantee: with no model attached
    the server runtime takes exactly its historical code paths, so a
    zero-noise ``[privacy]`` section reproduces the golden trajectories
    byte-for-byte.
    """
    if cfg is None or not cfg.enabled:
        return None
    return PrivacyModel(cfg, m)
