#!/usr/bin/env python3
"""Regenerate the golden trajectory fixtures under tests/fixtures/.

golden_sync_trajectory.npz pins 2 rounds of the SYNC simulation
(deterministic latency, heterogeneous profiles, DP noise ON) on the
reduced paper logreg task: per-round global objective, cumulative
simulated clock, the first 8 coordinates of the broadcast point w_tau,
and the final PRNG key / iteration counter.

golden_async_trajectory.npz pins 4 aggregation events of the ASYNC
simulation at its hairiest: concurrency-capped dispatch, error-feedback
codec, trace-resampled fleet (tests/fixtures/device_trace.csv) -- plus
the byte-ledger totals. ``simulate_golden_async`` takes an ``engine``
argument so the regression test diffs BOTH the eager event loop and the
scan record/replay engine (run as 2 chunks) against the same stored
trajectory.

tests/test_sim_invariants.py diffs every future server refactor against
these stored trajectories, so regressions show up even when a refactor
stays self-consistent.

ONLY regenerate after a DELIBERATE semantic change to the round math or
the sim's timing model, and say why in the commit:

    PYTHONPATH=src python tools/regen_golden_trajectory.py
"""
from __future__ import annotations

import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedepm
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.sim import FedSim, SimConfig, make_profiles
from repro.sim.clients import LatencyTrace
from repro.sim.transport import CodecConfig

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = ROOT / "tests" / "fixtures" / "golden_sync_trajectory.npz"
OUT_ASYNC = ROOT / "tests" / "fixtures" / "golden_async_trajectory.npz"
TRACE_CSV = ROOT / "tests" / "fixtures" / "device_trace.csv"

# frozen scenario -- changing ANY of these invalidates the fixture
M = 16
N = 14
D = 2000
ROUNDS = 2
SEED = 0
PROFILE_SEED = 5
HEAD = 8  # leading w_tau coordinates pinned


def simulate_golden(faults=None, privacy=None) -> dict[str, np.ndarray]:
    """Run the frozen scenario and return the trajectory arrays.

    ``faults`` (a repro.sim.faults.FaultConfig or None) exists for the
    zero-rate regression pin: a FaultConfig whose rates are all zero must
    leave this trajectory bit-for-bit unchanged. ``privacy`` (a
    repro.privacy.PrivacyConfig or None) is the same kind of pin for the
    privacy subsystem: an inert config (eps 0, secure-agg off) must
    build no privacy state and leave the trajectory bit-for-bit
    unchanged (tests/test_privacy.py).
    """
    X, y = synth.adult_like(d=D, n=N, seed=SEED)
    batches = jax.tree_util.tree_map(
        jnp.asarray, partition_iid(X, y, m=M, seed=SEED))
    loss = make_logistic_loss()
    cfg = fedepm.FedEPMConfig.paper_defaults(
        m=M, rho=0.5, k0=4, eps_dp=0.1, sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(SEED), jnp.zeros(N), cfg)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=make_profiles(M, seed=PROFILE_SEED),
                 sim=SimConfig(policy="sync", seed=SEED, faults=faults,
                               privacy=privacy))
    objective, t_total, w_head = [], [], []
    for _ in range(ROUNDS):
        m = sim.step()
        objective.append(
            float(fedepm.global_objective(loss, sim.state.w_tau, batches)))
        t_total.append(m.t_total)
        w_head.append(np.asarray(sim.state.w_tau)[:HEAD].copy())
    return {
        "objective": np.asarray(objective, np.float64),
        "t_total": np.asarray(t_total, np.float64),
        "w_tau_head": np.stack(w_head),
        "key_final": np.asarray(sim.state.key),
        "k_final": np.asarray(int(sim.state.k)),
    }


# frozen async scenario (golden_async_trajectory.npz)
ASYNC_ROUNDS = 4      # aggregation events
ASYNC_CHUNK = 2       # scan engine replays the run as 2 chunks


def simulate_golden_async(engine: str = "eager", faults=None,
                          privacy=None) -> dict[str, np.ndarray]:
    """Run the frozen async scenario -> trajectory arrays.

    ``engine`` is "eager" (per-event loop) or "scan" (record/replay in
    ASYNC_CHUNK-event chunks); both must reproduce the SAME stored
    arrays bit-for-bit (tests/test_sim_invariants.py). ``faults`` and
    ``privacy`` exist for the inert-config regression pins (see
    ``simulate_golden``).
    """
    X, y = synth.adult_like(d=D, n=N, seed=SEED)
    batches = jax.tree_util.tree_map(
        jnp.asarray, partition_iid(X, y, m=M, seed=SEED))
    loss = make_logistic_loss()
    cfg = fedepm.FedEPMConfig.paper_defaults(
        m=M, rho=0.5, k0=4, eps_dp=0.1, sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(SEED), jnp.zeros(N), cfg)
    sim = FedSim(
        alg="fedepm", cfg=cfg, state=s0, batches=batches, loss_fn=loss,
        profiles=LatencyTrace.load(TRACE_CSV).sample_profiles(
            M, seed=PROFILE_SEED),
        sim=SimConfig(policy="async", latency="pareto", latency_alpha=1.3,
                      seed=SEED, buffer_size=3, max_concurrency=4,
                      codec=CodecConfig(topk_frac=0.5, bits=8,
                                        error_feedback=True),
                      faults=faults, privacy=privacy))
    objective, t_total, w_head = [], [], []

    def observe(m):
        objective.append(
            float(fedepm.global_objective(loss, sim.state.w_tau, batches)))
        t_total.append(m.t_total)
        w_head.append(np.asarray(sim.state.w_tau)[:HEAD].copy())

    if engine == "eager":
        for _ in range(ASYNC_ROUNDS):
            observe(sim.step())
    else:
        from repro.sim.engine import run_rounds
        done = 0
        while done < ASYNC_ROUNDS:
            todo = min(ASYNC_CHUNK, ASYNC_ROUNDS - done)
            res = run_rounds(sim, todo, collect_w_tau=True)
            for m, w in zip(res.metrics, res.w_tau):
                w = jnp.asarray(w)
                objective.append(
                    float(fedepm.global_objective(loss, w, batches)))
                t_total.append(m.t_total)
                w_head.append(np.asarray(w)[:HEAD].copy())
            done += todo
    return {
        "objective": np.asarray(objective, np.float64),
        "t_total": np.asarray(t_total, np.float64),
        "w_tau_head": np.stack(w_head),
        "key_final": np.asarray(sim.state.key),
        "k_final": np.asarray(int(sim.state.k)),
        "ledger_up": np.asarray(sim.ledger.total_up, np.float64),
        "ledger_down": np.asarray(sim.ledger.total_down, np.float64),
    }


def main() -> int:
    arrays = simulate_golden()
    OUT.parent.mkdir(parents=True, exist_ok=True)
    np.savez(OUT, **arrays)
    print(f"wrote {OUT.relative_to(ROOT)}")
    for k, v in arrays.items():
        print(f"  {k:12s} shape={np.shape(v)} "
              f"{np.asarray(v).ravel()[:4]}")
    arrays = simulate_golden_async()
    np.savez(OUT_ASYNC, **arrays)
    print(f"wrote {OUT_ASYNC.relative_to(ROOT)}")
    for k, v in arrays.items():
        print(f"  {k:12s} shape={np.shape(v)} "
              f"{np.asarray(v).ravel()[:4]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
