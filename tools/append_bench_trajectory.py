#!/usr/bin/env python3
"""Append one BENCH_engine.json result as a row in BENCH_trajectory.json.

BENCH_trajectory.json is the committed per-PR benchmark history: each CI
benchmark run appends (or, for a re-run of the same label, replaces) one
flat row distilled from that run's BENCH_engine.json, so engine-speed
regressions show up as a diff in review instead of silently drifting.

Schema:

  {"schema": 1,
   "rows": [{"label": "pr6", "backend": "cpu", "d": 2000, "m": 16,
             "rounds": 120,
             "eager_rounds_per_sec": ..., "scan_rounds_per_sec": ...,
             "speedup_rounds_per_sec": ..., "speedup_wall_to_target": ...,
             "eager_wall_to_target_s": ..., "scan_wall_to_target_s": ...,
             "rounds_to_target": ..., "target_objective": ...,
             "async_eager_rounds_per_sec": ...,
             "async_scan_rounds_per_sec": ...,
             "async_speedup_rounds_per_sec": ...}, ...]}

The async_* fields mirror the summary's ``"async"`` block (the
record/replay scan engine vs the eager event loop) and are omitted from
rows distilled from pre-async BENCH_engine.json files, so old history
rows stay valid.

``--fig9-json`` (optional) merges the privacy-frontier distillation from
a ``benchmarks/fig9_privacy.py --json`` row list into the same labeled
row: the three claim checks as booleans
(``fig9_snr_increases_with_eps``, ``fig9_cr_stable_in_eps``,
``fig9_fedepm_smallest_snr`` -- ANDed over algorithms where both report)
plus ``fig9_secure_agg_mask_bytes`` (FedEPM secure-agg cell mask bytes).
Rows written before fig9 existed simply lack the fields, like the
async_* block.

Rows are keyed by ``label`` (CI passes the PR/branch name); re-running a
label replaces its row in place, keeping the file one-row-per-PR.

Usage:
  python tools/append_bench_trajectory.py \
      --engine-json BENCH_engine.json --out BENCH_trajectory.json \
      --label pr6

Stdlib-only (runs in the CI docs/bench jobs without the package
installed).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

SCHEMA = 1


def row_from_engine(summary: dict, label: str) -> dict:
    """Distill one BENCH_engine.json summary into a trajectory row."""
    cfg = summary["config"]
    eager, scan = summary["engines"]["eager"], summary["engines"]["scan"]
    row = {
        "label": label,
        "backend": cfg["backend"],
        "d": cfg["d"], "m": cfg["m"], "rounds": cfg["rounds"],
        "eager_rounds_per_sec": eager["rounds_per_sec"],
        "scan_rounds_per_sec": scan["rounds_per_sec"],
        "speedup_rounds_per_sec": summary["speedup_rounds_per_sec"],
        "speedup_wall_to_target": summary["speedup_wall_to_target"],
        "eager_wall_to_target_s": eager["wall_to_target_s"],
        "scan_wall_to_target_s": scan["wall_to_target_s"],
        "rounds_to_target": scan["rounds_to_target"],
        "target_objective": summary["target_objective"],
    }
    if "async" in summary:
        a = summary["async"]
        row.update({
            "async_eager_rounds_per_sec":
                a["engines"]["eager"]["rounds_per_sec"],
            "async_scan_rounds_per_sec":
                a["engines"]["scan"]["rounds_per_sec"],
            "async_speedup_rounds_per_sec": a["speedup_rounds_per_sec"],
        })
    return row


def fields_from_fig9(rows: list) -> dict:
    """Distill fig9_privacy.py --json rows into trajectory row fields.

    fig9 rows are ``{"name", "value", "derived"}`` where claim rows
    carry a stringified bool in ``derived``; per-algorithm claims are
    ANDed so the trajectory records one verdict per claim.
    """
    by_name = {r["name"]: r for r in rows}

    def claim(suffix: str) -> bool:
        hits = [r["derived"] == "True" for n, r in by_name.items()
                if n.endswith(suffix)]
        if not hits:
            raise SystemExit(f"fig9 json has no '*{suffix}' claim row")
        return all(hits)

    fields = {
        "fig9_snr_increases_with_eps": claim("/snr_increases_with_eps"),
        "fig9_cr_stable_in_eps": claim("/cr_stable_in_eps"),
        "fig9_fedepm_smallest_snr": claim("fedepm_smallest_SNR"),
    }
    mask = by_name.get("fig9/fedepm/secure_agg/mask_overhead")
    if mask is not None:
        fields["fig9_secure_agg_mask_bytes"] = mask["value"]
    return fields


def append(engine_json: Path, out: Path, label: str,
           fig9_json: Path | None = None) -> dict:
    """Load, append/replace the labeled row, write back. Returns the doc.

    A re-run of an existing label replaces its row IN PLACE (the file
    stays ordered by first appearance, so the diff under review is the
    changed numbers, not a moved row), and a replacement that DROPS
    fields the old row had (e.g. the async_* block after a summary
    regression) warns on stderr -- a shrinking row usually means the
    benchmark silently lost a section.
    """
    summary = json.loads(engine_json.read_text())
    if out.exists():
        doc = json.loads(out.read_text())
        if doc.get("schema") != SCHEMA:
            raise SystemExit(f"{out}: unknown schema {doc.get('schema')!r} "
                             f"(this tool writes schema {SCHEMA})")
    else:
        doc = {"schema": SCHEMA, "rows": []}
    row = row_from_engine(summary, label)
    if fig9_json is not None:
        row.update(fields_from_fig9(json.loads(fig9_json.read_text())))
    rows = doc["rows"]
    at = next((i for i, r in enumerate(rows)
               if r.get("label") == label), None)
    if at is None:
        rows.append(row)
    else:
        dropped = sorted(set(rows[at]) - set(row))
        if dropped:
            print(f"warning: {out}: row {label!r} loses field(s) "
                  f"{', '.join(dropped)} -- the new BENCH_engine.json is "
                  f"missing section(s) the previous run had",
                  file=sys.stderr)
        rows[at] = row
    out.write_text(json.dumps(doc, indent=1) + "\n")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="append a BENCH_engine.json run to the committed "
                    "benchmark trajectory")
    ap.add_argument("--engine-json", required=True, type=Path,
                    help="BENCH_engine.json produced by "
                         "benchmarks/bench_engine.py --json")
    ap.add_argument("--out", required=True, type=Path,
                    help="trajectory file to append to (created if missing)")
    ap.add_argument("--label", required=True,
                    help="row key, e.g. the PR number or branch name; "
                         "re-running a label replaces its row")
    ap.add_argument("--fig9-json", type=Path, default=None,
                    help="optional fig9_privacy.json row list; merges the "
                         "privacy claim checks + secure-agg mask bytes "
                         "into the same labeled row")
    args = ap.parse_args(argv)
    doc = append(args.engine_json, args.out, args.label,
                 fig9_json=args.fig9_json)
    print(f"{args.out}: {len(doc['rows'])} row(s); "
          f"latest label={args.label}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
