#!/usr/bin/env python3
"""Docs hygiene checker (stdlib-only; CI `docs` job, also runnable locally).

Three checks, all hard failures:

1. LINKS    -- every relative markdown link in README.md and docs/*.md
               resolves to an existing file (anchors stripped; http(s) and
               mailto links are out of scope).
2. DOCSTRINGS -- every Python module under src/repro/sim,
               src/repro/kernels, src/repro/spec, src/repro/telemetry and
               src/repro/privacy has a module docstring (the
               reference-doc entry points of the repo must be
               self-describing).
3. PAPER MAP -- docs/paper_map.md mentions every paper reference the code
               makes: explicit "eq. (N)" citations, "Algorithm N",
               "Lemma/Setup/Remark/Theorem X.Y", and every
               benchmarks/fig*/table* module.

Usage: python tools/check_docs.py  (from the repo root; exit 1 on failure)
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# explicit equation citations: "eq. (22)", "eqs. (35)/(36)", "Eq (19)"
EQ_RE = re.compile(r"[Ee]qs?\.?\s*\((\d+)\)((?:\s*/\s*\(\d+\))*)")
EQ_TAIL_RE = re.compile(r"\((\d+)\)")
ALG_RE = re.compile(r"Algorithm\s+(\d+)")
NAMED_RE = re.compile(r"(Lemma|Setup|Remark|Theorem)\s+([IVX]+\.\d+)")
BENCH_RE = re.compile(r"(fig\d+|table\d+)_\w+\.py$")


def check_links() -> list[str]:
    errors = []
    md_files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    for md in md_files:
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (md.parent / target.split("#")[0]).resolve()
            if not path.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_docstrings() -> list[str]:
    errors = []
    for pkg in ("src/repro/sim", "src/repro/kernels", "src/repro/spec",
                "src/repro/telemetry", "src/repro/privacy"):
        for py in sorted((ROOT / pkg).rglob("*.py")):
            tree = ast.parse(py.read_text())
            if ast.get_docstring(tree) is None:
                errors.append(f"{py.relative_to(ROOT)}: missing module "
                              f"docstring")
    return errors


def _code_refs() -> dict[str, set[str]]:
    """Paper references made anywhere in src/, benchmarks/ or tests/."""
    eqs: set[str] = set()
    algs: set[str] = set()
    named: set[str] = set()
    for scope in ("src", "benchmarks", "tests"):
        for py in sorted((ROOT / scope).rglob("*.py")):
            text = py.read_text()
            for m in EQ_RE.finditer(text):
                eqs.add(m.group(1))
                eqs.update(EQ_TAIL_RE.findall(m.group(2)))
            algs.update(ALG_RE.findall(text))
            named.update(f"{kind} {num}"
                         for kind, num in NAMED_RE.findall(text))
    benches = {m.group(1) for p in (ROOT / "benchmarks").glob("*.py")
               if (m := BENCH_RE.search(p.name))}
    return {"eq": eqs, "alg": algs, "named": named, "bench": benches}


def check_paper_map() -> list[str]:
    pm = ROOT / "docs" / "paper_map.md"
    if not pm.exists():
        return ["docs/paper_map.md is missing"]
    text = pm.read_text()
    refs = _code_refs()
    errors = []
    for n in sorted(refs["eq"], key=int):
        if f"({n})" not in text:
            errors.append(f"paper_map.md: equation ({n}) referenced in "
                          f"code but not documented")
    for n in sorted(refs["alg"], key=int):
        if f"Algorithm {n}" not in text:
            errors.append(f"paper_map.md: Algorithm {n} referenced in "
                          f"code but not documented")
    for name in sorted(refs["named"]):
        if name not in text:
            errors.append(f"paper_map.md: {name} referenced in code but "
                          f"not documented")
    for bench in sorted(refs["bench"]):
        # "fig7" must appear as Fig. 7 (or fig7_... link) in the map
        human = re.sub(r"(fig|table)(\d+)", r"\1. \2", bench).capitalize()
        if bench not in text and human not in text:
            errors.append(f"paper_map.md: benchmark {bench} has no entry")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings() + check_paper_map()
    for e in errors:
        print(f"FAIL: {e}")
    if errors:
        print(f"\n{len(errors)} docs check(s) failed")
        return 1
    print("docs checks OK: links resolve, modules documented, paper_map "
          "covers all code references")
    return 0


if __name__ == "__main__":
    sys.exit(main())
