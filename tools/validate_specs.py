#!/usr/bin/env python3
"""Validate + round-trip every bundled experiment spec (CI `spec` job).

For each ``examples/specs/*.toml``: load (strict parse + full validation),
re-dump to TOML and JSON in a scratch dir, reload both, and require
dataclass equality with the original plus byte-identical TOML re-dump
(dump∘load idempotence). Exit 1 listing every failing file.

A file carrying a ``[sweep]`` table (the multi-cell driver's grid files,
repro.spec.load_sweep) instead validates base + every expanded cell; the
byte round-trip is skipped there because the ``[sweep]`` table is not
part of the spec dataclass.

Usage: PYTHONPATH=src python tools/validate_specs.py
"""
from __future__ import annotations

import pathlib
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent
SPECS = ROOT / "examples" / "specs"


def main() -> int:
    from repro.spec import ExperimentSpec, SpecError, load_sweep
    from repro.spec.serialize import read_spec_file

    files = sorted(SPECS.glob("*.toml"))
    if not files:
        print(f"FAIL: no bundled specs under {SPECS}")
        return 1
    errors = []
    with tempfile.TemporaryDirectory() as td:
        scratch = pathlib.Path(td)
        for f in files:
            try:
                if "sweep" in dict(read_spec_file(f)):
                    base, cells = load_sweep(f)
                    print(f"ok: {f.relative_to(ROOT)} ({base.name}, "
                          f"{len(cells)}-cell sweep)")
                    continue
                spec = ExperimentSpec.load(f)
                toml_copy = scratch / f.name
                spec.dump(toml_copy)
                if ExperimentSpec.load(toml_copy) != spec:
                    raise SpecError("TOML round-trip changed the spec")
                spec.dump(scratch / ("rt_" + f.name))
                if (scratch / ("rt_" + f.name)).read_text() \
                        != toml_copy.read_text():
                    raise SpecError("TOML re-dump is not idempotent")
                json_copy = scratch / (f.stem + ".json")
                spec.dump(json_copy)
                if ExperimentSpec.load(json_copy) != spec:
                    raise SpecError("JSON round-trip changed the spec")
            except SpecError as e:
                errors.append(f"{f.relative_to(ROOT)}: {e}")
            else:
                print(f"ok: {f.relative_to(ROOT)} ({spec.name})")
    if errors:
        print(f"\n{len(errors)} spec(s) FAILED:")
        for e in errors:
            print(" ", e)
        return 1
    print(f"\nall {len(files)} bundled specs validate + round-trip")
    return 0


if __name__ == "__main__":
    sys.exit(main())
