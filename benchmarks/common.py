"""Shared harness for the paper-reproduction benchmarks.

Runs FedEPM / SFedAvg / SFedProx on the (synthetic) Adult-income logistic
regression task to the paper's stopping rule and reports the paper's five
factors: (f(w)/m, CR, TCT, LCT, SNR). See Sec. VII.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_logreg import termination_reached
from repro.core import baselines, fedepm
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid

_CACHE: dict = {}


def get_task(m: int, d: int = 45222, n: int = 14, seed: int = 0):
    key = (m, d, n, seed)
    if key not in _CACHE:
        X, y = synth.adult_like(d=d, n=n, seed=seed)
        batches = jax.tree_util.tree_map(
            jnp.asarray, partition_iid(X, y, m=m, seed=seed))
        _CACHE[key] = (X, y, batches)
    return _CACHE[key]


def run_algorithm(alg: str, *, m: int, k0: int, rho: float, eps: float,
                  seed: int = 0, max_rounds: int = 400, d: int = 45222,
                  ens_impl: str = "ref"):
    """Returns dict(f, CR, TCT, LCT, SNR, rounds). One trial."""
    X, y, batches = get_task(m, d=d)
    n = X.shape[1]
    loss = make_logistic_loss()

    if alg == "fedepm":
        cfg = fedepm.FedEPMConfig.paper_defaults(
            m=m, rho=rho, k0=k0, eps_dp=eps, ens_impl=ens_impl)
        state = fedepm.init_state(jax.random.PRNGKey(seed), jnp.zeros(n),
                                  cfg)
        step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))
    else:
        cfg = baselines.BaselineConfig(m=m, k0=k0, rho=rho, eps_dp=eps)
        state = baselines.init_state(jax.random.PRNGKey(seed), jnp.zeros(n),
                                     cfg)
        rnd = baselines.sfedavg_round if alg == "sfedavg" \
            else baselines.sfedprox_round
        step = jax.jit(lambda s: rnd(s, batches, loss, cfg))

    fobj = jax.jit(lambda w: fedepm.global_objective(loss, w, batches))
    gsq = jax.jit(lambda w: fedepm.global_grad_sq_norm(loss, w, batches))

    # warm up compile outside the timed region
    state_w, _ = step(state)
    jax.block_until_ready(state_w.w_tau)

    f_hist = []
    snr_last = np.inf
    snr_fixed = np.inf       # SNR at a FIXED round (20): isolates the
    t0 = time.perf_counter()  # eps -> noise effect from termination time
    rounds = 0
    for r in range(max_rounds):
        state, metrics = step(state)
        rounds += 1
        f_hist.append(float(fobj(state.w_tau)))
        snr = float(metrics.snr)
        if np.isfinite(snr):
            snr_last = snr
            if r <= 20:
                snr_fixed = snr
        if termination_reached(f_hist, float(gsq(state.w_tau)), n):
            break
    jax.block_until_ready(state.w_tau)
    tct = time.perf_counter() - t0

    lct = measure_lct(alg, m=m, k0=k0, rho=rho, eps=eps, d=d, seed=seed)
    return {"alg": alg, "m": m, "k0": k0, "rho": rho, "eps": eps,
            "f": f_hist[-1] / m, "CR": rounds, "TCT": tct, "LCT": lct,
            "SNR": snr_last, "SNR20": snr_fixed, "f_hist": f_hist}


def measure_lct(alg: str, *, m: int, k0: int, rho: float, eps: float,
                d: int = 45222, seed: int = 0, reps: int = 5) -> float:
    """Local computation time: what ONE client computes between two
    communications (k0 inner iterations), excluding aggregation/transport.
    FedEPM: one gradient + k0 closed-form prox steps; SFedAvg: k0 gradient
    steps; SFedProx: k0 * ell proximal GD steps (Alg. 4)."""
    X, y, batches = get_task(m, d=d)
    n = X.shape[1]
    loss = make_logistic_loss()
    b0 = jax.tree_util.tree_map(lambda x: x[0], batches)
    w = jnp.zeros(n)
    grad = jax.grad(loss)

    if alg == "fedepm":
        cfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=rho, k0=k0,
                                                 eps_dp=eps)

        def local(w_tau, wi):
            g = grad(w_tau, b0)
            wi, mu = fedepm._client_inner(wi, w_tau, g, jnp.asarray(0), cfg)
            return wi
    elif alg == "sfedavg":
        def local(w_tau, wi):
            def stp(wc, t):
                gamma = 2.0 / jnp.sqrt(2.0 * k0 + 1.0)
                base = jnp.where(t == 0, w_tau, wc)
                return base - gamma * grad(base, b0), None
            wi, _ = jax.lax.scan(stp, wi, jnp.arange(k0))
            return wi
    else:
        def local(w_tau, wi):
            def outer(wc, t):
                v = jnp.where(t == 0, w_tau, wc)

                def inner(vt, _):
                    gamma = 2.0 / jnp.sqrt(2.0 * k0 + 1.0)
                    return vt - gamma * (grad(vt, b0)
                                         + 1e-5 * (vt - w_tau)), None

                v, _ = jax.lax.scan(inner, v, jnp.arange(3))
                return v, None
            wi, _ = jax.lax.scan(outer, wi, jnp.arange(k0))
            return wi

    jlocal = jax.jit(local)
    out = jlocal(w, w)
    jax.block_until_ready(out)
    times = []
    for _ in range(max(reps, 10)):
        t0 = time.perf_counter()
        out = jlocal(w, w)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))  # robust to scheduler jitter


def average_trials(alg, trials=3, **kw):
    runs = [run_algorithm(alg, seed=s, **kw) for s in range(trials)]
    out = dict(runs[0])
    for k in ("f", "CR", "TCT", "LCT", "SNR"):
        out[k] = float(np.mean([r[k] for r in runs]))
    out.pop("f_hist", None)
    return out
