"""Fig. 4 reproduction: effect of the participation fraction rho.
Claims: CR slightly decreases and TCT increases with rho; FedEPM has the
lowest CR/TCT medians."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_algorithm


def run(m=50, k0=12, eps=0.1, rho_grid=(0.2, 0.6, 1.0), trials=3, d=45222):
    rows = []
    med = {}
    for alg in ("fedepm", "sfedavg", "sfedprox"):
        for rho in rho_grid:
            crs, tcts = [], []
            for s in range(trials):
                r = run_algorithm(alg, m=m, k0=k0, rho=rho, eps=eps,
                                  seed=s, d=d)
                crs.append(r["CR"])
                tcts.append(r["TCT"])
            med[(alg, rho)] = (float(np.median(crs)), float(np.median(tcts)))
            rows.append((f"fig4/{alg}/rho={rho}",
                         float(np.median(tcts)) * 1e6,
                         f"CR_med={np.median(crs)},TCT_med="
                         f"{np.median(tcts):.3f}s"))
    best = all(med[("fedepm", r)][0] <= min(med[("sfedavg", r)][0],
                                            med[("sfedprox", r)][0]) * 1.5
               for r in rho_grid)
    rows.append(("fig4/fedepm_lowest_CR", 0.0, str(best)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
