"""Engine benchmark: eager per-round dispatch vs the fused scan engine.

Measures, on the paper logreg task (sync policy, CPU unless the host has an
accelerator):

  * rounds/sec of the eager driver (one jit dispatch + host round-trip per
    round) vs ``repro.sim.engine.run_rounds`` (K rounds in one donated
    ``lax.scan``), post-compile;
  * wall-clock to a fixed objective: the objective the eager sync run ends
    at after the round budget, then each engine races a fresh sim to it
    (the trajectories are bit-identical, so both need the same number of
    rounds -- the gap is pure dispatch overhead);
  * host-sync counts (device->host transfers) per engine, the quantity the
    scan engine exists to remove: eager pays ~2/round, scan ~2/chunk.

Emits CSV rows for benchmarks/run.py and --json writes BENCH_engine.json:

  {"config": {...},
   "engines": {"eager": {"rounds_per_sec", "wall_to_target_s",
                         "rounds_to_target", "host_syncs",
                         "host_syncs_per_round"},
               "scan": {...}},
   "speedup_rounds_per_sec": ..., "speedup_wall_to_target": ...,
   "target_objective": ...,
   "async": {"config": {...},
             "engines": {"eager": {"rounds_per_sec", "host_syncs",
                                   "host_syncs_per_round"},
                         "scan": {...}},
             "speedup_rounds_per_sec": ...}}

The async cell times the SAME event-loop semantics under both engines
(concurrency-capped buffered aggregation, Pareto stragglers): eager pays
per-event jit dispatches, the scan engine records each chunk's event loop
on the host and replays it as one compiled scan (docs/perf.md). CI gates
its speedup at >= 2x (the recording pass bounds it below the sync cell's
factor).

The speedup is dispatch-bound: on the reduced task (--quick / default) the
round math is microseconds and scan wins by the dispatch factor; at the
paper's full d=45222 (--full) rounds are compute-bound and the gap narrows
toward 1 -- both regimes are the point (docs/perf.md).

Each scenario is ONE declarative spec cell (repro.spec); each timed arm
builds a fresh sim from it through the same ``spec.build()`` path the
CLI uses. The two cells (sync, async) execute through the multi-cell
sweep driver (repro.launch.sweep_run) under :func:`run_bench_cell` --
sequentially by default, because the arms time wall-clock and would
contend if run concurrently; ``--sweep-dir`` persists the per-cell
results (resumable) and writes the merged artifact there.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time

import jax
import numpy as np

from repro import spec as xspec
from repro.core import fedepm
from repro.sim import run_rounds, run_to_objective
from repro.spec.build import task_data

QUICK_KW = dict(d=2000, m=16, k0=4, rounds=120, repeats=3)

BENCH_RUNNER = "benchmarks.bench_engine:run_bench_cell"


def _cells(d: int = 4000, m: int = 50, k0: int = 8, rho: float = 0.5,
           n: int = 14, rounds: int = 60, seed: int = 0):
    """The two benchmark scenarios as declarative spec cells.

    ONE cell describes each scenario; the timed arms build fresh sims
    from it (the spec layer's task memo keeps the batches device-resident
    and the jit caches warm across builds, so the timed regions measure
    dispatch, not re-tracing)."""
    task = xspec.TaskSpec(kind="logreg", d=d, n=n, m=m)
    alg = xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0, eps_dp=0.0)
    engine = xspec.EngineSpec(name="eager", rounds=rounds)
    sync_cell = xspec.ExperimentSpec(
        name="bench-engine", seed=seed, task=task, algorithm=alg,
        fleet=xspec.FleetSpec(kind="uniform"),
        policy=xspec.PolicySpec(name="sync"),
        engine=engine).validate()
    async_cell = xspec.ExperimentSpec(
        name="bench-engine/async", seed=seed, task=task, algorithm=alg,
        fleet=xspec.FleetSpec(kind="synthetic", availability=0.9,
                              latency="pareto", latency_alpha=1.3),
        policy=xspec.PolicySpec(name="async", buffer_size=4,
                                max_concurrency=6),
        engine=engine).validate()
    return sync_cell, async_cell


def run_bench_cell(spec, ctx) -> dict:
    """Sweep-driver runner: time one benchmark cell (sync or async arm).

    ``ctx["repeats"]`` sets the median-of-N repeat count; the arm is
    picked off ``spec.policy.name``."""
    repeats = int(ctx.get("repeats", 3))
    if spec.policy.name == "async":
        return _bench_async(spec, repeats)
    return _bench_sync(spec, repeats)


def bench(d: int = 4000, m: int = 50, k0: int = 8, rho: float = 0.5,
          n: int = 14, rounds: int = 60, repeats: int = 3,
          seed: int = 0) -> dict:
    return _bench_sync(_cells(d=d, m=m, k0=k0, rho=rho, n=n,
                              rounds=rounds, seed=seed)[0], repeats)


def _bench_sync(cell, repeats: int) -> dict:
    t, alg = cell.task, cell.algorithm
    d, m, n, rounds = t.d, t.m, t.n, cell.engine.rounds
    data = task_data(cell)
    loss, batches = data.loss_fn, data.batches
    mk = lambda: cell.build().sim  # noqa: E731
    fobj = jax.jit(lambda w: fedepm.global_objective(loss, w, batches))

    # -- warmup: compile both engines' programs outside the timed region --
    # batched per-chunk objective for the scan race: same loss/batches,
    # vmapped over the chunk's stacked broadcast points (can differ from
    # the scalar fobj by 1 ulp at the target boundary -- the smoke test
    # allows +-1 round)
    fobj_chunk = jax.jit(lambda W: jax.vmap(
        lambda wt: fedepm.global_objective(loss, wt, batches))(W) / m)

    w = mk()
    w.run(2)
    float(fobj(w.state.w_tau))
    run_rounds(mk(), rounds)                      # chunk of `rounds`
    s = mk()
    res = run_rounds(s, min(16, rounds), collect_w_tau=True)  # race chunks
    np.asarray(fobj_chunk(np.asarray(res.w_tau)))

    # -- rounds/sec, median over repeats ----------------------------------
    def timed_eager():
        sim = mk()
        sim.host_syncs = 0
        t0 = time.perf_counter()
        sim.run(rounds)
        jax.block_until_ready(sim.state.w_tau)
        return time.perf_counter() - t0, sim.host_syncs

    def timed_scan():
        sim = mk()
        sim.host_syncs = 0
        t0 = time.perf_counter()
        run_rounds(sim, rounds)
        jax.block_until_ready(sim.state.w_tau)
        return time.perf_counter() - t0, sim.host_syncs

    eager_t, eager_syncs = zip(*(timed_eager() for _ in range(repeats)))
    scan_t, scan_syncs = zip(*(timed_scan() for _ in range(repeats)))
    eager_rps = rounds / statistics.median(eager_t)
    scan_rps = rounds / statistics.median(scan_t)

    # -- wall-clock to a fixed objective ----------------------------------
    # target: where the sync trajectory lands after the budget. Both
    # engines run the SAME trajectory bit-for-bit, so they hit it after
    # the same number of rounds; the wall-clock gap is dispatch overhead.
    ref = mk()
    ref.run(rounds)
    target = float(fobj(ref.state.w_tau)) / m

    sim = mk()
    t0 = time.perf_counter()
    er = 0
    f = float("inf")
    while f > target and er < 2 * rounds:
        sim.step()
        er += 1
        f = float(fobj(sim.state.w_tau)) / m
    eager_wall = time.perf_counter() - t0

    sim = mk()
    t0 = time.perf_counter()
    sr, hit, _ = run_to_objective(sim, fobj_chunk, target,
                                  max_rounds=2 * rounds, chunk=16)
    scan_wall = time.perf_counter() - t0
    assert hit and f <= target, "both engines must reach the target"

    def eng(rps, wall, rtt, syncs):
        return {"rounds_per_sec": rps, "wall_to_target_s": wall,
                "rounds_to_target": rtt,
                "host_syncs": int(statistics.median(syncs)),
                "host_syncs_per_round":
                    statistics.median(syncs) / rounds}

    return {
        "config": {"task": "paper_logreg", "policy": "sync", "d": d, "m": m,
                   "k0": alg.k0, "rho": alg.rho, "n": n, "rounds": rounds,
                   "repeats": repeats, "seed": cell.seed,
                   "backend": jax.default_backend()},
        "engines": {"eager": eng(eager_rps, eager_wall, er, eager_syncs),
                    "scan": eng(scan_rps, scan_wall, sr, scan_syncs)},
        "speedup_rounds_per_sec": scan_rps / eager_rps,
        "speedup_wall_to_target": eager_wall / scan_wall,
        "target_objective": target,
    }


def bench_async(d: int = 4000, m: int = 50, k0: int = 8, rho: float = 0.5,
                n: int = 14, rounds: int = 60, repeats: int = 3,
                seed: int = 0) -> dict:
    return _bench_async(_cells(d=d, m=m, k0=k0, rho=rho, n=n,
                               rounds=rounds, seed=seed)[1], repeats)


def _bench_async(cell, repeats: int) -> dict:
    """The async cell: eager event loop vs record/replay scan engine.

    Same declarative-cell discipline as the sync arm; no objective race
    (the trajectories are bit-identical -- tests/test_engine_async.py --
    so rounds/sec is the whole story)."""
    t, alg = cell.task, cell.algorithm
    rounds = cell.engine.rounds
    mk = lambda: cell.build().sim  # noqa: E731

    mk().run(2)                                   # warm the eager programs
    run_rounds(mk(), rounds)                      # compile the replay scan

    def timed(drive):
        sim = mk()
        sim.host_syncs = 0
        t0 = time.perf_counter()
        drive(sim)
        jax.block_until_ready(sim.state.w_tau)
        return time.perf_counter() - t0, sim.host_syncs

    eager_t, eager_syncs = zip(*(timed(lambda s: s.run(rounds))
                                 for _ in range(repeats)))
    scan_t, scan_syncs = zip(*(timed(lambda s: run_rounds(s, rounds))
                               for _ in range(repeats)))
    eager_rps = rounds / statistics.median(eager_t)
    scan_rps = rounds / statistics.median(scan_t)

    def eng(rps, syncs):
        return {"rounds_per_sec": rps,
                "host_syncs": int(statistics.median(syncs)),
                "host_syncs_per_round":
                    statistics.median(syncs) / rounds}

    return {
        "config": {"task": "paper_logreg", "policy": "async", "d": t.d,
                   "m": t.m, "k0": alg.k0, "rho": alg.rho, "n": t.n,
                   "rounds": rounds,
                   "buffer_size": cell.policy.buffer_size,
                   "max_concurrency": cell.policy.max_concurrency,
                   "repeats": repeats, "seed": cell.seed,
                   "backend": jax.default_backend()},
        "engines": {"eager": eng(eager_rps, eager_syncs),
                    "scan": eng(scan_rps, scan_syncs)},
        "speedup_rounds_per_sec": scan_rps / eager_rps,
    }


def rows_from(summary: dict) -> list:
    rows = []
    for name, e in summary["engines"].items():
        rows.append((f"engine/{name}/rounds_per_sec", e["rounds_per_sec"],
                     f"host_syncs_per_round={e['host_syncs_per_round']:.3f}"))
        rows.append((f"engine/{name}/wall_to_target_s",
                     e["wall_to_target_s"],
                     f"rounds_to_target={e['rounds_to_target']};"
                     f"f_target={summary['target_objective']:.6f}"))
    rows.append(("engine/speedup_rounds_per_sec",
                 summary["speedup_rounds_per_sec"],
                 f"backend={summary['config']['backend']};"
                 f"d={summary['config']['d']};m={summary['config']['m']}"))
    rows.append(("engine/speedup_wall_to_target",
                 summary["speedup_wall_to_target"], ""))
    if "async" in summary:
        a = summary["async"]
        for name, e in a["engines"].items():
            rows.append((f"engine/async/{name}/rounds_per_sec",
                         e["rounds_per_sec"],
                         "host_syncs_per_round="
                         f"{e['host_syncs_per_round']:.3f}"))
        rows.append(("engine/async/speedup_rounds_per_sec",
                     a["speedup_rounds_per_sec"],
                     f"buffer_size={a['config']['buffer_size']};"
                     f"max_concurrency={a['config']['max_concurrency']}"))
    return rows


def summarize(*, repeats: int = 3, jobs: int = 1, sweep_dir=None,
              **kw) -> dict:
    """Run both arms through the sweep driver -> BENCH_engine.json dict.

    Each arm executes as one driver cell under :func:`run_bench_cell`
    (atomic per-cell result file; a ``sweep_dir`` makes a killed run
    resumable and writes ``merged.json`` there). ``jobs`` defaults to 1:
    the arms are wall-clock timings, and running them concurrently would
    contend for the CPU they measure.
    """
    from repro.launch.sweep_run import execute_cells, write_merged
    cells = list(_cells(**kw))
    import pathlib
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        out_dir = sweep_dir if sweep_dir is not None else tmp
        res = execute_cells(cells, out_dir=out_dir, jobs=jobs,
                            runner=BENCH_RUNNER,
                            ctx={"repeats": int(repeats)})
        if not res.ok:
            bad = res.failed or res.pending
            raise RuntimeError(
                f"bench-engine sweep incomplete: failed={res.failed} "
                f"pending={res.pending} (first: {bad[0]})")
        if sweep_dir is not None:
            write_merged(pathlib.Path(sweep_dir) / "merged.json", cells,
                         res.records, meta={"name": "bench-engine"})
    summary = dict(res.records["bench-engine"]["summary"])
    summary["async"] = res.records["bench-engine/async"]["summary"]
    return summary


def run(**kw) -> list:
    """benchmarks/run.py entry point: CSV rows."""
    return rows_from(summarize(**kw))


def export_trace(trace_out, *, jax_profile_dir=None, policy: str = "sync",
                 d: int = 4000, m: int = 50, k0: int = 8, rho: float = 0.5,
                 n: int = 14, rounds: int = 60, seed: int = 0,
                 **_ignored) -> dict:
    """Run a benchmark scan cell with telemetry and export the timeline.

    One scan-engine run of the benchmark scenario with the event recorder
    attached: the simulated timeline goes to ``trace_out`` (Perfetto
    trace_event JSON), and ``jax_profile_dir`` additionally wraps the run
    in ``jax.profiler`` for a REAL wall-time trace of the fused scan --
    the artifact to look at when the speedup number regresses.
    ``policy="async"`` exports the async cell instead: per-client
    dispatch/arrival/merge tracks of the recorded event loop the scan
    replayed (the CI ``bench-engine-async-trace`` artifact).
    """
    if policy == "async":
        fleet = xspec.FleetSpec(kind="synthetic", availability=0.9,
                                latency="pareto", latency_alpha=1.3)
        pol = xspec.PolicySpec(name="async", buffer_size=4,
                               max_concurrency=6)
    else:
        fleet = xspec.FleetSpec(kind="uniform")
        pol = xspec.PolicySpec(name="sync")
    spec = xspec.ExperimentSpec(
        name=f"bench-engine/scan-trace-{policy}", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0),
        fleet=fleet, policy=pol,
        engine=xspec.EngineSpec(name="scan", rounds=rounds),
        telemetry=xspec.TelemetrySpec(
            enabled=True, trace_out=str(trace_out),
            jax_profiler_dir=str(jax_profile_dir) if jax_profile_dir
            else None))
    return spec.build().run()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Fused scan engine vs eager dispatch benchmark")
    ap.add_argument("--quick", action="store_true",
                    help="reduced task, short budget (CI smoke)")
    ap.add_argument("--full", action="store_true",
                    help="the paper's full d=45222 task (compute-bound)")
    ap.add_argument("--sweep-dir", default=None,
                    help="persistent sweep state dir (resumable; also "
                         "writes merged.json there)")
    ap.add_argument("--json", default=None,
                    help="write the summary dict (BENCH_engine.json schema) "
                         "to this path")
    ap.add_argument("--trace-out", default=None,
                    help="export a Perfetto trace_event JSON timeline of "
                         "one scan-engine run of the benchmark cell")
    ap.add_argument("--async-trace-out", default=None,
                    help="export the ASYNC cell's timeline: per-client "
                         "dispatch/arrival/merge tracks of the recorded "
                         "event loop the scan replayed")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="with --trace-out: wrap that run in jax.profiler "
                         "for a real wall-time trace under DIR")
    args = ap.parse_args(argv)
    kw = QUICK_KW if args.quick else (dict(d=45222) if args.full else {})
    summary = summarize(**kw, sweep_dir=args.sweep_dir)
    for r in rows_from(summary):
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
    if args.trace_out:
        export_trace(args.trace_out, jax_profile_dir=args.jax_profile, **kw)
        print(f"engine/trace_out,{args.trace_out}", file=sys.stderr)
    if args.async_trace_out:
        export_trace(args.async_trace_out, policy="async", **kw)
        print(f"engine/async_trace_out,{args.async_trace_out}",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
