"""Fig. 3 reproduction: effect of k0 on CR and TCT (m in {50, 128}).
Claim: bigger k0 => fewer communication rounds; FedEPM uses the fewest."""
from __future__ import annotations

from benchmarks.common import run_algorithm


def run(m=50, k0_grid=(4, 12, 20), rho=0.5, eps=0.1, d=45222):
    rows = []
    crs = {}
    for alg in ("fedepm", "sfedavg", "sfedprox"):
        for k0 in k0_grid:
            r = run_algorithm(alg, m=m, k0=k0, rho=rho, eps=eps, d=d)
            crs[(alg, k0)] = r["CR"]
            rows.append((f"fig3/{alg}/k0={k0}",
                         r["TCT"] * 1e6 / max(r["CR"], 1),
                         f"CR={r['CR']},TCT={r['TCT']:.3f}s"))
    for alg in ("fedepm", "sfedavg", "sfedprox"):
        mono = crs[(alg, k0_grid[-1])] <= crs[(alg, k0_grid[0])]
        rows.append((f"fig3/{alg}/k0_reduces_CR", 0.0, str(mono)))
    few = all(crs[("fedepm", k)] <= min(crs[("sfedavg", k)],
                                        crs[("sfedprox", k)]) * 1.5
              for k in k0_grid)
    rows.append(("fig3/fedepm_fewest_CR", 0.0, str(few)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
