"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows.

Full grids take tens of minutes on this CPU host; the default profile is
a reduced-but-faithful grid (documented per module). Pass --full for the
paper's complete grids, --quick for CI-speed smoke values.

The systems modules (fig6/fig7/fig8/fig9/engine) define their grids as
lists of declarative experiment specs (repro.spec, docs/spec.md) and
execute every cell through the multi-cell sweep driver
(repro.launch.sweep_run, same ``spec.build()`` path as the simulate
CLI); the kwargs this driver passes them only size the grid, ``--jobs``
parallelizes their cells uniformly across all of them. fig9 (the
upload-privacy frontier) supersedes the retired fig5 module and carries
its claim-check rows forward.

Each module runs isolated: a failure becomes a ``<name>/ERROR`` CSV row
and the remaining modules still run -- but the invocation then exits
nonzero (a broken module can never pass as a clean benchmark sweep).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small-d task, minimal grids (smoke)")
    ap.add_argument("--full", action="store_true",
                    help="the paper's complete grids (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (fig2,fig3,...)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="sweep-driver worker processes for the spec-grid "
                         "modules (fig6/fig7/fig8/fig9/engine)")
    args = ap.parse_args(argv)

    from benchmarks import (bench_engine, ens_kernel, fig2_accuracy, fig3_k0,
                            fig4_rho, fig6_stragglers, fig7_async,
                            fig8_faults, fig9_privacy, table1_lct)

    d = 4000 if args.quick else 45222
    trials = 1 if args.quick else (3 if not args.full else 10)
    k0_grid = (4, 12, 20) if not args.full else (4, 8, 12, 16, 20)

    jobs = {
        "fig2": lambda: fig2_accuracy.run(d=d),
        "fig3": lambda: fig3_k0.run(d=d, k0_grid=k0_grid),
        "table1": lambda: table1_lct.run(
            d=d, k0_grid=(4, 8, 12, 16, 20)),
        "fig4": lambda: fig4_rho.run(
            d=d, trials=trials,
            rho_grid=(0.2, 0.6, 1.0) if not args.full
            else (0.2, 0.4, 0.6, 0.8, 1.0)),
        "ens": lambda: ens_kernel.run(
            n=(1 << 12) if args.quick else (1 << 16)),
        "fig6": lambda: fig6_stragglers.run(
            d=d, m=16 if args.quick else 32,
            rounds=30 if args.quick else 80, jobs=args.jobs),
        "fig7": lambda: fig7_async.run(
            **(fig7_async.QUICK_KW if args.quick
               else dict(d=d, m=32, rounds=60)), jobs=args.jobs),
        "fig8": lambda: fig8_faults.run(
            **(fig8_faults.QUICK_KW if args.quick
               else dict(d=d, m=32, rounds=60)), jobs=args.jobs),
        "fig9": lambda: fig9_privacy.run(
            **(fig9_privacy.QUICK_KW if args.quick
               else dict(d=d, m=32, rounds=60,
                         eps_grid=fig9_privacy.EPS_GRID if not args.full
                         else (0.2, 0.5, 2.0, 8.0, 32.0))),
            jobs=args.jobs),
        "engine": lambda: bench_engine.run(
            **(bench_engine.QUICK_KW if args.quick
               else dict(d=d, m=50, rounds=60)), jobs=args.jobs),
    }
    if args.only:
        keep = set(args.only.split(","))
        jobs = {k: v for k, v in jobs.items() if k in keep}

    print("name,us_per_call,derived")
    t_all = time.time()
    failed = []
    for name, job in jobs.items():
        t0 = time.time()
        try:
            for row in job():
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:  # noqa: BLE001 - isolate, record, continue
            failed.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    print(f"# all benchmarks done in {time.time()-t_all:.1f}s",
          file=sys.stderr)
    if failed:
        # every job still ran (per-job isolation above), but a broken
        # module must fail the invocation instead of hiding in the CSV
        print(f"# {len(failed)} benchmark(s) failed: {','.join(failed)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
