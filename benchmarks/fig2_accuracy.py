"""Fig. 2 reproduction: objective f(w)/m vs communication round for the
three algorithms; all should approach the same value, FedEPM fastest."""
from __future__ import annotations

import numpy as np

from benchmarks.common import get_task, run_algorithm


def run(m=50, k0=12, rho=0.5, eps=0.1, rounds=120, d=45222):
    rows = []
    curves = {}
    for alg in ("fedepm", "sfedavg", "sfedprox"):
        r = run_algorithm(alg, m=m, k0=k0, rho=rho, eps=eps,
                          max_rounds=rounds, d=d)
        curves[alg] = r["f_hist"]
        rows.append((f"fig2/{alg}/f_final", r["TCT"] * 1e6 / max(r['CR'], 1),
                     f"f={r['f']:.5f},CR={r['CR']}"))
    # headline claims: same limit, FedEPM declines fastest
    finals = {a: c[-1] / m for a, c in curves.items()}
    spread = max(finals.values()) - min(finals.values())
    # rounds to close half the gap from f(0)=ln2 to the best final value
    # (an absolute-gap target: the paper's normalisation makes relative
    # declines tiny, so a multiplicative target is met trivially)
    f0 = 0.6931471805599453
    tgt = (min(finals.values()) + 0.5 * (f0 - min(finals.values()))) * m

    def rounds_to(c):
        for i, v in enumerate(c):
            if v <= tgt:
                return i + 1
        return len(c)

    speed = {a: rounds_to(c) for a, c in curves.items()}
    rows.append(("fig2/same_limit_spread", 0.0, f"{spread:.5f}"))
    rows.append(("fig2/rounds_to_target",
                 0.0, ";".join(f"{a}={v}" for a, v in speed.items())))
    rows.append(("fig2/fedepm_fastest", 0.0,
                 str(speed["fedepm"] <= min(speed["sfedavg"],
                                            speed["sfedprox"]))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
