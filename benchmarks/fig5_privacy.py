"""Fig. 5 reproduction: effect of the privacy budget eps.
Claims: eps barely moves CR/TCT; SNR increases with eps (less noise =>
weaker privacy); FedEPM attains the smallest SNR (strongest privacy)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_algorithm


def run(m=50, k0=12, rho=0.5, eps_grid=(0.1, 0.5, 0.9), trials=3, d=45222):
    rows = []
    snr = {}
    cr = {}
    for alg in ("fedepm", "sfedavg", "sfedprox"):
        for eps in eps_grid:
            snrs, crs = [], []
            for s in range(trials):
                r = run_algorithm(alg, m=m, k0=k0, rho=rho, eps=eps,
                                  seed=s, d=d)
                snrs.append(r["SNR20"])  # fixed-round SNR (see common.py)
                crs.append(r["CR"])
            snr[(alg, eps)] = float(np.median(snrs))
            cr[(alg, eps)] = float(np.median(crs))
            rows.append((f"fig5/{alg}/eps={eps}", 0.0,
                         f"SNR_med={np.median(snrs):.3f},"
                         f"CR_med={np.median(crs)}"))
    for alg in ("fedepm", "sfedavg", "sfedprox"):
        inc = snr[(alg, eps_grid[-1])] >= snr[(alg, eps_grid[0])]
        rows.append((f"fig5/{alg}/snr_increases_with_eps", 0.0, str(inc)))
        stable = abs(cr[(alg, eps_grid[-1])] - cr[(alg, eps_grid[0])]) \
            <= 0.5 * max(cr[(alg, eps_grid[0])], 1)
        rows.append((f"fig5/{alg}/cr_stable_in_eps", 0.0, str(stable)))
    strongest = all(snr[("fedepm", e)] <= min(snr[("sfedavg", e)],
                                              snr[("sfedprox", e)]) + 0.5
                    for e in eps_grid)
    rows.append(("fig5/fedepm_smallest_SNR", 0.0, str(strongest)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
