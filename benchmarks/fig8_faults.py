"""Fig. 8 (beyond-paper): aggregation policies under injected faults.

Races FedEPM and SFedAvg under sync, deadline (q80-calibrated cutoff) and
async-buffered aggregation across a grid of composite fault rates on the
paper logreg task with a heavy-tail (Pareto) fleet. A composite rate ``r``
maps onto the seeded fault model (repro.sim.faults, docs/sim.md) as

    drop_rate      = 0.3 r   (upload lost mid-flight, billed)
    transient_rate = 0.5 r   (server retries with backoff, each billed)
    corrupt_rate   = 0.2 r   (screened + quarantine for repeat offenders)
    duplicate_rate = 0.2 r   (delivered twice, deduped, the copy billed)

so the three attempt-outcome rates sum to ``r`` and the retry machinery
dominates the injected failures -- the regime where the byte overhead of
the defense path (retries + duplicates) is visible on the wire.

Two readouts per (algorithm, policy, rate) cell, both against the
algorithm's own FAULT-FREE sync endpoint as the objective target:

1. Objective-vs-simulated-time: the first simulated time at which the
   cell reaches the target (``NOT_REACHED`` when the budget expires
   first -- under heavy faults that plateau is the finding).
2. Bytes including retries: uplink bytes billed to the ledger, which
   under the fault model includes every failed attempt, every retry and
   every discarded duplicate -- the true wire cost of reaching (or
   failing to reach) the target, with the fault counters in the derived
   column.

Every cell is a declarative :class:`repro.spec.ExperimentSpec` with a
``[faults]`` section, and the grid executes through the multi-cell sweep
driver (repro.launch.sweep_run; parallel across ``jobs`` processes,
resumable under ``sweep_dir``) in two phases: the fault-free sync
references run first, their endpoints fix the per-algorithm targets, and
the fault-rate race cells run second under :func:`race_cell` with those
targets in the per-cell driver context.

Rows: fig8/<alg>/<policy>/r<rate>/time_to_target,<sim_s * 1e6>,<derived>
      fig8/<alg>/<policy>/r<rate>/bytes_up,<bytes>,<fault counters>

``--trace-out PATH`` additionally runs one faulted async cell with run
telemetry attached and exports the simulated timeline as a
Perfetto/Chrome ``trace_event`` JSON -- drop/retry/duplicate/quarantine
instants on the affected client's track (docs/observability.md).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

import numpy as np

from repro import spec as xspec
from repro.sim import (
    client_work_flops,
    make_latency_model,
    make_profiles,
    round_arrivals,
    tree_client_bytes,
)

# the one quick/smoke profile, shared by `--quick` and benchmarks/run.py
QUICK_KW = dict(d=2000, m=16, rounds=12, rates=(0.2,))

#: default composite fault-rate grid (0 is implicit: the phase-1 sync
#: references are fault-free and double as the r=0 row's baseline)
RATES = (0.1, 0.3)


def fault_spec(rate: float) -> xspec.FaultSpec:
    """Composite rate -> FaultSpec (see module docstring for the split)."""
    return xspec.FaultSpec(
        drop_rate=0.3 * rate, transient_rate=0.5 * rate,
        corrupt_rate=0.2 * rate, duplicate_rate=0.2 * rate)


def _calibrate_deadline(profiles, alpha, work, down_b, up_b, q: float = 0.8,
                        draws: int = 200, seed: int = 123) -> float:
    rng = np.random.default_rng(seed)
    lat = make_latency_model("pareto", alpha=alpha)
    t = np.concatenate([
        round_arrivals(profiles, rng, lat, work_flops=work,
                       down_bytes=down_b, up_bytes=up_b)
        for _ in range(draws)])
    return float(np.quantile(t[np.isfinite(t)], q))


def race_cell(spec, ctx) -> dict:
    """Sweep-driver runner for the faulted time-to-target race cells.

    ``ctx["f_target"]`` (set from the algorithm's phase-1 fault-free sync
    summary) is the objective the cell must reach within its
    ``spec.engine.rounds`` budget. The summary records the first
    simulated time at which f <= f_target (``t_hit`` None when never
    reached), the ledger bytes -- which bill every failed attempt, retry
    and duplicate -- and the fault counters.
    """
    handle = spec.build()
    sim = handle.sim
    m = spec.task.m
    f_target = ctx["f_target"]
    t_hit = None
    f = math.inf
    for _ in range(spec.engine.rounds):
        sim.step()
        f = float(handle.objective(sim.state.w_tau)) / m
        if f <= f_target:
            t_hit = float(sim.t)
            break
    out = {"policy": spec.policy.name, "f_target": float(f_target),
           "t_hit": t_hit, "f": f, "events": int(sim.round_idx),
           "sim_time_s": float(sim.t),
           "abandoned": int(sum(mm.abandoned for mm in sim.metrics)),
           "bytes_total": float(sim.ledger.total),
           "bytes_up": float(sim.ledger.total_up)}
    if sim._faults is not None:
        out["faults"] = sim._faults.summary()
    return out


def run(d: int = 4000, m: int = 32, k0: int = 8, rho: float = 0.5,
        rounds: int = 60, n: int = 14, seed: int = 0, alpha: float = 1.2,
        rates=RATES, jobs: int = 1, sweep_dir=None):
    from repro.launch.sweep_run import execute_cells, write_merged

    base = xspec.ExperimentSpec(
        name="fig8", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0,
                                      eps_dp=0.0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        engine=xspec.EngineSpec(name="eager", rounds=rounds))

    def _cell(policy_name, *, alg="fedepm", name=None, faults=None,
              cell_rounds=None, **knobs):
        cell = base.replace(**{
            "name": name or f"fig8/{alg}/{policy_name}",
            "algorithm.name": alg,
            "policy": xspec.PolicySpec(name=policy_name, **knobs)})
        if faults is not None:
            cell = cell.replace(faults=faults)
        if cell_rounds is not None:
            cell = cell.replace(**{"engine.rounds": cell_rounds})
        return cell.validate()

    profiles = make_profiles(m, seed=seed)
    down_b = float(tree_client_bytes(np.zeros(n, np.float32)))
    work = client_work_flops("fedepm", k0=k0, n_params=n, d_local=d / m)
    deadline = _calibrate_deadline(profiles, alpha, work, down_b, down_b)
    cohort = max(1, round(rho * m))
    buffer_k = max(1, cohort // 2)
    # race budgets: faults abandon rounds and stretch arrivals, so every
    # policy gets headroom over the reference budget; async counts events
    # (buffer_k per aggregation) instead of rounds
    budgets = {"sync": rounds * 3, "deadline": rounds * 3,
               "async": math.ceil(rounds * 3 * cohort / buffer_k)}
    policy_kw = {"sync": {}, "deadline": {"deadline": deadline},
                 "async": {"buffer_size": buffer_k}}
    algs = ("fedepm", "sfedavg")

    # phase 1 -- fault-free sync references: their endpoints are the
    # per-algorithm objective targets every faulted cell races toward
    fixed = [_cell("sync", alg=alg, name=f"fig8/{alg}/sync/ref")
             for alg in algs]
    # phase 2 -- the fault grid
    races, cell_names = [], []
    for alg in algs:
        for policy in ("sync", "deadline", "async"):
            for r in rates:
                name = f"fig8/{alg}/{policy}/r{r:g}"
                races.append(_cell(
                    policy, alg=alg, name=name, faults=fault_spec(r),
                    cell_rounds=budgets[policy], **policy_kw[policy]))
                cell_names.append((alg, policy, r, name))

    def _check(res, phase):
        if not res.ok:
            bad = res.failed or res.pending
            raise RuntimeError(f"fig8 {phase} sweep incomplete: "
                               f"failed={res.failed} "
                               f"pending={res.pending} (first: {bad[0]})")

    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        out_dir = sweep_dir if sweep_dir is not None else tmp
        res1 = execute_cells(fixed, out_dir=out_dir, jobs=jobs)
        _check(res1, "reference")
        s1 = {nm: rec["summary"] for nm, rec in res1.records.items()}
        targets = {alg: s1[f"fig8/{alg}/sync/ref"]["f_final"]
                   for alg in algs}
        cell_ctx = {name: {"f_target": targets[alg]}
                    for alg, _, _, name in cell_names}
        res2 = execute_cells(races, out_dir=out_dir, jobs=jobs,
                             runner="benchmarks.fig8_faults:race_cell",
                             cell_ctx=cell_ctx)
        _check(res2, "race")
        s2 = {nm: rec["summary"] for nm, rec in res2.records.items()}
        if sweep_dir is not None:
            write_merged(pathlib.Path(sweep_dir) / "merged.json",
                         fixed + races, {**res1.records, **res2.records},
                         meta={"name": "fig8"})

    rows = []
    for alg in algs:
        ref = s1[f"fig8/{alg}/sync/ref"]
        rows.append((f"fig8/{alg}/sync/ref/time_to_target",
                     ref["sim_time_s"] * 1e6,
                     f"f_target={targets[alg]:.6f};rounds={rounds};"
                     f"bytes_up={ref['bytes_up']:.0f}"))
    for alg, policy, r, name in cell_names:
        rec = s2[name]
        t_hit = rec["t_hit"]
        fl = rec.get("faults", {})
        counters = (f"drops={fl.get('upload_drops', 0)};"
                    f"retries={fl.get('retries', 0)};"
                    f"corrupt={fl.get('corrupt_rejected', 0)};"
                    f"dups={fl.get('duplicates_discarded', 0)};"
                    f"quarantines={fl.get('quarantines', 0)}")
        rows.append((
            f"{name}/time_to_target", (t_hit or 0.0) * 1e6,
            f"f={rec['f']:.6f};events={rec['events']};"
            f"abandoned={rec['abandoned']}"
            + ("" if t_hit else ";NOT_REACHED")))
        # ledger bytes bill every failed attempt, retry and duplicate:
        # this row IS the bytes-including-retries readout
        rows.append((f"{name}/bytes_up", rec["bytes_up"], counters))
    return rows


def export_trace(trace_out, events_out=None, *, d: int = 4000, m: int = 32,
                 k0: int = 8, rho: float = 0.5, rounds: int = 60,
                 n: int = 14, seed: int = 0, alpha: float = 1.2,
                 rate: float = 0.3, **_ignored) -> dict:
    """Run one faulted async cell with telemetry and export its timeline.

    Buffered-async (buffer = cohort/2, concurrency cap = cohort/2) on the
    Pareto fleet with the composite fault rate ``rate`` injected: the
    exported Perfetto trace shows drop/retry/duplicate/quarantine
    instants on the affected client tracks alongside the dispatch spans
    (docs/observability.md). Writes ``trace_out`` (and the raw event
    JSONL to ``events_out`` if given) and returns the run summary.
    """
    cohort = max(1, round(rho * m))
    buffer_k = max(1, cohort // 2)
    spec = xspec.ExperimentSpec(
        name="fig8/faults-trace", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        policy=xspec.PolicySpec(name="async", buffer_size=buffer_k,
                                max_concurrency=buffer_k),
        faults=fault_spec(rate),
        engine=xspec.EngineSpec(name="eager", rounds=rounds),
        telemetry=xspec.TelemetrySpec(
            enabled=True, trace_out=str(trace_out),
            events_jsonl=str(events_out) if events_out else None))
    return spec.build().run()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Fig. 8: aggregation policies under injected faults")
    ap.add_argument("--quick", action="store_true",
                    help="reduced task + short round budget (CI smoke)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="sweep-driver worker processes")
    ap.add_argument("--sweep-dir", default=None,
                    help="persistent sweep state dir (resumable; also "
                         "writes merged.json there)")
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON records to this path")
    ap.add_argument("--trace-out", default=None,
                    help="export a Perfetto trace_event JSON timeline of "
                         "one faulted async cell (fault instants on the "
                         "client tracks)")
    ap.add_argument("--events-out", default=None,
                    help="with --trace-out: also write the raw telemetry "
                         "event stream as JSONL")
    args = ap.parse_args(argv)
    kw = QUICK_KW if args.quick else {}
    rows = run(**kw, jobs=args.jobs, sweep_dir=args.sweep_dir)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": a, "value": b, "derived": c}
                       for a, b, c in rows], f, indent=1)
    if args.trace_out:
        export_trace(args.trace_out, args.events_out, **kw)
        print(f"fig8/trace_out,{args.trace_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
