"""ENS kernel micro-benchmark: jnp reference (XLA sort) vs the literal
paper Algorithm 1 vs the Pallas kernel (interpret mode on CPU -- the
timing of interest on this host is ref-vs-paper; the Pallas number is a
correctness checkpoint, its TPU performance is structural, see
EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ens import ops, ref


def _time(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(m=32, n=1 << 16, lam=0.5, eta=1.0):
    key = jax.random.PRNGKey(0)
    Z = jax.random.normal(key, (m, n))
    rows = []
    f_ref = jax.jit(lambda z: ref.ens_ref(z, lam, eta))
    f_pap = jax.jit(lambda z: ref.ens_paper(z, lam, eta))
    t_ref = _time(f_ref, Z)
    t_pap = _time(f_pap, Z)
    rows.append((f"ens/ref_m{m}_n{n}", t_ref * 1e6, "median-identity"))
    rows.append((f"ens/paper_alg1_m{m}_n{n}", t_pap * 1e6,
                 "literal Algorithm 1"))
    # pallas interpret: correctness + (slow) interpreted timing
    w_pal = ops.ens(Z, lam, eta, impl="pallas", interpret=True)
    w_ref = f_ref(Z)
    err = float(jnp.max(jnp.abs(w_pal - w_ref)))
    rows.append((f"ens/pallas_interpret_allclose", 0.0, f"maxerr={err:.2e}"))
    # objective comparison ref vs paper algorithm (documented deviation)
    obj_ref = float(jnp.sum(ref.ens_objective(Z, w_ref, lam, eta)))
    w_pap_v = f_pap(Z)
    obj_pap = float(jnp.sum(ref.ens_objective(Z, w_pap_v, lam, eta)))
    rows.append(("ens/objective_ref_vs_paper", 0.0,
                 f"ref={obj_ref:.4f};paper={obj_pap:.4f};"
                 f"ref_leq={obj_ref <= obj_pap + 1e-3}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
