"""Fig. 9 (fig5 successor): the upload-privacy frontier.

Races FedEPM and SFedAvg across a grid of transport-layer DP budgets
``eps`` (repro.privacy, docs/privacy.md) on the paper logreg task and
reads out the privacy-utility-bytes frontier per (algorithm, eps) cell:

  * SNR -- the paper's privacy readout ``min_i log10(||z_i|| /
    ||noise_i||)`` (Sec. VII), measured ON THE WIRE: each round the cell
    runner replays the round through a privacy-free twin simulation
    restored from the same snapshot (identical arrival RNG, selection
    masks and codec dither -- the privacy stream is decorrelated by
    construction), so ``noise_i`` is exactly what the transport noise
    plus its quantization interaction added to client i's stored upload.
  * CR -- communication rounds to the paper's termination rule (budget-
    capped; a cell that never terminates reports the budget and is
    flagged NOT_TERMINATED).
  * utility -- the terminal objective gap to the algorithm's own
    privacy-free sync reference from phase 1.
  * bytes -- uplink ledger bytes; the per-algorithm ``secure_agg`` cell
    re-runs the mid-grid eps with pairwise-mask exchanges on, so the
    secure-aggregation overhead is visible on the same byte axis
    (mask bytes bill per upload attempt, PR 9's rule).

The legacy fig5 claims carry over against the wire SNR: SNR increases
with eps (less noise = weaker privacy), FedEPM attains the smallest SNR
(strongest privacy), and CR is stable in eps.

Every cell is a declarative :class:`repro.spec.ExperimentSpec` with a
``[privacy]`` section and the grid executes through the multi-cell
sweep driver (repro.launch.sweep_run; parallel across ``jobs``
processes, resumable under ``sweep_dir``) in two phases: the
privacy-free sync references run first, their endpoints fix the
per-algorithm utility targets, and the eps-grid cells run second under
:func:`privacy_cell` with those targets in the per-cell driver context.

Rows: fig9/<alg>/eps=<e>/snr,<snr_db10>,<cr;f;bytes>
      fig9/<alg>/eps=<e>/bytes_up,<bytes>,<privacy counters>
      fig9/<alg>/secure_agg/mask_overhead,<bytes>,<mask counters>
      fig9/<alg>/snr_increases_with_eps,0,<bool>   (+ cr_stable_in_eps,
      fig9/fedepm_smallest_SNR)

``--trace-out PATH`` additionally runs one privacy-enabled async cell
with run telemetry attached and exports the simulated timeline as a
Perfetto/Chrome ``trace_event`` JSON -- ``privacy_charge`` and
``mask_exchange`` instants on the client tracks (docs/observability.md).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

import numpy as np

from repro import spec as xspec

# the one quick/smoke profile, shared by `--quick` and benchmarks/run.py
QUICK_KW = dict(d=2000, m=16, rounds=30, eps_grid=(0.5, 2.0))

#: default transport-DP budget grid (surrogate sensitivity). Shifted up
#: from fig5's (0.1, 0.5, 0.9): the transport mechanism noises the FULL
#: stored upload at scale 2*||z||_1/eps (no Thm VI.1 mu-decay, unlike the
#: in-algorithm mechanism fig5 swept), so the utility transition -- the
#: informative part of the frontier -- sits at larger eps
EPS_GRID = (0.5, 2.0, 8.0)

ALGS = ("fedepm", "sfedavg")


def _client_rows(tree) -> np.ndarray:
    """Stack a client-major state pytree into one (m, n_flat) matrix."""
    import jax
    leaves = jax.tree_util.tree_leaves(tree)
    return np.concatenate(
        [np.asarray(x, np.float64).reshape(x.shape[0], -1) for x in leaves],
        axis=1)


def _round_snr(prev, clean, noisy) -> float | None:
    """Paper SNR for one round: min_i log10(||z_i|| / ||noise_i||) over
    the clients whose stored upload changed (the merged set), with the
    clean twin's decode as the signal and the noisy-minus-clean delta as
    the wire noise."""
    merged = np.any(clean != prev, axis=1)
    if not merged.any():
        return None
    with np.errstate(invalid="ignore", over="ignore"):
        sig = np.linalg.norm(clean[merged], axis=1)
        noise = np.linalg.norm(noisy[merged] - clean[merged], axis=1)
    # once a heavily-noised trajectory overflows float32 the deltas go
    # non-finite; those rounds carry no SNR information
    ok = (noise > 0) & np.isfinite(noise) & np.isfinite(sig)
    if not ok.any():
        return None
    return float(np.min(np.log10(np.maximum(sig[ok], 1e-30) / noise[ok])))


def privacy_cell(spec, ctx) -> dict:
    """Sweep-driver runner for the eps-grid cells: wire SNR, CR, bytes.

    Runs the privacy-enabled cell round by round alongside a privacy-free
    TWIN simulation built from the same spec with the ``[privacy]``
    section stripped. Before each round the twin is restored from the
    noisy sim's snapshot (state, host RNG, clock, ledger), so it replays
    the identical round -- same selection, same arrivals, same codec
    dither -- without the clip/noise transform; the per-client delta
    between the two post-round upload states is exactly the noise the
    transport added, and the paper's SNR readout follows. The twin is
    observational: the reported trajectory is the noisy sim's own.

    Termination mirrors ``RunHandle._terminated`` (>= 8 rounds of
    history, >= 1 aggregated round) so CR is comparable to the phase-1
    references; ``ctx["f_target"]`` (the algorithm's privacy-free sync
    endpoint) anchors the utility-gap readout.
    """
    from repro.configs.paper_logreg import termination_reached

    handle = spec.build()
    twin = spec.replace(privacy=xspec.PrivacySpec()).validate().build().sim
    sim = handle.sim
    m = spec.task.m
    f_hist: list[float] = []
    snrs: list[float] = []
    cr = None
    for r in range(spec.engine.rounds):
        prev = _client_rows(sim.state.Z)
        snap = sim.snapshot()
        sim.step()
        f_hist.append(float(handle.objective(sim.state.w_tau)))
        twin.restore(snap)
        twin.step()
        snr = _round_snr(prev, _client_rows(twin.state.Z),
                         _client_rows(sim.state.Z))
        if snr is not None and r < 20:
            # fixed-window SNR, like fig5's SNR20: isolates the eps ->
            # noise effect from the (eps-dependent) termination time
            snrs.append(snr)
        if (len(f_hist) >= 8
                and any(not mm.abandoned for mm in sim.metrics)
                and termination_reached(
                    f_hist, float(handle.grad_sq_norm(sim.state.w_tau)),
                    spec.task.n)):
            cr = r + 1
            break
    out = {"alg": spec.algorithm.name, "eps": spec.privacy.eps,
           "cr": cr if cr is not None else spec.engine.rounds,
           "terminated": cr is not None,
           "f_final": f_hist[-1] / m,
           "f_gap": f_hist[-1] / m - ctx["f_target"],
           "snr": float(np.median(snrs)) if snrs else math.inf,
           "snr_rounds": len(snrs),
           "sim_time_s": float(sim.t),
           "bytes_up": float(sim.ledger.total_up),
           "bytes_total": float(sim.ledger.total),
           "privacy": sim._privacy.summary()}
    return out


def run(d: int = 4000, m: int = 32, k0: int = 8, rho: float = 0.5,
        rounds: int = 60, n: int = 14, seed: int = 0, alpha: float = 1.2,
        eps_grid=EPS_GRID, jobs: int = 1, sweep_dir=None):
    from repro.launch.sweep_run import execute_cells, write_merged

    base = xspec.ExperimentSpec(
        name="fig9", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0,
                                      eps_dp=0.0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        engine=xspec.EngineSpec(name="eager", rounds=rounds))

    def _cell(*, alg, name, privacy=None, terminate=False):
        cell = base.replace(**{"name": name, "algorithm.name": alg,
                               "engine.terminate": terminate})
        if privacy is not None:
            cell = cell.replace(privacy=privacy)
        return cell.validate()

    eps_mid = eps_grid[len(eps_grid) // 2]

    # phase 1 -- privacy-free sync references: their endpoints are the
    # per-algorithm utility targets, their CR the termination baseline
    fixed = [_cell(alg=alg, name=f"fig9/{alg}/ref", terminate=True)
             for alg in ALGS]
    # phase 2 -- the eps grid (surrogate sensitivity, the paper's), plus
    # one secure-agg cell per algorithm at the mid-grid eps so the mask
    # overhead shows up on the same byte axis
    cells, cell_names = [], []
    for alg in ALGS:
        for eps in eps_grid:
            name = f"fig9/{alg}/eps={eps:g}"
            cells.append(_cell(alg=alg, name=name,
                               privacy=xspec.PrivacySpec(eps=eps)))
            cell_names.append((alg, eps, False, name))
        name = f"fig9/{alg}/secure_agg"
        cells.append(_cell(alg=alg, name=name,
                           privacy=xspec.PrivacySpec(eps=eps_mid,
                                                     secure_agg=True)))
        cell_names.append((alg, eps_mid, True, name))

    def _check(res, phase):
        if not res.ok:
            bad = res.failed or res.pending
            raise RuntimeError(f"fig9 {phase} sweep incomplete: "
                               f"failed={res.failed} "
                               f"pending={res.pending} (first: {bad[0]})")

    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        out_dir = sweep_dir if sweep_dir is not None else tmp
        res1 = execute_cells(fixed, out_dir=out_dir, jobs=jobs)
        _check(res1, "reference")
        s1 = {nm: rec["summary"] for nm, rec in res1.records.items()}
        targets = {alg: s1[f"fig9/{alg}/ref"]["f_final"] for alg in ALGS}
        cell_ctx = {name: {"f_target": targets[alg]}
                    for alg, _, _, name in cell_names}
        res2 = execute_cells(cells, out_dir=out_dir, jobs=jobs,
                             runner="benchmarks.fig9_privacy:privacy_cell",
                             cell_ctx=cell_ctx)
        _check(res2, "frontier")
        s2 = {nm: rec["summary"] for nm, rec in res2.records.items()}
        if sweep_dir is not None:
            write_merged(pathlib.Path(sweep_dir) / "merged.json",
                         fixed + cells, {**res1.records, **res2.records},
                         meta={"name": "fig9"})

    rows = []
    for alg in ALGS:
        ref = s1[f"fig9/{alg}/ref"]
        rows.append((f"fig9/{alg}/ref", 0.0,
                     f"cr={ref['rounds']};f={ref['f_final']:.6f};"
                     f"bytes_up={ref['bytes_up']:.0f}"))
    snr, cr = {}, {}
    for alg, eps, sa, name in cell_names:
        rec = s2[name]
        pv = rec["privacy"]
        if sa:
            # secure-agg overhead readout: same eps as the mid-grid
            # cell, so the byte delta IS the mask traffic
            plain = s2[f"fig9/{alg}/eps={eps:g}"]
            rows.append((
                f"{name}/mask_overhead",
                rec["bytes_up"] - plain["bytes_up"],
                f"mask_attempts={pv['mask_attempts']};"
                f"mask_bytes={pv['mask_bytes']};"
                f"bytes_up={rec['bytes_up']:.0f}"))
            continue
        snr[(alg, eps)] = rec["snr"]
        cr[(alg, eps)] = rec["cr"]
        rows.append((
            f"{name}/snr", rec["snr"],
            f"cr={rec['cr']};f_gap={rec['f_gap']:.6f};"
            f"eps_spent_max={pv['eps_spent_max']:g}"
            + ("" if rec["terminated"] else ";NOT_TERMINATED")))
        rows.append((f"{name}/bytes_up", rec["bytes_up"],
                     f"charges={pv['charges']};"
                     f"mask_bytes={pv['mask_bytes']}"))
    # the fig5 claim checks, carried over against the wire SNR
    for alg in ALGS:
        inc = snr[(alg, eps_grid[-1])] >= snr[(alg, eps_grid[0])]
        rows.append((f"fig9/{alg}/snr_increases_with_eps", 0.0, str(inc)))
        stable = abs(cr[(alg, eps_grid[-1])] - cr[(alg, eps_grid[0])]) \
            <= 0.5 * max(cr[(alg, eps_grid[0])], 1)
        rows.append((f"fig9/{alg}/cr_stable_in_eps", 0.0, str(stable)))
    strongest = all(snr[("fedepm", e)] <= snr[("sfedavg", e)] + 0.5
                    for e in eps_grid)
    rows.append(("fig9/fedepm_smallest_SNR", 0.0, str(strongest)))
    return rows


def export_trace(trace_out, events_out=None, *, d: int = 4000, m: int = 32,
                 k0: int = 8, rho: float = 0.5, rounds: int = 30,
                 n: int = 14, seed: int = 0, alpha: float = 1.2,
                 eps: float = 0.5, **_ignored) -> dict:
    """Run one privacy-enabled async cell with telemetry and export its
    timeline.

    Buffered-async on the Pareto fleet with transport DP + secure
    aggregation: the exported Perfetto trace shows ``privacy_charge``
    (with per-merge staleness) and ``mask_exchange`` instants on the
    client tracks alongside the dispatch spans (docs/observability.md).
    Writes ``trace_out`` (and the raw event JSONL to ``events_out`` if
    given) and returns the run summary.
    """
    cohort = max(1, round(rho * m))
    buffer_k = max(1, cohort // 2)
    spec = xspec.ExperimentSpec(
        name="fig9/privacy-trace", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        policy=xspec.PolicySpec(name="async", buffer_size=buffer_k,
                                max_concurrency=buffer_k),
        privacy=xspec.PrivacySpec(eps=eps, secure_agg=True),
        engine=xspec.EngineSpec(name="eager", rounds=rounds),
        telemetry=xspec.TelemetrySpec(
            enabled=True, trace_out=str(trace_out),
            events_jsonl=str(events_out) if events_out else None))
    return spec.build().run()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Fig. 9: the upload-privacy frontier (fig5 successor)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced task + short round budget (CI smoke)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="sweep-driver worker processes")
    ap.add_argument("--sweep-dir", default=None,
                    help="persistent sweep state dir (resumable; also "
                         "writes merged.json there)")
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON records to this path")
    ap.add_argument("--trace-out", default=None,
                    help="export a Perfetto trace_event JSON timeline of "
                         "one privacy-enabled async cell (privacy_charge "
                         "/ mask_exchange instants on the client tracks)")
    ap.add_argument("--events-out", default=None,
                    help="with --trace-out: also write the raw telemetry "
                         "event stream as JSONL")
    args = ap.parse_args(argv)
    kw = QUICK_KW if args.quick else {}
    rows = run(**kw, jobs=args.jobs, sweep_dir=args.sweep_dir)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": a, "value": b, "derived": c}
                       for a, b, c in rows], f, indent=1)
    if args.trace_out:
        export_trace(args.trace_out, args.events_out,
                     **{k: v for k, v in kw.items() if k != "eps_grid"})
        print(f"fig9/trace_out,{args.trace_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
