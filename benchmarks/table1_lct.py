"""Table I reproduction: local computation time (LCT) vs k0 for the three
algorithms. Claim: FedEPM's LCT is the lowest and grows the slowest with
k0 (one gradient per round + elementwise inner steps); SFedProx the
highest (ell inner GD steps per iteration)."""
from __future__ import annotations

from benchmarks.common import measure_lct


def run(m=50, k0_grid=(4, 8, 12, 16, 20), d=45222):
    rows = []
    lct = {}
    for alg in ("sfedavg", "sfedprox", "fedepm"):
        for k0 in k0_grid:
            t = measure_lct(alg, m=m, k0=k0, rho=0.5, eps=0.1, d=d)
            lct[(alg, k0)] = t
            rows.append((f"table1/{alg}/k0={k0}", t * 1e6, f"{t*1e3:.3f}ms"))
    ok = all(lct[("fedepm", k)] <= lct[("sfedavg", k)] and
             lct[("fedepm", k)] <= lct[("sfedprox", k)] for k in k0_grid)
    rows.append(("table1/fedepm_lowest_LCT", 0.0, str(ok)))
    ok2 = all(lct[("sfedprox", k)] >= lct[("sfedavg", k)]
              for k in k0_grid[2:])
    rows.append(("table1/sfedprox_highest_LCT", 0.0, str(ok2)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
