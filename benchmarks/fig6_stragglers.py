"""Fig. 6 (beyond-paper): straggler robustness of aggregation policies.

Time-to-accuracy under a heavy-tail (Pareto) device fleet: FedEPM and
SFedAvg each run under three aggregation policies -- sync (wait for every
selected client), deadline (drop stragglers past a per-round cutoff set at
the q-th arrival quantile; eq. (22) carry-through for the dropped), and
over-selection (contact extra clients, aggregate the first ceil(rho*m)
arrivals). Reported per cell: simulated wall-clock to the paper's
termination rule (or the round cap), rounds, total bytes moved, stragglers
dropped. The headline systems claim: under heavy-tail compute jitter the
straggler-mitigating policies reach the same objective in a fraction of
sync's simulated time at (near-)identical byte cost.

The grid is a LIST OF EXPERIMENT SPECS (repro.spec, docs/spec.md):
``grid()`` sweeps one declarative base cell over algorithm x policy (the
deadline cell's cutoff calibrated per algorithm) and every cell executes
through the same ``spec.build()`` path the simulate CLI uses. Cells share
one device copy of the task data via the spec layer's task memo.

Rows: fig6/<alg>/<policy>/time,<sim_seconds * 1e6>,<derived>.
"""
from __future__ import annotations

import numpy as np

from repro import spec as xspec
from repro.configs.paper_logreg import termination_reached
from repro.sim import (
    client_work_flops,
    make_latency_model,
    make_profiles,
    round_arrivals,
    tree_client_bytes,
)

POLICIES = ("sync", "deadline", "overselect")
ALGS = ("fedepm", "sfedavg")


def _calibrate_deadline(profiles, latency_kind, alpha, work, down_b, up_b,
                        q: float = 0.8, draws: int = 200,
                        seed: int = 123) -> float:
    """Deadline = q-quantile of simulated arrival times (a server would set
    this from observed report latencies)."""
    rng = np.random.default_rng(seed)
    lat = make_latency_model(latency_kind, alpha=alpha)
    samples = [round_arrivals(profiles, rng, lat, work_flops=work,
                              down_bytes=down_b, up_bytes=up_b)
               for _ in range(draws)]
    t = np.concatenate(samples)
    return float(np.quantile(t[np.isfinite(t)], q))


def grid(*, d, m, k0, rho, rounds, n, seed, alpha,
         deadlines) -> list[xspec.ExperimentSpec]:
    """The fig6 grid as a spec list: ALGS x POLICIES, per-alg cutoffs."""
    base = xspec.ExperimentSpec(
        name="fig6", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0,
                                      eps_dp=0.0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        engine=xspec.EngineSpec(name="eager", rounds=rounds))
    cells = []
    for alg in ALGS:
        policies = [
            xspec.PolicySpec(name="sync"),
            xspec.PolicySpec(name="deadline", deadline=deadlines[alg]),
            xspec.PolicySpec(name="overselect", overselect_factor=1.5),
        ]
        cells += xspec.sweep(base.replace(**{"algorithm.name": alg}),
                             {"policy": policies})
    return cells


def run(d: int = 4000, m: int = 32, k0: int = 8, rho: float = 0.5,
        rounds: int = 80, n: int = 14, seed: int = 0, alpha: float = 1.2):
    profiles = make_profiles(m, seed=seed)
    # the broadcast w tree (float32, as the sim holds it)
    down_b = float(tree_client_bytes(np.zeros(n, np.float32)))
    # calibrate the cutoff PER ALGORITHM: SFedAvg does ~k0x FedEPM's work
    # per round, so a FedEPM-calibrated deadline would drop most SFedAvg
    # clients and skew the cross-policy comparison
    deadlines = {
        alg: _calibrate_deadline(
            profiles, "pareto", alpha,
            client_work_flops(alg, k0=k0, n_params=n, d_local=d / m),
            down_b, down_b)
        for alg in ALGS}

    rows = []
    results: dict[tuple, dict] = {}
    for cell in grid(d=d, m=m, k0=k0, rho=rho, rounds=rounds, n=n,
                     seed=seed, alpha=alpha, deadlines=deadlines):
        alg, policy = cell.algorithm.name, cell.policy.name
        handle = cell.build()
        sim = handle.sim
        f_hist: list[float] = []
        for _ in range(rounds):
            sim.step()
            f_hist.append(float(handle.objective(sim.state.w_tau)))
            # the paper's variance criterion fires spuriously on the
            # flat first rounds (w_tau barely moves while uploads warm
            # up, especially under heavy drops) -- require a real
            # history before trusting it
            if len(f_hist) >= 8 and termination_reached(
                    f_hist, float(handle.grad_sq_norm(sim.state.w_tau)), n):
                break
        res = {
            "f": f_hist[-1] / m, "rounds": len(f_hist),
            "sim_time": sim.t, "bytes": sim.ledger.total,
            "dropped": sum(mm.n_dropped for mm in sim.metrics),
        }
        results[(alg, policy)] = res
        rows.append((
            f"fig6/{alg}/{policy}/time", res["sim_time"] * 1e6,
            f"f={res['f']:.5f};rounds={res['rounds']};"
            f"bytes={res['bytes']:.0f};dropped={res['dropped']}"))

    # headline: straggler mitigation beats sync on simulated wall-clock at
    # (near-)equal objective; value is the SPEEDUP FACTOR (>1 = faster)
    for alg in ALGS:
        sync_t = results[(alg, "sync")]["sim_time"]
        best = min(results[(alg, p)]["sim_time"]
                   for p in ("deadline", "overselect"))
        spread = max(results[(alg, p)]["f"] for p in POLICIES) \
            - min(results[(alg, p)]["f"] for p in POLICIES)
        rows.append((f"fig6/{alg}/speedup_vs_sync",
                     0.0 if best == 0 else sync_t / best,
                     f"sync={sync_t:.4g}s;best={best:.4g}s;"
                     f"f_spread={spread:.2e}"))
    for alg in ALGS:
        rows.append((f"fig6/{alg}/deadline_calibrated_s",
                     deadlines[alg] * 1e6,
                     f"q80_arrival={deadlines[alg]:.4g}s"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
