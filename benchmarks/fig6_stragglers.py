"""Fig. 6 (beyond-paper): straggler robustness of aggregation policies.

Time-to-accuracy under a heavy-tail (Pareto) device fleet: FedEPM and
SFedAvg each run under three aggregation policies -- sync (wait for every
selected client), deadline (drop stragglers past a per-round cutoff set at
the q-th arrival quantile; eq. (22) carry-through for the dropped), and
over-selection (contact extra clients, aggregate the first ceil(rho*m)
arrivals). Reported per cell: simulated wall-clock to the paper's
termination rule (or the round cap), rounds, total bytes moved, stragglers
dropped. The headline systems claim: under heavy-tail compute jitter the
straggler-mitigating policies reach the same objective in a fraction of
sync's simulated time at (near-)identical byte cost.

The grid is a LIST OF EXPERIMENT SPECS (repro.spec, docs/spec.md):
``grid()`` sweeps one declarative base cell over algorithm x policy (the
deadline cell's cutoff calibrated per algorithm) and every cell executes
through the multi-cell sweep driver (repro.launch.sweep_run): parallel
across ``jobs`` local processes, one atomic result file per cell (a
killed run resumes under ``sweep_dir``), the paper's termination rule
applied by ``RunHandle.run`` via ``engine.terminate``. The rows are pure
functions of the driver's per-cell summaries.

Rows: fig6/<alg>/<policy>/time,<sim_seconds * 1e6>,<derived>.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro import spec as xspec
from repro.sim import (
    client_work_flops,
    make_latency_model,
    make_profiles,
    round_arrivals,
    tree_client_bytes,
)

POLICIES = ("sync", "deadline", "overselect")
ALGS = ("fedepm", "sfedavg")

# the one quick/smoke profile, shared by `--quick` and benchmarks/run.py
QUICK_KW = dict(d=4000, m=16, rounds=30)


def _calibrate_deadline(profiles, latency_kind, alpha, work, down_b, up_b,
                        q: float = 0.8, draws: int = 200,
                        seed: int = 123) -> float:
    """Deadline = q-quantile of simulated arrival times (a server would set
    this from observed report latencies)."""
    rng = np.random.default_rng(seed)
    lat = make_latency_model(latency_kind, alpha=alpha)
    samples = [round_arrivals(profiles, rng, lat, work_flops=work,
                              down_bytes=down_b, up_bytes=up_b)
               for _ in range(draws)]
    t = np.concatenate(samples)
    return float(np.quantile(t[np.isfinite(t)], q))


def grid(*, d, m, k0, rho, rounds, n, seed, alpha,
         deadlines) -> list[xspec.ExperimentSpec]:
    """The fig6 grid as a spec list: ALGS x POLICIES, per-alg cutoffs."""
    base = xspec.ExperimentSpec(
        name="fig6", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0,
                                      eps_dp=0.0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        engine=xspec.EngineSpec(name="eager", rounds=rounds,
                                terminate=True))
    cells = []
    for alg in ALGS:
        policies = [
            xspec.PolicySpec(name="sync"),
            xspec.PolicySpec(name="deadline", deadline=deadlines[alg]),
            xspec.PolicySpec(name="overselect", overselect_factor=1.5),
        ]
        cells += xspec.sweep(
            base.replace(**{"algorithm.name": alg, "name": f"fig6/{alg}"}),
            {"policy": policies})
    return cells


def run(d: int = 4000, m: int = 32, k0: int = 8, rho: float = 0.5,
        rounds: int = 80, n: int = 14, seed: int = 0, alpha: float = 1.2,
        jobs: int = 1, sweep_dir=None):
    from repro.launch.sweep_run import execute_cells, write_merged

    profiles = make_profiles(m, seed=seed)
    # the broadcast w tree (float32, as the sim holds it)
    down_b = float(tree_client_bytes(np.zeros(n, np.float32)))
    # calibrate the cutoff PER ALGORITHM: SFedAvg does ~k0x FedEPM's work
    # per round, so a FedEPM-calibrated deadline would drop most SFedAvg
    # clients and skew the cross-policy comparison
    deadlines = {
        alg: _calibrate_deadline(
            profiles, "pareto", alpha,
            client_work_flops(alg, k0=k0, n_params=n, d_local=d / m),
            down_b, down_b)
        for alg in ALGS}

    cells = grid(d=d, m=m, k0=k0, rho=rho, rounds=rounds, n=n,
                 seed=seed, alpha=alpha, deadlines=deadlines)
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        out_dir = sweep_dir if sweep_dir is not None else tmp
        res = execute_cells(cells, out_dir=out_dir, jobs=jobs)
        if not res.ok:
            bad = res.failed or res.pending
            raise RuntimeError(f"fig6 sweep incomplete: "
                               f"failed={res.failed} pending={res.pending}"
                               f" (first: {bad[0]})")
        if sweep_dir is not None:
            import pathlib
            write_merged(pathlib.Path(sweep_dir) / "merged.json", cells,
                         res.records, meta={"name": "fig6"})

    rows = []
    results: dict[tuple, dict] = {}
    for cell in cells:
        alg, policy = cell.algorithm.name, cell.policy.name
        s = res.records[cell.name]["summary"]
        res_c = {
            "f": s["f_final"], "rounds": s["rounds"],
            "sim_time": s["sim_time_s"], "bytes": s["bytes_total"],
            "dropped": s["stragglers_dropped"],
        }
        results[(alg, policy)] = res_c
        rows.append((
            f"fig6/{alg}/{policy}/time", res_c["sim_time"] * 1e6,
            f"f={res_c['f']:.5f};rounds={res_c['rounds']};"
            f"bytes={res_c['bytes']:.0f};dropped={res_c['dropped']}"))

    # headline: straggler mitigation beats sync on simulated wall-clock at
    # (near-)equal objective; value is the SPEEDUP FACTOR (>1 = faster)
    for alg in ALGS:
        sync_t = results[(alg, "sync")]["sim_time"]
        best = min(results[(alg, p)]["sim_time"]
                   for p in ("deadline", "overselect"))
        spread = max(results[(alg, p)]["f"] for p in POLICIES) \
            - min(results[(alg, p)]["f"] for p in POLICIES)
        rows.append((f"fig6/{alg}/speedup_vs_sync",
                     0.0 if best == 0 else sync_t / best,
                     f"sync={sync_t:.4g}s;best={best:.4g}s;"
                     f"f_spread={spread:.2e}"))
    for alg in ALGS:
        rows.append((f"fig6/{alg}/deadline_calibrated_s",
                     deadlines[alg] * 1e6,
                     f"q80_arrival={deadlines[alg]:.4g}s"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Fig. 6: straggler-policy benchmark grid")
    ap.add_argument("--quick", action="store_true",
                    help="reduced fleet + short round budget (CI smoke)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="sweep-driver worker processes")
    ap.add_argument("--sweep-dir", default=None,
                    help="persistent sweep state dir (resumable; also "
                         "writes merged.json there)")
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON records to this path")
    args = ap.parse_args(argv)
    kw = QUICK_KW if args.quick else {}
    rows = run(**kw, jobs=args.jobs, sweep_dir=args.sweep_dir)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": a, "value": b, "derived": c}
                       for a, b, c in rows], f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
