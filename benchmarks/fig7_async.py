"""Fig. 7 (beyond-paper): asynchronous buffered aggregation + error feedback.

Two experiments on the paper logreg task under a heavy-tail (Pareto) fleet:

1. Time-to-accuracy race, uncompressed: FedEPM under sync, deadline
   (q80-calibrated cutoff) and async-buffered (buffer = half a cohort,
   FedBuff-style staleness-weighted merges) aggregation. The target is the
   objective the SYNC run ends at after the round budget; each policy
   reports the simulated wall-clock at which it first reaches that
   sync-equal objective. Headline: async reaches it in a fraction of
   sync's simulated time -- aggregation events wait for the K-th arrival
   instead of the slowest cohort straggler.

2. Compression-bias closure: the same async run with an aggressive upload
   codec (top-25%, 8-bit), memoryless vs EF21-style error feedback
   (kernels/quant ``ef_accumulate`` pair). Reported: final objective gap
   to the uncompressed async run. Headline: error feedback shrinks the
   memoryless bias by an order of magnitude at identical wire bytes.

3. Cross-algorithm trace cells: FedEPM and SFedAvg race sync vs
   client-level async on a fleet RESAMPLED FROM A REAL DEVICE TRACE
   (tests/fixtures/device_trace.csv, sim/clients.py::LatencyTrace) under
   identical async semantics -- same event engine, concurrency cap
   (cohort/2), buffer (cohort/2) and staleness weighting; the baseline's
   eq. (34) mean anchors on the cohort via the agg_mask hook. Each
   algorithm reports simulated time to ITS OWN sync-run objective, so the
   async-vs-sync speedup is comparable across algorithms.

Every cell is a declarative :class:`repro.spec.ExperimentSpec` (the
``_cell`` helper varies one base spec per experiment; docs/spec.md), and
the grid executes through the multi-cell sweep driver
(repro.launch.sweep_run; parallel across ``jobs`` processes, resumable
under ``sweep_dir``) in two phases: the fixed-budget cells (sync
references, codec-bias runs) run first under the driver's default
runner, their summaries fix the per-cell objective targets, and the
time-to-target race cells run second under :func:`race_cell` with those
targets in the per-cell driver context. The rows are pure functions of
the per-cell summaries.

Rows: fig7/<policy>/time_to_target,<sim_seconds * 1e6>,<derived>
      fig7/async/speedup_vs_sync,<factor>
      fig7/codec/gap_{memoryless,error_feedback},<|f - f_raw|>
      fig7/trace/<alg>/time_to_target,<sim_seconds * 1e6>,<derived>
      fig7/trace/<alg>/speedup_vs_sync,<factor>

``--trace-out PATH`` additionally runs the async cell with run telemetry
attached and exports the simulated timeline as a Perfetto/Chrome
``trace_event`` JSON (one track per client; docs/observability.md) --
the straggler/staleness structure the race rows summarize, visible in
ui.perfetto.dev. ``--events-out`` writes the raw event JSONL.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

import numpy as np

from repro import spec as xspec
from repro.sim import (
    client_work_flops,
    make_latency_model,
    make_profiles,
    round_arrivals,
    tree_client_bytes,
)

TRACE_CSV = (pathlib.Path(__file__).resolve().parent.parent
             / "tests" / "fixtures" / "device_trace.csv")

# the one quick/smoke profile, shared by `--quick` and benchmarks/run.py
QUICK_KW = dict(d=2000, m=16, rounds=12)


def _calibrate_deadline(profiles, alpha, work, down_b, up_b, q: float = 0.8,
                        draws: int = 200, seed: int = 123) -> float:
    rng = np.random.default_rng(seed)
    lat = make_latency_model("pareto", alpha=alpha)
    t = np.concatenate([
        round_arrivals(profiles, rng, lat, work_flops=work,
                       down_bytes=down_b, up_bytes=up_b)
        for _ in range(draws)])
    return float(np.quantile(t[np.isfinite(t)], q))


def race_cell(spec, ctx) -> dict:
    """Sweep-driver runner for the time-to-target race cells.

    ``ctx["f_target"]`` (per-cell driver context, set from a phase-1 sync
    summary) is the objective the cell must reach; ``spec.engine.rounds``
    is the event budget. The summary records the first simulated time at
    which f <= f_target (``t_hit`` None when never reached).
    """
    handle = spec.build()
    sim = handle.sim
    m = spec.task.m
    f_target = ctx["f_target"]
    t_hit = None
    f = math.inf
    for _ in range(spec.engine.rounds):
        sim.step()
        f = float(handle.objective(sim.state.w_tau)) / m
        if f <= f_target:
            t_hit = float(sim.t)
            break
    return {"policy": spec.policy.name, "f_target": float(f_target),
            "t_hit": t_hit, "f": f, "events": int(sim.round_idx),
            "sim_time_s": float(sim.t),
            "bytes_total": float(sim.ledger.total),
            "bytes_up": float(sim.ledger.total_up),
            "staleness_max": int(max(
                (mm.staleness_max for mm in sim.metrics), default=0))}


def run(d: int = 4000, m: int = 32, k0: int = 8, rho: float = 0.5,
        rounds: int = 60, n: int = 14, seed: int = 0, alpha: float = 1.2,
        trace_file=TRACE_CSV, jobs: int = 1, sweep_dir=None):
    from repro.launch.sweep_run import execute_cells, write_merged

    base = xspec.ExperimentSpec(
        name="fig7", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0,
                                      eps_dp=0.0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        engine=xspec.EngineSpec(name="eager", rounds=rounds))

    def _cell(policy_name, *, alg="fedepm", name=None, fleet=None,
              codec=None, cell_rounds=None, **knobs):
        cell = base.replace(**{
            "name": name or f"fig7/{alg}/{policy_name}",
            "algorithm.name": alg,
            "policy": xspec.PolicySpec(name=policy_name, **knobs)})
        if fleet is not None:
            cell = cell.replace(fleet=fleet)
        if codec is not None:
            cell = cell.replace(codec=codec)
        if cell_rounds is not None:
            cell = cell.replace(**{"engine.rounds": cell_rounds})
        return cell.validate()

    profiles = make_profiles(m, seed=seed)
    down_b = float(tree_client_bytes(np.zeros(n, np.float32)))
    work = client_work_flops("fedepm", k0=k0, n_params=n, d_local=d / m)
    deadline = _calibrate_deadline(profiles, alpha, work, down_b, down_b)
    cohort = max(1, round(rho * m))
    buffer_k = max(1, cohort // 2)
    cap = max(1, cohort // 2)
    # fixed codec-bias budget: async events doing one sync budget's work
    async_events = math.ceil(rounds * cohort / buffer_k)
    # generous race budgets: one async event does buffer_k/cohort of a
    # round's work; a deadline round drops stragglers and may need extras
    budgets = {"deadline": rounds * 3,
               "async": math.ceil(rounds * 3 * cohort / buffer_k)}
    trace_fleet = xspec.FleetSpec(kind="trace", trace_file=str(trace_file),
                                  latency="pareto", latency_alpha=alpha)
    codec_kw = dict(topk_frac=0.25, bits=8)

    # phase 1 -- fixed-budget cells (default runner): the sync references
    # whose endpoints become the race targets, plus the codec-bias runs
    fixed = [
        _cell("sync"),
        _cell("async", name="fig7/fedepm/async/raw",
              buffer_size=buffer_k, cell_rounds=async_events),
        _cell("async", name="fig7/fedepm/async/codec-memoryless",
              buffer_size=buffer_k, cell_rounds=async_events,
              codec=xspec.CodecSpec(error_feedback=False, **codec_kw)),
        _cell("async", name="fig7/fedepm/async/codec-ef",
              buffer_size=buffer_k, cell_rounds=async_events,
              codec=xspec.CodecSpec(error_feedback=True, **codec_kw)),
        _cell("sync", name="fig7/trace/fedepm/sync", fleet=trace_fleet),
        _cell("sync", alg="sfedavg", name="fig7/trace/sfedavg/sync",
              fleet=trace_fleet),
    ]
    # phase 2 -- time-to-target races (race_cell runner), each fed its
    # phase-1 objective target through the per-cell driver context
    races = [
        _cell("deadline", deadline=deadline,
              cell_rounds=budgets["deadline"]),
        _cell("async", buffer_size=buffer_k,
              cell_rounds=budgets["async"]),
        _cell("async", name="fig7/trace/fedepm/async", fleet=trace_fleet,
              buffer_size=buffer_k, max_concurrency=cap,
              cell_rounds=budgets["async"]),
        _cell("async", alg="sfedavg", name="fig7/trace/sfedavg/async",
              fleet=trace_fleet, buffer_size=buffer_k,
              max_concurrency=cap, cell_rounds=budgets["async"]),
    ]

    def _check(res, phase):
        if not res.ok:
            bad = res.failed or res.pending
            raise RuntimeError(f"fig7 {phase} sweep incomplete: "
                               f"failed={res.failed} "
                               f"pending={res.pending} (first: {bad[0]})")

    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        out_dir = sweep_dir if sweep_dir is not None else tmp
        res1 = execute_cells(fixed, out_dir=out_dir, jobs=jobs)
        _check(res1, "fixed")
        s1 = {nm: rec["summary"] for nm, rec in res1.records.items()}
        f_target = s1["fig7/fedepm/sync"]["f_final"]
        cell_ctx = {
            "fig7/fedepm/deadline": {"f_target": f_target},
            "fig7/fedepm/async": {"f_target": f_target},
            "fig7/trace/fedepm/async":
                {"f_target": s1["fig7/trace/fedepm/sync"]["f_final"]},
            "fig7/trace/sfedavg/async":
                {"f_target": s1["fig7/trace/sfedavg/sync"]["f_final"]},
        }
        res2 = execute_cells(races, out_dir=out_dir, jobs=jobs,
                             runner="benchmarks.fig7_async:race_cell",
                             cell_ctx=cell_ctx)
        _check(res2, "race")
        s2 = {nm: rec["summary"] for nm, rec in res2.records.items()}
        if sweep_dir is not None:
            write_merged(pathlib.Path(sweep_dir) / "merged.json",
                         fixed + races, {**res1.records, **res2.records},
                         meta={"name": "fig7"})

    # -- 1. uncompressed time-to-target race -------------------------------
    sync_t = s1["fig7/fedepm/sync"]["sim_time_s"]
    rows = [("fig7/sync/time_to_target", sync_t * 1e6,
             f"f_target={f_target:.6f};rounds={rounds}")]
    times = {"sync": sync_t}
    for policy in ("deadline", "async"):
        r = s2[f"fig7/fedepm/{policy}"]
        t_hit = times[policy] = r["t_hit"]
        extra = ""
        if policy == "async":
            extra = (f";buffer={buffer_k};staleness_max="
                     f"{r['staleness_max']}")
        if t_hit is None:
            # e.g. deadline: dropped-straggler bias can floor the objective
            # JUST above the sync endpoint -- that plateau is the finding
            extra += ";NOT_REACHED"
        rows.append((
            f"fig7/{policy}/time_to_target",
            (t_hit or 0.0) * 1e6,
            f"f={r['f']:.6f};events={r['events']};"
            f"bytes={r['bytes_total']:.0f}" + extra))

    for policy in ("deadline", "async"):
        t_hit = times[policy]
        rows.append((
            f"fig7/{policy}/speedup_vs_sync",
            0.0 if not t_hit else times["sync"] / t_hit,
            f"sync={times['sync']:.4g}s;" + (
                f"{policy}={t_hit:.4g}s" if t_hit
                else f"{policy}=NOT_REACHED")))

    # -- 2. codec bias: memoryless vs error feedback (async transport) -----
    f_raw = s1["fig7/fedepm/async/raw"]["f_final"]
    gaps = {}
    for tag, cell_name in (
            ("memoryless", "fig7/fedepm/async/codec-memoryless"),
            ("error_feedback", "fig7/fedepm/async/codec-ef")):
        sc = s1[cell_name]
        gaps[tag] = abs(sc["f_final"] - f_raw)
        rows.append((f"fig7/codec/gap_{tag}", gaps[tag],
                     f"f={sc['f_final']:.6f};f_raw={f_raw:.6f};"
                     f"bytes_up={sc['bytes_up']:.0f}"))
    rows.append((
        "fig7/codec/ef_gap_shrink",
        0.0 if gaps["error_feedback"] == 0
        else gaps["memoryless"] / gaps["error_feedback"],
        f"memoryless={gaps['memoryless']:.2e};"
        f"ef={gaps['error_feedback']:.2e}"))

    # -- 3. cross-algorithm cells on a trace-resampled fleet ---------------
    # identical client-level async semantics for every algorithm: same
    # event engine, concurrency cap, buffer and staleness weighting; the
    # baselines anchor eq. (34) on the cohort via the agg_mask round hook
    for alg in ("fedepm", "sfedavg"):
        tsync_t = s1[f"fig7/trace/{alg}/sync"]["sim_time_s"]
        r = s2[f"fig7/trace/{alg}/async"]
        t_hit = r["t_hit"]
        rows.append((
            f"fig7/trace/{alg}/time_to_target", (t_hit or 0.0) * 1e6,
            f"f={r['f']:.6f};f_target={r['f_target']:.6f};"
            f"events={r['events']};"
            f"cap={cap};buffer={buffer_k};"
            f"staleness_max={r['staleness_max']};"
            f"trace={pathlib.Path(str(trace_file)).name}"
            + ("" if t_hit else ";NOT_REACHED")))
        rows.append((
            f"fig7/trace/{alg}/speedup_vs_sync",
            0.0 if not t_hit else tsync_t / t_hit,
            f"sync={tsync_t:.4g}s;" + (
                f"async={t_hit:.4g}s" if t_hit else "async=NOT_REACHED")))
    return rows


def export_trace(trace_out, events_out=None, *, d: int = 4000, m: int = 32,
                 k0: int = 8, rho: float = 0.5, rounds: int = 60,
                 n: int = 14, seed: int = 0, alpha: float = 1.2) -> dict:
    """Run the fig7 async cell with telemetry and export its timeline.

    One buffered-async run (buffer = cohort/2, concurrency cap = cohort/2
    -- the cap is what makes the stalled-dispatch FIFO visible in the
    counter track) on the Pareto fleet; writes the Perfetto trace to
    ``trace_out`` (and the event JSONL to ``events_out`` if given) and
    returns the run summary.
    """
    cohort = max(1, round(rho * m))
    buffer_k = max(1, cohort // 2)
    spec = xspec.ExperimentSpec(
        name="fig7/async-trace", seed=seed,
        task=xspec.TaskSpec(kind="logreg", d=d, n=n, m=m),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=rho, k0=k0),
        fleet=xspec.FleetSpec(latency="pareto", latency_alpha=alpha),
        policy=xspec.PolicySpec(name="async", buffer_size=buffer_k,
                                max_concurrency=buffer_k),
        engine=xspec.EngineSpec(name="eager", rounds=rounds),
        telemetry=xspec.TelemetrySpec(
            enabled=True, trace_out=str(trace_out),
            events_jsonl=str(events_out) if events_out else None))
    return spec.build().run()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Fig. 7: async client-level aggregation benchmarks")
    ap.add_argument("--quick", action="store_true",
                    help="reduced task + short round budget (CI smoke)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="sweep-driver worker processes")
    ap.add_argument("--sweep-dir", default=None,
                    help="persistent sweep state dir (resumable; also "
                         "writes merged.json there)")
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON records to this path")
    ap.add_argument("--trace-out", default=None,
                    help="export a Perfetto trace_event JSON timeline of "
                         "the async cell (one track per client)")
    ap.add_argument("--events-out", default=None,
                    help="with --trace-out: also write the raw telemetry "
                         "event stream as JSONL")
    args = ap.parse_args(argv)
    kw = QUICK_KW if args.quick else {}
    rows = run(**kw, jobs=args.jobs, sweep_dir=args.sweep_dir)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": a, "value": b, "derived": c}
                       for a, b, c in rows], f, indent=1)
    if args.trace_out:
        export_trace(args.trace_out, args.events_out, **kw)
        print(f"fig7/trace_out,{args.trace_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
