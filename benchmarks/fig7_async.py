"""Fig. 7 (beyond-paper): asynchronous buffered aggregation + error feedback.

Two experiments on the paper logreg task under a heavy-tail (Pareto) fleet:

1. Time-to-accuracy race, uncompressed: FedEPM under sync, deadline
   (q80-calibrated cutoff) and async-buffered (buffer = half a cohort,
   FedBuff-style staleness-weighted merges) aggregation. The target is the
   objective the SYNC run ends at after the round budget; each policy
   reports the simulated wall-clock at which it first reaches that
   sync-equal objective. Headline: async reaches it in a fraction of
   sync's simulated time -- aggregation events wait for the K-th arrival
   instead of the slowest cohort straggler.

2. Compression-bias closure: the same async run with an aggressive upload
   codec (top-25%, 8-bit), memoryless vs EF21-style error feedback
   (kernels/quant ``ef_accumulate`` pair). Reported: final objective gap
   to the uncompressed async run. Headline: error feedback shrinks the
   memoryless bias by an order of magnitude at identical wire bytes.

Rows: fig7/<policy>/time_to_target,<sim_seconds * 1e6>,<derived>
      fig7/async/speedup_vs_sync,<factor>
      fig7/codec/gap_{memoryless,error_feedback},<|f - f_raw|>
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedepm
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.sim import (
    CodecConfig,
    FedSim,
    SimConfig,
    client_work_flops,
    make_latency_model,
    make_profiles,
    round_arrivals,
    tree_client_bytes,
)


def _calibrate_deadline(profiles, alpha, work, down_b, up_b, q: float = 0.8,
                        draws: int = 200, seed: int = 123) -> float:
    rng = np.random.default_rng(seed)
    lat = make_latency_model("pareto", alpha=alpha)
    t = np.concatenate([
        round_arrivals(profiles, rng, lat, work_flops=work,
                       down_bytes=down_b, up_bytes=up_b)
        for _ in range(draws)])
    return float(np.quantile(t[np.isfinite(t)], q))


def _build(policy, *, cfg, state, batches, loss, profiles, seed, alpha,
           deadline=math.inf, buffer_size=0, codec=None):
    sim_cfg = SimConfig(policy=policy, deadline=deadline,
                        latency="pareto", latency_alpha=alpha, seed=seed,
                        buffer_size=buffer_size, codec=codec)
    return FedSim(alg="fedepm", cfg=cfg, state=state, batches=batches,
                  loss_fn=loss, profiles=profiles, sim=sim_cfg)


def _race(sim, fobj, m, f_target: float, max_events: int):
    """-> (sim seconds to first f <= f_target, events used, final f)."""
    t_hit = None
    f = math.inf
    for _ in range(max_events):
        sim.step()
        f = float(fobj(sim.state.w_tau)) / m
        if t_hit is None and f <= f_target:
            t_hit = sim.t
            break
    return t_hit, sim.round_idx, f


def run(d: int = 4000, m: int = 32, k0: int = 8, rho: float = 0.5,
        rounds: int = 60, n: int = 14, seed: int = 0, alpha: float = 1.2):
    X, y = synth.adult_like(d=d, n=n, seed=seed)
    batches = jax.tree_util.tree_map(
        jnp.asarray, partition_iid(X, y, m=m, seed=seed))
    loss = make_logistic_loss()
    fobj = jax.jit(lambda w: fedepm.global_objective(loss, w, batches))

    cfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=rho, k0=k0, eps_dp=0.0)
    state = fedepm.init_state(jax.random.PRNGKey(seed), jnp.zeros(n), cfg)
    profiles = make_profiles(m, seed=seed)
    down_b = float(tree_client_bytes(jnp.zeros(n)))
    work = client_work_flops("fedepm", k0=k0, n_params=n, d_local=d / m)
    deadline = _calibrate_deadline(profiles, alpha, work, down_b, down_b)
    cohort = max(1, round(rho * m))
    buffer_k = max(1, cohort // 2)

    mk = dict(cfg=cfg, state=state, batches=batches, loss=loss,
              profiles=profiles, seed=seed, alpha=alpha)

    # -- 1. uncompressed time-to-target race -------------------------------
    sync = _build("sync", **mk)
    for _ in range(rounds):
        sync.step()
    f_target = float(fobj(sync.state.w_tau)) / m

    rows = [(f"fig7/sync/time_to_target", sync.t * 1e6,
             f"f_target={f_target:.6f};rounds={rounds}")]
    times = {"sync": sync.t}
    # generous event budgets: one async event does buffer_k/cohort of a
    # round's work; a deadline round drops stragglers and may need extras
    budgets = {"deadline": rounds * 3,
               "async": math.ceil(rounds * 3 * cohort / buffer_k)}
    for policy in ("deadline", "async"):
        sim = _build(policy, deadline=deadline,
                     buffer_size=buffer_k if policy == "async" else 0, **mk)
        t_hit, events, f = _race(sim, fobj, m, f_target, budgets[policy])
        times[policy] = t_hit
        extra = ""
        if policy == "async":
            extra = (f";buffer={buffer_k};staleness_max="
                     f"{max(mm.staleness_max for mm in sim.metrics)}")
        if t_hit is None:
            # e.g. deadline: dropped-straggler bias can floor the objective
            # JUST above the sync endpoint -- that plateau is the finding
            extra += ";NOT_REACHED"
        rows.append((
            f"fig7/{policy}/time_to_target",
            (t_hit or 0.0) * 1e6,
            f"f={f:.6f};events={events};bytes={sim.ledger.total:.0f}"
            + extra))

    for policy in ("deadline", "async"):
        t_hit = times[policy]
        rows.append((
            f"fig7/{policy}/speedup_vs_sync",
            0.0 if not t_hit else times["sync"] / t_hit,
            f"sync={times['sync']:.4g}s;" + (
                f"{policy}={t_hit:.4g}s" if t_hit
                else f"{policy}=NOT_REACHED")))

    # -- 2. codec bias: memoryless vs error feedback (async transport) -----
    async_events = math.ceil(rounds * cohort / buffer_k)
    base = _build("async", buffer_size=buffer_k, **mk)
    for _ in range(async_events):
        base.step()
    f_raw = float(fobj(base.state.w_tau)) / m

    gaps = {}
    for tag, ef in (("memoryless", False), ("error_feedback", True)):
        codec = CodecConfig(topk_frac=0.25, bits=8, error_feedback=ef)
        sim = _build("async", buffer_size=buffer_k, codec=codec, **mk)
        for _ in range(async_events):
            sim.step()
        f = float(fobj(sim.state.w_tau)) / m
        gaps[tag] = abs(f - f_raw)
        rows.append((f"fig7/codec/gap_{tag}", gaps[tag],
                     f"f={f:.6f};f_raw={f_raw:.6f};"
                     f"bytes_up={sim.ledger.total_up:.0f}"))
    rows.append((
        "fig7/codec/ef_gap_shrink",
        0.0 if gaps["error_feedback"] == 0
        else gaps["memoryless"] / gaps["error_feedback"],
        f"memoryless={gaps['memoryless']:.2e};"
        f"ef={gaps['error_feedback']:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
