"""Property-based sweep of the async event loop (optional: hypothesis).

Skipped wholesale when hypothesis is not installed -- the SAME property
checkers run deterministically over a fixed grid in
test_engine_async.py::test_async_event_loop_properties, so tier-1 keeps
coverage either way. With hypothesis available, this module widens the
grid to randomly drawn (buffer_size, max_concurrency, staleness_exp,
seed) corners and asserts, per draw:

  * upload arrivals pop in the order a reference heapq of (finish time,
    dispatch sequence) would pop them;
  * the in-flight upload count never exceeds max_concurrency;
  * the byte ledger balances: running totals == per-event metric sums ==
    per-client row sums;
  * the scan engine's staleness histogram (from the telemetry merge
    stream) equals the eager loop's, and both account for every
    aggregated contribution.

Draws are kept small (5 aggregation events on the shared module task)
because the trajectory itself is exercised elsewhere; these tests buy
breadth over the event-interleaving knobs, not depth.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.sim import run_rounds  # noqa: E402
from repro.telemetry.events import EventRecorder  # noqa: E402

from test_engine_async import (  # noqa: E402
    build_async,
    check_inflight_never_exceeds_cap,
    check_ledger_balances,
    check_pop_order_matches_heapq,
    staleness_histogram,
    task,  # noqa: F401  (module-scoped fixture, reused by @given tests)
)

_knobs = st.fixed_dictionaries({
    "buffer_size": st.integers(min_value=2, max_value=6),
    "max_concurrency": st.sampled_from([0, 2, 3, 5, 8]),
    "staleness_exp": st.sampled_from([0.0, 0.5, 1.0, 2.0]),
})

_settings = settings(max_examples=10, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])


@_settings
@given(kw=_knobs, seed=st.integers(min_value=0, max_value=31))
def test_event_loop_properties_hold(task, kw, seed):  # noqa: F811
    kw = {k: v for k, v in kw.items() if v != 0}
    eager = build_async(task, kw, seed=seed)
    eager.attach_telemetry(EventRecorder())
    eager.run(5)
    assert check_pop_order_matches_heapq(eager.telemetry.events) > 0
    check_inflight_never_exceeds_cap(eager.telemetry.events,
                                     kw.get("max_concurrency"))
    check_ledger_balances(eager)


@_settings
@given(kw=_knobs, seed=st.integers(min_value=0, max_value=31),
       chunk=st.sampled_from([1, 2, 3, 5]))
def test_staleness_histogram_engine_invariant(task, kw, seed, chunk):  # noqa: F811
    kw = {k: v for k, v in kw.items() if v != 0}
    eager = build_async(task, kw, seed=seed)
    scan = build_async(task, kw, seed=seed)
    eager.attach_telemetry(EventRecorder())
    scan.attach_telemetry(EventRecorder())
    eager.run(5)
    run_rounds(scan, 5, chunk=chunk)
    h = staleness_histogram(eager.telemetry.events)
    assert h == staleness_histogram(scan.telemetry.events)
    assert sum(h.values()) == sum(m.n_aggregated for m in eager.metrics)
    check_ledger_balances(scan)
