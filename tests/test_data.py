"""Data substrate: synthetic generators + federated partitioners."""
import numpy as np

from repro.data import lm, partition, synth


def test_adult_like_shape_and_normalisation():
    X, y = synth.adult_like(d=1000, n=14, seed=0)
    assert X.shape == (1000, 14) and y.shape == (1000,)
    np.testing.assert_allclose(np.linalg.norm(X, axis=0), 1.0, atol=1e-4)
    assert set(np.unique(y)) <= {0.0, 1.0}
    assert 0.1 < y.mean() < 0.9


def test_adult_like_learnable():
    """An UNregularised centralized fit reaches decent accuracy -> the
    synthetic stand-in has real signal. (With the paper's beta=1e-3 the
    regularised optimum sits at ~0.74 accuracy because unit-column
    features make ||w*|| small -- measured, see DESIGN.md §8.)"""
    import jax
    import jax.numpy as jnp
    from repro.core.tasks import accuracy_logistic, make_logistic_loss
    X, y = synth.adult_like(d=4000, n=14, seed=1)
    loss = make_logistic_loss(beta=0.0)
    batch = {"x": jnp.asarray(X), "y": jnp.asarray(y),
             "mask": jnp.ones(len(y))}
    w = jnp.zeros(14)
    g = jax.jit(jax.grad(loss))
    for i in range(2000):
        w = w - 100.0 * g(w, batch)
    acc = float(accuracy_logistic(w, jnp.asarray(X), jnp.asarray(y)))
    assert acc > 0.75, acc


def test_partition_iid_covers_everything():
    X, y = synth.adult_like(d=500, n=14)
    out = partition.partition_iid(X, y, m=7, seed=0)
    assert out["x"].shape[0] == 7
    assert int(out["mask"].sum()) == 500


def test_partition_dirichlet_skew():
    X, y = synth.adult_like(d=2000, n=14)
    out = partition.partition_dirichlet(X, y, m=8, alpha=0.1, seed=0)
    assert int(out["mask"].sum()) == 2000
    # strong skew: per-client label means differ a lot
    means = []
    for i in range(8):
        mask = out["mask"][i] > 0
        if mask.sum():
            means.append(out["y"][i][mask].mean())
    assert np.std(means) > 0.08


def test_token_stream_determinism():
    s1 = lm.TokenStream(vocab=100, seed=3)
    s2 = lm.TokenStream(vocab=100, seed=3)
    r1 = s1.sample(np.random.default_rng(0), 2, 50, topic=1)
    r2 = s2.sample(np.random.default_rng(0), 2, 50, topic=1)
    np.testing.assert_array_equal(r1, r2)
    assert r1.min() >= 0 and r1.max() < 100


def test_lm_batches_shapes():
    it = lm.lm_batches(vocab=64, batch=3, seq=16, steps=2)
    b = next(it)
    assert b["tokens"].shape == (3, 16)
    assert b["targets"].shape == (3, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_federated_token_batches():
    it = lm.federated_token_batches(vocab=64, m=4, batch_per_client=2,
                                    seq=8, steps=1)
    b = next(it)
    assert b["tokens"].shape == (4, 2, 8)
