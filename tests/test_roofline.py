"""Roofline analytic model validation.

The dry-run's cost_analysis counts while-loop bodies ONCE (verified here),
so §Roofline uses the analytic FLOP model in launch/roofline.py. This test
validates that model against XLA's own counts on REDUCED configs lowered
with scans fully unrolled (where cost_analysis is trustworthy).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import roofline
from repro.models import registry


def _xla_flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    if isinstance(ca, list):  # newer jax returns one dict per device
        ca = ca[0] if ca else {}
    return ca.get("flops", 0.0)


def test_scan_body_counted_once():
    """The methodological premise: XLA cost_analysis does NOT multiply a
    while-loop body by its trip count."""
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h

    x = jnp.ones((64, 128))
    w = jnp.ones((128, 128))
    fl = _xla_flops(f, x, w)
    one_layer = 2 * 64 * 128 * 128
    assert fl < 2.5 * one_layer, fl  # ~1 body, certainly not 8


@pytest.mark.parametrize("arch", ["smollm-135m", "hubert-xlarge"])
def test_prefill_flops_analytic_vs_xla(arch):
    """Analytic forward FLOPs vs XLA on a reduced config with the layer
    stack unrolled (remat off, python-loop apply)."""
    cfg = configs.get_reduced(arch)
    cfg = dataclasses.replace(cfg, remat=False)
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 64

    from repro.models import dense as dmod

    def unrolled(params, batch):
        x, positions = dmod.embed_inputs(params, batch, cfg)
        L = cfg.n_layers
        for i in range(L):
            lp = jax.tree_util.tree_map(lambda p: p[i], params["layers"])
            x = dmod.block_forward(x, lp, cfg, positions)
        x = dmod.apply_norm(x, params["ln_f"], cfg.norm)
        return dmod.unembed(x, params, cfg)

    if cfg.family == "audio":
        batch = {"frame_embeds": jnp.ones((B, T, cfg.d_model))}
    else:
        batch = {"tokens": jnp.zeros((B, T), jnp.int32)}
    measured = _xla_flops(unrolled, params, batch)
    est = roofline.fwd_matmul_flops(cfg, B * T) \
        + roofline.attn_fwd_flops(cfg, B, T)
    # analytic should be within 2x of XLA's count (XLA adds elementwise
    # flops; we add causal-average attention)
    assert 0.5 < est / measured < 2.0, (est, measured)


def test_train_flops_scaling():
    """Train FLOPs ~ 4x forward matmuls + attention/ssd factors; ratio of
    MODEL_FLOPS (6ND) to analytic total is in a sane band."""
    for arch in configs.ALL_ARCHS:
        cfg = configs.get_config(arch)
        fl = roofline.train_flops(cfg, 256, 4096, k0=4, m=16)
        pc = roofline._param_counts(cfg)
        n_active = pc["layer_active"] * cfg.n_layers + pc["embed"] \
            + pc["unembed"] + pc.get("shared_attn_params", 0)
        model_flops = 6.0 * n_active * 256 * 4096
        ratio = model_flops / fl["total"]
        assert 0.2 < ratio < 1.6, (arch, ratio)


def test_decode_memory_bound():
    """Decode shapes must come out memory-bound (the classic result)."""
    cfg = configs.get_config("mixtral-8x7b")
    fl = roofline.decode_flops(cfg, 128, 32768)
    hb = roofline.decode_hbm_bytes(cfg, 128, 32768)
    t_c = fl["total"] / roofline.PEAK_FLOPS
    t_m = hb["total"] / roofline.HBM_BW
    assert t_m > t_c


def test_collective_chain_multiplier():
    trips = {"body2": 5, "body1": 3}
    parents = {"body2": "body1", "body1": "main"}
    assert roofline._chain_multiplier("body2", trips, parents) == 15
    assert roofline._chain_multiplier("body1", trips, parents) == 3
    assert roofline._chain_multiplier("main", trips, parents) == 1


def test_collective_seconds_from_census():
    rec = {
        "collectives": [
            {"op": "all-gather", "bytes": 1000, "computation": "body1"},
            {"op": "all-reduce", "bytes": 500, "computation": "main"},
        ],
        "while_trips": {"body1": 10},
        "while_parents": {"body1": "main"},
    }
    secs, detail = roofline.collective_seconds(rec, chips=1)
    assert detail["total_bytes"] == 1000 * 10 + 500
    assert secs == detail["total_bytes"] / roofline.ICI_BW
