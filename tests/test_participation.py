"""Partial-participation schedules (Setup VI.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import participation


def test_uniform_selects_rho_m():
    m, rho = 20, 0.3
    key = jax.random.PRNGKey(0)
    mask = participation.sample_uniform(key, m, rho)
    assert int(mask.sum()) == 6


def test_uniform_is_uniform():
    m, rho = 10, 0.5
    counts = np.zeros(m)
    for i in range(400):
        counts += np.asarray(
            participation.sample_uniform(jax.random.PRNGKey(i), m, rho))
    freq = counts / 400
    assert np.all(np.abs(freq - rho) < 0.1)


def test_coverage_guarantees_window():
    """Every client selected at least once per s0-round window => max gap
    < 2*s0 (eq. (30))."""
    m, rho, s0 = 12, 0.5, 4
    key = jax.random.PRNGKey(7)
    T = 40
    masks = jnp.stack([
        participation.sample_coverage(key, m, rho, jnp.asarray(t), s0)
        for t in range(T)])
    masks_np = np.asarray(masks)
    # window coverage: rounds [w*s0, (w+1)*s0) cover [m]
    for w in range(T // s0):
        assert masks_np[w * s0:(w + 1) * s0].any(axis=0).all()
    gap = float(participation.max_selection_gap(masks))
    assert gap < 2 * s0 + 1


def test_coverage_respects_rho():
    m, rho, s0 = 12, 0.5, 4
    mask = participation.sample_coverage(jax.random.PRNGKey(0), m, rho,
                                         jnp.asarray(3), s0)
    assert int(mask.sum()) == 6


def test_coverage_rejects_infeasible():
    with pytest.raises(ValueError):
        participation.sample_coverage(jax.random.PRNGKey(0), 10, 0.05,
                                      jnp.asarray(0), 2)


def test_remark_vi1_probability():
    """Remark VI.1: p_i = 1 - (1-rho)^{s0} ~ 0.999 for rho=.5, s0=10."""
    m, rho, s0 = 16, 0.5, 10
    misses = 0
    trials = 300
    for t in range(trials):
        sel = np.zeros(m, bool)
        for r in range(s0):
            key = jax.random.PRNGKey(t * 1000 + r)
            sel |= np.asarray(participation.sample_uniform(key, m, rho))
        misses += int((~sel).sum())
    p_hat = 1.0 - misses / (trials * m)
    assert p_hat > 0.99
