"""Partial-participation schedules (Setup VI.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import participation


def test_uniform_selects_rho_m():
    m, rho = 20, 0.3
    key = jax.random.PRNGKey(0)
    mask = participation.sample_uniform(key, m, rho)
    assert int(mask.sum()) == 6


def test_uniform_is_uniform():
    m, rho = 10, 0.5
    counts = np.zeros(m)
    for i in range(400):
        counts += np.asarray(
            participation.sample_uniform(jax.random.PRNGKey(i), m, rho))
    freq = counts / 400
    assert np.all(np.abs(freq - rho) < 0.1)


def test_coverage_guarantees_window():
    """Every client selected at least once per s0-round window => max gap
    < 2*s0 (eq. (30))."""
    m, rho, s0 = 12, 0.5, 4
    key = jax.random.PRNGKey(7)
    T = 40
    masks = jnp.stack([
        participation.sample_coverage(key, m, rho, jnp.asarray(t), s0)
        for t in range(T)])
    masks_np = np.asarray(masks)
    # window coverage: rounds [w*s0, (w+1)*s0) cover [m]
    for w in range(T // s0):
        assert masks_np[w * s0:(w + 1) * s0].any(axis=0).all()
    gap = float(participation.max_selection_gap(masks))
    assert gap < 2 * s0 + 1


def test_coverage_respects_rho():
    m, rho, s0 = 12, 0.5, 4
    mask = participation.sample_coverage(jax.random.PRNGKey(0), m, rho,
                                         jnp.asarray(3), s0)
    assert int(mask.sum()) == 6


def test_coverage_rejects_infeasible():
    with pytest.raises(ValueError):
        participation.sample_coverage(jax.random.PRNGKey(0), 10, 0.05,
                                      jnp.asarray(0), 2)


def test_max_selection_gap_known_masks():
    """Hand-built schedules with known gaps, including the implicit t=-1
    start (first selection measured from the start)."""
    # client 0 picked at t=0,3 (gap 3); client 1 at t=2 only (gap 3: 2-(-1))
    masks = jnp.asarray([[1, 0], [0, 0], [0, 1], [1, 0]], dtype=bool)
    assert int(participation.max_selection_gap(masks)) == 3
    # every round, everyone: all gaps are 1
    assert int(participation.max_selection_gap(
        jnp.ones((5, 3), bool))) == 1
    # a client selected only once, late: the start-to-first gap dominates
    masks = jnp.zeros((6, 2), bool).at[:, 0].set(True).at[5, 1].set(True)
    assert int(participation.max_selection_gap(masks)) == 6
    # never-selected clients contribute no gap-at-selection entries
    masks = jnp.ones((4, 2), bool).at[:, 1].set(False)
    assert int(participation.max_selection_gap(masks)) == 1


@pytest.mark.parametrize("m,s0", [(13, 5), (12, 5), (7, 3), (10, 4)])
def test_coverage_window_bound_noneven_chunks(m, s0):
    """Eq. (30) over MULTIPLE windows when m % s0 != 0: the cyclic chunking
    must still cover [m] inside every window, so the max selection gap stays
    < 2*s0 across window boundaries."""
    assert m % s0 != 0  # the edge this test pins
    rho = max(0.5, -(-m // s0) / m + 0.05)  # keep rho*m >= ceil(m/s0)
    key = jax.random.PRNGKey(11)
    T = 6 * s0
    masks = jnp.stack([
        participation.sample_coverage(key, m, rho, jnp.asarray(t), s0)
        for t in range(T)])
    masks_np = np.asarray(masks)
    for w in range(T // s0):
        window = masks_np[w * s0:(w + 1) * s0]
        assert window.any(axis=0).all(), f"window {w} missed a client"
    gap = int(participation.max_selection_gap(masks))
    assert gap < 2 * s0, f"eq. (30) violated: gap={gap} >= 2*s0={2 * s0}"
    # selection budget respected every round
    n_sel = max(1, int(round(rho * m)))
    assert (masks_np.sum(axis=1) == n_sel).all()


def test_coverage_mandatory_chunk_cyclic_wraparound():
    """With m % s0 != 0 the last window position wraps cyclically; the
    mandatory chunk must still be ceil(m/s0) DISTINCT clients."""
    m, s0 = 13, 5
    chunk = -(-m // s0)
    key = jax.random.PRNGKey(3)
    for pos in range(s0):
        mask = participation.sample_coverage(key, m, 0.5, jnp.asarray(pos),
                                             s0)
        assert int(mask.sum()) == max(1, round(0.5 * m))
        # the chunk wraps: (pos*chunk + [0..chunk)) % m are all distinct
        idx = (pos * chunk + np.arange(chunk)) % m
        assert len(set(idx.tolist())) == chunk


def test_remark_vi1_probability():
    """Remark VI.1: p_i = 1 - (1-rho)^{s0} ~ 0.999 for rho=.5, s0=10."""
    m, rho, s0 = 16, 0.5, 10
    misses = 0
    trials = 300
    for t in range(trials):
        sel = np.zeros(m, bool)
        for r in range(s0):
            key = jax.random.PRNGKey(t * 1000 + r)
            sel |= np.asarray(participation.sample_uniform(key, m, rho))
        misses += int((~sel).sum())
    p_hat = 1.0 - misses / (trials * m)
    assert p_hat > 0.99
