"""Multi-cell sweep driver (repro.launch.sweep_run) + benchmark runner.

Pins the driver's contract:

  * [sweep] FILES -- load_sweep expands the cross-product in grid order
    (last axis fastest, seeds innermost) and rejects malformed tables.
  * RESUMABILITY -- every cell writes an atomic result file; a run killed
    after N of M cells re-executes exactly M-N on rerun, and the merged
    artifact is byte-identical to an uninterrupted run's.
  * DETERMINISM -- the merged artifact is byte-identical between
    --jobs 1 and --jobs 4 (the wall-clock telemetry fields are stripped
    at merge; everything else is a pure function of the spec).
  * FAILURE IS LOUD -- a failing cell fails the invocation (no merge,
    nonzero exit), and a rerun re-executes only the failed cells.

Plus the benchmark-runner satellites: benchmarks/run.py exits nonzero
when any module fails (while still running the others), and
tools/append_bench_trajectory.py replaces re-run labels in place and
warns when a replacement row loses fields.
"""
from __future__ import annotations

import importlib.util
import json
import pathlib
import shutil

import pytest

from repro.launch import sweep_run
from repro.spec import (
    AlgorithmSpec,
    EngineSpec,
    ExperimentSpec,
    FleetSpec,
    PolicySpec,
    SpecError,
    TaskSpec,
    load_sweep,
    sweep,
)
from repro.spec.sweep import parse_sweep_table

ROOT = pathlib.Path(__file__).resolve().parent.parent
TRACE_CSV = ROOT / "tests" / "fixtures" / "device_trace.csv"

BASE = ExperimentSpec(
    name="t", seed=0,
    task=TaskSpec(kind="logreg", d=600, n=14, m=4),
    algorithm=AlgorithmSpec(name="fedepm", rho=0.5, k0=2),
    engine=EngineSpec(name="eager", rounds=2))


def _grid():
    return sweep(BASE, {"algorithm.name": ["fedepm", "sfedavg"]},
                 seeds=[0, 1])


SWEEP_TOML = """\
name = "t"
seed = 0

[task]
kind = "logreg"
d = 600
n = 14
m = 4

[algorithm]
name = "fedepm"
rho = 0.5
k0 = 2

[engine]
name = "eager"
rounds = 2

[sweep]
"algorithm.name" = ["fedepm", "sfedavg"]
seeds = [0, 1]
"""


# ---------------------------------------------------------------------------
# [sweep] table loading
# ---------------------------------------------------------------------------

def test_load_sweep_expands_in_grid_order(tmp_path):
    f = tmp_path / "grid.toml"
    f.write_text(SWEEP_TOML)
    base, cells = load_sweep(f)
    assert base.name == "t" and len(cells) == 4
    # axis outermost, seeds innermost; every cell validated + self-named
    assert [c.name for c in cells] == [
        "t/algorithm.name=fedepm/s0", "t/algorithm.name=fedepm/s1",
        "t/algorithm.name=sfedavg/s0", "t/algorithm.name=sfedavg/s1"]
    assert [(c.algorithm.name, c.seed) for c in cells] == [
        ("fedepm", 0), ("fedepm", 1), ("sfedavg", 0), ("sfedavg", 1)]
    # a plain single-cell file is a 1-cell grid
    f2 = tmp_path / "single.toml"
    f2.write_text(SWEEP_TOML.split("[sweep]")[0])
    base2, cells2 = load_sweep(f2)
    assert len(cells2) == 1 and cells2[0] == base2.validate()


def test_load_sweep_rejects_malformed_tables(tmp_path):
    head = SWEEP_TOML.split("[sweep]")[0]
    for table, match in [
            ('[sweep]\n"algorithm.name" = "fedepm"\n', "list"),
            ('[sweep]\n"algorithm.name" = []\n', "empty"),
            ("[sweep]\nseeds = [0, true]\n", "ints"),
            ("[sweep]\n", "no axes"),
            ('[sweep]\n"algorithm.nope" = [1]\n', "unknown"),
    ]:
        f = tmp_path / "bad.toml"
        f.write_text(head + table)
        with pytest.raises(SpecError, match=match):
            load_sweep(f)
    # axis order = table key order; seeds never an axis
    axes, seeds = parse_sweep_table(
        {"policy.deadline": [0.1], "seeds": [0, 1], "algorithm.k0": [2]})
    assert list(axes) == ["policy.deadline", "algorithm.k0"]
    assert seeds == [0, 1]


# ---------------------------------------------------------------------------
# driver: end-to-end, resume, determinism
# ---------------------------------------------------------------------------

def _merged_bytes(out_dir, cells, records):
    path = pathlib.Path(out_dir) / "merged.json"
    sweep_run.write_merged(path, cells, records, meta={"name": "t"})
    return path.read_bytes()


def test_execute_cells_end_to_end(tmp_path):
    cells = _grid()
    res = sweep_run.execute_cells(cells, out_dir=tmp_path)
    assert res.ok and sorted(res.executed) == sorted(c.name for c in cells)
    assert list(res.records) == [c.name for c in cells]  # grid order
    rec = res.records[cells[0].name]
    assert rec["status"] == "ok" and rec["wall_s"] > 0
    # the default runner attaches run telemetry; per-cell files keep the
    # wall-clock fields, the merged artifact strips them
    assert "wall_s" in rec["summary"]["telemetry"]
    merged = json.loads(_merged_bytes(tmp_path, cells, res.records))
    assert merged["kind"] == "sweep" and merged["n_cells"] == 4
    cell0 = merged["cells"][cells[0].name]
    assert "telemetry" in cell0
    assert "wall_s" not in cell0["telemetry"]
    assert "rounds_per_sec_wall" not in cell0["telemetry"]
    assert cell0["f_final"] == rec["summary"]["f_final"]
    # a second invocation skips every cell (fingerprint match)...
    res2 = sweep_run.execute_cells(cells, out_dir=tmp_path)
    assert res2.ok and not res2.executed and len(res2.skipped) == 4
    # ...but a changed ctx invalidates the fingerprint
    res3 = sweep_run.execute_cells(cells, out_dir=tmp_path,
                                   ctx={"telemetry": False})
    assert res3.ok and len(res3.executed) == 4
    with pytest.raises(ValueError, match="duplicate"):
        sweep_run.execute_cells([cells[0], cells[0]], out_dir=tmp_path)
    with pytest.raises(ValueError, match="unknown cell"):
        sweep_run.execute_cells(cells, out_dir=tmp_path,
                                cell_ctx={"nope": {}})


def test_kill_resume_and_jobs_give_identical_merged(tmp_path):
    cells = _grid()
    # reference: uninterrupted --jobs 1 run
    a = tmp_path / "a"
    res_a = sweep_run.execute_cells(cells, out_dir=a)
    bytes_a = _merged_bytes(a, cells, res_a.records)

    # killed after 2 of 4 cells (max_cells = the deterministic kill)
    b = tmp_path / "b"
    part = sweep_run.execute_cells(cells, out_dir=b, max_cells=2)
    assert not part.ok and len(part.executed) == 2
    assert part.pending == [c.name for c in cells[2:]]
    with pytest.raises(ValueError, match="no ok result"):
        sweep_run.write_merged(b / "merged.json", cells, part.records,
                               meta={})
    # the rerun executes EXACTLY the 4-2 missing cells
    rest = sweep_run.execute_cells(cells, out_dir=b)
    assert rest.ok and len(rest.skipped) == 2
    assert rest.executed == [c.name for c in cells[2:]]
    assert _merged_bytes(b, cells, rest.records) == bytes_a

    # same grid across 4 worker processes: byte-identical artifact
    c = tmp_path / "c"
    res_c = sweep_run.execute_cells(cells, out_dir=c, jobs=4)
    assert res_c.ok
    assert _merged_bytes(c, cells, res_c.records) == bytes_a


def test_failed_cell_is_loud_and_rerun_reexecutes_only_it(tmp_path):
    # a cell that validates but cannot build: trace fleet whose file
    # appears only later (exactly the transient-failure resume story)
    trace = tmp_path / "trace.csv"
    bad = BASE.replace(**{"name": "t/bad"}).replace(
        fleet=FleetSpec(kind="trace", trace_file=str(trace))).validate()
    cells = [*sweep(BASE, {"algorithm.name": ["fedepm", "sfedavg"]}), bad]
    out = tmp_path / "sweep"
    res = sweep_run.execute_cells(cells, out_dir=out)
    assert not res.ok and res.failed == ["t/bad"]
    rec = res.records["t/bad"]
    assert rec["status"] == "failed" and "traceback" in rec
    with pytest.raises(ValueError, match="no ok result"):
        sweep_run.write_merged(out / "merged.json", cells, res.records,
                               meta={})
    # rerun: the ok cells are skipped, the failed one re-executes -- and
    # succeeds now that the fixture exists
    shutil.copy(TRACE_CSV, trace)
    res2 = sweep_run.execute_cells(cells, out_dir=out)
    assert res2.ok and res2.executed == ["t/bad"]
    assert len(res2.skipped) == 2


def test_cli_exit_codes_and_resume(tmp_path):
    f = tmp_path / "grid.toml"
    f.write_text(SWEEP_TOML)
    out = tmp_path / "out"
    argv = ["--spec", str(f), "--out-dir", str(out), "--quiet"]
    assert sweep_run.main([*argv, "--max-cells", "1"]) \
        == sweep_run.EXIT_PENDING
    assert not (out / "merged.json").exists()
    assert sweep_run.main(argv) == sweep_run.EXIT_OK
    merged = json.loads((out / "merged.json").read_text())
    assert merged["n_cells"] == 4 and merged["name"] == "t"
    assert merged["axes"] == {"algorithm.name": ["fedepm", "sfedavg"]}
    assert merged["seeds"] == [0, 1]
    # idempotent: a third run skips everything, same artifact bytes
    before = (out / "merged.json").read_bytes()
    assert sweep_run.main(argv) == sweep_run.EXIT_OK
    assert (out / "merged.json").read_bytes() == before


def test_cell_filename_is_safe_and_collision_free():
    a = sweep_run.cell_filename("fig7/fedepm/async/codec-ef")
    assert "/" not in a and a.endswith(".json")
    # names differing only past the truncation point stay distinct
    long_a = sweep_run.cell_filename("x" * 100 + "a")
    long_b = sweep_run.cell_filename("x" * 100 + "b")
    assert long_a != long_b


# ---------------------------------------------------------------------------
# benchmarks/run.py: failures must fail the invocation
# ---------------------------------------------------------------------------

def test_benchmark_runner_exits_nonzero_but_isolates(monkeypatch, capsys):
    from benchmarks import ens_kernel, fig2_accuracy
    from benchmarks import run as bench_run

    def boom(**kw):
        raise RuntimeError("synthetic benchmark failure")

    monkeypatch.setattr(fig2_accuracy, "run", boom)
    monkeypatch.setattr(ens_kernel, "run",
                        lambda **kw: [("ens/stub", 1.0, "ok")])
    rc = bench_run.main(["--quick", "--only", "fig2,ens"])
    out = capsys.readouterr()
    # the failed module is an ERROR row, the later module still ran --
    # and the invocation as a whole reports failure
    assert "fig2/ERROR,0,RuntimeError:synthetic benchmark failure" in out.out
    assert "ens/stub,1.0,ok" in out.out
    assert "fig2" in out.err and rc == 1

    monkeypatch.setattr(fig2_accuracy, "run",
                        lambda **kw: [("fig2/stub", 2.0, "ok")])
    assert bench_run.main(["--quick", "--only", "fig2,ens"]) == 0


def test_benchmark_runner_forwards_jobs_uniformly(monkeypatch, capsys):
    """--jobs reaches EVERY spec-grid module (fig6/fig7/fig8/fig9/
    engine) -- the sweep-driver parallelism knob is uniform, not
    per-module."""
    from benchmarks import (bench_engine, fig6_stragglers, fig7_async,
                            fig8_faults, fig9_privacy)
    from benchmarks import run as bench_run

    seen = {}

    def record(name):
        def fake_run(**kw):
            seen[name] = kw
            return [(f"{name}/stub", 1.0, "ok")]
        return fake_run

    monkeypatch.setattr(fig6_stragglers, "run", record("fig6"))
    monkeypatch.setattr(fig7_async, "run", record("fig7"))
    monkeypatch.setattr(fig8_faults, "run", record("fig8"))
    monkeypatch.setattr(fig9_privacy, "run", record("fig9"))
    monkeypatch.setattr(bench_engine, "run", record("engine"))
    rc = bench_run.main(["--quick", "--jobs", "3",
                         "--only", "fig6,fig7,fig8,fig9,engine"])
    out = capsys.readouterr().out
    assert rc == 0
    assert set(seen) == {"fig6", "fig7", "fig8", "fig9", "engine"}
    for name, kw in seen.items():
        assert kw.get("jobs") == 3, f"{name} did not receive --jobs"
        assert f"{name}/stub,1.0,ok" in out


# ---------------------------------------------------------------------------
# tools/append_bench_trajectory.py: in-place replace + field-loss warning
# ---------------------------------------------------------------------------

def _load_trajectory_tool():
    tool = ROOT / "tools" / "append_bench_trajectory.py"
    spec = importlib.util.spec_from_file_location("append_traj_tool", tool)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _engine_summary(rps=100.0, with_async=True):
    def eng(r):
        return {"rounds_per_sec": r, "wall_to_target_s": 0.5,
                "rounds_to_target": 10, "host_syncs": 20,
                "host_syncs_per_round": 2.0}
    s = {"config": {"backend": "cpu", "d": 2000, "m": 16, "rounds": 120},
         "engines": {"eager": eng(rps), "scan": eng(rps * 4)},
         "speedup_rounds_per_sec": 4.0, "speedup_wall_to_target": 2.0,
         "target_objective": 0.5}
    if with_async:
        s["async"] = {"config": {"buffer_size": 4, "max_concurrency": 6},
                      "engines": {"eager": {"rounds_per_sec": rps / 2,
                                            "host_syncs": 5,
                                            "host_syncs_per_round": 0.5},
                                  "scan": {"rounds_per_sec": rps,
                                           "host_syncs": 1,
                                           "host_syncs_per_round": 0.1}},
                      "speedup_rounds_per_sec": 2.0}
    return s


def test_trajectory_append_replaces_in_place(tmp_path, capsys):
    tool = _load_trajectory_tool()
    ej = tmp_path / "BENCH_engine.json"
    out = tmp_path / "BENCH_trajectory.json"

    ej.write_text(json.dumps(_engine_summary(rps=100.0)))
    tool.append(ej, out, "pr1")
    ej.write_text(json.dumps(_engine_summary(rps=200.0)))
    tool.append(ej, out, "pr2")
    doc = json.loads(out.read_text())
    assert [r["label"] for r in doc["rows"]] == ["pr1", "pr2"]

    # re-running pr1 replaces ITS row, in place: order is stable and the
    # numbers change
    ej.write_text(json.dumps(_engine_summary(rps=300.0)))
    tool.append(ej, out, "pr1")
    doc = json.loads(out.read_text())
    assert [r["label"] for r in doc["rows"]] == ["pr1", "pr2"]
    assert doc["rows"][0]["eager_rounds_per_sec"] == 300.0
    assert "async_eager_rounds_per_sec" in doc["rows"][0]
    assert capsys.readouterr().err == ""

    # a replacement that LOST the async block warns on stderr
    ej.write_text(json.dumps(_engine_summary(rps=300.0, with_async=False)))
    tool.append(ej, out, "pr1")
    err = capsys.readouterr().err
    assert "warning" in err and "async_eager_rounds_per_sec" in err
    doc = json.loads(out.read_text())
    assert [r["label"] for r in doc["rows"]] == ["pr1", "pr2"]
    assert "async_eager_rounds_per_sec" not in doc["rows"][0]


def _fig9_rows(*, fedepm_snr="True", mask=True):
    rows = [
        {"name": "fig9/fedepm/snr_increases_with_eps", "value": 0.0,
         "derived": fedepm_snr},
        {"name": "fig9/sfedavg/snr_increases_with_eps", "value": 0.0,
         "derived": "True"},
        {"name": "fig9/fedepm/cr_stable_in_eps", "value": 0.0,
         "derived": "True"},
        {"name": "fig9/sfedavg/cr_stable_in_eps", "value": 0.0,
         "derived": "True"},
        {"name": "fig9/fedepm_smallest_SNR", "value": 0.0,
         "derived": "True"},
    ]
    if mask:
        rows.append({"name": "fig9/fedepm/secure_agg/mask_overhead",
                     "value": 7680.0, "derived": "mask_attempts=240"})
    return rows


def test_trajectory_fig9_merge(tmp_path):
    tool = _load_trajectory_tool()
    ej = tmp_path / "BENCH_engine.json"
    f9 = tmp_path / "fig9_privacy.json"
    out = tmp_path / "BENCH_trajectory.json"
    ej.write_text(json.dumps(_engine_summary()))

    f9.write_text(json.dumps(_fig9_rows()))
    tool.append(ej, out, "pr1", fig9_json=f9)
    row = json.loads(out.read_text())["rows"][0]
    assert row["fig9_snr_increases_with_eps"] is True
    assert row["fig9_cr_stable_in_eps"] is True
    assert row["fig9_fedepm_smallest_snr"] is True
    assert row["fig9_secure_agg_mask_bytes"] == 7680.0

    # per-algorithm claim verdicts are ANDed: one failing algorithm
    # flips the trajectory field (derived is a stringified bool)
    f9.write_text(json.dumps(_fig9_rows(fedepm_snr="False")))
    tool.append(ej, out, "pr1", fig9_json=f9)
    row = json.loads(out.read_text())["rows"][0]
    assert row["fig9_snr_increases_with_eps"] is False

    # a missing claim row is a loud error, not a silently absent field
    f9.write_text(json.dumps(
        [r for r in _fig9_rows() if "smallest" not in r["name"]]))
    with pytest.raises(SystemExit, match="fedepm_smallest_SNR"):
        tool.append(ej, out, "pr1", fig9_json=f9)

    # without --fig9-json the row simply lacks the fields (old history
    # rows stay valid)
    tool.append(ej, out, "pr2")
    row = json.loads(out.read_text())["rows"][1]
    assert not any(k.startswith("fig9_") for k in row)
