"""ENS kernel validation: Pallas (interpret) and jnp ref vs brute-force
oracle, plus property-based tests of the Lemma III.1/III.2 solution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional: on a bare environment only the property-based
# tests skip; the kernel-vs-oracle validation still runs
try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.kernels.ens import ops, ref

jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 16, 33])
@pytest.mark.parametrize("n", [1, 7, 128, 513])
@pytest.mark.parametrize("lam_eta", [(0.5, 1.0), (1e-3, 2e-3), (2.0, 0.5)])
def test_ref_matches_oracle(m, n, lam_eta):
    lam, eta = lam_eta
    key = jax.random.PRNGKey(m * 1000 + n)
    Z = jax.random.normal(key, (m, n)) * 3.0
    w_ref = ref.ens_ref(Z, lam, eta)
    w_orc = ref.ens_oracle(Z, lam, eta)
    # near-ties can make the fp32 brute-force argmin pick the wrong knot;
    # the meaningful check is on the OBJECTIVE (in float64)
    Z64 = np.asarray(Z, np.float64)

    def obj(w):
        d = np.asarray(w, np.float64)[None, :] - Z64
        return np.sum(lam * np.abs(d) + eta / 2 * d * d, axis=0)

    assert np.all(obj(w_ref) <= obj(w_orc) + 1e-6 * (1 + np.abs(obj(w_orc))))


@pytest.mark.parametrize("m", [2, 4, 16, 50])
@pytest.mark.parametrize("n", [64, 500, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_ref(m, n, dtype):
    lam, eta = 0.3, 0.9
    key = jax.random.PRNGKey(m + n)
    Z = (jax.random.normal(key, (m, n)) * 2.0).astype(dtype)
    w_pal = ops.ens(Z, lam, eta, impl="pallas", block_n=128, interpret=True)
    w_ref = ref.ens_ref(Z.astype(jnp.float32), lam, eta)
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(w_pal, np.float32), w_ref,
                               atol=atol, rtol=1e-2)


def test_objective_is_minimised_at_ens():
    """ENS output beats 1000 random perturbations on the true objective."""
    key = jax.random.PRNGKey(0)
    m, n = 9, 37
    lam, eta = 0.7, 1.3
    Z = jax.random.normal(key, (m, n)) * 2.0
    w = ref.ens_ref(Z, lam, eta)
    base = ref.ens_objective(Z, w, lam, eta)  # (n,)
    for i in range(20):
        pert = w + jax.random.normal(jax.random.fold_in(key, i), (n,)) * 0.1
        obj = ref.ens_objective(Z, pert, lam, eta)
        assert bool(jnp.all(obj >= base - 1e-5))


if hypothesis is not None:
    _given_properties = hypothesis.given(
        Z=hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                                  min_side=1, max_side=24),
                     elements=st.floats(-50, 50, width=32)),
        lam=st.floats(1e-4, 5.0),
        ratio=st.floats(0.1, 10.0),
    )
    _settings_properties = hypothesis.settings(deadline=None, max_examples=40)
else:
    _given_properties = pytest.mark.skip(reason="hypothesis not installed")
    _settings_properties = lambda f: f  # noqa: E731


@_settings_properties
@_given_properties
def test_properties(Z, lam, ratio):
    eta = lam * ratio
    Z = jnp.asarray(Z)
    m, n = Z.shape
    w = ref.ens_ref(Z, lam, eta)
    # (1) bounded by the per-coordinate extremes of the candidate set
    lo = jnp.min(Z, axis=0) - lam / eta
    hi = jnp.max(Z, axis=0) + lam / eta
    assert bool(jnp.all(w >= lo - 1e-4)) and bool(jnp.all(w <= hi + 1e-4))
    # (2) translation equivariance
    w_shift = ref.ens_ref(Z + 5.0, lam, eta)
    np.testing.assert_allclose(w_shift, w + 5.0, atol=1e-4)
    # (3) permutation invariance over clients
    perm = np.random.RandomState(0).permutation(m)
    np.testing.assert_allclose(ref.ens_ref(Z[perm], lam, eta), w, atol=1e-5)
    # (4) idempotence: all clients equal => that value exactly
    Zc = jnp.broadcast_to(Z[:1], Z.shape)
    np.testing.assert_allclose(ref.ens_ref(Zc, lam, eta), Z[0], atol=1e-5)


def test_limits_mean_and_median():
    key = jax.random.PRNGKey(3)
    m, n = 11, 50
    Z = jax.random.normal(key, (m, n)) * 2.0
    # lam -> 0: ENS -> mean (FedAvg aggregation)
    w0 = ref.ens_ref(Z, 1e-9, 1.0)
    np.testing.assert_allclose(w0, jnp.mean(Z, axis=0), atol=1e-5)
    # eta -> 0 (lam/eta -> inf): ENS -> coordinate-wise median, eq. (5)
    w1 = ref.ens_ref(Z, 1.0, 1e-9)
    np.testing.assert_allclose(w1, jnp.median(Z, axis=0), atol=1e-4)


def test_subgradient_optimality():
    """Zero in the subdifferential at the ENS point (Lemma III.2)."""
    key = jax.random.PRNGKey(5)
    m, n = 13, 29
    lam, eta = 0.8, 1.7
    Z = jax.random.normal(key, (m, n)) * 2.0
    w = ref.ens_ref(Z, lam, eta)
    d = w[None, :] - Z                       # (m, n)
    g_smooth = eta * jnp.sum(d, axis=0)      # smooth part
    s_fixed = lam * jnp.sum(jnp.sign(jnp.where(jnp.abs(d) > 1e-6, d, 0.0)),
                            axis=0)
    slack = lam * jnp.sum((jnp.abs(d) <= 1e-6).astype(jnp.float32), axis=0)
    resid = jnp.maximum(jnp.abs(g_smooth + s_fixed) - slack, 0.0)
    assert float(jnp.max(resid)) < 1e-3


def test_paper_algorithm_documented_deviation():
    """The literal Algorithm 1 (ens_paper) disagrees with the true argmin
    in asymmetric cases -- the sign issue documented in kernels/ens/ref.py.
    We assert the *oracle-correct* implementation wins on the objective."""
    Z = jnp.asarray([[0.0, 10.0], [1.0, 12.0], [5.0, 13.0]])
    lam, eta = 1.0, 0.5
    w_paper = ref.ens_paper(Z, lam, eta)
    w_ref = ref.ens_ref(Z, lam, eta)
    obj_p = ref.ens_objective(Z, w_paper, lam, eta)
    obj_r = ref.ens_objective(Z, w_ref, lam, eta)
    assert bool(jnp.all(obj_r <= obj_p + 1e-6))


def test_ens_tree_shapes():
    key = jax.random.PRNGKey(1)
    tree = {"a": jax.random.normal(key, (5, 3, 4)),
            "b": [jax.random.normal(key, (5, 7))]}
    out = ops.ens_tree(tree, 0.1, 0.2, impl="ref")
    assert out["a"].shape == (3, 4)
    assert out["b"][0].shape == (7,)
