"""Flash attention (O(T)-memory custom VJP) vs naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import flash_attention


def naive(q, k, v, mode="causal", window=None):
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    R = H // Hkv
    qg = q.reshape(B, Tq, Hkv, R, D).astype(jnp.float32)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg,
                   k.astype(jnp.float32)) / np.sqrt(D)
    qi, ki = jnp.arange(Tq), jnp.arange(Tk)
    valid = jnp.ones((Tq, Tk), bool)
    if mode == "causal":
        valid &= ki[None] <= qi[:, None]
    if window is not None:
        valid &= (qi[:, None] - ki[None]) < window
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, H, D).astype(q.dtype)


@pytest.mark.parametrize("mode,window", [("causal", None),
                                         ("bidirectional", None),
                                         ("causal", 8)])
@pytest.mark.parametrize("chunks", [(8, 16), (16, 8), (37, 37)])
def test_forward_and_grads(mode, window, chunks):
    qc, kc = chunks
    key = jax.random.PRNGKey(0)
    B, T, H, Hkv, D = 2, 37, 6, 2, 16
    q = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))

    def f1(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            q, k, v, mode=mode, window=window, q_chunk=qc, kv_chunk=kc)))

    def f2(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, mode, window)))

    o1 = flash_attention(q, k, v, mode=mode, window=window,
                         q_chunk=qc, kv_chunk=kc)
    o2 = naive(q, k, v, mode, window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_cross_attention_shapes():
    """Tq != Tk (e.g. decode with a longer cache)."""
    key = jax.random.PRNGKey(1)
    B, Tq, Tk, H, Hkv, D = 2, 5, 29, 4, 4, 8
    q = jax.random.normal(key, (B, Tq, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Tk, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Tk, Hkv, D))
    qpos = jnp.arange(Tk - Tq, Tk)
    o = flash_attention(q, k, v, mode="causal", q_positions=qpos,
                        q_chunk=4, kv_chunk=8)
    o2 = naive(jnp.pad(q, ((0, 0), (Tk - Tq, 0), (0, 0), (0, 0))), k, v,
               "causal")[:, Tk - Tq:]
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), atol=2e-5)


def test_bf16_stability():
    key = jax.random.PRNGKey(2)
    B, T, H, D = 2, 64, 4, 32
    q = (jax.random.normal(key, (B, T, H, D)) * 5).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.fold_in(key, 1),
                           (B, T, H, D)) * 5).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, T, H, D)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, q_chunk=16, kv_chunk=16)
    assert o.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(o.astype(jnp.float32))))
