"""Distributed FedEPM equivalence: spatial (gather + a2a ENS) and temporal
executions on an 8-device fake mesh must match the single-host reference.

Runs in a SUBPROCESS so the forced host-device count never leaks into the
other tests' single-device view.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.environ["REPRO_SRC"])
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import distributed as dist_mod
from repro.core import fedepm
from repro.core.tasks import make_lm_loss
from repro.models import registry

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))

cfg = configs.get_reduced("smollm-135m")
model = registry.get_model(cfg)
loss = make_lm_loss(model.apply)
m, B, T = 4, 2, 16
fcfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=0.5, k0=3, eps_dp=0.1)

key = jax.random.PRNGKey(0)
params0 = model.init(jax.random.PRNGKey(42))
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (m, B, T), 0,
                                 cfg.vocab),
    "targets": jax.random.randint(jax.random.PRNGKey(2), (m, B, T), 0,
                                  cfg.vocab),
    "loss_mask": jnp.ones((m, B, T), jnp.float32),
}

# ---- single-host reference ----
ref_state = fedepm.init_state(key, params0, fcfg)
ref_next, ref_metrics = jax.jit(
    lambda s, b: fedepm.fedepm_round(s, b, loss, fcfg))(ref_state, batch)

results = {}
for mode, ens in [("spatial", "gather"), ("spatial", "a2a"),
                  ("temporal", "gather")]:
    dist = dist_mod.DistConfig(mode=mode, ens=ens, client_axes=("data",),
                               fsdp_axes=("data",), remat=False)
    init_fn, step_fn, sspecs_fn = dist_mod.build_fedepm(
        model, loss, fcfg, mesh, dist)
    astate = jax.eval_shape(init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))
    sspecs = sspecs_fn(astate)

    def fn(state, batches):
        return step_fn(state, batches, sspecs)

    from repro.launch.steps import _named
    jitted = jax.jit(fn, in_shardings=(_named(sspecs, mesh), None))
    # IDENTICAL initial state to the reference (same key, same params0)
    state = fedepm.init_state(key, params0, fcfg)
    nxt, metrics = jitted(state, batch)
    results[(mode, ens)] = (nxt, metrics)

def tree_maxdiff(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(la, lb))

wscale = max(float(jnp.max(jnp.abs(x))) for x in
             jax.tree_util.tree_leaves(ref_next.W))
# Z = W + DP noise; at random init the Laplace noise is enormous
# (scale ~ ||g||_1 / (eps mu)), so its tolerance must be relative to Z
zscale = max(float(jnp.max(jnp.abs(x.astype(jnp.float32)))) for x in
             jax.tree_util.tree_leaves(ref_next.Z))
for kk, (nxt, metrics) in results.items():
    dW = tree_maxdiff(nxt.W, ref_next.W)
    dw = tree_maxdiff(nxt.w_tau, ref_next.w_tau)
    dZ = tree_maxdiff(nxt.Z, ref_next.Z)
    dsel = float(jnp.sum(jnp.abs(metrics.selected.astype(jnp.int32)
                                 - ref_metrics.selected.astype(jnp.int32))))
    print(f"{kk}: dW={dW:.2e} dw_tau={dw:.2e} dZ={dZ:.2e} dsel={dsel}")
    assert dsel == 0.0, (kk, "different client selection")
    assert dw < 1e-4 * (1 + wscale), (kk, dw)
    assert dW < 1e-4 * (1 + wscale), (kk, dW)
    assert dZ < 1e-5 * (1 + zscale), (kk, dZ)
print("DISTRIBUTED-EQUIVALENCE-OK")
"""


@pytest.mark.slow
def test_spatial_temporal_match_reference():
    env = dict(os.environ)
    env["REPRO_SRC"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "DISTRIBUTED-EQUIVALENCE-OK" in out.stdout, (
        out.stdout[-3000:], out.stderr[-5000:])
