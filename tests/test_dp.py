"""Differential-privacy machinery (Sec. V)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp


def test_laplace_moments():
    key = jax.random.PRNGKey(0)
    b = 0.7
    x = dp.sample_laplace(key, (200000,), b)
    # Laplace(0, b): E|x| = b, Var = 2 b^2
    assert abs(float(jnp.mean(jnp.abs(x))) - b) < 0.02
    assert abs(float(jnp.var(x)) - 2 * b * b) < 0.05
    assert abs(float(jnp.mean(x))) < 0.02


def test_laplace_tree_shapes_dtypes():
    tree = {"a": jnp.zeros((3, 4), jnp.bfloat16), "b": jnp.zeros((7,))}
    noise = dp.laplace_tree(jax.random.PRNGKey(1), tree, 0.5)
    assert noise["a"].shape == (3, 4) and noise["a"].dtype == jnp.bfloat16
    assert noise["b"].shape == (7,)


def test_noise_scale_decays_with_mu():
    d = jnp.asarray(3.0)
    s1 = dp.fedepm_noise_scale(d, 0.1, 1.0)
    s2 = dp.fedepm_noise_scale(d, 0.1, 10.0)
    assert float(s2) == float(s1) / 10.0


def test_snr_definition():
    w = {"a": jnp.ones((100,))}
    eps = {"a": jnp.ones((100,)) * 0.1}
    # ||w|| = 10, ||eps|| = 1 -> log10(10) = 1
    assert abs(float(dp.snr_db10(w, eps)) - 1.0) < 1e-5


def test_sensitivity_surrogate():
    g = {"a": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([[0.5]])}
    assert float(dp.sensitivity_surrogate(g)) == 2.0 * 3.5


def test_clip_enforces_l1_bound():
    g = {"a": jnp.asarray([3.0, -4.0])}
    c = dp.clip_tree_l1(g, 1.0)
    from repro.core.treeutil import tree_l1_norm
    assert float(tree_l1_norm(c)) <= 1.0 + 1e-6
    g2 = {"a": jnp.asarray([0.1, 0.2])}
    c2 = dp.clip_tree_l1(g2, 1.0)
    np.testing.assert_allclose(c2["a"], g2["a"])


def test_epsilon_dp_empirical():
    """Empirical check of the eps-DP mechanism on a 1-D example: the
    Laplace mechanism output distributions for adjacent datasets satisfy
    the eq. (24) ratio bound (up to sampling error)."""
    key = jax.random.PRNGKey(2)
    eps_dp = 0.5
    delta = 1.0                      # sensitivity |f(D) - f(D')|
    b = delta / eps_dp               # standard Laplace mechanism scale
    n = 400000
    out_d = 0.0 + dp.sample_laplace(key, (n,), b)
    out_dp = delta + dp.sample_laplace(jax.random.fold_in(key, 1), (n,), b)
    bins = np.linspace(-6, 6, 25)
    h1, _ = np.histogram(np.asarray(out_d), bins=bins, density=True)
    h2, _ = np.histogram(np.asarray(out_dp), bins=bins, density=True)
    mask = (h1 > 1e-3) & (h2 > 1e-3)
    ratio = np.abs(np.log(h1[mask] / h2[mask]))
    assert np.max(ratio) <= eps_dp * 1.3  # slack for sampling error
