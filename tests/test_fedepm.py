"""FedEPM algorithm behaviour (Alg. 2) on the paper's task + baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, fedepm
from repro.core.tasks import accuracy_logistic, make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid


# Paper-scale task (d=20k keeps the gradient/noise scales in the regime
# the paper's hyper-parameters were tuned for; at d=4000 the DP feedback
# loop -- noisier w^tau => larger ||g||_1 => larger noise -- diverges).
@pytest.fixture(scope="module")
def task():
    X, y = synth.adult_like(d=20000, n=14, seed=0)
    m = 50
    batches = partition_iid(X, y, m=m, seed=0)
    batches = jax.tree_util.tree_map(jnp.asarray, batches)
    loss = make_logistic_loss()
    return X, y, m, batches, loss


# measured by 5000-step centralized GD on this task (see DESIGN.md §8)
F_OPT = 0.69176


def _run_fedepm(task_t, rounds=60, eps_dp=0.1, rho=0.5, k0=8, **kw):
    X, y, m, batches, loss = task_t
    cfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=rho, k0=k0,
                                             eps_dp=eps_dp, **kw)
    state = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(X.shape[1]),
                              cfg)
    step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))
    fs = []
    for _ in range(rounds):
        state, metrics = step(state)
        fs.append(float(fedepm.global_objective(loss, state.w_tau, batches))
                  / m)
    return state, fs, cfg


def test_fedepm_decreases_objective(task):
    """Objective approaches the regularised optimum (absolute decline is
    small by construction of the paper's normalisation, DESIGN.md §8)."""
    state, fs, _ = _run_fedepm(task, rounds=60)
    assert fs[-1] < fs[0] - 5e-4          # ln2 = 0.69315 -> ~0.6918
    assert fs[-1] < F_OPT + 1e-3          # near the measured optimum
    tail = fs[-10:]
    assert max(tail) - min(tail) < 1e-3   # settled


def test_fedepm_reaches_useful_accuracy(task):
    """The regularised optimum of the paper's objective (beta=1e-3 on
    unit-column features) attains ~0.74 accuracy (measured by long GD);
    FedEPM should get within a few points of it under eps=0.1 DP."""
    X, y, m, batches, loss = task
    state, fs, _ = _run_fedepm(task, rounds=80, eps_dp=0.1)
    acc = float(accuracy_logistic(state.w_tau, jnp.asarray(X),
                                  jnp.asarray(y)))
    assert acc > 0.70, acc


def test_fedepm_matches_baselines_objective(task):
    """Fig. 2 claim: all three algorithms approach the same objective."""
    X, y, m, batches, loss = task
    _, fs_epm, _ = _run_fedepm(task, rounds=80)

    bcfg = baselines.BaselineConfig(m=m, k0=8, rho=0.5, eps_dp=0.1,
                                    d_i=1.0, gamma_scale=2.0)
    bstate = baselines.init_state(jax.random.PRNGKey(0),
                                  jnp.zeros(X.shape[1]), bcfg)
    step = jax.jit(lambda s: baselines.sfedavg_round(s, batches, loss, bcfg))
    for _ in range(80):
        bstate, _ = step(bstate)
    f_avg = float(fedepm.global_objective(loss, bstate.w_tau, batches)) / m

    pstate = baselines.init_state(jax.random.PRNGKey(0),
                                  jnp.zeros(X.shape[1]), bcfg)
    pstep = jax.jit(lambda s: baselines.sfedprox_round(s, batches, loss,
                                                       bcfg))
    for _ in range(80):
        pstate, _ = pstep(pstate)
    f_prox = float(fedepm.global_objective(loss, pstate.w_tau, batches)) / m

    # all three settle at the same optimum (Fig. 2 claim), tight in abs
    assert abs(fs_epm[-1] - f_avg) < 2e-3
    assert abs(fs_epm[-1] - f_prox) < 2e-3


def test_lyapunov_descent_noise_free(task):
    """Lemma VI.1: with eps_dp off and full participation, F(w^tau, W^k)
    descends monotonically once mu_{i,k} > r_i - eta."""
    X, y, m, batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=1.0, k0=4,
                                             eps_dp=-1.0, sampler="full")
    state = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(X.shape[1]),
                              cfg)
    step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))
    vals = []
    for _ in range(40):
        state, _ = step(state)
        vals.append(float(fedepm.lyapunov(loss, state, batches, cfg)))
    # allow a short burn-in; then monotone non-increase (tolerance for fp)
    burn = 5
    diffs = np.diff(vals[burn:])
    assert np.all(diffs <= 1e-4 * (1 + np.abs(vals[burn])))


def test_partial_participation_carries_state(task):
    """Eq. (22): non-selected clients keep (w_i, z_i, mu_i)."""
    X, y, m, batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=0.3, k0=4, eps_dp=0.1)
    state = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(X.shape[1]),
                              cfg)
    new_state, metrics = jax.jit(
        lambda s: fedepm.fedepm_round(s, batches, loss, cfg))(state)
    sel = np.asarray(metrics.selected)
    W_old = np.asarray(state.W)
    W_new = np.asarray(new_state.W)
    assert sel.sum() == int(round(0.3 * m))
    np.testing.assert_array_equal(W_new[~sel], W_old[~sel])
    assert np.all(np.any(W_new[sel] != W_old[sel], axis=-1))


def test_mu_grows_geometrically(task):
    X, y, m, batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=1.0, k0=4,
                                             eps_dp=0.1, sampler="full")
    state = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(X.shape[1]),
                              cfg)
    step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))
    mus = []
    for _ in range(10):
        state, metrics = step(state)
        mus.append(float(metrics.mu_last[0]))
    ratios = np.asarray(mus[1:]) / np.asarray(mus[:-1])
    # alpha^k0 growth (alpha=1.001, k0=4 -> ~1.004), modulated by drift
    assert np.all(ratios > 1.0)


def test_snr_decreases_with_stronger_privacy(task):
    """Smaller eps => larger noise => smaller SNR (Fig. 5 trend)."""
    snrs = {}
    for eps in (0.1, 0.9):
        state, fs, cfg = _run_fedepm(task, rounds=10, eps_dp=eps)
        X, y, m, batches, loss = task
        st = fedepm.init_state(jax.random.PRNGKey(1),
                               jnp.zeros(X.shape[1]), cfg)
        _, metrics = jax.jit(
            lambda s: fedepm.fedepm_round(s, batches, loss, cfg))(st)
        snrs[eps] = float(metrics.snr)
    assert snrs[0.1] < snrs[0.9]


def test_checkpoint_roundtrip(task, tmp_path):
    from repro import checkpoint
    X, y, m, batches, loss = task
    state, _, cfg = _run_fedepm(task, rounds=2)
    path = str(tmp_path / "ck")
    checkpoint.save_fedepm(path, state, cfg)
    restored, meta = checkpoint.restore_fedepm(path)
    np.testing.assert_allclose(restored.w_tau, state.w_tau)
    np.testing.assert_allclose(restored.k, state.k)
    assert "fedepm_config" in meta
