"""Upload-codec quantizer + error-feedback accumulate: Pallas (interpret)
vs jnp ref, grid/unbiasedness properties, property-based (hypothesis)
codec laws, and the transport codec round-trip built on top of them."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional: on a bare environment only the property-based
# tests skip; the kernel validation still runs
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.kernels.quant import ops, ref
from repro.sim.transport import CodecConfig, codec_roundtrip, encoded_client_bytes


def _data(m, n, seed=0, scale=2.0):
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (m, n)) * scale
    s = jnp.max(jnp.abs(X), axis=1)
    u32 = jax.random.bits(jax.random.fold_in(key, 1), (m, n),
                          dtype=jnp.uint32)
    return X, s, u32


@pytest.mark.parametrize("m,n", [(1, 7), (5, 300), (32, 1024), (3, 513)])
@pytest.mark.parametrize("bits", [2, 4, 8, 16])
@pytest.mark.parametrize("stochastic", [True, False])
def test_pallas_matches_ref_bitexact(m, n, bits, stochastic):
    """Same dither bits => the kernel and the jnp reference must agree
    EXACTLY (the dither is an input, not drawn in-kernel)."""
    X, s, u32 = _data(m, n, seed=m * n)
    u = u32 if stochastic else None
    qp = ops.quantize(X, s, bits, u, impl="pallas", interpret=True)
    qr = ops.quantize(X, s, bits, u, impl="ref")
    assert np.array_equal(np.asarray(qp), np.asarray(qr))
    assert qp.dtype == X.dtype


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantization_error_bounded(bits):
    """|q - x| <= delta (stochastic) resp. delta/2 (deterministic)."""
    X, s, u32 = _data(8, 400, seed=3)
    L = ref.quant_levels(bits)
    delta = np.asarray(s)[:, None] / L
    q_st = np.asarray(ops.quantize(X, s, bits, u32, impl="ref"))
    q_dt = np.asarray(ops.quantize(X, s, bits, None, impl="ref"))
    Xn = np.asarray(X)
    assert (np.abs(q_st - Xn) <= delta * (1 + 1e-6)).all()
    assert (np.abs(q_dt - Xn) <= delta / 2 + delta * 1e-6).all()


def test_values_on_grid():
    X, s, u32 = _data(4, 200, seed=5)
    bits = 4
    L = ref.quant_levels(bits)
    q = np.asarray(ops.quantize(X, s, bits, u32, impl="ref"), np.float64)
    delta = (np.asarray(s, np.float64) * np.float32(1.0 / L))[:, None]
    levels = np.rint(q / delta)
    np.testing.assert_allclose(levels * delta, q, rtol=1e-6)
    assert (np.abs(levels) <= L).all()


def test_stochastic_rounding_unbiased():
    """E[q] = x for |x| <= scale: average over many dither draws."""
    n = 4096
    X = jnp.full((1, n), 0.37, jnp.float32)
    s = jnp.ones((1,))
    means = []
    for seed in range(40):
        u32 = jax.random.bits(jax.random.PRNGKey(seed), (1, n),
                              dtype=jnp.uint32)
        means.append(float(np.asarray(
            ops.quantize(X, s, 4, u32, impl="ref")).mean()))
    assert abs(np.mean(means) - 0.37) < 2e-3
    # deterministic rounding is biased toward the nearer grid point instead
    q_dt = float(np.asarray(ops.quantize(X, s, 4, None, impl="ref")).mean())
    assert abs(q_dt - 0.37) > 5e-3


def test_zero_rows_quantize_to_zero():
    X, _, u32 = _data(4, 64, seed=7)
    X = X.at[2].set(0.0)
    s = jnp.max(jnp.abs(X), axis=1)
    for impl in ("ref", "pallas"):
        q = np.asarray(ops.quantize(X, s, 8, u32, impl=impl,
                                    interpret=True))
        assert (q[2] == 0).all()
        assert np.isfinite(q).all()


def test_bits_validation():
    X, s, _ = _data(2, 16)
    with pytest.raises(ValueError):
        ops.quantize(X, s, 1, None, impl="ref")


# ---------------------------------------------------------------------------
# error-feedback accumulate/compress (H + Q(Z - H))
# ---------------------------------------------------------------------------

def _ef_data(m, n, seed=0):
    key = jax.random.PRNGKey(seed)
    Z = jax.random.normal(key, (m, n)) * 2.0
    H = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    s = jnp.max(jnp.abs(Z - H), axis=1)
    u32 = jax.random.bits(jax.random.fold_in(key, 2), (m, n),
                          dtype=jnp.uint32)
    return Z, H, s, u32


@pytest.mark.parametrize("m,n", [(1, 7), (5, 300), (32, 1024), (3, 513)])
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stochastic", [True, False])
def test_ef_pallas_matches_ref_bitexact(m, n, bits, stochastic):
    """Fused kernel and jnp reference consume the same dither and must
    agree EXACTLY -- the codec-memory contract of docs/kernels.md."""
    Z, H, s, u32 = _ef_data(m, n, seed=m * n)
    u = u32 if stochastic else None
    op = ops.ef_accumulate(Z, H, s, bits, u, impl="pallas", interpret=True)
    orf = ops.ef_accumulate(Z, H, s, bits, u, impl="ref")
    assert np.array_equal(np.asarray(op), np.asarray(orf))
    assert op.dtype == Z.dtype


@pytest.mark.parametrize("bits", [4, 8])
def test_ef_accumulate_equals_quantized_residual(bits):
    """ef_accumulate(Z, H) == H + quantize(Z - H) up to the final-add
    rounding: the fused op keeps the accumulate in one FMA (one rounding),
    the composition rounds the dequantized residual to f32 first. The two
    therefore differ by at most 1 ulp of the DEQUANTIZED RESIDUAL (which,
    under cancellation h ~ -dec, can be many ulps of the tiny sum)."""
    Z, H, s, u32 = _ef_data(6, 256, seed=11)
    fused = np.asarray(ops.ef_accumulate(Z, H, s, bits, u32, impl="ref"))
    dec = np.asarray(ops.quantize(Z - H, s, bits, u32, impl="ref"))
    composed = np.asarray(H) + dec
    tol = np.spacing(np.maximum(np.abs(composed), np.abs(dec))
                     .astype(np.float32))
    assert (np.abs(fused - composed) <= tol).all()


def test_ef_zero_residual_rows_pass_h_through():
    """A row where Z == H (scale 0) must return H exactly -- a converged
    client's memory never drifts."""
    Z, H, _, u32 = _ef_data(4, 64, seed=5)
    Z = Z.at[2].set(H[2])
    s = jnp.max(jnp.abs(Z - H), axis=1)
    for impl in ("ref", "pallas"):
        out = np.asarray(ops.ef_accumulate(Z, H, s, 8, u32, impl=impl,
                                           interpret=True))
        np.testing.assert_array_equal(out[2], np.asarray(H)[2])
        assert np.isfinite(out).all()


def test_ef_error_bounded_by_residual_grid():
    """|out - Z| <= residual grid step: the memory moves to within one
    quantization step of the target."""
    Z, H, s, u32 = _ef_data(8, 400, seed=3)
    bits = 8
    L = ref.quant_levels(bits)
    delta = np.asarray(s)[:, None] / L
    out = np.asarray(ops.ef_accumulate(Z, H, s, bits, u32, impl="ref"))
    assert (np.abs(out - np.asarray(Z)) <= delta * (1 + 1e-6)).all()


def test_ef_shape_validation():
    Z, H, s, _ = _ef_data(2, 16)
    from repro.kernels.quant.ef import ef_accumulate_pallas
    with pytest.raises(ValueError, match="matching"):
        ef_accumulate_pallas(Z, H[:1], s, 8)


# ---------------------------------------------------------------------------
# property-based codec laws (hypothesis; optional as in the other kernels)
# ---------------------------------------------------------------------------

if hypothesis is not None:
    _given_codec_case = hypothesis.given(case=st.tuples(
        st.integers(1, 5),                       # m clients
        st.integers(2, 96),                      # n coords
        st.sampled_from([2, 4, 8]),              # wire bits
        st.floats(0.1, 1.0),                     # topk fraction
        st.integers(0, 2 ** 31 - 1),             # data seed
    ))
    _settings_codec = hypothesis.settings(deadline=None, max_examples=30)
else:
    _given_codec_case = pytest.mark.skip(reason="hypothesis not installed")
    _settings_codec = lambda f: f  # noqa: E731


def _rand_tree(m, n, seed, scale=3.0):
    key = jax.random.PRNGKey(seed % (2 ** 31 - 1))
    return {"w": jax.random.normal(key, (m, n)) * scale}


@_settings_codec
@_given_codec_case
def test_prop_roundtrip_error_bound(case):
    """|decode(encode(z)) - z| <= scale/levels on every KEPT coordinate,
    for any shape/bits/sparsity; dropped coordinates take the fallback
    exactly (here: z itself, isolating quantization error)."""
    m, n, bits, frac, seed = case
    t = _rand_tree(m, n, seed)
    out = codec_roundtrip(t, t, jax.random.PRNGKey(seed % 997),
                          CodecConfig(topk_frac=frac, bits=bits))
    L = ref.quant_levels(bits)
    z = np.asarray(t["w"], np.float64)
    o = np.asarray(out["w"], np.float64)
    k = n if frac >= 1.0 else max(1, int(np.ceil(frac * n)))
    for i in range(m):
        kept = np.argsort(-np.abs(z[i]))[:k]
        delta = np.abs(z[i, kept]).max() / L
        assert (np.abs(o[i, kept] - z[i, kept]) <= delta * (1 + 1e-5)).all()
        dropped = np.setdiff1d(np.arange(n), kept)
        np.testing.assert_array_equal(o[i, dropped], z[i, dropped])


@_settings_codec
@_given_codec_case
def test_prop_ef_residual_never_grows(case):
    """EF memory contraction, worst case: one deterministic-rounding EF
    pass never increases the residual sup-norm ||z - h|| -- kept
    coordinates land within half a grid step of their target, dropped
    coordinates keep their old (smaller-magnitude) residual."""
    from repro.sim.transport import ef_roundtrip

    m, n, bits, frac, seed = case
    z = _rand_tree(m, n, seed)
    h = _rand_tree(m, n, seed + 1, scale=1.0)
    codec = CodecConfig(topk_frac=frac, bits=bits, stochastic=False,
                        error_feedback=True)
    h_new = ef_roundtrip(z, h, jax.random.PRNGKey(0), codec)
    r0 = np.abs(np.asarray(z["w"], np.float64)
                - np.asarray(h["w"], np.float64)).max(axis=1)
    r1 = np.abs(np.asarray(z["w"], np.float64)
                - np.asarray(h_new["w"], np.float64)).max(axis=1)
    assert (r1 <= r0 * (1 + 1e-6)).all()


def test_prop_ef_residual_contracts_in_expectation():
    """Stochastic rounding can grow a single residual; ITS EXPECTATION must
    still contract: averaged over many dither draws, E||z - h'||^2 after
    one dense 8-bit EF pass is far below ||z - h||^2."""
    from repro.sim.transport import ef_roundtrip

    z = _rand_tree(4, 64, seed=0)
    h = _rand_tree(4, 64, seed=1, scale=1.0)
    codec = CodecConfig(topk_frac=1.0, bits=8, error_feedback=True)
    r0 = float(np.sum((np.asarray(z["w"]) - np.asarray(h["w"])) ** 2))
    sq = []
    for s in range(32):
        h_new = ef_roundtrip(z, h, jax.random.PRNGKey(s), codec)
        sq.append(float(np.sum(
            (np.asarray(z["w"]) - np.asarray(h_new["w"])) ** 2)))
    assert np.mean(sq) < 0.1 * r0


@_settings_codec
@_given_codec_case
def test_prop_topk_sparsity_count_exact(case):
    """The codec touches EXACTLY ceil(frac * n) coordinates per client per
    leaf -- the count the byte ledger bills for. A sentinel fallback makes
    touched coordinates identifiable."""
    m, n, bits, frac, seed = case
    t = _rand_tree(m, n, seed)          # |values| <= ~15, sentinel unreachable
    sentinel = 1.0e9
    fb = jax.tree_util.tree_map(lambda x: jnp.full_like(x, sentinel), t)
    out = codec_roundtrip(t, fb, jax.random.PRNGKey(seed % 997),
                          CodecConfig(topk_frac=frac, bits=bits))
    k = n if frac >= 1.0 else max(1, int(np.ceil(frac * n)))
    o = np.asarray(out["w"])
    touched = (o != sentinel).sum(axis=1)
    np.testing.assert_array_equal(touched, np.full(m, k))


# ---------------------------------------------------------------------------
# transport codec round-trip (top-k + quantize + dequantize-with-fallback)
# ---------------------------------------------------------------------------

def _tree(m, seed=0):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, (m, 6, 8)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (m, 10))}


def test_codec_identity_when_disabled():
    t = _tree(4)
    out = codec_roundtrip(t, t, jax.random.PRNGKey(0), None)
    assert out is t


def test_codec_dense_lossless_when_raw():
    """topk_frac=1, bits=0: the codec transmits everything exactly."""
    t = _tree(4)
    fb = jax.tree_util.tree_map(jnp.zeros_like, t)
    out = codec_roundtrip(t, fb, jax.random.PRNGKey(0),
                          CodecConfig(topk_frac=1.0, bits=0))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(t)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_codec_topk_exact_on_kept_raw():
    """bits=0, topk<1: kept (top-magnitude) coords come through exactly,
    dropped coords take the fallback value."""
    m = 3
    t = _tree(m, seed=2)
    fb = jax.tree_util.tree_map(lambda x: jnp.full_like(x, -7.0), t)
    frac = 0.25
    out = codec_roundtrip(t, fb, jax.random.PRNGKey(0),
                          CodecConfig(topk_frac=frac, bits=0))
    for o, z in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(t)):
        of = np.asarray(o).reshape(m, -1)
        zf = np.asarray(z).reshape(m, -1)
        n = zf.shape[1]
        k = max(1, int(np.ceil(frac * n)))
        for i in range(m):
            kept = np.argsort(-np.abs(zf[i]))[:k]
            np.testing.assert_array_equal(of[i, kept], zf[i, kept])
            dropped = np.setdiff1d(np.arange(n), kept)
            assert (of[i, dropped] == -7.0).all()


def test_codec_quantized_close_and_on_grid():
    m = 4
    t = _tree(m, seed=3)
    fb = jax.tree_util.tree_map(jnp.zeros_like, t)
    out = codec_roundtrip(t, fb, jax.random.PRNGKey(1),
                          CodecConfig(topk_frac=1.0, bits=8))
    L = ref.quant_levels(8)
    for o, z in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(t)):
        of, zf = np.asarray(o).reshape(m, -1), np.asarray(z).reshape(m, -1)
        delta = np.abs(zf).max(axis=1, keepdims=True) / L
        assert (np.abs(of - zf) <= delta * (1 + 1e-5)).all()


def test_encoded_bytes_accounting():
    m = 2
    t = {"w": jnp.zeros((m, 100), jnp.float32)}
    # raw dense = 400 B
    assert encoded_client_bytes(t, None) == 400.0
    # dense 8-bit: 100 B payload + 4 B scale
    assert encoded_client_bytes(t, CodecConfig(topk_frac=1.0, bits=8)) \
        == 104.0
    # top-10% 8-bit: 10 B payload + 40 B indices + 4 B scale
    assert encoded_client_bytes(t, CodecConfig(topk_frac=0.1, bits=8)) \
        == 54.0
    # top-10% raw: 40 B payload + 40 B indices + 4 B scale
    assert encoded_client_bytes(t, CodecConfig(topk_frac=0.1, bits=0)) \
        == 84.0


# ---------------------------------------------------------------------------
# batched column-bounded quantizer (fused multi-leaf codec kernel)
# ---------------------------------------------------------------------------

def _cols_data(m, n, seed=0):
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (m, n)) * 2.0
    F = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    kc = jax.random.randint(jax.random.fold_in(key, 2), (m,), 0, n + 1)
    live = jnp.arange(n)[None, :] < kc[:, None]
    s = jnp.max(jnp.where(live, jnp.abs(X), 0.0), axis=1)
    u32 = jax.random.bits(jax.random.fold_in(key, 3), (m, n),
                          dtype=jnp.uint32)
    return X, F, s, kc, u32


@pytest.mark.parametrize("m,n", [(1, 7), (5, 300), (32, 1024), (3, 513)])
@pytest.mark.parametrize("bits", [2, 8])
@pytest.mark.parametrize("stochastic", [True, False])
def test_quantize_cols_pallas_matches_ref_bitexact(m, n, bits, stochastic):
    """Same dither bits => the batched kernel and the jnp reference agree
    EXACTLY, per-row live-column bounds included."""
    X, F, s, kc, u32 = _cols_data(m, n, seed=m * n + 1)
    u = u32 if stochastic else None
    qp = ops.quantize_cols(X, F, s, kc, bits, u, impl="pallas",
                           interpret=True)
    qr = ops.quantize_cols(X, F, s, kc, bits, u, impl="ref")
    assert np.array_equal(np.asarray(qp), np.asarray(qr))
    assert qp.dtype == X.dtype


def test_quantize_cols_dead_columns_pass_fallback_bituntouched():
    """Columns at or past a row's live count return F exactly; live
    columns match the plain row-wise quantizer driven by the same scale."""
    X, F, s, kc, u32 = _cols_data(6, 128, seed=11)
    out = np.asarray(ops.quantize_cols(X, F, s, kc, 8, u32, impl="ref"))
    live = np.arange(128)[None, :] < np.asarray(kc)[:, None]
    np.testing.assert_array_equal(out[~live], np.asarray(F)[~live])
    full = np.asarray(ops.quantize(X, s, 8, u32, impl="ref"))
    np.testing.assert_array_equal(out[live], full[live])


def test_quantize_cols_zero_live_row_is_all_fallback():
    X, F, s, _, u32 = _cols_data(4, 64, seed=13)
    kc = jnp.zeros((4,), jnp.int32)
    for impl in ("ref", "pallas"):
        out = np.asarray(ops.quantize_cols(X, F, s, kc, 8, u32, impl=impl,
                                           interpret=True))
        np.testing.assert_array_equal(out, np.asarray(F))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_quantize_cols_shape_validation(impl):
    """Both impls must reject mismatched X/F (ref would otherwise silently
    broadcast the fallback)."""
    X, F, s, kc, _ = _cols_data(2, 16)
    with pytest.raises(ValueError):
        ops.quantize_cols(X, F[:1], s, kc, 8, None, impl=impl)
