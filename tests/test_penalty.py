"""Exact-penalty theory (Sec. III): Theorem III.1 validated numerically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import penalty
from repro.data import synth


def _quadratic_clients(m=6, n=8, seed=0):
    """f_i(w) = 0.5 ||A_i w - b_i||^2: smooth, convex, closed-form sum."""
    rng = np.random.default_rng(seed)
    As = jnp.asarray(rng.standard_normal((m, n, n)), jnp.float32) / np.sqrt(n)
    bs = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)

    def make(i):
        return lambda w: 0.5 * jnp.sum((As[i] @ w - bs[i]) ** 2)

    fs = [make(i) for i in range(m)]

    # global optimum of sum_i f_i: solve (sum A_i^T A_i) w = sum A_i^T b_i
    H = sum(np.asarray(As[i]).T @ np.asarray(As[i]) for i in range(m))
    c = sum(np.asarray(As[i]).T @ np.asarray(bs[i]) for i in range(m))
    w_star = jnp.asarray(np.linalg.solve(H, c), jnp.float32)
    return fs, w_star


def test_exact_penalty_theorem():
    """A stationary point of (6) is stationary for (7) when lam >= lam*."""
    m, n = 6, 8
    fs, w_star = _quadratic_clients(m, n)
    grads = jnp.stack([jax.grad(f)(w_star) for f in fs])
    lam_star = penalty.lambda_star(grads)
    W_star = jnp.broadcast_to(w_star, (m, n))

    for factor, should_hold in [(1.0, True), (2.0, True), (0.05, False)]:
        lam = float(lam_star) * factor
        eta = lam  # any eta > 0
        r_client, r_server = penalty.stationarity_residual_penalty(
            grads, W_star, w_star, lam, eta)
        if should_hold:
            assert float(r_client) < 1e-4, (factor, float(r_client))
            assert float(r_server) < 1e-3
        else:
            # with lam << lam* the consensus point is NOT stationary for
            # (7): some client can decrease F by moving w_i off w
            assert float(r_client) > 1e-3


def test_penalty_minimiser_drifts_below_threshold():
    """Minimising (7) directly with small lam yields w_i != w; with
    lam >= lam* the minimiser is consensual (numerically)."""
    m, n = 4, 6
    fs, w_star = _quadratic_clients(m, n, seed=1)
    grads = jnp.stack([jax.grad(f)(w_star) for f in fs])
    lam_star = float(penalty.lambda_star(grads))

    # Minimise (7) by exact alternating proximal steps (plain GD chatters
    # at the |.| kink and never reaches exact consensus): w via ENS
    # (closed-form argmin, Lemma III.2), each w_i via proximal gradient.
    from repro.kernels.ens.ref import ens_ref
    from repro.core.penalty import soft

    for lam, expect_consensus in [(lam_star * 2.0, True),
                                  (lam_star * 0.02, False)]:
        eta = lam
        W = jnp.zeros((m, n))
        w = jnp.zeros(n)
        lr = 0.2
        for it in range(2000):
            w = ens_ref(W, lam, eta)
            for i in range(m):
                gi = jax.grad(fs[i])(W[i])
                v = W[i] - w
                v = soft(v - lr * (gi + eta * v), lr * lam)
                W = W.at[i].set(w + v)
        spread = float(jnp.max(jnp.abs(W - w[None])))
        if expect_consensus:
            assert spread < 5e-3, spread
        else:
            assert spread > 5e-2, spread


def test_soft_is_prox_of_l1():
    t = jnp.linspace(-4, 4, 101)
    for a in (0.0, 0.5, 2.0):
        s = penalty.soft(t, a)
        # prox property: |s| = max(|t|-a, 0), sign preserved
        np.testing.assert_allclose(jnp.abs(s),
                                   jnp.maximum(jnp.abs(t) - a, 0.0),
                                   atol=1e-6)
        assert bool(jnp.all(s * t >= 0.0))


def test_elastic_net_values():
    z = jnp.asarray([1.0, -2.0, 0.0])
    assert float(penalty.elastic_net(z, 1.0, 0.0)) == pytest.approx(3.0)
    assert float(penalty.elastic_net(z, 0.0, 2.0)) == pytest.approx(5.0)
    tree = {"a": z, "b": -z}
    assert float(penalty.elastic_net_tree(tree, 1.0, 0.0)) \
        == pytest.approx(6.0)


def test_lambda_star_on_paper_task():
    """lambda* is finite and modest on the (synthetic) Adult logistic
    task, so the paper's 'properly large lambda' is practical."""
    from repro.core.tasks import make_logistic_loss
    from repro.data.partition import partition_iid

    X, y = synth.adult_like(d=2000, n=14, seed=0)
    batches = partition_iid(X, y, m=10, seed=0)
    loss = make_logistic_loss()
    w = jnp.zeros(14)
    grads = jax.vmap(lambda b: jax.grad(loss)(w, b))(batches)
    lam_star = float(penalty.lambda_star(grads))
    assert 0 < lam_star < 10.0
