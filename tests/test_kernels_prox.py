"""Fused FedEPM client-update kernel (eq. (20)) vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional: on a bare environment only the property-based
# tests skip; the kernel-vs-oracle validation still runs
try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.kernels.prox import ops, ref


@pytest.mark.parametrize("shape", [(8,), (130,), (64, 64), (3, 5, 7),
                                   (1, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    wi = (jax.random.normal(ks[0], shape) * 2).astype(dtype)
    wt = (jax.random.normal(ks[1], shape) * 2).astype(dtype)
    g = (jax.random.normal(ks[2], shape)).astype(dtype)
    mu, lam, eta = 0.37, 0.05, 0.02
    out_p = ops.prox_update(wi, wt, g, mu, lam, eta, impl="pallas",
                            block_r=8, interpret=True)
    out_r = ref.prox_update_ref(wi, wt, g, mu, lam, eta)
    atol = 5e-6 if dtype == jnp.float32 else 4e-2  # 1 bf16 ULP at |x|~4
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), atol=atol)
    assert out_p.dtype == wi.dtype


if hypothesis is not None:
    _given_subproblem = hypothesis.given(
        w=hnp.arrays(np.float32, 17, elements=st.floats(-10, 10, width=32)),
        mu=st.floats(1e-3, 100.0),
        lam=st.floats(1e-6, 5.0),
        eta=st.floats(1e-6, 5.0),
    )
    _settings_subproblem = hypothesis.settings(deadline=None, max_examples=30)
else:
    _given_subproblem = pytest.mark.skip(reason="hypothesis not installed")
    _settings_subproblem = lambda f: f  # noqa: E731


@_settings_subproblem
@_given_subproblem
def test_prox_solves_subproblem(w, mu, lam, eta):
    """out is the argmin of (23): compare against a dense grid search over
    per-coordinate candidates."""
    wi = jnp.asarray(w)
    wt = jnp.zeros_like(wi) + 0.3
    g = jnp.linspace(-1, 1, wi.size)
    out = ref.prox_update_ref(wi, wt, g, mu, lam, eta)
    v_opt = out - wt

    def obj(v):
        return (g * v + mu / 2 * (v - (wi - wt)) ** 2
                + lam * jnp.abs(v) + eta / 2 * v ** 2)

    base = obj(v_opt)
    tol = 1e-5 * (1.0 + jnp.abs(base))  # scale-aware fp32 tolerance
    for d in (-1e-3, 1e-3, -0.1, 0.1):
        assert bool(jnp.all(obj(v_opt + d) >= base - tol))


def test_soft_threshold_two_lipschitz():
    """Lemma A.1/(45): |soft(t,a)-soft(t',a)| <= 2|t-t'| -- the property
    the DP proof (Thm V.1) rests on. Fuzz over a grid."""
    t = jnp.linspace(-5, 5, 201)
    for a in (0.1, 1.0, 3.0):
        s = ref.soft(t, a)
        dt = t[None, :] - t[:, None]
        ds = s[None, :] - s[:, None]
        assert float(jnp.max(jnp.abs(ds) - 2 * jnp.abs(dt))) <= 1e-6
        # (and in fact soft-thresholding is 1-Lipschitz; the paper's bound
        # of 2 is loose but valid)
        assert float(jnp.max(jnp.abs(ds) - jnp.abs(dt))) <= 1e-6


def test_tree_update():
    tree_w = {"a": jnp.ones((4, 4)), "b": jnp.zeros((3,))}
    tree_t = {"a": jnp.zeros((4, 4)), "b": jnp.ones((3,))}
    tree_g = {"a": jnp.ones((4, 4)) * 0.1, "b": jnp.ones((3,)) * -0.2}
    out = ops.prox_update_tree(tree_w, tree_t, tree_g, 1.0, 0.01, 0.02)
    ra = ref.prox_update_ref(tree_w["a"], tree_t["a"], tree_g["a"],
                             1.0, 0.01, 0.02)
    np.testing.assert_allclose(out["a"], ra)
