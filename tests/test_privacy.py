"""Privacy subsystem (repro.privacy) + private upload path, end to end.

Pins the privacy model's contract:

  * ENGINE EQUIVALENCE -- with DP noise and secure aggregation on, every
    aggregation policy produces bit-identical states, byte ledgers,
    accountant totals AND telemetry event streams between the eager and
    scan engines (the noise is host-drawn in one standalone program and
    replayed into both, never re-drawn in-body);
  * ZERO-NOISE GOLDEN PIN -- an inert [privacy] config (eps 0, secure-agg
    off, even with non-default knobs) builds NO privacy state and
    reproduces the pinned golden trajectories byte-for-byte, sync AND
    async, both engines, ledger included;
  * EXACT ACCOUNTING -- mask bytes bill exactly one exchange per upload
    attempt that reached the wire (clean arrivals + retries + duplicates,
    PR 9's rule) even with the fault mix on; the accountant charges
    MERGED contributions only and its per-client state replays exactly
    from a JSONL export of the telemetry stream;
  * MECHANISM PROPERTIES -- the paper's noise scale decays geometrically
    with the penalty mu_{i,k} (Setup V.1 / Thm VI.1); clip_tree_l1
    enforces its l1 bound; the fused clip+noise+quantize kernel matches
    the sequential composition AND the Pallas impl bit-for-bit on shared
    noise/dither streams (widened by hypothesis when installed);
  * SPEC SURFACE -- [privacy] validation rejects out-of-domain values;
    TOML round-trips; the CLI --dp-*/--secure-agg flags map onto the
    spec with strict ownership errors.
"""
from __future__ import annotations

import collections
import dataclasses
import importlib.util
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional: on a bare environment only the widened
# property sweeps skip; the deterministic grids below still run
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.core.dp import clip_tree_l1, fedepm_noise_scale
from repro.core.treeutil import tree_l1_norm
from repro.kernels.quant import ops as quant_ops
from repro.kernels.quant.ref import (laplace_from_u32,
                                     private_quantize_cols_ref,
                                     quantize_cols_ref)
from repro.launch import simulate
from repro.privacy import PrivacyConfig, PrivacyModel, build_privacy_model
from repro.spec import ExperimentSpec, PrivacySpec, SpecError, TaskSpec
from repro.spec.types import TelemetrySpec
from repro.telemetry import read_events_jsonl, write_events_jsonl

M = 16
N = 14
FIXTURES = pathlib.Path(__file__).parent / "fixtures"

PRIVATE = dict(eps=2.0, secure_agg=True, mask_bytes=32, seed=7)

POLICIES = [
    ("sync", {}),
    ("deadline", {"deadline": 0.05}),
    ("adaptive", {}),
    ("overselect", {}),
    ("async", {"buffer_size": 3, "max_concurrency": 4}),
]


def _spec(policy, policy_kw, engine, *, chunk=None, rounds=6, pv=PRIVATE,
          faults=None, telemetry=True, seed=0):
    spec = ExperimentSpec(
        task=TaskSpec(kind="logreg", m=M, n=N, d=200),
        privacy=PrivacySpec(**pv),
        telemetry=TelemetrySpec(enabled=telemetry),
        name="privacy-test", seed=seed)
    if faults:
        from repro.spec import FaultSpec
        spec = dataclasses.replace(spec, faults=FaultSpec(**faults))
    return dataclasses.replace(
        spec,
        policy=dataclasses.replace(spec.policy, name=policy, **policy_kw),
        engine=dataclasses.replace(spec.engine, name=engine, rounds=rounds,
                                   chunk=chunk)).validate()


def _event_tuples(sim):
    return [(e.kind, e.round_idx, e.client, e.ts,
             tuple(sorted(e.attrs.items()))) for e in sim.telemetry.events]


def _load_regen_tool():
    tool = FIXTURES.parent.parent / "tools" / "regen_golden_trajectory.py"
    spec = importlib.util.spec_from_file_location("regen_golden", tool)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# engine equivalence under DP noise + secure aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_eager_scan_bitforbit_under_privacy(policy, kw):
    """Eager and scan runs of the same private experiment agree on the
    final state, ledger, accountant totals and the FULL telemetry event
    stream -- the ISSUE's bit-for-bit acceptance bar. The noise stream is
    host-drawn data, so both engines consume identical draws."""
    h1 = _spec(policy, kw, "eager").build()
    s1 = h1.run()
    h2 = _spec(policy, kw, "scan", chunk=3).build()
    s2 = h2.run()
    w1, w2 = np.asarray(h1.sim.state.w_tau), np.asarray(h2.sim.state.w_tau)
    assert np.array_equal(w1, w2)
    assert h1.sim.t == h2.sim.t
    assert s1["bytes_up"] == s2["bytes_up"]
    assert s1["bytes_down"] == s2["bytes_down"]
    assert s1["privacy"] == s2["privacy"]
    assert s1["privacy"]["charges"] > 0
    assert s1["privacy"]["mask_attempts"] > 0
    assert np.array_equal(h1.sim._privacy.eps_spent, h2.sim._privacy.eps_spent)
    assert _event_tuples(h1.sim) == _event_tuples(h2.sim)


@pytest.mark.parametrize("pv", [
    dict(eps=1.0, sensitivity="clip", clip=2.0, seed=7),
    dict(eps=1.0, mechanism="gaussian", delta=1e-6, seed=7),
    dict(secure_agg=True, mask_bytes=48),
], ids=["laplace-clip", "gaussian", "mask-only"])
def test_eager_scan_bitforbit_policy_variants(pv):
    """The remaining mechanism/sensitivity corners (l1-clip mode, the
    gaussian sequential path, secure-agg with NO noise) hold the same
    bit-for-bit bar, checked on the async policy (the hairiest: per-merge
    charges with staleness attribution)."""
    kw = {"buffer_size": 3, "max_concurrency": 4}
    h1 = _spec("async", kw, "eager", pv=pv).build()
    s1 = h1.run()
    h2 = _spec("async", kw, "scan", chunk=3, pv=pv).build()
    s2 = h2.run()
    assert np.array_equal(np.asarray(h1.sim.state.w_tau),
                          np.asarray(h2.sim.state.w_tau))
    assert s1["privacy"] == s2["privacy"]
    assert s1["bytes_up"] == s2["bytes_up"]
    assert _event_tuples(h1.sim) == _event_tuples(h2.sim)


# ---------------------------------------------------------------------------
# zero-noise golden pins
# ---------------------------------------------------------------------------

#: inert on purpose: eps == 0 and secure_agg False, with every OTHER knob
#: off its default -- inertness must come from .enabled, not from
#: comparing against PrivacyConfig()
INERT = dict(mechanism="gaussian", delta=1e-6, mask_bytes=64, seed=99)


def test_zero_noise_golden_sync():
    """A [privacy] config with eps == 0 and secure-agg off -- even with
    non-default mechanism/seed knobs -- reproduces the pinned sync golden
    trajectory byte-for-byte: the inert path is the pre-privacy code
    path, not a private run that happens to add zero noise."""
    golden = np.load(FIXTURES / "golden_sync_trajectory.npz")
    got = _load_regen_tool().simulate_golden(
        privacy=PrivacyConfig(**INERT))
    np.testing.assert_array_equal(got["objective"], golden["objective"])
    np.testing.assert_array_equal(got["t_total"], golden["t_total"])
    np.testing.assert_array_equal(got["w_tau_head"], golden["w_tau_head"])
    np.testing.assert_array_equal(got["key_final"], golden["key_final"])
    assert int(got["k_final"]) == int(golden["k_final"])


@pytest.mark.parametrize("engine", ["eager", "scan"])
def test_zero_noise_golden_async(engine):
    """Same zero-noise guarantee on the async fixture, under BOTH
    engines: byte ledger included, zero tolerance."""
    golden = np.load(FIXTURES / "golden_async_trajectory.npz")
    got = _load_regen_tool().simulate_golden_async(
        engine, privacy=PrivacyConfig(**INERT))
    np.testing.assert_array_equal(got["objective"], golden["objective"])
    np.testing.assert_array_equal(got["t_total"], golden["t_total"])
    np.testing.assert_array_equal(got["w_tau_head"], golden["w_tau_head"])
    np.testing.assert_array_equal(got["key_final"], golden["key_final"])
    assert int(got["k_final"]) == int(golden["k_final"])
    assert float(got["ledger_up"]) == float(golden["ledger_up"])
    assert float(got["ledger_down"]) == float(golden["ledger_down"])


def test_inert_spec_builds_no_privacy_model():
    """The all-default [privacy] section (and any inert variant) builds
    NO PrivacyModel: no accountant, no summary block, no noise stream."""
    h = _spec("sync", {}, "eager", pv=INERT).build()
    assert h.sim._privacy is None and h.sim._privacy_tx is None
    assert "privacy" not in h.run()
    assert build_privacy_model(None, M) is None
    assert build_privacy_model(PrivacyConfig(), M) is None
    with pytest.raises(ValueError, match="inert"):
        PrivacyModel(PrivacyConfig(), M)


# ---------------------------------------------------------------------------
# exact accounting: masks x faults, accountant replay
# ---------------------------------------------------------------------------

#: lossy-uplink mix from test_sim_invariants: drops, retried transients,
#: corruption screens and duplicated deliveries all reach the wire
FAULTY = dict(drop_rate=0.15, transient_rate=0.25, corrupt_rate=0.1,
              duplicate_rate=0.2, max_retries=2, reorder_jitter=0.002,
              seed=3)


@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_mask_bytes_attempt_exact_under_faults(policy, kw):
    """With secure aggregation AND the fault mix on, the ledger balances
    exactly: every upload attempt that reached the wire (clean arrival,
    retry, discarded duplicate, terminal drop) billed payload + exactly
    one mask-pair exchange; attempts the server cut off before they fired
    billed nothing. The accountant's mask counters agree with the billed
    attempt count derived from the event stream."""
    h = _spec(policy, kw, "eager", faults=FAULTY).build()
    s = h.run()
    sim = h.sim
    kinds = [e.kind for e in sim.telemetry.events]
    attempts = (kinds.count("upload_arrival") + kinds.count("retry")
                + kinds.count("duplicate_discard")
                + kinds.count("upload_drop"))
    assert attempts > kinds.count("upload_arrival"), "fault mix never fired"
    pm = sim._privacy
    assert pm.total_mask_attempts == attempts
    assert pm.total_mask_bytes == attempts * pm.cfg.mask_bytes
    # the mask bytes ride inside the per-attempt upload price, so the
    # ledger total is attempt-exact (and integral in attempts)
    up_b = sim.up_bytes_per_client
    assert up_b > pm.mask_overhead > 0
    assert sim.ledger.total_up == pytest.approx(attempts * up_b)
    # mask_exchange events re-derive the same totals
    ev_attempts = sum(e.attrs["attempts"] for e in sim.telemetry.events
                      if e.kind == "mask_exchange")
    ev_bytes = sum(e.attrs["bytes"] for e in sim.telemetry.events
                   if e.kind == "mask_exchange")
    assert ev_attempts == attempts and ev_bytes == pm.total_mask_bytes
    assert s["privacy"]["mask_bytes"] == pm.total_mask_bytes


def test_charges_merged_contributions_only():
    """Accountant charges follow MERGED uploads exactly: total charges ==
    merge count from telemetry, every charge carries the running total,
    and clients the deadline cut off spend nothing that round."""
    h = _spec("deadline", {"deadline": 0.05}, "eager", rounds=8).build()
    h.run()
    sim = h.sim
    charges = [e for e in sim.telemetry.events if e.kind == "privacy_charge"]
    pm = sim._privacy
    assert pm.total_charges == len(charges) > 0
    per_client = collections.Counter(e.client for e in charges)
    for c in range(M):
        assert pm.participation[c] == per_client.get(c, 0)
        assert pm.eps_spent[c] == pytest.approx(
            per_client.get(c, 0) * pm.cfg.eps)
    # running totals are cumulative in stream order
    running = collections.defaultdict(float)
    for e in charges:
        running[e.client] += e.attrs["eps"]
        assert e.attrs["eps_total"] == pytest.approx(running[e.client])


def test_accountant_replays_from_jsonl(tmp_path):
    """The accountant's full per-client state reconstructs from a JSONL
    export of the telemetry stream alone -- the docs/privacy.md
    replayability contract, via the exact write/read round-trip."""
    h = _spec("async", {"buffer_size": 3, "max_concurrency": 4}, "eager",
              faults=FAULTY, rounds=8).build()
    h.run()
    sim = h.sim
    path = tmp_path / "events.jsonl"
    write_events_jsonl(sim.telemetry.events, path)
    events = read_events_jsonl(path)
    assert events == sim.telemetry.events

    replay = PrivacyModel(sim._privacy.cfg, M)
    for e in events:
        if e.kind == "privacy_charge":
            assert replay.charge(e.client) == pytest.approx(
                e.attrs["eps_total"])
        elif e.kind == "mask_exchange":
            assert replay.bill_masks(e.attrs["attempts"]) == e.attrs["bytes"]
    assert np.array_equal(replay.eps_spent, sim._privacy.eps_spent)
    assert np.array_equal(replay.participation, sim._privacy.participation)
    assert replay.summary() == sim._privacy.summary()


def test_snapshot_restore_exact_rewind():
    pm = PrivacyModel(PrivacyConfig(eps=0.5, secure_agg=True), 4)
    pm.charge(1)
    snap0 = pm.state_snapshot()
    pm.charge(1)
    pm.charge(3)
    pm.bill_masks(5)
    pm.state_restore(snap0)
    assert pm.eps_spent.tolist() == [0.0, 0.5, 0.0, 0.0]
    assert pm.total_charges == 1 and pm.total_mask_bytes == 0
    # the snapshot stays reusable after a restore
    pm.charge(0)
    pm.state_restore(snap0)
    assert pm.total_charges == 1


# ---------------------------------------------------------------------------
# mechanism properties (deterministic grids; hypothesis widens below)
# ---------------------------------------------------------------------------

def test_noise_scale_decays_geometrically_with_mu():
    """Setup V.1 / Thm VI.1: b = factor * Delta_hat / (eps_dp * mu), so as
    the penalty mu_{i,k} = alpha^k grows geometrically the injected noise
    decays geometrically -- strictly monotone in mu, and exactly inverse:
    b(alpha * mu) * alpha == b(mu)."""
    alpha = 1.5
    mus = [alpha ** k for k in range(12)]
    scales = [float(fedepm_noise_scale(3.0, 0.1, mu)) for mu in mus]
    assert all(a > b > 0 for a, b in zip(scales, scales[1:]))
    for mu, b in zip(mus, scales):
        assert b * mu == pytest.approx(scales[0] * mus[0])
    # factor and Delta_hat enter linearly, eps inversely
    assert fedepm_noise_scale(3.0, 0.1, 2.0, factor=2.0) \
        == pytest.approx(2.0 * fedepm_noise_scale(3.0, 0.1, 2.0))
    assert fedepm_noise_scale(3.0, 0.2, 2.0) \
        == pytest.approx(0.5 * fedepm_noise_scale(3.0, 0.1, 2.0))


@pytest.mark.parametrize("max_l1", [0.5, 3.0, 1e4])
def test_clip_tree_l1_bound(max_l1):
    """clip_tree_l1 enforces ||tree||_1 <= max_l1 (to float tolerance) and
    leaves trees already under the bound untouched."""
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (37,)) * 4.0,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (5, 8))}
    clipped = clip_tree_l1(tree, max_l1)
    n1 = float(tree_l1_norm(clipped))
    assert n1 <= max_l1 * (1 + 1e-5)
    if float(tree_l1_norm(tree)) <= max_l1:
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(clipped)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def _private_case(m, n, bits, seed):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)
    X = jax.random.normal(ks[0], (m, n)) * 3.0
    X = X.at[m // 2].set(0.0)  # an all-zero row: scale 0 -> exact zeros
    F = jax.random.normal(ks[1], (m, n))
    clipf = jnp.minimum(1.0, jax.random.uniform(ks[2], (m,)) * 2.0)
    b = jax.random.uniform(ks[3], (m,)) * 0.5
    scale = jnp.max(jnp.abs(X), axis=1) * clipf
    kcols = jax.random.randint(ks[4], (m,), 0, n + 1)
    u32q = jax.random.bits(ks[5], (m, n), dtype=jnp.uint32)
    lap = laplace_from_u32(
        jax.random.bits(jax.random.fold_in(k, 9), (m, n), dtype=jnp.uint32))
    return X, F, clipf, b, scale, kcols, u32q, lap


@pytest.mark.parametrize("m,n", [(4, 33), (8, 512), (3, 1000)])
@pytest.mark.parametrize("bits", [4, 8])
def test_fused_private_kernel_equals_sequential(m, n, bits):
    """The fused clip+noise+quantize transform (jnp ref AND Pallas
    interpret impl) is bit-identical to the sequential composition --
    clip, add calibrated noise, then the existing column-bounded
    quantizer -- when both consume the same dither and unit-noise
    streams. The noise entering as DATA is what makes this exact."""
    X, F, clipf, b, scale, kcols, u32q, lap = _private_case(m, n, bits, m * n)

    fused_ref = private_quantize_cols_ref(X, F, clipf, b, scale, kcols,
                                          bits, u32q, lap)
    # sequential: same float32 ops in the same order, then the plain codec
    y = (X.astype(jnp.float32) * clipf.reshape(-1, 1)
         + b.reshape(-1, 1) * lap.astype(jnp.float32)).astype(X.dtype)
    seq = quantize_cols_ref(y, F, scale, kcols, bits, u32q)
    assert np.array_equal(np.asarray(fused_ref), np.asarray(seq))

    for impl in ("ref", "pallas"):
        out = quant_ops.private_quantize_cols(
            X, F, clipf, b, scale, kcols, bits, u32q, lap, impl=impl,
            interpret=True if impl == "pallas" else None)
        assert np.array_equal(np.asarray(out), np.asarray(fused_ref)), impl
    # the zero row quantized to exact zeros, noise included
    dead = np.asarray(kcols) > 0
    row = m // 2
    if dead[row]:
        assert not np.asarray(fused_ref)[row, :int(kcols[row])].any()


def test_laplace_from_u32_unit_properties():
    """The shared inverse-CDF transform: finite everywhere (u32 == 0
    endpoint included), odd-symmetric around the midpoint, and unit
    scale (sample mean |eps| -> 1 for a dense uniform grid)."""
    u32 = jnp.asarray(
        np.linspace(0, 2 ** 32 - 1, 200001, dtype=np.uint64).astype(
            np.uint32))
    eps = np.asarray(laplace_from_u32(u32), np.float64)
    assert np.isfinite(eps).all()
    assert np.isfinite(float(laplace_from_u32(jnp.zeros((1,), jnp.uint32))[0]))
    assert abs(np.mean(np.abs(eps)) - 1.0) < 5e-3  # E|Laplace(0,1)| = 1


if hypothesis is not None:
    _settings = hypothesis.settings(deadline=None, max_examples=40)

    @_settings
    @hypothesis.given(
        delta_hat=st.floats(1e-6, 1e6),
        eps_dp=st.floats(1e-6, 1e3),
        mu=st.floats(1e-6, 1e6),
        growth=st.floats(1.0 + 1e-6, 1e3),
    )
    def test_noise_scale_monotone_property(delta_hat, eps_dp, mu, growth):
        b1 = float(fedepm_noise_scale(delta_hat, eps_dp, mu))
        b2 = float(fedepm_noise_scale(delta_hat, eps_dp, mu * growth))
        assert b2 < b1 or b1 == 0.0

    @_settings
    @hypothesis.given(
        vals=st.lists(st.floats(-100, 100, width=32), min_size=1,
                      max_size=64),
        max_l1=st.floats(1e-3, 1e3),
    )
    def test_clip_tree_l1_bound_property(vals, max_l1):
        tree = (jnp.asarray(vals, jnp.float32),)
        n1 = float(tree_l1_norm(clip_tree_l1(tree, max_l1)))
        assert n1 <= max_l1 * (1 + 1e-5)

    @_settings
    @hypothesis.given(seed=st.integers(0, 2 ** 31 - 1),
                      m=st.integers(1, 9), n=st.integers(1, 130),
                      bits=st.sampled_from([2, 4, 8]))
    def test_fused_equals_sequential_property(seed, m, n, bits):
        X, F, clipf, b, scale, kcols, u32q, lap = _private_case(
            m, n, bits, seed)
        fused = private_quantize_cols_ref(X, F, clipf, b, scale, kcols,
                                          bits, u32q, lap)
        y = (X.astype(jnp.float32) * clipf.reshape(-1, 1)
             + b.reshape(-1, 1) * lap.astype(jnp.float32)).astype(X.dtype)
        seq = quantize_cols_ref(y, F, scale, kcols, bits, u32q)
        assert np.array_equal(np.asarray(fused), np.asarray(seq))


# ---------------------------------------------------------------------------
# spec surface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad,match", [
    (dict(mechanism="fuzz"), r"\[privacy\] unknown mechanism"),
    (dict(eps=-0.5), r"\[privacy\] eps"),
    (dict(eps=float("nan")), r"\[privacy\] eps"),
    (dict(eps=float("inf")), r"\[privacy\] eps"),
    (dict(delta=0.0), "delta"),
    (dict(delta=1.0), "delta"),
    (dict(sensitivity="l2"), "sensitivity"),
    (dict(eps=1.0, sensitivity="clip", clip=0.0), "clip"),
    (dict(eps=1.0, sensitivity="clip", clip=float("inf")), "clip"),
    (dict(eps=1.0, clip=3.0), "clip"),  # surrogate mode owns clip == 0
    (dict(mask_bytes=0), "mask_bytes"),
    (dict(seed=-1), "seed"),
])
def test_privacy_spec_validation_rejects(bad, match):
    spec = ExperimentSpec(task=TaskSpec(kind="logreg", m=M, n=N, d=200),
                          name="x", seed=0)
    spec = dataclasses.replace(spec, privacy=PrivacySpec(**bad))
    with pytest.raises(SpecError, match=match):
        spec.validate()


def test_privacy_spec_toml_roundtrip(tmp_path):
    spec = _spec("sync", {}, "eager",
                 pv=dict(eps=1.5, sensitivity="clip", clip=4.0,
                         secure_agg=True, mask_bytes=48, seed=11))
    f = tmp_path / "private.toml"
    spec.dump(f)
    assert ExperimentSpec.load(f) == spec
    assert "[privacy]" in f.read_text()


def test_bundled_fig9_spec_roundtrips(tmp_path):
    """The shipped fig9 cell (the CI privacy smoke's input) validates,
    builds a live accountant, and survives a dump/load cycle."""
    src = FIXTURES.parent.parent / "examples" / "specs" / "fig9_privacy.toml"
    spec = ExperimentSpec.load(src).validate()
    assert spec.privacy.eps == 2.0 and spec.privacy.secure_agg
    f = tmp_path / "fig9.toml"
    spec.dump(f)
    assert ExperimentSpec.load(f) == spec
    h = spec.build()
    assert h.sim._privacy is not None
    assert h.sim._privacy.cfg.secure_agg


def test_cli_privacy_flags(tmp_path):
    """--dp-eps/--dp-clip/--secure-agg/--privacy-seed reach the model
    (summary carries the accountant block), same seed reproduces, and
    ownership violations + --spec conflicts error out."""
    outs = []
    for i in range(2):
        p = tmp_path / f"run{i}.json"
        rc = simulate.main([
            "--alg", "fedepm", "--aggregation", "sync",
            "--m", "8", "--d", "400", "--rounds", "4", "--seed", "3",
            "--dp-eps", "2.0", "--dp-clip", "5.0", "--secure-agg",
            "--privacy-seed", "11", "--quiet", "--json", str(p)])
        assert rc == 0
        outs.append(json.loads(p.read_text()))
    assert outs[0] == outs[1]
    pvs = outs[0]["privacy"]
    assert pvs["eps_per_round"] == 2.0
    assert pvs["charges"] > 0 and pvs["mask_attempts"] > 0
    with pytest.raises(SystemExit):  # --dp-clip needs --dp-eps
        simulate.main(["--alg", "fedepm", "--m", "8", "--d", "400",
                       "--rounds", "2", "--dp-clip", "1.0", "--quiet"])
    with pytest.raises(SystemExit):  # --privacy-seed needs a privacy owner
        simulate.main(["--alg", "fedepm", "--m", "8", "--d", "400",
                       "--rounds", "2", "--privacy-seed", "4", "--quiet"])
    with pytest.raises(SystemExit):  # privacy flags conflict with --spec
        simulate.main(["--spec", "examples/specs/fig9_privacy.toml",
                       "--dp-eps", "0.5", "--quiet"])
