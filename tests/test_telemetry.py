"""Run telemetry (repro.telemetry): the recorder must be invisible to the
trajectory (bit-for-bit on/off across every policy and both engines), the
scan engine must reconstruct the eager event stream exactly, and the sinks
(JSONL, summary block, Perfetto trace) must round-trip/validate. Plus the
ByteLedger snapshot/delta API and the event->metric derivation rules."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                       # optional, like the kernel tests
    hypothesis = None

from repro import spec as xspec
from repro.core import fedepm
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.launch import simulate
from repro.sim import CodecConfig, FedSim, SimConfig, make_profiles, \
    run_rounds
from repro.sim.transport import ByteLedger
from repro.spec.types import SpecError
from repro.telemetry import (
    EVENT_KINDS,
    Event,
    EventRecorder,
    MetricsRegistry,
    NULL_RECORDER,
    read_events_jsonl,
    to_trace,
    validate_trace,
    write_events_jsonl,
)

M = 12
N = 10

POLICIES = [
    ("sync", {}),
    ("deadline", {"deadline": 0.002}),
    ("adaptive", {"deadline_slack": 1.5, "ewma_beta": 0.5}),
    ("overselect", {"overselect_factor": 1.5}),
    ("async", {"buffer_size": 3, "max_concurrency": 4}),
]
CLOCKED = POLICIES[:4]


@pytest.fixture(scope="module")
def task():
    X, y = synth.adult_like(d=800, n=N, seed=0)
    batches = jax.tree_util.tree_map(jnp.asarray,
                                     partition_iid(X, y, m=M, seed=0))
    return batches, make_logistic_loss()


def _build(task, policy, kw, *, codec=None, availability=0.9, eps=0.1,
           seed=9, profile_seed=5, telemetry=None):
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(
        m=M, rho=0.5, k0=2, eps_dp=eps, sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim_cfg = SimConfig(policy=policy, latency="pareto", latency_alpha=1.3,
                        seed=seed, codec=codec, **kw)
    return FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                  loss_fn=loss,
                  profiles=make_profiles(M, seed=profile_seed,
                                         availability=availability),
                  sim=sim_cfg, telemetry=telemetry)


def _run(sim, rounds, engine):
    if engine == "eager":
        sim.run(rounds)
    else:
        run_rounds(sim, rounds, chunk=2)


# ---------------------------------------------------------------------------
# the overhead contract: recording cannot perturb the trajectory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["eager", "scan"])
@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_recorder_on_off_bitforbit(task, policy, kw, engine):
    """Telemetry-on state/clock/metrics/ledger == telemetry-off, exactly,
    under every policy and both engines (the recorder reads host values
    only -- no RNG draws, no jit dispatches)."""
    off = _build(task, policy, kw)
    on = _build(task, policy, kw, telemetry=EventRecorder())
    _run(off, 5, engine)
    _run(on, 5, engine)
    for name, a, b in zip(off.state._fields, on.state, off.state):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"state leaf {name!r} diverged with telemetry on"
    assert on.t == off.t
    assert on.round_idx == off.round_idx
    assert on.metrics == off.metrics
    assert on.ledger.total_up == off.ledger.total_up
    assert on.ledger.total_down == off.ledger.total_down
    assert on.telemetry.events, "enabled recorder captured nothing"
    assert off.telemetry is NULL_RECORDER


@pytest.mark.parametrize("policy,kw", CLOCKED, ids=[p for p, _ in CLOCKED])
def test_eager_scan_event_streams_identical(task, policy, kw):
    """The scan engine's bookkeeping loop reconstructs the eager event
    stream EXACTLY (same kinds, timestamps, clients, attrs), including
    across chunk boundaries."""
    eager = _build(task, policy, kw, telemetry=EventRecorder())
    scan = _build(task, policy, kw, telemetry=EventRecorder())
    eager.run(5)
    run_rounds(scan, 3, chunk=2)
    run_rounds(scan, 2)
    assert scan.telemetry.events == eager.telemetry.events


def test_codec_and_ledger_events(task):
    """A lossy codec run emits codec_encode with the codec's parameters
    and ledger_record events whose running totals match the ledger."""
    codec = CodecConfig(topk_frac=0.5, bits=8)
    sim = _build(task, "sync", {}, codec=codec, eps=0.0,
                 telemetry=EventRecorder())
    sim.run(4)
    encs = [e for e in sim.telemetry.events if e.kind == "codec_encode"]
    assert encs and all(e.attrs["bits"] == 8 and e.attrs["topk_frac"] == 0.5
                        for e in encs)
    recs = [e for e in sim.telemetry.events if e.kind == "ledger_record"]
    assert recs
    assert recs[-1].attrs["total_up"] == sim.ledger.total_up
    assert recs[-1].attrs["total_down"] == sim.ledger.total_down
    # per-round deltas sum to the totals
    assert sum(e.attrs["up"] for e in recs) == pytest.approx(
        sim.ledger.total_up)


# ---------------------------------------------------------------------------
# sinks: JSONL round-trip, Perfetto validation
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip_exact(task, tmp_path):
    """read(write(events)) == events, exactly -- every field of every
    event, including float timestamps and attr payloads."""
    sim = _build(task, "async", {"buffer_size": 3, "max_concurrency": 4},
                 codec=CodecConfig(topk_frac=0.5, bits=8), eps=0.0,
                 telemetry=EventRecorder())
    sim.run(6)
    path = tmp_path / "events.jsonl"
    write_events_jsonl(sim.telemetry.events, path)
    assert read_events_jsonl(path) == sim.telemetry.events


@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_trace_export_validates(task, policy, kw):
    """Every exported trace event carries the Chrome trace_event required
    keys and the client events land on per-client tracks (pid 2)."""
    sim = _build(task, policy, kw, telemetry=EventRecorder())
    sim.run(5)
    trace = to_trace(sim.telemetry.events, label=policy)
    assert validate_trace(trace) == []
    evs = trace["traceEvents"]
    assert all({"name", "ph", "ts", "pid", "tid"} <= set(e) for e in evs)
    client_tids = {e["tid"] for e in evs
                   if e["pid"] == 2 and e["ph"] != "M"}
    assert len(client_tids) > 1, "expected one track per client"
    names = {e["name"] for e in evs if e["ph"] == "M"}
    assert "process_name" in names and "thread_name" in names


def test_validate_trace_flags_problems():
    assert validate_trace({}) != []
    assert validate_trace({"traceEvents": []}) != []
    bad = {"traceEvents": [{"name": "x", "ph": "i", "ts": 0.0, "pid": 1}]}
    assert any("tid" in p for p in validate_trace(bad))
    neg = {"traceEvents": [{"name": "x", "ph": "X", "ts": 1.0, "pid": 1,
                            "tid": 0, "dur": -5.0}]}
    assert validate_trace(neg) != []


# ---------------------------------------------------------------------------
# per-client timestamp monotonicity
# ---------------------------------------------------------------------------

def _assert_monotone_per_client(events):
    per_client: dict = {}
    for ev in events:
        if ev.client is None:
            continue
        last = per_client.get(ev.client)
        assert last is None or ev.ts >= last, \
            (ev.client, last, ev.ts, ev.kind)
        per_client[ev.client] = ev.ts
    assert per_client, "no client-scoped events recorded"


@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_timestamps_monotone_per_client(task, policy, kw):
    sim = _build(task, policy, kw, telemetry=EventRecorder())
    sim.run(6)
    _assert_monotone_per_client(sim.telemetry.events)


if hypothesis is not None:
    @hypothesis.settings(deadline=None, max_examples=10)
    @hypothesis.given(seed=st.integers(0, 2**16),
                      profile_seed=st.integers(0, 2**16))
    def test_timestamps_monotone_property(task, seed, profile_seed):
        """Any fleet/arrival randomization keeps each client's event track
        monotone in simulated time (the async event loop's clock and the
        clocked policies' min(arrival, dur) clamp both guarantee it)."""
        sim = _build(task, "async",
                     {"buffer_size": 2, "max_concurrency": 3},
                     seed=seed, profile_seed=profile_seed,
                     telemetry=EventRecorder())
        sim.run(4)
        _assert_monotone_per_client(sim.telemetry.events)


# ---------------------------------------------------------------------------
# ByteLedger snapshot/delta
# ---------------------------------------------------------------------------

def test_ledger_snapshot_delta():
    led = ByteLedger(4)
    s0 = led.snapshot()
    led.record_round(down_mask=np.array([True, True, False, False]),
                     up_mask=np.array([True, False, False, False]),
                     down_bytes=100, up_bytes=40)
    s1 = led.snapshot()
    assert led.delta(s0) == {"up": 40.0, "down": 200.0}
    assert s1.up == led.total_up and s1.down == led.total_down
    led.record_round(down_mask=np.array([False, False, True, True]),
                     up_mask=np.array([False, False, True, True]),
                     down_bytes=100, up_bytes=40.5)  # float path
    assert led.delta(s1) == {"up": 81.0, "down": 200.0}
    assert led.delta(s0)["up"] == pytest.approx(121.0)
    # the O(1) totals agree with the per-client array sums
    assert led.total_up == pytest.approx(float(led.up.sum()))
    assert led.total_down == pytest.approx(float(led.down.sum()))


# ---------------------------------------------------------------------------
# metrics registry: event-stream derivation
# ---------------------------------------------------------------------------

def test_registry_replay_reproduces_summary(task):
    """Metrics are a pure fold over the event stream: replaying a run's
    events through a fresh registry reproduces the summary exactly."""
    sim = _build(task, "async", {"buffer_size": 3, "max_concurrency": 4},
                 telemetry=EventRecorder())
    sim.run(6)
    fresh = MetricsRegistry()
    for ev in sim.telemetry.events:
        fresh.observe(ev)
    assert fresh.summary() == sim.telemetry.registry.summary()


def test_registry_derivation_rules():
    reg = MetricsRegistry()
    reg.observe(Event(0.0, "round_start", 0, None, {"policy": "sync"}))
    reg.observe(Event(0.0, "dispatch", 0, 1, {"arrival_s": 0.5}))
    reg.observe(Event(0.5, "upload_arrival", 0, 1, {}))
    reg.observe(Event(1.0, "merge", 0, 1, {"staleness": 2, "gamma": 0.5}))
    reg.observe(Event(1.0, "ledger_record", 0, None,
                      {"up": 10.0, "down": 20.0}))
    reg.observe(Event(2.0, "abandon", 1, None, {"n_contacted": 0}))
    s = reg.summary()
    assert s["counters"] == {"rounds": 1.0, "dispatches": 1.0,
                             "uploads": 1.0, "merges": 1.0,
                             "abandoned_rounds": 1.0,
                             "bytes_up": 10.0, "bytes_down": 20.0}
    assert s["gauges"]["staleness"] == 2
    assert s["histograms"]["staleness"]["dist"] == {"2": 1}
    assert s["series"]["bytes_up"] == [[1.0, 10.0]]


def test_recorder_rejects_unknown_kind():
    rec = EventRecorder()
    with pytest.raises(ValueError, match="unknown event kind"):
        rec.event("warp_drive", ts=0.0, round_idx=0)
    assert set(EVENT_KINDS) == {
        "round_start", "dispatch", "upload_arrival", "merge", "abandon",
        "codec_encode", "ledger_record",
        "upload_drop", "retry", "duplicate_discard", "quarantine",
        "privacy_charge", "mask_exchange"}


# ---------------------------------------------------------------------------
# spec + RunHandle integration (the acceptance scenario)
# ---------------------------------------------------------------------------

def _async_spec(**tel):
    return xspec.ExperimentSpec(
        name="tel-accept", seed=3,
        task=xspec.TaskSpec(kind="logreg", d=400, n=N, m=M),
        algorithm=xspec.AlgorithmSpec(name="fedepm", rho=0.5, k0=2),
        fleet=xspec.FleetSpec(kind="synthetic", latency="pareto",
                              latency_alpha=1.2),
        policy=xspec.PolicySpec(name="async", buffer_size=3,
                                max_concurrency=4),
        engine=xspec.EngineSpec(name="eager", rounds=6),
        telemetry=xspec.TelemetrySpec(**tel))


def test_runhandle_summary_and_sinks(tmp_path):
    """The fig7-style acceptance run: JSONL + summary series + loadable
    trace, with the objective trajectory bit-for-bit identical to
    telemetry-off and the historical summary schema untouched."""
    ej, tr = tmp_path / "ev.jsonl", tmp_path / "trace.json"
    on = _async_spec(enabled=True, events_jsonl=str(ej),
                     trace_out=str(tr)).validate().build().run()
    off = _async_spec().validate().build().run()
    tel = on.pop("telemetry")
    assert on == off, "telemetry changed the trajectory or summary schema"
    for k in ("bytes_up", "bytes_down", "staleness", "in_flight",
              "stalled", "objective"):
        assert tel["series"].get(k), (k, sorted(tel["series"]))
    assert tel["counters"]["merges"] > 0
    assert tel["wall_s"] > 0 and tel["host_syncs"] > 0
    assert len(read_events_jsonl(ej)) == tel["events"]
    trace = json.loads(tr.read_text())
    assert validate_trace(trace) == []


def test_scan_engine_summary_matches_eager_with_telemetry():
    """engine=scan under telemetry: same f_final as eager, same series."""
    eager = _async_spec(enabled=True).validate()
    scan = eager.replace(**{"engine.name": "scan"}).validate()
    a, b = eager.build().run(), scan.build().run()
    assert a["f_final"] == b["f_final"]
    assert a["telemetry"]["counters"] == b["telemetry"]["counters"]


def test_telemetry_spec_validation():
    with pytest.raises(SpecError, match="enabled"):
        _async_spec(trace_out="x.json").validate()
    with pytest.raises(SpecError, match="enabled"):
        _async_spec(events_jsonl="x.jsonl").validate()
    with pytest.raises(SpecError):
        _async_spec(enabled=True, trace_out="").validate()
    _async_spec(enabled=True).validate()          # sinks are optional
    # dict round-trip keeps the section
    spec = _async_spec(enabled=True, trace_out="t.json")
    again = xspec.ExperimentSpec.from_dict(spec.to_dict())
    assert again.telemetry == spec.telemetry


# ---------------------------------------------------------------------------
# CLI glue
# ---------------------------------------------------------------------------

def test_cli_telemetry_flags(tmp_path):
    """--events-out/--trace-out imply --telemetry; the summary gains the
    telemetry block and stays otherwise identical to a flag-free run."""
    ej = tmp_path / "ev.jsonl"
    tr = tmp_path / "trace.json"
    base = ["--alg", "fedepm", "--aggregation", "async",
            "--buffer-size", "3", "--latency", "pareto",
            "--m", "8", "--d", "500", "--rounds", "4", "--seed", "3",
            "--quiet"]
    on_p, off_p = tmp_path / "on.json", tmp_path / "off.json"
    assert simulate.main(base + ["--json", str(on_p),
                                 "--events-out", str(ej),
                                 "--trace-out", str(tr)]) == 0
    assert simulate.main(base + ["--json", str(off_p)]) == 0
    on = json.loads(on_p.read_text())
    off = json.loads(off_p.read_text())
    tel = on.pop("telemetry")
    assert on == off
    assert tel["events"] == len(read_events_jsonl(ej))
    assert validate_trace(json.loads(tr.read_text())) == []


def test_cli_spec_telemetry_override(tmp_path):
    """--telemetry on top of --spec enables recording for a spec file that
    has no [telemetry] section."""
    import pathlib
    spec_path = str(pathlib.Path(__file__).parent.parent
                    / "examples" / "specs" / "fig7_async.toml")
    out = tmp_path / "s.json"
    rc = simulate.main(["--spec", spec_path,
                        "--rounds", "3", "--telemetry", "--quiet",
                        "--json", str(out)])
    assert rc == 0
    s = json.loads(out.read_text())
    assert s["telemetry"]["counters"]["rounds"] >= 1
