"""Differential harness for the scan-compiled ASYNC engine.

The async policy is host-driven (event heap, staleness bookkeeping,
adaptive cutoffs), so the scan engine runs it in two passes: a recording
pass executes the SAME event-loop pump as the eager engine against a
fixed-capacity payload table, then one jitted ``lax.scan`` replays every
dispatch and staleness-masked merge on device (repro.sim.engine's module
docstring has the layout). This file pins the replay to the eager loop
bit-for-bit -- not allclose -- across the knobs that change the event
interleaving:

  * buffer size (aggregation trigger) and max_concurrency, including a
    cap SMALLER than the refill draw so one dispatch splits across
    slot-release instants;
  * the unset-cap cell (whole cohorts dispatch in one round call);
  * staleness exponent 0 (gamma = 1, exact-replace merge branch) and a
    steep exponent (deep blend);
  * memoryless and error-feedback codecs (EF threads residuals through
    the payload table);
  * all three algorithms (fedepm, sfedavg, sfedprox);
  * chunk boundaries -- every dispatch its own chunk, uneven chunks,
    repeated run_rounds calls -- which must be invisible;
  * a pinned event_table_capacity (fixed slots, overflow = error);
  * telemetry: the scan engine's recording pass must emit the EXACT event
    stream (every Event tuple) the eager loop does;
  * --terminate through the CLI: identical summaries, including the
    stopping round, via snapshot/rollback at chunk granularity.

Also here: deterministic event-loop property checks shared with the
hypothesis sweep in test_async_properties.py (heap pop order, in-flight
cap, ledger balance, staleness histogram).
"""
import heapq
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, fedepm
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.launch import simulate
from repro.sim import CodecConfig, FedSim, SimConfig, make_profiles, run_rounds
from repro.telemetry.events import EventRecorder

M = 16
N = 14


@pytest.fixture(scope="module")
def task():
    X, y = synth.adult_like(d=2000, n=N, seed=0)
    batches = jax.tree_util.tree_map(jnp.asarray,
                                     partition_iid(X, y, m=M, seed=0))
    return batches, make_logistic_loss()


def build_async(task, kw, *, alg="fedepm", codec=None, eps=0.1, seed=9,
                availability=0.9):
    """One async FedSim on the shared logreg task (module-level so the
    hypothesis property sweep can reuse it)."""
    batches, loss = task
    if alg == "fedepm":
        cfg = fedepm.FedEPMConfig.paper_defaults(
            m=M, rho=0.5, k0=2, eps_dp=eps, sensitivity_clip=1.0)
        s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    else:
        cfg = baselines.BaselineConfig(m=M, k0=2, rho=0.5, eps_dp=eps)
        s0 = baselines.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim_cfg = SimConfig(policy="async", latency="pareto", latency_alpha=1.3,
                        seed=seed, codec=codec, **kw)
    return FedSim(alg=alg, cfg=cfg, state=s0, batches=batches, loss_fn=loss,
                  profiles=make_profiles(M, seed=5,
                                         availability=availability),
                  sim=sim_cfg)


def _assert_bitforbit(eager: FedSim, scan: FedSim):
    for name, a, b in zip(eager.state._fields, scan.state, eager.state):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"state leaf {name!r} diverged"
    assert scan.t == eager.t
    assert scan.round_idx == eager.round_idx
    assert scan.metrics == eager.metrics
    assert scan.ledger.total_up == eager.ledger.total_up
    assert scan.ledger.total_down == eager.ledger.total_down
    np.testing.assert_array_equal(scan.ledger.up, eager.ledger.up)
    np.testing.assert_array_equal(scan.ledger.down, eager.ledger.down)


# ---------------------------------------------------------------------------
# the knob sweep: scan == eager, bit for bit
# ---------------------------------------------------------------------------

# (id, alg, SimConfig kwargs, error_feedback (None = no codec), chunk)
CASES = [
    ("buf4-cap5", "fedepm",
     {"buffer_size": 4, "max_concurrency": 5}, None, None),
    ("small-buffer", "fedepm",
     {"buffer_size": 2, "max_concurrency": 5}, None, 2),
    ("big-buffer", "fedepm",
     {"buffer_size": 6, "max_concurrency": 8}, None, 3),
    # cap < refill draw: a single selection's dispatch splits across
    # slot-release instants, exercising the stalled FIFO + partial fires
    ("cap-splits-dispatch", "fedepm",
     {"buffer_size": 3, "max_concurrency": 2}, None, None),
    ("uncapped", "fedepm",
     {"buffer_size": 3}, None, 2),
    # staleness_exp = 0 -> gamma = 1 exactly -> the merge's exact-replace
    # branch; 2.0 -> steep down-weighting of stale contributions
    ("stale-exp0", "fedepm",
     {"buffer_size": 3, "max_concurrency": 4, "staleness_exp": 0.0},
     None, None),
    ("stale-exp2", "fedepm",
     {"buffer_size": 3, "max_concurrency": 4, "staleness_exp": 2.0},
     None, 3),
    ("codec-memoryless", "fedepm",
     {"buffer_size": 3, "max_concurrency": 4}, False, None),
    ("codec-ef", "fedepm",
     {"buffer_size": 3, "max_concurrency": 4}, True, 3),
    ("sfedavg", "sfedavg",
     {"buffer_size": 3, "max_concurrency": 4}, None, None),
    ("sfedprox", "sfedprox",
     {"buffer_size": 3, "max_concurrency": 4}, None, 2),
]


@pytest.mark.parametrize("alg,kw,ef,chunk", [c[1:] for c in CASES],
                         ids=[c[0] for c in CASES])
def test_async_scan_matches_eager_bitforbit(task, alg, kw, ef, chunk):
    """6 aggregation events under a heterogeneous, partially-available
    Pareto fleet with DP noise on: the replayed scan trajectory (state
    leaves, key, clock, metrics incl. staleness stats, per-client ledger
    rows) is the eager event loop's, exactly."""
    codec = None if ef is None else CodecConfig(topk_frac=0.5, bits=8,
                                                error_feedback=ef)
    eager = build_async(task, kw, alg=alg, codec=codec)
    scan = build_async(task, kw, alg=alg, codec=codec)
    eager.run(6)
    res = run_rounds(scan, 6, chunk=chunk)
    assert len(res.metrics) == 6
    assert any(m.staleness_max > 0 for m in eager.metrics), \
        "scenario produced no stale merges -- sweep lost its teeth"
    _assert_bitforbit(eager, scan)
    if ef:
        for a, b in zip(jax.tree_util.tree_leaves(eager._H),
                        jax.tree_util.tree_leaves(scan._H)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_chunk_boundaries_invisible(task):
    """chunk=1 (every aggregation event its own compiled chunk), uneven
    chunks, and back-to-back run_rounds calls all land on the same
    trajectory as 7 eager events."""
    kw = {"buffer_size": 3, "max_concurrency": 4}
    eager = build_async(task, kw)
    eager.run(7)
    for chunks in ([(7, 1)], [(3, 2), (4, 3)], [(2, None), (5, 2)]):
        scan = build_async(task, kw)
        for rounds, chunk in chunks:
            run_rounds(scan, rounds, chunk=chunk)
        _assert_bitforbit(eager, scan)


def test_async_collect_w_tau_stream(task):
    """collect_w_tau returns each aggregation event's broadcast point --
    the exact states an eager run passes through."""
    kw = {"buffer_size": 3, "max_concurrency": 4}
    eager = build_async(task, kw)
    scan = build_async(task, kw)
    res = run_rounds(scan, 4, chunk=2, collect_w_tau=True)
    assert res.w_tau.shape[0] == 4
    for t in range(4):
        eager.step()
        np.testing.assert_array_equal(res.w_tau[t],
                                      np.asarray(eager.state.w_tau))


def test_async_engine_interop(task):
    """Eager and scan legs interleave freely on one sim: the event-loop
    state (heap, stalled FIFO, RNG, payload slots) hands off exactly."""
    kw = {"buffer_size": 3, "max_concurrency": 4}
    eager = build_async(task, kw)
    mixed = build_async(task, kw)
    eager.run(8)
    mixed.run(2)
    run_rounds(mixed, 3)
    mixed.run(2)
    run_rounds(mixed, 1)
    _assert_bitforbit(eager, mixed)


# ---------------------------------------------------------------------------
# event-table capacity + mesh knobs
# ---------------------------------------------------------------------------

def test_event_table_capacity_pinned(task):
    """A sufficient pinned capacity is trajectory-neutral; an insufficient
    one is an ERROR (the fixed table refuses to grow), naming the knob."""
    kw = {"buffer_size": 3, "max_concurrency": 4}
    eager = build_async(task, kw)
    scan = build_async(task, kw)
    eager.run(4)
    run_rounds(scan, 4, event_table_capacity=8)
    _assert_bitforbit(eager, scan)

    tiny = build_async(task, kw)
    with pytest.raises(ValueError, match="event_table_capacity"):
        run_rounds(tiny, 4, event_table_capacity=1)


def test_async_mesh_single_device_bitidentical(task):
    """A 1-device mesh shards the client axis trivially; the trajectory
    must be bit-identical to the unsharded run (and hence to eager)."""
    kw = {"buffer_size": 3, "max_concurrency": 4}
    plain = build_async(task, kw)
    sharded = build_async(task, kw)
    run_rounds(plain, 5, chunk=2)
    run_rounds(sharded, 5, chunk=2, mesh=1)
    _assert_bitforbit(plain, sharded)


# ---------------------------------------------------------------------------
# telemetry: the recording pass reproduces the eager event stream exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ef", [None, True], ids=["plain", "codec-ef"])
def test_async_telemetry_event_stream_equal(task, ef):
    """Every telemetry Event -- kind, simulated timestamp, round, client,
    attrs (dur_s, version, in_flight, stalled, staleness, gamma, codec
    bytes, ledger totals) -- is identical between engines, element for
    element. The scan engine's recording pass IS the eager pump, so the
    stream equality is by construction; this pins it."""
    codec = None if ef is None else CodecConfig(topk_frac=0.5, bits=8,
                                                error_feedback=True)
    kw = {"buffer_size": 3, "max_concurrency": 4}
    eager = build_async(task, kw, codec=codec)
    scan = build_async(task, kw, codec=codec)
    eager.attach_telemetry(EventRecorder())
    scan.attach_telemetry(EventRecorder())
    eager.run(5)
    run_rounds(scan, 5, chunk=2)
    assert len(eager.telemetry.events) > 0
    assert scan.telemetry.events == eager.telemetry.events
    kinds = {ev.kind for ev in eager.telemetry.events}
    assert {"round_start", "dispatch", "upload_arrival",
            "merge"} <= kinds


# ---------------------------------------------------------------------------
# --terminate parity through the CLI
# ---------------------------------------------------------------------------

def _run_cli_async(tmp_path, engine, rounds, extra=()):
    p = tmp_path / f"{engine}.json"
    rc = simulate.main([
        "--alg", "fedepm", "--aggregation", "async",
        "--buffer-size", "3", "--max-concurrency", "4",
        "--latency", "pareto", "--engine", engine,
        "--m", "8", "--d", "1000", "--rounds", str(rounds),
        "--seed", "3", "--quiet", "--json", str(p), *extra])
    assert rc == 0
    return json.loads(p.read_text())


def test_cli_terminate_parity_async(tmp_path):
    """--terminate under --engine scan stops at EXACTLY the eager
    stopping round (snapshot/rollback at chunk granularity) and the whole
    summary -- f_final, rounds, simulated time, byte totals, staleness
    stats -- matches field for field."""
    a = _run_cli_async(tmp_path, "eager", 120, ("--terminate",))
    b = _run_cli_async(tmp_path, "scan", 120, ("--terminate",))
    assert a.pop("engine") == "eager" and b.pop("engine") == "scan"
    assert a["rounds"] < 120, \
        "termination never fired -- the parity check is vacuous"
    assert a == b


def test_cli_async_scan_matches_eager(tmp_path):
    """Fixed-budget async CLI runs: identical summaries."""
    a = _run_cli_async(tmp_path, "eager", 4)
    b = _run_cli_async(tmp_path, "scan", 4)
    assert a.pop("engine") == "eager" and b.pop("engine") == "scan"
    assert a == b


# ---------------------------------------------------------------------------
# event-loop properties (deterministic grid; hypothesis sweep reuses these
# helpers from test_async_properties.py)
# ---------------------------------------------------------------------------

def check_pop_order_matches_heapq(events):
    """Upload arrivals must pop in (finish time, dispatch order) order --
    i.e. the engine's event queue behaves as the reference heapq: replay
    the stream, pushing each live dispatch's finish instant and popping on
    each arrival."""
    heap, seq, checked = [], 0, 0
    for ev in events:
        if ev.kind == "dispatch" and ev.attrs.get("live", True):
            heapq.heappush(heap, (ev.ts + ev.attrs["dur_s"], seq, ev.client))
            seq += 1
        elif ev.kind == "upload_arrival":
            t_fin, _, client = heapq.heappop(heap)
            assert client == ev.client, \
                f"arrival order diverged from heapq reference at #{checked}"
            assert ev.ts == t_fin
            checked += 1
    assert checked > 0
    return checked


def check_inflight_never_exceeds_cap(events, cap):
    """The dispatcher never holds more than max_concurrency uploads in
    flight (both the engine's own counter and an independent recount).
    Dispatch events of one fired group all carry the post-group total, so
    the recount matches it exactly at the group's last event and bounds it
    from below inside the group; arrivals match exactly."""
    inflight = 0
    for ev in events:
        if ev.kind == "dispatch" and ev.attrs.get("live", True):
            inflight += 1
            assert inflight <= ev.attrs["in_flight"]
        elif ev.kind == "upload_arrival":
            inflight -= 1
            assert inflight == ev.attrs["in_flight"]
        else:
            continue
        if cap:
            assert inflight <= cap and ev.attrs["in_flight"] <= cap
    assert inflight >= 0


def check_ledger_balances(sim):
    """The ledger's running totals equal the per-event metrics' sums and
    the per-client rows' sums -- every recorded byte is accounted once."""
    assert sim.ledger.total_up == sum(m.bytes_up for m in sim.metrics)
    assert sim.ledger.total_down == sum(m.bytes_down for m in sim.metrics)
    assert sim.ledger.total_up == int(np.sum(sim.ledger.up))
    assert sim.ledger.total_down == int(np.sum(sim.ledger.down))


def staleness_histogram(events):
    """Histogram {staleness -> merge count} from the telemetry stream."""
    hist: dict[int, int] = {}
    for ev in events:
        if ev.kind == "merge":
            s = int(ev.attrs["staleness"])
            hist[s] = hist.get(s, 0) + 1
    return hist


PROP_GRID = [
    ("capped", {"buffer_size": 3, "max_concurrency": 4}),
    ("tight-cap", {"buffer_size": 4, "max_concurrency": 2}),
    ("uncapped", {"buffer_size": 3}),
]


@pytest.mark.parametrize("kw", [g[1] for g in PROP_GRID],
                         ids=[g[0] for g in PROP_GRID])
def test_async_event_loop_properties(task, kw):
    eager = build_async(task, kw, seed=11)
    scan = build_async(task, kw, seed=11)
    eager.attach_telemetry(EventRecorder())
    scan.attach_telemetry(EventRecorder())
    eager.run(5)
    run_rounds(scan, 5, chunk=2)
    assert check_pop_order_matches_heapq(eager.telemetry.events) > 0
    check_inflight_never_exceeds_cap(eager.telemetry.events,
                                     kw.get("max_concurrency"))
    check_ledger_balances(eager)
    check_ledger_balances(scan)
    h = staleness_histogram(eager.telemetry.events)
    assert h == staleness_histogram(scan.telemetry.events)
    assert sum(h.values()) == sum(m.n_aggregated for m in eager.metrics)
